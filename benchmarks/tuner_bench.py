"""Tuner validation bench: does ``repro.tuning`` rediscover the paper's
tuning rules from cost models + simulation, without being told them?

Three rule checks (the actionable findings of §5.2/§7):

1. **Index-class crossover** — graph (DiskANN-class) wins the
   very-high-recall × high-concurrency × high-dim regime on cloud
   storage; cluster (SPANN-class) wins low-recall serving on cheap/fast
   storage.  (RQ1/RQ2, Figs 7–9)
2. **Cloud-vs-SSD nprobe gap** — at equal recall targets the recommended
   nprobe on high-TTFB cloud storage is a multiple of the SSD one: the
   TTFB floor makes extra probes nearly free, so the tuner buys recall
   headroom.  (Figs 18–19)
3. **Cache-size-dependent policy flip** — with a small cache the tuner
   pins the hot set (scan-resistant, no churn); with a large cache it
   switches to SLRU, which adapts beyond any fixed pinned set.  (§7 A3,
   Figs 20–25; this check is simulation-backed.)

Every autotune call also asserts the analytic screen pruned ≥90% of the
joint space before any simulation ran.

    PYTHONPATH=src python benchmarks/tuner_bench.py

Exit status is non-zero if any rule fails.
"""
import sys

from common import emit

from repro.tuning import (EnvSpec, EvalBudget, WorkloadSpec, autotune,
                          resolve_storage)

MIN_PRUNE = 0.90
_failures: list[str] = []
_prunes: list[float] = []


def _check(name: str, ok: bool, detail: str) -> None:
    print(f"# [{name}] {'PASS' if ok else 'FAIL'}: {detail}",
          file=sys.stderr)
    if not ok:
        _failures.append(name)


def _tuned(name, w, env, budget):
    rec = autotune(w, env, budget=budget)
    _prunes.append(rec.prune_fraction)
    emit(f"tuner/{name}", 1e6 / max(rec.pred_qps, 1e-9),
         kind=rec.config.kind, policy=rec.config.cache_policy,
         nprobe=rec.config.nprobe if rec.config.kind == "cluster" else 0,
         qps=rec.pred_qps, recall=rec.pred_recall,
         prune=rec.prune_fraction, simulated=rec.simulated)
    return rec


def rule1_index_class_crossover():
    """High recall × concurrency × dim on cloud → graph; low recall on
    fast storage → cluster.  Both ends are simulation-backed."""
    hi = WorkloadSpec(n=1_000_000, dim=960, target_recall=0.995,
                      concurrency=64)
    rec_hi = _tuned("crossover-hi", hi,
                    EnvSpec(storage=resolve_storage("tos")),
                    EvalBudget(rungs=((300, 12),), max_rung0=6))
    lo = WorkloadSpec(n=10_000_000, dim=96, target_recall=0.7,
                      concurrency=1)
    rec_lo = _tuned("crossover-lo", lo,
                    EnvSpec(storage=resolve_storage("ssd")),
                    EvalBudget(rungs=((800, 20),), max_rung0=6))
    _check("rule1-crossover",
           rec_hi.config.kind == "graph" and rec_lo.config.kind == "cluster",
           f"hi-recall/conc/dim on cloud -> {rec_hi.config.kind} "
           f"(want graph); low-recall on SSD -> {rec_lo.config.kind} "
           f"(want cluster)")
    _check("rule1-simulated",
           rec_hi.simulated > 0 and rec_lo.simulated > 0,
           f"simulated configs: hi={rec_hi.simulated} lo={rec_lo.simulated}")


def rule2_nprobe_gap():
    """Same recall target, cluster-only: cloud nprobe ≫ SSD nprobe."""
    def tune(storage):
        w = WorkloadSpec(n=1_000_000, dim=128, dtype="int8",
                         target_recall=0.9, concurrency=1)
        rec = autotune(w, EnvSpec(storage=resolve_storage(storage)),
                       budget="screen", kinds=("cluster",))
        _prunes.append(rec.prune_fraction)
        emit(f"tuner/nprobe-{storage}", 1e6 / max(rec.pred_qps, 1e-9),
             nprobe=rec.config.nprobe, qps=rec.pred_qps,
             recall=rec.pred_recall, prune=rec.prune_fraction)
        return rec
    cloud = tune("tos-external")
    ssd = tune("ssd")
    _check("rule2-nprobe-gap",
           cloud.config.nprobe >= 2 * ssd.config.nprobe,
           f"cloud nprobe={cloud.config.nprobe} vs "
           f"ssd nprobe={ssd.config.nprobe} (want >=2x)")


def rule3_cache_policy_flip():
    """Zipf workload: small cache → pinned hot set; big cache → SLRU.
    Simulation-backed: measured hit rates decide."""
    def tune(gb):
        w = WorkloadSpec(n=10_000_000, dim=96, target_recall=0.9,
                         concurrency=8, query_dist="zipf")
        return _tuned(f"cache-{gb}gb", w,
                      EnvSpec(storage=resolve_storage("tos"),
                              cache_bytes=int(gb * 2**30)),
                      EvalBudget(rungs=((1200, 32),), max_rung0=8))
    small = tune(0.25)
    big = tune(16.0)
    _check("rule3-policy-flip",
           small.config.cache_policy == "pinned"
           and big.config.cache_policy == "slru",
           f"small cache -> {small.config.cache_policy} (want pinned); "
           f"big cache -> {big.config.cache_policy} (want slru)")
    _check("rule3-simulated", small.simulated > 0 and big.simulated > 0,
           f"simulated configs: small={small.simulated} "
           f"big={big.simulated}")


def main() -> int:
    rule1_index_class_crossover()
    rule2_nprobe_gap()
    rule3_cache_policy_flip()
    worst = min(_prunes)
    _check("screen-prune-fraction", worst >= MIN_PRUNE,
           f"worst prune fraction {worst:.3f} (want >= {MIN_PRUNE})")
    if _failures:
        print(f"# tuner_bench: FAILED {_failures}", file=sys.stderr)
        return 1
    print("# tuner_bench: all paper rules rediscovered", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
