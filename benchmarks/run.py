"""Run every benchmark harness; one CSV row per measurement:

    name,us_per_call,derived

Set REPRO_BENCH_QUICK=1 for a fast smoke pass (smaller datasets).
Index builds and search traces are cached under benchmarks/.cache.
"""
from __future__ import annotations

import os
import sys
import time
import traceback

# make both import styles work regardless of the caller's cwd:
# "benchmarks.<mod>" (package) and "from common import emit" (script)
_HERE = os.path.dirname(os.path.abspath(__file__))
for _p in (_HERE, os.path.dirname(_HERE)):
    if _p not in sys.path:
        sys.path.insert(0, _p)

MODULES = [
    "benchmarks.kernel_bench",
    "benchmarks.fig2_overheads",
    "benchmarks.fig7_qps_recall",
    "benchmarks.fig8_query_metrics",
    "benchmarks.fig10_datasets",
    "benchmarks.tab4_fig14_16_centroids_replicas",
    "benchmarks.fig17_19_graph_params",
    "benchmarks.fig20_25_caching",
    "benchmarks.tuner_bench",
    "benchmarks.fleet_bench",
    "benchmarks.ingest_bench",
    "benchmarks.tenancy_bench",
    "benchmarks.tier_bench",
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = []
    for modname in MODULES:
        t0 = time.time()
        print(f"# === {modname} ===", file=sys.stderr)
        try:
            mod = __import__(modname, fromlist=["main"])
            if mod.main():                 # rule/fleet benches return 1 on
                failures.append(modname)   # failed hard checks
                print(f"# FAILED {modname} (hard check)", file=sys.stderr)
        except Exception:
            failures.append(modname)
            print(f"# FAILED {modname}", file=sys.stderr)
            traceback.print_exc()
        print(f"# {modname}: {time.time()-t0:.0f}s", file=sys.stderr)
    if failures:
        print(f"# failures: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
