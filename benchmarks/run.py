"""Run every benchmark harness; one CSV row per measurement:

    name,us_per_call,derived

Set REPRO_BENCH_QUICK=1 for a fast smoke pass (smaller datasets).
Index builds and search traces are cached under benchmarks/.cache.
"""
from __future__ import annotations

import sys
import time
import traceback

MODULES = [
    "benchmarks.kernel_bench",
    "benchmarks.fig2_overheads",
    "benchmarks.fig7_qps_recall",
    "benchmarks.fig8_query_metrics",
    "benchmarks.fig10_datasets",
    "benchmarks.tab4_fig14_16_centroids_replicas",
    "benchmarks.fig17_19_graph_params",
    "benchmarks.fig20_25_caching",
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = []
    for modname in MODULES:
        t0 = time.time()
        print(f"# === {modname} ===", file=sys.stderr)
        try:
            mod = __import__(modname, fromlist=["main"])
            mod.main()
        except Exception:
            failures.append(modname)
            print(f"# FAILED {modname}", file=sys.stderr)
            traceback.print_exc()
        print(f"# {modname}: {time.time()-t0:.0f}s", file=sys.stderr)
    if failures:
        print(f"# failures: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
