"""Fig 17/18/19: DiskANN build/search parameter studies (RQ2, §5.3).

* Fig 17/18: denser graph (R up) cuts roundtrips AND requests per query —
  consistent QPS gains on remote storage despite the bigger index;
* Fig 19: higher beamwidth W cuts roundtrips only at high recall and
  inflates requests/query — a win for low-concurrency high-recall ad-hoc
  queries, a loss once the GET-QPS limit saturates at high concurrency.
"""
from __future__ import annotations

import dataclasses

from repro.core.types import SearchParams
from repro.storage.spec import TOS

from benchmarks.common import (default_graph_params, emit, get_dataset,
                               get_graph_index, replay, sweep_recall_qps)

DATASET = "gist-analog"


def main():
    gp = default_graph_params(DATASET)
    dense = dataclasses.replace(gp, R=128)
    g_base = get_graph_index(DATASET, gp)
    g_dense = get_graph_index(DATASET, dense)

    emit("fig17.size.base", 0.0, index_MB=g_base.meta.index_bytes / 1e6,
         node_KB=g_base.meta.node_nbytes / 1e3)
    emit("fig17.size.dense", 0.0, index_MB=g_dense.meta.index_bytes / 1e6,
         node_KB=g_dense.meta.node_nbytes / 1e3)

    # ---- Fig 17/18: R=base vs dense across recalls & concurrency -------
    for conc in [1, 16, 64]:
        rb = sweep_recall_qps(DATASET, "graph", g_base, concurrency=conc)
        rd = sweep_recall_qps(DATASET, "graph", g_dense, concurrency=conc)
        for (kb, recb, repb), (kd, recd, repd) in zip(rb, rd):
            emit(f"fig17.c{conc}", 0.0,
                 knob=kb, recall_base=recb, recall_dense=recd,
                 ratio=repd.qps / max(repb.qps, 1e-12),
                 rt_base=repb.mean_roundtrips, rt_dense=repd.mean_roundtrips,
                 req_base=repb.mean_requests, req_dense=repd.mean_requests)

    # ---- Fig 19: beamwidth sweep ----------------------------------------
    _, _, gt = get_dataset(DATASET)
    for W in [4, 16, 32, 64]:
        for conc in [1, 4, 64]:
            sp = SearchParams(k=10, search_len=160, beamwidth=W)
            rep = replay(DATASET, "graph", g_base, sp, concurrency=conc)
            iops = rep.storage_requests / max(rep.wall_time_s, 1e-12)
            emit(f"fig19.W{W}.c{conc}", rep.mean_latency * 1e6,
                 recall=rep.recall_against(gt), qps=rep.qps,
                 roundtrips=rep.mean_roundtrips,
                 requests=rep.mean_requests,
                 iops=iops, iops_sat=iops / TOS.get_qps_limit)


if __name__ == "__main__":
    main()
