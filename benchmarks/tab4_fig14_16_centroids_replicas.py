"""Table 4 + Fig 14/15/16: SPANN build-parameter studies (RQ2, §5.3).

* Table 4: index size / list count / list size across configurations;
* Fig 14/15: centroid%=32 (fine-grained lists) wins under I/O congestion
  (high recall × concurrency), loses at low recall/concurrency;
* Fig 16: lower replication shrinks lists but costs index quality —
  higher nprobe needed for the same recall, more data read overall.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import (DEFAULT_CLUSTER, emit, get_cluster_index,
                               get_dataset, sweep_recall_qps)

DATASET = "gist-analog"

C32 = dataclasses.replace(DEFAULT_CLUSTER, centroid_frac=0.32)
R4 = dataclasses.replace(DEFAULT_CLUSTER, num_replica=4)
R2 = dataclasses.replace(DEFAULT_CLUSTER, num_replica=2)

CONFIGS = {
    "c16_r8": DEFAULT_CLUSTER,
    "c32_r8": C32,
    "c16_r4": R4,
    "c16_r2": R2,
}


def _interp_qps(rows, recall_target):
    """QPS at a recall level (nearest sweep point >= target, else last)."""
    for knob, recall, rep in rows:
        if recall >= recall_target:
            return rep, knob, recall
    return rows[-1][2], rows[-1][0], rows[-1][1]


def main():
    idx = {name: get_cluster_index(DATASET, p)
           for name, p in CONFIGS.items()}

    # ---- Table 4 --------------------------------------------------------
    for name, ix in idx.items():
        emit(f"tab4.{name}", 0.0,
             index_MB=ix.meta.index_bytes / 1e6,
             n_lists=ix.meta.n_lists,
             avg_list_KB=ix.meta.avg_list_bytes / 1e3)

    # ---- Fig 14: centroid%=32 / centroid%=16 QPS ratio grid -------------
    for conc in [1, 16, 64]:
        r16 = sweep_recall_qps(DATASET, "cluster", idx["c16_r8"],
                               concurrency=conc)
        r32 = sweep_recall_qps(DATASET, "cluster", idx["c32_r8"],
                               concurrency=conc)
        for target in [0.8, 0.95, 0.99]:
            rep16, k16, rec16 = _interp_qps(r16, target)
            rep32, k32, rec32 = _interp_qps(r32, target)
            emit(f"fig14.c{conc}.r{target}", 0.0,
                 ratio=rep32.qps / max(rep16.qps, 1e-12),
                 qps16=rep16.qps, qps32=rep32.qps,
                 MB16=rep16.mean_bytes_read / 1e6,
                 MB32=rep32.mean_bytes_read / 1e6,
                 io16_ms=rep16.mean_io_latency * 1e3,
                 io32_ms=rep32.mean_io_latency * 1e3)

    # ---- Fig 16: replication sweep --------------------------------------
    for name in ["c16_r8", "c16_r4", "c16_r2"]:
        rows = sweep_recall_qps(DATASET, "cluster", idx[name],
                                concurrency=4)
        for knob, recall, rep in rows:
            emit(f"fig16.{name}", rep.mean_latency * 1e6,
                 nprobe=knob, recall=recall, qps=rep.qps,
                 MB_per_query=rep.mean_bytes_read / 1e6)


if __name__ == "__main__":
    main()
