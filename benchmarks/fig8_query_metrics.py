"""Fig 8 + Fig 9: per-query metrics vs recall on the GIST analogue.

* SPANN reads far more data per query than DiskANN at matched recall;
* DiskANN's roundtrips grow with recall (its latency floor);
* DiskANN makes more requests at low recall, SPANN overtakes at high;
* SPANN's mean I/O latency blows up with recall × concurrency (Fig 9).
"""
from __future__ import annotations

from benchmarks.common import (DEFAULT_CLUSTER, default_graph_params, emit,
                               get_cluster_index, get_graph_index,
                               sweep_recall_qps)

DATASET = "gist-analog"


def main():
    ci = get_cluster_index(DATASET, DEFAULT_CLUSTER)
    gi = get_graph_index(DATASET, default_graph_params(DATASET))
    for kind, idx in [("cluster", ci), ("graph", gi)]:
        rows = sweep_recall_qps(DATASET, kind, idx, concurrency=1)
        for knob, recall, rep in rows:
            emit(f"fig8.{kind}", rep.mean_latency * 1e6,
                 knob=knob, recall=recall,
                 MB_per_query=rep.mean_bytes_read / 1e6,
                 roundtrips=rep.mean_roundtrips,
                 requests=rep.mean_requests)
    # Fig 9: SPANN mean I/O latency vs concurrency at the highest recall
    for conc in [1, 16, 64]:
        rows = sweep_recall_qps(DATASET, "cluster", ci, concurrency=conc)
        knob, recall, rep = rows[-1]
        emit(f"fig9.cluster.c{conc}", rep.mean_latency * 1e6,
             recall=recall, mean_io_latency_ms=rep.mean_io_latency * 1e3,
             qps=rep.qps)


if __name__ == "__main__":
    main()
