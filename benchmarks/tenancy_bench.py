"""Multi-tenant cache-sharing bench: policy comparison, interference,
single-tenant parity, cache-split tuning.

Four measurements (written to ``BENCH_tenancy.json`` at the repo root
and emitted as CSV rows):

1. **Policy comparison** — the skewed two-tenant scenario (a steady
   zipf-trace tenant with a hot set vs a bursty wide-scan tenant) under
   shared / static / weighted cache policies, with per-tenant solo
   baselines.  Hard checks: the ``weighted`` policy strictly dominates
   ``static`` on aggregate goodput; the steady tenant's interference
   ratio under ``weighted`` stays within the documented bound (1.5x
   solo, docs/tenancy.md) and below the free-sharing ratio; static
   partitions protect the steady tenant's hit rate vs free sharing.
2. **Single-tenant parity** — one tenant under ``shared`` reproduces
   the plain fleet run bit-exactly (ids + wall time), extending the
   golden-parity chain.
3. **Cache-split tuning** — ``tune_cache_split`` screens the simplex
   analytically (Che-approximation miss curves) and refines on real
   static-policy runs.  Hard check: the recommended split's measured
   aggregate goodput is the best of the refined candidates.

    PYTHONPATH=src python benchmarks/tenancy_bench.py

Exit status is non-zero if a hard check fails.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from common import QUICK, emit

from repro.fleet import FleetConfig, run_fleet
from repro.obs import run_manifest
from repro.tenancy import (TENANT_CACHE_POLICIES, Tenant, TenantSpec,
                           materialize_tenant, run_tenant_fleet)
from repro.tuning import tune_cache_split

OUT_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_tenancy.json")

#: the documented weighted-policy interference bound (docs/tenancy.md)
WEIGHTED_INTERFERENCE_BOUND = 1.5

_failures: list[str] = []


def _check(name: str, ok: bool, detail: str) -> None:
    print(f"# [{name}] {'PASS' if ok else 'FAIL'}: {detail}",
          file=sys.stderr)
    if not ok:
        _failures.append(name)


def _skewed_specs() -> list[TenantSpec]:
    """The skewed two-tenant scenario: cache-friendly steady traffic vs
    a cache-polluting burst of wide scans."""
    n_arr = 64 if QUICK else 128
    return [
        TenantSpec(name="steady", n=600, dim=32, n_queries=32, nprobe=8,
                   scenario="trace", rate_qps=250.0, n_arrivals=n_arr,
                   zipf_a=1.4, slo_ms=60, weight=1.0),
        TenantSpec(name="bursty", n=1200, dim=32, n_queries=24,
                   nprobe=64, scenario="burst", rate_qps=250.0,
                   n_arrivals=n_arr, burst_factor=10.0,
                   burst_start_s=0.1, burst_len_s=0.3, slo_ms=150,
                   weight=1.0),
    ]


def _contended_cfg() -> FleetConfig:
    return FleetConfig(n_shards=2, replication=2, concurrency=6,
                       cache_bytes=64 * 1024, cache_policy="slru",
                       seed=3)


def bench_policies() -> dict:
    cfg = _contended_cfg()

    def mk() -> list[Tenant]:
        return [materialize_tenant(s, base_seed=cfg.seed, tid=i)
                for i, s in enumerate(_skewed_specs())]

    steady_solo = materialize_tenant(_skewed_specs()[0],
                                     base_seed=cfg.seed, tid=0)
    solo = run_tenant_fleet([steady_solo], cfg, "shared")
    solo_p99 = solo.tenants[0].sojourn_percentile(99)
    rows = {}
    for pol in TENANT_CACHE_POLICIES:
        rep = run_tenant_fleet(mk(), cfg, pol)
        rep.tenant("steady").solo_p99_s = solo_p99
        st = rep.tenant("steady")
        bu = rep.tenant("bursty")
        rows[pol] = dict(
            steady_p99_sojourn_s=round(st.sojourn_percentile(99), 6),
            steady_hit_rate=round(st.hit_rate, 4),
            steady_interference=round(st.interference_ratio, 4),
            bursty_p99_sojourn_s=round(bu.sojourn_percentile(99), 6),
            bursty_hit_rate=round(bu.hit_rate, 4),
            aggregate_goodput_qps=round(rep.aggregate_goodput_qps, 2),
            aggregate_goodput_frac=round(rep.aggregate_goodput_frac, 4),
            reallocations=rep.reallocations)
        emit(f"tenancy/policy-{pol}",
             st.sojourn_percentile(99) * 1e6,
             steady_p99_ms=st.sojourn_percentile(99) * 1e3,
             steady_hit=st.hit_rate,
             interference=st.interference_ratio,
             agg_goodput=rep.aggregate_goodput_qps)
    w, s, sh = rows["weighted"], rows["static"], rows["shared"]
    _check("tenancy-weighted-dominates-static",
           w["aggregate_goodput_qps"] > s["aggregate_goodput_qps"],
           f"aggregate goodput weighted={w['aggregate_goodput_qps']} vs "
           f"static={s['aggregate_goodput_qps']} (want strictly higher)")
    _check("tenancy-weighted-interference-bounded",
           w["steady_interference"] <= WEIGHTED_INTERFERENCE_BOUND
           and w["steady_interference"] < sh["steady_interference"],
           f"steady interference weighted={w['steady_interference']} "
           f"(bound {WEIGHTED_INTERFERENCE_BOUND}) vs shared="
           f"{sh['steady_interference']}")
    _check("tenancy-static-protects-hit-rate",
           s["steady_hit_rate"] > sh["steady_hit_rate"],
           f"steady hit static={s['steady_hit_rate']} vs shared="
           f"{sh['steady_hit_rate']} (want higher: isolation blocks "
           f"pollution)")
    return dict(solo_steady_p99_sojourn_s=round(solo_p99, 6), **rows)


def bench_parity() -> dict:
    """One tenant under ``shared`` == the plain fleet run, bit-exactly."""
    from repro.core.cluster_index import ClusterIndex
    from repro.core.types import ClusterIndexParams, SearchParams
    from repro.data.synth import DEEP_ANALOG, make_dataset, scaled
    n, nq = (500, 16) if QUICK else (1000, 32)
    data, queries = make_dataset(scaled(DEEP_ANALOG, n, nq))
    params = SearchParams(k=10, nprobe=16)
    cfg = FleetConfig(n_shards=2, replication=2, concurrency=8,
                      cache_bytes=1 << 20, cache_policy="slru", seed=0)

    def build():
        return ClusterIndex.build(data, ClusterIndexParams(
            kmeans_iters=4, seed=0))

    plain = run_fleet(build(), queries, params, cfg)
    tenant = Tenant(spec=TenantSpec(name="solo"), index=build(),
                    queries=queries, params=params)
    ten = run_tenant_fleet([tenant], cfg, "shared")
    by_qid = {r.qid: r for r in plain.records}
    ids_equal = all(np.array_equal(r.ids, by_qid[r.qid].ids)
                    for r in ten.tenants[0].records)
    wall_equal = ten.fleet.wall_time_s == plain.wall_time_s
    hit_equal = round(ten.fleet.hit_rate, 12) == round(plain.hit_rate, 12)
    _check("tenancy-single-tenant-parity",
           ids_equal and wall_equal and hit_equal,
           f"ids_equal={ids_equal}, wall {ten.fleet.wall_time_s} vs "
           f"{plain.wall_time_s}, hit {ten.fleet.hit_rate:.4f} vs "
           f"{plain.hit_rate:.4f} (want bit-exact)")
    emit("tenancy/parity-1tenant", 1e6 / max(ten.fleet.qps, 1e-9),
         fleet_qps=plain.qps, tenant_qps=ten.fleet.qps)
    return dict(ids_equal=ids_equal, wall_equal=wall_equal,
                fleet_qps=round(plain.qps, 2),
                tenant_qps=round(ten.fleet.qps, 2))


def bench_showback() -> dict:
    """Dollar show-back for the skewed two-tenant scenario.  Hard check:
    the per-tenant rows (plus the unattributed residual) sum to the
    fleet total within float error."""
    import math

    from repro.obs import PRICEBOOKS
    cfg = _contended_cfg()
    tenants = [materialize_tenant(s, base_seed=cfg.seed, tid=i)
               for i, s in enumerate(_skewed_specs())]
    rep = run_tenant_fleet(tenants, cfg, "weighted",
                           pricebook=PRICEBOOKS["default"])
    sb = rep.showback
    _check("tenancy-showback-sums-to-fleet-total",
           math.isclose(sb["sum_usd"], sb["fleet_total_usd"],
                        rel_tol=1e-9, abs_tol=1e-12),
           f"sum(rows)={sb['sum_usd']} vs fleet total "
           f"{sb['fleet_total_usd']} (want exact within float error)")
    for row in sb["rows"]:
        if row["tenant"] == "(unattributed)":
            continue
        emit(f"tenancy/showback-{row['tenant']}",
             max(row["total_usd"] * 1e9, 1.0),
             total_usd=row["total_usd"], shared=row["shared_usd"],
             usd_per_1k=row["usd_per_1k_queries"])
    return sb


def bench_tuning() -> dict:
    cfg = FleetConfig(n_shards=2, replication=1, concurrency=8,
                      cache_bytes=96 * 1024, cache_policy="slru", seed=0)
    specs = [TenantSpec(name="hot", n=500, dim=32, n_queries=32,
                        nprobe=8),
             TenantSpec(name="cold", n=900, dim=32, n_queries=16,
                        nprobe=32)]
    steps, top = (4, 2) if QUICK else (8, 3)
    rec = tune_cache_split(specs, cfg, steps=steps, refine_top=top)
    best = max(o.aggregate_goodput_qps for o in rec.outcomes)
    mine = [o for o in rec.outcomes if o.split == rec.split][0]
    _check("tenancy-tuner-picks-best-refined",
           mine.aggregate_goodput_qps >= best - 1e-9,
           f"recommended split {rec.split.label()} goodput "
           f"{mine.aggregate_goodput_qps:.2f} vs best {best:.2f}")
    emit("tenancy/tune-cache-split", mine.aggregate_goodput_qps,
         split=rec.split.label(), goodput=mine.aggregate_goodput_qps)
    return rec.to_dict()


def main() -> int:
    t0 = time.perf_counter()
    results = dict(
        bench="tenancy",
        quick=QUICK,
        policies=bench_policies(),
        parity=bench_parity(),
        showback=bench_showback(),
        tuning=bench_tuning(),
        failures=_failures,
    )
    results["meta"] = run_manifest(
        seed=0, config=dict(bench="tenancy", quick=QUICK),
        wall_s=time.perf_counter() - t0)
    with open(OUT_PATH, "w") as f:
        json.dump(results, f, indent=2)
        f.write("\n")
    print(f"# wrote {os.path.abspath(OUT_PATH)}", file=sys.stderr)
    if _failures:
        print(f"# tenancy_bench: FAILED {_failures}", file=sys.stderr)
        return 1
    print("# tenancy_bench: all tenancy checks passed", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
