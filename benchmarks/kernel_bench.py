"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels run under the interpreter (their
timings measure the interpreter, not TPU silicon), so the *performance*
numbers reported are for the jnp reference path compiled by XLA:CPU, and
the Pallas rows are labelled interpret=1.  On TPU hardware the same ops
compile to Mosaic; roofline work for the kernels lives in EXPERIMENTS.md
§Perf (kernel section) via lowered-HLO analysis.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.ref import adc_lookup_ref, l2_distance_ref, l2_topk_ref

from benchmarks.common import emit


def _time(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main():
    rng = np.random.default_rng(0)
    cases = [
        ("dist.q64.n8192.d960", rng.normal(size=(64, 960)),
         rng.normal(size=(8192, 960))),
        ("dist.q64.n8192.d96", rng.normal(size=(64, 96)),
         rng.normal(size=(8192, 96))),
    ]
    for name, q, x in cases:
        qj = jnp.asarray(q, jnp.float32)
        xj = jnp.asarray(x, jnp.float32)
        flops = 2.0 * q.shape[0] * x.shape[0] * q.shape[1]
        us = _time(jax.jit(l2_distance_ref), qj, xj)
        emit(f"kernel.{name}.ref", us, gflops=flops / us / 1e3,
             interpret=0)
        us_k = _time(lambda a, b: ops.l2_distance(a, b, interpret=True),
                     qj[:8], xj[:512], iters=1, warmup=1)
        emit(f"kernel.{name}.pallas_interp", us_k, interpret=1)

    codes = jnp.asarray(rng.integers(0, 256, size=(65536, 112)), jnp.int32)
    table = jnp.asarray(rng.random((112, 256)), jnp.float32)
    us = _time(jax.jit(adc_lookup_ref), codes, table)
    emit("kernel.adc.n65536.m112.ref", us, interpret=0)
    us_k = _time(lambda c, t: ops.adc_lookup(c, t, interpret=True),
                 codes[:2048], table, iters=1, warmup=1)
    emit("kernel.adc.n2048.m112.pallas_interp", us_k, interpret=1)

    q = jnp.asarray(rng.normal(size=(32, 960)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(8192, 960)), jnp.float32)
    us = _time(jax.jit(lambda a, b: l2_topk_ref(a, b, 10)), q, x)
    emit("kernel.topk.q32.n8192.ref", us, interpret=0)
    us_k = _time(lambda a, b: ops.l2_topk(a, b, 10, interpret=True),
                 q[:8], x[:1024], iters=1, warmup=1)
    emit("kernel.topk.q8.n1024.pallas_interp", us_k, interpret=1)


if __name__ == "__main__":
    main()
