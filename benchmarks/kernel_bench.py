"""Kernel micro-benchmarks + calibrated-pricing regression gate.

Writes ``BENCH_kernels.json`` at the repo root (gated by
``check_regression.py`` like the fleet/ingest/tenancy benches).  Three
sections, split by what can be gated deterministically:

1. **parity** — the batched MXU execution path (``repro.exec.batched``)
   against the numpy oracles: result ids must be bit-identical on random
   floats, and ids *and* distances bit-identical on integer-valued
   vectors (exact float32 sums).  Hard checks; the booleans are gated.
2. **pricing** — ``plan_seconds`` rows computed from the *committed*
   CalibrationTable over a fixed (dim, pq_m, work, batch) grid.  Pure
   arithmetic on committed JSON, so identical on every machine; gated at
   the default tolerance.  Hard check: batching amortizes (large-batch
   unit cost below batch-of-one).
3. **meta.timings** — measured wall-clock rows for the XLA:CPU reference
   paths and a Pallas-interpret spot check.  Timing is per-host noise,
   so these live under ``meta`` which the regression gate never compares
   (they still land in the CSV stream for eyeballing).

On this CPU container the Pallas kernels run under the interpreter, so
interpret rows measure the interpreter, not TPU silicon; on TPU hardware
the same ops compile to Mosaic and ``repro.exec.calibrate`` re-measures
the table.
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import numpy as np

from common import QUICK, emit

from repro.exec import batched_topk, load_table, scan_topk_oracle
from repro.kernels import ops
from repro.kernels.ref import adc_lookup_ref, l2_distance_ref
from repro.obs import run_manifest

OUT_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_kernels.json")

_failures: list[str] = []


def _check(name: str, ok: bool, detail: str) -> None:
    print(f"# [{name}] {'PASS' if ok else 'FAIL'}: {detail}",
          file=sys.stderr)
    if not ok:
        _failures.append(name)


def _time_us(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


# ------------------------------------------------------------- parity --

def bench_parity() -> list[dict]:
    """Batched execution path vs the numpy oracles (see repro.exec)."""
    rng = np.random.default_rng(0)
    cases = [("b3.n200.d32.k10", 3, 200, 32, 10, False),
             ("b9.n300.d64.k10", 9, 300, 64, 10, False)]
    if not QUICK:
        cases += [("b1.n50.d16.k8", 1, 50, 16, 8, False),
                  ("b5.n160.d32.k10.int", 5, 160, 32, 10, True)]
    else:
        cases += [("b5.n160.d32.k10.int", 5, 160, 32, 10, True)]
    rows = []
    for name, b, n, d, k, integer in cases:
        if integer:     # small integers: float32 sums are exact, so the
            q = rng.integers(-8, 8, (b, d)).astype(np.float32)
            x = rng.integers(-8, 8, (n, d)).astype(np.float32)
        else:
            q = rng.standard_normal((b, d)).astype(np.float32)
            x = rng.standard_normal((n, d)).astype(np.float32)
        vk, ik = batched_topk(q, x, k)
        vo, io = scan_topk_oracle(q, x, k)
        ids_eq = bool(np.array_equal(ik, io))
        vals_eq = bool(np.array_equal(vk, vo))
        vals_close = bool(np.allclose(vk, vo, rtol=1e-5, atol=1e-5))
        rows.append(dict(case=name, batch=b, n=n, dim=d, k=k,
                         integer_valued=integer, ids_identical=ids_eq,
                         vals_identical=vals_eq, vals_close=vals_close))
        emit(f"kernel/parity-{name}", 0.0, ids_identical=int(ids_eq),
             vals_identical=int(vals_eq))
    _check("kernel-parity-ids",
           all(r["ids_identical"] for r in rows),
           "batched_topk result ids bit-identical to the numpy oracle "
           "on every case")
    _check("kernel-parity-vals-close",
           all(r["vals_close"] for r in rows),
           "batched_topk distances within float tolerance everywhere")
    _check("kernel-parity-int-exact",
           all(r["vals_identical"] for r in rows if r["integer_valued"]),
           "integer-valued inputs: distances bit-identical too")
    return rows


# ------------------------------------------------------------ pricing --

PRICING_GRID = [
    # (dim, pq_m, d_dist, d_pq, batch_jobs) — scan-only and PQ'd plans
    (32, 0, 4096, 0, 1), (32, 0, 4096, 0, 8), (32, 0, 4096, 0, 64),
    (128, 0, 4096, 0, 1), (128, 0, 4096, 0, 64),
    (64, 8, 512, 2048, 1), (64, 8, 512, 2048, 64),
    (128, 16, 512, 2048, 8),
]


def bench_pricing() -> dict:
    """Deterministic pricing rows from the committed CalibrationTable."""
    table = load_table()
    rows = []
    for dim, pq_m, d_dist, d_pq, batch in PRICING_GRID:
        lookups = d_pq * max(pq_m, 1)
        sec = table.plan_seconds(
            d_dist, d_pq, dim, pq_m,
            dist_batch=batch * d_dist, adc_batch=batch * lookups)
        rows.append(dict(dim=dim, pq_m=pq_m, d_dist=d_dist, d_pq=d_pq,
                         batch_jobs=batch, seconds=round(sec, 12)))
        emit(f"kernel/price-d{dim}m{pq_m}b{batch}", sec * 1e6,
             d_dist=d_dist, d_pq=d_pq)
    amort = {}
    for dim in (32, 128):
        solo, bulk = table.dist_unit_s(dim, 1), table.dist_unit_s(dim, 1e5)
        amort[str(dim)] = round(solo / bulk, 3)
        _check(f"kernel-pricing-amortizes-d{dim}", bulk < solo,
               f"dim={dim} unit cost {solo:.3e}s/comp at batch 1 vs "
               f"{bulk:.3e} at batch 1e5 (want batching cheaper)")
    frac = max(r["roofline_frac"] for r in table.meta["rooflines"])
    _check("kernel-pricing-roofline-sane", frac < 1.0,
           f"max measured roofline fraction {frac:.2e} (want < 1)")
    return dict(table_entries=len(table.entries),
                backend=table.meta.get("backend"),
                amortization=amort, rows=rows)


# ------------------------------------------- measured timings (ungated) --

def bench_timings() -> list[dict]:
    rng = np.random.default_rng(0)
    iters, warmup = (1, 1) if QUICK else (5, 2)
    rows = []

    n, d = (2048, 96) if QUICK else (8192, 960)
    q = rng.standard_normal((64, d)).astype(np.float32)
    x = rng.standard_normal((n, d)).astype(np.float32)
    flops = 2.0 * q.shape[0] * n * d
    us = _time_us(jax.jit(l2_distance_ref), q, x, iters=iters,
                  warmup=warmup)
    rows.append(dict(name=f"dist.q64.n{n}.d{d}.ref", us=round(us, 2),
                     gflops=round(flops / us / 1e3, 3), interpret=0))
    us_k = _time_us(lambda a, b: ops.l2_distance(a, b, interpret=True),
                    q[:8], x[:512], iters=1, warmup=1)
    rows.append(dict(name=f"dist.q8.n512.d{d}.pallas_interp",
                     us=round(us_k, 2), interpret=1))

    nc = 2048 if QUICK else 65536
    codes = rng.integers(0, 256, (nc, 112)).astype(np.int32)
    tab = rng.random((112, 256)).astype(np.float32)
    us = _time_us(jax.jit(adc_lookup_ref), codes, tab, iters=iters,
                  warmup=warmup)
    rows.append(dict(name=f"adc.n{nc}.m112.ref", us=round(us, 2),
                     interpret=0))

    bq, bn = (8, 512) if QUICK else (32, 2048)
    q2 = rng.standard_normal((bq, 64)).astype(np.float32)
    x2 = rng.standard_normal((bn, 64)).astype(np.float32)
    us = _time_us(lambda a, b: batched_topk(a, b, 10)[0], q2, x2,
                  iters=iters, warmup=warmup)
    rows.append(dict(name=f"exec.batched_topk.q{bq}.n{bn}.d64",
                     us=round(us, 2),
                     unit_ns=round(us * 1e3 / (bq * bn), 3)))

    for r in rows:
        emit(f"kernel/{r['name']}", r["us"],
             **{k: v for k, v in r.items() if k not in ("name", "us")})
    return rows


def main() -> int:
    t0 = time.perf_counter()
    results = dict(
        bench="kernels",
        quick=QUICK,
        parity=bench_parity(),
        pricing=bench_pricing(),
        failures=_failures,
    )
    results["meta"] = run_manifest(
        seed=0, config=dict(bench="kernels", quick=QUICK),
        wall_s=time.perf_counter() - t0)
    # measured wall-clock: per-host noise, kept out of the gate's reach
    results["meta"]["timings"] = bench_timings()
    results["meta"]["wall_s"] = round(time.perf_counter() - t0, 3)
    with open(OUT_PATH, "w") as f:
        json.dump(results, f, indent=2)
        f.write("\n")
    print(f"# wrote {os.path.abspath(OUT_PATH)}", file=sys.stderr)
    if _failures:
        print(f"# kernel_bench: FAILED {_failures}", file=sys.stderr)
        return 1
    print("# kernel_bench: all kernel checks passed", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
