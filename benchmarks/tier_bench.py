"""Tiered storage bench: DRAM -> local NVMe -> object store.

Four measurements (written to ``BENCH_tier.json`` at the repo root and
emitted as CSV rows):

1. **Policy sweep** — the same DRAM-starved fleet flat, with a
   second-hit NVMe tier, and with admit-always.  Hard checks: result
   ids are bit-identical across all three (the tier moves bytes, never
   answers), and the best tiered p99 beats the flat p99 — the tier's
   reason to exist.
2. **nvme=0 parity** — ``nvme_bytes=0`` must construct no tier and
   reproduce the flat fleet report bit for bit (same RNG stream names,
   same JSON).
3. **Write-back ingest** — live compaction on a write-back tier:
   rewritten lists land on the device first (admits > 0), every async
   flush reaches the object store, nothing is dropped.
4. **Dollars** — the tiered run priced with the default book: the NVMe
   reservation shows up as its own component, and the tier's
   egress/GET savings vs flat are recorded.

    PYTHONPATH=src python benchmarks/tier_bench.py

Exit status is non-zero if a hard check fails.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from common import QUICK, emit

from repro.core.cluster_index import ClusterIndex
from repro.core.flat import exact_topk
from repro.core.types import ClusterIndexParams, SearchParams
from repro.data.synth import DEEP_ANALOG, make_dataset, scaled
from repro.fleet import FleetConfig, run_fleet
from repro.ingest import IngestConfig, make_mutable, synth_updates
from repro.obs import PRICEBOOKS, run_manifest

OUT_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_tier.json")

_failures: list[str] = []

#: DRAM-starved operating point: the cache holds a sliver of the index,
#: the NVMe tier holds effectively all of it.
CACHE_BYTES = 64 * 1024
NVME_BYTES = 16 << 20


def _check(name: str, ok: bool, detail: str) -> None:
    print(f"# [{name}] {'PASS' if ok else 'FAIL'}: {detail}",
          file=sys.stderr)
    if not ok:
        _failures.append(name)


def _setup():
    n, nq = (800, 24) if QUICK else (1500, 48)
    data, queries = make_dataset(scaled(DEEP_ANALOG, n, nq))
    gt, _ = exact_topk(data, queries, 10)
    index = ClusterIndex.build(data, ClusterIndexParams(kmeans_iters=4,
                                                        seed=0))
    return data, index, queries, gt


def _cfg(**kw) -> FleetConfig:
    base = dict(n_shards=2, replication=1, concurrency=24,
                shard_concurrency=4, queue_depth=32,
                cache_bytes=CACHE_BYTES, cache_policy="slru", seed=4)
    base.update(kw)
    return FleetConfig(**base)


def _nvme_totals(rep) -> dict:
    tot: dict = dict(hits=0, misses=0, nvme_bytes=0, promotions=0,
                     evictions=0)
    for s in rep.shard_stats:
        nv = s.nvme
        if nv:
            for k in tot:
                tot[k] += nv[k]
    return tot


def bench_policies(index, queries, gt) -> list[dict]:
    """Flat vs second-hit vs admit-always at the same DRAM budget."""
    params = SearchParams(k=10, nprobe=64)
    variants = (("flat", dict()),
                ("second-hit", dict(nvme_bytes=NVME_BYTES,
                                    tier_policy="second-hit")),
                ("admit-always", dict(nvme_bytes=NVME_BYTES,
                                      tier_policy="admit-always")))
    rows = []
    ids_by_variant = {}
    for label, kw in variants:
        rep = run_fleet(index, queries, params, _cfg(**kw))
        ids_by_variant[label] = {r.qid: r.ids for r in rep.records}
        nv = _nvme_totals(rep)
        dev = nv["hits"] + nv["misses"]
        rows.append(dict(
            policy=label, qps=round(rep.qps, 2),
            p50_s=round(rep.latency_percentile(50), 6),
            p99_s=round(rep.latency_percentile(99), 6),
            recall=round(rep.recall_against(gt), 4),
            dram_hit_rate=round(rep.hit_rate, 4),
            nvme_hit_frac=round(nv["hits"] / dev, 4) if dev else 0.0,
            nvme_promotions=nv["promotions"],
            remote_bytes=int(rep.storage_bytes)))
        emit(f"tier/policy-{label}", 1e6 / max(rep.qps, 1e-9),
             qps=rep.qps, p99_ms=rep.latency_percentile(99) * 1e3,
             dram_hit=rep.hit_rate, nvme_hit_frac=rows[-1]["nvme_hit_frac"])
    flat = rows[0]
    base_ids = ids_by_variant["flat"]
    ids_eq = all(
        np.array_equal(ids, ids_by_variant[label][qid])
        for label in ("second-hit", "admit-always")
        for qid, ids in base_ids.items())
    _check("tier-results-exact", ids_eq,
           "tiered result ids bit-identical to flat for every query "
           "(the tier moves bytes, never answers)")
    best = min(rows[1:], key=lambda r: r["p99_s"])
    _check("tier-beats-flat-p99", best["p99_s"] < flat["p99_s"],
           f"p99 flat={flat['p99_s'] * 1e3:.1f}ms vs best tiered "
           f"({best['policy']})={best['p99_s'] * 1e3:.1f}ms (want lower)")
    served = all(r["nvme_hit_frac"] > 0 and r["nvme_promotions"] > 0
                 for r in rows[1:])
    _check("tier-serves-traffic", served,
           "both tier policies promoted lists and served device hits")
    less_egress = all(r["remote_bytes"] < flat["remote_bytes"]
                      for r in rows[1:])
    _check("tier-cuts-egress", less_egress,
           f"remote bytes flat={flat['remote_bytes']} vs tiered="
           f"{[r['remote_bytes'] for r in rows[1:]]} (want lower)")
    return rows


def bench_nvme_zero_parity(index, queries, gt) -> dict:
    """nvme_bytes=0 is the flat data path, bit for bit."""
    params = SearchParams(k=10, nprobe=64)
    flat = run_fleet(index, queries, params, _cfg())
    zero = run_fleet(index, queries, params, _cfg(nvme_bytes=0))
    bit_exact = flat.to_json() == zero.to_json()
    _check("tier-nvme0-parity", bit_exact,
           "nvme_bytes=0 fleet report bit-identical to the flat config")
    emit("tier/nvme0-parity", 1e6 / max(zero.qps, 1e-9),
         bit_exact=int(bit_exact))
    return dict(bit_exact=bit_exact, qps=round(zero.qps, 2))


def bench_writeback(data, index, queries, gt) -> list[dict]:
    """Live compaction with write-through vs write-back placement."""
    params = SearchParams(k=10, nprobe=32)
    rows = []
    for label, wb in (("write-through", False), ("write-back", True)):
        cfg = _cfg(concurrency=8, nvme_bytes=NVME_BYTES,
                   nvme_writeback=wb, seed=2)
        stream = synth_updates(data, rate_qps=600.0, n_updates=120,
                               delete_frac=0.3, seed=3)
        rep = run_fleet(make_mutable(index), queries, params, cfg,
                        updates=stream,
                        ingest=IngestConfig(delta_cap_bytes=24 * 1024))
        admits = flushes = pending = 0
        for s in rep.shard_stats:
            nv = s.nvme or {}
            admits += nv.get("writeback_admits", 0)
            flushes += nv.get("flushes_done", 0)
            pending += nv.get("flush_pending", 0)
        rows.append(dict(
            placement=label, qps=round(rep.qps, 2),
            p99_s=round(rep.latency_percentile(99), 6),
            recall=round(rep.recall_against(gt), 4),
            completed=len(rep.records), arrivals=rep.n_arrivals,
            writeback_admits=admits, flushes_done=flushes,
            flush_pending=pending))
        emit(f"tier/ingest-{label}", 1e6 / max(rep.qps, 1e-9),
             qps=rep.qps, admits=admits, flushes=flushes)
    wt, wb = rows
    _check("tier-writeback-admits",
           wt["writeback_admits"] == 0 and wb["writeback_admits"] > 0,
           f"write-through admits={wt['writeback_admits']} (want 0), "
           f"write-back admits={wb['writeback_admits']} (want > 0)")
    _check("tier-writeback-drains",
           wb["flushes_done"] > 0 and wb["flush_pending"] == 0,
           f"write-back flushed {wb['flushes_done']} deltas, "
           f"{wb['flush_pending']} pending at drain (want 0)")
    _check("tier-ingest-complete",
           all(r["completed"] == r["arrivals"] for r in rows),
           "every arrival completed under live compaction")
    return rows


def bench_cost(index, queries, gt) -> dict:
    """The tier priced: NVMe reservation vs the egress + GETs it saves."""
    params = SearchParams(k=10, nprobe=64)
    book = PRICEBOOKS["default"]
    flat = run_fleet(index, queries, params, _cfg(), pricebook=book)
    tier = run_fleet(index, queries, params,
                     _cfg(nvme_bytes=NVME_BYTES), pricebook=book)
    fc, tc = flat.cost, tier.cost
    _check("tier-nvme-component-priced",
           fc["nvme_usd"] == 0.0 and tc["nvme_usd"] > 0.0,
           f"nvme_usd flat={fc['nvme_usd']} (want 0) vs tiered="
           f"{tc['nvme_usd']} (want > 0)")
    _check("tier-cost-cuts-egress-dollars",
           tc["egress_usd"] < fc["egress_usd"],
           f"egress flat=${fc['egress_usd']:.9f} vs tiered="
           f"${tc['egress_usd']:.9f} (want lower)")
    emit("tier/cost-default", 1e6 / max(tier.qps, 1e-9),
         total_usd=tc["total_usd"], egress_usd=tc["egress_usd"],
         nvme_usd=tc["nvme_usd"])
    return dict(flat=fc, tiered=tc)


def main() -> int:
    t0 = time.perf_counter()
    data, index, queries, gt = _setup()
    results = dict(
        bench="tier",
        quick=QUICK,
        policies=bench_policies(index, queries, gt),
        nvme_zero=bench_nvme_zero_parity(index, queries, gt),
        writeback=bench_writeback(data, index, queries, gt),
        cost=bench_cost(index, queries, gt),
        failures=_failures,
    )
    results["meta"] = run_manifest(
        seed=0, config=dict(bench="tier", quick=QUICK),
        wall_s=time.perf_counter() - t0)
    with open(OUT_PATH, "w") as f:
        json.dump(results, f, indent=2)
        f.write("\n")
    print(f"# wrote {os.path.abspath(OUT_PATH)}", file=sys.stderr)
    if _failures:
        print(f"# tier_bench: FAILED {_failures}", file=sys.stderr)
        return 1
    print("# tier_bench: all tier checks passed", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
