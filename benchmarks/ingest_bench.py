"""Ingest bench: recall under churn, compaction storms, freshness lag.

Three measurements (written to ``BENCH_ingest.json`` at the repo root
and emitted as CSV rows), all virtual-time deterministic:

1. **Recall vs write rate** — a closed-loop read stream cycling the
   query set while an insert/delete stream churns the corpus at 0× /
   1× / 4× the base write rate.  *Live* recall is measured against the
   post-churn ground truth, so staleness (updates a query ran too
   early to see) shows up directly; *settled* recall re-runs the
   queries after the delta fully compacts.  Hard checks: the
   zero-write run matches the static index's recall; settled recall
   stays above 0.85 at every churn rate.
2. **Compaction storm** — the same read load with and without a heavy
   write stream through a deliberately small delta tier.  Compaction
   reads/writes share the serving sims' NIC/IOPS budget, so the storm
   must lengthen the run and show a during-compaction p99.  Hard
   checks: queries overlapped compaction; churn wall > quiet wall.
3. **Freshness vs delta capacity** — seal lag (arrival → folded into
   sealed objects) for a small vs large memtable.  Hard check: the
   larger delta seals later (or never flushes inside the run).

    PYTHONPATH=src python benchmarks/ingest_bench.py

Exit status is non-zero if a hard check fails.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from common import QUICK, emit

from repro.core.cluster_index import ClusterIndex
from repro.core.flat import exact_topk
from repro.core.types import ClusterIndexParams, SearchParams
from repro.data.synth import DEEP_ANALOG, make_dataset, scaled
from repro.fleet import FleetConfig, run_fleet
from repro.ingest import (IngestConfig, churn_ground_truth, make_mutable,
                          synth_updates)
from repro.obs import run_manifest
from repro.serving.engine import run_workload
from repro.sim.arrivals import Scenario
from repro.storage.spec import TOS

OUT_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_ingest.json")

_failures: list[str] = []


def _check(name: str, ok: bool, detail: str) -> None:
    print(f"# [{name}] {'PASS' if ok else 'FAIL'}: {detail}",
          file=sys.stderr)
    if not ok:
        _failures.append(name)


def _setup():
    n, nq = (800, 24) if QUICK else (1500, 48)
    data, queries = make_dataset(scaled(DEEP_ANALOG, n, nq))
    gt, _ = exact_topk(data, queries, 10)
    return data, queries, gt


def _index(data):
    return ClusterIndex.build(data, ClusterIndexParams(kmeans_iters=4,
                                                       seed=0))


def _drain(mutable, seed=7):
    """Force-flush the remaining delta (post-run settlement)."""
    from repro.core.cost_model import ComputeSpec
    from repro.ingest import IngestAgent, IngestReport
    from repro.sim.kernel import Kernel
    from repro.storage.simulator import StorageSim
    kernel = Kernel(seed=seed)
    sim = StorageSim(TOS, kernel, seed=seed)
    for sid in sorted(mutable.sites):
        IngestAgent(mutable, site_id=sid, kernel=kernel,
                    cfg=IngestConfig(), compute=ComputeSpec(),
                    sim_provider=lambda: sim,
                    report=IngestReport()).flush_now()
    kernel.run()


def bench_recall_vs_write_rate(data, queries, gt) -> list[dict]:
    params = SearchParams(k=10, nprobe=32)
    base_rate = 400.0 if QUICK else 800.0
    n_up = 120 if QUICK else 300
    arrivals = 3 * len(queries)
    static = run_workload(_index(data), queries, params, TOS,
                          concurrency=8, seed=1,
                          arrivals=Scenario(
                              kind="closed",
                              n_arrivals=arrivals).make_arrivals(
                                  len(queries), 8))
    static_recall = static.recall_against(gt)
    rows = []
    for mult in (0.0, 1.0, 4.0):
        rate = mult * base_rate
        stream = (synth_updates(data, rate, int(n_up * mult),
                                delete_frac=0.25, seed=2)
                  if rate > 0 else None)
        index = make_mutable(_index(data))
        rep = run_workload(
            index, queries, params, TOS,
            concurrency=8, seed=1,
            arrivals=Scenario(kind="rw",
                              n_arrivals=arrivals).make_arrivals(
                                  len(queries), 8),
            updates=stream,
            ingest=IngestConfig(delta_cap_bytes=32 * 1024))
        g = churn_ground_truth(data, stream, queries, 10) \
            if stream is not None else gt
        recall_live = float(np.mean(
            [np.isin(r.ids[r.ids >= 0],
                     g[r.qid % len(queries)]).sum() / 10.0
             for r in rep.records]))
        if stream is not None:
            _drain(index)
            settled = [index.search(q, params) for q in queries]
            recall_settled = float(np.mean(
                [np.isin(r.ids[r.ids >= 0], g[i]).sum() / 10.0
                 for i, r in enumerate(settled)]))
        else:
            recall_settled = recall_live
        ing = rep.ingest or {}
        rows.append(dict(
            write_rate=rate, recall_live=round(recall_live, 4),
            recall_settled=round(recall_settled, 4),
            qps=round(rep.qps, 2),
            p99_s=round(rep.latency_percentile(99), 6),
            write_amplification=ing.get("write_amplification", 0.0),
            seal_p99_s=(ing.get("seal_lag", {}) or {}).get("p99_s", 0.0),
            visibility_p99_s=(ing.get("visibility_lag", {})
                              or {}).get("p99_s", 0.0),
            flushes=ing.get("flushes", 0)))
        emit(f"ingest/recall-wr{mult:g}x", 1e6 / max(rep.qps, 1e-9),
             write_rate=rate, recall_live=recall_live,
             recall_settled=recall_settled, qps=rep.qps,
             wa=ing.get("write_amplification", 0.0))
    _check("ingest-zero-write-matches-static",
           abs(rows[0]["recall_live"] - static_recall) < 1e-9,
           f"write-rate-0 recall {rows[0]['recall_live']:.4f} vs static "
           f"{static_recall:.4f} (want identical)")
    _check("ingest-settled-recall-floor",
           min(r["recall_settled"] for r in rows) > 0.85,
           f"worst settled recall "
           f"{min(r['recall_settled'] for r in rows):.4f} (want > 0.85)")
    return rows


def bench_compaction_storm(data, queries, gt) -> dict:
    params = SearchParams(k=10, nprobe=32)
    cfg = FleetConfig(n_shards=2, replication=1, storage=TOS,
                      concurrency=8, seed=2)
    arrivals = 4 * len(queries)
    mk_arr = lambda: Scenario(kind="rw",
                              n_arrivals=arrivals).make_arrivals(
                                  len(queries), cfg.concurrency)
    rate = 1500.0 if QUICK else 3000.0
    n_up = 300 if QUICK else 600
    quiet = run_fleet(make_mutable(_index(data)), queries, params, cfg,
                      arrivals=mk_arr())
    stream = synth_updates(data, rate, n_up, delete_frac=0.2, seed=5)
    from repro.obs import PRICEBOOKS
    churn = run_fleet(make_mutable(_index(data)), queries, params, cfg,
                      arrivals=mk_arr(), updates=stream,
                      ingest=IngestConfig(delta_cap_bytes=16 * 1024,
                                          recluster=False),
                      pricebook=PRICEBOOKS["default"])
    ing = churn.ingest
    row = dict(
        quiet_wall_s=round(quiet.wall_time_s, 6),
        churn_wall_s=round(churn.wall_time_s, 6),
        quiet_p99_s=round(quiet.latency_percentile(99), 6),
        churn_p99_s=round(churn.latency_percentile(99), 6),
        queries_during_compaction=ing["queries_during_compaction"],
        p50_during_s=ing["query_p50_during_compaction_s"],
        p99_during_s=ing["query_p99_during_compaction_s"],
        p50_outside_s=ing["query_p50_outside_compaction_s"],
        p99_outside_s=ing["query_p99_outside_compaction_s"],
        write_amplification=ing["write_amplification"],
        compaction_busy_s=ing["compaction_busy_s"],
        flushes=ing["flushes"],
        cost=churn.cost)
    emit("ingest/storm", churn.latency_percentile(99) * 1e6,
         quiet_p99_ms=quiet.latency_percentile(99) * 1e3,
         churn_p99_ms=churn.latency_percentile(99) * 1e3,
         during_p99_ms=row["p99_during_s"] * 1e3,
         wa=row["write_amplification"])
    _check("ingest-storm-overlaps-queries",
           row["queries_during_compaction"] > 0,
           f"{row['queries_during_compaction']} queries overlapped "
           f"compaction (want > 0)")
    _check("ingest-storm-steals-bandwidth",
           row["churn_wall_s"] > row["quiet_wall_s"],
           f"wall quiet={row['quiet_wall_s']:.4f}s vs "
           f"churn={row['churn_wall_s']:.4f}s (want longer)")
    _check("ingest-storm-meters-puts",
           row["cost"]["put_usd"] > 0,
           f"compaction writes priced as PUTs: "
           f"put_usd={row['cost']['put_usd']} (want > 0)")
    return row


def bench_freshness(data, queries, gt) -> list[dict]:
    params = SearchParams(k=10, nprobe=16)
    rate = 600.0 if QUICK else 1000.0
    n_up = 150 if QUICK else 250
    rows = []
    for label, cap in (("small", 8 * 1024), ("large", 96 * 1024)):
        stream = synth_updates(data, rate, n_up, delete_frac=0.2, seed=6)
        rep = run_workload(
            make_mutable(_index(data)), queries, params, TOS,
            concurrency=8, seed=3, updates=stream,
            ingest=IngestConfig(delta_cap_bytes=cap))
        ing = rep.ingest
        rows.append(dict(
            delta=label, delta_cap_bytes=cap,
            sealed=ing["seal_lag"]["n"], unsealed=ing["unsealed"],
            seal_mean_s=ing["seal_lag"]["mean_s"],
            seal_p99_s=ing["seal_lag"]["p99_s"],
            visibility_p99_s=ing["visibility_lag"]["p99_s"],
            flushes=ing["flushes"],
            write_amplification=ing["write_amplification"]))
        emit(f"ingest/freshness-{label}",
             ing["seal_lag"]["mean_s"] * 1e6 or 1.0,
             sealed=ing["seal_lag"]["n"], unsealed=ing["unsealed"],
             seal_p99_ms=ing["seal_lag"]["p99_s"] * 1e3)
    small, large = rows
    later = (large["sealed"] == 0
             or large["seal_mean_s"] > small["seal_mean_s"])
    _check("ingest-freshness-tracks-delta-capacity",
           small["sealed"] > 0 and later,
           f"small-delta mean seal {small['seal_mean_s']:.4f}s vs large "
           f"{large['seal_mean_s']:.4f}s (sealed {large['sealed']}) — "
           f"want the larger delta to seal later (or not at all)")
    return rows


def main() -> int:
    t0 = time.perf_counter()
    data, queries, gt = _setup()
    results = dict(
        bench="ingest",
        quick=QUICK,
        recall_vs_write_rate=bench_recall_vs_write_rate(data, queries,
                                                        gt),
        compaction_storm=bench_compaction_storm(data, queries, gt),
        freshness=bench_freshness(data, queries, gt),
        failures=_failures,
    )
    results["meta"] = run_manifest(
        seed=0, config=dict(bench="ingest", quick=QUICK),
        wall_s=time.perf_counter() - t0)
    with open(OUT_PATH, "w") as f:
        json.dump(results, f, indent=2)
        f.write("\n")
    print(f"# wrote {os.path.abspath(OUT_PATH)}", file=sys.stderr)
    if _failures:
        print(f"# ingest_bench: FAILED {_failures}", file=sys.stderr)
        return 1
    print("# ingest_bench: all ingest checks passed", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
