"""Regression gate: compare freshly-produced ``BENCH_*.json`` at the repo
root against the committed baselines in ``benchmarks/baselines/``.

Every bench metric in this repo is *virtual-time* (deterministic event
simulation), so fresh numbers should match the committed baseline almost
exactly on any machine; the tolerance only absorbs numpy/platform float
wiggle.  A genuine behaviour change (faster, slower, different recall)
trips the gate and forces a deliberate baseline refresh.

Usage:

    PYTHONPATH=src python benchmarks/run.py          # or a single bench
    python benchmarks/check_regression.py            # gate
    python benchmarks/check_regression.py --update   # bless new numbers

Baselines are kept per quick-mode: CI runs with ``REPRO_BENCH_QUICK=1``
and compares against ``<name>.quick.json``; full runs compare against
``<name>.json``.  Fresh files with no baseline are reported (add one with
--update); a fresh file whose ``failures`` list is non-empty always
fails.

Exit status: 0 clean, 1 on any mismatch/missing baseline/hard failure.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
BASELINE_DIR = os.path.join(HERE, "baselines")

#: Relative tolerance for numeric leaves.  Virtual-time determinism means
#: the real drift across platforms is ~float-ulp; 2% headroom keeps the
#: gate quiet across numpy versions while catching any real regression.
DEFAULT_REL_TOL = float(os.environ.get("REPRO_REGRESSION_REL_TOL", "0.02"))
DEFAULT_ABS_TOL = float(os.environ.get("REPRO_REGRESSION_ABS_TOL", "1e-9"))

#: Keys whose values are allowed to drift more (percentile estimates over
#: small samples are the noisiest virtual metrics).
LOOSE_KEYS = ("p999", "p99", "peak_", "hedge", "sheds", "shed_")
LOOSE_REL_TOL = float(os.environ.get("REPRO_REGRESSION_LOOSE_TOL", "0.10"))

#: Keys the gate never compares: ``meta`` is per-run provenance (git sha,
#: wall time) and ``attrib`` is the diagnostic critical-path breakdown —
#: both describe the run, they are not the metrics under test.
SKIP_KEYS = ("meta", "attrib")


def _tol_for(path: str) -> float:
    leaf = path.rsplit(".", 1)[-1]
    if any(marker in leaf for marker in LOOSE_KEYS):
        return LOOSE_REL_TOL
    return DEFAULT_REL_TOL


def compare(fresh, base, path: str = "") -> list[str]:
    """Walk both JSON trees; return human-readable mismatch lines."""
    diffs: list[str] = []
    if isinstance(base, dict):
        if not isinstance(fresh, dict):
            return [f"{path}: type changed ({type(fresh).__name__})"]
        for key in base:
            if key in SKIP_KEYS:
                continue
            if key not in fresh:
                diffs.append(f"{path}.{key}: missing from fresh output")
            else:
                diffs.extend(compare(fresh[key], base[key],
                                     f"{path}.{key}" if path else key))
        return diffs
    if isinstance(base, list):
        if not isinstance(fresh, list):
            return [f"{path}: type changed ({type(fresh).__name__})"]
        if len(fresh) != len(base):
            return [f"{path}: length {len(fresh)} != baseline {len(base)}"]
        for i, (f, b) in enumerate(zip(fresh, base)):
            diffs.extend(compare(f, b, f"{path}[{i}]"))
        return diffs
    if isinstance(base, bool) or base is None or isinstance(base, str):
        if fresh != base:
            diffs.append(f"{path}: {fresh!r} != baseline {base!r}")
        return diffs
    if isinstance(base, (int, float)):
        try:
            fv = float(fresh)
        except (TypeError, ValueError):
            return [f"{path}: non-numeric {fresh!r} vs baseline {base!r}"]
        rel = _tol_for(path)
        if abs(fv - base) > max(DEFAULT_ABS_TOL, rel * abs(float(base))):
            diffs.append(f"{path}: {fresh} vs baseline {base} "
                         f"(rel tol {rel})")
        return diffs
    return diffs


def _attrib_diff_lines(fresh: dict, base: dict) -> list[str]:
    """Where the regression lives: a critical-path diff of the benches'
    ``attrib`` blocks (present when the bench ran a traced probe)."""
    fa, ba = fresh.get("attrib"), base.get("attrib")
    if not (isinstance(fa, dict) and isinstance(ba, dict)):
        return []
    try:
        from repro.obs import render_diff, trace_diff
    except ImportError:
        sys.path.insert(0, os.path.join(ROOT, "src"))
        from repro.obs import render_diff, trace_diff
    return render_diff(trace_diff(ba, fa)).splitlines()


def _explain_lines(fresh: dict) -> list[str]:
    """What the fresh tail looked like: the explain probe's headline and
    top exemplar clusters (present when the bench ran an explained
    probe), printed next to the critical-path diff so a gate failure
    comes with its own forensics."""
    exp = fresh.get("explain")
    if not (isinstance(exp, dict) and exp.get("headline")):
        return []
    lines = [f"tail explanation: {exp['headline']}"]
    for c in exp.get("clusters", []):
        events = ", ".join(c.get("events", [])) or "no concurrent events"
        lines.append(f"  {c['n']}x {c['stage']}@shard{c['shard']} "
                     f"during {events}")
    return lines


def _meta_lines(fresh: dict) -> list[str]:
    meta = fresh.get("meta")
    if not isinstance(meta, dict):
        return []
    keep = ("git_sha", "seed", "config_hash", "command", "wall_s")
    return ["run manifest: "
            + "  ".join(f"{k}={meta[k]}" for k in keep if k in meta)]


def baseline_path(fresh_path: str, quick: bool) -> str:
    name = os.path.basename(fresh_path)
    if quick:
        stem, ext = os.path.splitext(name)
        name = f"{stem}.quick{ext}"
    return os.path.join(BASELINE_DIR, name)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--update", action="store_true",
                    help="bless the fresh numbers as the new baselines")
    ap.add_argument("paths", nargs="*",
                    help="fresh BENCH_*.json files (default: repo root)")
    args = ap.parse_args(argv)

    fresh_paths = args.paths or sorted(
        glob.glob(os.path.join(ROOT, "BENCH_*.json")))
    if not fresh_paths:
        print("check_regression: no fresh BENCH_*.json found "
              "(run the benches first)", file=sys.stderr)
        return 1

    failed = False
    for fp in fresh_paths:
        with open(fp) as f:
            fresh = json.load(f)
        quick = bool(fresh.get("quick", False))
        bp = baseline_path(fp, quick)
        label = os.path.relpath(fp, ROOT)
        if fresh.get("failures"):
            print(f"FAIL {label}: bench hard checks failed: "
                  f"{fresh['failures']}")
            for line in _meta_lines(fresh):
                print(f"  {line}")
            for line in _explain_lines(fresh):
                print(f"  {line}")
            failed = True
            continue
        if args.update:
            os.makedirs(BASELINE_DIR, exist_ok=True)
            shutil.copyfile(fp, bp)
            print(f"UPDATED {os.path.relpath(bp, ROOT)}")
            continue
        if not os.path.exists(bp):
            print(f"FAIL {label}: no committed baseline at "
                  f"{os.path.relpath(bp, ROOT)} (run with --update)")
            failed = True
            continue
        with open(bp) as f:
            base = json.load(f)
        diffs = compare(fresh, base)
        if diffs:
            failed = True
            print(f"FAIL {label}: {len(diffs)} mismatches vs "
                  f"{os.path.relpath(bp, ROOT)}")
            for d in diffs[:20]:
                print(f"  {d}")
            if len(diffs) > 20:
                print(f"  ... and {len(diffs) - 20} more")
            for line in _meta_lines(fresh):
                print(f"  {line}")
            for line in _attrib_diff_lines(fresh, base):
                print(f"  {line}")
            for line in _explain_lines(fresh):
                print(f"  {line}")
        else:
            print(f"OK   {label} matches "
                  f"{os.path.relpath(bp, ROOT)}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
