"""Fig 2 + Fig 3 analogue: where does search time go, disk vs cloud?

For each index at the paper's default low-recall settings (SPANN nprobe=8,
DiskANN search_len=10, concurrency=1) we decompose per-query time into
I/O wait vs priced compute on SSD and on TOS, and report the QPS drop.
Paper claim: on remote storage both indexes become I/O-dominated
(SPANN 31%→54% I/O, DiskANN 69%→71%), and the disk→cloud QPS drop is much
larger for DiskANN (TTFB-bound) than SPANN (bandwidth-bound).
"""
from __future__ import annotations

from repro.core.types import SearchParams
from repro.storage.spec import SSD, TOS

from benchmarks.common import (DEFAULT_CLUSTER, default_graph_params, emit,
                               get_cluster_index, get_graph_index, replay)

DATASET = "gist-analog"


def _split(rep):
    io = sum(b.io_latency for r in rep.records for b in r.batches)
    total = sum(r.latency for r in rep.records)
    compute = max(total - io, 0.0)
    return io / total * 100, compute / total * 100


def main():
    ci = get_cluster_index(DATASET, DEFAULT_CLUSTER)
    gi = get_graph_index(DATASET, default_graph_params(DATASET))
    qps = {}
    for store, sname in [(SSD, "disk"), (TOS, "cloud")]:
        rep = replay(DATASET, "cluster", ci, SearchParams(k=10, nprobe=8),
                     storage=store)
        io_pct, comp_pct = _split(rep)
        qps[("spann", sname)] = rep.qps
        emit(f"fig2.spann.{sname}", rep.mean_latency * 1e6,
             io_pct=io_pct, compute_pct=comp_pct, qps=rep.qps)
        rep = replay(DATASET, "graph", gi,
                     SearchParams(k=10, search_len=10, beamwidth=16),
                     storage=store)
        io_pct, comp_pct = _split(rep)
        qps[("diskann", sname)] = rep.qps
        emit(f"fig2.diskann.{sname}", rep.mean_latency * 1e6,
             io_pct=io_pct, compute_pct=comp_pct, qps=rep.qps)
    # Fig 3f: relative QPS drop disk -> cloud
    for idx in ["spann", "diskann"]:
        drop = qps[(idx, "disk")] / max(qps[(idx, "cloud")], 1e-9)
        emit(f"fig3f.qps_drop.{idx}", 0.0, disk_over_cloud=drop)


if __name__ == "__main__":
    main()
