"""Fig 7: QPS–recall curves, SPANN vs DiskANN × concurrency (RQ1).

Paper claims validated here:
* SPANN wins at low recall / low concurrency; DiskANN overtakes at high
  recall × high concurrency;
* the crossover recall rises on low-dim (deep) datasets.
"""
from __future__ import annotations

from benchmarks.common import (DEFAULT_CLUSTER, default_graph_params, emit,
                               get_cluster_index, get_graph_index,
                               sweep_recall_qps)

CONCURRENCIES = [1, 4, 16, 64]
DATASETS = ["gist-analog", "deep-analog"]


def main():
    for dataset in DATASETS:
        ci = get_cluster_index(dataset, DEFAULT_CLUSTER)
        gi = get_graph_index(dataset, default_graph_params(dataset))
        for conc in CONCURRENCIES:
            for kind, idx in [("cluster", ci), ("graph", gi)]:
                rows = sweep_recall_qps(dataset, kind, idx,
                                        concurrency=conc)
                for knob, recall, rep in rows:
                    emit(f"fig7.{dataset}.{kind}.c{conc}",
                         rep.mean_latency * 1e6,
                         knob=knob, recall=recall, qps=rep.qps,
                         bw_MBps=rep.bandwidth_Bps / 1e6)


if __name__ == "__main__":
    main()
