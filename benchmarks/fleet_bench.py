"""Fleet serving bench: scaling curves, hedging tails, 1-shard parity.

Three measurements (written to ``BENCH_fleet.json`` at the repo root and
emitted as CSV rows):

1. **QPS vs shards** — closed-loop aggregate throughput at a fixed recall
   operating point (fixed nprobe => identical results at every fleet
   size), shards 1 -> 8 with up-to-2x replication.  Hard check: QPS rises
   monotonically from 1 to 4 shards.
2. **Tail latency vs hedging** — under the paper's heavy cold-TTFB tail,
   sweep the hedge deadline percentile and record p95/p99/p99.9 plus
   hedge and win rates.
3. **1-shard parity** — a 1-shard fleet must reproduce the single
   ``QueryEngine`` report (identical per-query results; QPS within 5%).

    PYTHONPATH=src python benchmarks/fleet_bench.py

Exit status is non-zero if a hard check fails.
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys

import numpy as np

from common import QUICK, emit

from repro.core.cluster_index import ClusterIndex
from repro.core.flat import exact_topk
from repro.core.types import ClusterIndexParams, SearchParams
from repro.data.synth import DEEP_ANALOG, make_dataset, scaled
from repro.fleet import FleetConfig, run_fleet
from repro.serving.engine import run_workload
from repro.storage.spec import TOS

OUT_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_fleet.json")

_failures: list[str] = []


def _check(name: str, ok: bool, detail: str) -> None:
    print(f"# [{name}] {'PASS' if ok else 'FAIL'}: {detail}",
          file=sys.stderr)
    if not ok:
        _failures.append(name)


def _setup():
    n, nq = (800, 24) if QUICK else (1500, 48)
    data, queries = make_dataset(scaled(DEEP_ANALOG, n, nq))
    gt, _ = exact_topk(data, queries, 10)
    index = ClusterIndex.build(data, ClusterIndexParams(kmeans_iters=4,
                                                        seed=0))
    return index, queries, gt


def bench_scaling(index, queries, gt) -> list[dict]:
    """QPS-vs-shards at fixed recall (R = min(2, shards), po2c routing)."""
    params = SearchParams(k=10, nprobe=64)
    rows = []
    for shards in (1, 2, 4, 8):
        rep = run_fleet(index, queries, params, FleetConfig(
            n_shards=shards, replication=min(2, shards), storage=TOS,
            concurrency=64, shard_concurrency=8, queue_depth=128, seed=1))
        recall = rep.recall_against(gt)
        rows.append(dict(shards=shards, replication=min(2, shards),
                         qps=round(rep.qps, 2),
                         p99_s=round(rep.latency_percentile(99), 6),
                         recall=round(recall, 4),
                         load_imbalance=round(rep.load_imbalance, 4)))
        emit(f"fleet/scaling-{shards}sh", 1e6 / max(rep.qps, 1e-9),
             qps=rep.qps, p99_ms=rep.latency_percentile(99) * 1e3,
             recall=recall, imbalance=rep.load_imbalance)
    qps = [r["qps"] for r in rows]
    _check("fleet-scaling-monotonic", qps[0] < qps[1] < qps[2],
           f"QPS 1->2->4 shards: {qps[0]:.0f} -> {qps[1]:.0f} -> "
           f"{qps[2]:.0f} (want strictly increasing)")
    recalls = {r["recall"] for r in rows}
    _check("fleet-scaling-fixed-recall", len(recalls) == 1,
           f"recall constant across fleet sizes: {sorted(recalls)}")
    return rows


def bench_hedging(index, queries, gt) -> list[dict]:
    """Tail latency vs hedge deadline under a heavy cold-TTFB tail."""
    params = SearchParams(k=10, nprobe=64)
    heavy = dataclasses.replace(TOS, ttfb_sigma=1.1)
    rows = []
    for pct in (None, 90.0, 75.0):
        cfg = FleetConfig(
            n_shards=4, replication=2, storage=heavy, concurrency=4,
            shard_concurrency=8, queue_depth=64, seed=3,
            hedge=pct is not None, hedge_percentile=pct or 95.0,
            hedge_min_samples=16)
        rep = run_fleet(index, queries, params, cfg)
        label = "off" if pct is None else f"p{pct:.0f}"
        rows.append(dict(hedge=label,
                         p95_s=round(rep.latency_percentile(95), 6),
                         p99_s=round(rep.latency_percentile(99), 6),
                         p999_s=round(rep.latency_percentile(99.9), 6),
                         qps=round(rep.qps, 2),
                         hedge_rate=round(rep.hedge_rate, 4),
                         hedge_win_rate=round(rep.hedge_win_rate, 4),
                         recall=round(rep.recall_against(gt), 4)))
        emit(f"fleet/hedge-{label}", rep.mean_latency * 1e6,
             p95_ms=rep.latency_percentile(95) * 1e3,
             p99_ms=rep.latency_percentile(99) * 1e3,
             hedge_rate=rep.hedge_rate, qps=rep.qps)
    off_p95 = rows[0]["p95_s"]
    best_p95 = min(r["p95_s"] for r in rows[1:])
    _check("fleet-hedging-cuts-tail", best_p95 < off_p95,
           f"p95 off={off_p95 * 1e3:.1f}ms vs best hedged="
           f"{best_p95 * 1e3:.1f}ms (want lower)")
    return rows


def bench_parity(index, queries, gt) -> dict:
    """A 1-shard fleet reproduces the single-engine report."""
    params = SearchParams(k=10, nprobe=32)
    mono = run_workload(index, queries, params, TOS, concurrency=8,
                        seed=0, cache_policy="none")
    fleet = run_fleet(index, queries, params, FleetConfig(
        n_shards=1, replication=1, storage=TOS, concurrency=8,
        shard_concurrency=8, queue_depth=64, seed=0))
    by_qid = {r.qid: r for r in mono.records}
    ids_equal = all(np.array_equal(r.ids, by_qid[r.qid].ids)
                    for r in fleet.records)
    rel = abs(fleet.qps - mono.qps) / mono.qps
    _check("fleet-1shard-parity", ids_equal and rel < 0.05,
           f"ids_equal={ids_equal}, QPS engine={mono.qps:.1f} vs "
           f"fleet={fleet.qps:.1f} (rel diff {rel:.4f}, want < 0.05)")
    emit("fleet/parity-1shard", 1e6 / max(fleet.qps, 1e-9),
         engine_qps=mono.qps, fleet_qps=fleet.qps, rel_diff=rel)
    return dict(engine_qps=round(mono.qps, 2),
                fleet_qps=round(fleet.qps, 2),
                qps_rel_diff=round(rel, 6), ids_equal=ids_equal)


def main() -> int:
    index, queries, gt = _setup()
    results = dict(
        bench="fleet",
        quick=QUICK,
        scaling=bench_scaling(index, queries, gt),
        hedging=bench_hedging(index, queries, gt),
        parity=bench_parity(index, queries, gt),
        failures=_failures,
    )
    with open(OUT_PATH, "w") as f:
        json.dump(results, f, indent=2)
        f.write("\n")
    print(f"# wrote {os.path.abspath(OUT_PATH)}", file=sys.stderr)
    if _failures:
        print(f"# fleet_bench: FAILED {_failures}", file=sys.stderr)
        return 1
    print("# fleet_bench: all fleet checks passed", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
