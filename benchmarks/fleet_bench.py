"""Fleet serving bench: scaling curves, hedging tails, 1-shard parity,
open-loop scenarios.

Five measurements (written to ``BENCH_fleet.json`` at the repo root and
emitted as CSV rows):

1. **QPS vs shards** — closed-loop aggregate throughput at a fixed recall
   operating point (fixed nprobe => identical results at every fleet
   size), shards 1 -> 8 with up-to-2x replication.  Hard check: QPS rises
   monotonically from 1 to 4 shards.
2. **Tail latency vs hedging** — under the paper's heavy cold-TTFB tail,
   sweep the hedge deadline percentile and record p95/p99/p99.9 plus
   hedge and win rates.
3. **1-shard parity** — a 1-shard fleet must reproduce the single
   ``QueryEngine`` report (identical per-query results; QPS within 5%).
4. **Open-loop Poisson** — offered vs achieved QPS and goodput under a
   50ms SLO below and above saturation.  Hard check: underloaded
   achieved ~ offered; saturated achieved ~ closed-loop capacity.
5. **Fault injection** — kill 1 of 4 shards (R=2) for half an open-loop
   run.  Hard checks: recall identical to the clean run; every arrival
   completes; p99 sojourn degrades.

    PYTHONPATH=src python benchmarks/fleet_bench.py

Exit status is non-zero if a hard check fails.
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time

import numpy as np

from common import QUICK, emit

from repro.core.cluster_index import ClusterIndex
from repro.core.flat import exact_topk
from repro.core.types import ClusterIndexParams, SearchParams
from repro.data.synth import DEEP_ANALOG, make_dataset, scaled
from repro.fleet import FleetConfig, run_fleet
from repro.fleet.router import FleetRouter
from repro.obs import (PRICEBOOKS, MonitorConfig, Tracer, attribute,
                       run_manifest)
from repro.serving.engine import run_workload
from repro.sim.arrivals import Poisson
from repro.sim.faults import FaultSchedule, ShardFault
from repro.storage.spec import TOS

OUT_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_fleet.json")

_failures: list[str] = []


def _check(name: str, ok: bool, detail: str) -> None:
    print(f"# [{name}] {'PASS' if ok else 'FAIL'}: {detail}",
          file=sys.stderr)
    if not ok:
        _failures.append(name)


def _setup():
    n, nq = (800, 24) if QUICK else (1500, 48)
    data, queries = make_dataset(scaled(DEEP_ANALOG, n, nq))
    gt, _ = exact_topk(data, queries, 10)
    index = ClusterIndex.build(data, ClusterIndexParams(kmeans_iters=4,
                                                        seed=0))
    return index, queries, gt


def bench_scaling(index, queries, gt) -> list[dict]:
    """QPS-vs-shards at fixed recall (R = min(2, shards), po2c routing)."""
    params = SearchParams(k=10, nprobe=64)
    rows = []
    for shards in (1, 2, 4, 8):
        rep = run_fleet(index, queries, params, FleetConfig(
            n_shards=shards, replication=min(2, shards), storage=TOS,
            concurrency=64, shard_concurrency=8, queue_depth=128, seed=1))
        recall = rep.recall_against(gt)
        rows.append(dict(shards=shards, replication=min(2, shards),
                         qps=round(rep.qps, 2),
                         p99_s=round(rep.latency_percentile(99), 6),
                         recall=round(recall, 4),
                         load_imbalance=round(rep.load_imbalance, 4)))
        emit(f"fleet/scaling-{shards}sh", 1e6 / max(rep.qps, 1e-9),
             qps=rep.qps, p99_ms=rep.latency_percentile(99) * 1e3,
             recall=recall, imbalance=rep.load_imbalance)
    qps = [r["qps"] for r in rows]
    _check("fleet-scaling-monotonic", qps[0] < qps[1] < qps[2],
           f"QPS 1->2->4 shards: {qps[0]:.0f} -> {qps[1]:.0f} -> "
           f"{qps[2]:.0f} (want strictly increasing)")
    recalls = {r["recall"] for r in rows}
    _check("fleet-scaling-fixed-recall", len(recalls) == 1,
           f"recall constant across fleet sizes: {sorted(recalls)}")
    return rows


def bench_hedging(index, queries, gt) -> list[dict]:
    """Tail latency vs hedge deadline under a heavy cold-TTFB tail."""
    params = SearchParams(k=10, nprobe=64)
    heavy = dataclasses.replace(TOS, ttfb_sigma=1.1)
    rows = []
    for pct in (None, 90.0, 75.0):
        cfg = FleetConfig(
            n_shards=4, replication=2, storage=heavy, concurrency=4,
            shard_concurrency=8, queue_depth=64, seed=3,
            hedge=pct is not None, hedge_percentile=pct or 95.0,
            hedge_min_samples=16)
        rep = run_fleet(index, queries, params, cfg)
        label = "off" if pct is None else f"p{pct:.0f}"
        rows.append(dict(hedge=label,
                         p95_s=round(rep.latency_percentile(95), 6),
                         p99_s=round(rep.latency_percentile(99), 6),
                         p999_s=round(rep.latency_percentile(99.9), 6),
                         qps=round(rep.qps, 2),
                         hedge_rate=round(rep.hedge_rate, 4),
                         hedge_win_rate=round(rep.hedge_win_rate, 4),
                         recall=round(rep.recall_against(gt), 4)))
        emit(f"fleet/hedge-{label}", rep.mean_latency * 1e6,
             p95_ms=rep.latency_percentile(95) * 1e3,
             p99_ms=rep.latency_percentile(99) * 1e3,
             hedge_rate=rep.hedge_rate, qps=rep.qps)
    off_p95 = rows[0]["p95_s"]
    best_p95 = min(r["p95_s"] for r in rows[1:])
    _check("fleet-hedging-cuts-tail", best_p95 < off_p95,
           f"p95 off={off_p95 * 1e3:.1f}ms vs best hedged="
           f"{best_p95 * 1e3:.1f}ms (want lower)")
    return rows


def bench_parity(index, queries, gt) -> dict:
    """A 1-shard fleet reproduces the single-engine report."""
    params = SearchParams(k=10, nprobe=32)
    mono = run_workload(index, queries, params, TOS, concurrency=8,
                        seed=0, cache_policy="none")
    fleet = run_fleet(index, queries, params, FleetConfig(
        n_shards=1, replication=1, storage=TOS, concurrency=8,
        shard_concurrency=8, queue_depth=64, seed=0))
    by_qid = {r.qid: r for r in mono.records}
    ids_equal = all(np.array_equal(r.ids, by_qid[r.qid].ids)
                    for r in fleet.records)
    rel = abs(fleet.qps - mono.qps) / mono.qps
    _check("fleet-1shard-parity", ids_equal and rel < 0.05,
           f"ids_equal={ids_equal}, QPS engine={mono.qps:.1f} vs "
           f"fleet={fleet.qps:.1f} (rel diff {rel:.4f}, want < 0.05)")
    emit("fleet/parity-1shard", 1e6 / max(fleet.qps, 1e-9),
         engine_qps=mono.qps, fleet_qps=fleet.qps, rel_diff=rel)
    return dict(engine_qps=round(mono.qps, 2),
                fleet_qps=round(fleet.qps, 2),
                qps_rel_diff=round(rel, 6), ids_equal=ids_equal)


def bench_open_loop(index, queries, gt) -> list[dict]:
    """Offered vs achieved QPS + goodput under a 50ms SLO, below and
    above the fleet's closed-loop capacity."""
    params = SearchParams(k=10, nprobe=64)
    base = dict(n_shards=4, replication=2, storage=TOS, concurrency=24,
                shard_concurrency=4, queue_depth=32, seed=1)
    closed = run_fleet(index, queries, params, FleetConfig(**base))
    rows = []
    for label, frac in (("under", 0.6), ("saturated", 3.0)):
        rate = frac * closed.qps
        rep = run_fleet(index, queries, params, FleetConfig(**base),
                        arrivals=Poisson(rate_qps=rate,
                                         n_total=4 * len(queries)),
                        slo_s=0.05)
        rows.append(dict(
            load=label, offered_qps=round(rep.offered_qps, 2),
            achieved_qps=round(rep.qps, 2),
            goodput_qps=round(rep.goodput_qps, 2),
            goodput_frac=round(rep.goodput_frac, 4),
            p99_sojourn_s=round(rep.sojourn_percentile(99), 6),
            shed_rate=round(rep.shed_rate, 4),
            recall=round(rep.recall_against(gt), 4)))
        emit(f"fleet/openloop-{label}", 1e6 / max(rep.qps, 1e-9),
             offered_qps=rep.offered_qps, achieved_qps=rep.qps,
             goodput_frac=rep.goodput_frac,
             p99_sojourn_ms=rep.sojourn_percentile(99) * 1e3)
    under, sat = rows
    _check("fleet-openloop-tracks-offered",
           abs(under["achieved_qps"] - under["offered_qps"])
           < 0.2 * under["offered_qps"],
           f"underloaded achieved {under['achieved_qps']} vs offered "
           f"{under['offered_qps']} (want within 20%)")
    _check("fleet-openloop-saturates-at-capacity",
           abs(sat["achieved_qps"] - closed.qps) < 0.25 * closed.qps,
           f"saturated achieved {sat['achieved_qps']} vs closed-loop "
           f"capacity {closed.qps:.1f} (want within 25%)")
    return rows


def bench_faults(index, queries, gt) -> dict:
    """Kill 1 of 4 shards (R=2) for half an open-loop run: p99 degrades,
    recall does not, nothing is dropped."""
    params = SearchParams(k=10, nprobe=64)
    base = dict(n_shards=4, replication=2, storage=TOS, concurrency=24,
                shard_concurrency=4, queue_depth=32, seed=2)
    cal = run_fleet(index, queries, params, FleetConfig(**base))
    arr = lambda: Poisson(rate_qps=0.85 * cal.qps,
                          n_total=6 * len(queries))
    clean = run_fleet(index, queries, params, FleetConfig(**base),
                      arrivals=arr(), slo_s=0.1)
    horizon = clean.wall_time_s
    faults = FaultSchedule((ShardFault(shard=1, t_fail=0.2 * horizon,
                                       t_recover=0.7 * horizon),))
    faulty = run_fleet(index, queries, params, FleetConfig(**base),
                       arrivals=arr(), faults=faults, slo_s=0.1)
    rec_clean = clean.recall_against(gt)
    rec_fault = faulty.recall_against(gt)
    row = dict(
        fault="shard1-half-run",
        clean_p99_sojourn_s=round(clean.sojourn_percentile(99), 6),
        fault_p99_sojourn_s=round(faulty.sojourn_percentile(99), 6),
        clean_goodput_frac=round(clean.goodput_frac, 4),
        fault_goodput_frac=round(faulty.goodput_frac, 4),
        jobs_aborted=sum(e.get("jobs_aborted", 0)
                         for e in faulty.fault_log),
        completed=len(faulty.records), arrivals=faulty.n_arrivals,
        recall_clean=round(rec_clean, 4), recall_fault=round(rec_fault, 4))
    emit("fleet/fault-shard1", faulty.sojourn_percentile(99) * 1e6,
         clean_p99_ms=clean.sojourn_percentile(99) * 1e3,
         fault_p99_ms=faulty.sojourn_percentile(99) * 1e3,
         recall=rec_fault)
    _check("fleet-fault-recall-unchanged", rec_fault == rec_clean,
           f"recall clean={rec_clean:.4f} vs fault={rec_fault:.4f} "
           f"(want identical, R=2 re-routes losslessly)")
    _check("fleet-fault-nothing-dropped",
           len(faulty.records) == faulty.n_arrivals,
           f"{len(faulty.records)}/{faulty.n_arrivals} arrivals completed")
    _check("fleet-fault-degrades-p99",
           row["fault_p99_sojourn_s"] > row["clean_p99_sojourn_s"],
           f"p99 sojourn clean={row['clean_p99_sojourn_s'] * 1e3:.1f}ms vs "
           f"fault={row['fault_p99_sojourn_s'] * 1e3:.1f}ms (want higher)")
    return row


def bench_batch_window(index, queries, gt) -> dict:
    """Kernel execution backend (repro.exec): hard parity at window=0 —
    per-query result ids bit-identical to the analytic backend — plus
    the batch-window frontier (MXU-tile occupancy and p99 vs window),
    priced from the committed CalibrationTable."""
    params = SearchParams(k=10, nprobe=64)
    base = dict(n_shards=2, replication=1, storage=TOS, concurrency=32,
                shard_concurrency=8, queue_depth=64, seed=1)
    analytic = run_fleet(index, queries, params, FleetConfig(**base))
    by_qid = {r.qid: r for r in analytic.records}
    rows = []
    windows = (0.0, 200.0, 1000.0) if QUICK \
        else (0.0, 100.0, 200.0, 500.0, 1000.0)
    for us_w in windows:
        cfg = FleetConfig(**base, backend="kernel",
                          batch_window_s=us_w * 1e-6)
        router = FleetRouter(index, cfg)
        rep = router.run(queries, params)
        batches = jobs = 0
        occ = 0.0
        for g in router.groups:
            for srv in g.all_servers():
                be = srv.engine.backend
                batches += be.batches
                jobs += be.jobs_batched
                occ += be.occupancy_sum
        ids_eq = all(np.array_equal(r.ids, by_qid[r.qid].ids)
                     for r in rep.records)
        rows.append(dict(
            window_us=us_w, qps=round(rep.qps, 2),
            p99_s=round(rep.latency_percentile(99), 6),
            recall=round(rep.recall_against(gt), 4),
            mean_occupancy=round(occ / batches, 4) if batches else 0.0,
            mean_batch_jobs=round(jobs / batches, 3) if batches else 0.0,
            batches=batches, ids_identical=ids_eq))
        emit(f"fleet/window-{us_w:.0f}us", 1e6 / max(rep.qps, 1e-9),
             qps=rep.qps, p99_ms=rep.latency_percentile(99) * 1e3,
             occupancy=rows[-1]["mean_occupancy"],
             batch_jobs=rows[-1]["mean_batch_jobs"])
    _check("fleet-kernel-parity", all(r["ids_identical"] for r in rows),
           "kernel-backend result ids bit-identical to analytic per "
           "query at every window")
    rec_a = round(analytic.recall_against(gt), 4)
    _check("fleet-kernel-recall",
           all(r["recall"] == rec_a for r in rows),
           f"kernel-backend recall {sorted({r['recall'] for r in rows})} "
           f"vs analytic {rec_a} (want identical)")
    _check("fleet-window-batches",
           rows[-1]["mean_batch_jobs"] >= rows[0]["mean_batch_jobs"],
           f"jobs per batch {rows[0]['mean_batch_jobs']} at window 0 vs "
           f"{rows[-1]['mean_batch_jobs']} at {rows[-1]['window_us']:.0f}"
           "us (want coalescing to grow with the window)")
    return dict(analytic_qps=round(analytic.qps, 2),
                analytic_p99_s=round(analytic.latency_percentile(99), 6),
                sweep=rows)


def bench_obs(index, queries, gt) -> dict:
    """Tracing observes, never perturbs: a traced run must reproduce the
    untraced report bit for bit, cost at most 1.5x the wall time, and
    its critical-path stages must account for the measured mean sojourn
    (within 1%)."""
    params = SearchParams(k=10, nprobe=64)
    cfg = FleetConfig(
        n_shards=4, replication=2, storage=TOS, concurrency=16,
        shard_concurrency=4, queue_depth=16, seed=5,
        hedge=True, hedge_percentile=75.0, hedge_min_samples=16)

    def _run(tracer=None):
        t0 = time.perf_counter()
        rep = run_fleet(index, queries, params, cfg, tracer=tracer)
        return rep, time.perf_counter() - t0

    # min of two runs each: the guard measures tracer cost, not noise
    plain, t_plain = _run()
    _, t_plain2 = _run()
    t_plain = min(t_plain, t_plain2)
    tracer = Tracer()
    traced, t_traced = _run(tracer)
    _, t_traced2 = _run(Tracer())
    t_traced = min(t_traced, t_traced2)

    _check("obs-traced-bit-exact", plain.to_json() == traced.to_json(),
           "traced and untraced fleet reports are bit-identical")
    ratio = t_traced / max(t_plain, 1e-9)
    _check("obs-tracer-overhead", t_traced <= 1.5 * t_plain + 0.05,
           f"traced {t_traced * 1e3:.0f}ms vs untraced "
           f"{t_plain * 1e3:.0f}ms ({ratio:.2f}x, want <= 1.5x)")

    rep = attribute(tracer)
    d = rep.to_dict()
    drift = abs(d["accounted_s"] - d["mean_sojourn_s"]) \
        / max(d["mean_sojourn_s"], 1e-12)
    _check("obs-attrib-accounts-sojourn", drift < 0.01,
           f"stages account for {d['accounted_s'] * 1e3:.3f}ms of "
           f"{d['mean_sojourn_s'] * 1e3:.3f}ms mean sojourn "
           f"(drift {drift:.2e}, want < 1%)")
    emit("fleet/obs-traced", 1e6 / max(traced.qps, 1e-9),
         overhead_ratio=ratio, n_spans=len(tracer.spans),
         accounted_ms=d["accounted_s"] * 1e3)
    # wall times stay out of the returned row: the regression gate
    # compares these values and timing noise would flake it
    return dict(bit_exact=plain.to_json() == traced.to_json(),
                n_spans=len(tracer.spans), n_flows=len(tracer.flows),
                attrib=d)


def bench_explain(index, queries, gt) -> dict:
    """Tail explanation + online MRC observe, never perturb: an
    explained, MRC-profiled run must reproduce the plain report bit for
    bit, cost at most 1.5x the plain wall time, and its explain/MRC
    blocks must be identical across reruns (seeded reservoir, RNG-free
    spatial sampling)."""
    params = SearchParams(k=10, nprobe=64)
    cfg = FleetConfig(
        n_shards=4, replication=2, storage=TOS, concurrency=16,
        shard_concurrency=4, queue_depth=16, seed=5,
        hedge=True, hedge_percentile=75.0, hedge_min_samples=16,
        cache_bytes=64 * 1024, cache_policy="slru")

    def _run(**kw):
        t0 = time.perf_counter()
        rep = run_fleet(index, queries, params, cfg, **kw)
        return rep, time.perf_counter() - t0

    # min of two runs each: the guard measures observer cost, not noise
    plain, t_plain = _run()
    _, t_plain2 = _run()
    t_plain = min(t_plain, t_plain2)
    obs, t_obs = _run(tracer=Tracer(), explain=True, mrc=True)
    obs2, t_obs2 = _run(tracer=Tracer(), explain=True, mrc=True)
    t_obs = min(t_obs, t_obs2)

    s = obs.summary()
    exp, mrc = s.pop("explain"), s.pop("mrc")
    bit_exact = s == plain.summary()
    _check("obs-explain-bit-exact", bit_exact,
           "explained + MRC-profiled fleet report is bit-identical to "
           "the plain run minus the explain/mrc blocks")
    ratio = t_obs / max(t_plain, 1e-9)
    _check("obs-explain-overhead", t_obs <= 1.5 * t_plain + 0.05,
           f"explained {t_obs * 1e3:.0f}ms vs plain "
           f"{t_plain * 1e3:.0f}ms ({ratio:.2f}x, want <= 1.5x)")
    deterministic = (
        json.dumps(exp, sort_keys=True)
        == json.dumps(obs2.explain, sort_keys=True)
        and json.dumps(mrc, sort_keys=True)
        == json.dumps(obs2.mrc, sort_keys=True))
    _check("obs-explain-deterministic", deterministic,
           "explain + mrc blocks identical across two identical runs")

    top = exp["clusters"][0]
    emit("fleet/obs-explain", 1e6 / max(obs.qps, 1e-9),
         overhead_ratio=ratio, n_exemplars=exp["n_exemplars"],
         top_stage=top["stage"],
         mrc_sampled=sum(t["sampled"] for t in mrc["tenants"]))
    # wall times stay out of the returned row (timing noise would flake
    # the regression gate); the headline + clusters are virtual-time
    # deterministic and double as forensics when the gate trips
    return dict(bit_exact=bit_exact, deterministic=deterministic,
                headline=exp["headline"],
                clusters=[dict(stage=c["stage"], shard=c["shard"],
                               n=c["n"], events=c["events"])
                          for c in exp["clusters"][:3]],
                n_exemplars=exp["n_exemplars"],
                tail_pct=exp["tail_pct"],
                mrc_sampled=sum(t["sampled"] for t in mrc["tenants"]),
                mrc_accesses=sum(t["accesses"] for t in mrc["tenants"]))


def bench_cost(index, queries, gt) -> dict:
    """Monitoring + costing observe, never perturb: a monitored, priced
    run must reproduce the plain report bit for bit, and the dollar fold
    is deterministic (the regression gate compares it run to run)."""
    params = SearchParams(k=10, nprobe=64)
    cfg = FleetConfig(n_shards=4, replication=2, storage=TOS,
                      concurrency=24, shard_concurrency=4,
                      queue_depth=32, seed=1)
    plain = run_fleet(index, queries, params, cfg)
    priced = run_fleet(index, queries, params, cfg,
                       monitor=MonitorConfig(),
                       pricebook=PRICEBOOKS["default"])
    s = priced.summary()
    alerts, cost = s.pop("alerts"), s.pop("cost")
    bit_exact = s == plain.summary()
    _check("obs-priced-bit-exact", bit_exact,
           "monitored + priced fleet report is bit-identical to the "
           "plain run minus the alerts/cost blocks")
    emit("fleet/cost-default", 1e6 / max(priced.qps, 1e-9),
         total_usd=cost["total_usd"],
         usd_per_1k=cost["usd_per_1k_queries"])
    return dict(bit_exact=bit_exact, fired=len(alerts["fired"]), **cost)


def main() -> int:
    t0 = time.perf_counter()
    index, queries, gt = _setup()
    results = dict(
        bench="fleet",
        quick=QUICK,
        scaling=bench_scaling(index, queries, gt),
        hedging=bench_hedging(index, queries, gt),
        parity=bench_parity(index, queries, gt),
        scenarios=dict(open_loop=bench_open_loop(index, queries, gt),
                       fault=bench_faults(index, queries, gt)),
        batch_window=bench_batch_window(index, queries, gt),
        obs=bench_obs(index, queries, gt),
        explain=bench_explain(index, queries, gt),
        cost=bench_cost(index, queries, gt),
        failures=_failures,
    )
    results["attrib"] = results["obs"].pop("attrib")
    results["meta"] = run_manifest(
        seed=0, config=dict(bench="fleet", quick=QUICK),
        wall_s=time.perf_counter() - t0)
    with open(OUT_PATH, "w") as f:
        json.dump(results, f, indent=2)
        f.write("\n")
    print(f"# wrote {os.path.abspath(OUT_PATH)}", file=sys.stderr)
    if _failures:
        print(f"# fleet_bench: FAILED {_failures}", file=sys.stderr)
        return 1
    print("# fleet_bench: all fleet checks passed", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
