"""Fig 20–25: index-cache integration studies (RQ3, §5.4).

* Fig 20/21: SPANN gains monotonically with cache size (hit rate grows
  with recall); DiskANN saturates at a small cache under low concurrency;
* Fig 23: DiskANN per-expansion-round hit rate — entry-point rounds ~1,
  deep rounds ~0;
* Fig 24: replication × cache size — mid-size caches favour low
  replication (smaller lists -> higher hit rate), small & large caches
  favour replica=8 again;
* Fig 25: beamwidth × cache — large W suppresses roundtrip savings, but
  W-gains dominate cache-gains at high recall.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.types import SearchParams
from repro.serving.engine import EngineConfig
from repro.serving.trace import replay_workload
from repro.storage.spec import TOS

from benchmarks.common import (DEFAULT_CLUSTER, default_graph_params, emit,
                               get_cluster_index, get_dataset,
                               get_graph_index, get_traces, replay,
                               sweep_recall_qps)

DATASET = "gist-analog"


def _cache_sizes(index_bytes: int) -> dict[str, int]:
    # paper: 1/4/8 GB against a 13 GB index -> express as index fractions
    return {"none": 0,
            "small": int(index_bytes * 1 / 13),
            "mid": int(index_bytes * 4 / 13),
            "large": int(index_bytes * 8 / 13)}


def main():
    ci = get_cluster_index(DATASET, DEFAULT_CLUSTER)
    gi = get_graph_index(DATASET, default_graph_params(DATASET))
    _, _, gt = get_dataset(DATASET)

    # ---- Fig 20/21: cache size x concurrency x recall -------------------
    for kind, idx in [("cluster", ci), ("graph", gi)]:
        sizes = _cache_sizes(idx.meta.index_bytes)
        for cname, cbytes in sizes.items():
            for conc in [4, 64]:
                rows = sweep_recall_qps(DATASET, kind, idx,
                                        concurrency=conc,
                                        cache_bytes=cbytes)
                for knob, recall, rep in rows:
                    if recall >= 0.9 or (knob, recall, rep) == rows[-1]:
                        emit(f"fig20.{kind}.{cname}.c{conc}",
                             rep.mean_latency * 1e6,
                             knob=knob, recall=recall, qps=rep.qps,
                             hit_rate=rep.hit_rate)
                        break

    # ---- Fig 23: per-round hit rate profile (graph, mid cache) ---------
    sp = SearchParams(k=10, search_len=160, beamwidth=16)
    traces = get_traces(DATASET, "graph", gi, sp)
    cfg = EngineConfig(storage=TOS, concurrency=1,
                       cache_bytes=_cache_sizes(gi.meta.index_bytes)["mid"])
    rep = replay_workload(gi, traces, cfg)
    by_round: dict[int, list[float]] = {}
    for r in rep.records:
        for b in r.batches:
            tot = b.n_requests + b.n_hits
            if tot:
                by_round.setdefault(b.round_idx, []).append(b.n_hits / tot)
    for ridx in sorted(by_round)[:12]:
        emit(f"fig23.round{ridx}", 0.0,
             hit_rate=float(np.mean(by_round[ridx])),
             n=len(by_round[ridx]))

    # ---- Fig 24: replication x cache ------------------------------------
    for rep_name, rparams in [("r8", DEFAULT_CLUSTER),
                              ("r4", dataclasses.replace(DEFAULT_CLUSTER,
                                                         num_replica=4)),
                              ("r2", dataclasses.replace(DEFAULT_CLUSTER,
                                                         num_replica=2))]:
        ridx = get_cluster_index(DATASET, rparams)
        sizes = _cache_sizes(ci.meta.index_bytes)   # common base sizes
        for cname in ["small", "mid", "large"]:
            rows = sweep_recall_qps(DATASET, "cluster", ridx,
                                    concurrency=4,
                                    cache_bytes=sizes[cname])
            rep2, knob, recall = None, None, None
            for knob, recall, rep2 in rows:
                if recall >= 0.95:
                    break
            emit(f"fig24.{rep_name}.{cname}", rep2.mean_latency * 1e6,
                 nprobe=knob, recall=recall, qps=rep2.qps,
                 hit_rate=rep2.hit_rate,
                 MB_storage=rep2.mean_bytes_storage / 1e6)

    # ---- Fig 25: beamwidth x cache (ad-hoc, high recall) ----------------
    sizes = _cache_sizes(gi.meta.index_bytes)
    for W in [4, 16, 64]:
        for cname in ["none", "mid"]:
            sp = SearchParams(k=10, search_len=160, beamwidth=W)
            rep3 = replay(DATASET, "graph", gi, sp, concurrency=1,
                          cache_bytes=sizes[cname])
            emit(f"fig25.W{W}.{cname}", rep3.mean_latency * 1e6,
                 recall=rep3.recall_against(gt), qps=rep3.qps,
                 hit_rate=rep3.hit_rate,
                 roundtrips=rep3.mean_roundtrips)


if __name__ == "__main__":
    main()
