"""Shared benchmark infrastructure.

* disk-cached index builds (builds are the expensive offline step — the
  paper also builds once on local disk and uploads);
* QPS–recall sweep helper following the paper's §5.1 protocol
  (power-of-2 nprobe / search_len sweeps, early-stop at recall > 0.995);
* CSV emission: every row is ``name,us_per_call,derived`` where
  ``us_per_call`` is mean per-query latency in microseconds under the
  simulated environment and ``derived`` packs the figure-specific fields.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import pickle
import sys
import time

import numpy as np

from repro.core.cluster_index import ClusterIndex
from repro.core.flat import exact_topk
from repro.core.graph_index import GraphIndex
from repro.core.types import (ClusterIndexParams, GraphIndexParams,
                              SearchParams)
from repro.data.synth import (ANALOGS, BIGANN_ANALOG, DEEP_ANALOG,
                              GIST_ANALOG, MSSPACE_ANALOG, DatasetSpec,
                              make_dataset, scaled)
from repro.serving.engine import EngineConfig
from repro.serving.trace import record_traces, replay_workload
from repro.storage.spec import SSD, TOS, StorageSpec

CACHE_DIR = os.environ.get(
    "REPRO_BENCH_CACHE", os.path.join(os.path.dirname(__file__), ".cache"))

QUICK = os.environ.get("REPRO_BENCH_QUICK", "0") == "1"

# benchmark-scale dataset sizes (reduced-cardinality analogues — DESIGN.md
# assumption 3; QUICK mode shrinks further for smoke runs)
_SCALE = 0.2 if QUICK else 1.0


def bench_dataset(name: str) -> DatasetSpec:
    base = {
        "gist-analog": scaled(GIST_ANALOG, int(4000 * _SCALE), 40),
        "deep-analog": scaled(DEEP_ANALOG, int(15000 * _SCALE), 80),
        "msspace-analog": scaled(MSSPACE_ANALOG, int(15000 * _SCALE), 80),
        "bigann-analog": scaled(BIGANN_ANALOG, int(24000 * _SCALE), 80),
        # size-scaling variants for the Fig 13 study
        "bigann-analog-s": scaled(BIGANN_ANALOG, int(6000 * _SCALE), 50),
        "bigann-analog-m": scaled(BIGANN_ANALOG, int(12000 * _SCALE), 50),
    }
    return base[name]


def _key(*parts) -> str:
    raw = repr(parts).encode()
    return hashlib.sha256(raw).hexdigest()[:24]


def _cache_path(key: str) -> str:
    os.makedirs(CACHE_DIR, exist_ok=True)
    return os.path.join(CACHE_DIR, key + ".pkl")


def cached(key_parts, builder):
    path = _cache_path(_key(*key_parts))
    if os.path.exists(path):
        with open(path, "rb") as f:
            return pickle.load(f)
    obj = builder()
    with open(path, "wb") as f:
        pickle.dump(obj, f, protocol=4)
    return obj


# ------------------------------------------------------------- datasets --

def get_dataset(name: str):
    spec = bench_dataset(name)
    def build():
        data, queries = make_dataset(spec)
        gt, _ = exact_topk(data, queries, 10)
        return data, queries, gt
    return cached(("dataset", spec), build)


# -------------------------------------------------------------- indexes --

def get_cluster_index(dataset: str, params: ClusterIndexParams
                      ) -> ClusterIndex:
    spec = bench_dataset(dataset)
    def build():
        data, _, _ = get_dataset(dataset)
        t0 = time.time()
        idx = ClusterIndex.build(data, params)
        print(f"# built cluster[{dataset},{params}] in {time.time()-t0:.0f}s",
              file=sys.stderr)
        return idx
    return cached(("cluster", spec, params), build)


def get_graph_index(dataset: str, params: GraphIndexParams) -> GraphIndex:
    spec = bench_dataset(dataset)
    def build():
        data, _, _ = get_dataset(dataset)
        t0 = time.time()
        idx = GraphIndex.build(data, params)
        print(f"# built graph[{dataset},{params}] in {time.time()-t0:.0f}s",
              file=sys.stderr)
        return idx
    return cached(("graph", spec, params), build)


DEFAULT_CLUSTER = ClusterIndexParams(centroid_frac=0.16, num_replica=8,
                                     seed=0)
DEFAULT_GRAPH = GraphIndexParams(R=48, L_build=96, build_passes=2, seed=0)


def default_graph_params(dataset: str) -> GraphIndexParams:
    from repro.core.pq import default_pq_dims
    dim = bench_dataset(dataset).dim
    return dataclasses.replace(DEFAULT_GRAPH, pq_dims=default_pq_dims(dim))


# --------------------------------------------------------------- sweeps --

NPROBE_SWEEP = [8, 16, 32, 64, 128, 256, 512, 1024]
SEARCHLEN_SWEEP = [10, 20, 40, 80, 160, 320, 640]


def get_traces(dataset: str, index_kind: str, index, params: SearchParams):
    """Record (and cache) per-query search traces."""
    spec = bench_dataset(dataset)
    def build():
        _, queries, _ = get_dataset(dataset)
        return record_traces(index, queries, params)
    ip = index.meta.params
    return cached(("traces", spec, index_kind, ip, params), build)


def replay(dataset: str, index_kind: str, index, sparams: SearchParams,
           storage: StorageSpec = TOS, concurrency: int = 1,
           cache_bytes: int = 0, seed: int = 0):
    traces = get_traces(dataset, index_kind, index, sparams)
    cfg = EngineConfig(storage=storage, concurrency=concurrency,
                       cache_bytes=cache_bytes, seed=seed)
    rep = replay_workload(index, traces, cfg)
    return rep


def sweep_recall_qps(dataset: str, index_kind: str, index,
                     storage: StorageSpec = TOS, concurrency: int = 1,
                     cache_bytes: int = 0, stop_recall: float = 0.995):
    """Paper §5.1 protocol: sweep the index's knob in powers of two,
    early-stopping once recall > stop_recall.  Returns rows of
    (knob, recall, report)."""
    _, _, gt = get_dataset(dataset)
    rows = []
    knobs = NPROBE_SWEEP if index_kind == "cluster" else SEARCHLEN_SWEEP
    for knob in knobs:
        if index_kind == "cluster":
            if knob > index.meta.n_lists:
                break
            sp = SearchParams(k=10, nprobe=knob)
        else:
            sp = SearchParams(k=10, search_len=knob, beamwidth=16)
        rep = replay(dataset, index_kind, index, sp, storage=storage,
                     concurrency=concurrency, cache_bytes=cache_bytes)
        recall = rep.recall_against(gt)
        rows.append((knob, recall, rep))
        if recall > stop_recall:
            break
    return rows


# ------------------------------------------------------------------ CSV --

def emit(name: str, us_per_call: float, **derived) -> None:
    kv = ";".join(f"{k}={v:.6g}" if isinstance(v, float) else f"{k}={v}"
                  for k, v in derived.items())
    print(f"{name},{us_per_call:.2f},{kv}")
