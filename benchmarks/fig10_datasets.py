"""Fig 10/11/12/13: dataset-characteristics studies (RQ1, §5.2).

* Fig 10/11 (DEEP, low dim): lower nprobe & smaller lists -> SPANN's data
  read collapses; DiskANN benefits only via lower search_len (fixed-size
  4KB blocks don't shrink).
* Fig 12 (MSSPACE, int8): quantized datatype cuts SPANN bytes/query
  uniformly at fixed nprobe; DiskANN unchanged.
* Fig 13 (BIGANN, size): DiskANN roundtrips/requests scale ~log(N).
* Fig 10d: SPANN on DEEP saturates the GET-QPS limit at high concurrency.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core.types import SearchParams
from repro.storage.spec import TOS

from benchmarks.common import (DEFAULT_CLUSTER, bench_dataset,
                               default_graph_params, emit, get_cluster_index,
                               get_dataset, get_graph_index, replay,
                               sweep_recall_qps)


def main():
    # --- Fig 10/11: dimensionality (gist 960d vs deep 96d) --------------
    for dataset in ["gist-analog", "deep-analog"]:
        ci = get_cluster_index(dataset, DEFAULT_CLUSTER)
        gi = get_graph_index(dataset, default_graph_params(dataset))
        for kind, idx in [("cluster", ci), ("graph", gi)]:
            rows = sweep_recall_qps(dataset, kind, idx, concurrency=1)
            for knob, recall, rep in rows:
                if recall >= 0.9:
                    emit(f"fig10.{dataset}.{kind}", rep.mean_latency * 1e6,
                         knob=knob, recall=recall,
                         MB_per_query=rep.mean_bytes_read / 1e6,
                         roundtrips=rep.mean_roundtrips)
                    break
        emit(f"fig10.{dataset}.listsize", 0.0,
             avg_list_KB=ci.meta.avg_list_bytes / 1e3)

    # --- Fig 10d: IOPS saturation on deep at high recall/concurrency ----
    ci = get_cluster_index("deep-analog", DEFAULT_CLUSTER)
    _, _, gt = get_dataset("deep-analog")
    rows = sweep_recall_qps("deep-analog", "cluster", ci, concurrency=64)
    knob, recall, rep = rows[-1]
    iops = rep.storage_requests / rep.wall_time_s
    emit("fig10d.iops", rep.mean_latency * 1e6, recall=recall,
         iops=iops, iops_limit=TOS.get_qps_limit,
         saturation=iops / TOS.get_qps_limit,
         bw_MBps=rep.bandwidth_Bps / 1e6)

    # --- Fig 12: int8 vs f32 at matched dim (msspace vs deep) -----------
    for dataset in ["deep-analog", "msspace-analog"]:
        ci = get_cluster_index(dataset, DEFAULT_CLUSTER)
        rep = replay(dataset, "cluster", ci, SearchParams(k=10, nprobe=64))
        emit(f"fig12.{dataset}", rep.mean_latency * 1e6,
             nprobe=64, MB_per_query=rep.mean_bytes_read / 1e6,
             qps=rep.qps)

    # --- Fig 13: graph roundtrips vs dataset size -----------------------
    for dataset in ["bigann-analog-s", "bigann-analog-m", "bigann-analog"]:
        gp = default_graph_params(dataset)
        gi = get_graph_index(dataset, gp)
        _, _, gt = get_dataset(dataset)
        rows = sweep_recall_qps(dataset, "graph", gi, concurrency=1,
                                stop_recall=0.95)
        knob, recall, rep = rows[-1]
        n = bench_dataset(dataset).n
        emit(f"fig13.{dataset}", rep.mean_latency * 1e6,
             n=n, log2n=math.log2(n), recall=recall,
             roundtrips=rep.mean_roundtrips, requests=rep.mean_requests)


if __name__ == "__main__":
    main()
