"""Pallas TPU kernel: PQ asymmetric-distance (ADC) lookup.

x86/GPU ADC gathers from a 256-entry LUT per subquantizer (L1/shared-memory
resident).  TPUs have no fast per-lane gather, so the TPU-native idiom is a
one-hot × LUT matmul:

    out[n] = Σ_m  table[m, codes[n, m]]
           = Σ_m  onehot(codes[n, m]) · table[m, :]

The whole table (m × 256 f32, ≤ 128 KB for m ≤ 128) is pinned in VMEM for
every grid step — the VMEM analogue of the paper's cache-resident LUT —
while code tiles stream through.  The one-hot compare runs on the VPU and
the 256-wide contraction on the MXU.

Grid: (N/BN,) over code tiles; the m loop is a static unroll inside the
kernel (m is a small compile-time constant: paper Table 3 uses 48–112).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adc_kernel(codes_ref, table_ref, o_ref):
    codes = codes_ref[...].astype(jnp.int32)         # (BN, m)
    table = table_ref[...]                           # (m, 256) f32
    m = table.shape[0]
    # one-hot over the 256 codebook entries, contracted against the LUT:
    # (BN, m, 256) one-hot × (m, 256) -> (BN,)
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 256), 2)
    onehot = (codes[:, :, None] == iota).astype(jnp.float32)
    o_ref[...] = jnp.einsum(
        "nmc,mc->n", onehot, table,
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def adc_lookup(
    codes: jax.Array,        # (N, m) uint8/int32
    table: jax.Array,        # (m, 256) f32
    *,
    block_n: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    """ADC distances (N,) f32.

    VMEM per grid cell: BN*m codes + m*256 table + BN out
    (defaults, m=112: 1024*112*4 + 112*256*4 + 4 KB ≈ 0.6 MB).
    """
    N, m = codes.shape
    assert table.shape == (m, 256), (codes.shape, table.shape)
    bn = min(block_n, N)
    rem = (-N) % bn
    cp = jnp.pad(codes, ((0, rem), (0, 0))) if rem else codes
    Np = cp.shape[0]

    out = pl.pallas_call(
        _adc_kernel,
        grid=(Np // bn,),
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec((m, 256), lambda i: (0, 0)),   # VMEM-pinned LUT
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Np,), jnp.float32),
        interpret=interpret,
    )(cp, table.astype(jnp.float32))
    return out[:N]
