"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs under the Pallas interpreter, validating BlockSpec tiling and
numerics; on TPU the same calls compile to Mosaic.  ``interpret=None``
auto-detects.
"""
from __future__ import annotations

import jax

from repro.kernels import distance as _distance
from repro.kernels import fused_topk as _fused_topk
from repro.kernels import pq_adc as _pq_adc
from repro.kernels import ref as ref  # re-export oracles


# Backend detection is resolved once (jax.default_backend() initializes
# the platform backend — too heavy for the per-op hot path) and cached;
# tests and TPU-vs-interpret comparisons override via
# set_default_interpret().
_DEFAULT_INTERPRET: bool | None = None


def default_interpret() -> bool:
    """The cached module-level interpret default (True off-TPU)."""
    global _DEFAULT_INTERPRET
    if _DEFAULT_INTERPRET is None:
        _DEFAULT_INTERPRET = jax.default_backend() != "tpu"
    return _DEFAULT_INTERPRET


def set_default_interpret(value: bool | None) -> None:
    """Override (or, with ``None``, re-arm auto-detection of) the
    interpret default used when a call site passes ``interpret=None``."""
    global _DEFAULT_INTERPRET
    _DEFAULT_INTERPRET = value


def _auto_interpret(interpret: bool | None) -> bool:
    if interpret is not None:
        return interpret
    return default_interpret()


def l2_distance(q, x, *, interpret: bool | None = None, **kw):
    return _distance.l2_distance(
        q, x, interpret=_auto_interpret(interpret), **kw)


def adc_lookup(codes, table, *, interpret: bool | None = None, **kw):
    return _pq_adc.adc_lookup(
        codes, table, interpret=_auto_interpret(interpret), **kw)


def l2_topk(q, x, k=10, *, interpret: bool | None = None, **kw):
    return _fused_topk.l2_topk(
        q, x, k, interpret=_auto_interpret(interpret), **kw)
