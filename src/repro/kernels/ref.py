"""Pure-jnp oracles for every Pallas kernel in this package.

Kernel tests sweep shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def l2_distance_ref(q: jax.Array, x: jax.Array) -> jax.Array:
    """Squared L2: q (Q, D), x (N, D) -> (Q, N).

    f32 accumulation for float inputs; exact int32 accumulation for int8.
    """
    if q.dtype == jnp.int8:
        qi, xi = q.astype(jnp.int32), x.astype(jnp.int32)
        qn = jnp.sum(qi * qi, axis=-1)[:, None]
        xn = jnp.sum(xi * xi, axis=-1)[None, :]
        ip = jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.int32)
        return (qn + xn - 2 * ip).astype(jnp.float32)
    qf, xf = q.astype(jnp.float32), x.astype(jnp.float32)
    qn = jnp.sum(qf * qf, axis=-1)[:, None]
    xn = jnp.sum(xf * xf, axis=-1)[None, :]
    ip = jax.lax.dot_general(qf, xf, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    return jnp.maximum(qn + xn - 2.0 * ip, 0.0)


def adc_lookup_ref(codes: jax.Array, table: jax.Array) -> jax.Array:
    """PQ asymmetric distance: codes (N, m) int, table (m, 256) f32 -> (N,).

    out[n] = sum_m table[m, codes[n, m]]
    """
    m = table.shape[0]
    gathered = jnp.take_along_axis(
        table.T[None],                       # (1, 256, m)
        codes.astype(jnp.int32)[:, None, :], # (N, 1, m)
        axis=1,
    )[:, 0, :]                               # (N, m)
    return gathered.sum(axis=-1).astype(jnp.float32)


def l2_topk_ref(q: jax.Array, x: jax.Array, k: int
                ) -> tuple[jax.Array, jax.Array]:
    """Fused distance + top-k oracle: returns (dists (Q, k), ids (Q, k))."""
    d = l2_distance_ref(q, x)
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, idx
