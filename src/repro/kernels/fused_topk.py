"""Pallas TPU kernel: fused squared-L2 distance + running top-k.

Cluster-index scanning is distance-then-top-k over every probed posting
list (§2.3.1).  Materialising the (Q, N) distance matrix in HBM makes the
scan memory-bound; this kernel keeps a running per-query top-k in the
output VMEM block while streaming database tiles, so HBM traffic is
O(Q·D + N·D + Q·k) instead of O(Q·N).

Top-k inside the kernel is k rounds of Mosaic-safe min-extraction
(min-reduce + id-tiebreak + mask) — no sort/argmin primitives, so it
lowers on both interpret mode and real TPU.

Grid: (Q/BQ, N/BN); the N axis is innermost and revisits the same output
block (zero-init at j==0, merge per tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BIG = 3.4e38            # python scalars: Pallas kernels cannot capture
_BIG_ID = 2**31 - 1      # tracers/arrays from the enclosing scope


def _fused_kernel(q_ref, x_ref, vals_ref, ids_ref, *, k, bn, n_total):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, _BIG)
        ids_ref[...] = jnp.full_like(ids_ref, -1)

    q = q_ref[...].astype(jnp.float32)            # (BQ, D)
    x = x_ref[...].astype(jnp.float32)            # (BN, D)
    qn = jnp.sum(q * q, axis=-1)[:, None]
    xn = jnp.sum(x * x, axis=-1)[None, :]
    ip = jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    d = jnp.maximum(qn + xn - 2.0 * ip, 0.0)      # (BQ, BN)

    tile_ids = j * bn + jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    d = jnp.where(tile_ids < n_total, d, _BIG)    # mask padding rows

    cand_vals = jnp.concatenate([vals_ref[...], d], axis=1)
    cand_ids = jnp.concatenate([ids_ref[...], tile_ids], axis=1)
    new_vals = []
    new_ids = []
    for _ in range(k):                            # static unroll, k small
        mv = jnp.min(cand_vals, axis=1, keepdims=True)          # (BQ, 1)
        sel = jnp.where(cand_vals <= mv, cand_ids, _BIG_ID)
        mid = jnp.min(sel, axis=1, keepdims=True)               # (BQ, 1)
        new_vals.append(mv)
        new_ids.append(mid)
        cand_vals = jnp.where(cand_ids == mid, _BIG, cand_vals)
    vals_ref[...] = jnp.concatenate(new_vals, axis=1)
    ids_ref[...] = jnp.concatenate(new_ids, axis=1).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("k", "block_q", "block_n", "interpret"))
def l2_topk(
    q: jax.Array,            # (Q, D)
    x: jax.Array,            # (N, D)
    k: int = 10,
    *,
    block_q: int = 128,
    block_n: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused top-k nearest: returns (dists (Q, k) f32, ids (Q, k) int32).

    VMEM per cell (defaults, D=1024): 128*1024 + 512*1024 f32 + merge
    buffers ≈ 2.7 MB.  D is taken whole per block (fine to D≈4k).
    """
    Q, D = q.shape
    N, _ = x.shape
    qf = q.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    bq, bn = min(block_q, Q), min(block_n, max(N, k))

    remq = (-Q) % bq
    qp = jnp.pad(qf, ((0, remq), (0, 0))) if remq else qf
    remn = (-N) % bn
    xp = jnp.pad(xf, ((0, remn), (0, 0))) if remn else xf
    Qp, Np = qp.shape[0], xp.shape[0]

    vals, ids = pl.pallas_call(
        functools.partial(_fused_kernel, k=k, bn=bn, n_total=N),
        grid=(Qp // bq, Np // bn),
        in_specs=[
            pl.BlockSpec((bq, D), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, D), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Qp, k), jnp.float32),
            jax.ShapeDtypeStruct((Qp, k), jnp.int32),
        ],
        interpret=interpret,
    )(qp, xp)
    return vals[:Q], ids[:Q]
