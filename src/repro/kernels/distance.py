"""Pallas TPU kernel: tiled squared-L2 distance matrix.

The paper's hottest compute loop (Fig 2: distance comps are 29–51% of
search cost) mapped onto the MXU: per (query-tile, database-tile) the
kernel accumulates

    out[i, j] = ‖q_i‖² + ‖x_j‖² − 2·q_i·x_j

over D-tiles streamed HBM→VMEM.  The inner product rides the systolic
array (jnp.dot with f32/int32 accumulation); the norm terms are computed
tile-locally and folded into the same accumulator, so the distance matrix
never materialises in more than one VMEM tile per grid cell.

Grid: (Q/BQ, N/BN, D/BD) with the last axis as the reduction loop
(out BlockSpec ignores it; accumulate in-place, zero-init at k==0).

dtypes: float32, bfloat16 (f32 accumulate), int8 (int32 accumulate —
exact, serving the paper's quantized-dataset studies §5.2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dist_kernel(q_ref, x_ref, o_ref, *, acc_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...]          # (BQ, BD)
    x = x_ref[...]          # (BN, BD)
    if acc_dtype == jnp.int32:
        qa = q.astype(jnp.int32)
        xa = x.astype(jnp.int32)
    else:
        qa = q.astype(acc_dtype)
        xa = x.astype(acc_dtype)
    qn = jnp.sum(qa * qa, axis=-1)[:, None]      # (BQ, 1)
    xn = jnp.sum(xa * xa, axis=-1)[None, :]      # (1, BN)
    ip = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())),
        preferred_element_type=acc_dtype)        # (BQ, BN) on the MXU
    o_ref[...] += qn + xn - 2 * ip


def _pad_to(a: jax.Array, mult: int, axis: int) -> jax.Array:
    size = a.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, rem)
    return jnp.pad(a, widths)


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "block_n", "block_d", "interpret"))
def l2_distance(
    q: jax.Array,            # (Q, D)
    x: jax.Array,            # (N, D)
    *,
    block_q: int = 128,
    block_n: int = 256,
    block_d: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Squared-L2 distance matrix (Q, N), f32 (exact int32 path for int8).

    VMEM working set per grid cell:
      BQ*BD + BN*BD inputs + BQ*BN accumulator
      (defaults: 128*256 + 256*256 + 128*256 f32 ≈ 0.6 MB — well under
      the ~16 MB v5e VMEM budget, leaving room for double buffering).
    """
    Q, D = q.shape
    N, _ = x.shape
    is_int = q.dtype == jnp.int8
    acc_dtype = jnp.int32 if is_int else jnp.float32

    bq, bn, bd = min(block_q, Q), min(block_n, N), min(block_d, D)
    qp = _pad_to(_pad_to(q, bq, 0), bd, 1)
    xp = _pad_to(_pad_to(x, bn, 0), bd, 1)
    Qp, Dp = qp.shape
    Np, _ = xp.shape
    grid = (Qp // bq, Np // bn, Dp // bd)

    out = pl.pallas_call(
        functools.partial(_dist_kernel, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bd), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Qp, Np), acc_dtype),
        interpret=interpret,
    )(qp, xp)
    out = out[:Q, :N].astype(jnp.float32)
    if not is_int:
        out = jnp.maximum(out, 0.0)
    return out
