"""Deterministic discrete-event kernel — the one virtual clock everything
in this repo now runs on.

Before this module existed the codebase carried four hand-rolled
virtual-clock loops (the storage simulator's ``advance_to``, the serving
engine's event heap, the closed-loop driver's drain loop and the fleet
router's min-merge over shard clocks).  They are unified here:

* :class:`EventQueue` — a min-heap of :class:`Event` ordered by
  ``(time, seq)``; the monotonically increasing sequence number makes
  same-time events fire in insertion order, which is what makes every
  simulation in this repo bit-reproducible.
* :class:`Clock` — the virtual time owned by a kernel.  Time only moves
  when an event fires; nothing in the system polls.
* :class:`Kernel` — schedule with :meth:`Kernel.at` / :meth:`Kernel.after`
  (both return a cancellable :class:`Event`), repeat with
  :meth:`Kernel.every` (a :class:`Ticker` — the "process" primitive used
  by monitors and the autoscaler), and draw randomness through
  :meth:`Kernel.rng`, which hands out named, independently seeded streams
  so adding a consumer in one component can never shift the samples seen
  by another.

Everything is plain Python + numpy; a kernel is cheap enough to create
per run.
"""
from __future__ import annotations

import heapq
import zlib
from typing import Callable

import numpy as np

from repro.obs.trace import NULL_TRACER

#: Slack used when deciding whether an event at ``t`` belongs to
#: ``run_until(t)`` — absorbs last-ulp float error in event arithmetic.
TIME_EPS = 1e-15


class Clock:
    """Virtual time.  Advanced only by the kernel, read by everyone."""

    __slots__ = ("now",)

    def __init__(self, start: float = 0.0):
        self.now = float(start)


class Event:
    """A scheduled callback; cancel via :meth:`Kernel.cancel` (lazy).

    ``span`` is the tracing context the event was scheduled under (set
    by :meth:`Kernel.at` only when a tracer is attached); it costs one
    slot and lets ``repr`` say which span an event belongs to.
    """

    __slots__ = ("t", "seq", "fn", "args", "cancelled", "span")

    def __init__(self, t: float, seq: int, fn: Callable, args: tuple):
        self.t = t
        self.seq = seq
        self.fn = fn
        self.args = args
        self.cancelled = False
        self.span = None

    def __lt__(self, other: "Event") -> bool:
        return (self.t, self.seq) < (other.t, other.seq)

    def __repr__(self) -> str:
        state = " cancelled" if self.cancelled else ""
        span = ""
        if self.span is not None:
            span = f" span={self.span.name}#{self.span.sid}"
        return f"Event(t={self.t!r}, seq={self.seq}{state}{span})"


class EventQueue:
    """Min-heap of events keyed ``(time, seq)`` with lazy cancellation.

    The seq tie-break is load-bearing: two events scheduled for the same
    virtual instant fire in the order they were scheduled, so causally
    chained same-time work (job done -> pop queue -> submit next) keeps
    its program order and runs are deterministic.
    """

    def __init__(self):
        self._heap: list[Event] = []
        self._seq = 0
        self._live = 0

    def push(self, t: float, fn: Callable, args: tuple = ()) -> Event:
        ev = Event(t, self._seq, fn, args)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        self._live += 1
        return ev

    def cancel(self, ev: Event) -> None:
        if not ev.cancelled:
            ev.cancelled = True
            self._live -= 1

    def peek(self) -> Event | None:
        """Earliest live event without removing it."""
        heap = self._heap
        while heap and heap[0].cancelled:
            heapq.heappop(heap)
        return heap[0] if heap else None

    def pop(self) -> Event | None:
        ev = self.peek()
        if ev is not None:
            heapq.heappop(self._heap)
            self._live -= 1
        return ev

    def __len__(self) -> int:
        return self._live

    def __bool__(self) -> bool:
        return self._live > 0


class Ticker:
    """A repeating timer (the kernel's "process" for periodic work).

    Fires ``fn(now)`` every ``interval`` until cancelled.  Tickers keep
    the kernel busy forever, so whoever starts one owns stopping it
    (e.g. the fleet router cancels its monitor once the workload drains).
    """

    def __init__(self, kernel: "Kernel", interval: float, fn: Callable,
                 start: float | None = None):
        if interval <= 0:
            raise ValueError(f"interval must be > 0, got {interval}")
        self.kernel = kernel
        self.interval = interval
        self.fn = fn
        self.cancelled = False
        first = kernel.now + interval if start is None else start
        self._ev = kernel.at(first, self._fire)

    def _fire(self) -> None:
        if self.cancelled:
            return
        self.fn(self.kernel.now)
        if not self.cancelled:                    # fn may cancel us
            self._ev = self.kernel.at(self.kernel.now + self.interval,
                                      self._fire)

    def cancel(self) -> None:
        if not self.cancelled:
            self.cancelled = True
            self.kernel.cancel(self._ev)


class Kernel:
    """The discrete-event kernel: one clock, one queue, named RNG streams.

    Components hold a reference to the kernel, schedule their own events
    and never see each other's: causality is purely through event times
    and the (time, seq) total order.
    """

    def __init__(self, seed: int = 0):
        self.seed = seed
        self.clock = Clock()
        self.queue = EventQueue()
        self._rngs: dict[str, np.random.Generator] = {}
        self._name_counts: dict[str, int] = {}
        self.events_fired = 0
        # Tracing context.  The tracer observes and never perturbs: it
        # schedules no events and draws no RNG, so attaching one leaves
        # the (time, seq) order — and therefore every result — bit-exact.
        self.tracer = NULL_TRACER
        self.current_span = None

    # ------------------------------------------------------------ clock --
    @property
    def now(self) -> float:
        return self.clock.now

    # ------------------------------------------------------- scheduling --
    def at(self, t: float, fn: Callable, *args) -> Event:
        """Schedule ``fn(*args)`` at virtual time ``t`` (>= now)."""
        if t < self.clock.now - TIME_EPS:
            raise ValueError(
                f"cannot schedule at t={t!r} before now={self.clock.now!r}")
        ev = self.queue.push(max(t, self.clock.now), fn, args)
        if self.tracer.enabled:
            ev.span = self.current_span
        return ev

    def after(self, delay: float, fn: Callable, *args) -> Event:
        return self.at(self.clock.now + delay, fn, *args)

    def every(self, interval: float, fn: Callable,
              start: float | None = None) -> Ticker:
        return Ticker(self, interval, fn, start=start)

    def cancel(self, ev: Event) -> None:
        self.queue.cancel(ev)

    # ------------------------------------------------------------- rng ---
    def rng(self, name: str, seed: int | None = None) -> np.random.Generator:
        """The named RNG stream, created on first use.

        Without an explicit ``seed`` the stream is derived from
        ``(kernel seed, crc32(name))`` so distinct components draw from
        independent, reproducible streams.  An explicit ``seed`` pins the
        stream to ``default_rng(seed)`` (used where a pre-kernel sample
        sequence must be preserved exactly).
        """
        if name not in self._rngs:
            if seed is None:
                self._rngs[name] = np.random.default_rng(
                    (self.seed, zlib.crc32(name.encode())))
            else:
                self._rngs[name] = np.random.default_rng(seed)
        return self._rngs[name]

    def unique_name(self, prefix: str) -> str:
        """Deterministic per-kernel unique names (RNG stream keys)."""
        i = self._name_counts.get(prefix, 0)
        self._name_counts[prefix] = i + 1
        return f"{prefix}#{i}"

    # ------------------------------------------------------------- run ---
    def peek(self) -> float | None:
        """Time of the next live event, or None when idle."""
        ev = self.queue.peek()
        return ev.t if ev is not None else None

    def step(self) -> bool:
        """Fire the single earliest event; False when the queue is idle."""
        ev = self.queue.pop()
        if ev is None:
            return False
        if ev.t > self.clock.now:
            self.clock.now = ev.t
        self.events_fired += 1
        if self.tracer.enabled:
            # Restore the scheduling span around the callback so spans
            # opened without an explicit parent nest across event hops.
            prev = self.current_span
            self.current_span = ev.span
            try:
                ev.fn(*ev.args)
            finally:
                self.current_span = prev
        else:
            ev.fn(*ev.args)
        return True

    def run(self, max_events: int | None = None) -> int:
        """Fire events until the queue drains; returns events fired.

        ``max_events`` is a runaway guard: exceeding it raises instead of
        hanging (a scheduling bug in any component would otherwise stall
        the whole simulation).
        """
        n = 0
        while self.step():
            n += 1
            if max_events is not None and n >= max_events:
                raise RuntimeError(
                    f"kernel fired {n} events without draining "
                    f"(suspected event loop; next at t={self.peek()!r})")
        return n

    def run_until(self, t: float) -> int:
        """Fire every event with timestamp <= ``t``; clock ends at ``t``."""
        n = 0
        while True:
            ev = self.queue.peek()
            if ev is None or ev.t > t + TIME_EPS:
                break
            self.step()
            n += 1
        if t > self.clock.now:
            self.clock.now = t
        return n

    @property
    def busy(self) -> bool:
        return bool(self.queue)
