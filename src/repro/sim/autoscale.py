"""SLO-driven autoscaling: size the fleet from tail-latency error.

The controller is a kernel :class:`~repro.sim.kernel.Ticker` that wakes
every ``check_interval_s``, estimates p99 arrival-to-completion time over
a sliding window of recent completions and computes the relative SLO
error ``(p99 - slo) / slo``:

* error > ``up_error``   → add one instance to the most loaded shard
  (cold cache — the new replica re-warms from traffic);
* error < ``down_error`` → drain one extra instance from the least
  loaded shard (it stops taking new work, finishes its queue, then stops
  billing).

Scaling acts on serving *instances*, not data placement: storage is
disaggregated, so capacity can follow load while the partition (and with
R >= 2, fault tolerance) stays fixed.  Every decision is recorded, and
the fleet report prices the run in **shards·seconds** — the integral of
active instances over the run, i.e. what a cloud bill would charge.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sim.kernel import Kernel, Ticker


@dataclasses.dataclass(frozen=True)
class AutoscaleConfig:
    slo_p99_s: float               # the target the controller defends
    check_interval_s: float = 0.1
    window: int = 64               # completions in the p99 estimate
    min_samples: int = 16          # don't act on thin evidence
    up_error: float = 0.0          # scale up when error > this
    down_error: float = -0.5       # scale down when error < this
    cooldown_s: float = 0.25       # min time between actions
    min_instances: int = 1         # per shard
    max_instances: int = 4         # per shard

    def __post_init__(self):
        if self.slo_p99_s <= 0:
            raise ValueError(f"slo_p99_s must be > 0, got {self.slo_p99_s}")
        if self.check_interval_s <= 0:
            raise ValueError("check_interval_s must be > 0")
        if self.down_error >= self.up_error:
            raise ValueError(
                f"down_error ({self.down_error}) must be < up_error "
                f"({self.up_error})")
        if not 1 <= self.min_instances <= self.max_instances:
            raise ValueError(
                f"need 1 <= min_instances <= max_instances, got "
                f"{self.min_instances}..{self.max_instances}")

    def to_dict(self) -> dict:
        return dict(slo_p99_s=self.slo_p99_s,
                    check_interval_s=self.check_interval_s,
                    window=self.window, min_samples=self.min_samples,
                    up_error=self.up_error,
                    down_error=self.down_error, cooldown_s=self.cooldown_s,
                    min_instances=self.min_instances,
                    max_instances=self.max_instances)


class Autoscaler:
    """The controller process.  ``fleet`` is any object exposing
    ``recent_sojourns`` (iterable of floats), ``total_instances``,
    ``scale_up_one()`` and ``scale_down_one()`` (both return a bool)."""

    def __init__(self, cfg: AutoscaleConfig, fleet):
        self.cfg = cfg
        self.fleet = fleet
        self.events: list[dict] = []       # every decision, acted or not
        self._last_action_t = -float("inf")
        self._ticker: Ticker | None = None
        self._kernel: Kernel | None = None

    def start(self, kernel: Kernel) -> None:
        self._kernel = kernel
        self._ticker = kernel.every(self.cfg.check_interval_s, self._check)

    def stop(self) -> None:
        if self._ticker is not None:
            self._ticker.cancel()
            self._ticker = None

    # ------------------------------------------------------------ policy --
    def _check(self, now: float) -> None:
        cfg = self.cfg
        lats = list(self.fleet.recent_sojourns)
        if len(lats) < cfg.min_samples:
            return
        p99 = float(np.percentile(np.asarray(lats), 99.0))
        err = (p99 - cfg.slo_p99_s) / cfg.slo_p99_s
        action = "hold"
        if now - self._last_action_t >= cfg.cooldown_s:
            if err > cfg.up_error:
                if self.fleet.scale_up_one():
                    action = "up"
                    self._last_action_t = now
            elif err < cfg.down_error:
                if self.fleet.scale_down_one():
                    action = "down"
                    self._last_action_t = now
        if action != "hold" or not self.events or \
                self.events[-1]["action"] != "hold":
            self.events.append(dict(
                t=round(now, 6), p99_s=round(p99, 6), error=round(err, 4),
                action=action, instances=self.fleet.total_instances))
        if action != "hold":
            tr = self._kernel.tracer
            if tr.enabled:
                tr.instant(f"autoscale_{action}", now, p99_s=round(p99, 6),
                           error=round(err, 4),
                           instances=self.fleet.total_instances)

    # ------------------------------------------------------- alert hook --
    def alert_scale_up(self, now: float, alert) -> bool:
        """Action-bus subscriber (``repro.obs.monitor``): a fired
        page-severity burn alert forces a scale-up decision *between*
        periodic checks.  The cooldown still applies — the burn windows
        and the controller share one actuation budget, so the two
        policies cannot fight each other into oscillation."""
        if now - self._last_action_t < self.cfg.cooldown_s:
            return False
        if not self.fleet.scale_up_one():
            return False
        self._last_action_t = now
        self.events.append(dict(
            t=round(now, 6), action="up",
            reason=f"alert:{alert.monitor}/{alert.rule}",
            instances=self.fleet.total_instances))
        if self._kernel is not None:
            tr = self._kernel.tracer
            if tr.enabled:
                tr.instant("autoscale_up", now,
                           reason=f"alert:{alert.monitor}/{alert.rule}",
                           instances=self.fleet.total_instances)
        return True
