"""``repro.sim`` — the discrete-event simulation layer.

* :mod:`repro.sim.kernel` — deterministic event kernel (EventQueue with
  seq tie-breaking, Clock, timers/Ticker, named RNG streams).  Storage,
  serving and fleet all run on one kernel per run.
* :mod:`repro.sim.arrivals` — how queries arrive: closed-loop windows,
  open-loop Poisson (optionally diurnal/burst-modulated) and trace
  replay.
* :mod:`repro.sim.faults` — shard failure/recovery schedules.
* :mod:`repro.sim.autoscale` — SLO-driven replica autoscaling policy.
"""
from repro.sim.kernel import Clock, Event, EventQueue, Kernel, Ticker

__all__ = ["Clock", "Event", "EventQueue", "Kernel", "Ticker"]
