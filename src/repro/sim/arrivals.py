"""Arrival processes: how queries reach a serving system.

The paper's harness is **closed-loop** — a fixed concurrency window drains
a query list, so offered load always equals capacity and the system never
falls behind.  Cloud services face **open-loop** traffic: queries arrive
whether or not the fleet keeps up.  This module makes the arrival process
a first-class axis:

* :class:`ClosedLoop` — the paper's §5.1 regime (all work queued at t=0,
  a window of ``concurrency`` in service) — the default everywhere, and
  the process under which the kernel refactor reproduces the pre-kernel
  reports exactly.
* :class:`Poisson` — open-loop memoryless arrivals at ``rate_qps``,
  optionally modulated (``diurnal`` / ``burst``) via thinning.
* :class:`Trace` — replay explicit (arrival time, workload index) pairs;
  :func:`zipf_trace` builds one from ``serving.workload``'s long-tailed
  repetition model.

A driver (``QueryEngine`` or ``FleetRouter``) passes itself as the sink:
``arrive(arrival_idx, workload_idx)`` is called at the kernel's current
virtual time for each arrival; the driver owns admission (window + FIFO
backlog) and completion accounting.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

from repro.sim.kernel import Kernel

ARRIVAL_KINDS = ("closed", "poisson", "burst", "trace", "rw")


def offered_rate(n_arrivals: int, last_arrival_t: float,
                 wall_t: float) -> float:
    """Offered load in QPS: arrivals over the arrival span, falling back
    to the wall clock for instantaneous processes (closed loop arrives
    everything at t=0, where offered == achieved by construction)."""
    if last_arrival_t > 0:
        return n_arrivals / last_arrival_t
    return n_arrivals / wall_t if wall_t > 0 else 0.0


# ------------------------------------------------------------ modulation --

@dataclasses.dataclass(frozen=True)
class Modulation:
    """A time-varying rate multiplier with a known peak (for thinning)."""

    fn: Callable[[float], float]
    peak: float

    def __call__(self, t: float) -> float:
        return self.fn(t)


def diurnal(period_s: float, amplitude: float = 0.5) -> Modulation:
    """Sinusoidal day/night load: rate × (1 + amplitude·sin(2πt/T))."""
    if not 0.0 <= amplitude <= 1.0:
        raise ValueError(f"amplitude must be in [0, 1], got {amplitude}")
    return Modulation(
        fn=lambda t: 1.0 + amplitude * math.sin(2 * math.pi * t / period_s),
        peak=1.0 + amplitude)


def burst(t0: float, t1: float, factor: float) -> Modulation:
    """Rate × ``factor`` inside [t0, t1), ×1 outside (a traffic spike)."""
    if factor <= 0:
        raise ValueError(f"factor must be > 0, got {factor}")
    peak = max(1.0, factor)
    return Modulation(fn=lambda t: factor if t0 <= t < t1 else 1.0,
                      peak=peak)


# -------------------------------------------------------------- processes --

class ArrivalProcess:
    """Base class.  ``window`` overrides the driver's admission window.

    ``start`` begins generating: ``arrive(arrival_idx, workload_idx)``
    fires at each arrival's virtual time; ``done()`` fires once no
    further arrivals will ever come (drivers use it to stop their
    monitor/controller processes).
    """

    kind = "closed"
    window: int | None = None
    #: kernel RNG stream name — tenancy renames it per tenant so N
    #: stochastic arrival processes on one kernel draw independently
    rng_stream: str = "arrivals"

    def start(self, kernel: Kernel, arrive: Callable[[int, int], None],
              n_workload: int, done: Callable[[], None] | None = None
              ) -> None:
        raise NotImplementedError


class ClosedLoop(ArrivalProcess):
    """The paper's closed loop: ``n_total`` queries queued at t=0 and
    served through a window of ``concurrency`` (driver default)."""

    kind = "closed"

    def __init__(self, concurrency: int | None = None,
                 n_total: int | None = None):
        self.window = concurrency
        self.n_total = n_total

    def start(self, kernel, arrive, n_workload, done=None):
        n = self.n_total if self.n_total is not None else n_workload
        for i in range(n):
            arrive(i, i % n_workload)
        if done is not None:
            done()


class Poisson(ArrivalProcess):
    """Open-loop Poisson arrivals at ``rate_qps`` (optionally modulated).

    Generation stops after ``n_total`` arrivals or past ``duration_s``,
    whichever comes first (at least one must be given).  Modulated rates
    use thinning: candidates at the peak rate, accepted with probability
    ``m(t)/peak`` — exact for any bounded profile.
    """

    kind = "poisson"

    def __init__(self, rate_qps: float, *, n_total: int | None = None,
                 duration_s: float | None = None,
                 modulation: Modulation | None = None,
                 kind: str | None = None):
        if rate_qps <= 0:
            raise ValueError(f"rate_qps must be > 0, got {rate_qps}")
        if n_total is None and duration_s is None:
            raise ValueError("Poisson needs n_total and/or duration_s")
        self.rate = rate_qps
        self.n_total = n_total
        self.duration = duration_s
        self.modulation = modulation
        if kind is not None:           # e.g. "burst" from Scenario
            self.kind = kind

    def start(self, kernel, arrive, n_workload, done=None):
        rng = kernel.rng(self.rng_stream)
        mod = self.modulation
        peak_rate = self.rate * (mod.peak if mod is not None else 1.0)

        def next_time(t: float) -> float:
            while True:
                t += rng.exponential(1.0 / peak_rate)
                if mod is None:
                    return t
                if rng.uniform() * mod.peak <= max(mod(t), 0.0):
                    return t

        def fire(i: int) -> None:
            arrive(i, i % n_workload)
            schedule(i + 1, kernel.now)

        def schedule(i: int, t_prev: float) -> None:
            if self.n_total is not None and i >= self.n_total:
                if done is not None:
                    done()
                return
            t = next_time(t_prev)
            if self.duration is not None and t > self.duration:
                if done is not None:
                    done()
                return
            kernel.at(t, fire, i)

        schedule(0, 0.0)


class Trace(ArrivalProcess):
    """Replay explicit arrivals: ``times[i]`` → workload item ``qids[i]``
    (defaults to round-robin over the workload)."""

    kind = "trace"

    def __init__(self, times, qids=None):
        self.times = np.asarray(times, dtype=np.float64)
        if len(self.times) == 0:
            raise ValueError("trace must contain at least one arrival")
        if np.any(np.diff(self.times) < 0):
            raise ValueError("trace times must be non-decreasing")
        self.qids = None if qids is None else np.asarray(qids, dtype=np.int64)
        if self.qids is not None and len(self.qids) != len(self.times):
            raise ValueError(
                f"times ({len(self.times)}) and qids ({len(self.qids)}) "
                f"lengths differ")

    def start(self, kernel, arrive, n_workload, done=None):
        for i, t in enumerate(self.times):
            wi = int(self.qids[i]) % n_workload if self.qids is not None \
                else i % n_workload
            kernel.at(float(t), arrive, i, wi)
        if done is not None:
            # scheduled after the last arrival (same time, later seq)
            kernel.at(float(self.times[-1]), lambda: done())


def zipf_trace(n_workload: int, rate_qps: float, n_total: int,
               a: float = 1.2, seed: int = 0) -> Trace:
    """A production-style trace: Poisson arrival times × the long-tailed
    (Zipf-repeated) query popularity of ``serving.workload`` — hot queries
    recur, which is what makes shard caches and re-warm matter."""
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(1.0 / rate_qps, size=n_total))
    ranks = rng.zipf(a, size=n_total)
    idx = np.minimum(ranks - 1, n_workload - 1)
    perm = rng.permutation(n_workload)            # random hot set
    return Trace(times, qids=perm[idx])


# --------------------------------------------------------------- scenario --

@dataclasses.dataclass(frozen=True)
class Scenario:
    """Declarative scenario — what the CLIs and the tuner pass around.

    ``kind``: "closed" (paper harness), "poisson" (open loop), "burst"
    (Poisson with a mid-run spike), "trace" (Zipf-repeated replay),
    "rw" (closed-loop queries + a live insert/delete stream at
    ``write_rate_qps`` — the read-write mix ``repro.ingest`` serves).
    A zero write rate makes "rw" byte-identical to "closed".
    """

    kind: str = "closed"
    rate_qps: float = 200.0            # offered load (open-loop kinds)
    duration_s: float | None = None    # arrival horizon
    n_arrivals: int | None = None      # arrival count cap
    burst_factor: float = 4.0
    burst_start_s: float = 0.25
    burst_len_s: float = 0.25
    zipf_a: float = 1.2                # trace popularity skew
    slo_s: float = 0.05                # p99 target for goodput/autoscaling
    write_rate_qps: float = 0.0        # rw: update arrival rate
    n_updates: int | None = None       # rw: update count cap
    delete_frac: float = 0.2           # rw: delete share of updates

    def __post_init__(self):
        if self.kind not in ARRIVAL_KINDS:
            raise ValueError(
                f"unknown scenario kind {self.kind!r}; one of "
                f"{ARRIVAL_KINDS}")
        if self.slo_s <= 0:
            raise ValueError(f"slo_s must be > 0, got {self.slo_s}")
        if self.kind not in ("closed", "rw") and self.rate_qps <= 0:
            raise ValueError(f"rate_qps must be > 0, got {self.rate_qps}")
        if self.kind == "trace" and self.zipf_a <= 1.0:
            raise ValueError(
                f"zipf_a must be > 1 (numpy zipf domain), got "
                f"{self.zipf_a}")
        if self.write_rate_qps < 0:
            raise ValueError(f"write_rate_qps must be >= 0, got "
                             f"{self.write_rate_qps}")
        if not 0.0 <= self.delete_frac < 1.0:
            raise ValueError(f"delete_frac must be in [0, 1), got "
                             f"{self.delete_frac}")

    def make_arrivals(self, n_workload: int, concurrency: int,
                      seed: int = 0) -> ArrivalProcess:
        if self.kind in ("closed", "rw"):
            # n_arrivals cycles the query set (rw runs use it to keep
            # read traffic live for the whole write stream)
            return ClosedLoop(concurrency, n_total=self.n_arrivals)
        n = self.n_arrivals
        dur = self.duration_s
        if n is None and dur is None:
            dur = 1.0
        if self.kind == "poisson":
            return Poisson(self.rate_qps, n_total=n, duration_s=dur)
        if self.kind == "burst":
            return Poisson(
                self.rate_qps, n_total=n, duration_s=dur, kind="burst",
                modulation=burst(self.burst_start_s,
                                 self.burst_start_s + self.burst_len_s,
                                 self.burst_factor))
        # trace: needs a concrete arrival count
        n = n if n is not None else max(
            1, int(round(self.rate_qps * (dur if dur else 1.0))))
        return zipf_trace(n_workload, self.rate_qps, n, a=self.zipf_a,
                          seed=seed)

    def make_updates(self, data, seed: int = 0,
                     protected: frozenset | None = None):
        """The rw scenario's write stream (None for read-only kinds or a
        zero write rate — so a zero-write "rw" run schedules no update
        events and stays bit-identical to "closed")."""
        if self.kind != "rw" or self.write_rate_qps <= 0:
            return None
        from repro.ingest.stream import synth_updates
        n = self.n_updates
        if n is None:
            n = max(1, int(round(self.write_rate_qps
                                 * (self.duration_s or 1.0))))
        return synth_updates(data, self.write_rate_qps, n,
                             delete_frac=self.delete_frac, seed=seed,
                             protected=protected)

    def to_dict(self) -> dict:
        d = dict(kind=self.kind, slo_s=self.slo_s)
        if self.kind not in ("closed", "rw"):
            d.update(rate_qps=self.rate_qps, duration_s=self.duration_s,
                     n_arrivals=self.n_arrivals)
        if self.kind == "burst":
            d.update(burst_factor=self.burst_factor,
                     burst_start_s=self.burst_start_s,
                     burst_len_s=self.burst_len_s)
        if self.kind == "trace":
            d.update(zipf_a=self.zipf_a)
        if self.kind == "rw":
            d.update(write_rate_qps=self.write_rate_qps,
                     n_updates=self.n_updates,
                     delete_frac=self.delete_frac)
        return d
