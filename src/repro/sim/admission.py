"""The one admission-window driver every stream in the system shares.

Three consumers used to hand-roll the same loop — a window of
``window`` in-service items over a FIFO backlog, with per-arrival
bookkeeping (arrival time, offered-rate counters):

* :class:`repro.serving.engine.QueryEngine` — queries into one engine;
* :class:`repro.fleet.router.FleetRouter` — queries into a shard fleet;
* :class:`repro.ingest.compaction.IngestAgent` — the update stream into
  a delta tier (applies are serialized through a window of 1, so update
  backpressure surfaces as freshness lag, exactly like query
  backpressure surfaces as sojourn).

The helper is purely synchronous — it schedules **no kernel events** of
its own, so folding it into a driver cannot perturb event order: an
``offer`` either starts the item immediately (same virtual instant,
same call stack) or parks it in the backlog; a ``release`` either pops
the backlog (starting the next item at the completing item's timestamp)
or shrinks the in-service count.  That property is what lets the
kernel-refactor golden files (bit-exact closed-loop reports) survive
the unification.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Callable

from repro.sim.arrivals import offered_rate
from repro.sim.kernel import Kernel


class AdmissionWindow:
    """Window + FIFO backlog + arrival bookkeeping for one stream.

    ``start(item, t)`` is the driver's service entry point: it is called
    synchronously either from :meth:`offer` (admission at the arrival
    instant) or from :meth:`release` (backlog pop at the completing
    item's virtual time ``t``).
    """

    def __init__(self, kernel: Kernel, window: int,
                 start: Callable[[Any, float], None]):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.kernel = kernel
        self.window = window
        self._start = start
        self.backlog: deque = deque()
        self.in_window = 0
        self.arrive_t: dict[Any, float] = {}
        self.arrivals_total = 0
        self.last_arrival_t = 0.0
        self.exhausted = False        # the arrival process finished

    # --------------------------------------------------------- arrivals --
    def offer(self, item: Any, key: Any = None) -> bool:
        """An arrival at the kernel's current time.  Returns True when the
        item entered service immediately (window had room), False when it
        joined the backlog.  ``key`` (default: the item itself) indexes
        the arrival-time record consumed by :meth:`pop_arrive_t`."""
        t = self.kernel.now
        self.arrivals_total += 1
        self.last_arrival_t = t
        self.arrive_t[item if key is None else key] = t
        if self.in_window < self.window:
            self.in_window += 1
            self._start(item, t)
            return True
        self.backlog.append(item)
        return False

    def pop_arrive_t(self, key: Any) -> float:
        """Claim (and forget) the arrival time recorded for ``key``."""
        return self.arrive_t.pop(key)

    # ------------------------------------------------------ completions --
    def release(self, t: float) -> bool:
        """One in-service item finished at virtual time ``t``: start the
        next backlogged item at exactly ``t``, or shrink the in-service
        count.  Returns True when a backlogged item was started.

        The ``in_window <= window`` guard only matters when ``window``
        was shrunk mid-run (alert-driven tenant deprioritization,
        ``repro.obs.monitor``): in-flight items above the new window
        drain off instead of being replaced from the backlog.  With a
        static window the guard always holds at this point, so the
        behavior (and the golden files) are unchanged."""
        if self.backlog and self.in_window <= self.window:
            self._start(self.backlog.popleft(), t)
            return True
        self.in_window -= 1
        return False

    def mark_exhausted(self) -> None:
        self.exhausted = True

    # ------------------------------------------------------------ state --
    @property
    def idle(self) -> bool:
        return self.in_window == 0 and not self.backlog

    @property
    def drained(self) -> bool:
        """No more arrivals will ever come and nothing is in service."""
        return self.exhausted and self.idle

    @property
    def depth(self) -> int:
        """Items waiting (not yet in service)."""
        return len(self.backlog)

    def offered_qps(self, wall_t: float) -> float:
        return offered_rate(self.arrivals_total, self.last_arrival_t,
                            wall_t)
