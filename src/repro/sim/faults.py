"""Shard failure/recovery schedules.

A fault kills every instance of one logical shard at ``t_fail`` (their
in-flight and queued jobs are aborted and re-routed by the router to
surviving replica owners) and optionally revives them at ``t_recover``
with **cold caches** — the re-warm after recovery is part of what the
scenario measures.  With data replication R >= 2 a failure degrades tail
latency but never recall: every key is still owned by a live shard and
replica scans return identical results.
"""
from __future__ import annotations

import dataclasses

from repro.sim.kernel import Kernel


@dataclasses.dataclass(frozen=True)
class ShardFault:
    """One shard goes down at ``t_fail`` (back at ``t_recover``, if set)."""

    shard: int
    t_fail: float
    t_recover: float | None = None

    def __post_init__(self):
        if self.shard < 0:
            raise ValueError(f"shard must be >= 0, got {self.shard}")
        if self.t_fail < 0:
            raise ValueError(f"t_fail must be >= 0, got {self.t_fail}")
        if self.t_recover is not None and self.t_recover <= self.t_fail:
            raise ValueError(
                f"t_recover ({self.t_recover}) must be after t_fail "
                f"({self.t_fail})")

    @classmethod
    def parse(cls, spec: str) -> "ShardFault":
        """Parse the CLI form ``shard:t_fail[:t_recover]``."""
        parts = spec.split(":")
        if len(parts) not in (2, 3):
            raise ValueError(
                f"fault spec {spec!r} is not shard:t_fail[:t_recover]")
        return cls(shard=int(parts[0]), t_fail=float(parts[1]),
                   t_recover=float(parts[2]) if len(parts) == 3 else None)

    def to_dict(self) -> dict:
        return dict(shard=self.shard, t_fail=self.t_fail,
                    t_recover=self.t_recover)


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    faults: tuple[ShardFault, ...]

    @classmethod
    def parse(cls, specs) -> "FaultSchedule":
        return cls(tuple(ShardFault.parse(s) for s in specs))

    def install(self, kernel: Kernel, fleet) -> None:
        """Schedule the kill/revive events against a fleet router (any
        object with ``fail_shard(shard)`` / ``recover_shard(shard)``)."""
        for f in self.faults:
            kernel.at(f.t_fail, fleet.fail_shard, f.shard)
            if f.t_recover is not None:
                kernel.at(f.t_recover, fleet.recover_shard, f.shard)

    def to_dicts(self) -> list[dict]:
        return [f.to_dict() for f in self.faults]
