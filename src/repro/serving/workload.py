"""Query workload generation.

The paper serves each dataset's query set sequentially from a cold cache
(§5.4); production traces additionally show *commonality and stability*
(long-tailed, stable access patterns — §4.1 [47, 62, 63, 91]), which we
model with Zipf-repeated queries for the extended cache studies.
"""
from __future__ import annotations

import numpy as np


def sequential(queries: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """The paper's workload: each query once, in order."""
    return queries, np.arange(len(queries))


def zipf_repeated(queries: np.ndarray, n_total: int, a: float = 1.2,
                  seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Long-tailed repetition: hot queries recur (agentic-AI style traces).

    Returns (workload queries, original query ids) — ids map results back
    to ground truth.
    """
    rng = np.random.default_rng(seed)
    ranks = rng.zipf(a, size=n_total)
    idx = np.minimum(ranks - 1, len(queries) - 1)
    perm = rng.permutation(len(queries))      # random hot set
    idx = perm[idx]
    return queries[idx], idx


def perturbed_zipf(queries: np.ndarray, n_total: int, noise: float = 0.01,
                   a: float = 1.2, seed: int = 0
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Zipf repetition with small perturbations: near-duplicate queries hit
    the same index segments without being byte-identical (cache-friendly
    but not degenerate)."""
    base, idx = zipf_repeated(queries, n_total, a=a, seed=seed)
    rng = np.random.default_rng(seed + 1)
    scale = np.abs(base).mean() * noise
    out = base.astype(np.float32) + rng.normal(
        0, scale, size=base.shape).astype(np.float32)
    if queries.dtype == np.int8:
        out = np.clip(np.round(out), -127, 127).astype(np.int8)
    return out, idx
