"""The cloud-native query engine: index × storage simulator × cache.

Closed-loop serving (paper §5.1): ``concurrency`` workers drain the query
queue; each query runs its index ``search_plan`` generator, whose fetch
batches flow through the cache and the discrete-event storage simulator.
Compute phases are priced from the metrics deltas the plan records
(distance comps × ComputeSpec) — reproducing the CPU/I/O split of Fig 2/3.

Everything is virtual-time deterministic for a given seed.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Iterable

import numpy as np

from repro.cache.slru import PinnedCache, SLRUCache
from repro.core.cost_model import DEFAULT_COMPUTE, ComputeSpec
from repro.core.types import QueryMetrics, SearchParams
from repro.serving.metrics import BatchTrace, QueryRecord, WorkloadReport
from repro.storage.simulator import StorageSim
from repro.storage.spec import StorageSpec


@dataclasses.dataclass
class EngineConfig:
    storage: StorageSpec
    concurrency: int = 1
    cache_bytes: int = 0
    cache_policy: str = "slru"         # "slru" | "pinned" | "none"
    pinned_keys: frozenset | None = None
    hit_latency_s: float = 100e-6      # local (memory/SSD) cache service
    compute: ComputeSpec = dataclasses.field(default_factory=ComputeSpec)
    seed: int = 0


@dataclasses.dataclass
class _QueryState:
    qid: int
    gen: object
    metrics: QueryMetrics
    start_t: float
    batches: list[BatchTrace]
    round_idx: int = 0
    last_snapshot: tuple = (0, 0)
    pending_batch: object = None        # FetchBatch in flight
    pending_submit_t: float = 0.0
    pending_hits: int = 0
    pending_total_bytes: int = 0


class QueryEngine:
    def __init__(self, index, config: EngineConfig):
        self.index = index
        self.cfg = config
        self.cache = self._make_cache()
        # compute-pricing constants from the index
        self.dim = index.meta.dim
        pq = getattr(index.meta, "pq", None)
        self.pq_m = pq.m if pq is not None else 0

    def _make_cache(self):
        cfg = self.cfg
        if cfg.cache_policy == "pinned" and cfg.pinned_keys:
            return PinnedCache(set(cfg.pinned_keys))
        if cfg.cache_policy == "slru" and cfg.cache_bytes > 0:
            return SLRUCache(cfg.cache_bytes)
        return None

    # ------------------------------------------------------------------ --
    def _compute_seconds(self, st: _QueryState) -> float:
        """Price the compute the plan did since the last yield."""
        m = st.metrics
        d0, p0 = st.last_snapshot
        dd = m.dist_comps - d0
        dp = m.pq_dist_comps - p0
        st.last_snapshot = (m.dist_comps, m.pq_dist_comps)
        c = self.cfg.compute
        return (dd * 2.0 * self.dim / c.dist_flops_per_s
                + dp * max(self.pq_m, 1) * c.adc_lookup_s)

    def run(self, queries: np.ndarray, params: SearchParams,
            query_ids: Iterable[int] | None = None) -> WorkloadReport:
        cfg = self.cfg
        sim = StorageSim(cfg.storage, seed=cfg.seed)
        store = self.index.store
        qids = list(query_ids) if query_ids is not None else list(
            range(len(queries)))

        # engine event heap: (time, seq, kind, payload)
        events: list = []
        seq = 0

        def push(t, kind, payload):
            nonlocal seq
            heapq.heappush(events, (t, seq, kind, payload))
            seq += 1

        queue = list(range(len(queries)))
        queue.reverse()                      # pop() serves in order
        records: list[QueryRecord] = []
        waiting: dict[int, _QueryState] = {}  # batch_id -> state
        clock = 0.0

        def start_next_query(t: float):
            if not queue:
                return
            qi = queue.pop()
            metrics = QueryMetrics()
            gen = self.index.search_plan(queries[qi], params, metrics)
            st = _QueryState(qid=qids[qi], gen=gen, metrics=metrics,
                             start_t=t, batches=[])
            _advance(st, t, first=True)

        def _submit(st: _QueryState, batch, t: float):
            """Cache-split the batch and route misses to storage."""
            hits = 0
            miss_bytes = 0
            miss_n = 0
            for rq in batch.requests:
                st.metrics.cache_lookups += 1
                if self.cache is not None and self.cache.get(rq.key):
                    hits += 1
                    st.metrics.cache_hits += 1
                else:
                    miss_bytes += rq.nbytes
                    miss_n += 1
            st.metrics.bytes_storage += miss_bytes
            st.pending_batch = batch
            st.pending_submit_t = t
            st.pending_hits = hits
            st.pending_total_bytes = batch.nbytes
            if miss_n == 0:
                push(t + cfg.hit_latency_s, "fetched", (st, t + cfg.hit_latency_s, 0, 0))
            else:
                ticket = sim.submit_batch(t, miss_bytes, miss_n)
                waiting[ticket.batch_id] = st

        def _advance(st: _QueryState, t: float, first: bool = False,
                     payloads: dict | None = None):
            """Resume the generator; charge compute; submit next batch."""
            try:
                if first:
                    batch = next(st.gen)
                else:
                    batch = st.gen.send(payloads)
            except StopIteration as stop:
                res = stop.value
                dt = self._compute_seconds(st)
                records.append(QueryRecord(
                    qid=st.qid, start_t=st.start_t, end_t=t + dt,
                    ids=res.ids, dists=res.dists, metrics=st.metrics,
                    batches=st.batches))
                start_next_query(t + dt)
                return
            dt = self._compute_seconds(st)
            push(t + dt, "submit", (st, batch))

        def _on_fetched(st: _QueryState, t: float, n_storage_req: int,
                        storage_bytes: int):
            batch = st.pending_batch
            st.batches.append(BatchTrace(
                round_idx=st.round_idx, submit_t=st.pending_submit_t,
                done_t=t, n_requests=n_storage_req,
                n_hits=st.pending_hits, nbytes_storage=storage_bytes,
                nbytes_total=st.pending_total_bytes))
            st.round_idx += 1
            if self.cache is not None:
                for rq in batch.requests:
                    self.cache.put(rq.key, rq.nbytes)
            payloads = {rq.key: store.get(rq.key) for rq in batch.requests}
            st.pending_batch = None
            _advance(st, t, payloads=payloads)

        # ---- bootstrap: fill the concurrency window --------------------
        for _ in range(min(cfg.concurrency, len(queue))):
            start_next_query(0.0)

        # ---- main interleaved event loop -------------------------------
        while events or sim.busy:
            t_engine = events[0][0] if events else float("inf")
            t_storage = sim.next_event_time()
            t_storage = t_storage if t_storage is not None else float("inf")
            if t_storage < t_engine:
                for ticket in sim.advance_to(t_storage):
                    st = waiting.pop(ticket.batch_id)
                    clock = max(clock, ticket.done_t)
                    _on_fetched(st, ticket.done_t, ticket.n_requests,
                                ticket.nbytes)
            elif events:
                t, _, kind, payload = heapq.heappop(events)
                sim.advance_to(t)
                clock = max(clock, t)
                if kind == "submit":
                    st, batch = payload
                    _submit(st, batch, t)
                elif kind == "fetched":
                    st, tt, nreq, nbytes = payload
                    _on_fetched(st, tt, nreq, nbytes)
            else:
                break

        wall = max((r.end_t for r in records), default=0.0)
        return WorkloadReport(
            records=records, wall_time_s=wall,
            storage_bytes=sim.total_bytes,
            storage_requests=sim.total_requests,
            concurrency=cfg.concurrency)


def run_workload(index, queries: np.ndarray, params: SearchParams,
                 storage: StorageSpec | EngineConfig, concurrency: int = 1,
                 cache_bytes: int = 0, seed: int = 0,
                 compute: ComputeSpec = DEFAULT_COMPUTE,
                 cache_policy: str = "slru",
                 pinned_keys: frozenset | None = None,
                 query_ids: Iterable[int] | None = None) -> WorkloadReport:
    """The one-call evaluation hook: run ``queries`` through the engine.

    Accepts either a bare :class:`StorageSpec` plus knobs (the benchmark
    harness style) or a fully-formed :class:`EngineConfig` as the fourth
    argument (the ``repro.tuning`` style — every cache/seed/compute knob in
    one value).  ``query_ids`` maps repeated/reordered workload queries
    back to ground-truth rows (see ``serving.workload``).
    """
    if isinstance(storage, EngineConfig):
        cfg = storage
    else:
        cfg = EngineConfig(
            storage=storage, concurrency=concurrency,
            cache_bytes=cache_bytes, cache_policy=cache_policy,
            pinned_keys=pinned_keys, compute=compute, seed=seed)
    eng = QueryEngine(index, cfg)
    return eng.run(queries, params, query_ids=query_ids)
