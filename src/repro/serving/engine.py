"""The cloud-native query engine: index × storage simulator × cache.

Serving (paper §5.1): each query runs its index ``search_plan``
generator, whose fetch batches flow through the cache and the
discrete-event storage simulator.  Compute phases are priced from the
metrics deltas the plan records (distance comps × ComputeSpec) —
reproducing the CPU/I/O split of Fig 2/3.

Two layers, both components of a :class:`repro.sim.Kernel`:

* :class:`SteppableEngine` — the plan executor.  ``submit()`` starts a
  plan generator; every subsequent step (compute completion, cache-hit
  service, storage completion) is a kernel event, so N engines sharing a
  kernel (``repro.fleet``) interleave exactly by virtual time.
* :class:`QueryEngine` — the driver process: an admission window of
  ``concurrency`` jobs over a FIFO backlog, fed by an arrival process
  (:mod:`repro.sim.arrivals`).  The default :class:`ClosedLoop` arrivals
  reproduce the paper's fixed-concurrency harness; open-loop processes
  (Poisson, trace) turn the same engine into an M/G/c-style service.

Everything is virtual-time deterministic for a given seed.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable

import numpy as np

from repro.cache.slru import CACHE_POLICIES, make_cache
from repro.core.cost_model import (DEFAULT_COMPUTE, ComputeSpec,
                                   plan_compute_seconds)
from repro.core.types import QueryMetrics, SearchParams
from repro.obs.trace import NULL_TRACER, Tracer, emit_job_spans
from repro.serving.metrics import BatchTrace, QueryRecord, WorkloadReport
from repro.sim.admission import AdmissionWindow
from repro.sim.arrivals import ArrivalProcess, ClosedLoop
from repro.sim.kernel import Event, Kernel
from repro.storage.simulator import StorageSim
from repro.storage.spec import StorageSpec
from repro.storage.tier import NVMeTier, TierConfig, TieredWritePath


@dataclasses.dataclass
class EngineConfig:
    storage: StorageSpec
    concurrency: int = 1
    cache_bytes: int = 0
    cache_policy: str = "slru"         # "slru" | "pinned" | "none"
    pinned_keys: frozenset | None = None
    hit_latency_s: float = 100e-6      # local (memory/SSD) cache service
    compute: ComputeSpec = dataclasses.field(default_factory=ComputeSpec)
    seed: int = 0
    #: local NVMe middle tier (repro.storage.tier); None (or capacity 0)
    #: keeps the flat DRAM -> remote hierarchy event-for-event identical
    tier: TierConfig | None = None

    def __post_init__(self):
        if self.cache_policy not in CACHE_POLICIES:
            raise ValueError(
                f"unknown cache_policy {self.cache_policy!r}; "
                f"one of {CACHE_POLICIES}")
        if self.cache_policy == "pinned" and self.pinned_keys is None:
            raise ValueError(
                "cache_policy='pinned' requires pinned_keys (the fixed "
                "key set to pin; see repro.tuning.evaluate.hot_keys)")
        if self.cache_policy != "pinned" and self.pinned_keys:
            raise ValueError(
                f"pinned_keys given but cache_policy is "
                f"{self.cache_policy!r} (use cache_policy='pinned')")
        if self.cache_bytes < 0:
            raise ValueError(f"cache_bytes must be >= 0, got "
                             f"{self.cache_bytes}")
        if self.concurrency < 1:
            raise ValueError(f"concurrency must be >= 1, got "
                             f"{self.concurrency}")

    def make_cache(self):
        """The single cache construction path for every engine in the
        system (serving and fleet): policy/pinned validation happened at
        config construction, so a cache can only be built from a config
        that passed it."""
        return make_cache(self.cache_policy, self.cache_bytes,
                          self.pinned_keys)


@dataclasses.dataclass
class _JobState:
    tag: Any
    gen: object
    metrics: QueryMetrics
    start_t: float
    batches: list[BatchTrace]
    dim: int = 0                        # compute-pricing dims for this job
    pq_m: int = 0
    round_idx: int = 0
    last_snapshot: tuple = (0, 0)
    pending_batch: object = None        # FetchBatch in flight
    pending_submit_t: float = 0.0
    pending_hits: int = 0
    pending_total_bytes: int = 0
    pending_nvme_n: int = 0             # tier-resident misses this round
    pending_nvme_bytes: int = 0
    pending_parts: int = 0              # device sub-batches still in flight
    pending_remote_done: tuple = (0, 0)
    pending_ev: Event | None = None     # next engine event for this job
    alive: bool = True                  # False once aborted (shard death)
    #: [enq_t, flush_t] intervals spent waiting in a KernelBackend batch
    #: window (empty on the analytic backend)
    coalesce: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class JobRecord:
    """One completed plan execution on a :class:`SteppableEngine`.

    ``result`` is whatever the plan generator returned — a
    :class:`SearchResult` for full searches, a payload dict for fleet
    fetch sub-jobs.
    """

    tag: Any
    start_t: float
    end_t: float
    result: Any
    metrics: QueryMetrics
    batches: list[BatchTrace]
    #: batch-coalescing waits ([enq_t, flush_t] pairs) when the job ran
    #: on a kernel backend; tiled as "batching" legs in the span tree
    coalesce: list = dataclasses.field(default_factory=list)

    @property
    def latency(self) -> float:
        return self.end_t - self.start_t


class SteppableEngine:
    """Plan executor registered on a (possibly shared) event kernel.

    ``submit()`` starts a plan generator (optionally at a virtual time
    ``at`` >= now — completion chains schedule follow-on work at the
    completing job's ``end_t``); every fetch round's cache split, storage
    I/O and compute pricing then advances through kernel events.
    ``on_complete(JobRecord)`` fires synchronously at each job's
    completion so a driver can start the next query, or a shard server
    can pop its admission queue, at exactly that virtual instant.
    """

    def __init__(self, cfg: EngineConfig, store, cache=None, *,
                 kernel: Kernel | None = None, dim: int, pq_m: int = 0,
                 on_complete: Callable[[JobRecord], None] | None = None,
                 backend=None):
        self.cfg = cfg
        self.store = store
        self.cache = cache
        self.dim = dim
        self.pq_m = pq_m
        self.on_complete = on_complete
        self.kernel = kernel if kernel is not None else Kernel(seed=cfg.seed)
        self.sim = StorageSim(cfg.storage, self.kernel, seed=cfg.seed)
        # NVMe tier: constructed ONLY when capacity > 0 — a zero-capacity
        # tier must not even allocate a second StorageSim, or the kernel's
        # unique_name/RNG-stream sequence (and every flat golden) shifts.
        self.tier = (NVMeTier(cfg.tier, self.kernel, seed=cfg.seed)
                     if cfg.tier is not None and cfg.tier.capacity_bytes > 0
                     else None)
        #: ingest data plane: compaction PUTs go through here so a
        #: write-back tier can land them locally first (flat engines hand
        #: out the remote sim itself — identical object, identical path)
        self.write_path = (TieredWritePath(self.tier, self.sim)
                           if self.tier is not None and self.tier.writeback
                           else self.sim)
        # Optional repro.exec.KernelBackend: compute is then batch-
        # coalesced and priced from a measured CalibrationTable instead
        # of the analytic ComputeSpec.  None keeps the analytic path
        # event-for-event identical to before the backend existed.
        self.backend = backend.attach(self) if backend is not None else None
        self._jobs: list[_JobState] = []
        self.in_flight = 0
        self.jobs_done = 0

    # ------------------------------------------------------------- jobs --
    def submit(self, plan, metrics: QueryMetrics, tag: Any = None,
               at: float | None = None, dim: int | None = None,
               pq_m: int | None = None) -> _JobState:
        """Start a plan generator (at virtual time ``at``, default now).

        ``dim``/``pq_m`` override the engine-level compute-pricing
        constants for this job (multi-tenant fleets run jobs of several
        index geometries through one shard engine)."""
        t = self.kernel.now if at is None else max(at, self.kernel.now)
        st = _JobState(tag=tag, gen=plan, metrics=metrics, start_t=t,
                       batches=[],
                       dim=self.dim if dim is None else dim,
                       pq_m=self.pq_m if pq_m is None else pq_m)
        self._jobs.append(st)
        self.in_flight += 1
        self._advance_job(st, t, first=True)
        return st

    def abort_all(self) -> list[Any]:
        """Kill every in-flight job (the node died): cancel their pending
        events, drop their storage transfers, return the aborted tags."""
        tags = []
        for st in self._jobs:
            st.alive = False
            if st.pending_ev is not None:
                self.kernel.cancel(st.pending_ev)
                st.pending_ev = None
            tags.append(st.tag)
        self._jobs.clear()
        self.sim.abort_all()
        if self.tier is not None:
            self.tier.sim.abort_all()
        self.in_flight = 0
        return tags

    # ---------------------------------------------------------- internal --
    def _work_delta(self, st: _JobState) -> tuple[int, int]:
        """Distance comps / PQ lookups the plan did since the last yield."""
        m = st.metrics
        d0, p0 = st.last_snapshot
        st.last_snapshot = (m.dist_comps, m.pq_dist_comps)
        return m.dist_comps - d0, m.pq_dist_comps - p0

    def _compute_seconds(self, st: _JobState) -> float:
        """Price the compute the plan did since the last yield."""
        d_dist, d_pq = self._work_delta(st)
        return plan_compute_seconds(d_dist, d_pq,
                                    st.dim, st.pq_m, self.cfg.compute)

    def _advance_job(self, st: _JobState, t: float, first: bool = False,
                     payloads: dict | None = None) -> None:
        """Resume the generator; charge compute; schedule the next batch.

        On the analytic backend compute is priced inline and the next
        step scheduled at ``t + dt``.  On a kernel backend the work
        delta is handed to the batch coalescer, which calls back (at
        flush + calibrated batch time) with the completion instant."""
        try:
            if first:
                batch = next(st.gen)
            else:
                batch = st.gen.send(payloads)
        except StopIteration as stop:
            if self.backend is not None:
                d_dist, d_pq = self._work_delta(st)
                self.backend.submit(
                    st, t, d_dist, d_pq,
                    lambda td, st=st, v=stop.value:
                        self._finish_job(st, td, v))
                return
            self._finish_job(st, t + self._compute_seconds(st), stop.value)
            return
        if self.backend is not None:
            d_dist, d_pq = self._work_delta(st)
            self.backend.submit(
                st, t, d_dist, d_pq,
                lambda td, st=st, b=batch: self._dispatch_batch(st, b, td))
            return
        dt = self._compute_seconds(st)
        st.pending_ev = self.kernel.at(t + dt, self._submit_batch, st, batch)

    def _finish_job(self, st: _JobState, end_t: float, value: Any) -> None:
        """Retire a completed plan and fire ``on_complete`` synchronously."""
        self.in_flight -= 1
        self.jobs_done += 1
        self._jobs.remove(st)
        record = JobRecord(tag=st.tag, start_t=st.start_t,
                           end_t=end_t, result=value,
                           metrics=st.metrics, batches=st.batches,
                           coalesce=st.coalesce)
        if self.on_complete is not None:
            self.on_complete(record)

    def _dispatch_batch(self, st: _JobState, batch, t: float) -> None:
        """Kernel-backend continuation: fetch round starts at batch end."""
        if not st.alive:
            return
        st.pending_ev = self.kernel.at(t, self._submit_batch, st, batch)

    def _submit_batch(self, st: _JobState, batch) -> None:
        """Cache-split the batch, then tier-split the misses.

        Up to two device sub-batches go out concurrently — NVMe-resident
        misses to the tier device, the rest to the remote store — and the
        round completes when the slower one does (a join).  Without a
        tier the remote sub-batch is the whole miss set and the path is
        event-for-event what it was in the flat hierarchy."""
        st.pending_ev = None
        t = self.kernel.now
        hits = 0
        miss = []
        for rq in batch.requests:
            st.metrics.cache_lookups += 1
            if self.cache is not None and self.cache.get(rq.key):
                hits += 1
                st.metrics.cache_hits += 1
            else:
                miss.append(rq)
        if self.tier is not None and miss:
            nvme_reqs, remote_reqs = self.tier.split(miss)
        else:
            nvme_reqs, remote_reqs = [], miss
        miss_bytes = sum(rq.nbytes for rq in remote_reqs)
        miss_n = len(remote_reqs)
        nvme_bytes = sum(rq.nbytes for rq in nvme_reqs)
        # bytes_storage stays remote-only: it feeds egress attribution,
        # and tier-served bytes never cross the NIC
        st.metrics.bytes_storage += miss_bytes
        tr = self.kernel.tracer
        if tr.enabled:
            tr.metrics.counter("cache.hits").inc(hits)
            tr.metrics.counter("cache.misses").inc(len(miss))
            tr.metrics.counter("storage.bytes").inc(miss_bytes)
            if self.tier is not None:
                tr.metrics.counter("nvme.hits").inc(len(nvme_reqs))
                tr.metrics.counter("nvme.bytes").inc(nvme_bytes)
        st.pending_batch = batch
        st.pending_submit_t = t
        st.pending_hits = hits
        st.pending_total_bytes = batch.nbytes
        st.pending_nvme_n = len(nvme_reqs)
        st.pending_nvme_bytes = nvme_bytes
        st.pending_remote_done = (0, 0)
        if miss_n == 0 and not nvme_reqs:
            st.pending_ev = self.kernel.at(t + self.cfg.hit_latency_s,
                                           self._on_fetched, st, 0, 0)
            return
        st.pending_parts = (1 if nvme_reqs else 0) + (1 if miss_n else 0)
        if nvme_reqs:
            self.tier.sim.submit_batch(
                nvme_bytes, len(nvme_reqs),
                on_done=lambda tk, st=st: self._part_done(st, None))
        if miss_n:
            self.sim.submit_batch(
                miss_bytes, miss_n,
                on_done=lambda tk, st=st, reqs=remote_reqs:
                    self._part_done(st, reqs, tk))

    def _part_done(self, st: _JobState, remote_reqs, ticket=None) -> None:
        """One device sub-batch finished; the round resumes at the join."""
        if not st.alive:
            return
        if ticket is not None:
            st.pending_remote_done = (ticket.n_requests, ticket.nbytes)
            if self.tier is not None and remote_reqs:
                # promotion happens the instant the remote bytes land
                for rq in remote_reqs:
                    self.tier.note_remote_fetch(rq.key, rq.nbytes)
        st.pending_parts -= 1
        if st.pending_parts == 0:
            n, b = st.pending_remote_done
            self._on_fetched(st, n, b)

    def _on_fetched(self, st: _JobState, n_storage_req: int,
                    storage_bytes: int) -> None:
        st.pending_ev = None
        t = self.kernel.now
        batch = st.pending_batch
        st.batches.append(BatchTrace(
            round_idx=st.round_idx, submit_t=st.pending_submit_t,
            done_t=t, n_requests=n_storage_req,
            n_hits=st.pending_hits, nbytes_storage=storage_bytes,
            nbytes_total=st.pending_total_bytes,
            n_nvme=st.pending_nvme_n,
            nbytes_nvme=st.pending_nvme_bytes))
        st.round_idx += 1
        if self.cache is not None:
            for rq in batch.requests:
                self.cache.put(rq.key, rq.nbytes)
        payloads = {rq.key: self.store.get(rq.key) for rq in batch.requests}
        st.pending_batch = None
        self._advance_job(st, t, payloads=payloads)


class QueryEngine:
    """Driver process: an admission window over an arrival stream.

    With the default :class:`ClosedLoop` arrivals this is the paper's
    closed loop (all queries backlogged at t=0, ``concurrency`` in
    service); with open-loop arrivals queries wait in the backlog when
    the window is full, and per-query ``arrive_t``/sojourn make
    queue-delay visible in the report.
    """

    def __init__(self, index, config: EngineConfig):
        self.index = index
        self.cfg = config
        self.cache = config.make_cache()
        # compute-pricing constants from the index
        self.dim = index.meta.dim
        pq = getattr(index.meta, "pq", None)
        self.pq_m = pq.m if pq is not None else 0

    def run(self, queries: np.ndarray, params: SearchParams,
            query_ids: Iterable[int] | None = None,
            arrivals: ArrivalProcess | None = None,
            updates=None, ingest=None,
            tracer: Tracer | None = None) -> WorkloadReport:
        """``updates`` (an :class:`repro.ingest.stream.UpdateStream`)
        interleaves live inserts/deletes with the query stream; the
        index is wrapped mutable on first use and an
        :class:`repro.ingest.compaction.IngestAgent` applies the stream
        and runs background compaction whose I/O contends with query
        I/O on this engine's storage simulator.  ``ingest`` is its
        :class:`repro.ingest.compaction.IngestConfig`.  With no updates
        the run is byte-identical to the pure-query path."""
        cfg = self.cfg
        qids = list(query_ids) if query_ids is not None else list(
            range(len(queries)))
        arr = arrivals if arrivals is not None else ClosedLoop(
            cfg.concurrency, n_total=len(queries))
        window = arr.window if arr.window is not None else cfg.concurrency

        kernel = Kernel(seed=cfg.seed)
        tr = tracer if tracer is not None else NULL_TRACER
        tr.attach(kernel)
        records: list[QueryRecord] = []
        core = SteppableEngine(cfg, self.index.store, self.cache,
                               kernel=kernel, dim=self.dim, pq_m=self.pq_m)

        def start_query(item: tuple[int, int], t: float) -> None:
            ai, wi = item
            metrics = QueryMetrics()
            gen = self.index.search_plan(queries[wi], params, metrics)
            core.submit(gen, metrics, tag=(ai, qids[wi]), at=t)

        adm = AdmissionWindow(kernel, window, start_query)

        def on_complete(job: JobRecord) -> None:
            ai, qid = job.tag
            res = job.result
            arrive_t = adm.pop_arrive_t(ai)
            if tr.enabled:
                # the single-engine span tree: query root with the job's
                # fetch/compute legs directly under it (no rounds)
                sp = tr.record("query", arrive_t, job.end_t, parent=None,
                               qid=qid, tid=0, kind="engine")
                if job.start_t > arrive_t:
                    tr.record("admission", arrive_t, job.start_t,
                              parent=sp)
                emit_job_spans(tr, sp, job.start_t, job)
                tr.metrics.counter("engine.queries").inc()
                tr.metrics.histogram("engine.sojourn_s").observe(
                    job.end_t - arrive_t)
            records.append(QueryRecord(
                qid=qid, start_t=job.start_t, end_t=job.end_t,
                ids=res.ids, dists=res.dists, metrics=job.metrics,
                batches=job.batches, arrive_t=arrive_t))
            adm.release(job.end_t)

        core.on_complete = on_complete
        agent = None
        if updates is not None and len(updates):
            from repro.ingest.compaction import IngestAgent, IngestConfig
            from repro.ingest.metrics import IngestReport
            from repro.ingest.mutable import make_mutable
            self.index = make_mutable(self.index)
            inval = None
            if self.cache is not None or core.tier is not None:
                def inval(key, _c=self.cache, _t=core.tier):
                    if _c is not None:
                        _c.remove(key)
                    if _t is not None:
                        _t.invalidate(key)
            agent = IngestAgent(
                self.index, site_id=0, kernel=kernel,
                cfg=ingest if ingest is not None else IngestConfig(),
                compute=cfg.compute, sim_provider=lambda: core.write_path,
                report=IngestReport(),
                invalidate=inval,
                inflight_floor=lambda: min(
                    (st.start_t for st in core._jobs),
                    default=float("inf")))
            updates.start(kernel, agent.deliver)
        arr.start(kernel, lambda ai, wi: adm.offer((ai, wi), key=ai),
                  len(queries))
        kernel.run()

        wall = max((r.end_t for r in records), default=0.0)
        ingest_dict = None
        if agent is not None:
            agent.finalize()
            ingest_dict = agent.report.to_dict(records)
        return WorkloadReport(
            records=records, wall_time_s=wall,
            storage_bytes=core.sim.total_bytes,
            storage_requests=core.sim.total_requests,
            concurrency=cfg.concurrency, scenario=arr.kind,
            n_arrivals=adm.arrivals_total,
            offered_qps=adm.offered_qps(wall),
            ingest=ingest_dict)


def run_workload(index, queries: np.ndarray, params: SearchParams,
                 storage: StorageSpec | EngineConfig, concurrency: int = 1,
                 cache_bytes: int = 0, seed: int = 0,
                 compute: ComputeSpec = DEFAULT_COMPUTE,
                 cache_policy: str = "slru",
                 pinned_keys: frozenset | None = None,
                 query_ids: Iterable[int] | None = None,
                 arrivals: ArrivalProcess | None = None,
                 updates=None, ingest=None,
                 tracer: Tracer | None = None) -> WorkloadReport:
    """The one-call evaluation hook: run ``queries`` through the engine.

    Accepts either a bare :class:`StorageSpec` plus knobs (the benchmark
    harness style) or a fully-formed :class:`EngineConfig` as the fourth
    argument (the ``repro.tuning`` style — every cache/seed/compute knob in
    one value).  ``query_ids`` maps repeated/reordered workload queries
    back to ground-truth rows (see ``serving.workload``); ``arrivals``
    selects the arrival process (default: the paper's closed loop).
    """
    if isinstance(storage, EngineConfig):
        cfg = storage
    else:
        cfg = EngineConfig(
            storage=storage, concurrency=concurrency,
            cache_bytes=cache_bytes, cache_policy=cache_policy,
            pinned_keys=pinned_keys, compute=compute, seed=seed)
    eng = QueryEngine(index, cfg)
    return eng.run(queries, params, query_ids=query_ids, arrivals=arrivals,
                   updates=updates, ingest=ingest, tracer=tracer)
