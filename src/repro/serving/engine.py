"""The cloud-native query engine: index × storage simulator × cache.

Closed-loop serving (paper §5.1): ``concurrency`` workers drain the query
queue; each query runs its index ``search_plan`` generator, whose fetch
batches flow through the cache and the discrete-event storage simulator.
Compute phases are priced from the metrics deltas the plan records
(distance comps × ComputeSpec) — reproducing the CPU/I/O split of Fig 2/3.

Two layers:

* :class:`SteppableEngine` — the open-loop core.  It executes plan
  generators against (cache × storage sim) but never advances time on its
  own: a driver owns the virtual clock through ``next_event_time()`` /
  ``advance_to()``.  This is what lets ``repro.fleet`` advance N shard
  engines on one shared clock.
* :class:`QueryEngine` — the paper's closed-loop driver: a fixed
  concurrency window over a query queue, drained to completion.

Everything is virtual-time deterministic for a given seed.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Iterable

import numpy as np

from repro.cache.slru import CACHE_POLICIES, make_cache
from repro.core.cost_model import (DEFAULT_COMPUTE, ComputeSpec,
                                   plan_compute_seconds)
from repro.core.types import QueryMetrics, SearchParams
from repro.serving.metrics import BatchTrace, QueryRecord, WorkloadReport
from repro.storage.simulator import StorageSim
from repro.storage.spec import StorageSpec


@dataclasses.dataclass
class EngineConfig:
    storage: StorageSpec
    concurrency: int = 1
    cache_bytes: int = 0
    cache_policy: str = "slru"         # "slru" | "pinned" | "none"
    pinned_keys: frozenset | None = None
    hit_latency_s: float = 100e-6      # local (memory/SSD) cache service
    compute: ComputeSpec = dataclasses.field(default_factory=ComputeSpec)
    seed: int = 0

    def __post_init__(self):
        if self.cache_policy not in CACHE_POLICIES:
            raise ValueError(
                f"unknown cache_policy {self.cache_policy!r}; "
                f"one of {CACHE_POLICIES}")
        if self.cache_policy == "pinned" and self.pinned_keys is None:
            raise ValueError(
                "cache_policy='pinned' requires pinned_keys (the fixed "
                "key set to pin; see repro.tuning.evaluate.hot_keys)")
        if self.cache_policy != "pinned" and self.pinned_keys:
            raise ValueError(
                f"pinned_keys given but cache_policy is "
                f"{self.cache_policy!r} (use cache_policy='pinned')")
        if self.cache_bytes < 0:
            raise ValueError(f"cache_bytes must be >= 0, got "
                             f"{self.cache_bytes}")
        if self.concurrency < 1:
            raise ValueError(f"concurrency must be >= 1, got "
                             f"{self.concurrency}")


@dataclasses.dataclass
class _JobState:
    tag: Any
    gen: object
    metrics: QueryMetrics
    start_t: float
    batches: list[BatchTrace]
    round_idx: int = 0
    last_snapshot: tuple = (0, 0)
    pending_batch: object = None        # FetchBatch in flight
    pending_submit_t: float = 0.0
    pending_hits: int = 0
    pending_total_bytes: int = 0


@dataclasses.dataclass
class JobRecord:
    """One completed plan execution on a :class:`SteppableEngine`.

    ``result`` is whatever the plan generator returned — a
    :class:`SearchResult` for full searches, a payload dict for fleet
    fetch sub-jobs.
    """

    tag: Any
    start_t: float
    end_t: float
    result: Any
    metrics: QueryMetrics
    batches: list[BatchTrace]

    @property
    def latency(self) -> float:
        return self.end_t - self.start_t


class SteppableEngine:
    """Open-loop plan executor on an externally-driven virtual clock.

    ``submit()`` starts a plan generator at virtual time ``t``;
    ``advance_to(t)`` processes every engine/storage event up to ``t``,
    invoking ``on_complete(JobRecord)`` synchronously at each job's
    completion time (so a closed-loop driver can start the next query, or
    a shard server can pop its admission queue, at exactly that instant).
    """

    def __init__(self, cfg: EngineConfig, store, cache=None, *,
                 dim: int, pq_m: int = 0,
                 on_complete: Callable[[JobRecord], None] | None = None):
        self.cfg = cfg
        self.store = store
        self.cache = cache
        self.dim = dim
        self.pq_m = pq_m
        self.on_complete = on_complete
        self.sim = StorageSim(cfg.storage, seed=cfg.seed)
        self._events: list = []        # (time, seq, kind, payload)
        self._seq = 0
        self._waiting: dict[int, _JobState] = {}   # batch_id -> job
        self.in_flight = 0
        self.jobs_done = 0

    # ------------------------------------------------------------ clock --
    def next_event_time(self) -> float | None:
        cands = []
        if self._events:
            cands.append(self._events[0][0])
        ts = self.sim.next_event_time()
        if ts is not None:
            cands.append(ts)
        return min(cands) if cands else None

    @property
    def busy(self) -> bool:
        return bool(self._events or self.sim.busy)

    def advance_to(self, t: float) -> None:
        """Process every event with timestamp <= ``t`` in causal order."""
        while True:
            t_engine = self._events[0][0] if self._events else float("inf")
            t_storage = self.sim.next_event_time()
            t_storage = t_storage if t_storage is not None else float("inf")
            nxt = min(t_engine, t_storage)
            if nxt == float("inf") or nxt > t + 1e-15:
                break
            if t_storage < t_engine:
                for ticket in self.sim.advance_to(t_storage):
                    st = self._waiting.pop(ticket.batch_id)
                    self._on_fetched(st, ticket.done_t, ticket.n_requests,
                                     ticket.nbytes)
            else:
                tt, _, kind, payload = heapq.heappop(self._events)
                self.sim.advance_to(tt)
                if kind == "submit":
                    st, batch = payload
                    self._submit_batch(st, batch, tt)
                else:                                   # "fetched" (all-hit)
                    st, t_hit, nreq, nbytes = payload
                    self._on_fetched(st, t_hit, nreq, nbytes)

    # ------------------------------------------------------------- jobs --
    def submit(self, t: float, plan, metrics: QueryMetrics,
               tag: Any = None) -> _JobState:
        """Start a plan generator at virtual time ``t``."""
        st = _JobState(tag=tag, gen=plan, metrics=metrics, start_t=t,
                       batches=[])
        self.in_flight += 1
        self._advance_job(st, t, first=True)
        return st

    def _push(self, t: float, kind: str, payload) -> None:
        heapq.heappush(self._events, (t, self._seq, kind, payload))
        self._seq += 1

    def _compute_seconds(self, st: _JobState) -> float:
        """Price the compute the plan did since the last yield."""
        m = st.metrics
        d0, p0 = st.last_snapshot
        st.last_snapshot = (m.dist_comps, m.pq_dist_comps)
        return plan_compute_seconds(m.dist_comps - d0, m.pq_dist_comps - p0,
                                    self.dim, self.pq_m, self.cfg.compute)

    def _advance_job(self, st: _JobState, t: float, first: bool = False,
                     payloads: dict | None = None) -> None:
        """Resume the generator; charge compute; submit the next batch."""
        try:
            if first:
                batch = next(st.gen)
            else:
                batch = st.gen.send(payloads)
        except StopIteration as stop:
            dt = self._compute_seconds(st)
            self.in_flight -= 1
            self.jobs_done += 1
            record = JobRecord(tag=st.tag, start_t=st.start_t,
                               end_t=t + dt, result=stop.value,
                               metrics=st.metrics, batches=st.batches)
            if self.on_complete is not None:
                self.on_complete(record)
            return
        dt = self._compute_seconds(st)
        self._push(t + dt, "submit", (st, batch))

    def _submit_batch(self, st: _JobState, batch, t: float) -> None:
        """Cache-split the batch and route misses to storage."""
        hits = 0
        miss_bytes = 0
        miss_n = 0
        for rq in batch.requests:
            st.metrics.cache_lookups += 1
            if self.cache is not None and self.cache.get(rq.key):
                hits += 1
                st.metrics.cache_hits += 1
            else:
                miss_bytes += rq.nbytes
                miss_n += 1
        st.metrics.bytes_storage += miss_bytes
        st.pending_batch = batch
        st.pending_submit_t = t
        st.pending_hits = hits
        st.pending_total_bytes = batch.nbytes
        if miss_n == 0:
            t_hit = t + self.cfg.hit_latency_s
            self._push(t_hit, "fetched", (st, t_hit, 0, 0))
        else:
            ticket = self.sim.submit_batch(t, miss_bytes, miss_n)
            self._waiting[ticket.batch_id] = st

    def _on_fetched(self, st: _JobState, t: float, n_storage_req: int,
                    storage_bytes: int) -> None:
        batch = st.pending_batch
        st.batches.append(BatchTrace(
            round_idx=st.round_idx, submit_t=st.pending_submit_t,
            done_t=t, n_requests=n_storage_req,
            n_hits=st.pending_hits, nbytes_storage=storage_bytes,
            nbytes_total=st.pending_total_bytes))
        st.round_idx += 1
        if self.cache is not None:
            for rq in batch.requests:
                self.cache.put(rq.key, rq.nbytes)
        payloads = {rq.key: self.store.get(rq.key) for rq in batch.requests}
        st.pending_batch = None
        self._advance_job(st, t, payloads=payloads)


class QueryEngine:
    """Closed-loop driver: a fixed concurrency window over a query queue."""

    def __init__(self, index, config: EngineConfig):
        self.index = index
        self.cfg = config
        self.cache = make_cache(config.cache_policy, config.cache_bytes,
                                config.pinned_keys)
        # compute-pricing constants from the index
        self.dim = index.meta.dim
        pq = getattr(index.meta, "pq", None)
        self.pq_m = pq.m if pq is not None else 0

    def run(self, queries: np.ndarray, params: SearchParams,
            query_ids: Iterable[int] | None = None) -> WorkloadReport:
        cfg = self.cfg
        qids = list(query_ids) if query_ids is not None else list(
            range(len(queries)))
        queue = list(range(len(queries)))
        queue.reverse()                      # pop() serves in order
        records: list[QueryRecord] = []
        core = SteppableEngine(cfg, self.index.store, self.cache,
                               dim=self.dim, pq_m=self.pq_m)

        def start_next_query(t: float) -> None:
            if not queue:
                return
            qi = queue.pop()
            metrics = QueryMetrics()
            gen = self.index.search_plan(queries[qi], params, metrics)
            core.submit(t, gen, metrics, tag=qids[qi])

        def on_complete(job: JobRecord) -> None:
            res = job.result
            records.append(QueryRecord(
                qid=job.tag, start_t=job.start_t, end_t=job.end_t,
                ids=res.ids, dists=res.dists, metrics=job.metrics,
                batches=job.batches))
            start_next_query(job.end_t)

        core.on_complete = on_complete

        # ---- bootstrap the concurrency window, then drain ---------------
        for _ in range(min(cfg.concurrency, len(queue))):
            start_next_query(0.0)
        while core.busy:
            core.advance_to(core.next_event_time())

        wall = max((r.end_t for r in records), default=0.0)
        return WorkloadReport(
            records=records, wall_time_s=wall,
            storage_bytes=core.sim.total_bytes,
            storage_requests=core.sim.total_requests,
            concurrency=cfg.concurrency)


def run_workload(index, queries: np.ndarray, params: SearchParams,
                 storage: StorageSpec | EngineConfig, concurrency: int = 1,
                 cache_bytes: int = 0, seed: int = 0,
                 compute: ComputeSpec = DEFAULT_COMPUTE,
                 cache_policy: str = "slru",
                 pinned_keys: frozenset | None = None,
                 query_ids: Iterable[int] | None = None) -> WorkloadReport:
    """The one-call evaluation hook: run ``queries`` through the engine.

    Accepts either a bare :class:`StorageSpec` plus knobs (the benchmark
    harness style) or a fully-formed :class:`EngineConfig` as the fourth
    argument (the ``repro.tuning`` style — every cache/seed/compute knob in
    one value).  ``query_ids`` maps repeated/reordered workload queries
    back to ground-truth rows (see ``serving.workload``).
    """
    if isinstance(storage, EngineConfig):
        cfg = storage
    else:
        cfg = EngineConfig(
            storage=storage, concurrency=concurrency,
            cache_bytes=cache_bytes, cache_policy=cache_policy,
            pinned_keys=pinned_keys, compute=compute, seed=seed)
    eng = QueryEngine(index, cfg)
    return eng.run(queries, params, query_ids=query_ids)
