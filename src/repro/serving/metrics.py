"""Workload-level measurement (paper §5.1 ①–⑦)."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.types import QueryMetrics


@dataclasses.dataclass
class BatchTrace:
    """One fetch phase of one query (for Fig 22a / Fig 23-style plots)."""

    round_idx: int
    submit_t: float
    done_t: float
    n_requests: int        # remote storage requests (misses)
    n_hits: int            # cache hits in this batch
    nbytes_storage: int
    nbytes_total: int
    n_nvme: int = 0        # requests served from the local NVMe tier
    nbytes_nvme: int = 0   # bytes served from the local NVMe tier

    @property
    def io_latency(self) -> float:
        return self.done_t - self.submit_t


@dataclasses.dataclass
class QueryRecord:
    qid: int
    start_t: float                 # service start (window admission)
    end_t: float
    ids: np.ndarray
    dists: np.ndarray
    metrics: QueryMetrics
    batches: list[BatchTrace]
    arrive_t: float | None = None  # open-loop arrival (None => start_t)

    @property
    def latency(self) -> float:
        return self.end_t - self.start_t

    @property
    def sojourn(self) -> float:
        """Arrival-to-completion time (includes backlog wait)."""
        t0 = self.start_t if self.arrive_t is None else self.arrive_t
        return self.end_t - t0


@dataclasses.dataclass
class WorkloadReport:
    """Aggregates for one (index, params, environment, workload) run."""

    records: list[QueryRecord]
    wall_time_s: float
    storage_bytes: int
    storage_requests: int
    concurrency: int
    scenario: str = "closed"       # arrival process kind
    n_arrivals: int = 0
    offered_qps: float = 0.0       # arrival rate (== qps when closed-loop)
    ingest: dict | None = None     # repro.ingest accounting (rw runs)

    # ------------------------------------------------ paper metrics ①–⑦ --
    @property
    def qps(self) -> float:                                   # ①
        return len(self.records) / max(self.wall_time_s, 1e-12)

    def latency_percentile(self, p: float) -> float:          # ②
        return float(np.percentile([r.latency for r in self.records], p))

    def sojourn_percentile(self, p: float) -> float:
        """Arrival-to-completion percentile — includes backlog wait
        (closed loop backlogs everything at t=0, so there it measures
        drain position, not service time; use latency_percentile there)."""
        return float(np.percentile([r.sojourn for r in self.records], p))

    @property
    def mean_latency(self) -> float:
        return float(np.mean([r.latency for r in self.records]))

    @property
    def bandwidth_Bps(self) -> float:                         # ③
        return self.storage_bytes / max(self.wall_time_s, 1e-12)

    @property
    def mean_expansions(self) -> float:                       # ④
        return float(np.mean([r.metrics.expansions for r in self.records]))

    @property
    def mean_lists_visited(self) -> float:                    # ⑤
        return float(np.mean([r.metrics.lists_visited
                              for r in self.records]))

    @property
    def mean_io_latency(self) -> float:                       # ⑥
        waits = [b.io_latency for r in self.records for b in r.batches
                 if b.n_requests > 0]
        return float(np.mean(waits)) if waits else 0.0

    @property
    def hit_rate(self) -> float:                              # ⑦
        hits = sum(r.metrics.cache_hits for r in self.records)
        lookups = sum(r.metrics.cache_lookups for r in self.records)
        return hits / lookups if lookups else 0.0

    # ------------------------------------------------------ derived -----
    @property
    def mean_roundtrips(self) -> float:
        return float(np.mean([r.metrics.roundtrips for r in self.records]))

    @property
    def mean_requests(self) -> float:
        return float(np.mean([r.metrics.requests for r in self.records]))

    @property
    def mean_bytes_read(self) -> float:
        return float(np.mean([r.metrics.bytes_read for r in self.records]))

    @property
    def mean_bytes_storage(self) -> float:
        return float(np.mean([r.metrics.bytes_storage
                              for r in self.records]))

    def recall_against(self, gt_ids: np.ndarray) -> float:
        from repro.core.types import recall_at_k
        recs = [recall_at_k(r.ids[r.ids >= 0], gt_ids[r.qid])
                for r in self.records]
        return float(np.mean(recs))

    def summary(self) -> dict:
        out = dict(
            qps=self.qps,
            mean_latency_s=self.mean_latency,
            p50_latency_s=self.latency_percentile(50),
            p99_latency_s=self.latency_percentile(99),
            bandwidth_MBps=self.bandwidth_Bps / 1e6,
            mean_io_latency_s=self.mean_io_latency,
            mean_roundtrips=self.mean_roundtrips,
            mean_requests=self.mean_requests,
            mean_bytes_read_MB=self.mean_bytes_read / 1e6,
            hit_rate=self.hit_rate,
            storage_requests=self.storage_requests,
        )
        if self.ingest is not None:
            out["ingest"] = self.ingest
        return out
