"""Search-trace record & replay.

A query's fetch trace (which segments, in which dependency phases, with
which compute between them) is a property of the *index + parameters*, not
of the environment: the algorithms never adapt mid-query to cache state or
congestion.  So the benchmark harness records each search once and replays
the trace through the timing engine for every (storage × concurrency ×
cache) configuration — identical results, orders-of-magnitude faster
sweeps (the paper's figures are exactly such grids).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.types import (FetchBatch, QueryMetrics, SearchParams,
                              SearchResult)
from repro.serving.engine import EngineConfig, QueryEngine
from repro.serving.metrics import WorkloadReport


@dataclasses.dataclass
class QueryTrace:
    qid: int
    batches: list[FetchBatch]
    checkpoints: list[tuple]       # metrics snapshot at each yield
    final: tuple                   # metrics snapshot at return
    result_ids: np.ndarray
    result_dists: np.ndarray


_FIELDS = ("bytes_read", "requests", "roundtrips", "expansions",
           "lists_visited", "dist_comps", "pq_dist_comps")


def _snap(m: QueryMetrics) -> tuple:
    return tuple(getattr(m, f) for f in _FIELDS)


def _restore(m: QueryMetrics, snap: tuple) -> None:
    for f, v in zip(_FIELDS, snap):
        setattr(m, f, v)


def record_traces(index, queries: np.ndarray, params: SearchParams,
                  query_ids=None) -> list[QueryTrace]:
    """Run every search once against the raw store, capturing its trace."""
    qids = list(query_ids) if query_ids is not None else range(len(queries))
    out = []
    for qi, qid in zip(range(len(queries)), qids):
        m = QueryMetrics()
        gen = index.search_plan(queries[qi], params, m)
        batches, checkpoints = [], []
        try:
            batch = next(gen)
            while True:
                batches.append(batch)
                checkpoints.append(_snap(m))
                payloads = {r.key: index.store.get(r.key)
                            for r in batch.requests}
                batch = gen.send(payloads)
        except StopIteration as stop:
            res: SearchResult = stop.value
        out.append(QueryTrace(
            qid=qid, batches=batches, checkpoints=checkpoints,
            final=_snap(m), result_ids=res.ids, result_dists=res.dists))
    return out


def _replay_plan(trace: QueryTrace, metrics: QueryMetrics):
    for batch, snap in zip(trace.batches, trace.checkpoints):
        _restore(metrics, snap)
        yield batch
    _restore(metrics, trace.final)
    return SearchResult(trace.result_ids, trace.result_dists, metrics)


class _TraceAdapter:
    """Duck-typed index whose search_plan replays recorded traces."""

    def __init__(self, index, traces: list[QueryTrace]):
        self.meta = index.meta
        self.store = index.store
        self._traces = traces
        self._cursor = 0

    def reset(self):
        self._cursor = 0

    def search_plan(self, q, params, metrics=None):
        metrics = metrics if metrics is not None else QueryMetrics()
        tr = self._traces[self._cursor]
        self._cursor += 1
        return _replay_plan(tr, metrics)


def replay_workload(index, traces: list[QueryTrace],
                    config: EngineConfig) -> WorkloadReport:
    """Replay recorded traces under an environment configuration."""
    adapter = _TraceAdapter(index, traces)
    engine = QueryEngine(adapter, config)
    dummy_queries = np.zeros((len(traces), 1), dtype=np.float32)
    return engine.run(dummy_queries, SearchParams(),
                      query_ids=[t.qid for t in traces])
