"""LM -> vector-search bridge: pooled embeddings from backbone states.

This is the integration point between the assigned LM architectures and
the paper's cloud-native vector index (DESIGN.md §4 Arch-applicability):
documents are embedded by the LM, indexed by ``repro.core``, and queried
at serving time (examples/rag_serving.py).  The embedding width equals
``d_model`` — the paper's dimensionality studies (96-D vs 960-D) map onto
the choice of projection width here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def embed_tokens(lm, params, batch, out_dim: int | None = None,
                 seed: int = 0) -> np.ndarray:
    """Mean-pooled, L2-normalised embeddings (B, out_dim or d_model)."""
    x = lm._backbone(params, batch)            # (B, S, D) final-norm states
    pooled = x.astype(jnp.float32).mean(axis=1)
    if out_dim is not None and out_dim != pooled.shape[-1]:
        key = jax.random.PRNGKey(seed)
        proj = jax.random.normal(key, (pooled.shape[-1], out_dim),
                                 jnp.float32) / jnp.sqrt(out_dim)
        pooled = pooled @ proj
    norm = jnp.linalg.norm(pooled, axis=-1, keepdims=True)
    return np.asarray(pooled / jnp.maximum(norm, 1e-9))
