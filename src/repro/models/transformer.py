"""Composable decoder stack with lax.scan over repeating layer units.

The layer sequence of every assigned arch is a repetition of a short unit
(dense: [attn]; mamba2: [ssm]; recurrentgemma: [rglru, rglru, attn] with a
2-layer tail; vlm: [attn x4, cross]), so the stack scans stacked unit
params — one compiled unit regardless of depth (compile-time and HLO size
stay O(unit), which also keeps the 512-device dry-runs tractable).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import rglru as rg
from repro.models import ssm as ssm_mod
from repro.models.layers import (attention, attention_decode,
                                 attention_init, attention_prefill,
                                 cross_attention, mlp, mlp_init, moe,
                                 moe_init)

Params = Any


# --------------------------------------------------------------- structure

def layer_kinds(cfg: ModelConfig) -> list[str]:
    return [cfg.layer_kind(i) for i in range(cfg.n_layers)]


def unit_structure(cfg: ModelConfig) -> tuple[list[str], int, list[str]]:
    """(unit kinds, n_repetitions, tail kinds)."""
    kinds = layer_kinds(cfg)
    if cfg.block_pattern:
        unit = list(cfg.block_pattern)
    elif cfg.cross_attn_period:
        unit = kinds[: cfg.cross_attn_period]
    else:
        unit = kinds[:1]
    n_rep = len(kinds) // len(unit)
    tail = kinds[n_rep * len(unit):]
    return unit, n_rep, tail


# ------------------------------------------------------------------- init

def _block_init(key, cfg: ModelConfig, kind: str) -> Params:
    k1, k2 = jax.random.split(key)
    if kind == "ssm":
        return {"ssm": ssm_mod.ssm_init(k1, cfg)}
    if kind == "rglru":
        return {"rec": rg.rglru_init(k1, cfg), "ffn": mlp_init(k2, cfg)}
    if kind == "cross":
        return {"attn": attention_init(k1, cfg, cross=True),
                "ffn": mlp_init(k2, cfg)}
    ffn = (moe_init(k2, cfg) if cfg.n_experts else mlp_init(k2, cfg))
    return {"attn": attention_init(k1, cfg), "ffn": ffn}


def stack_init(key, cfg: ModelConfig) -> Params:
    unit, n_rep, tail = unit_structure(cfg)
    keys = jax.random.split(key, n_rep * len(unit) + len(tail))
    reps = []
    ki = 0
    for _ in range(n_rep):
        blocks = []
        for kind in unit:
            blocks.append(_block_init(keys[ki], cfg, kind))
            ki += 1
        reps.append(tuple(blocks))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *reps)
    tail_params = []
    for kind in tail:
        tail_params.append(_block_init(keys[ki], cfg, kind))
        ki += 1
    return {"unit": stacked, "tail": tail_params}


# ---------------------------------------------------------------- forward

def _apply_block(kind: str, p: Params, cfg: ModelConfig, x, positions,
                 ctx):
    if kind == "ssm":
        return ssm_mod.ssm_forward(p["ssm"], cfg, x)
    if kind == "rglru":
        x = rg.rglru_forward(p["rec"], cfg, x)
        return mlp(p["ffn"], cfg, x)
    if kind == "cross":
        x = cross_attention(p["attn"], cfg, x, ctx)
        return mlp(p["ffn"], cfg, x)
    window = cfg.local_window if cfg.block_pattern else 0
    x = attention(p["attn"], cfg, x, positions, window=window)
    if cfg.n_experts:
        return moe(p["ffn"], cfg, x)
    return mlp(p["ffn"], cfg, x)


# Optional remat policy for the layer-scan checkpoint (perf knob):
# None = full recompute (4x fwd flops in training);
# "dots" = save matmul outputs, recompute elementwise only (~3x)
REMAT_POLICY: str | None = None


def set_remat_policy(name: str | None) -> None:
    global REMAT_POLICY
    assert name in (None, "dots"), name
    REMAT_POLICY = name


def _checkpoint(fn):
    if REMAT_POLICY == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def stack_forward(params: Params, cfg: ModelConfig, x: jax.Array,
                  positions: jax.Array, ctx: jax.Array | None = None
                  ) -> jax.Array:
    unit, _, tail = unit_structure(cfg)

    def unit_fn(h, unit_params):
        for kind, p in zip(unit, unit_params):
            h = _apply_block(kind, p, cfg, h, positions, ctx)
        return h

    if cfg.remat:
        unit_fn = _checkpoint(unit_fn)

    def body(h, unit_params):
        return unit_fn(h, unit_params), None

    x, _ = jax.lax.scan(body, x, params["unit"])
    for kind, p in zip(tail, params["tail"]):
        x = _apply_block(kind, p, cfg, x, positions, ctx)
    return x


# ------------------------------------------------------------- serving ---

def _block_prefill(kind, p, cfg, x, positions, ctx):
    if kind == "ssm":
        out, cache = ssm_mod.ssm_prefill(p["ssm"], cfg, x)
        return out, cache
    if kind == "rglru":
        x, cache = rg.rglru_prefill(p["rec"], cfg, x)
        return mlp(p["ffn"], cfg, x), cache
    if kind == "cross":
        x = cross_attention(p["attn"], cfg, x, ctx)
        # cache the projected image K/V once (static during decode)
        from repro.models.layers import _qkv, rmsnorm
        c = rmsnorm(p["attn"]["kv_norm"], ctx)
        _, k, v = _qkv(p["attn"], cfg, c, c)
        return mlp(p["ffn"], cfg, x), (k, v)
    window = cfg.local_window if cfg.block_pattern else 0
    x, (k, v) = attention_prefill(p["attn"], cfg, x, positions,
                                  window=window)
    if window:
        # keep only the ring window, rolled so position p sits at slot
        # p % window (the layout attention_decode's ring writes expect)
        S = k.shape[1]
        if S >= window:
            k = jnp.roll(k[:, -window:], S % window, axis=1)
            v = jnp.roll(v[:, -window:], S % window, axis=1)
    ffn = moe if cfg.n_experts else mlp
    return ffn(p["ffn"], cfg, x), (k, v)


def _block_decode(kind, p, cfg, x, pos, cache, ctx):
    if kind == "ssm":
        return ssm_mod.ssm_decode(p["ssm"], cfg, x, cache)
    if kind == "rglru":
        x, cache = rg.rglru_decode(p["rec"], cfg, x, cache)
        return mlp(p["ffn"], cfg, x), cache
    if kind == "cross":
        from repro.models.layers import _sdpa, rmsnorm
        k, v = cache
        h = rmsnorm(p["attn"]["norm"], x)
        q = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wq"].astype(x.dtype))
        if cfg.qk_norm:
            q = rmsnorm(p["attn"]["q_norm"], q)
        o = _sdpa(q, k.astype(x.dtype), v.astype(x.dtype), None,
                  cfg.n_kv_heads)
        x = x + jnp.einsum("bshk,hkd->bsd", o,
                           p["attn"]["wo"].astype(x.dtype))
        return mlp(p["ffn"], cfg, x), cache
    window = cfg.local_window if cfg.block_pattern else 0
    x, cache = attention_decode(p["attn"], cfg, x, cache, pos,
                                window=window)
    ffn = moe if cfg.n_experts else mlp
    return ffn(p["ffn"], cfg, x), cache


def stack_prefill(params, cfg, x, positions, ctx=None):
    unit, _, tail = unit_structure(cfg)

    def unit_fn(h, unit_params):
        caches = []
        for kind, p in zip(unit, unit_params):
            h, c = _block_prefill(kind, p, cfg, h, positions, ctx)
            caches.append(c)
        return h, tuple(caches)

    def body(h, unit_params):
        return unit_fn(h, unit_params)

    x, unit_caches = jax.lax.scan(body, x, params["unit"])
    tail_caches = []
    for kind, p in zip(tail, params["tail"]):
        x, c = _block_prefill(kind, p, cfg, x, positions, ctx)
        tail_caches.append(c)
    return x, {"unit": unit_caches, "tail": tail_caches}


def stack_decode(params, cfg, x, pos, caches, ctx=None):
    unit, _, tail = unit_structure(cfg)

    def body(h, scanned):
        unit_params, unit_cache = scanned
        new_caches = []
        for kind, p, c in zip(unit, unit_params, unit_cache):
            h, nc = _block_decode(kind, p, cfg, h, pos, c, ctx)
            new_caches.append(nc)
        return h, tuple(new_caches)

    x, new_unit_caches = jax.lax.scan(
        body, x, (params["unit"], caches["unit"]))
    new_tail = []
    for kind, p, c in zip(tail, params["tail"], caches["tail"]):
        x, nc = _block_decode(kind, p, cfg, x, pos, c, ctx)
        new_tail.append(nc)
    return x, {"unit": new_unit_caches, "tail": new_tail}
