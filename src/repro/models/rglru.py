"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Recurrent branch: linear -> causal depthwise conv -> RG-LRU; gate branch:
linear -> GeLU; merged multiplicatively and projected back.  The RG-LRU:

    r_t = sigmoid(W_a xi_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x xi_t + b_x)          (input gate)
    log a_t = c * r_t * log sigmoid(Lambda)   (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * xi_t)

Training uses ``jax.lax.associative_scan`` over (a, b) pairs (parallel
prefix — the TPU-native mapping of the linear recurrence); decode is the
O(1) single step that makes long_500k viable for this arch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, dense_init, rmsnorm, rmsnorm_init

_C = 8.0


def rglru_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    w = cfg.lru_width or d
    K = cfg.ssm_conv
    ks = jax.random.split(key, 7)
    # Lambda init so that a in [0.9, 0.999] at r=1 (Griffin appendix)
    u = jax.random.uniform(ks[0], (w,), minval=0.9, maxval=0.999)
    lam = jnp.log(u ** (-1.0 / _C) - 1.0)   # sigmoid(-lam)^c = u... inverse
    return {
        "norm": rmsnorm_init(d),
        "in_rec": dense_init(ks[1], (d, w)),
        "in_gate": dense_init(ks[2], (d, w)),
        "conv_w": (jax.random.normal(ks[3], (K, w)) * 0.1
                   ).astype(jnp.float32),
        "conv_b": jnp.zeros((w,), jnp.float32),
        "w_a": dense_init(ks[4], (w, w)),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_x": dense_init(ks[5], (w, w)),
        "b_x": jnp.zeros((w,), jnp.float32),
        "lam": -lam.astype(jnp.float32),
        "out": dense_init(ks[6], (w, d)),
    }


def _conv(p, x):
    K = p["conv_w"].shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    return sum(pad[:, i:i + x.shape[1], :] * p["conv_w"][i].astype(x.dtype)
               for i in range(K)) + p["conv_b"].astype(x.dtype)


def _gates(p, xi):
    """log_a (f32) and gated input for the RG-LRU."""
    r = jax.nn.sigmoid((xi @ p["w_a"].astype(xi.dtype)).astype(jnp.float32)
                       + p["b_a"])
    i = jax.nn.sigmoid((xi @ p["w_x"].astype(xi.dtype)).astype(jnp.float32)
                       + p["b_x"])
    log_a = _C * r * jax.nn.log_sigmoid(p["lam"])[None, None, :]
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i * xi.astype(jnp.float32))
    return a, gated


def rglru_forward(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Training forward with parallel associative scan.  (B,S,D)->(B,S,D)."""
    h = rmsnorm(p["norm"], x)
    gate = jax.nn.gelu(h @ p["in_gate"].astype(x.dtype))
    xi = _conv(p, h @ p["in_rec"].astype(x.dtype))
    a, b = _gates(p, xi)                      # (B,S,W) f32 each

    def combine(left, right):
        (a1, b1), (a2, b2) = left, right
        return a2 * a1, a2 * b1 + b2

    _, hseq = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (hseq * gate.astype(jnp.float32)).astype(x.dtype)
    return x + y @ p["out"].astype(x.dtype)


def rglru_init_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    w = cfg.lru_width or cfg.d_model
    K = cfg.ssm_conv
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, K - 1, w), dtype)}


def rglru_prefill(p, cfg, x):
    h = rmsnorm(p["norm"], x)
    gate = jax.nn.gelu(h @ p["in_gate"].astype(x.dtype))
    pre = h @ p["in_rec"].astype(x.dtype)
    xi = _conv(p, pre)
    a, b = _gates(p, xi)

    def combine(left, right):
        (a1, b1), (a2, b2) = left, right
        return a2 * a1, a2 * b1 + b2

    _, hseq = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (hseq * gate.astype(jnp.float32)).astype(x.dtype)
    out = x + y @ p["out"].astype(x.dtype)
    K = cfg.ssm_conv
    cache = {"h": hseq[:, -1, :],
             "conv": pre[:, pre.shape[1] - (K - 1):, :]}
    return out, cache


def rglru_decode(p, cfg, x, cache):
    """One-token step.  x: (B, 1, D)."""
    h = rmsnorm(p["norm"], x)
    gate = jax.nn.gelu(h @ p["in_gate"].astype(x.dtype))
    pre = h @ p["in_rec"].astype(x.dtype)                  # (B,1,W)
    window = jnp.concatenate([cache["conv"], pre], axis=1)  # (B,K,W)
    w = p["conv_w"].astype(x.dtype)
    xi = (jnp.einsum("bkw,kw->bw", window, w)
          + p["conv_b"].astype(x.dtype))[:, None, :]
    a, b = _gates(p, xi)
    hnew = a[:, 0] * cache["h"] + b[:, 0]
    y = (hnew[:, None, :] * gate.astype(jnp.float32)).astype(x.dtype)
    out = x + y @ p["out"].astype(x.dtype)
    return out, {"h": hnew, "conv": window[:, 1:, :]}
