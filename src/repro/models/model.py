"""LM wrapper: embeddings, stack, head, losses, serving steps, input specs.

One class serves all 10 assigned architectures; modality differences are
confined to ``input_specs`` / frontend handling:

* text archs: int32 ``tokens``;
* musicgen (audio): the EnCodec frontend is a stub — inputs are
  precomputed frame *embeddings* (B, S, D) (assignment rule);
* llama-3.2-vision (vlm): text tokens + precomputed patch embeddings
  (B, n_frontend_tokens, D) consumed by the cross-attention layers.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import rglru as rg
from repro.models import ssm as ssm_mod
from repro.models import transformer as tr
from repro.models.layers import dense_init, rmsnorm, rmsnorm_init

Params = Any

# sequence-chunk size for the vocab-parallel chunked loss (memory: the
# full (B, S, V) f32 logits of a 256k-vocab model would be hundreds of
# GB per chip — the loss is computed per sequence chunk instead)
LOSS_CHUNK = 256


def _maybe_shard(x, *spec_axes):
    from repro.models.layers import maybe_shard
    return maybe_shard(x, *spec_axes)


class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------- init --
    def init(self, key) -> Params:
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        p = {
            "embed": dense_init(k1, (cfg.vocab, cfg.d_model), scale=1.0),
            "blocks": tr.stack_init(k2, cfg),
            "final_norm": rmsnorm_init(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = dense_init(k3, (cfg.d_model, cfg.vocab))
        return p

    def abstract_params(self) -> Params:
        return jax.eval_shape(lambda k: self.init(k), jax.random.PRNGKey(0))

    # ---------------------------------------------------------- forward --
    def _embed_inputs(self, params, batch) -> jax.Array:
        cfg = self.cfg
        if cfg.family == "audio":
            return batch["frames"].astype(jnp.dtype(cfg.dtype))
        tok = batch["tokens"]
        x = params["embed"][tok].astype(jnp.dtype(cfg.dtype))
        return x * (cfg.d_model ** 0.5)

    def _ctx(self, params, batch):
        if self.cfg.family == "vlm":
            return batch["image_embeds"].astype(jnp.dtype(self.cfg.dtype))
        return None


    def _head(self, params, dtype):
        cfg = self.cfg
        if cfg.tie_embeddings:
            # tied head: rescale so init logits are O(1) like an untied head
            return params["embed"].T.astype(dtype) * (cfg.d_model ** -0.5)
        return params["lm_head"].astype(dtype)

    def logits(self, params, batch) -> jax.Array:
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        S = x.shape[1]
        positions = jnp.arange(S)[None, :]
        x = tr.stack_forward(params["blocks"], cfg, x, positions,
                             ctx=self._ctx(params, batch))
        x = rmsnorm(params["final_norm"], x)
        head = self._head(params, x.dtype)
        return jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)

    def _backbone(self, params, batch) -> jax.Array:
        """Final-norm hidden states (B, S, D)."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        S = x.shape[1]
        positions = jnp.arange(S)[None, :]
        x = tr.stack_forward(params["blocks"], cfg, x, positions,
                             ctx=self._ctx(params, batch))
        return rmsnorm(params["final_norm"], x)

    def loss(self, params, batch) -> jax.Array:
        """Mean next-token cross entropy (+ tiny z-loss for stability).

        The head matmul + softmax run per sequence chunk with the vocab
        dim sharded over "model" — the (B, S, V) f32 logits of a
        256k-vocab arch never materialise (DESIGN.md §5).
        """
        cfg = self.cfg
        x = self._backbone(params, batch)
        head = self._head(params, x.dtype)
        labels = batch["labels"]
        B, S, _ = x.shape
        chunk = min(LOSS_CHUNK, S)
        nc = S // chunk if S % chunk == 0 else 1
        chunk = S // nc
        xc = x.reshape(B, nc, chunk, -1).swapaxes(0, 1)
        lc = labels.reshape(B, nc, chunk).swapaxes(0, 1)

        @jax.checkpoint
        def chunk_nll(xs, ls):
            # checkpointed: the (b, chunk, V) logits are recomputed in the
            # backward instead of being stacked across the scan (a 256k-
            # vocab logits stack is ~4 GB/chip otherwise)
            logits = jnp.einsum("bsd,dv->bsv", xs, head
                                ).astype(jnp.float32)
            from repro.models.layers import BATCH_AXES
            logits = _maybe_shard(logits, BATCH_AXES, None, "model")
            logz = jax.nn.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(logits, ls[..., None],
                                     axis=-1)[..., 0]
            nll = (logz - ll) + 1e-4 * (logz ** 2)
            return nll.sum()

        def chunk_loss(carry, inp):
            xs, ls = inp
            return carry + chunk_nll(xs, ls), None

        total, _ = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32),
                                (xc, lc))
        return total / (B * S)

    # ---------------------------------------------------------- serving --
    def prefill(self, params, batch):
        """Prompt pass: returns (last-position logits, serving caches)."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        S = x.shape[1]
        positions = jnp.arange(S)[None, :]
        x, caches = tr.stack_prefill(params["blocks"], cfg, x, positions,
                                     ctx=self._ctx(params, batch))
        x = rmsnorm(params["final_norm"], x[:, -1:, :])
        logits = jnp.einsum("bsd,dv->bsv", x, self._head(params, x.dtype)
                            ).astype(jnp.float32)
        return logits, caches

    def decode_step(self, params, batch, pos, caches):
        """One new token against existing caches.  pos: int32 scalar."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)        # (B, 1, D)
        x, caches = tr.stack_decode(params["blocks"], cfg, x, pos, caches,
                                    ctx=self._ctx(params, batch))
        x = rmsnorm(params["final_norm"], x)
        logits = jnp.einsum("bsd,dv->bsv", x, self._head(params, x.dtype)
                            ).astype(jnp.float32)
        return logits, caches

    # ----------------------------------------------------- cache specs ---
    def init_caches(self, batch: int, capacity: int) -> Params:
        """Concrete zero caches with given KV capacity (decode serving)."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        unit, n_rep, tail = tr.unit_structure(cfg)

        def one(kind):
            if kind == "ssm":
                return ssm_mod.ssm_init_cache(cfg, batch, dt)
            if kind == "rglru":
                return rg.rglru_init_cache(cfg, batch, dt)
            hd = cfg.resolved_head_dim
            if kind == "cross":
                T = cfg.n_frontend_tokens
                return (jnp.zeros((batch, T, cfg.n_kv_heads, hd), dt),
                        jnp.zeros((batch, T, cfg.n_kv_heads, hd), dt))
            window = cfg.local_window if cfg.block_pattern else 0
            T = min(capacity, window) if window else capacity
            return (jnp.zeros((batch, T, cfg.n_kv_heads, hd), dt),
                    jnp.zeros((batch, T, cfg.n_kv_heads, hd), dt))

        unit_caches = tuple(
            jax.tree.map(lambda x: jnp.broadcast_to(x, (n_rep,) + x.shape),
                         one(kind))
            for kind in unit)
        tail_caches = [one(kind) for kind in tail]
        return {"unit": unit_caches, "tail": tail_caches}

    # ----------------------------------------------------- input specs ---
    def input_specs(self, shape: ShapeConfig) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of this cell
        (weak-type-correct, shardable, no allocation) — dry-run fuel."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        dt = jnp.dtype(cfg.dtype)
        sds = jax.ShapeDtypeStruct

        def text_inputs(seq):
            if cfg.family == "audio":
                return {"frames": sds((B, seq, cfg.d_model), dt)}
            return {"tokens": sds((B, seq), i32)}

        if shape.kind == "train":
            batch = text_inputs(S)
            batch["labels"] = sds((B, S), i32)
            if cfg.family == "vlm":
                batch["image_embeds"] = sds(
                    (B, cfg.n_frontend_tokens, cfg.d_model), dt)
            return {"batch": batch}
        if shape.kind == "prefill":
            batch = text_inputs(S)
            if cfg.family == "vlm":
                batch["image_embeds"] = sds(
                    (B, cfg.n_frontend_tokens, cfg.d_model), dt)
            return {"batch": batch}
        # decode: one token + caches at capacity S
        batch = text_inputs(1)
        if cfg.family == "vlm":
            batch["image_embeds"] = sds(
                (B, cfg.n_frontend_tokens, cfg.d_model), dt)
        caches = jax.eval_shape(
            lambda: self.init_caches(B, S))
        return {"batch": batch, "pos": sds((), i32), "caches": caches}


def build(cfg: ModelConfig) -> LM:
    return LM(cfg)
