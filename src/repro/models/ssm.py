"""Mamba-2 (SSD / state-space duality) block — train, prefill and decode.

Chunked SSD algorithm (Dao & Gu, arXiv:2405.21060, "minimal SSD" form):
within chunks the recurrence is materialised as a masked attention-like
matmul (MXU-friendly); across chunks a small recurrent state
(B, H, P, N) is carried by ``lax.scan``.  Decode is the O(1) single-step
state update — the reason this arch runs the long_500k cell.

Layout: x (B, S, d_inner) viewed as (B, S, H, P); B/C projections are
single-group (B, S, N) shared across heads; A is per-head scalar decay.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, dense_init, rmsnorm, rmsnorm_init


def ssm_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    din = cfg.d_inner
    N = cfg.ssm_state
    H = cfg.ssm_heads
    K = cfg.ssm_conv
    conv_ch = din + 2 * N
    ks = jax.random.split(key, 8)
    return {
        "norm": rmsnorm_init(d),
        "in_x": dense_init(ks[0], (d, din)),
        "in_z": dense_init(ks[1], (d, din)),
        "in_B": dense_init(ks[2], (d, N)),
        "in_C": dense_init(ks[3], (d, N)),
        "in_dt": dense_init(ks[4], (d, H)),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "conv_w": (jax.random.normal(ks[5], (K, conv_ch)) * 0.1
                   ).astype(jnp.float32),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "out_norm": rmsnorm_init(din),
        "out": dense_init(ks[6], (din, d)),
    }


def _causal_conv(p, xbc: jax.Array) -> jax.Array:
    """Depthwise causal conv, kernel K.  xbc: (B, S, C)."""
    K = p["conv_w"].shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :]
              * p["conv_w"][i].astype(xbc.dtype)
              for i in range(K))
    return jax.nn.silu(out + p["conv_b"].astype(xbc.dtype))


def _proj_inputs(p, cfg, x):
    dt_ = x.dtype
    h = rmsnorm(p["norm"], x)
    z = h @ p["in_z"].astype(dt_)
    xc = h @ p["in_x"].astype(dt_)
    Bc = h @ p["in_B"].astype(dt_)
    Cc = h @ p["in_C"].astype(dt_)
    dt = jax.nn.softplus(
        (h @ p["in_dt"].astype(dt_)).astype(jnp.float32)
        + p["dt_bias"])                                   # (B,S,H) f32
    return z, xc, Bc, Cc, dt


def _ssd_chunked(cfg: ModelConfig, xh, Bc, Cc, dt, A, init_state=None):
    """Chunked SSD scan.

    xh: (B,S,H,P) f32; Bc/Cc: (B,S,N) f32; dt: (B,S,H) f32; A: (H,) f32<0.
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bsz, S0, H, P = xh.shape
    N = Bc.shape[-1]
    Q = min(cfg.ssm_chunk, S0)
    pad = (-S0) % Q
    if pad:
        # zero-pad the tail: dt=0 there, so decay=1 and contribution=0 —
        # the carried state is unaffected (verified by decode-consistency
        # tests with non-multiple prompt lengths)
        zp = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] *
                               (t.ndim - 2))
        xh, Bc, Cc, dt = zp(xh), zp(Bc), zp(Cc), zp(dt)
    S = S0 + pad
    nc = S // Q
    # chunk-major layout for lax.scan: (nc, B, Q, ...) — the whole SSD
    # runs as ONE scan over chunks carrying the (B,H,P,N) state, so peak
    # memory is O(chunk), independent of sequence length (required for
    # the 32k/500k cells).
    r = lambda t: t.reshape(Bsz, nc, Q, *t.shape[2:]).swapaxes(0, 1)
    xh, Bc, Cc, dt = r(xh), r(Bc), r(Cc), r(dt)
    causal = jnp.tril(jnp.ones((Q, Q), bool))

    def chunk_step(state, inp):
        xh_c, B_c, C_c, dt_c = inp                        # (B,Q,...)
        dA = dt_c * A[None, None, :]                      # (B,Q,H) < 0
        La = jnp.cumsum(dA, axis=1)
        # intra-chunk: decay from t..s, masked in the exponent so
        # cotangents stay finite (exp of +large would poison where-grads)
        seg = La[:, :, None, :] - La[:, None, :, :]       # (B,Q,Q,H)
        seg = jnp.where(causal[None, :, :, None], seg, -1e30)
        M = jnp.exp(seg) * jnp.einsum("bsn,btn->bst", C_c, B_c)[..., None] \
            * dt_c[:, None, :, :]                         # (B,Q,Q,H)
        y_intra = jnp.einsum("bsth,bthp->bshp", M, xh_c)
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum("bsh,bsn,bhpn->bshp",
                             jnp.exp(La), C_c, state)
        # state update
        dec_last = jnp.exp(La[:, -1:, :] - La)            # (B,Q,H)
        contrib = jnp.einsum("bth,bthp,btn->bhpn",
                             dec_last * dt_c, xh_c, B_c)
        new_state = state * jnp.exp(La[:, -1, :])[..., None, None] + contrib
        return new_state, y_intra + y_inter

    state0 = (init_state if init_state is not None
              else jnp.zeros((Bsz, H, P, N), jnp.float32))
    final, y = jax.lax.scan(chunk_step, state0, (xh, Bc, Cc, dt))
    y = y.swapaxes(0, 1).reshape(Bsz, S, H, P)[:, :S0]
    return y, final


def ssm_forward(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Training forward (B, S, D) -> (B, S, D), residual included."""
    Bsz, S, D = x.shape
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    z, xc, Bc, Cc, dt = _proj_inputs(p, cfg, x)
    conv_in = jnp.concatenate([xc, Bc, Cc], axis=-1)
    conv_out = _causal_conv(p, conv_in)
    xc, Bc, Cc = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner
                                      + cfg.ssm_state], axis=-1)
    A = -jnp.exp(p["A_log"])
    xh = xc.reshape(Bsz, S, H, P).astype(jnp.float32)
    y, _ = _ssd_chunked(cfg, xh, Bc.astype(jnp.float32),
                        Cc.astype(jnp.float32), dt, A)
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(Bsz, S, cfg.d_inner).astype(x.dtype)
    y = rmsnorm(p["out_norm"], y * jax.nn.silu(z))
    return x + y @ p["out"].astype(x.dtype)


# ------------------------------------------------------------- serving ----

def ssm_init_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    K = cfg.ssm_conv
    conv_ch = cfg.d_inner + 2 * N
    return {
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, K - 1, conv_ch), dtype),
    }


def ssm_prefill(p, cfg, x):
    """Forward over a prompt, returning output and the serving cache."""
    Bsz, S, _ = x.shape
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    z, xc, Bc, Cc, dt = _proj_inputs(p, cfg, x)
    conv_in = jnp.concatenate([xc, Bc, Cc], axis=-1)
    conv_tail = conv_in[:, S - (cfg.ssm_conv - 1):, :]
    conv_out = _causal_conv(p, conv_in)
    xc, Bc, Cc = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner
                                      + cfg.ssm_state], axis=-1)
    A = -jnp.exp(p["A_log"])
    xh = xc.reshape(Bsz, S, H, P).astype(jnp.float32)
    y, final = _ssd_chunked(cfg, xh, Bc.astype(jnp.float32),
                            Cc.astype(jnp.float32), dt, A)
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(Bsz, S, cfg.d_inner).astype(x.dtype)
    y = rmsnorm(p["out_norm"], y * jax.nn.silu(z))
    out = x + y @ p["out"].astype(x.dtype)
    return out, {"state": final, "conv": conv_tail}


def ssm_decode(p, cfg, x, cache):
    """One-token step.  x: (B, 1, D).  Returns (out, new_cache)."""
    Bsz = x.shape[0]
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z, xc, Bc, Cc, dt = _proj_inputs(p, cfg, x)
    conv_in = jnp.concatenate([xc, Bc, Cc], axis=-1)      # (B,1,C)
    window = jnp.concatenate([cache["conv"], conv_in], axis=1)  # (B,K,C)
    w = p["conv_w"].astype(x.dtype)                        # (K, C)
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", window, w)
        + p["conv_b"].astype(x.dtype))[:, None, :]
    xc, Bc, Cc = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + N],
                           axis=-1)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[:, 0, :] * A[None, :])                 # (B,H)
    xh = xc.reshape(Bsz, H, P).astype(jnp.float32)
    contrib = jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0], xh,
                         Bc[:, 0].astype(jnp.float32))
    state = cache["state"] * a[..., None, None] + contrib
    y = jnp.einsum("bn,bhpn->bhp", Cc[:, 0].astype(jnp.float32), state)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(Bsz, 1, cfg.d_inner).astype(x.dtype)
    y = rmsnorm(p["out_norm"], y * jax.nn.silu(z))
    out = x + y @ p["out"].astype(x.dtype)
    return out, {"state": state, "conv": window[:, 1:, :]}
