"""Transformer building blocks (pure-functional jax; params are pytrees).

Conventions
-----------
* params are dicts of jnp arrays; init functions take (key, cfg) and
  return the dict.  Layer params for scanned stacks are later stacked
  along a leading layer axis by the model builder.
* activations flow as (B, S, D) in cfg.dtype (bf16 by default); matmul
  accumulation and softmax/norm math are f32.
* decode paths take a cache pytree and a position index; caches are
  (B, S_max, kv, hd) for global attention and ring buffers of
  (B, window, kv, hd) for local attention.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = dict

# batch-sharding axes for activation anchors; under the pure-FSDP policy
# (launch.sharding.set_policy) the model axis joins the batch axes
BATCH_AXES = ("pod", "data")


def set_batch_axes(axes: tuple) -> None:
    global BATCH_AXES
    BATCH_AXES = tuple(axes)


def maybe_shard(x, *spec_axes):
    """with_sharding_constraint iff a mesh is in context AND the dims
    divide the axis sizes; no-op on the bare-CPU test path.

    GSPMD propagation loses activation shardings through the scan/map
    bodies of the chunked attention and layer stack (observed: global-
    batch-sized buffers inside while bodies, 30x the per-chip budget) —
    these explicit anchors at block boundaries are what keep every
    intermediate batch- and head-sharded.
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:
            from jax._src.mesh import thread_resources
            mesh = thread_resources.env.physical_mesh   # legacy `with mesh:`
        if mesh is None or mesh.empty:
            return x
        sizes = dict(mesh.shape)
    except Exception:
        return x

    used: set = set()

    def resolve(a, dim):
        if a is None:
            return None
        names = a if isinstance(a, tuple) else (a,)
        names = tuple(n for n in names if n in sizes and n not in used)
        total = 1
        for n in names:
            total *= sizes[n]
        if not names or total <= 1 or x.shape[dim] % total != 0:
            return None
        used.update(names)
        return names if len(names) > 1 else names[0]

    spec = [resolve(a, i) for i, a in enumerate(spec_axes)]
    if all(s is None for s in spec):
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(*spec))


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, dtype=jnp.float32)
            * scale).astype(jnp.float32)


# ------------------------------------------------------------------ norm --

def rmsnorm_init(dim: int) -> Params:
    return {"scale": jnp.ones((dim,), jnp.float32)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


# ------------------------------------------------------------------ rope --

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd), positions: (B, S) or (S,) -> rotated x."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------- attention --

def attention_init(key, cfg: ModelConfig, cross: bool = False) -> Params:
    d, H, KV, hd = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                    cfg.resolved_head_dim)
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, H, hd)),
        "wk": dense_init(ks[1], (d, KV, hd)),
        "wv": dense_init(ks[2], (d, KV, hd)),
        "wo": dense_init(ks[3], (H, hd, d), scale=(H * hd) ** -0.5),
        "norm": rmsnorm_init(d),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd)
        p["k_norm"] = rmsnorm_init(hd)
    if cross:
        p["kv_norm"] = rmsnorm_init(d)
    return p


def _qkv(p: Params, cfg: ModelConfig, x: jax.Array, kv_src: jax.Array):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"].astype(dt))
    q = maybe_shard(q, BATCH_AXES, None, "model", None)
    k = maybe_shard(k, BATCH_AXES, None, "model", None)
    v = maybe_shard(v, BATCH_AXES, None, "model", None)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    return q, k, v


def _sdpa(q, k, v, mask, n_kv: int):
    """q: (B,S,H,hd), k/v: (B,T,KV,hd); GQA via head grouping; f32 softmax."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    G = H // n_kv
    qg = q.reshape(B, S, n_kv, G, hd)
    scores = jnp.einsum("bsngk,btnk->bngst", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / (hd ** 0.5)
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bngst,btnk->bsngk", probs.astype(q.dtype), v)
    return out.reshape(B, S, H, hd)


def causal_mask(S: int, T: int, window: int = 0) -> jax.Array:
    """(1,1,1,S,T) causal (optionally banded/local) mask; True = attend."""
    qpos = jnp.arange(S)[:, None] + (T - S)
    kpos = jnp.arange(T)[None, :]
    m = kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return m[None, None, None]


# Sequence length above which attention switches to the online-softmax
# chunked path (full S x T score materialisation at 32k would need
# hundreds of GB per chip — DESIGN.md §5).
CHUNKED_ATTN_THRESHOLD = 2048
ATTN_CHUNK = 1024
CAUSAL_BLOCK_UNROLL = 8     # unroll q chunks (causal blocking) up to here


@functools.partial(jax.checkpoint, static_argnums=(3, 4, 5, 6, 7, 8, 9))
def _causal_q_block(qch, kcs, vcs, qi, chunk, n_kv, G, hd, window, scale):
    """One query chunk attending to its (qi+1) causal KV chunks."""
    B = qch.shape[0]
    qch = maybe_shard(qch, BATCH_AXES, None, "model", None)
    qg = (qch.reshape(B, chunk, n_kv, G, hd).astype(jnp.float32) * scale)

    def kv_step(carry, inp):
        m_run, l_run, acc = carry
        kj, kch, vch = inp
        kch = maybe_shard(kch, BATCH_AXES, None, "model", None)
        vch = maybe_shard(vch, BATCH_AXES, None, "model", None)
        s = jnp.einsum("bsngk,btnk->bngst", qg, kch.astype(jnp.float32))
        s = maybe_shard(s, BATCH_AXES, "model", None, None, None)
        qpos = qi * chunk + jnp.arange(chunk)[:, None]
        kpos = kj * chunk + jnp.arange(chunk)[None, :]
        msk = kpos <= qpos
        if window:
            msk &= kpos > qpos - window
        s = jnp.where(msk[None, None, None], s, -1e30)
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        alpha = jnp.exp(m_run - m_new)
        pexp = jnp.exp(s - m_new[..., None])
        l_new = l_run * alpha + pexp.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bngst,btnk->bngsk", pexp, vch.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, n_kv, G, chunk), -1e30, jnp.float32)
    l0 = jnp.zeros((B, n_kv, G, chunk), jnp.float32)
    a0 = jnp.zeros((B, n_kv, G, chunk, hd), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(
        kv_step, (m0, l0, a0),
        (jnp.arange(qi + 1), kcs, vcs))
    o = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return o.transpose(0, 3, 1, 2, 4).reshape(
        B, chunk, n_kv * G, hd).astype(qch.dtype)


def _sdpa_chunked(q, k, v, n_kv: int, window: int = 0,
                  chunk: int | None = None):
    """Flash-style causal attention: scan over query chunks; per q-chunk
    either a banded KV slice (local attention) or an online-softmax scan
    over KV chunks.  Peak memory O(chunk^2) instead of O(S*T).

    q: (B,S,H,hd); k/v: (B,S,KV,hd).  Self-attention (S == T) only.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // n_kv
    chunk = min(chunk or ATTN_CHUNK, S)   # module attr read at call time
    assert S % chunk == 0, (S, chunk)
    nq = S // chunk
    scale = hd ** -0.5
    qc = q.reshape(B, nq, chunk, H, hd).transpose(1, 0, 2, 3, 4)

    if window and window + chunk < S:
        # banded path: each q chunk attends to a static-size KV slice
        span = window + chunk
        kp = jnp.pad(k, ((0, 0), (span - chunk, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (span - chunk, 0), (0, 0), (0, 0)))

        @jax.checkpoint
        def band(ci, qch):
            start = ci * chunk            # in padded coords
            qch = maybe_shard(qch, BATCH_AXES, None, "model", None)
            ks = jax.lax.dynamic_slice_in_dim(kp, start, span, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(vp, start, span, axis=1)
            ks = maybe_shard(ks, BATCH_AXES, None, "model", None)
            vs = maybe_shard(vs, BATCH_AXES, None, "model", None)
            qg = qch.reshape(B, chunk, n_kv, G, hd)
            s = jnp.einsum("bsngk,btnk->bngst", qg, ks,
                           preferred_element_type=jnp.float32) * scale
            qpos = ci * chunk + jnp.arange(chunk)[:, None]
            kpos = ci * chunk + jnp.arange(span)[None, :] - (span - chunk)
            m = (kpos <= qpos) & (kpos > qpos - window) & (kpos >= 0)
            s = jnp.where(m[None, None, None], s, -1e30)
            pr = jax.nn.softmax(s, axis=-1).astype(q.dtype)
            o = jnp.einsum("bngst,btnk->bsngk", pr, vs)
            return o.reshape(B, chunk, H, hd)

        out = jax.lax.map(lambda args: band(*args),
                          (jnp.arange(nq), qc))
        return out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)

    kc = k.reshape(B, nq, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nq, chunk, KV, hd).transpose(1, 0, 2, 3, 4)

    if 1 < nq <= CAUSAL_BLOCK_UNROLL:
        # causal-aware blocking: unroll q chunks; chunk i scans only its
        # i+1 causal KV chunks — skips the ~(nq-1)/(2nq) fraction of
        # blocks the uniform scan computes-then-masks (pure FLOP saving;
        # EXPERIMENTS.md §Perf iteration 3)
        outs = []
        for qi in range(nq):
            outs.append(_causal_q_block(
                qc[qi], kc[: qi + 1], vc[: qi + 1], qi, chunk,
                n_kv, G, hd, window, scale))
        return jnp.stack(outs).transpose(1, 0, 2, 3, 4).reshape(
            B, S, H, hd)

    # checkpointed: backward recomputes each q-block's KV scan instead of
    # materialising nested scan-VJP residual stacks (O(S^2) memory — this
    # was a 470 GB/chip blowup in the train_4k dry-run before)
    @jax.checkpoint
    def q_block(qi, qch):
        qch = maybe_shard(qch, BATCH_AXES, None, "model", None)
        qg = (qch.reshape(B, chunk, n_kv, G, hd).astype(jnp.float32)
              * scale)
        qg = maybe_shard(qg, BATCH_AXES, None, "model", None, None)

        def kv_step(carry, inp):
            m_run, l_run, acc = carry
            kj, kch, vch = inp
            kch = maybe_shard(kch, BATCH_AXES, None, "model", None)
            vch = maybe_shard(vch, BATCH_AXES, None, "model", None)
            s = jnp.einsum("bsngk,btnk->bngst", qg,
                           kch.astype(jnp.float32))
            s = maybe_shard(s, BATCH_AXES, "model", None, None, None)
            qpos = qi * chunk + jnp.arange(chunk)[:, None]
            kpos = kj * chunk + jnp.arange(chunk)[None, :]
            msk = kpos <= qpos
            if window:
                msk &= kpos > qpos - window
            s = jnp.where(msk[None, None, None], s, -1e30)
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            alpha = jnp.exp(m_run - m_new)
            pexp = jnp.exp(s - m_new[..., None])
            l_new = l_run * alpha + pexp.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bngst,btnk->bngsk", pexp, vch.astype(jnp.float32))
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, n_kv, G, chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((B, n_kv, G, chunk), jnp.float32)
        a0 = jnp.zeros((B, n_kv, G, chunk, hd), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nq), kc, vc))
        o = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return o.transpose(0, 3, 1, 2, 4).reshape(B, chunk, H, hd
                                                  ).astype(q.dtype)

    out = jax.lax.map(lambda args: q_block(*args), (jnp.arange(nq), qc))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)


def _self_attention_core(q, k, v, n_kv: int, window: int, S: int):
    if S > CHUNKED_ATTN_THRESHOLD:
        return _sdpa_chunked(q, k, v, n_kv, window=window)
    return _sdpa(q, k, v, causal_mask(S, S, window), n_kv)


def attention(p: Params, cfg: ModelConfig, x: jax.Array,
              positions: jax.Array, window: int = 0) -> jax.Array:
    """Full (training/prefill) self-attention with residual."""
    h = rmsnorm(p["norm"], x)
    q, k, v = _qkv(p, cfg, h, h)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    S = x.shape[1]
    o = _self_attention_core(q, k, v, cfg.n_kv_heads, window, S)
    o = maybe_shard(o, BATCH_AXES, None, "model", None)
    return x + jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))


def cross_attention(p: Params, cfg: ModelConfig, x: jax.Array,
                    ctx: jax.Array) -> jax.Array:
    """Cross-attention over a (B, T, D) context (VLM image tokens)."""
    h = rmsnorm(p["norm"], x)
    c = rmsnorm(p["kv_norm"], ctx)
    q, k, v = _qkv(p, cfg, h, c)
    o = _sdpa(q, k, v, None, cfg.n_kv_heads)
    return x + jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))


# -------------------------------------------------- attention: serving ----

def attention_prefill(p, cfg, x, positions, window: int = 0):
    """Like ``attention`` but also returns the (k, v) cache content."""
    h = rmsnorm(p["norm"], x)
    q, k, v = _qkv(p, cfg, h, h)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    S = x.shape[1]
    o = _self_attention_core(q, k, v, cfg.n_kv_heads, window, S)
    out = x + jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return out, (k, v)


def attention_decode(p, cfg, x, cache_kv, pos, window: int = 0):
    """One-token decode. x: (B, 1, D); cache_kv: (k, v) each
    (B, S_max, KV, hd) (or (B, window, KV, hd) ring for local attention);
    pos: scalar current position.  Returns (out, new_cache)."""
    h = rmsnorm(p["norm"], x)
    q, k, v = _qkv(p, cfg, h, h)
    posv = jnp.full((x.shape[0], 1), pos, dtype=jnp.int32)
    q = rope(q, posv, cfg.rope_theta)
    k = rope(k, posv, cfg.rope_theta)
    ck, cv = cache_kv
    T = ck.shape[1]
    slot = pos % T if window else pos
    ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), slot,
                                             axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), slot,
                                             axis=1)
    kpos = jnp.arange(T)
    if window:
        # ring buffer: valid entries are the last `window` positions
        age = (slot - kpos) % T
        valid = (age < jnp.minimum(pos + 1, T))
        mask = valid[None, None, None, None, :]
    else:
        mask = (kpos <= pos)[None, None, None, None, :]
    o = _sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype), mask,
              cfg.n_kv_heads)
    out = x + jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return out, (ck, cv)


# ------------------------------------------------------------------- mlp --

def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None) -> Params:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"norm": rmsnorm_init(d),
         "wi": dense_init(ks[0], (d, f)),
         "wo": dense_init(ks[1], (f, d))}
    if cfg.mlp in ("swiglu", "geglu"):
        p["wg"] = dense_init(ks[2], (d, f))
    return p


def _mlp_core(p: Params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    dt = h.dtype
    h = maybe_shard(h, BATCH_AXES, None, None)
    up = maybe_shard(h @ p["wi"].astype(dt), BATCH_AXES, None, "model")
    if cfg.mlp == "swiglu":
        act = jax.nn.silu(h @ p["wg"].astype(dt)) * up
    elif cfg.mlp == "geglu":
        act = jax.nn.gelu(h @ p["wg"].astype(dt)) * up
    else:
        act = jax.nn.gelu(up)
    act = maybe_shard(act, BATCH_AXES, None, "model")
    return act @ p["wo"].astype(dt)


def mlp(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    return x + _mlp_core(p, cfg, rmsnorm(p["norm"], x))


# ------------------------------------------------------------------- moe --

def moe_init(key, cfg: ModelConfig) -> Params:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "norm": rmsnorm_init(d),
        "router": dense_init(ks[0], (d, E), scale=d ** -0.5),
        "wi": dense_init(ks[1], (E, d, f)),
        "wo": dense_init(ks[2], (E, f, d)),
    }
    if cfg.mlp in ("swiglu", "geglu"):
        p["wg"] = dense_init(ks[3], (E, d, f))
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], cfg,
                               d_ff=cfg.d_ff * cfg.n_shared_experts)
    return p


MOE_GROUP = 8192


def moe(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Capacity-based top-k MoE with gather/scatter dispatch.

    Dispatch/combine are index gathers and scatter-adds (TPU-idiomatic —
    the GShard one-hot dispatch *einsum* costs 2·T·E·C·d real matmul
    FLOPs, which at 4k-seq batches is 10-100x the expert FFN compute;
    measured in the dry-run and replaced).  Tokens beyond an expert's
    capacity are dropped (residual passes through) — standard TPU MoE;
    capacity_factor controls the slack.

    Tokens are routed in GShard-style groups of <= MOE_GROUP: the
    (E, C, D) dispatch buffers stay O(group) regardless of sequence
    length (dbrx prefill_32k needed 37 GB/chip without grouping).
    """
    B, S, D = x.shape
    h = rmsnorm(p["norm"], x)
    T = B * S
    if T > MOE_GROUP and T % MOE_GROUP == 0:
        ng = T // MOE_GROUP
        hg = h.reshape(ng, MOE_GROUP, D)
        out = jax.lax.map(lambda g: _moe_group(p, cfg, g), hg)
        out = out.reshape(B, S, D)
    else:
        out = _moe_group(p, cfg, h.reshape(T, D)).reshape(B, S, D)
    if cfg.n_shared_experts:
        out = out + _mlp_core(p["shared"], cfg, h)
    return x + out


def _moe_group(p: Params, cfg: ModelConfig, ht: jax.Array) -> jax.Array:
    """Route one token group.  ht: (T, D) -> (T, D) expert mixture."""
    E, K = cfg.n_experts, cfg.experts_per_token
    T, D = ht.shape
    ht = maybe_shard(ht, BATCH_AXES, None)        # tokens stay data-sharded
    logits = (ht @ p["router"].astype(ht.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                   # (T, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)             # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    C = max(1, int(cfg.capacity_factor * T * K / E))
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)     # (T, K, E)
    pos_in_e = (jnp.cumsum(onehot.reshape(T * K, E), axis=0)
                .reshape(T, K, E) - onehot)                   # rank per slot
    pos = jnp.sum(pos_in_e * onehot, axis=-1)                 # (T, K)
    keep = pos < C
    # slot index per (token, k): expert*C + rank; overflow -> dump slot
    slot = jnp.where(keep, gate_idx * C + pos, E * C)         # (T, K)
    token_of_slot = jnp.full((E * C + 1,), T, jnp.int32)
    tkn = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[:, None],
                           (T, K))
    token_of_slot = token_of_slot.at[slot.reshape(-1)].set(
        tkn.reshape(-1), mode="drop")
    gate_of_slot = jnp.zeros((E * C + 1,), jnp.float32).at[
        slot.reshape(-1)].set(gate_vals.reshape(-1), mode="drop")
    # gather tokens into expert slots (padding row = zeros)
    ht_pad = jnp.concatenate([ht, jnp.zeros((1, D), ht.dtype)], axis=0)
    xe = ht_pad[token_of_slot[: E * C]].reshape(E, C, D)
    # experts over model (EP), capacity slots over data: dispatch becomes
    # an all-to-all instead of a full all-gather
    xe = maybe_shard(xe, "model", BATCH_AXES, None)
    up = jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(ht.dtype))
    if cfg.mlp in ("swiglu", "geglu"):
        g = jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(ht.dtype))
        act = (jax.nn.silu(g) if cfg.mlp == "swiglu"
               else jax.nn.gelu(g)) * up
    else:
        act = jax.nn.gelu(up)
    act = maybe_shard(act, "model", BATCH_AXES, None)
    ye = jnp.einsum("ecf,efd->ecd", act, p["wo"].astype(ht.dtype))
    ye = maybe_shard(ye, "model", BATCH_AXES, None)
    ye = ye.reshape(E * C, D) * gate_of_slot[: E * C, None].astype(
        ye.dtype)
    # scatter-add back to tokens (duplicate targets across k accumulate).
    # Accumulate in the activation dtype: the cross-expert-shard combine
    # all-reduce rides this array (bf16 halves ~1 TB/step of AR traffic
    # on dbrx prefill; <= top-k+shared summands, so error is bounded)
    yt = jnp.zeros((T + 1, D), ye.dtype).at[
        token_of_slot[: E * C]].add(ye)[:T]
    return yt.astype(ht.dtype)

