"""Multi-tenant fleet serving: N tenant workloads over one shard fleet.

Each tenant brings its own corpus, sealed index and partition, its own
arrival process (any :mod:`repro.sim.arrivals` kind, independent RNG
stream per tenant) and optionally its own update stream + compaction
schedule.  The fleet's *hardware* is shared: every shard instance's
segment cache (arbitrated by a :mod:`repro.tenancy.policy` sharing
strategy), NIC bandwidth pipe and GET-rate bucket serve all tenants'
jobs interleaved on one deterministic kernel.

Fairness mechanisms:

* **per-tenant admission windows** — each tenant's in-service query
  window is its weighted share of ``FleetConfig.concurrency``
  (:func:`fair_share_windows`), so a bursty tenant backlogs in its *own*
  queue instead of occupying the whole fleet window;
* **cache policy** — ``shared`` / ``static`` / ``weighted`` per-instance
  byte arbitration (see :mod:`repro.tenancy.policy`);
* **fair-share backpressure** — shard-level sheds are retried per
  sub-job exactly as in the single-tenant router; per-tenant shed
  retries are reported so a noisy tenant's pressure is attributable.

A **single closed-loop tenant under the ``shared`` policy is the
degenerate case** and reproduces the plain
:class:`repro.fleet.FleetRouter` reports bit-exactly — the tenancy
layer extends the repo's golden-parity chain rather than forking the
serving path.  (Stochastic arrival kinds draw from tenant-named RNG
streams — identical solo vs shared, but not sample-identical to the
plain path's ``"arrivals"`` stream.)
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Callable

import numpy as np

from repro.core.cluster_index import ClusterIndex
from repro.core.graph_index import GraphIndex
from repro.core.types import (ClusterIndexParams, GraphIndexParams,
                              SearchParams)
from repro.data.synth import DatasetSpec, make_dataset
from repro.fleet.partition import partition_for_index
from repro.fleet.router import FleetConfig, FleetRouter, _TenantCtx
from repro.tenancy.metrics import MultiTenantReport, TenantSlice
from repro.tenancy.policy import (TENANT_CACHE_POLICIES, TenantCacheBase,
                                  make_tenant_cache)
from repro.tenancy.spec import TenantSpec


def fair_share_windows(concurrency: int,
                       weights: list[float]) -> list[int]:
    """Apportion the fleet admission window across tenants by weight.

    Largest-remainder apportionment with a floor of 1: the windows sum
    to exactly ``concurrency`` (so the multi-tenant fleet never admits
    more concurrent work than a single-tenant run could — independent
    rounding would oversubscribe), except when there are more tenants
    than window slots, where every tenant still gets its minimum of 1.
    """
    total_w = sum(weights)
    if total_w <= 0:
        raise ValueError(f"weights must sum > 0, got {weights}")
    quotas = [concurrency * w / total_w for w in weights]
    out = [int(q) for q in quotas]
    remainders = [q - b for q, b in zip(quotas, out)]
    # hand out the leftover slots by largest remainder (ties: lower idx)
    for i in sorted(range(len(out)),
                    key=lambda i: (-remainders[i], i)):
        if sum(out) >= concurrency:
            break
        out[i] += 1
    # floor of 1: steal from the largest window (never below 1)
    for i, w in enumerate(out):
        if w < 1:
            donor = max(range(len(out)),
                        key=lambda j: (out[j], -j))
            if out[donor] > 1:
                out[donor] -= 1
            out[i] = 1
    return out


def tenant_seed(spec: TenantSpec, base_seed: int) -> int:
    """A tenant's derived seed, keyed by its *name*, never its position
    in the tenant list — so a tenant's dataset, trace and arrival
    randomness are identical whether it runs solo or shared (the
    property interference ratios depend on)."""
    if spec.seed is not None:
        return spec.seed
    return base_seed + (zlib.crc32(spec.name.encode()) & 0xFFFF)


@dataclasses.dataclass
class Tenant:
    """A materialised tenant: spec + built index + query stream.

    A tenant whose run applies updates is *consumed* by that run (its
    index is mutated); use a fresh materialisation per run —
    :func:`measure_interference` takes a factory for exactly this
    reason.
    """

    spec: TenantSpec
    index: object
    queries: np.ndarray
    params: SearchParams
    data: np.ndarray | None = None
    updates: object | None = None
    ingest_cfg: object | None = None
    query_ids: list[int] | None = None


def materialize_tenant(spec: TenantSpec, base_seed: int = 0,
                       tid: int = 0) -> Tenant:
    """Build one tenant's synthetic corpus, index and update stream.

    ``tid`` is accepted for call-site symmetry but deliberately does
    not enter the seed: a tenant's corpus must not depend on where it
    sits in the tenant list (see :func:`tenant_seed`)."""
    seed = tenant_seed(spec, base_seed)
    ds = DatasetSpec(f"tenant-{spec.name}", spec.dim, "float32", spec.n,
                     spec.n_queries,
                     n_clusters=max(8, min(64, spec.n // 16)),
                     intrinsic_dim=min(32, spec.dim), seed=seed)
    data, queries = make_dataset(ds)
    if spec.index == "cluster":
        index = ClusterIndex.build(data, ClusterIndexParams(
            kmeans_iters=4, seed=seed))
        params = SearchParams(k=spec.k, nprobe=spec.nprobe)
    else:
        from repro.core.pq import default_pq_dims
        index = GraphIndex.build(data, GraphIndexParams(
            R=24, L_build=48, build_passes=1,
            pq_dims=default_pq_dims(spec.dim), seed=seed))
        params = SearchParams(k=spec.k, search_len=spec.search_len,
                              beamwidth=spec.beamwidth)
    scenario = spec.scenario_obj()
    updates = None
    ingest_cfg = None
    if scenario.kind == "rw" and scenario.write_rate_qps > 0:
        from repro.ingest.compaction import IngestConfig
        protected = frozenset([index.meta.medoid]) \
            if spec.index == "graph" else None
        updates = scenario.make_updates(data, seed=seed,
                                        protected=protected)
        ingest_cfg = IngestConfig(
            delta_cap_bytes=int(spec.delta_kb * 1024),
            flush_frac=spec.flush_frac,
            compaction_parallelism=spec.compaction_par)
    return Tenant(spec=spec, index=index, queries=queries, params=params,
                  data=data, updates=updates, ingest_cfg=ingest_cfg)


class MultiTenantRouter(FleetRouter):
    """The N-context fleet run (shares every mechanism with the
    single-tenant :class:`FleetRouter` — scatter/gather, po2c, hedging,
    backpressure, faults, autoscaling — via the tenant contexts)."""

    def __init__(self, tenants: list[Tenant], cfg: FleetConfig,
                 cache_policy: str = "shared",
                 policy_kwargs: dict | None = None,
                 quota_weights: dict[int, float] | None = None):
        """``quota_weights`` overrides the cache-quota weighting only
        (tid -> weight; default: the tenants' spec weights) — the hook
        :func:`repro.tuning.tenancy.tune_cache_split` evaluates
        candidate splits through, leaving admission fair shares alone."""
        if not tenants:
            raise ValueError("need at least one tenant")
        names = [t.spec.name for t in tenants]
        if len(set(names)) != len(names):
            # duplicate names would alias the name-keyed seeds and RNG
            # streams (and slice lookup), silently coupling "two" tenants
            raise ValueError(f"duplicate tenant names: {names}")
        if cache_policy not in TENANT_CACHE_POLICIES:
            raise ValueError(
                f"unknown cache policy {cache_policy!r}; one of "
                f"{TENANT_CACHE_POLICIES}")
        self.tenants = tenants
        self.cfg = cfg
        self.cache_policy = cache_policy
        weights = quota_weights if quota_weights is not None else \
            {tid: t.spec.weight for tid, t in enumerate(tenants)}
        kw = policy_kwargs or {}
        self._cache_factory = (
            lambda: make_tenant_cache(cache_policy, cfg.cache_bytes,
                                      weights, **kw))
        self.partitions = [
            partition_for_index(t.index, cfg.n_shards, cfg.replication,
                                seed=cfg.seed)
            for t in tenants]

    def run_tenants(self, *, faults=None, autoscale=None,
                    series_dt: float | None = None,
                    tracer=None, monitor=None,
                    pricebook=None, explain=False,
                    mrc=False) -> MultiTenantReport:
        cfg = self.cfg
        windows = fair_share_windows(
            cfg.concurrency, [t.spec.weight for t in self.tenants])
        ctxs: list[_TenantCtx] = []
        for tid, t in enumerate(self.tenants):
            window = windows[tid]
            # arrival randomness is keyed by tenant *name* (seed for
            # trace construction, kernel stream for poisson/burst), so
            # a tenant's arrival sample is identical solo vs shared —
            # closed-loop arrivals use neither, which is what keeps the
            # single-tenant run on the golden-parity chain
            arr = t.spec.scenario_obj().make_arrivals(
                len(t.queries), window,
                seed=tenant_seed(t.spec, cfg.seed))
            arr.rng_stream = f"arrivals.{t.spec.name}"
            qids = list(t.query_ids) if t.query_ids is not None \
                else list(range(len(t.queries)))
            ctxs.append(_TenantCtx(
                tid, t.index, self.partitions[tid], t.queries, t.params,
                qids, arr, arr.window if arr.window is not None else window,
                slo_s=t.spec.slo_s, weight=t.spec.weight,
                name=t.spec.name, updates=t.updates,
                ingest_cfg=t.ingest_cfg))
        wall = self._execute(ctxs, faults=faults, autoscale=autoscale,
                             series_dt=series_dt, tracer=tracer,
                             monitor=monitor, pricebook=pricebook,
                             explain=explain, mrc=mrc)
        return self._build_report(ctxs, wall, faults)

    # ------------------------------------------------------------ report --
    def _cache_assemblies(self) -> list[TenantCacheBase]:
        out = []
        for g in self.groups:
            for srv in g.all_servers():
                if isinstance(srv.engine.cache, TenantCacheBase):
                    out.append(srv.engine.cache)
        return out

    def _build_report(self, ctxs, wall: float, faults) -> MultiTenantReport:
        from repro.fleet.metrics import FleetReport
        cfg = self.cfg
        stats = [srv.finalize_stats() for g in self.groups
                 for srv in g.all_servers()]
        shards_seconds = sum(srv.active_seconds(wall) for g in self.groups
                             for srv in g.all_servers())
        assemblies = self._cache_assemblies()
        slices = []
        for ctx in ctxs:
            used = sum(a.tenant_used_bytes(ctx.tid) for a in assemblies)
            quotas = [a.tenant_quota_bytes(ctx.tid) for a in assemblies]
            quota = sum(q for q in quotas if q is not None) \
                if any(q is not None for q in quotas) else None
            ingest_dict = None
            if ctx.ingest_report is not None:
                ingest_dict = ctx.ingest_report.to_dict(ctx.records)
            slices.append(TenantSlice(
                name=ctx.name, tid=ctx.tid, records=ctx.records,
                n_arrivals=ctx.adm.arrivals_total,
                offered_qps=ctx.adm.offered_qps(wall),
                slo_s=ctx.slo_s, good_total=ctx.good_total,
                wall_time_s=wall, cache_bytes_used=used,
                cache_quota_bytes=quota, weight=ctx.weight,
                window=ctx.window, ingest=ingest_dict))
        all_records = [r for ctx in ctxs for r in ctx.records]
        fleet = FleetReport(
            records=all_records, shard_stats=stats, wall_time_s=wall,
            n_shards=cfg.n_shards, replication=cfg.replication,
            concurrency=cfg.concurrency, jobs_total=self._jobs_total,
            hedges_launched=self._hedges, hedge_wins=self._hedge_wins,
            sheds_total=sum(s.sheds for s in stats),
            submissions_total=sum(s.submissions for s in stats),
            scenario="multi-tenant",
            n_arrivals=sum(c.adm.arrivals_total for c in ctxs),
            offered_qps=sum(c.adm.offered_qps(wall) for c in ctxs),
            series=self._series, shards_seconds=shards_seconds,
            scale_events=(self._autoscaler.events
                          if self._autoscaler is not None else None),
            fault_log=self._fault_log if faults is not None else None)
        self.attach_obs(fleet)
        showback = None
        if self._pricebook is not None:
            from repro.obs.cost import tenant_showback
            showback = tenant_showback(slices, fleet, cfg,
                                       self._pricebook)
            for sl, row in zip(slices, showback["rows"]):
                sl.cost = row
        reallocs = sum(getattr(a, "reallocations", 0) for a in assemblies)
        return MultiTenantReport(tenants=slices, fleet=fleet,
                                 cache_policy=self.cache_policy,
                                 reallocations=reallocs,
                                 showback=showback)


def run_tenant_fleet(tenants: list[Tenant] | list[TenantSpec],
                     cfg: FleetConfig, cache_policy: str = "shared", *,
                     faults=None, autoscale=None,
                     series_dt: float | None = None,
                     policy_kwargs: dict | None = None,
                     quota_weights: dict[int, float] | None = None,
                     tracer=None, monitor=None,
                     pricebook=None, explain=False,
                     mrc=False) -> MultiTenantReport:
    """One-call multi-tenant evaluation (the tenancy analogue of
    :func:`repro.fleet.run_fleet`).  Accepts either materialised
    :class:`Tenant` s or bare :class:`TenantSpec` s (materialised with
    the fleet seed)."""
    mats = [t if isinstance(t, Tenant)
            else materialize_tenant(t, base_seed=cfg.seed, tid=i)
            for i, t in enumerate(tenants)]
    router = MultiTenantRouter(mats, cfg, cache_policy,
                               policy_kwargs=policy_kwargs,
                               quota_weights=quota_weights)
    return router.run_tenants(faults=faults, autoscale=autoscale,
                              series_dt=series_dt, tracer=tracer,
                              monitor=monitor, pricebook=pricebook,
                              explain=explain, mrc=mrc)


def measure_interference(make_tenants: Callable[[], list[Tenant]],
                         cfg: FleetConfig, cache_policy: str = "shared",
                         *, policy_kwargs: dict | None = None,
                         series_dt: float | None = None,
                         tracer=None, monitor=None,
                         pricebook=None, explain=False,
                         mrc=False) -> MultiTenantReport:
    """Run the shared fleet, then each tenant **solo** on an identical
    fleet, and attach the solo p99 sojourns so every slice reports its
    interference ratio (p99 shared / p99 solo).  ``make_tenants`` is a
    factory because a run with updates consumes its tenants.  Name-keyed
    arrival seeding guarantees the solo run replays the tenant's exact
    shared-run arrival sample, so the ratio measures contention, not
    seed noise."""
    # only the shared run is traced (and monitored/priced): solo reruns
    # are per-tenant controls
    shared = run_tenant_fleet(make_tenants(), cfg, cache_policy,
                              policy_kwargs=policy_kwargs,
                              series_dt=series_dt, tracer=tracer,
                              monitor=monitor, pricebook=pricebook,
                              explain=explain, mrc=mrc)
    fresh = make_tenants()
    for i, sl in enumerate(shared.tenants):
        solo = run_tenant_fleet([fresh[i]], cfg, cache_policy,
                                policy_kwargs=policy_kwargs)
        sl.solo_p99_s = solo.tenants[0].sojourn_percentile(99)
    return shared
