"""Cache-sharing policies: how one shard instance's byte budget is split
across tenants.

The paper's final study shows cache behaviour dominates cloud-native
search economics; a provider amortises one cache fleet across many
tenants, so the *sharing policy* decides who actually receives those
gains.  Three first-class strategies, all built from the same
:class:`repro.cache.slru.SLRUCache` primitive and all speaking the
engine's cache protocol (``get``/``put``/``remove``/``invalidate``),
keyed by tenant-namespaced fetch keys ``(tid, *native_key)``:

* **shared** — one fleet-wide SLRU per instance; tenants compete freely.
  Best aggregate hit rate when working sets are complementary, worst
  isolation: a scan-heavy tenant evicts everyone (the same failure mode
  §5.1's scan-resistance defends against, now across tenants).  A
  single-tenant ``shared`` assembly degenerates to the plain SLRU —
  that degeneracy is what extends the golden-parity chain.
* **static** — hard byte partitions, one SLRU per tenant sized
  ``total × weight_t / Σ weights``.  Perfect isolation (tenant hit
  rates are independent by construction) at the price of stranded
  bytes: an idle tenant's partition helps nobody.
* **weighted** — static quotas plus **ghost-list-driven adaptive
  reallocation**: each tenant tracks the keys it recently evicted
  (a ghost list holds metadata only — no payload bytes); a miss that
  hits the ghost list means "this tenant would have hit with more
  quota".  Every ``realloc_every`` lookups the policy moves one
  ``step_frac`` slice of the total from the lowest-pressure tenant to
  the highest-pressure one, floored at ``min_frac`` of each tenant's
  weighted fair share so a bursty neighbour can never starve a steady
  tenant below a documented bound.  Each ghost list is byte-bounded to
  ``ghost_frac ×`` the tenant's *current quota* (the ARC shadow-cache
  rule): a tenant whose working set is slightly bigger than its quota
  re-references its ghosts before they age out (high marginal utility
  of more bytes), while a scan tenant's ghosts churn through unseen —
  raw miss volume alone earns no quota.

Quota invariant (property-tested): Σ per-tenant capacities == total at
all times, and no tenant's SLRU ever holds more bytes than its quota.
"""
from __future__ import annotations

from collections import OrderedDict

from repro.cache.slru import SLRUCache

TENANT_CACHE_POLICIES = ("shared", "static", "weighted")

#: adaptive-reallocation defaults (weighted policy)
REALLOC_EVERY = 256          # lookups between reallocation decisions
REALLOC_STEP_FRAC = 0.05     # slice of the total budget moved per step
MIN_QUOTA_FRAC = 0.5         # floor: fraction of weighted fair share
GHOST_FRAC = 1.0             # ghost-list byte bound vs current quota


def _normalized_weights(weights: dict[int, float]) -> dict[int, float]:
    total = sum(weights.values())
    if total <= 0:
        raise ValueError(f"tenant weights must sum > 0, got {weights}")
    return {tid: w / total for tid, w in weights.items()}


class TenantCacheBase:
    """Engine-facing protocol shared by the three assemblies."""

    policy = "base"

    def get(self, key) -> bool:
        raise NotImplementedError

    def put(self, key, nbytes: int) -> None:
        raise NotImplementedError

    def remove(self, key) -> int:
        raise NotImplementedError

    def invalidate(self, key) -> bool:
        return self.remove(key) > 0

    # ------------------------------------------------------ introspection --
    @property
    def used_bytes(self) -> int:
        raise NotImplementedError

    def tenant_used_bytes(self, tid: int) -> int:
        raise NotImplementedError

    def tenant_quota_bytes(self, tid: int) -> int | None:
        """Current byte quota for ``tid`` (None: no per-tenant bound)."""
        return None

    def set_observer(self, observer) -> None:
        """Attach a read-only access-stream observer (the sampled-ghost
        MRC estimator, :mod:`repro.obs.mrc`) to every underlying SLRU.
        Observers see the tenant-namespaced key stream exactly as the
        segments do; they never mutate cache state."""
        inner = getattr(self, "inner", None)
        if inner is not None:
            inner.observer = observer
        for part in getattr(self, "parts", {}).values():
            part.observer = observer


class SharedTenantCache(TenantCacheBase):
    """One fleet-wide SLRU; tenant keys compete in the same segments."""

    policy = "shared"

    def __init__(self, capacity_bytes: int, weights: dict[int, float]):
        self.inner = SLRUCache(capacity_bytes)
        self.tenants = tuple(sorted(weights))

    def get(self, key) -> bool:
        return self.inner.get(key)

    def put(self, key, nbytes: int) -> None:
        self.inner.put(key, nbytes)

    def remove(self, key) -> int:
        return self.inner.remove(key)

    def invalidate(self, key) -> bool:
        return self.inner.invalidate(key)

    @property
    def used_bytes(self) -> int:
        return self.inner.used_bytes

    @property
    def hit_rate(self) -> float:
        return self.inner.hit_rate

    def tenant_used_bytes(self, tid: int) -> int:
        return (sum(s for k, s in self.inner.probation.items()
                    if k[0] == tid)
                + sum(s for k, s in self.inner.protected.items()
                      if k[0] == tid))


class StaticTenantCache(TenantCacheBase):
    """Hard byte partitions: one SLRU per tenant, no trespassing."""

    policy = "static"

    def __init__(self, capacity_bytes: int, weights: dict[int, float]):
        shares = _normalized_weights(weights)
        self.parts: dict[int, SLRUCache] = {}
        remaining = int(capacity_bytes)
        order = sorted(shares)
        for i, tid in enumerate(order):
            quota = remaining if i == len(order) - 1 else \
                int(capacity_bytes * shares[tid])
            self.parts[tid] = SLRUCache(quota)
            remaining -= quota

    def _part(self, key) -> SLRUCache:
        return self.parts[key[0]]

    def get(self, key) -> bool:
        return self._part(key).get(key)

    def put(self, key, nbytes: int) -> None:
        self._part(key).put(key, nbytes)

    def remove(self, key) -> int:
        return self._part(key).remove(key)

    def invalidate(self, key) -> bool:
        return self._part(key).invalidate(key)

    @property
    def used_bytes(self) -> int:
        return sum(p.used_bytes for p in self.parts.values())

    @property
    def hit_rate(self) -> float:
        hits = sum(p.hits for p in self.parts.values())
        total = hits + sum(p.misses for p in self.parts.values())
        return hits / total if total else 0.0

    def tenant_used_bytes(self, tid: int) -> int:
        return self.parts[tid].used_bytes

    def tenant_quota_bytes(self, tid: int) -> int:
        return self.parts[tid].capacity


class WeightedTenantCache(StaticTenantCache):
    """Weighted quotas with ghost-list-driven adaptive reallocation.

    The ghost list is the classic second-chance structure (ARC/2Q
    lineage): per-tenant metadata of recently evicted keys.  A miss
    found in the ghost list is *reclaimable* — evidence the tenant's
    quota is the binding constraint rather than its working set.  The
    reallocation loop compares ghost pressure across tenants and moves
    quota from the least- to the most-pressured, bounded below by
    ``min_frac × fair_share`` so isolation survives adaptation.
    """

    policy = "weighted"

    def __init__(self, capacity_bytes: int, weights: dict[int, float], *,
                 realloc_every: int = REALLOC_EVERY,
                 step_frac: float = REALLOC_STEP_FRAC,
                 min_frac: float = MIN_QUOTA_FRAC,
                 ghost_frac: float = GHOST_FRAC):
        super().__init__(capacity_bytes, weights)
        if not 0.0 < step_frac < 1.0:
            raise ValueError(f"step_frac must be in (0, 1), got {step_frac}")
        if not 0.0 <= min_frac <= 1.0:
            raise ValueError(f"min_frac must be in [0, 1], got {min_frac}")
        if ghost_frac <= 0.0:
            raise ValueError(f"ghost_frac must be > 0, got {ghost_frac}")
        self.total = int(capacity_bytes)
        shares = _normalized_weights(weights)
        self.floors = {tid: int(min_frac * capacity_bytes * shares[tid])
                       for tid in shares}
        self.realloc_every = int(realloc_every)
        self.step_bytes = max(1, int(step_frac * capacity_bytes))
        self.ghost_frac = float(ghost_frac)
        self.ghosts: dict[int, OrderedDict] = {
            tid: OrderedDict() for tid in shares}
        self.ghost_bytes = {tid: 0 for tid in shares}
        self.ghost_hits = {tid: 0 for tid in shares}   # epoch counters
        self.epoch_lookups = {tid: 0 for tid in shares}
        self.reallocations = 0
        self._lookups = 0
        for tid, part in self.parts.items():
            part.on_evict = (lambda key, nbytes, tid=tid:
                             self._note_evict(tid, key, nbytes))

    # ------------------------------------------------------- ghost lists --
    def _ghost_pop(self, tid: int, key) -> bool:
        nbytes = self.ghosts[tid].pop(key, None)
        if nbytes is None:
            return False
        self.ghost_bytes[tid] -= nbytes
        return True

    def _trim_ghost(self, tid: int) -> None:
        g = self.ghosts[tid]
        cap = int(self.ghost_frac * self.parts[tid].capacity)
        while self.ghost_bytes[tid] > cap and g:
            _, s = g.popitem(last=False)
            self.ghost_bytes[tid] -= s

    def _note_evict(self, tid: int, key, nbytes: int) -> None:
        self._ghost_pop(tid, key)
        self.ghosts[tid][key] = nbytes
        self.ghost_bytes[tid] += nbytes
        self._trim_ghost(tid)

    def get(self, key) -> bool:
        tid = key[0]
        hit = self.parts[tid].get(key)
        if not hit and self._ghost_pop(tid, key):
            self.ghost_hits[tid] += 1
        self.epoch_lookups[tid] += 1
        self._lookups += 1
        if self._lookups % self.realloc_every == 0:
            self._reallocate()
        return hit

    def put(self, key, nbytes: int) -> None:
        self._ghost_pop(key[0], key)
        self.parts[key[0]].put(key, nbytes)

    def remove(self, key) -> int:
        # a rewritten object's ghost must die with its cached copy —
        # its old content hitting the ghost list is not quota pressure
        self._ghost_pop(key[0], key)
        return self.parts[key[0]].remove(key)

    def invalidate(self, key) -> bool:
        self._ghost_pop(key[0], key)
        return self.parts[key[0]].invalidate(key)

    # ------------------------------------------------------ reallocation --
    def _pressure(self, tid: int) -> float:
        """Reclaimable-miss *rate*: ghost hits per lookup this epoch.
        Normalising by the tenant's own lookup volume keeps a
        high-fan-out scanner (many lookups per query) from out-shouting
        a low-fan-out tenant whose every miss is reclaimable."""
        return self.ghost_hits[tid] / max(1, self.epoch_lookups[tid])

    def _reallocate(self) -> None:
        """Move one quota slice from the least- to the most-pressured
        tenant (ghost-hit rate this epoch; deterministic tid
        tie-break)."""
        if len(self.parts) < 2:
            self._reset_epoch()
            return
        order = sorted(self.parts)
        recipient = max(order, key=lambda t: (self._pressure(t), -t))
        donors = [t for t in order
                  if t != recipient
                  and self.parts[t].capacity - self.step_bytes
                  >= self.floors[t]]
        if donors and self.ghost_hits[recipient] > 0:
            donor = min(donors, key=lambda t: (self._pressure(t), t))
            if self._pressure(donor) < self._pressure(recipient):
                self.parts[donor].set_capacity(
                    self.parts[donor].capacity - self.step_bytes)
                self.parts[recipient].set_capacity(
                    self.parts[recipient].capacity + self.step_bytes)
                self._trim_ghost(donor)      # shadow shrinks with quota
                self.reallocations += 1
        self._reset_epoch()

    def _reset_epoch(self) -> None:
        for tid in self.ghost_hits:
            self.ghost_hits[tid] = 0
            self.epoch_lookups[tid] = 0


def make_tenant_cache(policy: str, capacity_bytes: int,
                      weights: dict[int, float], **kwargs):
    """Build one instance's cache assembly (None when no budget)."""
    if policy not in TENANT_CACHE_POLICIES:
        raise ValueError(
            f"unknown tenant cache policy {policy!r}; one of "
            f"{TENANT_CACHE_POLICIES}")
    if capacity_bytes <= 0:
        return None
    cls = {"shared": SharedTenantCache, "static": StaticTenantCache,
           "weighted": WeightedTenantCache}[policy]
    return cls(capacity_bytes, weights, **kwargs)
