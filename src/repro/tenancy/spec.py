"""Declarative tenant workloads (the ``--tenants spec.json`` schema).

A :class:`TenantSpec` is one tenant's :class:`~repro.tuning.space.
WorkloadSpec`-style contract with the shared fleet: dataset scale and
index kind (its own corpus and sealed index), arrival process (any
:mod:`repro.sim.arrivals` scenario kind), write rate (its own update
stream + compaction schedule), recall/latency SLO, and a *weight* — its
share of the fleet's admission window and cache budget under the
``static``/``weighted`` sharing policies.
"""
from __future__ import annotations

import dataclasses
import json

from repro.sim.arrivals import ARRIVAL_KINDS, Scenario

INDEX_KINDS = ("cluster", "graph")


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's workload contract (all fields JSON-serialisable)."""

    name: str
    # dataset / index
    n: int = 2000
    dim: int = 64
    index: str = "cluster"             # "cluster" | "graph"
    n_queries: int = 64
    k: int = 10
    nprobe: int = 16                   # cluster search knob
    search_len: int = 40               # graph search knobs
    beamwidth: int = 8
    # arrival process (repro.sim.arrivals Scenario axes)
    scenario: str = "closed"
    rate_qps: float = 200.0
    duration_s: float | None = None
    n_arrivals: int | None = None
    burst_factor: float = 4.0
    burst_start_s: float = 0.25
    burst_len_s: float = 0.25
    zipf_a: float = 1.2
    # write path
    write_rate_qps: float = 0.0
    n_updates: int | None = None
    delete_frac: float = 0.2
    delta_kb: float = 256.0            # memtable capacity per site
    flush_frac: float = 0.5            # flush trigger (fraction of cap)
    compaction_par: int = 1            # concurrent compaction jobs/site
    # SLOs + fair share
    slo_ms: float = 50.0
    target_recall: float = 0.9
    weight: float = 1.0
    seed: int | None = None            # dataset/build seed (None: derived)

    def __post_init__(self):
        if not self.name:
            raise ValueError("tenant needs a name")
        if self.index not in INDEX_KINDS:
            raise ValueError(f"tenant {self.name!r}: index must be one of "
                             f"{INDEX_KINDS}, got {self.index!r}")
        if self.scenario not in ARRIVAL_KINDS:
            raise ValueError(
                f"tenant {self.name!r}: scenario must be one of "
                f"{ARRIVAL_KINDS}, got {self.scenario!r}")
        if self.n < 8:
            raise ValueError(f"tenant {self.name!r}: n must be >= 8")
        if self.weight <= 0:
            raise ValueError(f"tenant {self.name!r}: weight must be > 0, "
                             f"got {self.weight}")
        if self.slo_ms <= 0:
            raise ValueError(f"tenant {self.name!r}: slo_ms must be > 0")

    @property
    def slo_s(self) -> float:
        return self.slo_ms * 1e-3

    def scenario_obj(self) -> Scenario:
        """This tenant's arrival scenario (reuses the fleet-wide axis)."""
        return Scenario(
            kind=self.scenario, rate_qps=self.rate_qps,
            duration_s=self.duration_s, n_arrivals=self.n_arrivals,
            burst_factor=self.burst_factor,
            burst_start_s=self.burst_start_s, burst_len_s=self.burst_len_s,
            zipf_a=self.zipf_a, slo_s=self.slo_s,
            write_rate_qps=self.write_rate_qps, n_updates=self.n_updates,
            delete_frac=self.delete_frac)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TenantSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown tenant-spec fields {sorted(unknown)} "
                             f"(known: {sorted(known)})")
        return cls(**d)


def load_tenant_specs(path: str) -> list[TenantSpec]:
    """Parse a ``--tenants`` JSON file: a list of tenant objects (or
    ``{"tenants": [...]}``)."""
    with open(path) as f:
        payload = json.load(f)
    if isinstance(payload, dict):
        payload = payload.get("tenants", payload)
    if not isinstance(payload, list) or not payload:
        raise ValueError(f"{path}: expected a non-empty list of tenant "
                         f"objects")
    specs = [TenantSpec.from_dict(d) for d in payload]
    names = [s.name for s in specs]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate tenant names in {path}: {names}")
    return specs
