"""Per-tenant report slices over one shared fleet run.

The fleet-level :class:`repro.fleet.metrics.FleetReport` answers "how did
the hardware do"; a provider also owes each tenant an answer to "how did
*my* traffic do".  A :class:`TenantSlice` carries the per-tenant cut:
hit rate (from the tenant's own query metrics), p50/p99 latency and
sojourn, goodput against the tenant's SLO, bytes of shared cache its
objects occupy, and — when a solo baseline is attached — *interference*:
p99 shared over p99 solo, the number the isolation policies exist to
bound.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.fleet.metrics import FleetQueryRecord, FleetReport


def _pct(vals: list[float], p: float) -> float:
    return float(np.percentile(vals, p)) if vals else 0.0


@dataclasses.dataclass
class TenantSlice:
    """One tenant's view of a shared fleet run."""

    name: str
    tid: int
    records: list[FleetQueryRecord]
    n_arrivals: int
    offered_qps: float
    slo_s: float | None
    good_total: int
    wall_time_s: float
    cache_bytes_used: int          # Σ over instances at run end
    cache_quota_bytes: int | None  # Σ per-instance quota (partitioned)
    weight: float
    window: int                    # admission fair share
    solo_p99_s: float | None = None    # attached by interference probes
    ingest: dict | None = None
    cost: dict | None = None       # this tenant's show-back row ($)

    # ------------------------------------------------------------ stats --
    @property
    def qps(self) -> float:
        return len(self.records) / max(self.wall_time_s, 1e-12)

    def latency_percentile(self, p: float) -> float:
        return _pct([r.latency for r in self.records], p)

    def sojourn_percentile(self, p: float) -> float:
        return _pct([r.sojourn for r in self.records], p)

    @property
    def hit_rate(self) -> float:
        hits = sum(r.metrics.cache_hits for r in self.records)
        lookups = sum(r.metrics.cache_lookups for r in self.records)
        return hits / lookups if lookups else 0.0

    @property
    def bytes_read(self) -> int:
        return sum(r.metrics.bytes_storage for r in self.records)

    @property
    def goodput_qps(self) -> float:
        if self.slo_s is None:
            return self.qps
        return self.good_total / max(self.wall_time_s, 1e-12)

    @property
    def goodput_frac(self) -> float:
        if self.slo_s is None or not self.n_arrivals:
            return 1.0
        return self.good_total / self.n_arrivals

    @property
    def interference_ratio(self) -> float | None:
        """p99 sojourn shared / p99 sojourn solo (1.0 = no interference;
        None until a solo baseline is attached)."""
        if self.solo_p99_s is None or self.solo_p99_s <= 0:
            return None
        return self.sojourn_percentile(99) / self.solo_p99_s

    @property
    def shed_retries(self) -> int:
        return sum(r.shed_retries for r in self.records)

    def recall_against(self, gt_ids: np.ndarray) -> float:
        from repro.core.types import recall_at_k
        recs = [recall_at_k(r.ids[r.ids >= 0], gt_ids[r.qid])
                for r in self.records]
        return float(np.mean(recs)) if recs else 0.0

    def to_dict(self) -> dict:
        out = dict(
            name=self.name, tid=self.tid, weight=self.weight,
            window=self.window,
            n_queries=len(self.records), n_arrivals=self.n_arrivals,
            offered_qps=round(self.offered_qps, 4),
            qps=round(self.qps, 4),
            p50_latency_s=round(self.latency_percentile(50), 9),
            p99_latency_s=round(self.latency_percentile(99), 9),
            p50_sojourn_s=round(self.sojourn_percentile(50), 9),
            p99_sojourn_s=round(self.sojourn_percentile(99), 9),
            hit_rate=round(self.hit_rate, 4),
            bytes_read=self.bytes_read,
            cache_bytes_used=self.cache_bytes_used,
            shed_retries=self.shed_retries)
        if self.cache_quota_bytes is not None:
            out["cache_quota_bytes"] = self.cache_quota_bytes
        if self.slo_s is not None:
            out.update(slo_s=self.slo_s,
                       goodput_qps=round(self.goodput_qps, 4),
                       goodput_frac=round(self.goodput_frac, 4))
        if self.solo_p99_s is not None and \
                self.interference_ratio is not None:
            out.update(
                solo_p99_sojourn_s=round(self.solo_p99_s, 9),
                interference_ratio=round(self.interference_ratio, 4))
        if self.ingest is not None:
            out["ingest"] = self.ingest
        if self.cost is not None:
            out["cost"] = self.cost
        return out


@dataclasses.dataclass
class MultiTenantReport:
    """N tenant slices plus the fleet-level aggregate they share."""

    tenants: list[TenantSlice]
    fleet: FleetReport             # aggregate (all records, shard stats)
    cache_policy: str
    reallocations: int = 0         # weighted-policy quota moves (Σ inst.)
    showback: dict | None = None   # per-tenant $ table (repro.obs.cost)

    def tenant(self, name: str) -> TenantSlice:
        for t in self.tenants:
            if t.name == name:
                return t
        raise KeyError(f"no tenant named {name!r}; have "
                       f"{[t.name for t in self.tenants]}")

    @property
    def aggregate_goodput_qps(self) -> float:
        """Σ per-tenant goodput — the provider's sellable throughput."""
        return sum(t.goodput_qps for t in self.tenants)

    @property
    def aggregate_goodput_frac(self) -> float:
        good = sum(t.good_total for t in self.tenants
                   if t.slo_s is not None)
        arr = sum(t.n_arrivals for t in self.tenants
                  if t.slo_s is not None)
        return good / arr if arr else 1.0

    def summary(self) -> dict:
        out = dict(
            cache_policy=self.cache_policy,
            n_tenants=len(self.tenants),
            aggregate_goodput_qps=round(self.aggregate_goodput_qps, 4),
            aggregate_goodput_frac=round(self.aggregate_goodput_frac, 4),
            tenants=[t.to_dict() for t in self.tenants],
            fleet=self.fleet.summary())
        if self.cache_policy == "weighted":
            out["reallocations"] = self.reallocations
        if self.showback is not None:
            out["showback"] = self.showback
        return out

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.summary(), indent=indent)
