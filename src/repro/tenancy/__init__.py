"""``repro.tenancy`` — multi-tenant fleets with shared shard caches.

The paper's last study shows cache behaviour dominates cloud-native
search economics (§7); a provider amortises one cache fleet across many
tenants, so *who gets the cache* becomes the deciding policy question.
This subsystem serves N tenant workloads — each with its own corpus,
index kind, arrival process, write rate and SLO — over one shard fleet:

* ``spec`` — :class:`TenantSpec`, the ``--tenants spec.json`` schema;
* ``policy`` — cache-sharing strategies per instance: fully ``shared``
  SLRU, ``static`` per-tenant byte partitions, ``weighted`` quotas with
  ghost-list-driven adaptive reallocation;
* ``fleet`` — :class:`MultiTenantRouter` /
  :func:`run_tenant_fleet` (tenant contexts over the shared
  scatter-gather router) and :func:`measure_interference` (p99 solo vs
  shared);
* ``metrics`` — per-tenant report slices + the fleet aggregate.

CLI: ``python -m repro.fleet --tenants spec.json --cache-policy
weighted``.  A single closed-loop tenant under ``shared`` reproduces
the plain fleet reports bit-exactly (golden-parity chain); stochastic
arrival kinds draw from tenant-named RNG streams, so their tenancy
runs are deterministic but not sample-identical to the plain path.
"""
from repro.tenancy.fleet import (MultiTenantRouter, Tenant,
                                 fair_share_windows, materialize_tenant,
                                 measure_interference, run_tenant_fleet)
from repro.tenancy.metrics import MultiTenantReport, TenantSlice
from repro.tenancy.policy import (TENANT_CACHE_POLICIES, SharedTenantCache,
                                  StaticTenantCache, WeightedTenantCache,
                                  make_tenant_cache)
from repro.tenancy.spec import TenantSpec, load_tenant_specs

__all__ = [
    "TenantSpec", "load_tenant_specs",
    "TENANT_CACHE_POLICIES", "make_tenant_cache",
    "SharedTenantCache", "StaticTenantCache", "WeightedTenantCache",
    "Tenant", "materialize_tenant", "fair_share_windows",
    "MultiTenantRouter", "run_tenant_fleet", "measure_interference",
    "TenantSlice", "MultiTenantReport",
]
