"""Exact brute-force search — ground-truth oracle for recall measurement."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.distances import pairwise_sq_l2, topk_smallest


def exact_topk(
    x: np.ndarray, queries: np.ndarray, k: int, chunk: int = 512
) -> tuple[np.ndarray, np.ndarray]:
    """Exact k-NN.  Returns (ids (Q, k) int64, dists (Q, k) f32)."""
    xs = jnp.asarray(x)
    out_ids = []
    out_d = []
    for s in range(0, len(queries), chunk):
        qs = jnp.asarray(queries[s:s + chunk])
        d = pairwise_sq_l2(qs, xs)
        vals, idx = topk_smallest(d, k)
        out_ids.append(np.asarray(idx, dtype=np.int64))
        out_d.append(np.asarray(vals, dtype=np.float32))
    return np.concatenate(out_ids), np.concatenate(out_d)
