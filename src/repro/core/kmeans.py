"""K-means machinery: Lloyd iterations, soft-balanced assignment, and the
hierarchical balanced clustering that SPANN uses to partition the dataset
(the hierarchy doubles as the BKT centroid tree held in compute-node memory,
paper §2.3.1).

Build runs host-side (numpy): index construction is an offline job in the
paper too (built on local disk, then uploaded).  The *query-time* centroid
search has two implementations:

* ``BKTree.search`` — best-first tree descent, the paper's in-memory BKT
  (O(n log nprobe) scaling, §2.3.1).  Pointer-chasing: host metadata path.
* flat top-nprobe matmul over all centroids — the TPU/MXU-native equivalent
  used on the device serving path (see DESIGN.md §2: BKT pointer-chasing
  does not transfer to TPU; a flat fused distance+top-k does).

Batched Lloyd (``kmeans_batched``) is jax/vmap-based and is used for PQ
codebook training where all subproblems share one shape.
"""
from __future__ import annotations

import dataclasses
import heapq

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distances import np_sq_l2


# ---------------------------------------------------------------------------
# numpy Lloyd with balanced assignment (host-side build path)
# ---------------------------------------------------------------------------

def _enforce_capacity(d: np.ndarray, assign: np.ndarray, k: int,
                      cap: int) -> np.ndarray:
    """Greedy capacity repair: overfull clusters evict their farthest
    members to the members' next-preferred cluster with space."""
    assign = assign.copy()
    counts = np.bincount(assign, minlength=k)
    if (counts <= cap).all():
        return assign
    pref = np.argsort(d, axis=1)                 # (N, k) preference order
    for j in np.flatnonzero(counts > cap):
        members = np.flatnonzero(assign == j)
        order = np.argsort(d[members, j])        # keep the closest
        for p in members[order[cap:]]:
            for alt in pref[p]:
                if counts[alt] < cap:
                    assign[p] = alt
                    counts[alt] += 1
                    counts[j] -= 1
                    break
    return assign

def kmeans_np(
    x: np.ndarray,
    k: int,
    iters: int = 8,
    balance_penalty: float = 0.0,
    rng: np.random.Generator | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Lloyd's k-means.  Returns (centroids (k, D) f32, assign (N,) int32).

    balance_penalty > 0 enforces a hard per-cluster capacity of
    ``ceil(n/k * (1 + 1/balance_penalty))``: overflow members (farthest
    first) are greedily reassigned to their next-preferred cluster with
    space — the balanced clustering SPANN's partitioning relies on.
    Empty clusters are reseeded to the points farthest from their centroid.
    """
    rng = rng or np.random.default_rng(0)
    x = np.asarray(x, dtype=np.float32)
    n = x.shape[0]
    k = min(k, n)
    centroids = x[rng.choice(n, size=k, replace=False)].copy()
    assign = np.zeros(n, dtype=np.int32)
    cap = n + 1
    if balance_penalty > 0.0:
        cap = int(np.ceil(n / k * (1.0 + 1.0 / balance_penalty)))
    for it in range(iters):
        d = np_sq_l2(x, centroids)  # (N, k)
        assign = np.argmin(d, axis=1).astype(np.int32)
        if balance_penalty > 0.0:
            assign = _enforce_capacity(d, assign, k, cap)
        counts = np.bincount(assign, minlength=k)
        # reseed empties to points with largest distance to their centroid
        empties = np.flatnonzero(counts == 0)
        if empties.size:
            worst = np.argsort(-d[np.arange(n), assign])[: empties.size]
            assign[worst] = empties
            counts = np.bincount(assign, minlength=k)
        sums = np.zeros((k, x.shape[1]), dtype=np.float64)
        np.add.at(sums, assign, x)
        centroids = (sums / np.maximum(counts, 1)[:, None]).astype(np.float32)
    return centroids, assign


# ---------------------------------------------------------------------------
# jax batched Lloyd (PQ codebooks: m independent same-shape subproblems)
# ---------------------------------------------------------------------------

def kmeans_batched(
    key: jax.Array, x: jax.Array, k: int, iters: int = 10
) -> tuple[jax.Array, jax.Array]:
    """Batched Lloyd.  x: (M, N, D) -> (centroids (M, k, D), assign (M, N)).

    All M subproblems run in lockstep under one jit/vmap — this is the PQ
    codebook trainer (M = number of subquantizers, k = 256).
    """
    m, n, _ = x.shape
    k = min(k, n)
    init_idx = jax.vmap(
        lambda kk: jax.random.choice(kk, n, shape=(k,), replace=False)
    )(jax.random.split(key, m))
    init = jax.vmap(lambda xx, ii: xx[ii])(x, init_idx)

    def dist(xx, cc):  # (N, D), (k, D) -> (N, k)
        xn = jnp.sum(xx * xx, axis=-1)[:, None]
        cn = jnp.sum(cc * cc, axis=-1)[None, :]
        return xn + cn - 2.0 * xx @ cc.T

    def step(cc, _):
        def one(xx, c1):
            a = jnp.argmin(dist(xx, c1), axis=1)
            onehot = jax.nn.one_hot(a, k, dtype=xx.dtype)  # (N, k)
            sums = onehot.T @ xx
            counts = onehot.sum(axis=0)[:, None]
            new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), c1)
            return new, a
        new, a = jax.vmap(one)(x, cc)
        return new, a

    @jax.jit
    def run(c0):
        cc, aa = jax.lax.scan(step, c0, None, length=iters)
        return cc, aa[-1]

    return run(init.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Hierarchical balanced partition (SPANN's dataset split + BKT tree)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Node:
    center: np.ndarray          # (D,) f32
    children: list[int]         # child node indices ([] for leaf)
    leaf_id: int                # posting-list id if leaf else -1


@dataclasses.dataclass
class BKTree:
    """Balanced k-means tree over the dataset partition.

    Leaves correspond 1:1 to posting lists; ``centroids[i]`` is the center
    of leaf i.  Lives in compute-node memory (the paper: TurboPuffer caches
    exactly this metadata).
    """

    nodes: list[_Node]
    root: int
    centroids: np.ndarray       # (n_leaves, D) f32

    def search(self, q: np.ndarray, nprobe: int, overquery: int = 4
               ) -> tuple[np.ndarray, int]:
        """Best-first descent; returns (top-nprobe leaf ids, dist comps).

        Emits ~``nprobe * overquery`` candidate leaves then takes the exact
        top-nprobe among them — mirrors SPTAG's BKT search behaviour and
        gives the O(n log nprobe) cost the paper cites.
        """
        q = np.asarray(q, dtype=np.float32)
        want = min(nprobe * overquery, len(self.centroids))
        heap: list[tuple[float, int]] = []
        root = self.nodes[self.root]
        ndist = 0
        if not root.children:          # degenerate single-leaf tree
            return np.array([root.leaf_id]), 1
        d0 = np_sq_l2(q, np.stack([self.nodes[c].center
                                   for c in root.children]))
        ndist += len(root.children)
        for c, dd in zip(root.children, d0):
            heapq.heappush(heap, (float(dd), c))
        out: list[tuple[float, int]] = []
        while heap and len(out) < want:
            d, ni = heapq.heappop(heap)
            node = self.nodes[ni]
            if not node.children:
                out.append((d, node.leaf_id))
                continue
            dc = np_sq_l2(q, np.stack([self.nodes[c].center
                                       for c in node.children]))
            ndist += len(node.children)
            for c, dd in zip(node.children, dc):
                heapq.heappush(heap, (float(dd), c))
        out.sort()
        ids = np.array([i for _, i in out[:nprobe]], dtype=np.int64)
        return ids, ndist

    def flat_search(self, q: np.ndarray, nprobe: int) -> np.ndarray:
        """Exact flat top-nprobe over all leaf centroids (device-path ref)."""
        d = np_sq_l2(q, self.centroids)
        return np.argsort(d)[:nprobe].astype(np.int64)


def hierarchical_partition(
    x: np.ndarray,
    n_leaves: int,
    branch: int = 8,
    iters: int = 8,
    balance_penalty: float = 1.0,
    seed: int = 0,
) -> tuple[BKTree, np.ndarray]:
    """Recursively split ``x`` with balanced k-means until ~n_leaves leaves.

    Returns (tree, leaf_assign (N,) int32).  Leaf centers become the posting
    -list centroids.  This is SPANN's multi-level balanced clustering (much
    cheaper than flat k-means with k = 16% * N, and identical in spirit).
    """
    rng = np.random.default_rng(seed)
    x = np.asarray(x, dtype=np.float32)
    n = x.shape[0]
    target_leaf = max(1, int(round(n / max(1, n_leaves))))
    nodes: list[_Node] = []
    leaf_assign = np.zeros(n, dtype=np.int32)
    leaf_centers: list[np.ndarray] = []

    def build(idx: np.ndarray) -> int:
        center = x[idx].mean(axis=0).astype(np.float32)
        if len(idx) <= target_leaf or len(idx) <= branch:
            leaf_id = len(leaf_centers)
            leaf_centers.append(center)
            leaf_assign[idx] = leaf_id
            nodes.append(_Node(center=center, children=[], leaf_id=leaf_id))
            return len(nodes) - 1
        k = min(branch, max(2, len(idx) // target_leaf))
        _, a = kmeans_np(x[idx], k, iters=iters,
                         balance_penalty=balance_penalty, rng=rng)
        children = []
        for j in range(a.max() + 1):
            sub = idx[a == j]
            if sub.size == 0:
                continue
            children.append(build(sub))
        me = _Node(center=center, children=children, leaf_id=-1)
        nodes.append(me)
        return len(nodes) - 1

    root = build(np.arange(n))
    tree = BKTree(nodes=nodes, root=root,
                  centroids=np.stack(leaf_centers).astype(np.float32))
    return tree, leaf_assign
