"""Product quantization (Jégou et al.) — DiskANN's in-memory compressed
vectors (paper Table 3 "PQ dim.", default ``QD = max(dim/8, 48)``).

Traversal order in DiskANN is driven by asymmetric-distance computation
(ADC) against PQ codes held in compute-node memory; exact distances come
from the full-precision vectors inside fetched 4KB blocks (rerank).

TPU adaptation: the per-lane 256-entry LUT gather of x86/GPU ADC becomes a
VMEM-resident LUT kernel (``repro.kernels.pq_adc``); the functions here are
the pure-jnp oracles plus the host-side (numpy) path used by the simulated
serving engine.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kmeans import kmeans_batched

KSUB = 256  # codebook entries per subquantizer (uint8 codes)


@dataclasses.dataclass
class ProductQuantizer:
    codebooks: np.ndarray     # (m, 256, dsub) f32
    dim: int                  # original dimensionality (pre-padding)

    @property
    def m(self) -> int:
        return self.codebooks.shape[0]

    @property
    def dsub(self) -> int:
        return self.codebooks.shape[2]

    @property
    def padded_dim(self) -> int:
        return self.m * self.dsub

    # -- encode ------------------------------------------------------------
    def _split(self, x: np.ndarray) -> np.ndarray:
        """(N, dim) -> (N, m, dsub) with zero padding to m*dsub."""
        x = np.asarray(x, dtype=np.float32)
        n = x.shape[0]
        pad = self.padded_dim - self.dim
        if pad:
            x = np.concatenate([x, np.zeros((n, pad), np.float32)], axis=1)
        return x.reshape(n, self.m, self.dsub)

    def encode(self, x: np.ndarray, chunk: int = 8192) -> np.ndarray:
        """(N, dim) -> (N, m) uint8 codes."""
        xs = self._split(x)
        out = np.empty((xs.shape[0], self.m), dtype=np.uint8)
        cb = self.codebooks  # (m, 256, dsub)
        cb_norm = np.einsum("mkd,mkd->mk", cb, cb)  # (m, 256)
        for s in range(0, xs.shape[0], chunk):
            xe = xs[s:s + chunk]  # (c, m, dsub)
            # d = |x|^2 - 2 x.c + |c|^2 ; |x|^2 constant in argmin
            ip = np.einsum("cmd,mkd->cmk", xe, cb)
            d = cb_norm[None] - 2.0 * ip
            out[s:s + chunk] = np.argmin(d, axis=2).astype(np.uint8)
        return out

    def decode(self, codes: np.ndarray) -> np.ndarray:
        """(N, m) uint8 -> (N, dim) f32 reconstruction."""
        n = codes.shape[0]
        rec = self.codebooks[np.arange(self.m)[None, :], codes.astype(np.int64)]
        return rec.reshape(n, self.padded_dim)[:, : self.dim]

    # -- ADC ---------------------------------------------------------------
    def adc_table(self, q: np.ndarray) -> np.ndarray:
        """(dim,) query -> (m, 256) table of per-subspace squared distances."""
        qs = self._split(q[None])[0]              # (m, dsub)
        diff = self.codebooks - qs[:, None, :]    # (m, 256, dsub)
        return np.einsum("mkd,mkd->mk", diff, diff).astype(np.float32)

    def adc_lookup(self, codes: np.ndarray, table: np.ndarray) -> np.ndarray:
        """codes (N, m) uint8, table (m, 256) -> (N,) approx sq distances."""
        idx = codes.astype(np.int64)
        return table[np.arange(self.m)[None, :], idx].sum(axis=1)


def train_pq(
    x: np.ndarray,
    m: int,
    iters: int = 10,
    sample: int = 20000,
    seed: int = 0,
) -> ProductQuantizer:
    """Train an m-subquantizer PQ on (a sample of) x.

    dim is zero-padded up to a multiple of m (DiskANN does the same).
    """
    x = np.asarray(x, dtype=np.float32)
    n, dim = x.shape
    rng = np.random.default_rng(seed)
    if n > sample:
        x = x[rng.choice(n, size=sample, replace=False)]
        n = sample
    dsub = -(-dim // m)  # ceil
    pad = m * dsub - dim
    if pad:
        x = np.concatenate([x, np.zeros((n, pad), np.float32)], axis=1)
    xs = jnp.asarray(x.reshape(n, m, dsub).transpose(1, 0, 2))  # (m, N, dsub)
    key = jax.random.PRNGKey(seed)
    cb, _ = kmeans_batched(key, xs, KSUB, iters=iters)
    cb = np.asarray(cb, dtype=np.float32)
    if cb.shape[1] < KSUB:  # tiny datasets: pad codebook by repetition
        reps = -(-KSUB // cb.shape[1])
        cb = np.tile(cb, (1, reps, 1))[:, :KSUB]
    return ProductQuantizer(codebooks=cb, dim=dim)


def default_pq_dims(dim: int) -> int:
    """Paper §5.1: QD = max(dim/8, 48) (capped at dim)."""
    return int(min(dim, max(dim // 8, 48)))
