"""Distributed (multi-pod) sharded vector search — beyond the paper.

The paper studies one-compute-node-to-one-bucket setups and defers
distributed serving to future work (§2.1 footnote 1).  This module is
that future work, TPU-native: the cluster index's posting lists are
sharded across every chip of the production mesh; a query fans out to
all shards (each probes its local top-``nprobe_local`` lists with the
MXU distance pipeline), and the per-shard top-k results are merged with
one small all-gather — a single dependency-free collective phase, which
is exactly the property (§2.3.1) that makes cluster indexes
cloud-friendly, re-expressed at pod scale.

Also here: the distributed k-means index-build step (the offline path),
where each shard computes local assignments and partial centroid sums
that are all-reduced — one line of jnp thanks to jax collectives.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.distances import pairwise_sq_l2, topk_smallest


def _all_axes(mesh) -> tuple:
    return tuple(mesh.axis_names)


def sharded_search_step(mesh, *, nprobe_local: int, k: int):
    """Builds the pjit-able fan-out/merge search step for ``mesh``.

    Array layouts (dim 0 = posting-list shards across ALL mesh axes):
      centroids  (L, D) f32,  list_vecs (L, M, D),  list_ids (L, M) i32,
      queries    (B, D) replicated.
    Returns fn(centroids, list_vecs, list_ids, queries) -> (ids, dists).
    """
    axes = _all_axes(mesh)
    shard_spec = P(axes)

    def local_search(cent, vecs, ids, norms, q):
        # per-shard: probe local top-nprobe lists, scan, local top-k.
        # Vector norms are precomputed at build time and gathered as
        # scalars — the gathered vectors are read exactly once, by the
        # int8 MXU dot (§Perf vector-search iteration 1: the baseline
        # recomputed ||x||^2 from the gathered vectors, ~2x the bytes).
        d_c = pairwise_sq_l2(q, cent)                    # (B, L_loc)
        # NOTE (§Perf iteration 2, refuted on this artifact): lowering
        # this top-k through jax.lax.approx_min_k measured +9% HBO bytes
        # on the CPU dry-run artifact (sort fallback); on real TPU it
        # lowers to PartialReduce and is the right choice — revisit there.
        _, probe = topk_smallest(d_c, nprobe_local)      # (B, np)
        pv = vecs[probe]                                 # (B, np, M, D)
        pi = ids[probe].reshape(q.shape[0], -1)          # (B, np*M)
        pn = norms[probe].reshape(q.shape[0], -1)        # (B, np*M) f32
        B = q.shape[0]
        qf = q.astype(jnp.float32)
        qn = jnp.sum(qf * qf, axis=-1, keepdims=True)    # (B, 1)
        ip = jax.lax.dot_general(
            q, pv, (((1,), (3,)), ((0,), (0,))),
            preferred_element_type=(jnp.int32 if pv.dtype == jnp.int8
                                    else jnp.float32))   # (B, np, M)
        d = qn + pn - 2.0 * ip.reshape(B, -1).astype(jnp.float32)
        d = jnp.where(pi < 0, jnp.inf, d)
        vals, sel = topk_smallest(d, k)                  # (B, k) local
        out_ids = jnp.take_along_axis(pi, sel, axis=1)
        # merge across every shard: one small all-gather
        av = jax.lax.all_gather(vals, axes, tiled=False)   # (S, B, k)
        ai = jax.lax.all_gather(out_ids, axes, tiled=False)
        S = av.shape[0]
        av = av.transpose(1, 0, 2).reshape(B, S * k)
        ai = ai.transpose(1, 0, 2).reshape(B, S * k)
        gvals, gsel = topk_smallest(av, k)
        gids = jnp.take_along_axis(ai, gsel, axis=1)
        return gids, gvals

    fn = shard_map(
        local_search, mesh=mesh,
        in_specs=(shard_spec, shard_spec, shard_spec, shard_spec, P()),
        out_specs=(P(), P()),
        check_rep=False)
    return fn


def sharded_kmeans_step(mesh):
    """One distributed Lloyd iteration: local assign + all-reduce sums.

    data (N, D) sharded over all axes; centroids (K, D) replicated.
    Returns fn(data, centroids) -> new centroids.
    """
    axes = _all_axes(mesh)

    def step(x, cent):
        d = pairwise_sq_l2(x, cent)                      # (N_loc, K)
        a = jnp.argmin(d, axis=1)
        onehot = jax.nn.one_hot(a, cent.shape[0], dtype=jnp.float32)
        sums = onehot.T @ x.astype(jnp.float32)          # (K, D) local
        counts = onehot.sum(axis=0)                      # (K,)
        sums = jax.lax.psum(sums, axes)
        counts = jax.lax.psum(counts, axes)
        return jnp.where(counts[:, None] > 0,
                         sums / jnp.maximum(counts, 1.0)[:, None], cent)

    return shard_map(step, mesh=mesh,
                     in_specs=(P(axes), P()), out_specs=P(),
                     check_rep=False)


# --------------------------------------------------------- dry-run cell --

def dryrun_distributed_search(
    mesh, *,
    n_lists: int = 1 << 21,       # 2M posting lists (BIGANN-1B-scale SPANN)
    max_len: int = 128,
    dim: int = 128,
    batch: int = 256,
    nprobe_local: int = 8,
    k: int = 10,
) -> dict:
    """Lower + compile the production-scale sharded search; returns the
    §Dry-run record (memory/cost/collective analysis)."""
    from repro.launch import roofline as rf

    chips = mesh.devices.size
    sds = jax.ShapeDtypeStruct
    shard = NamedSharding(mesh, P(_all_axes(mesh)))
    repl = NamedSharding(mesh, P())
    cent = sds((n_lists, dim), jnp.float32, sharding=shard)
    vecs = sds((n_lists, max_len, dim), jnp.int8, sharding=shard)
    ids = sds((n_lists, max_len), jnp.int32, sharding=shard)
    norms = sds((n_lists, max_len), jnp.float32, sharding=shard)
    q = sds((batch, dim), jnp.float32, sharding=repl)

    fn = jax.jit(sharded_search_step(mesh, nprobe_local=nprobe_local,
                                     k=k))
    lowered = fn.lower(cent, vecs, ids, norms, q)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    mem_info = {k2: int(getattr(mem, k2)) for k2 in
                ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes") if getattr(mem, k2, None)
                is not None}
    ca = compiled.cost_analysis() or {}
    text = compiled.as_text()
    coll = rf.collective_bytes(text)
    # analytic "model flops": distance comps actually requested
    lists_scanned = chips * nprobe_local * batch
    model_flops = 2.0 * lists_scanned * max_len * dim \
        + 2.0 * batch * n_lists * dim          # centroid matmul
    flops_dev = float(ca.get("flops", 0.0))
    return dict(
        status="ok", chips=chips,
        shape=dict(n_lists=n_lists, max_len=max_len, dim=dim,
                   batch=batch, nprobe_local=nprobe_local, k=k),
        memory=mem_info,
        cost=dict(flops_per_device=flops_dev,
                  bytes_per_device=float(ca.get("bytes accessed", 0.0))),
        collective_bytes=coll,
        roofline=dict(
            compute_s=flops_dev / rf.HW["peak_flops"],
            memory_s=float(ca.get("bytes accessed", 0.0)) / rf.HW["hbm_Bps"],
            collective_s=sum(coll.values()) / rf.HW["ici_Bps"],
            model_flops=model_flops,
            useful_flops_ratio=(model_flops / (flops_dev * chips)
                                if flops_dev else 0.0),
        ),
    )
