"""Core datatypes for the cloud-native vector search engine.

These mirror the paper's vocabulary: indexes are built from a dataset and
parameterised (Table 3), searched with per-query parameters (nprobe /
search_len / beamwidth), and every query produces the instrumentation
metrics of §5.1 (①–⑦).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClusterIndexParams:
    """SPANN-style cluster index build parameters (paper §2.3.1, §3).

    centroid_frac: fraction of dataset points promoted to centroids
      (paper's ``centroid%``; 0.16 means 16%).
    num_replica:   closure replication bound (paper's ``replica#``) —
      boundary vectors are duplicated into up to this many posting lists.
    closure_eps:   a point is replicated into list j iff
      d(p, c_j) <= (1 + closure_eps) * d(p, c_1)  (SPANN's closure rule).
    kmeans_iters / branch: hierarchical balanced k-means controls for the
      BKT build.
    """

    centroid_frac: float = 0.16
    num_replica: int = 8
    closure_eps: float = 0.15
    kmeans_iters: int = 8
    branch: int = 8
    balance_penalty: float = 0.0
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class GraphIndexParams:
    """DiskANN-style graph index build parameters (paper §2.3.2, §3).

    R:          max out-degree (graph density knob of Fig 17).
    L_build:    candidate-set size used during construction.
    alpha:      robust-prune slack (>1 keeps long-range edges).
    pq_dims:    number of PQ subquantizers held in memory (Table 3 "PQ dim.";
                paper default QD = max(dim/8, 48)).
    sector_bytes: storage block size per node (4KB in the paper).
    """

    R: int = 64
    L_build: int = 128
    alpha: float = 1.2
    pq_dims: int = 48
    build_passes: int = 2
    sector_bytes: int = 4096
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class SearchParams:
    """Per-query search parameters (paper §5.1 Query Serving)."""

    k: int = 10
    # cluster index
    nprobe: int = 8
    # graph index
    search_len: int = 10          # candidate-set bound (DiskANN's L)
    beamwidth: int = 16           # W: blocks fetched per expansion round
    max_rounds: int = 512         # safety bound on traversal iterations


@dataclasses.dataclass
class QueryMetrics:
    """Instrumentation for a single query (paper §5.1 ①–⑦ analogues).

    bytes_read:   total data fetched from (cache + storage).
    bytes_storage: bytes actually served by remote storage (cache misses).
    requests:     number of GET requests issued to storage (IOPS pressure).
    roundtrips:   dependent fetch phases (1 for cluster; rt for graph).
    expansions:   neighbour expansions performed (graph) ④.
    lists_visited: posting lists visited (cluster) ⑤.
    dist_comps:   full-precision distance computations.
    pq_dist_comps: ADC (PQ) distance computations.
    cache_hits / cache_lookups: segment-cache statistics ⑦.
    """

    bytes_read: int = 0
    bytes_storage: int = 0
    requests: int = 0
    roundtrips: int = 0
    expansions: int = 0
    lists_visited: int = 0
    dist_comps: int = 0
    pq_dist_comps: int = 0
    cache_hits: int = 0
    cache_lookups: int = 0

    @property
    def hit_rate(self) -> float:
        return self.cache_hits / max(1, self.cache_lookups)


@dataclasses.dataclass
class SearchResult:
    ids: np.ndarray            # (k,) int64 result ids
    dists: np.ndarray          # (k,) float32 distances (squared L2)
    metrics: QueryMetrics


@dataclasses.dataclass
class FetchRequest:
    """One GET against the object store."""

    key: Any                   # object key (e.g. ("list", 17) / ("node", 93))
    nbytes: int


@dataclasses.dataclass
class FetchBatch:
    """A dependency-free batch of GETs issued in one roundtrip.

    Cluster search issues a single batch with all nprobe posting lists
    (no intra-query dependencies, paper §2.3.1).  Graph search issues one
    batch of <=W node blocks per expansion round (paper footnote 8: the W
    requests still count individually against the IOPS limit).
    """

    requests: list[FetchRequest]

    @property
    def nbytes(self) -> int:
        return sum(r.nbytes for r in self.requests)


def recall_at_k(found_ids: np.ndarray, true_ids: np.ndarray) -> float:
    """recall@k with k = len(true_ids) (paper uses k=10)."""
    return float(len(np.intersect1d(found_ids, true_ids))) / float(len(true_ids))
