"""SPANN-style cluster index (paper §2.3.1, §3, §5.3).

Build: hierarchically balanced k-means partitions the dataset into posting
lists (leaf centers = centroids; the hierarchy is the in-memory BKT).
Boundary vectors are *closure-replicated* into up to ``num_replica`` lists
(a point joins list j iff d(p,c_j) <= (1+eps) * d(p,c_1)) — SPANN's key
data-read-per-query optimization, studied in Fig 16/24.

Search: BKT (or flat) centroid search picks the top-``nprobe`` lists; all
lists are fetched in ONE dependency-free roundtrip (paper §2.3.1 — cluster
indexes' big advantage on long-latency storage), then scanned with full-
precision distance computations.

Two serving paths:
* ``search_plan`` — generator yielding :class:`FetchBatch` for the
  discrete-event cloud simulator (the paper's setting).
* ``device_search_batch`` — resident-array pjit path (TPU-native serving /
  distributed dry-run), with padded posting lists.
"""
from __future__ import annotations

import dataclasses
from typing import Generator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kmeans as km
from repro.core.distances import np_sq_l2, pairwise_sq_l2, topk_smallest
from repro.core.types import (ClusterIndexParams, FetchBatch, FetchRequest,
                              QueryMetrics, SearchParams, SearchResult)
from repro.storage.object_store import ObjectStore


@dataclasses.dataclass
class ClusterIndexMeta:
    """Compute-node-resident metadata (what TurboPuffer caches, §2.1)."""

    tree: km.BKTree
    list_lengths: np.ndarray      # (n_lists,) int32
    list_nbytes: np.ndarray       # (n_lists,) int64 billable object sizes
    n_data: int
    dim: int
    dtype: np.dtype
    params: ClusterIndexParams

    @property
    def n_lists(self) -> int:
        return len(self.list_lengths)

    @property
    def index_bytes(self) -> int:
        return int(self.list_nbytes.sum())

    @property
    def avg_list_bytes(self) -> float:
        return float(self.list_nbytes.mean())


def dedup_topk(ids: np.ndarray, d: np.ndarray, k: int
               ) -> tuple[np.ndarray, np.ndarray]:
    """Top-``k`` of (ids, distances) with replica dedup, padded to k.

    Stable distance order + first-occurrence id dedup keeps the nearest
    copy of every closure-replicated point.  The one kernel behind both
    the single-node posting-list scan and the fleet's global merge of
    shard-local top-ks.
    """
    order = np.argsort(d, kind="stable")
    ids_sorted = ids[order]
    _, first = np.unique(ids_sorted, return_index=True)
    first.sort()
    sel = order[first[:k]]
    # re-sort final k by distance
    sel = sel[np.argsort(d[sel], kind="stable")]
    out_ids = ids[sel]
    out_d = d[sel].astype(np.float32)
    if len(out_ids) < k:
        out_ids = np.pad(out_ids, (0, k - len(out_ids)),
                         constant_values=-1)
        out_d = np.pad(out_d, (0, k - len(out_d)),
                       constant_values=np.inf)
    return out_ids, out_d


def scan_posting_lists(q: np.ndarray, payload_items, k: int,
                       metrics: QueryMetrics,
                       exclude: set | None = None) -> SearchResult:
    """Scan fetched posting lists and return the top-``k``.

    ``payload_items`` is an iterable of ``(ids, vecs)`` posting-list
    payloads.  Closure-replicated points are deduplicated by keeping the
    first (nearest) occurrence.  Shared by the single-node plan and the
    fleet's shard-local scan jobs — a shard scanning its own subset of the
    probed lists produces a local top-k whose global merge equals the
    single-node result.  ``exclude`` (a set or int64 array) drops
    tombstoned ids (live-ingest deletes not yet compacted out of the
    sealed lists).
    """
    all_ids = []
    all_vecs = []
    for ids, vecs in payload_items:
        if len(ids):
            all_ids.append(ids)
            all_vecs.append(vecs)
    if not all_ids:
        return SearchResult(np.full(k, -1, np.int64),
                            np.full(k, np.inf, np.float32), metrics)
    ids = np.concatenate(all_ids)
    vecs = np.concatenate(all_vecs)
    if exclude is not None and len(exclude):
        excl = exclude if isinstance(exclude, np.ndarray) else \
            np.fromiter(exclude, dtype=np.int64)
        keep = ~np.isin(ids, excl)
        ids, vecs = ids[keep], vecs[keep]
        if not len(ids):
            return SearchResult(np.full(k, -1, np.int64),
                                np.full(k, np.inf, np.float32), metrics)
    d = np_sq_l2(q, vecs)
    metrics.dist_comps += len(ids)
    out_ids, out_d = dedup_topk(ids, d, k)
    return SearchResult(out_ids, out_d, metrics)


class ClusterIndex:
    def __init__(self, meta: ClusterIndexMeta, store: ObjectStore,
                 use_bkt: bool = True):
        self.meta = meta
        self.store = store
        self.use_bkt = use_bkt

    # ------------------------------------------------------------- build --
    @staticmethod
    def build(data: np.ndarray, params: ClusterIndexParams,
              store: ObjectStore | None = None,
              chunk: int = 4096) -> "ClusterIndex":
        store = store if store is not None else ObjectStore()
        data = np.ascontiguousarray(data)
        n, dim = data.shape
        n_leaves = max(1, int(round(params.centroid_frac * n)))
        tree, _ = km.hierarchical_partition(
            data.astype(np.float32), n_leaves, branch=params.branch,
            iters=params.kmeans_iters,
            balance_penalty=max(params.balance_penalty, 1.0),
            seed=params.seed)
        cents = jnp.asarray(tree.centroids)
        n_lists = len(tree.centroids)
        r = min(params.num_replica, n_lists)

        # closure replication: top-r centroids per point, keep those within
        # (1+eps) of the nearest (squared distances -> (1+eps)^2).
        thresh = (1.0 + params.closure_eps) ** 2
        pair_list: list[np.ndarray] = []
        pair_point: list[np.ndarray] = []
        for s in range(0, n, chunk):
            end = min(s + chunk, n)
            xc = jnp.zeros((chunk, dim), dtype=jnp.float32
                           ).at[: end - s].set(data[s:end])
            d = pairwise_sq_l2(xc, cents)                   # (chunk, n_lists)
            dd, idx = topk_smallest(d, r)
            dd = np.asarray(dd)[: end - s]
            idx = np.asarray(idx)[: end - s]
            keep = dd <= (thresh * dd[:, :1] + 1e-12)
            keep[:, 0] = True
            rows, cols = np.nonzero(keep)
            pair_list.append(idx[rows, cols].astype(np.int64))
            pair_point.append((rows + s).astype(np.int64))
        lists_flat = np.concatenate(pair_list)
        points_flat = np.concatenate(pair_point)
        order = np.argsort(lists_flat, kind="stable")
        lists_flat, points_flat = lists_flat[order], points_flat[order]
        starts = np.searchsorted(lists_flat, np.arange(n_lists))
        ends = np.searchsorted(lists_flat, np.arange(n_lists) + 1)

        itemsize = data.dtype.itemsize
        lengths = (ends - starts).astype(np.int32)
        # billable size: raw vectors + 8-byte ids (paper's posting lists
        # store full vectors inline)
        nbytes = lengths.astype(np.int64) * (dim * itemsize + 8)
        for li in range(n_lists):
            ids_arr = points_flat[starts[li]:ends[li]]
            vecs = data[ids_arr] if len(ids_arr) else np.zeros(
                (0, dim), data.dtype)
            store.put(("list", li), (ids_arr, vecs), int(max(nbytes[li], 1)))

        meta = ClusterIndexMeta(
            tree=tree, list_lengths=lengths, list_nbytes=nbytes,
            n_data=n, dim=dim, dtype=data.dtype, params=params)
        return ClusterIndex(meta, store)

    # ------------------------------------------------------------ search --
    def select_lists(self, q: np.ndarray, nprobe: int
                     ) -> tuple[np.ndarray, int]:
        nprobe = min(nprobe, self.meta.n_lists)
        if self.use_bkt:
            return self.meta.tree.search(q, nprobe)
        ids = self.meta.tree.flat_search(q, nprobe)
        return ids, self.meta.n_lists

    def search_plan(
        self, q: np.ndarray, params: SearchParams,
        metrics: QueryMetrics | None = None,
    ) -> Generator[FetchBatch, dict, SearchResult]:
        """Generator protocol: yields one FetchBatch; engine sends back
        {key: payload}; returns SearchResult.  ``metrics`` may be supplied
        by the serving engine (it snapshots deltas to price compute)."""
        m = metrics if metrics is not None else QueryMetrics()
        lids, ndist = self.select_lists(q, params.nprobe)
        m.dist_comps += ndist                      # BKT centroid comps
        m.lists_visited = len(lids)
        reqs = [FetchRequest(("list", int(i)), int(self.meta.list_nbytes[i]))
                for i in lids]
        payloads = yield FetchBatch(reqs)
        m.roundtrips += 1
        m.requests += len(reqs)
        m.bytes_read += sum(r.nbytes for r in reqs)
        return scan_posting_lists(q, (payloads[rq.key] for rq in reqs),
                                  params.k, m)

    def search(self, q: np.ndarray, params: SearchParams) -> SearchResult:
        """Drive search_plan directly against the store (no timing)."""
        gen = self.search_plan(q, params)
        batch = next(gen)
        try:
            while True:
                payloads = {r.key: self.store.get(r.key)
                            for r in batch.requests}
                batch = gen.send(payloads)
        except StopIteration as stop:
            return stop.value

    # ----------------------------------------------------- device arrays --
    def device_arrays(self, max_len: int | None = None) -> dict[str, np.ndarray]:
        """Padded resident layout for the TPU serving path.

        Returns centroids (L, D), list_vecs (L, maxlen, D),
        list_ids (L, maxlen) int32 (-1 pad), list_len (L,) int32.
        """
        L = self.meta.n_lists
        dim = self.meta.dim
        ml = int(max_len or self.meta.list_lengths.max())
        vecs = np.zeros((L, ml, dim), dtype=np.float32)
        ids = np.full((L, ml), -1, dtype=np.int32)
        for li in range(L):
            pids, pv = self.store.get(("list", li))
            cnt = min(len(pids), ml)
            if cnt:
                vecs[li, :cnt] = pv[:cnt].astype(np.float32)
                ids[li, :cnt] = pids[:cnt]
        return dict(
            centroids=self.meta.tree.centroids.astype(np.float32),
            list_vecs=vecs, list_ids=ids,
            list_len=np.minimum(self.meta.list_lengths, ml).astype(np.int32))


def device_search_batch(
    centroids: jax.Array,     # (L, D)
    list_vecs: jax.Array,     # (L, maxlen, D)
    list_ids: jax.Array,      # (L, maxlen) int32, -1 padded
    queries: jax.Array,       # (B, D)
    *, nprobe: int, k: int,
) -> tuple[jax.Array, jax.Array]:
    """Resident-array batched cluster search (pjit/TPU path).

    One fused pipeline: centroid matmul -> top-nprobe -> posting-list gather
    -> masked distance -> global top-k.  This is the MXU-native equivalent
    of the paper's fetch-then-scan; "fetch" becomes an HBM gather.
    """
    B = queries.shape[0]
    cd = pairwise_sq_l2(queries, centroids)              # (B, L)
    _, probe = topk_smallest(cd, nprobe)                 # (B, nprobe)
    vecs = list_vecs[probe]                              # (B, np, ml, D)
    ids = list_ids[probe]                                # (B, np, ml)
    d = jax.vmap(lambda qv, vv: pairwise_sq_l2(qv[None], vv.reshape(-1, vv.shape[-1]))[0]
                 )(queries, vecs)                        # (B, np*ml)
    ids = ids.reshape(B, -1)
    d = jnp.where(ids < 0, jnp.inf, d)
    # dedup replicas: a duplicated id appears with identical distance; k-NN
    # sets are computed on unique ids via a small penalty-free pass: sort by
    # distance and mask repeated ids within the top window.
    dd, ii = jax.lax.top_k(-d, min(4 * k, d.shape[-1]))
    dd = -dd
    cand_ids = jnp.take_along_axis(ids, ii, axis=1)      # (B, 4k)
    same = cand_ids[:, :, None] == cand_ids[:, None, :]
    earlier = jnp.tril(jnp.ones(same.shape[-2:], bool), k=-1)[None]
    dup = jnp.any(same & earlier, axis=-1)
    dd = jnp.where(dup, jnp.inf, dd)
    vals, sel = topk_smallest(dd, k)
    out_ids = jnp.take_along_axis(cand_ids, sel, axis=1)
    return out_ids, vals
