"""The paper's analytic cost models (Eq. 1, Eq. 2) made executable.

Cluster:  C = c_centroid(n, nprobe) + c_fetch(l) + l * c_dist        (Eq. 1)
Graph:    C = rt × (TTFB + c_fetch(K) + K * c_dist)                  (Eq. 2)

``environment``-aware: c_fetch terms are priced with a StorageSpec
(bandwidth under concurrency sharing + IOPS throttling + TTFB), c_dist with
a compute-rate constant.  Used by tests (crossover/monotonicity) and by
``examples/cloud_tuning.py`` to pick the index class per workload — the
actionable deliverable of RQ1/RQ2.
"""
from __future__ import annotations

import dataclasses
import math

from repro.storage.spec import StorageSpec


@dataclasses.dataclass(frozen=True)
class ComputeSpec:
    """Host compute model for the serving node.

    dist_flops_per_s is calibrated to the paper's Fig 2 CPU/I-O splits:
    scattered posting-list scans on x86 are memory-bound at ~4 GFLOP/s
    effective (GIST1M nprobe=8 on SSD: 51% distance comps vs 31% I/O
    implies ~3.7 GFLOP/s), not the peak SIMD rate.
    """

    dist_flops_per_s: float = 4e9       # sustained distance-comp throughput
    bkt_node_visit_s: float = 2e-7      # per BKT node visit (pointer chase)
    adc_lookup_s: float = 2e-9          # per (code, subquantizer) lookup


DEFAULT_COMPUTE = ComputeSpec()


def plan_compute_seconds(d_dist: int, d_pq: int, dim: int, pq_m: int,
                         spec: ComputeSpec = DEFAULT_COMPUTE) -> float:
    """Price the compute a search plan performed between two yields.

    ``d_dist`` full-precision and ``d_pq`` ADC distance computations since
    the last checkpoint, priced with the node's :class:`ComputeSpec`.  Both
    the serving engine and the fleet router charge plan compute through this
    one function, so a query costs the same wherever its scan runs.
    """
    return (d_dist * 2.0 * dim / spec.dist_flops_per_s
            + d_pq * max(pq_m, 1) * spec.adc_lookup_s)


@dataclasses.dataclass(frozen=True)
class ClusterWorkloadPoint:
    """Index/workload statistics needed by Eq. (1)."""

    n_lists: int
    avg_list_bytes: float
    avg_list_len: float
    dim: int
    nprobe: int


@dataclasses.dataclass(frozen=True)
class GraphWorkloadPoint:
    """Index/workload statistics needed by Eq. (2)."""

    roundtrips: int          # rt — grows with search_len/recall (Fig 8b)
    requests_per_round: float  # ≈ beamwidth W
    node_nbytes: int
    R: int                   # out-degree: neighbours scored per expansion
    pq_m: int
    dim: int


def _fetch_time_s(env: StorageSpec, nbytes: float, n_requests: float,
                  concurrency: int = 1, hit_rate: float = 0.0,
                  hit_latency_s: float = 100e-6) -> float:
    """One dependency-free fetch phase under `concurrency` active queries.

    Bandwidth is a shared pipe (processor sharing): effective per-query
    bandwidth = bw / concurrency.  The IOPS limit throttles request
    admission at ``get_qps_limit / concurrency`` per query.  TTFB is paid
    once per phase (requests within a phase are issued together).

    ``hit_rate`` models a compute-node segment cache: a fraction of the
    phase's requests are served locally at ``hit_latency_s``, shrinking the
    bytes/requests hitting storage.  The phase still waits on its slowest
    request, so TTFB is charged with the probability that at least one of
    the phase's requests misses (1 - hit_rate^n).
    """
    hr = min(max(hit_rate, 0.0), 1.0)
    bw = env.bandwidth_Bps / max(1, concurrency)
    iops = env.get_qps_limit / max(1, concurrency)
    t_bw = nbytes * (1.0 - hr) / bw
    t_iops = n_requests * (1.0 - hr) / iops
    p_any_miss = 1.0 - hr ** max(n_requests, 1.0)
    return (hr * hit_latency_s + env.ttfb_p50_s * p_any_miss
            + max(t_bw, t_iops))


def cluster_query_cost(
    env: StorageSpec, w: ClusterWorkloadPoint,
    compute: ComputeSpec = DEFAULT_COMPUTE,
    concurrency: int = 1,
    dtype_bytes: int = 4,
    hit_rate: float = 0.0,
    hit_latency_s: float = 100e-6,
) -> dict[str, float]:
    """Eq. (1) with environment pricing.  Returns per-term seconds.

    ``hit_rate`` discounts the single fetch phase's storage traffic by the
    expected cache hit fraction (Eq. 1 extended for §7's cached serving):
    the reported ``bytes``/``requests`` are the *storage-billed* residuals,
    which is what the QPS ceilings in :func:`predicted_qps` care about.
    """
    hr = min(max(hit_rate, 0.0), 1.0)
    # c_centroid: BKT descent is O(branch * log(n) * nprobe-ish); we price
    # the empirical ~n log(nprobe) form the paper cites.
    visits = w.nprobe + math.log2(max(2, w.n_lists)) * 8.0
    c_centroid = visits * compute.bkt_node_visit_s + (
        visits * w.dim / compute.dist_flops_per_s * 2.0)
    l_vectors = w.nprobe * w.avg_list_len
    nbytes = w.nprobe * w.avg_list_bytes
    c_fetch = _fetch_time_s(env, nbytes, w.nprobe, concurrency,
                            hit_rate=hr, hit_latency_s=hit_latency_s)
    c_dist = l_vectors * (2.0 * w.dim) / compute.dist_flops_per_s
    total = c_centroid + c_fetch + c_dist
    return dict(total=total, c_centroid=c_centroid, c_fetch=c_fetch,
                c_dist=c_dist, bytes=nbytes * (1.0 - hr),
                requests=float(w.nprobe) * (1.0 - hr))


def graph_query_cost(
    env: StorageSpec, w: GraphWorkloadPoint,
    compute: ComputeSpec = DEFAULT_COMPUTE,
    concurrency: int = 1,
    hit_rate: float = 0.0,
    hit_latency_s: float = 100e-6,
) -> dict[str, float]:
    """Eq. (2) with environment pricing.  Returns per-term seconds.

    ``hit_rate`` is modelled at *round* granularity: graph cache hits
    concentrate in the early traversal rounds (entry-point neighbourhood,
    paper Fig 23 / suggestion A3), so a hit fraction ``hr`` removes that
    fraction of the ``rt × TTFB`` latency floor entirely — cached rounds
    cost only ``hit_latency_s`` — and discounts storage bytes/requests.
    """
    hr = min(max(hit_rate, 0.0), 1.0)
    per_round_bytes = w.requests_per_round * w.node_nbytes
    c_fetch = _fetch_time_s(env, per_round_bytes, w.requests_per_round,
                            concurrency) - env.ttfb_p50_s
    # neighbours scored by ADC each round + W exact rerank distances
    c_dist = (w.requests_per_round * w.R * w.pq_m * compute.adc_lookup_s
              + w.requests_per_round * 2.0 * w.dim
              / compute.dist_flops_per_s)
    rt_miss = w.roundtrips * (1.0 - hr)
    rt_hit = w.roundtrips * hr
    per_round = env.ttfb_p50_s + c_fetch + c_dist
    total = rt_miss * per_round + rt_hit * (hit_latency_s + c_dist)
    return dict(total=total, ttfb_total=rt_miss * env.ttfb_p50_s,
                c_fetch=rt_miss * c_fetch,
                c_dist=w.roundtrips * c_dist,
                bytes=rt_miss * per_round_bytes,
                requests=rt_miss * w.requests_per_round)


def predicted_qps(env: StorageSpec, per_query_s: float, bytes_per_query: float,
                  requests_per_query: float, concurrency: int) -> float:
    """Workload QPS under the environment's three ceilings:

    latency pipelineing (concurrency/latency), shared bandwidth
    (bw / bytes-per-query), and the GET rate limit (IOPS / requests).
    """
    qps_lat = concurrency / max(per_query_s, 1e-12)
    qps_bw = env.bandwidth_Bps / max(bytes_per_query, 1e-12)
    qps_iops = env.get_qps_limit / max(requests_per_query, 1e-12)
    return min(qps_lat, qps_bw, qps_iops)
