"""Distance computation — the paper's dominant compute cost (Fig 2).

Two execution paths share one interface:

* ``pairwise_sq_l2`` / ``pairwise_neg_ip``: pure-jnp reference path, used by
  index build, the host-side (simulated-cloud) serving engine, and as the
  oracle for the Pallas kernels.
* ``repro.kernels.ops``: Pallas TPU kernels (MXU-tiled) used on the device
  serving path; they are validated against these functions in
  ``tests/test_kernels_*``.

TPU adaptation note: the paper's x86 SIMD distance loops become matmuls via
``‖a−b‖² = ‖a‖² − 2·a·b + ‖b‖²`` so that the 128×128 MXU does the heavy
lifting.  int8 datasets (MSSPACE/BIGANN analogues, §5.2) accumulate in int32
on the MXU integer path and are only widened at the end.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def _as_f32(x: Array) -> Array:
    return x.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=())
def pairwise_sq_l2(q: Array, x: Array) -> Array:
    """Squared L2 distances.  q: (Q, D), x: (N, D) -> (Q, N) float32.

    Supports float32/bfloat16/int8 inputs; accumulation is always f32
    (int8 inputs go through the int32 dot-product path first).
    """
    if q.dtype == jnp.int8 or x.dtype == jnp.int8:
        qi = q.astype(jnp.int32)
        xi = x.astype(jnp.int32)
        qn = jnp.sum(qi * qi, axis=-1, dtype=jnp.int32)[:, None]
        xn = jnp.sum(xi * xi, axis=-1, dtype=jnp.int32)[None, :]
        ip = jax.lax.dot_general(
            q, x,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        return (qn + xn - 2 * ip).astype(jnp.float32)
    qf, xf = _as_f32(q), _as_f32(x)
    qn = jnp.sum(qf * qf, axis=-1)[:, None]
    xn = jnp.sum(xf * xf, axis=-1)[None, :]
    ip = jax.lax.dot_general(
        qf, xf,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    d = qn + xn - 2.0 * ip
    return jnp.maximum(d, 0.0)


@jax.jit
def pairwise_neg_ip(q: Array, x: Array) -> Array:
    """Negative inner product (smaller = closer), (Q, D)x(N, D) -> (Q, N)."""
    ip = jax.lax.dot_general(
        _as_f32(q), _as_f32(x),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return -ip


def pairwise(q: Array, x: Array, metric: str = "l2") -> Array:
    if metric == "l2":
        return pairwise_sq_l2(q, x)
    if metric == "ip":
        return pairwise_neg_ip(q, x)
    raise ValueError(f"unknown metric {metric!r}")


@functools.partial(jax.jit, static_argnames=("k",))
def topk_smallest(d: Array, k: int) -> tuple[Array, Array]:
    """Top-k smallest along the last axis -> (values, indices)."""
    neg_vals, idx = jax.lax.top_k(-d, k)
    return -neg_vals, idx


# ---------------------------------------------------------------------------
# numpy host-path (used inside the discrete-event serving engine where data
# arrives as numpy objects from the simulated object store; keeping this in
# numpy avoids host<->device ping-pong for tiny per-round batches).
# ---------------------------------------------------------------------------

def np_sq_l2(q: np.ndarray, x: np.ndarray) -> np.ndarray:
    """q: (D,) or (Q, D); x: (N, D) -> (N,) or (Q, N), float32."""
    q = np.asarray(q, dtype=np.float32)
    x = np.asarray(x, dtype=np.float32)
    single = q.ndim == 1
    if single:
        q = q[None]
    qn = np.einsum("qd,qd->q", q, q)[:, None]
    xn = np.einsum("nd,nd->n", x, x)[None, :]
    d = qn + xn - 2.0 * (q @ x.T)
    np.maximum(d, 0.0, out=d)
    return d[0] if single else d
