"""DiskANN-style graph index (paper §2.3.2, §3, §5.3).

Build (Vamana): batched greedy-search + α-robust-prune passes over the
dataset; fixed max out-degree R (the graph-density knob of Fig 17).

Storage layout: one block per node holding the full-precision vector and
the padded adjacency list, rounded up to ``sector_bytes`` (4KB; GIST-like
960-d f32 + 64 neighbours is exactly one sector — the paper's layout).

Memory-resident metadata: PQ codes of every vector + codebooks (paper
Table 3 "PQ dim."), the medoid/entry point.

Search: iterative best-first traversal with beamwidth W (Alg 1 + DiskANN's
multi-vector extraction): each round extracts the W nearest unexpanded
candidates (by ADC/PQ distance), fetches their blocks in ONE roundtrip of W
GET requests (footnote 8: the W requests count individually against IOPS),
scores their neighbours by ADC, and reranks the final top-k with the exact
distances recovered from fetched blocks.  ``rt × TTFB`` is the latency
floor the paper identifies — the simulator charges exactly that.
"""
from __future__ import annotations

import dataclasses
from typing import Generator

import jax.numpy as jnp
import numpy as np

from repro.core import pq as pqmod
from repro.core.distances import np_sq_l2, pairwise_sq_l2
from repro.core.types import (FetchBatch, FetchRequest, GraphIndexParams,
                              QueryMetrics, SearchParams, SearchResult)
from repro.storage.object_store import ObjectStore, round_to_sectors


@dataclasses.dataclass
class GraphIndexMeta:
    """Compute-node-resident metadata (PQ codes + codebooks + entry point)."""

    pq: pqmod.ProductQuantizer
    codes: np.ndarray             # (N, m) uint8
    medoid: int
    n_data: int
    dim: int
    dtype: np.dtype
    node_nbytes: int              # per-node billable block size
    params: GraphIndexParams

    @property
    def index_bytes(self) -> int:
        return self.n_data * self.node_nbytes


def _robust_prune(
    p_vec: np.ndarray,            # (D,)
    cand_ids: np.ndarray,         # (C,) unique candidate ids (no self)
    cand_vecs: np.ndarray,        # (C, D)
    R: int,
    alpha: float,
    max_pool: int = 192,
) -> np.ndarray:
    """DiskANN RobustPrune: greedy α-dominated candidate elimination.

    The candidate pool is capped at ``max_pool`` points to bound the C×C
    distance matrix — the nearest ones plus a 16-candidate far tail, so
    long-range (navigability) edges always remain prunable-in rather than
    silently dropped.
    """
    d_p = np_sq_l2(p_vec, cand_vecs)              # (C,)
    if len(cand_ids) > max_pool:
        order = np.argsort(d_p, kind="stable")
        keep = np.concatenate([order[: max_pool - 16], order[-16:]])
        cand_ids, cand_vecs, d_p = cand_ids[keep], cand_vecs[keep], d_p[keep]
    order = np.argsort(d_p, kind="stable")
    d_p = d_p[order]
    cand_ids = cand_ids[order]
    cand_vecs = cand_vecs[order]
    d_cc = np_sq_l2(cand_vecs, cand_vecs)         # (C, C), one matmul
    alive = np.ones(len(cand_ids), dtype=bool)
    chosen: list[int] = []
    a2 = alpha * alpha                            # α on metric -> α² on sq
    for oi in range(len(cand_ids)):               # increasing d_p order
        if not alive[oi]:
            continue
        chosen.append(oi)
        if len(chosen) >= R:
            break
        # prune c' if α·d(p*, c') <= d(p, c')
        alive &= ~(a2 * d_cc[oi] <= d_p)
        alive[oi] = False
    return cand_ids[np.asarray(chosen, dtype=np.int64)]


def _merge_candidates(
    cand_ids: np.ndarray, cand_d: np.ndarray, expanded: np.ndarray,
    new_ids: np.ndarray, new_d: np.ndarray, L: int,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorised candidate-list merge with id-dedup, batched over rows.

    All inputs are (B, *); new entries carry d=inf where padded (<0 ids).
    Dedup keeps the earliest (already-expanded / smallest-distance) copy —
    both copies of an id always carry the same distance, and stable sorts
    keep the pre-existing candidate first, so expansion flags survive.
    """
    ids_all = np.concatenate([cand_ids, new_ids], axis=1)
    d_all = np.concatenate([cand_d, new_d], axis=1)
    e_all = np.concatenate(
        [expanded, np.zeros(new_ids.shape, dtype=bool)], axis=1)
    # 1) stable sort by distance
    o1 = np.argsort(d_all, axis=1, kind="stable")
    ids_all = np.take_along_axis(ids_all, o1, axis=1)
    d_all = np.take_along_axis(d_all, o1, axis=1)
    e_all = np.take_along_axis(e_all, o1, axis=1)
    # 2) stable sort by id -> equal ids adjacent, distance-ordered within
    o2 = np.argsort(ids_all, axis=1, kind="stable")
    ids_s = np.take_along_axis(ids_all, o2, axis=1)
    dup = np.zeros_like(ids_s, dtype=bool)
    dup[:, 1:] = (ids_s[:, 1:] == ids_s[:, :-1]) & (ids_s[:, 1:] >= 0)
    # scatter dup mask back to distance order and kill duplicates
    dup_back = np.zeros_like(dup)
    np.put_along_axis(dup_back, o2, dup, axis=1)
    d_all = np.where(dup_back | (ids_all < 0), np.inf, d_all)
    # 3) final stable distance sort, truncate to L
    o3 = np.argsort(d_all, axis=1, kind="stable")[:, :L]
    out_ids = np.take_along_axis(ids_all, o3, axis=1)
    out_d = np.take_along_axis(d_all, o3, axis=1)
    out_e = np.take_along_axis(e_all, o3, axis=1)
    out_ids = np.where(np.isinf(out_d), -1, out_ids)
    out_e &= out_ids >= 0
    return out_ids, out_d, out_e


def _batch_sq_l2(q: np.ndarray, vecs: np.ndarray) -> np.ndarray:
    """q (B, D), vecs (B, M, D) -> (B, M) float32 squared L2 (numpy)."""
    q = q.astype(np.float32, copy=False)
    v = vecs.astype(np.float32, copy=False)
    qn = np.einsum("bd,bd->b", q, q)[:, None]
    vn = np.einsum("bmd,bmd->bm", v, v)
    ip = np.einsum("bd,bmd->bm", q, v)
    d = qn + vn - 2.0 * ip
    np.maximum(d, 0.0, out=d)
    return d


def _greedy_search_build(
    data: np.ndarray,             # (N, D) f32 resident for build
    adj: np.ndarray,              # (N, R) int32, -1 padded
    q_vecs: np.ndarray,           # (B, D) batch of query points
    entry: int,
    L: int,
    max_rounds: int | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Batched greedy search on the under-construction graph (pure numpy,
    fully vectorised over the batch).

    Returns (visited_ids (B, T) padded with -1, visited_dists (B, T)) —
    the candidate pools RobustPrune consumes.  Distances are exact (build
    runs in memory, as DiskANN's builder does).
    """
    B = len(q_vecs)
    max_rounds = max_rounds or (L + 8)
    q = q_vecs.astype(np.float32, copy=False)
    cand_ids = np.full((B, L), -1, dtype=np.int64)
    cand_d = np.full((B, L), np.inf, dtype=np.float32)
    expanded = np.zeros((B, L), dtype=bool)
    d0 = _batch_sq_l2(q, data[entry][None, None, :].repeat(B, axis=0))[:, 0]
    cand_ids[:, 0] = entry
    cand_d[:, 0] = d0
    ar = np.arange(B)
    vis_ids = np.full((B, max_rounds), -1, dtype=np.int64)
    vis_d = np.full((B, max_rounds), np.inf, dtype=np.float32)

    for t in range(max_rounds):
        masked = np.where(expanded | (cand_ids < 0), np.inf, cand_d)
        fi = np.argmin(masked, axis=1)
        act = masked[ar, fi] < np.inf
        if not act.any():
            break
        nodes = np.where(act, cand_ids[ar, fi], 0)
        expanded[ar[act], fi[act]] = True
        vis_ids[act, t] = nodes[act]
        vis_d[act, t] = cand_d[ar, fi][act]
        nbrs = adj[nodes].astype(np.int64)         # (B, R)
        nbrs = np.where(act[:, None], nbrs, -1)
        dn = _batch_sq_l2(q, data[np.maximum(nbrs, 0)])
        dn = np.where(nbrs < 0, np.inf, dn)
        cand_ids, cand_d, expanded = _merge_candidates(
            cand_ids, cand_d, expanded, nbrs, dn, L)
    return vis_ids, vis_d


class GraphIndex:
    def __init__(self, meta: GraphIndexMeta, store: ObjectStore):
        self.meta = meta
        self.store = store

    # ------------------------------------------------------------- build --
    @staticmethod
    def build(data: np.ndarray, params: GraphIndexParams,
              store: ObjectStore | None = None,
              batch: int = 256) -> "GraphIndex":
        store = store if store is not None else ObjectStore()
        data = np.ascontiguousarray(data)
        n, dim = data.shape
        rng = np.random.default_rng(params.seed)
        R = params.R
        data_f = data.astype(np.float32)
        data_j = jnp.asarray(data_f)

        # medoid = closest point to the dataset mean
        mean = data_f.mean(axis=0)
        medoid = int(np.argmin(np_sq_l2(mean, data_f)))

        # init: random regular graph of degree min(R, 16)
        deg0 = min(R, 16)
        adj = np.full((n, R), -1, dtype=np.int32)
        for i in range(n):
            nb = rng.choice(n - 1, size=min(deg0, n - 1), replace=False)
            nb[nb >= i] += 1
            adj[i, :len(nb)] = nb

        order = rng.permutation(n)
        for pass_i in range(params.build_passes):
            alpha = 1.0 if pass_i == 0 else params.alpha
            for s in range(0, n, batch):
                pts = order[s:s + batch]
                vis_ids, _ = _greedy_search_build(
                    data_j, adj, data_f[pts], medoid, params.L_build)
                rev: dict[int, list[int]] = {}
                for bi, p in enumerate(pts):
                    cand = vis_ids[bi]
                    cand = cand[(cand >= 0) & (cand != p)]
                    # also keep current neighbours in the pool (Vamana)
                    cur = adj[p]
                    cur = cur[(cur >= 0) & (cur != p)]
                    cand = np.unique(np.concatenate([cand, cur]))
                    if cand.size == 0:
                        continue
                    sel = _robust_prune(
                        data_f[p], cand, data_f[cand], R, alpha)
                    adj[p, :] = -1
                    adj[p, :len(sel)] = sel
                    for t in sel:
                        rev.setdefault(int(t), []).append(int(p))
                # reverse edges with overflow pruning
                for t, srcs in rev.items():
                    cur = adj[t]
                    cur = cur[cur >= 0]
                    merged = np.unique(np.concatenate(
                        [cur, np.asarray(srcs, dtype=np.int32)]))
                    merged = merged[merged != t]
                    if len(merged) <= R:
                        adj[t, :] = -1
                        adj[t, :len(merged)] = merged
                    else:
                        sel = _robust_prune(
                            data_f[t], merged.astype(np.int64),
                            data_f[merged], R, alpha)
                        adj[t, :] = -1
                        adj[t, :len(sel)] = sel

        # ---- PQ metadata (in-memory) ----
        m = params.pq_dims
        pq = pqmod.train_pq(data_f, m, seed=params.seed)
        codes = pq.encode(data_f)

        # ---- persist node blocks ----
        itemsize = data.dtype.itemsize
        raw = dim * itemsize + R * 4 + 8
        node_nbytes = round_to_sectors(raw, params.sector_bytes)
        for i in range(n):
            store.put(("node", i), (data[i], adj[i].copy()), node_nbytes)

        meta = GraphIndexMeta(
            pq=pq, codes=codes, medoid=medoid, n_data=n, dim=dim,
            dtype=data.dtype, node_nbytes=node_nbytes, params=params)
        return GraphIndex(meta, store)

    # ------------------------------------------------------------ search --
    def search_plan(
        self, q: np.ndarray, params: SearchParams,
        metrics: QueryMetrics | None = None,
    ) -> Generator[FetchBatch, dict, SearchResult]:
        meta = self.meta
        mtr = metrics if metrics is not None else QueryMetrics()
        q = np.asarray(q, dtype=np.float32)
        table = meta.pq.adc_table(q)
        L = params.search_len
        W = params.beamwidth

        visited = np.zeros(meta.n_data, dtype=bool)
        in_cand = np.zeros(meta.n_data, dtype=bool)
        cand_ids = np.full(L, -1, dtype=np.int64)
        cand_d = np.full(L, np.inf, dtype=np.float32)
        expanded = np.zeros(L, dtype=bool)
        d0 = meta.pq.adc_lookup(meta.codes[meta.medoid][None], table)[0]
        mtr.pq_dist_comps += 1
        cand_ids[0] = meta.medoid
        cand_d[0] = d0
        in_cand[meta.medoid] = True
        exact: dict[int, float] = {}

        for _ in range(params.max_rounds):
            masked = np.where(expanded | (cand_ids < 0), np.inf, cand_d)
            order = np.argsort(masked, kind="stable")
            frontier = order[: W]
            frontier = frontier[masked[frontier] < np.inf]
            if frontier.size == 0:
                break
            nodes = cand_ids[frontier]
            expanded[frontier] = True
            visited[nodes] = True
            reqs = [FetchRequest(("node", int(i)), meta.node_nbytes)
                    for i in nodes]
            payloads = yield FetchBatch(reqs)
            mtr.roundtrips += 1
            mtr.requests += len(reqs)
            mtr.expansions += len(reqs)
            mtr.bytes_read += len(reqs) * meta.node_nbytes

            new_nbrs: list[np.ndarray] = []
            for nd, rq in zip(nodes, reqs):
                vec, nbrs = payloads[rq.key]
                de = float(np_sq_l2(q, np.asarray(
                    vec, dtype=np.float32)[None])[0])
                mtr.dist_comps += 1
                exact[int(nd)] = de
                nbrs = nbrs[nbrs >= 0]
                new_nbrs.append(nbrs)
            if new_nbrs:
                nn = np.unique(np.concatenate(new_nbrs))
                # snapshot isolation under live ingest: nodes stitched in
                # after this plan started (id >= the entry-time n_data)
                # are invisible to it — the merged-search delta scan
                # covers them until the next query.
                nn = nn[nn < len(visited)]
                nn = nn[~visited[nn] & ~in_cand[nn]]
            else:
                nn = np.zeros(0, dtype=np.int64)
            if nn.size:
                dn = meta.pq.adc_lookup(meta.codes[nn], table)
                mtr.pq_dist_comps += len(nn)
                ids_all = np.concatenate([cand_ids, nn])
                d_all = np.concatenate([cand_d, dn])
                e_all = np.concatenate([expanded,
                                        np.zeros(len(nn), dtype=bool)])
                oo = np.argsort(d_all, kind="stable")[:L]
                evicted = np.setdiff1d(ids_all[np.argsort(d_all)[L:]],
                                       ids_all[oo], assume_unique=False)
                in_cand[nn] = True
                ev = evicted[evicted >= 0]
                in_cand[ev] = False
                cand_ids = ids_all[oo]
                cand_d = d_all[oo]
                expanded = e_all[oo]
        # rerank by exact distances of expanded nodes (DiskANN full-precision
        # rerank from fetched blocks)
        if exact:
            ids = np.fromiter(exact.keys(), dtype=np.int64)
            ds = np.fromiter(exact.values(), dtype=np.float32)
            oo = np.argsort(ds)[: params.k]
            out_ids, out_d = ids[oo], ds[oo]
        else:
            out_ids = np.zeros(0, np.int64)
            out_d = np.zeros(0, np.float32)
        k = params.k
        if len(out_ids) < k:
            out_ids = np.pad(out_ids, (0, k - len(out_ids)),
                             constant_values=-1)
            out_d = np.pad(out_d, (0, k - len(out_d)),
                           constant_values=np.inf)
        return SearchResult(out_ids, out_d, mtr)

    def search(self, q: np.ndarray, params: SearchParams) -> SearchResult:
        gen = self.search_plan(q, params)
        batch = next(gen)
        try:
            while True:
                payloads = {r.key: self.store.get(r.key)
                            for r in batch.requests}
                batch = gen.send(payloads)
        except StopIteration as stop:
            return stop.value

    # ----------------------------------------------------- device arrays --
    def device_arrays(self) -> dict[str, np.ndarray]:
        """Resident layout for the TPU beam-search path: full vectors +
        padded adjacency."""
        n = self.meta.n_data
        dim = self.meta.dim
        R = self.meta.params.R
        vecs = np.zeros((n, dim), dtype=np.float32)
        adj = np.full((n, R), -1, dtype=np.int32)
        for i in range(n):
            v, nb = self.store.get(("node", i))
            vecs[i] = v.astype(np.float32)
            adj[i] = nb
        return dict(vectors=vecs, adjacency=adj,
                    medoid=np.int32(self.meta.medoid))
