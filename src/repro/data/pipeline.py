"""Deterministic, resumable LM token pipeline.

Training at scale needs a data source that (a) is reproducible across
restarts, (b) can seek to an arbitrary step (checkpoint resume without
replaying), and (c) shards across data-parallel workers without overlap.
This synthetic pipeline (a fixed-vocab Zipf-mixture "language" with local
n-gram structure so models actually have something to learn) provides all
three; a file-backed source can implement the same interface.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_patterns: int = 512      # latent bigram patterns (learnable signal)


class TokenPipeline:
    """``batch(step)`` is a pure function of (config, step) — resumable
    and shardable by construction."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # latent bigram table: each token prefers a successor set
        self.succ = rng.integers(
            0, cfg.vocab, size=(cfg.vocab, 4)).astype(np.int32)

    def batch(self, step: int, worker: int = 0, n_workers: int = 1) -> dict:
        cfg = self.cfg
        assert cfg.global_batch % n_workers == 0
        b = cfg.global_batch // n_workers
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + worker)
        toks = np.empty((b, cfg.seq_len + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab, size=b)
        follow = rng.random((b, cfg.seq_len)) < 0.8
        choice = rng.integers(0, 4, size=(b, cfg.seq_len))
        noise = rng.integers(0, cfg.vocab, size=(b, cfg.seq_len))
        for t in range(cfg.seq_len):
            nxt = self.succ[toks[:, t], choice[:, t]]
            toks[:, t + 1] = np.where(follow[:, t], nxt, noise[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
