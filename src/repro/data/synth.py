"""Synthetic dataset analogues of the paper's Table 2.

The container has no copies of GIST1M/DEEP10M/MSSPACE10M/BIGANN1B, so we
generate clustered Gaussian-mixture analogues matching each dataset's
*dimensionality and datatype* (the two axes the paper's §5.2 shows drive
index behaviour) at reduced cardinality.  Cluster structure makes recall
non-trivial (pure iid Gaussians make ANN degenerate in high dim).

| analogue      | dim | dtype   | stands in for |
|---------------|-----|---------|---------------|
| gist-analog   | 960 | float32 | GIST1M        |
| deep-analog   |  96 | float32 | DEEP10M       |
| msspace-analog| 100 | int8    | MSSPACE10M    |
| bigann-analog | 128 | int8    | BIGANN1B      |
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    dim: int
    dtype: str           # "float32" | "int8"
    n: int
    n_queries: int
    n_clusters: int = 64
    cluster_std: float = 0.35
    intrinsic_dim: int = 32
    seed: int = 0


GIST_ANALOG = DatasetSpec("gist-analog", 960, "float32", 20_000, 200,
                          intrinsic_dim=32)
DEEP_ANALOG = DatasetSpec("deep-analog", 96, "float32", 50_000, 500,
                          intrinsic_dim=24)
MSSPACE_ANALOG = DatasetSpec("msspace-analog", 100, "int8", 50_000, 500,
                             intrinsic_dim=24)
BIGANN_ANALOG = DatasetSpec("bigann-analog", 128, "int8", 100_000, 500,
                            intrinsic_dim=32)

ANALOGS = {d.name: d for d in
           [GIST_ANALOG, DEEP_ANALOG, MSSPACE_ANALOG, BIGANN_ANALOG]}


def make_dataset(spec: DatasetSpec) -> tuple[np.ndarray, np.ndarray]:
    """Returns (data (N, D), queries (Q, D)) with the spec's dtype.

    Data lives on a low-rank manifold (x = U z, intrinsic_dim << dim) with
    per-cluster scale variation.  Isotropic full-rank Gaussians at 960-D
    exhibit total distance concentration (every pairwise distance equal),
    which (a) no real embedding set shows and (b) degenerates graph-index
    pruning — the ambient dim still controls vector BYTES, which is the
    axis the paper's dimensionality studies measure.

    Queries are perturbed dataset points (they live on the data manifold —
    the regime where ANN search is meaningful).
    """
    rng = np.random.default_rng(spec.seed)
    r = min(spec.intrinsic_dim, spec.dim)
    basis = rng.normal(0.0, 1.0, size=(r, spec.dim)) / np.sqrt(r)
    centers_z = rng.normal(0.0, 1.0, size=(spec.n_clusters, r))
    scales = rng.uniform(0.3, 1.2, size=spec.n_clusters) * spec.cluster_std
    assign = rng.integers(0, spec.n_clusters, size=spec.n)
    z = centers_z[assign] + rng.normal(
        0.0, 1.0, size=(spec.n, r)) * scales[assign][:, None]
    data = z @ basis + rng.normal(0.0, 0.02, size=(spec.n, spec.dim))
    qi = rng.choice(spec.n, size=spec.n_queries, replace=False)
    qz = z[qi] + rng.normal(0.0, 1.0, size=(spec.n_queries, r)) \
        * (scales[assign[qi]] * 0.5)[:, None]
    queries = qz @ basis + rng.normal(
        0.0, 0.02, size=(spec.n_queries, spec.dim))
    if spec.dtype == "int8":
        scale = 127.0 / (np.abs(data).max() + 1e-9)
        data = np.clip(np.round(data * scale), -127, 127).astype(np.int8)
        queries = np.clip(np.round(queries * scale), -127, 127).astype(np.int8)
    else:
        data = data.astype(np.float32)
        queries = queries.astype(np.float32)
    return data, queries


def scaled(spec: DatasetSpec, n: int, n_queries: int | None = None,
           **overrides) -> DatasetSpec:
    """A smaller/larger copy of a dataset spec (for tests/benchmarks)."""
    return dataclasses.replace(
        spec, n=n, n_queries=n_queries or min(spec.n_queries, max(16, n // 100)),
        **overrides)
