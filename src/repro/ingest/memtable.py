"""The in-memory delta tier: a brute-force flat segment + tombstones.

Cloud-native indexes are built once and served read-only (the paper's
setting); live corpora churn.  The standard reconciliation — LSM-style —
is a small memory-resident *delta* absorbing writes at memory speed while
the sealed segments stay immutable on the object store:

* **inserts** land in the memtable (id → vector [+ posting-list
  assignment for cluster indexes]) and become searchable the moment they
  are applied: merged search scans the delta by brute force (it is tiny
  relative to the sealed tier, so a flat scan is both exact and cheap).
* **deletes** are tombstones: sealed copies cannot be touched without a
  rewrite, so the id is recorded and filtered out of every merged result
  until compaction folds the delete into the sealed objects.

The memtable is **sized in bytes** (vector payload + 8-byte id per
entry, 8 bytes per tombstone) because bytes are what trigger flushes and
what the flush ultimately writes; entry counts would mis-size the tier
across dims/dtypes.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.core.distances import np_sq_l2

#: per-entry id overhead (matches the sealed posting-list layout)
ID_BYTES = 8
#: per-tombstone bookkeeping bytes
TOMBSTONE_BYTES = 8


@dataclasses.dataclass
class DeltaEntry:
    """One live insert: the vector plus where it will be sealed.

    ``lists`` is the closure-replicated posting-list assignment for
    cluster indexes (empty tuple for graph nodes, whose placement is the
    node id itself); ``arrive_t`` feeds freshness-lag accounting.
    """

    id: int
    vec: np.ndarray
    lists: tuple[int, ...]
    arrive_t: float
    apply_t: float


class Memtable:
    """Flat delta segment + tombstone set for one ingest site.

    A *site* is whoever applies updates against one view: the single
    engine, or one fleet shard group (each owner group of an update's
    keys holds its own copy — replication at the delta tier, mirroring
    replication of the sealed objects).
    """

    def __init__(self, vec_nbytes: int):
        self.vec_nbytes = int(vec_nbytes)       # payload bytes per vector
        self.entries: dict[int, DeltaEntry] = {}
        self.tombstones: dict[int, float] = {}  # id -> arrive_t
        self.by_list: dict[int, set[int]] = {}  # list id -> delta ids
        self.peak_bytes = 0
        self.total_inserts = 0
        self.total_deletes = 0

    # ------------------------------------------------------------ sizing --
    @property
    def entry_nbytes(self) -> int:
        return self.vec_nbytes + ID_BYTES

    @property
    def used_bytes(self) -> int:
        return (len(self.entries) * self.entry_nbytes
                + len(self.tombstones) * TOMBSTONE_BYTES)

    def __len__(self) -> int:
        return len(self.entries)

    # ----------------------------------------------------------- mutation --
    def insert(self, id_: int, vec: np.ndarray, lists: tuple[int, ...],
               arrive_t: float, apply_t: float) -> None:
        """Apply an insert: the id becomes searchable immediately.  A
        re-insert of a tombstoned id resurrects it (the delta copy wins
        over any stale sealed copy via the tombstone it replaces)."""
        self.tombstones.pop(id_, None)
        old = self.entries.pop(id_, None)
        if old is not None:
            for li in old.lists:
                self.by_list.get(li, set()).discard(id_)
        self.entries[id_] = DeltaEntry(id_, vec, tuple(lists),
                                       arrive_t, apply_t)
        for li in lists:
            self.by_list.setdefault(li, set()).add(id_)
        self.total_inserts += 1
        self.peak_bytes = max(self.peak_bytes, self.used_bytes)

    def delete(self, id_: int, arrive_t: float) -> bool:
        """Apply a delete.  Returns True when the victim was still in the
        delta (no sealed copy to tombstone — the entry just vanishes)."""
        self.total_deletes += 1
        old = self.entries.pop(id_, None)
        if old is not None:
            for li in old.lists:
                self.by_list.get(li, set()).discard(id_)
            return True
        self.tombstones[id_] = arrive_t
        self.peak_bytes = max(self.peak_bytes, self.used_bytes)
        return False

    def clear_flushed(self, entries: dict, tombstones: dict) -> None:
        """Drop the snapshot a completed flush sealed.  Entries replaced
        *after* the snapshot (re-insert of the same id) and tombstones
        re-laid since are kept — only the exact flushed state clears."""
        for id_, e in entries.items():
            if self.entries.get(id_) is e:
                del self.entries[id_]
                for li in e.lists:
                    self.by_list.get(li, set()).discard(id_)
        for id_, arrive_t in tombstones.items():
            if self.tombstones.get(id_) == arrive_t:
                del self.tombstones[id_]

    def remap_list(self, old_li: int, moved: dict[int, int]) -> None:
        """A re-cluster split list ``old_li``: delta ids in ``moved``
        now belong to their new list id (entries keep closure copies in
        unaffected lists)."""
        for id_, new_li in moved.items():
            e = self.entries.get(id_)
            if e is None:
                continue
            e.lists = tuple(new_li if li == old_li else li
                            for li in e.lists)
            self.by_list.get(old_li, set()).discard(id_)
            self.by_list.setdefault(new_li, set()).add(id_)

    # ------------------------------------------------------------- search --
    def live_items(self, lists: Iterator[int] | None = None
                   ) -> tuple[np.ndarray, np.ndarray]:
        """(ids, vecs) of live delta entries — restricted to entries
        assigned to ``lists`` when given (the shard-scan path: a scan job
        probing posting lists L sees exactly the delta points destined
        for L, so every replica owner serves the same content)."""
        if lists is None:
            ids = sorted(self.entries)
        else:
            sel: set[int] = set()
            for li in lists:
                sel |= self.by_list.get(li, set())
            ids = sorted(sel)
        if not ids:
            return (np.zeros(0, dtype=np.int64), np.zeros((0, 0)))
        vecs = np.stack([self.entries[i].vec for i in ids])
        return np.asarray(ids, dtype=np.int64), vecs

    def search(self, q: np.ndarray, k: int,
               lists: Iterator[int] | None = None
               ) -> tuple[np.ndarray, np.ndarray, int]:
        """Brute-force top-``k`` over the (restricted) live delta.

        Returns (ids, sq-l2 dists, n_dist_comps) — the caller merges
        them with the sealed result through ``dedup_topk`` and charges
        the comps to its compute budget.
        """
        ids, vecs = self.live_items(lists)
        if len(ids) == 0:
            return ids, np.zeros(0, dtype=np.float32), 0
        d = np_sq_l2(np.asarray(q, dtype=np.float32),
                     vecs.astype(np.float32, copy=False))
        if len(ids) > k:
            sel = np.argpartition(d, k)[:k]
            sel = sel[np.argsort(d[sel], kind="stable")]
        else:
            sel = np.argsort(d, kind="stable")
        return ids[sel], d[sel].astype(np.float32), len(ids)
