"""``repro.ingest`` — streaming updates, delta indexes and background
compaction under live traffic.

The paper serves build-once indexes; this subsystem makes both index
families mutable end to end:

* :mod:`repro.ingest.memtable` — the in-memory delta tier (flat
  brute-force segment + tombstones, sized in bytes);
* :mod:`repro.ingest.mutable` — :class:`MutableClusterIndex` /
  :class:`MutableGraphIndex`: merged (delta ∪ sealed) search through
  ``dedup_topk`` with tombstone filtering, plus the pure mutation
  kernels compaction drives;
* :mod:`repro.ingest.compaction` — :class:`IngestAgent`: applies the
  update stream through the shared admission window and runs flushes,
  posting-list re-clustering and graph stitch/repair as kernel events
  whose I/O goes through the query-serving :class:`StorageSim`;
* :mod:`repro.ingest.stream` — timestamped insert/delete streams and
  churn ground truth;
* :mod:`repro.ingest.metrics` — freshness lags, write amplification,
  compaction busy intervals.

Entry points: ``run_workload(..., updates=, ingest=)`` for one engine,
``run_fleet(..., updates=, ingest=)`` / ``python -m repro.fleet
--scenario rw`` for a sharded fleet.
"""
from repro.ingest.compaction import IngestAgent, IngestConfig
from repro.ingest.memtable import DeltaEntry, Memtable
from repro.ingest.metrics import (IngestReport, latency_during,
                                  merge_intervals)
from repro.ingest.mutable import (MutableClusterIndex, MutableGraphIndex,
                                  make_mutable)
from repro.ingest.stream import (UpdateOp, UpdateStream, churn_ground_truth,
                                 churned_corpus, synth_updates)

__all__ = [
    "IngestAgent", "IngestConfig", "IngestReport",
    "Memtable", "DeltaEntry",
    "MutableClusterIndex", "MutableGraphIndex", "make_mutable",
    "UpdateOp", "UpdateStream", "synth_updates", "churned_corpus",
    "churn_ground_truth", "latency_during", "merge_intervals",
]
