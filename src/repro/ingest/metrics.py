"""Ingest-side measurement: freshness, write amplification, compaction
pressure.

Two freshness clocks per update (both in virtual seconds):

* **visibility lag** — arrival → applied to a delta tier (searchable).
  Grows when the apply window backs up behind a write burst.
* **seal lag** — arrival → folded into the sealed objects by a flush.
  Grows with the delta capacity (bigger memtables flush later) and with
  compaction queueing (a storm of flush jobs serialises behind
  ``compaction_parallelism``).

Write amplification is measured, not modelled: compaction bytes written
divided by payload bytes ingested (rewriting a whole posting list to add
one vector is the cloud-native update tax both follow-up papers call
out).  Compaction busy intervals are recorded so serving reports can
slice query latency into during/outside-compaction populations.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _lag_stats(lags: list[float]) -> dict:
    if not lags:
        return dict(n=0, mean_s=0.0, p99_s=0.0, max_s=0.0)
    a = np.asarray(lags)
    return dict(n=len(a), mean_s=round(float(a.mean()), 9),
                p99_s=round(float(np.percentile(a, 99)), 9),
                max_s=round(float(a.max()), 9))


def merge_intervals(intervals: list[tuple[float, float]]
                    ) -> list[tuple[float, float]]:
    """Coalesce overlapping (t0, t1) busy windows."""
    out: list[list[float]] = []
    for t0, t1 in sorted(intervals):
        if out and t0 <= out[-1][1]:
            out[-1][1] = max(out[-1][1], t1)
        else:
            out.append([t0, t1])
    return [(a, b) for a, b in out]


def latency_during(records, intervals: list[tuple[float, float]],
                   invert: bool = False) -> list[float]:
    """Latencies of queries whose service overlapped (or, with
    ``invert``, avoided) any compaction busy window."""
    merged = merge_intervals(intervals)

    def overlaps(r) -> bool:
        return any(r.start_t < t1 and r.end_t > t0 for t0, t1 in merged)

    return [r.latency for r in records if overlaps(r) != invert]


@dataclasses.dataclass
class IngestReport:
    """Aggregated over every :class:`IngestAgent` of a run (a fleet run
    appends all sites into one report)."""

    ops_delivered: int = 0
    inserts_applied: int = 0
    deletes_applied: int = 0
    bytes_ingested: int = 0               # applied insert payload bytes
    visibility_lags: list = dataclasses.field(default_factory=list)
    seal_lags: list = dataclasses.field(default_factory=list)
    # compaction I/O (charged through StorageSim)
    compaction_read_bytes: int = 0
    compaction_read_requests: int = 0
    compaction_write_bytes: int = 0
    compaction_write_requests: int = 0
    flushes: int = 0
    lists_rewritten: int = 0
    blocks_rewritten: int = 0
    reclusters: int = 0
    repairs: int = 0                      # robust-prune reruns (graph)
    overflow_applies: int = 0             # applies past the hard delta cap
    intervals: list = dataclasses.field(default_factory=list)
    peak_delta_bytes: int = 0
    final_delta_bytes: int = 0
    unsealed: int = 0                     # updates still delta-only at end
    #: optional live hook ``fn(kind, lag_s)`` called on every apply —
    #: the fleet monitor subscribes its freshness-lag SLO here.  Not
    #: data: excluded from comparison and repr, never serialized.
    on_apply: object = dataclasses.field(default=None, repr=False,
                                         compare=False)

    # ------------------------------------------------------------ derived --
    @property
    def updates_applied(self) -> int:
        return self.inserts_applied + self.deletes_applied

    @property
    def write_amplification(self) -> float:
        """Compaction bytes written per payload byte ingested."""
        if self.bytes_ingested == 0:
            return 0.0
        return self.compaction_write_bytes / self.bytes_ingested

    @property
    def compaction_busy_s(self) -> float:
        return sum(t1 - t0 for t0, t1 in merge_intervals(self.intervals))

    def record_apply(self, kind: str, lag: float, nbytes: int) -> None:
        if kind == "insert":
            self.inserts_applied += 1
            self.bytes_ingested += nbytes
        else:
            self.deletes_applied += 1
        self.visibility_lags.append(lag)
        if self.on_apply is not None:
            self.on_apply(kind, lag)

    def record_seal(self, lags: list[float]) -> None:
        self.seal_lags.extend(lags)

    # --------------------------------------------------------------- JSON --
    def to_dict(self, records=None) -> dict:
        out = dict(
            ops_delivered=self.ops_delivered,
            inserts_applied=self.inserts_applied,
            deletes_applied=self.deletes_applied,
            bytes_ingested=self.bytes_ingested,
            visibility_lag=_lag_stats(self.visibility_lags),
            seal_lag=_lag_stats(self.seal_lags),
            unsealed=self.unsealed,
            flushes=self.flushes,
            lists_rewritten=self.lists_rewritten,
            blocks_rewritten=self.blocks_rewritten,
            reclusters=self.reclusters,
            repairs=self.repairs,
            overflow_applies=self.overflow_applies,
            compaction_read_bytes=self.compaction_read_bytes,
            compaction_read_requests=self.compaction_read_requests,
            compaction_write_bytes=self.compaction_write_bytes,
            compaction_write_requests=self.compaction_write_requests,
            write_amplification=round(self.write_amplification, 4),
            compaction_busy_s=round(self.compaction_busy_s, 9),
            peak_delta_bytes=self.peak_delta_bytes,
            final_delta_bytes=self.final_delta_bytes,
        )
        if records is not None:
            during = latency_during(records, self.intervals)
            outside = latency_during(records, self.intervals, invert=True)
            out["queries_during_compaction"] = len(during)
            out["query_p50_during_compaction_s"] = round(
                float(np.percentile(during, 50)), 9) if during else 0.0
            out["query_p99_during_compaction_s"] = round(
                float(np.percentile(during, 99)), 9) if during else 0.0
            out["query_p50_outside_compaction_s"] = round(
                float(np.percentile(outside, 50)), 9) if outside else 0.0
            out["query_p99_outside_compaction_s"] = round(
                float(np.percentile(outside, 99)), 9) if outside else 0.0
        return out
