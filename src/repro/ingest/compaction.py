"""Background maintenance under live traffic, as discrete events.

An :class:`IngestAgent` owns one site's write path end to end:

* **apply** — update arrivals run through an :class:`AdmissionWindow`
  of width 1 (the third consumer of the shared admission helper): each
  apply costs ``apply_latency_s`` plus the priced assignment compute,
  so a write burst queues and *visibility lag* becomes measurable.
* **flush** — when the memtable crosses ``flush_frac × delta_cap_bytes``
  a flush job enters the compaction window (width
  ``compaction_parallelism``).  A flush reads the affected sealed
  objects, rewrites them with the delta folded in and tombstones
  dropped, and writes them back — **all bytes and requests go through
  the same** :class:`repro.storage.simulator.StorageSim` **that serves
  queries**, so compaction storms steal NIC bandwidth and GET tokens
  from live traffic and the p99 cost shows up in the report.
* **re-cluster** — a posting list that overflowed past
  ``overflow_factor ×`` the build-time average is split in two with a
  local 2-means (SPANN's balance repair), the BKT growing a level.
* **stitch / repair** (graph) — flushed inserts are Vamana-stitched:
  candidate discovery over the metadata-resident PQ+adjacency, exact
  vectors read from candidate blocks, ``_robust_prune`` for the new
  node and every back-edge-overflowed or delete-wounded neighbour,
  rewritten blocks written back.

Every job is a chain of kernel events (compute delays priced through
``plan_compute_seconds``); nothing polls, everything is deterministic.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.cost_model import ComputeSpec, plan_compute_seconds
from repro.ingest.metrics import IngestReport
from repro.ingest.mutable import MutableClusterIndex, MutableGraphIndex
from repro.ingest.stream import UpdateOp
from repro.sim.admission import AdmissionWindow
from repro.sim.kernel import Kernel

#: a compaction job that finds no live storage sim (its shard is down)
#: backs off this long before retrying
SIM_RETRY_S = 1e-3


@dataclasses.dataclass(frozen=True)
class IngestConfig:
    """The compaction knobs (also the tuner's ingest axis)."""

    delta_cap_bytes: int = 256 * 1024   # memtable capacity per site
    flush_frac: float = 0.5             # flush trigger (fraction of cap)
    compaction_parallelism: int = 1     # concurrent maintenance jobs
    apply_latency_s: float = 20e-6      # fixed per-update apply cost
    overflow_factor: float = 2.0        # list length vs build avg
    recluster: bool = True              # split overflowed lists
    graph_stitch_L: int = 32            # candidate pool per stitched node
    #: retired graph blocks stay readable (unlinked, unbilled) this many
    #: virtual seconds before a flush install may purge them — must
    #: exceed the longest a query sub-request can stay parked (shed
    #: backoff, fault windows) while holding a pre-compaction reference
    reclaim_grace_s: float = 1.0

    def __post_init__(self):
        if self.delta_cap_bytes <= 0:
            raise ValueError(f"delta_cap_bytes must be > 0, got "
                             f"{self.delta_cap_bytes}")
        if not 0.0 < self.flush_frac <= 1.0:
            raise ValueError(f"flush_frac must be in (0, 1], got "
                             f"{self.flush_frac}")
        if self.compaction_parallelism < 1:
            raise ValueError(f"compaction_parallelism must be >= 1, got "
                             f"{self.compaction_parallelism}")
        if self.overflow_factor <= 1.0:
            raise ValueError(f"overflow_factor must be > 1, got "
                             f"{self.overflow_factor}")
        if self.reclaim_grace_s < 0:
            raise ValueError(f"reclaim_grace_s must be >= 0, got "
                             f"{self.reclaim_grace_s}")

    def to_dict(self) -> dict:
        return dict(delta_cap_bytes=self.delta_cap_bytes,
                    flush_frac=self.flush_frac,
                    compaction_parallelism=self.compaction_parallelism,
                    apply_latency_s=self.apply_latency_s,
                    overflow_factor=self.overflow_factor,
                    recluster=self.recluster)


class IngestAgent:
    """One site's apply + compaction driver on the shared kernel."""

    def __init__(self, mutable, site_id: int, kernel: Kernel,
                 cfg: IngestConfig, compute: ComputeSpec,
                 sim_provider: Callable[[], object],
                 report: IngestReport,
                 invalidate: Callable[[object], None] | None = None,
                 on_new_list: Callable[[int, int], None] | None = None,
                 owned_lists: set | None = None,
                 inflight_floor: Callable[[], float] | None = None):
        self.mutable = mutable
        self.site_id = site_id
        self.kernel = kernel
        self.cfg = cfg
        self.compute = compute
        self.sim_provider = sim_provider
        self.report = report
        self.invalidate = invalidate or (lambda key: None)
        self.on_new_list = on_new_list
        self.owned_lists = owned_lists
        # earliest start time of any in-flight query (the serving
        # driver's view); corpses younger than it may still be
        # referenced by a parked sub-request, however long it parks
        self.inflight_floor = inflight_floor
        self.mem = mutable.site(site_id)
        self.dim = mutable.meta.dim
        pq = getattr(mutable.meta, "pq", None)
        self.pq_m = pq.m if pq is not None else 0
        self._apply_adm = AdmissionWindow(kernel, 1, self._start_apply)
        self._compact_adm = AdmissionWindow(
            kernel, cfg.compaction_parallelism, self._start_job)
        self._flush_outstanding = False
        self._job_seq = 0

    # ------------------------------------------------------------- apply --
    def deliver(self, op: UpdateOp, lists: tuple[int, ...] | None = None,
                ndist: int = 0) -> None:
        """An update reaches this site at the kernel's current time.
        ``lists``/``ndist``: a precomputed (router-side) posting-list
        assignment; otherwise the apply computes — and is charged — it."""
        self.report.ops_delivered += 1
        self._apply_adm.offer((op, lists, ndist), key=("op", op.seq))

    def _start_apply(self, item, t: float) -> None:
        op, lists, ndist = item
        if (op.kind == "insert" and lists is None
                and isinstance(self.mutable, MutableClusterIndex)):
            lists, ndist = self.mutable.assign_lists(op.vec)
        dt = self.cfg.apply_latency_s + plan_compute_seconds(
            ndist, 0, self.dim, self.pq_m, self.compute)
        self.kernel.at(t + dt, self._finish_apply, op, lists)

    def _finish_apply(self, op: UpdateOp,
                      lists: tuple[int, ...] | None) -> None:
        now = self.kernel.now
        self._apply_adm.pop_arrive_t(("op", op.seq))
        if op.kind == "insert":
            self.mem.insert(op.id, op.vec, lists or (), op.t, now)
            self.mutable.note_insert(op.id)
            nbytes = self.mem.entry_nbytes
        else:
            self.mem.delete(op.id, op.t)
            self.mutable.note_delete(op.id)
            nbytes = 0
        self.report.record_apply(op.kind, now - op.t, nbytes)
        tr = self.kernel.tracer
        if tr.enabled:
            tr.metrics.counter("ingest.applies").inc()
            tr.metrics.histogram("ingest.apply_lag_s").observe(now - op.t)
        if self.mem.used_bytes > self.cfg.delta_cap_bytes:
            self.report.overflow_applies += 1
        self._apply_adm.release(now)
        self._maybe_flush()

    # ----------------------------------------------------------- triggers --
    def _maybe_flush(self, force: bool = False) -> None:
        if self._flush_outstanding:
            return
        trigger = self.cfg.flush_frac * self.cfg.delta_cap_bytes
        if not (self.mem.entries or self.mem.tombstones):
            return
        if force or self.mem.used_bytes >= trigger:
            self._flush_outstanding = True
            self._job_seq += 1
            self._compact_adm.offer(("flush", self._job_seq))

    def flush_now(self) -> None:
        """Force a flush regardless of the trigger (drain / tests)."""
        self._maybe_flush(force=True)

    def _sim(self):
        return self.sim_provider()

    def _start_job(self, item, t: float) -> None:
        # claim the arrival record (jobs have no per-item sojourn
        # metric; unclaimed records would accumulate across a run)
        self._compact_adm.arrive_t.pop(item, None)
        kind = item[0]
        if self._sim() is None:            # shard down: back off
            self.kernel.after(SIM_RETRY_S, self._retry_job, item)
            return
        if kind == "flush":
            if isinstance(self.mutable, MutableGraphIndex):
                self._flush_graph(t)
            else:
                self._flush_cluster(t)
        else:
            self._recluster(item[1], t)

    def _retry_job(self, item) -> None:
        self._start_job(item, self.kernel.now)

    def _job_done(self, t0: float, kind: str = "flush") -> None:
        now = self.kernel.now
        tr = self.kernel.tracer
        if tr.enabled:
            # recorded retrospectively as one complete span: the job's
            # I/O runs through the shared storage sim, where ambient
            # kernel span context is not reliably this job's
            tr.record("compaction", t0, now, parent=None, kind=kind,
                      shard=self.site_id, instance=0)
            tr.metrics.counter(f"ingest.jobs.{kind}").inc()
        self.report.intervals.append((t0, now))
        self._compact_adm.release(now)
        self._maybe_flush()

    # ----------------------------------------------------- cluster flush --
    def _owned(self, lists) -> set[int]:
        s = set(int(li) for li in lists)
        return s if self.owned_lists is None else s & self.owned_lists

    def _flush_cluster(self, t0: float) -> None:
        meta = self.mutable.meta
        entries = dict(self.mem.entries)
        tombs = dict(self.mem.tombstones)
        affected: set[int] = set()
        for e in entries.values():
            affected |= self._owned(e.lists)
        for id_ in tombs:
            affected |= self._owned(self.mutable.lists_of(id_))
        affected_l = sorted(affected)
        if not affected_l:                 # nothing sealed to rewrite
            self._install_cluster([], entries, tombs, t0)
            return
        read_bytes = int(sum(meta.list_nbytes[li] for li in affected_l))
        self.report.compaction_read_bytes += read_bytes
        self.report.compaction_read_requests += len(affected_l)
        self._sim().submit_batch(
            read_bytes, len(affected_l),
            on_done=lambda tk: self._flush_cluster_write(
                affected_l, entries, tombs, t0))

    def _flush_cluster_write(self, affected, entries, tombs,
                             t0: float) -> None:
        tomb_ids = set(tombs)
        write_bytes = sum(self.mutable.rewrite_size(li, entries,
                                                    tomb_ids)
                          for li in affected)
        self.report.compaction_write_bytes += write_bytes
        self.report.compaction_write_requests += len(affected)
        self._sim().submit_batch(
            write_bytes, len(affected), put=True,
            on_done=lambda tk: self._install_cluster(
                affected, entries, tombs, t0))

    def _install_cluster(self, affected, entries, tombs,
                         t0: float) -> None:
        now = self.kernel.now
        tomb_ids = set(tombs)
        for li in affected:
            ids, vecs, nb = self.mutable.rewrite_list(li, entries,
                                                      tomb_ids)
            self.mutable.install_list(li, ids, vecs, nb)
            self.invalidate(("list", li))
        self.mem.clear_flushed(entries, tombs)
        self.report.record_seal(
            [now - e.arrive_t for _, e in sorted(entries.items())]
            + [now - at for _, at in sorted(tombs.items())])
        self.report.flushes += 1
        self.report.lists_rewritten += len(affected)
        self._flush_outstanding = False
        self._job_done(t0, "flush")
        if self.cfg.recluster:
            for li in affected:
                if self.mutable.overflowed(li, self.cfg.overflow_factor):
                    self.mutable.reclustering.add(li)
                    self._job_seq += 1
                    self._compact_adm.offer(
                        ("recluster", li, self._job_seq))

    # -------------------------------------------------------- re-cluster --
    def _recluster(self, li: int, t0: float) -> None:
        meta = self.mutable.meta
        if meta.list_lengths[li] <= self.cfg.overflow_factor \
                * self.mutable.base_avg_len:
            self.mutable.reclustering.discard(li)
            self._compact_adm.release(self.kernel.now)
            return
        nb = int(meta.list_nbytes[li])
        self.report.compaction_read_bytes += nb
        self.report.compaction_read_requests += 1
        self._sim().submit_batch(
            nb, 1, on_done=lambda tk: self._recluster_compute(li, t0))

    def _recluster_compute(self, li: int, t0: float) -> None:
        n = int(self.mutable.meta.list_lengths[li])
        dt = plan_compute_seconds(2 * n * 4, 0, self.dim, self.pq_m,
                                  self.compute)    # 2-means, 4 iters
        self.kernel.after(dt, self._recluster_write, li, t0)

    def _recluster_write(self, li: int, t0: float) -> None:
        nb = int(self.mutable.meta.list_nbytes[li])
        self.report.compaction_write_bytes += nb
        self.report.compaction_write_requests += 2
        self._sim().submit_batch(
            nb, 2, put=True,
            on_done=lambda tk: self._recluster_install(li, t0))

    def _recluster_install(self, li: int, t0: float) -> None:
        res = self.mutable.split_list(li)
        self.mutable.reclustering.discard(li)
        if res is not None:
            new_li, _moved, _payloads, _nb = res
            self.report.reclusters += 1
            # register the split before broadcasting staleness: the
            # invalidate consumer may need the new list's placement
            # (write-back tiers admit the rewritten object on its owners)
            if self.on_new_list is not None:
                self.on_new_list(new_li, li)
            self.invalidate(("list", li))
            self.invalidate(("list", new_li))
        self._job_done(t0, "recluster")

    # ------------------------------------------------------- graph flush --
    def _flush_graph(self, t0: float) -> None:
        mut: MutableGraphIndex = self.mutable
        entries = dict(self.mem.entries)
        tombs = dict(self.mem.tombstones)
        dels = [i for i in sorted(tombs) if i in mut._adj]
        cand_map: dict[int, np.ndarray] = {}
        n_pq = 0
        for id_ in sorted(entries):
            cands, npq = mut.graph_candidates(
                entries[id_].vec, L=self.cfg.graph_stitch_L)
            cands = cands[~np.isin(cands, dels)] if dels else cands
            cand_map[id_] = cands
            n_pq += npq
        # blocks the stitch/repair must read for exact vectors:
        # candidates + their adjacency (back-edge prune pools), deleted
        # nodes + their in-neighbours + both sides' adjacency.
        read_ids: set[int] = set()
        for cands in cand_map.values():
            for c in cands:
                read_ids.add(int(c))
                read_ids.update(int(x) for x in mut.adjacency(int(c)))
        for d in dels:
            read_ids.add(d)
            read_ids.update(int(x) for x in mut.adjacency(d))
            for u in mut.in_neighbors(d):
                read_ids.add(u)
                read_ids.update(int(x) for x in mut.adjacency(u))
        read_ids -= set(int(i) for i in mut.dead)
        dt = plan_compute_seconds(0, n_pq, self.dim, self.pq_m,
                                  self.compute)
        self.kernel.after(dt, self._flush_graph_read, entries, tombs,
                          dels, cand_map, sorted(read_ids), t0)

    def _flush_graph_read(self, entries, tombs, dels, cand_map,
                          read_ids, t0: float) -> None:
        nb = self.mutable.node_nbytes()
        if read_ids:
            self.report.compaction_read_bytes += nb * len(read_ids)
            self.report.compaction_read_requests += len(read_ids)
            self._sim().submit_batch(
                nb * len(read_ids), len(read_ids),
                on_done=lambda tk: self._flush_graph_stitch(
                    entries, tombs, dels, cand_map, t0))
        else:
            self._flush_graph_stitch(entries, tombs, dels, cand_map, t0)

    def _flush_graph_stitch(self, entries, tombs, dels, cand_map,
                            t0: float) -> None:
        mut: MutableGraphIndex = self.mutable
        del_set = set(dels)
        new_nodes: dict[int, tuple] = {}
        rewrites: dict[int, np.ndarray] = {}
        d_dist = 0

        def vec_of(i: int) -> np.ndarray:
            if i in new_nodes:
                return np.asarray(new_nodes[i][0], dtype=np.float32)
            if i in entries:
                return np.asarray(entries[i].vec, dtype=np.float32)
            return np.asarray(self.mutable.store.get(("node", i))[0],
                              dtype=np.float32)

        def adj_of(i: int) -> np.ndarray:
            if i in rewrites:
                return rewrites[i]
            if i in new_nodes:
                return np.asarray(new_nodes[i][1], dtype=np.int64)
            return mut.adjacency(i)

        # ---- stitch inserts ----
        for id_ in sorted(entries):
            e = entries[id_]
            cands = cand_map[id_]
            cands = cands[[int(c) not in del_set for c in cands]] \
                if len(cands) else cands
            if len(cands) == 0:
                cands = np.asarray([mut.meta.medoid], dtype=np.int64)
            cvecs = np.stack([vec_of(int(c)) for c in cands])
            sel = mut.stitch_insert(id_, e.vec, cands, cvecs)
            d_dist += len(cands) * (len(cands) + 1)
            new_nodes[id_] = (e.vec, sel)
            for tgt in sorted(int(x) for x in sel):
                merged = np.unique(np.append(adj_of(tgt), id_))
                merged = merged[[int(x) not in del_set for x in merged]]
                mvecs = np.stack([vec_of(int(x)) for x in merged])
                rep = mut.repair_adjacency(tgt, vec_of(tgt), merged,
                                           mvecs)
                if tgt in new_nodes:       # back-edge onto a sibling
                    new_nodes[tgt] = (new_nodes[tgt][0], rep)
                else:
                    rewrites[tgt] = rep
                d_dist += len(merged) * (len(merged) + 1)
                self.report.repairs += 1
        # ---- repair around deletes (stitch through the hole) ----
        for d in dels:
            d_adj = mut.adjacency(d)
            d_adj = d_adj[[int(x) not in del_set for x in d_adj]]
            for u in mut.in_neighbors(d):
                if u in del_set or u in new_nodes:
                    continue
                cur = adj_of(u)
                merged = np.unique(np.concatenate(
                    [cur[cur != d], d_adj]))
                merged = merged[[int(x) not in del_set for x in merged]]
                mvecs = (np.stack([vec_of(int(x)) for x in merged])
                         if len(merged) else
                         np.zeros((0, self.dim), np.float32))
                rewrites[u] = mut.repair_adjacency(
                    u, vec_of(u), merged, mvecs)
                d_dist += len(merged) * (len(merged) + 1)
                self.report.repairs += 1
        dt = plan_compute_seconds(d_dist, 0, self.dim, self.pq_m,
                                  self.compute)
        self.kernel.after(dt, self._flush_graph_write, entries, tombs,
                          new_nodes, rewrites, dels, t0)

    def _flush_graph_write(self, entries, tombs, new_nodes, rewrites,
                           dels, t0: float) -> None:
        nb = self.mutable.node_nbytes()
        n_blocks = len(new_nodes) + len(
            [r for r in rewrites if r not in new_nodes])
        n_writes = n_blocks + len(dels)
        if n_writes == 0:
            self._flush_graph_install(entries, tombs, new_nodes,
                                      rewrites, dels, t0)
            return
        self.report.compaction_write_bytes += nb * n_blocks
        self.report.compaction_write_requests += n_writes
        self._sim().submit_batch(
            max(1, nb * n_blocks), n_writes, put=True,
            on_done=lambda tk: self._flush_graph_install(
                entries, tombs, new_nodes, rewrites, dels, t0))

    def _flush_graph_install(self, entries, tombs, new_nodes, rewrites,
                             dels, t0: float) -> None:
        now = self.kernel.now
        # reclaim corpses no in-flight query can reference: a query that
        # started after a block's unlink can never reach it (its wounded
        # neighbours were rewritten in the same install), so purge up to
        # the oldest in-flight query's start — parked sub-requests (shed
        # backoff, fault windows) keep their query in flight and their
        # corpses alive however long they park.  The grace window is a
        # belt-and-braces cap for drivers that supply no floor.
        floor = self.inflight_floor() if self.inflight_floor is not None \
            else now
        self.mutable.store.purge_lingering(
            before=min(now - self.cfg.reclaim_grace_s, floor))
        stale = self.mutable.install_graph(new_nodes, rewrites, dels,
                                           t=now)
        self.mem.clear_flushed(entries, tombs)
        self.report.record_seal(
            [now - e.arrive_t for _, e in sorted(entries.items())]
            + [now - at for _, at in sorted(tombs.items())])
        self.report.flushes += 1
        self.report.blocks_rewritten += len(stale)
        for key in stale:
            self.invalidate(key)
        self._flush_outstanding = False
        self._job_done(t0, "flush")

    # ---------------------------------------------------------- finalize --
    def finalize(self) -> None:
        self.report.unsealed += (len(self.mem.entries)
                                 + len(self.mem.tombstones))
        self.report.peak_delta_bytes = max(self.report.peak_delta_bytes,
                                           self.mem.peak_bytes)
        self.report.final_delta_bytes += self.mem.used_bytes
