"""Update streams: the write side of a read-write workload.

An :class:`UpdateStream` is an explicit, pre-materialised sequence of
timestamped insert/delete operations — the write analogue of
:class:`repro.sim.arrivals.Trace`.  Pre-materialising (rather than
drawing from a kernel RNG stream at run time) keeps the *query* side of
a mixed run byte-identical to the pure-query run: the stream is fixed
before the kernel exists, so a zero-write run schedules zero events and
reproduces the closed-loop golden reports bit-exactly.

:func:`synth_updates` builds a production-style stream from the dataset:
Poisson arrival times at ``rate_qps``; inserts are perturbed points from
the data manifold (new ids above the sealed range), deletes pick live
ids uniformly (never an id already deleted, optionally never a protected
id such as a graph medoid).  :func:`churned_corpus` materialises the
corpus the stream leaves behind, for ground-truth recall under churn.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.sim.kernel import Kernel


@dataclasses.dataclass(frozen=True)
class UpdateOp:
    """One timestamped update."""

    t: float
    seq: int
    kind: str                  # "insert" | "delete"
    id: int
    vec: np.ndarray | None = None     # insert payload


class UpdateStream:
    """An ordered sequence of updates, schedulable on a kernel."""

    def __init__(self, ops: list[UpdateOp]):
        if any(b.t < a.t for a, b in zip(ops, ops[1:])):
            raise ValueError("update times must be non-decreasing")
        self.ops = list(ops)

    def __len__(self) -> int:
        return len(self.ops)

    @property
    def n_inserts(self) -> int:
        return sum(1 for op in self.ops if op.kind == "insert")

    @property
    def n_deletes(self) -> int:
        return len(self.ops) - self.n_inserts

    @property
    def bytes_ingested(self) -> int:
        """Payload bytes the stream writes (inserted vectors + 8B ids)."""
        return sum(op.vec.nbytes + 8 for op in self.ops
                   if op.vec is not None)

    def start(self, kernel: Kernel,
              deliver: Callable[[UpdateOp], None]) -> None:
        """Schedule every op at its timestamp.  An empty stream schedules
        nothing — the zero-write invariant the rw scenario relies on."""
        for op in self.ops:
            kernel.at(op.t, deliver, op)

    def to_dict(self) -> dict:
        return dict(n_updates=len(self.ops), n_inserts=self.n_inserts,
                    n_deletes=self.n_deletes,
                    bytes_ingested=self.bytes_ingested)


def synth_updates(data: np.ndarray, rate_qps: float, n_updates: int,
                  delete_frac: float = 0.2, seed: int = 0,
                  protected: frozenset | None = None,
                  jitter: float = 0.05) -> UpdateStream:
    """A synthetic churn stream against ``data`` (the sealed corpus).

    Inserts are existing points plus small manifold-scale noise — the
    recommender/RAG regime where new vectors land near old ones, so they
    genuinely compete for top-k slots.  New ids start at ``len(data)``.
    Deletes draw uniformly from the live set (sealed ∪ inserted − already
    deleted), excluding ``protected`` ids.
    """
    if rate_qps <= 0:
        raise ValueError(f"rate_qps must be > 0, got {rate_qps}")
    if not 0.0 <= delete_frac < 1.0:
        raise ValueError(f"delete_frac must be in [0, 1), got "
                         f"{delete_frac}")
    rng = np.random.default_rng((seed, 0x1463E57))
    n = len(data)
    times = np.cumsum(rng.exponential(1.0 / rate_qps, size=n_updates))
    scale = float(np.std(data.astype(np.float64))) * jitter
    protected = protected or frozenset()
    live = [i for i in range(n) if i not in protected]
    live_set = set(live)
    next_id = n
    ops: list[UpdateOp] = []
    for s in range(n_updates):
        is_delete = (rng.uniform() < delete_frac) and len(live) > 1
        if is_delete:
            # lazily compact the live list of stale (deleted) ids
            while True:
                victim = live[int(rng.integers(len(live)))]
                if victim in live_set:
                    break
            live_set.discard(victim)
            live = [i for i in live if i in live_set] \
                if len(live) > 2 * len(live_set) else live
            ops.append(UpdateOp(t=float(times[s]), seq=s, kind="delete",
                                id=victim))
        else:
            src = int(rng.integers(n))
            vec = data[src].astype(np.float64) + rng.normal(
                0.0, scale, size=data.shape[1])
            vec = vec.astype(data.dtype) if data.dtype != np.int8 else \
                np.clip(np.round(vec), -127, 127).astype(np.int8)
            ops.append(UpdateOp(t=float(times[s]), seq=s, kind="insert",
                                id=next_id, vec=vec))
            live_set.add(next_id)
            live.append(next_id)
            next_id += 1
    return UpdateStream(ops)


def churned_corpus(data: np.ndarray, stream: UpdateStream
                   ) -> tuple[np.ndarray, np.ndarray]:
    """The corpus after the whole stream applies: (vectors, ids).

    Ground truth for recall-under-churn: exact top-k over this corpus is
    what a fully-compacted (or freshly rebuilt) index must return.
    """
    vecs: dict[int, np.ndarray] = {i: data[i] for i in range(len(data))}
    for op in stream.ops:
        if op.kind == "insert":
            vecs[op.id] = op.vec
        else:
            vecs.pop(op.id, None)
    ids = np.array(sorted(vecs), dtype=np.int64)
    return np.stack([vecs[i] for i in ids]), ids


def churn_ground_truth(data: np.ndarray, stream: UpdateStream,
                       queries: np.ndarray, k: int) -> np.ndarray:
    """Exact top-``k`` ids per query against the post-churn corpus."""
    from repro.core.flat import exact_topk
    corpus, ids = churned_corpus(data, stream)
    idx, _ = exact_topk(corpus, queries, k)
    return ids[idx]
