"""Mutable views over the two sealed index families.

A wrapper owns the *reconciliation* between the immutable sealed tier
(posting-list / node-block objects on the store, built once) and the
delta tier (:mod:`repro.ingest.memtable`):

* it exposes the same serving surface as the wrapped index (``meta``,
  ``store``, ``search_plan``, ``select_lists``), so every engine, shard
  server, partitioner and tuner path works unchanged;
* merged search = sealed search ∪ brute-force delta scan, unified
  through :func:`repro.core.cluster_index.dedup_topk` with tombstone
  filtering — the invariant under test is that a deleted id can never
  surface and a zero-delta search is bit-identical to the sealed one;
* it provides the *pure* mutation kernels (assignment, list rewrite,
  list split, graph stitch/repair via ``_robust_prune``) that
  :mod:`repro.ingest.compaction` drives as kernel events, charging the
  I/O to a :class:`repro.storage.simulator.StorageSim`.

Sites: update application is per *site* (the single engine, or one
fleet shard group).  Each site holds its own memtable + tombstones —
delta-tier replication, mirroring the sealed replication — and flushes
independently; rewrites are computed at install time from current
sealed content, so replica flushes are idempotent.
"""
from __future__ import annotations

import numpy as np

from repro.core import kmeans as km
from repro.core.cluster_index import ClusterIndex, dedup_topk
from repro.core.distances import np_sq_l2
from repro.core.graph_index import GraphIndex, _robust_prune
from repro.core.types import QueryMetrics, SearchParams, SearchResult
from repro.ingest.memtable import ID_BYTES, Memtable


def _merge_results(base: SearchResult, extra_ids: np.ndarray,
                   extra_d: np.ndarray, dead: np.ndarray, k: int
                   ) -> SearchResult:
    """Union the sealed top-k with delta hits; drop tombstoned ids; pad
    back to k through the same ``dedup_topk`` kernel every other merge in
    the repo uses."""
    ids = base.ids[base.ids >= 0]
    d = base.dists[: len(ids)]
    if len(dead):
        keep = ~np.isin(ids, dead)
        ids, d = ids[keep], d[keep]
    if len(extra_ids):
        ids = np.concatenate([ids, extra_ids])
        d = np.concatenate([d, extra_d.astype(np.float32)])
    out_ids, out_d = dedup_topk(ids, d.astype(np.float32), k)
    return SearchResult(out_ids, out_d, base.metrics)


class _MutableBase:
    """Shared site/tombstone bookkeeping for both index families."""

    def __init__(self, base):
        self.base = base
        self.meta = base.meta
        self.store = base.store
        self.sites: dict[int, Memtable] = {}
        # applied deletes, not re-inserted.  Append-only by design: a
        # plan in flight may still hold a pre-compaction payload that
        # contains a flushed-out victim, so the filter must outlive the
        # install.  The sorted-array mirror keeps the per-scan filter a
        # single vectorised isin instead of a per-query set walk.
        self.deleted: set[int] = set()
        self._deleted_arr: np.ndarray | None = None
        self.live_count = base.meta.n_data

    def site(self, site_id: int) -> Memtable:
        if site_id not in self.sites:
            self.sites[site_id] = Memtable(self._vec_nbytes())
        return self.sites[site_id]

    @property
    def delta_bytes(self) -> int:
        return sum(m.used_bytes for m in self.sites.values())

    @property
    def has_delta(self) -> bool:
        return any(m.entries or m.tombstones for m in self.sites.values())

    def _delta_scan(self, q: np.ndarray, k: int, m: QueryMetrics
                    ) -> tuple[np.ndarray, np.ndarray]:
        """Brute-force scan across every site's live delta (sites in id
        order for determinism), charging comps to ``m``."""
        all_ids, all_d = [], []
        for sid in sorted(self.sites):
            ids, d, nc = self.sites[sid].search(q, k)
            m.dist_comps += nc
            if len(ids):
                all_ids.append(ids)
                all_d.append(d)
        if not all_ids:
            return np.zeros(0, np.int64), np.zeros(0, np.float32)
        return np.concatenate(all_ids), np.concatenate(all_d)

    def search_plan(self, q, params: SearchParams,
                    metrics: QueryMetrics | None = None):
        """Merged plan: the sealed plan's fetches pass through untouched
        (same batches, same event sequence), the delta merge happens in
        the final compute step.  With no delta and no tombstones the
        sealed result is returned as-is — bit-exact with the wrapped
        index."""
        m = metrics if metrics is not None else QueryMetrics()
        base_res = yield from self.base.search_plan(q, params, m)
        if not self.has_delta and not self.deleted:
            return base_res
        return self.merge_result(q, params.k, base_res, m)

    def merge_result(self, q, k: int, base_res: SearchResult,
                     m: QueryMetrics) -> SearchResult:
        """Delta-merge + tombstone-filter a sealed result (also the hook
        the fleet router calls after its scatter-gather plan finishes)."""
        extra_ids, extra_d = self._delta_scan(q, k, m)
        return _merge_results(base_res, extra_ids, extra_d,
                              self.deleted_array(), k)

    def deleted_array(self) -> np.ndarray:
        """Sorted array mirror of ``deleted`` (cached between deletes)."""
        if self._deleted_arr is None:
            self._deleted_arr = np.fromiter(
                sorted(self.deleted), dtype=np.int64,
                count=len(self.deleted))
        return self._deleted_arr

    def search(self, q, params: SearchParams) -> SearchResult:
        gen = self.search_plan(q, params)
        try:
            batch = next(gen)
            while True:
                payloads = {r.key: self.store.get(r.key)
                            for r in batch.requests}
                batch = gen.send(payloads)
        except StopIteration as stop:
            return stop.value

    # ---------------------------------------------------------- applies --
    def note_insert(self, id_: int) -> None:
        if id_ in self.deleted:
            self.deleted.discard(id_)
            self._deleted_arr = None

    def note_delete(self, id_: int) -> None:
        if id_ not in self.deleted:
            self.deleted.add(id_)
            self._deleted_arr = None


class MutableClusterIndex(_MutableBase):
    """SPANN-style index with a delta tier and rewriting compaction."""

    kind = "cluster"

    def __init__(self, base: ClusterIndex):
        super().__init__(base)
        self.use_bkt = base.use_bkt
        # sealed membership: id -> set of posting lists currently holding
        # a copy (delete routing + idempotent flush accounting)
        self._id_lists: dict[int, set[int]] = {}
        for li in range(self.meta.n_lists):
            ids, _ = self.store.get(("list", li))
            for i in ids:
                self._id_lists.setdefault(int(i), set()).add(li)
        # overflow reference: the build-time average list length
        self.base_avg_len = max(1.0, float(self.meta.list_lengths.mean()))
        self._leaf_node: dict[int, int] = {
            node.leaf_id: ni for ni, node in enumerate(self.meta.tree.nodes)
            if not node.children}
        self.reclustering: set[int] = set()

    def _vec_nbytes(self) -> int:
        return self.meta.dim * np.dtype(self.meta.dtype).itemsize

    @property
    def entry_nbytes(self) -> int:
        return self._vec_nbytes() + ID_BYTES

    # ---------------------------------------------------------- serving --
    def select_lists(self, q, nprobe):
        return self.base.select_lists(q, nprobe)

    # ------------------------------------------------------- assignment --
    def assign_lists(self, vec: np.ndarray) -> tuple[tuple[int, ...], int]:
        """Closure-replicated assignment of one vector against the
        current leaf centroids (the build rule, applied incrementally).
        Returns (list ids, distance comps to charge)."""
        cents = self.meta.tree.centroids
        d = np_sq_l2(np.asarray(vec, dtype=np.float32), cents)
        p = self.meta.params
        r = min(p.num_replica, len(cents))
        idx = np.argsort(d, kind="stable")[:r]
        thresh = (1.0 + p.closure_eps) ** 2 * d[idx[0]] + 1e-12
        keep = idx[d[idx] <= thresh]
        if len(keep) == 0:
            keep = idx[:1]
        return tuple(int(i) for i in keep), len(cents)

    def lists_of(self, id_: int) -> tuple[int, ...]:
        """Sealed posting lists currently holding ``id_``."""
        return tuple(sorted(self._id_lists.get(id_, ())))

    # -------------------------------------------------------- compaction --
    def list_nbytes_of(self, ids_len: int) -> int:
        return max(1, ids_len * self.entry_nbytes)

    def rewrite_size(self, li: int, entries: dict,
                     tombstones: set) -> int:
        """Billable size of the rewrite — the flush's I/O sizing pass.
        Count-only: the content itself is materialised once, at
        install."""
        old_ids, _ = self.store.get(("list", li))
        delta_ids = [id_ for id_, e in entries.items() if li in e.lists]
        drop = tombstones | set(delta_ids)
        n_keep = len(old_ids)
        if drop and len(old_ids):
            n_keep -= int(np.isin(
                old_ids, np.fromiter(drop, dtype=np.int64)).sum())
        return self.list_nbytes_of(n_keep + len(delta_ids))

    def rewrite_list(self, li: int, entries: dict, tombstones: set
                     ) -> tuple[np.ndarray, np.ndarray, int]:
        """Pure rewrite kernel: sealed content − tombstones + the delta
        entries destined for ``li`` (delta copy wins on id collision).
        Idempotent — a replica site flushing the same entries later
        reproduces the same content."""
        old_ids, old_vecs = self.store.get(("list", li))
        delta = {id_: e for id_, e in entries.items() if li in e.lists}
        drop = tombstones | set(delta)
        if drop and len(old_ids):
            keep = ~np.isin(old_ids, np.fromiter(drop, dtype=np.int64))
            old_ids, old_vecs = old_ids[keep], old_vecs[keep]
        if delta:
            add_ids = np.array(sorted(delta), dtype=np.int64)
            add_vecs = np.stack([delta[i].vec for i in sorted(delta)]
                                ).astype(old_vecs.dtype if len(old_vecs)
                                         else self.meta.dtype)
            new_ids = np.concatenate([old_ids, add_ids])
            new_vecs = np.concatenate([
                old_vecs if len(old_vecs) else
                np.zeros((0, self.meta.dim), add_vecs.dtype), add_vecs])
        else:
            new_ids, new_vecs = old_ids, old_vecs
        return new_ids, new_vecs, self.list_nbytes_of(len(new_ids))

    def install_list(self, li: int, ids: np.ndarray, vecs: np.ndarray,
                     nbytes: int) -> None:
        """Swap in a rewritten posting list and reconcile membership and
        live-count bookkeeping (idempotent across replica flushes)."""
        old_ids, _ = self.store.get(("list", li))
        self.store.put(("list", li), (ids, vecs), nbytes)
        self.meta.list_lengths[li] = len(ids)
        self.meta.list_nbytes[li] = nbytes
        removed = set(int(i) for i in old_ids) - set(int(i) for i in ids)
        added = set(int(i) for i in ids) - set(int(i) for i in old_ids)
        for i in removed:
            s = self._id_lists.get(i)
            if s is not None:
                s.discard(li)
                if not s:
                    del self._id_lists[i]
                    self.live_count -= 1
        for i in added:
            s = self._id_lists.get(i)
            if s is None:
                self._id_lists[i] = {li}
                self.live_count += 1
            else:
                s.add(li)
        self.meta.n_data = self.live_count

    # --------------------------------------------------------- overflow --
    def overflowed(self, li: int, factor: float) -> bool:
        return (li not in self.reclustering
                and self.meta.list_lengths[li] > factor * self.base_avg_len)

    def split_list(self, li: int
                   ) -> tuple[int, dict[int, int], list, int] | None:
        """Split an overflowed posting list in two with a local 2-means
        (the SPANN re-cluster step).  Returns (new list id, moved id →
        new list, [payloads for (li, new_li)], write bytes), or None when
        the list refuses to split (degenerate geometry).

        The caller owns scheduling, I/O pricing and cache invalidation;
        this method only installs the new sealed state + tree surgery:
        the overflowed leaf becomes an internal node with two leaf
        children, so BKT descent and flat centroid search both route to
        the halves."""
        ids, vecs = self.store.get(("list", li))
        if len(ids) < 4:
            return None
        rng = np.random.default_rng((int(li), 0x5EED))
        cents, assign = km.kmeans_np(
            np.asarray(vecs, dtype=np.float32), 2, iters=4, rng=rng)
        if (assign == 0).all() or (assign == 1).all():
            return None
        new_li = self.meta.n_lists
        keep_ids, keep_vecs = ids[assign == 0], vecs[assign == 0]
        move_ids, move_vecs = ids[assign == 1], vecs[assign == 1]
        tree = self.meta.tree
        old_node_i = self._leaf_node[li]
        old_node = tree.nodes[old_node_i]
        n_a = km._Node(center=cents[0], children=[], leaf_id=li)
        n_b = km._Node(center=cents[1], children=[], leaf_id=new_li)
        tree.nodes.append(n_a)
        tree.nodes.append(n_b)
        ia, ib = len(tree.nodes) - 2, len(tree.nodes) - 1
        old_node.children = [ia, ib]
        old_node.leaf_id = -1
        self._leaf_node[li] = ia
        self._leaf_node[new_li] = ib
        tree.centroids = np.concatenate(
            [tree.centroids, cents[1][None]], axis=0)
        tree.centroids[li] = cents[0]
        # sealed state
        nb_a = self.list_nbytes_of(len(keep_ids))
        nb_b = self.list_nbytes_of(len(move_ids))
        self.store.put(("list", li), (keep_ids, keep_vecs), nb_a)
        self.store.put(("list", new_li), (move_ids, move_vecs), nb_b)
        self.meta.list_lengths = np.concatenate(
            [self.meta.list_lengths,
             np.array([len(move_ids)], dtype=np.int32)])
        self.meta.list_lengths[li] = len(keep_ids)
        self.meta.list_nbytes = np.concatenate(
            [self.meta.list_nbytes, np.array([nb_b], dtype=np.int64)])
        self.meta.list_nbytes[li] = nb_a
        moved = {int(i): new_li for i in move_ids}
        for i in move_ids:
            s = self._id_lists.get(int(i))
            if s is not None and li in s:
                s.discard(li)
                s.add(new_li)
        for mem in self.sites.values():
            mem.remap_list(li, moved)
        return new_li, moved, [(keep_ids, keep_vecs), (move_ids, move_vecs)], \
            nb_a + nb_b


class MutableGraphIndex(_MutableBase):
    """DiskANN-style index with delta nodes and stitch/repair compaction.

    The adjacency mirror + reverse-edge map live in compute-node memory
    alongside the PQ codes (the same metadata class the paper's §2.1
    node caches); the node *blocks* on the object store remain the
    truth the compactor reads (for exact vectors) and rewrites.
    """

    kind = "graph"

    def __init__(self, base: GraphIndex):
        super().__init__(base)
        n = self.meta.n_data
        self._adj: dict[int, np.ndarray] = {}
        self._rev: dict[int, set[int]] = {}
        for i in range(n):
            _, nbrs = self.store.get(("node", i))
            nbrs = nbrs[nbrs >= 0].astype(np.int64)
            self._adj[i] = nbrs
            for t in nbrs:
                self._rev.setdefault(int(t), set()).add(i)
        self.dead: set[int] = set()         # flushed (sealed) deletes

    def _vec_nbytes(self) -> int:
        return self.meta.dim * np.dtype(self.meta.dtype).itemsize

    def adjacency(self, id_: int) -> np.ndarray:
        return self._adj.get(id_, np.zeros(0, dtype=np.int64))

    def in_neighbors(self, id_: int) -> tuple[int, ...]:
        return tuple(sorted(self._rev.get(id_, ())))

    # ------------------------------------------------------- candidates --
    def graph_candidates(self, vec: np.ndarray, L: int = 48
                         ) -> tuple[np.ndarray, int]:
        """Metadata-resident greedy search (PQ distances over the
        adjacency mirror) producing the candidate pool an insert's
        RobustPrune consumes.  Returns (candidate ids, pq comps)."""
        meta = self.meta
        table = meta.pq.adc_table(np.asarray(vec, dtype=np.float32))
        start = meta.medoid
        dists = {start: float(meta.pq.adc_lookup(
            meta.codes[start][None], table)[0])}
        n_pq = 1
        expanded: set[int] = set()
        frontier = {start}
        for _ in range(L + 8):
            cand = [(d, i) for i, d in dists.items() if i not in expanded]
            if not cand or len(expanded) >= L:
                break
            cand.sort()
            _, node = cand[0]
            expanded.add(node)
            nbrs = [int(t) for t in self._adj.get(node, ())
                    if int(t) not in dists and int(t) not in self.dead]
            if nbrs:
                codes = meta.codes[np.asarray(nbrs, dtype=np.int64)]
                dd = meta.pq.adc_lookup(codes, table)
                n_pq += len(nbrs)
                for t, d in zip(nbrs, dd):
                    dists[t] = float(d)
        out = np.asarray(sorted(expanded), dtype=np.int64)
        return out, n_pq

    # -------------------------------------------------------- compaction --
    def stitch_insert(self, id_: int, vec: np.ndarray,
                      cand_ids: np.ndarray, cand_vecs: np.ndarray
                      ) -> np.ndarray:
        """RobustPrune the candidate pool into the new node's adjacency
        (the Vamana insert rule, run incrementally)."""
        p = self.meta.params
        keep = cand_ids != id_
        cand_ids, cand_vecs = cand_ids[keep], cand_vecs[keep]
        if len(cand_ids) == 0:
            return np.zeros(0, dtype=np.int64)
        return _robust_prune(np.asarray(vec, dtype=np.float32),
                             cand_ids.astype(np.int64),
                             cand_vecs.astype(np.float32),
                             p.R, p.alpha)

    def repair_adjacency(self, node: int, node_vec: np.ndarray,
                         merged: np.ndarray, vecs: np.ndarray
                         ) -> np.ndarray:
        """Re-run RobustPrune over a node whose neighbourhood changed
        (back-edge overflow, or a deleted neighbour stitched around)."""
        p = self.meta.params
        keep = merged != node
        merged, vecs = merged[keep], vecs[keep]
        if len(merged) <= p.R:
            return merged.astype(np.int64)
        return _robust_prune(np.asarray(node_vec, dtype=np.float32),
                             merged.astype(np.int64),
                             vecs.astype(np.float32), p.R, p.alpha)

    def node_nbytes(self) -> int:
        return self.meta.node_nbytes

    def install_graph(self, new_nodes: dict[int, tuple[np.ndarray,
                                                       np.ndarray]],
                      rewrites: dict[int, np.ndarray],
                      removed: list[int], t: float = 0.0) -> list:
        """Atomically swap in a compaction round's sealed graph state.

        ``new_nodes``: id → (vector, adjacency); ``rewrites``: existing
        id → new adjacency; ``removed``: deleted ids whose blocks retire
        (``t``: the install's virtual time, stamped on the unlinked
        corpses for grace-based purging).
        Returns the store keys whose cached copies are now stale.
        """
        meta = self.meta
        p = meta.params
        stale = []
        # grow the PQ code matrix to cover the new id range
        max_id = max([meta.codes.shape[0] - 1]
                     + [i for i in new_nodes]) + 1
        if max_id > meta.codes.shape[0]:
            pad = np.zeros((max_id - meta.codes.shape[0], meta.pq.m),
                           dtype=meta.codes.dtype)
            meta.codes = np.concatenate([meta.codes, pad], axis=0)
        for id_ in sorted(new_nodes):
            vec, adj = new_nodes[id_]
            meta.codes[id_] = meta.pq.encode(
                np.asarray(vec, dtype=np.float32)[None])[0]
            self._set_adj(id_, adj)
            self.store.put(("node", id_), (vec, self._padded(adj, p.R)),
                           meta.node_nbytes)
            stale.append(("node", id_))
            self.live_count += 1
            self.dead.discard(id_)
        for id_ in sorted(rewrites):
            if id_ in new_nodes:
                continue
            adj = rewrites[id_]
            vec, _ = self.store.get(("node", id_))
            self._set_adj(id_, adj)
            self.store.put(("node", id_), (vec, self._padded(adj, p.R)),
                           meta.node_nbytes)
            stale.append(("node", id_))
        for id_ in sorted(removed):
            if ("node", id_) in self.store:
                self._retire(id_, t)
                stale.append(("node", id_))
        meta.n_data = max(meta.n_data, max_id)
        return stale

    def _padded(self, adj: np.ndarray, R: int) -> np.ndarray:
        out = np.full(R, -1, dtype=np.int32)
        adj = np.asarray(adj, dtype=np.int32)[:R]
        out[: len(adj)] = adj
        return out

    def _set_adj(self, id_: int, adj: np.ndarray) -> None:
        old = self._adj.get(id_)
        if old is not None:
            for t in old:
                self._rev.get(int(t), set()).discard(id_)
        adj = np.asarray(adj, dtype=np.int64)
        self._adj[id_] = adj
        for t in adj:
            self._rev.setdefault(int(t), set()).add(id_)

    def _retire(self, id_: int, t: float = 0.0) -> None:
        """Retire a repaired-around node: adjacency and reverse edges go,
        and the block is **unlinked** from the store — its bytes are
        reclaimed immediately, while the payload lingers readable for
        queries already in flight (a plan may hold a pre-compaction
        adjacency that still points at the victim; tombstone filtering
        keeps it out of their results).  Lingering corpses are purged by
        later flush installs once they outlive the reclaim grace window
        (covering readers parked by shed backoff or fault windows).
        Re-elects the medoid if the entry point died."""
        self.store.unlink(("node", id_), t=t)
        old = self._adj.pop(id_, None)
        if old is not None:
            for t in old:
                self._rev.get(int(t), set()).discard(id_)
        self._rev.pop(id_, None)
        self.dead.add(id_)
        self.live_count -= 1
        if id_ == self.meta.medoid:
            live_nbrs = [int(t) for t in (old if old is not None else ())
                         if int(t) in self._adj]
            if live_nbrs:
                self.meta.medoid = min(live_nbrs)
            else:
                self.meta.medoid = min(self._adj)


def make_mutable(index):
    """Wrap a sealed index in its mutable counterpart."""
    if isinstance(index, (MutableClusterIndex, MutableGraphIndex)):
        return index
    if isinstance(index, ClusterIndex):
        return MutableClusterIndex(index)
    if isinstance(index, GraphIndex):
        return MutableGraphIndex(index)
    raise TypeError(f"cannot make {type(index).__name__} mutable")
