"""Shared CLI plumbing for ``python -m repro.fleet`` / ``python -m
repro.tuning``.

Both CLIs previously duplicated seed/JSON/output handling; with scenario
serving they also share the whole scenario axis (``--scenario
{closed,poisson,burst,trace}`` plus rate/duration/SLO knobs, fault
schedules and autoscaling).  One definition here keeps flags, defaults
and JSON emission identical across entry points.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.sim.arrivals import ARRIVAL_KINDS, Scenario
from repro.sim.autoscale import AutoscaleConfig
from repro.sim.faults import FaultSchedule


def add_common_args(p: argparse.ArgumentParser, *, seed: int = 0) -> None:
    """--seed / --compact / --out: determinism and emission knobs."""
    p.add_argument("--seed", type=int, default=seed)
    p.add_argument("--compact", action="store_true",
                   help="single-line JSON output")
    p.add_argument("--out", default=None, metavar="PATH",
                   help="also write the JSON report to PATH")


def add_exec_args(p: argparse.ArgumentParser) -> None:
    """--backend / --batch-window-us / --calibration: the execution-
    backend axis (repro.exec; see docs/execution.md)."""
    g = p.add_argument_group("execution backend")
    g.add_argument("--backend", choices=["analytic", "kernel"],
                   default="analytic",
                   help="compute pricing: hand-set ComputeSpec constants "
                        "(analytic) or batch-coalesced, measured "
                        "CalibrationTable pricing (kernel)")
    g.add_argument("--batch-window-us", type=float, default=0.0,
                   metavar="US",
                   help="kernel backend: per-shard batch-coalescing "
                        "window in microseconds (0 = per-job dispatch)")
    g.add_argument("--calibration", default=None, metavar="TABLE.JSON",
                   help="kernel backend: CalibrationTable JSON to price "
                        "from (default: the committed measured table)")


def exec_fields_from_args(args, parser: argparse.ArgumentParser = None
                          ) -> dict:
    """FleetConfig kwargs for the execution-backend axis (validated)."""
    if args.backend == "analytic" and (args.batch_window_us
                                       or args.calibration):
        msg = ("--batch-window-us/--calibration are kernel-backend "
               "knobs; add --backend kernel")
        if parser is not None:
            parser.error(msg)
        raise ValueError(msg)
    return dict(backend=args.backend,
                batch_window_s=args.batch_window_us * 1e-6,
                calibration=args.calibration)


def add_obs_args(p: argparse.ArgumentParser) -> None:
    """--trace / --attrib: the observability axis (repro.obs)."""
    g = p.add_argument_group("observability")
    g.add_argument("--trace", default=None, metavar="PATH",
                   help="record a span trace and write Chrome-trace/"
                        "Perfetto JSON to PATH (open at ui.perfetto.dev)")
    g.add_argument("--attrib", action="store_true",
                   help="print a critical-path attribution breakdown "
                        "(and include it in the JSON report)")
    g.add_argument("--explain", nargs="?", const="-", default=None,
                   metavar="PATH",
                   help="explain the latency tail: exemplar reservoirs, "
                        "windowed attribution and alert forensics "
                        "(repro.obs.explain); the report gains an "
                        "'explain' block, a summary renders to stderr, "
                        "and with PATH the full report is also written "
                        "there as JSON (implies tracing)")
    g.add_argument("--mrc", nargs="?", const="-", default=None,
                   metavar="PATH",
                   help="profile online miss-ratio curves per tenant "
                        "(SHARDS sampled ghost, repro.obs.mrc); the "
                        "report gains an 'mrc' block, and with PATH the "
                        "curves artifact is also written there — feed it "
                        "to 'python -m repro.tuning --tune-split --mrc'")


def tracer_from_args(args):
    """A live Tracer when --trace/--attrib/--explain asked for one,
    else None."""
    from repro.obs import Tracer
    if (getattr(args, "trace", None) or getattr(args, "attrib", False)
            or getattr(args, "explain", None)):
        return Tracer()
    return None


def _write_artifact(path: str, payload: dict) -> None:
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"# wrote {path}", file=sys.stderr)


def emit_obs(out: dict, args, tracer) -> None:
    """Fold the observability outputs into the report payload.

    Renderings go to stderr so stdout stays machine-parseable; the
    Chrome trace goes to the ``--trace`` path, and the ``--explain`` /
    ``--mrc`` blocks (already inside ``out`` via the report summary)
    are additionally written as standalone artifacts when those flags
    carry a PATH.
    """
    def block(key):
        # the report summary nests the block at report.<key> (single
        # fleet run) or report.fleet.<key> (multi-tenant run)
        rep = out.get("report", out)
        return rep.get(key, rep.get("fleet", {}).get(key))

    if getattr(args, "explain", None) and block("explain") is not None:
        from repro.obs.explain import render_explain
        print(render_explain(block("explain")), file=sys.stderr)
        if args.explain != "-":
            _write_artifact(args.explain, block("explain"))
    if getattr(args, "mrc", None) and args.mrc != "-" \
            and block("mrc") is not None:
        _write_artifact(args.mrc, block("mrc"))
    if tracer is None:
        return
    from repro.obs import attribute, write_chrome_trace
    if args.attrib:
        rep = attribute(tracer)
        out["attrib"] = rep.to_dict()
        print(rep.render(), file=sys.stderr)
    if args.trace:
        write_chrome_trace(args.trace, tracer)
        print(f"# wrote {args.trace}", file=sys.stderr)


def add_monitor_args(p: argparse.ArgumentParser) -> None:
    """--monitor / --alert-actions / --pricebook: live SLO monitoring
    and dollar metering (repro.obs.monitor / repro.obs.cost)."""
    g = p.add_argument_group("monitoring / costing")
    g.add_argument("--monitor", action="store_true",
                   help="attach live SLO monitors with burn-rate "
                        "alerting (alert log lands in the JSON report; "
                        "observation only unless --alert-actions)")
    g.add_argument("--monitor-interval", type=float, default=0.05,
                   help="rule-evaluation tick in virtual seconds")
    g.add_argument("--alert-actions", action="store_true",
                   help="let alerts actuate: scale-out on a page-"
                        "severity latency burn, tenant deprioritization "
                        "on a sustained ticket burn (requires --monitor;"
                        " the run is no longer bit-exact vs unmonitored)")
    g.add_argument("--recall-slo", type=float, default=None,
                   metavar="FLOOR",
                   help="with --monitor: also watch live recall@k "
                        "against this floor (computes ground truth "
                        "before the run; pure-query scenarios only)")
    g.add_argument("--pricebook", default=None, metavar="NAME|PATH",
                   help="price the run in dollars: a preset name "
                        "(default, egress-heavy, dense-cache) or a JSON "
                        "file of PriceBook fields (docs/cost.md)")


def monitor_from_args(args, parser: argparse.ArgumentParser = None):
    """A MonitorConfig when --monitor asked for one, else None."""
    from repro.obs import MonitorConfig
    if not args.monitor:
        if args.alert_actions or args.recall_slo is not None:
            flag = ("--alert-actions" if args.alert_actions
                    else "--recall-slo")
            msg = f"{flag} requires --monitor"
            if parser is not None:
                parser.error(msg)
            raise SystemExit(f"error: {msg}")
        return None
    return MonitorConfig(interval_s=args.monitor_interval,
                         actions=args.alert_actions,
                         recall_target=args.recall_slo)


def pricebook_from_args(args, parser: argparse.ArgumentParser = None):
    """A PriceBook when --pricebook named one, else None."""
    if args.pricebook is None:
        return None
    from repro.obs import resolve_pricebook
    try:
        return resolve_pricebook(args.pricebook)
    except (KeyError, ValueError) as e:
        msg = str(e).strip('"')
        if parser is not None:
            parser.error(msg)
        raise SystemExit(f"error: {msg}")


def add_scenario_args(p: argparse.ArgumentParser, *,
                      faults: bool = True) -> None:
    """The arrival-scenario axis shared by fleet and tuning.

    ``faults=False`` (the tuner) registers only the arrival/SLO knobs:
    fault injection and autoscaling act on a single concrete run, which
    is ``python -m repro.fleet``'s job, not the sizing sweep's.
    """
    g = p.add_argument_group("scenario")
    g.add_argument("--scenario", choices=list(ARRIVAL_KINDS),
                   default="closed",
                   help="arrival process: closed (paper harness), poisson "
                        "(open loop), burst (poisson with a spike), trace "
                        "(zipf-repeated replay)")
    g.add_argument("--rate", type=float, default=200.0,
                   help="offered load in QPS (open-loop scenarios)")
    g.add_argument("--duration", type=float, default=None,
                   help="arrival horizon in virtual seconds")
    g.add_argument("--arrivals", type=int, default=None,
                   help="cap on total arrivals (cycles the query set)")
    g.add_argument("--slo-ms", type=float, default=50.0,
                   help="p99 SLO in milliseconds (goodput / autoscaling)")
    g.add_argument("--burst-factor", type=float, default=4.0)
    g.add_argument("--burst-start", type=float, default=0.25,
                   help="burst window start (virtual seconds)")
    g.add_argument("--burst-len", type=float, default=0.25)
    g.add_argument("--trace-zipf-a", type=float, default=1.2,
                   help="trace popularity skew (zipf exponent)")
    w = p.add_argument_group("read-write mix (--scenario rw)")
    w.add_argument("--write-rate", type=float, default=0.0,
                   help="update arrivals per virtual second (0 = pure "
                        "query run, bit-identical to --scenario closed)")
    w.add_argument("--n-updates", type=int, default=None,
                   help="cap on total updates (default: write rate x 1s)")
    w.add_argument("--delete-frac", type=float, default=0.2,
                   help="delete share of the update stream")
    w.add_argument("--delta-kb", type=float, default=256.0,
                   help="delta-tier (memtable) capacity per site, KiB")
    w.add_argument("--flush-frac", type=float, default=0.5,
                   help="flush trigger as a fraction of the delta cap")
    w.add_argument("--compaction-par", type=int, default=1,
                   help="concurrent background compaction jobs per site")
    if not faults:
        return
    g.add_argument("--fail", action="append", default=[],
                   metavar="SHARD:T_FAIL[:T_RECOVER]",
                   help="kill shard SHARD at T_FAIL (revive at T_RECOVER); "
                        "repeatable")
    g.add_argument("--autoscale", action="store_true",
                   help="enable the SLO-driven instance autoscaler")
    g.add_argument("--autoscale-max", type=int, default=4,
                   help="max serving instances per shard")
    g.add_argument("--series-dt", type=float, default=None,
                   help="time-series slice width (default 0.05s when a "
                        "non-closed scenario, fault or autoscaler is on)")


def scenario_from_args(args) -> Scenario:
    return Scenario(
        kind=args.scenario, rate_qps=args.rate, duration_s=args.duration,
        n_arrivals=args.arrivals, burst_factor=args.burst_factor,
        burst_start_s=args.burst_start, burst_len_s=args.burst_len,
        zipf_a=args.trace_zipf_a, slo_s=args.slo_ms * 1e-3,
        write_rate_qps=getattr(args, "write_rate", 0.0),
        n_updates=getattr(args, "n_updates", None),
        delete_frac=getattr(args, "delete_frac", 0.2))


def ingest_from_args(args):
    """The compaction knobs (only consulted on rw runs)."""
    from repro.ingest.compaction import IngestConfig
    return IngestConfig(
        delta_cap_bytes=int(args.delta_kb * 1024),
        flush_frac=args.flush_frac,
        compaction_parallelism=args.compaction_par)


def faults_from_args(args) -> FaultSchedule | None:
    return FaultSchedule.parse(args.fail) if args.fail else None


def autoscale_from_args(args) -> AutoscaleConfig | None:
    if not args.autoscale:
        return None
    return AutoscaleConfig(slo_p99_s=args.slo_ms * 1e-3,
                           max_instances=args.autoscale_max)


def emit_json(payload: dict, args) -> None:
    """Print (and optionally persist) the deterministic JSON report."""
    text = json.dumps(payload, indent=None if args.compact else 2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
            f.write("\n")
        print(f"# wrote {args.out}", file=sys.stderr)
