"""Storage environment specifications (paper Table 1 + §2.2).

These constants parameterise the discrete-event I/O simulator; the presets
are the paper's measured environments.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class StorageSpec:
    name: str
    ttfb_p50_s: float              # time-to-first-byte, median
    ttfb_sigma: float              # lognormal sigma for the latency tail
    bandwidth_Bps: float           # read throughput (shared pipe, bytes/s)
    get_qps_limit: float           # GET request rate limit (IOPS throttle)
    min_latency_s: float = 0.0     # hard floor (e.g. kernel I/O stack)

    def describe(self) -> str:
        return (f"{self.name}: p50 TTFB {self.ttfb_p50_s*1e6:.1f}us, "
                f"{self.get_qps_limit:.0f} GET QPS, "
                f"{self.bandwidth_Bps/2**30:.3f} GiB/s")


# Paper Table 1 (experiment section uses p50=31ms for the external-network
# TOS path, §5.1; Table 1 lists 9ms for the storage itself — we expose both).
TOS = StorageSpec(
    name="volcano-tos",
    ttfb_p50_s=9e-3,
    ttfb_sigma=0.55,               # 30-200ms cold tail (§2.2)
    bandwidth_Bps=0.625e9,         # 5 Gbps external network
    get_qps_limit=20_000.0,
)

TOS_EXTERNAL = dataclasses.replace(
    TOS, name="volcano-tos-external", ttfb_p50_s=31e-3)

SSD = StorageSpec(
    name="local-ssd",
    ttfb_p50_s=66.5e-6,
    ttfb_sigma=0.25,
    bandwidth_Bps=12e9,
    get_qps_limit=420_000.0,
)

# Per-shard local NVMe used as a middle tier between the DRAM segment
# cache and the remote object store (repro.storage.tier): ~100us base
# latency (TTFB median + kernel I/O floor), with its own IOPS bucket and
# bandwidth pipe so an NVMe-resident working set never touches the
# remote NIC or GET tokens.
NVME = StorageSpec(
    name="local-nvme",
    ttfb_p50_s=90e-6,
    ttfb_sigma=0.25,
    bandwidth_Bps=3.5e9,
    get_qps_limit=300_000.0,
    min_latency_s=10e-6,
)

S3_EXTERNAL = StorageSpec(
    name="s3-external",
    ttfb_p50_s=30e-3,
    ttfb_sigma=0.6,
    bandwidth_Bps=0.625e9,         # 5 Gbps
    get_qps_limit=5_500.0,         # per-prefix (paper §2.2)
)

INTERNAL_NIC = StorageSpec(
    name="tos-internal-50gbps",
    ttfb_p50_s=9e-3,
    ttfb_sigma=0.55,
    bandwidth_Bps=6.25e9,          # 50 Gbps on-premise internal network
    get_qps_limit=20_000.0,
)

PRESETS = {s.name: s for s in [TOS, TOS_EXTERNAL, SSD, NVME, S3_EXTERNAL,
                               INTERNAL_NIC]}
