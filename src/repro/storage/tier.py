"""Tiered storage data path: DRAM cache -> per-shard local NVMe ->
remote object store.

The flat hierarchy (segment cache over one remote :class:`StorageSim`)
cannot reach the billion-scale operating points the paper's cloud-vs-
disk analysis turns on — index far larger than DRAM, where a second
local tier breaks the performance/size tradeoff.  This module inserts
that tier: each shard instance may own a local NVMe device, modeled as
a second :class:`StorageSim` (its own IOPS token bucket, its own
bandwidth pipe, ~100 us base latency — :data:`repro.storage.spec.NVME`)
plus a byte-accounted LRU *residency map* deciding which objects live
on the device.

Promotion/demotion is a policy axis, mirroring ``tenancy/policy.py``:

* ``admit-always`` — every remote miss-fetch is admitted on completion;
  simple, but one scan can wash the device.
* ``second-hit`` — a remote fetch is admitted only if its key is on the
  ghost list (it has missed before); first touches only leave a ghost
  entry.  The ghost list is key metadata only, byte-bounded to the
  device capacity — the same second-chance structure the weighted
  tenant-cache policy uses.

Demotion is eviction: NVMe content is a clean copy of remote data, so
dropping the LRU resident is free.  Compaction output placement is a
second policy axis (``writeback``): write-through sends compaction PUTs
straight to the object store as before; write-back lands them on the
local device first — readable at local latency immediately — and
flushes to the object store asynchronously (the PUT bill is deferred,
not avoided).

The contract that keeps the tier safe: capacity 0 builds no tier at
all — no second ``StorageSim`` is constructed, so kernel RNG stream
names and event sequences are byte-identical to the flat hierarchy and
every pre-tier golden still reproduces bit-exactly.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Hashable

from repro.sim.kernel import Kernel
from repro.storage.simulator import StorageSim
from repro.storage.spec import NVME, StorageSpec

TIER_POLICIES = ("admit-always", "second-hit")


@dataclasses.dataclass(frozen=True)
class TierConfig:
    """Per-instance NVMe tier knobs (``--nvme-gb`` and friends)."""

    capacity_bytes: int
    policy: str = "second-hit"
    writeback: bool = False
    spec: StorageSpec = NVME

    def __post_init__(self):
        if self.capacity_bytes < 0:
            raise ValueError(f"capacity_bytes must be >= 0, got "
                             f"{self.capacity_bytes}")
        if self.policy not in TIER_POLICIES:
            raise ValueError(f"unknown tier policy {self.policy!r}; "
                             f"one of {TIER_POLICIES}")


class NVMeTier:
    """One shard instance's local NVMe device + residency policy.

    The device itself is a :class:`StorageSim`; this class owns what is
    *on* it.  Residency is an LRU over keys with exact byte accounting
    (``used_bytes <= capacity`` always); the promotion policy decides
    which remote fetches earn a copy.
    """

    def __init__(self, cfg: TierConfig, kernel: Kernel, *, seed: int = 0):
        assert cfg.capacity_bytes > 0, \
            "capacity 0 means no tier — construct nothing"
        self.cfg = cfg
        self.capacity = int(cfg.capacity_bytes)
        self.writeback = cfg.writeback
        self.sim = StorageSim(cfg.spec, kernel, seed=seed)
        self._resident: OrderedDict[Hashable, int] = OrderedDict()
        self.used_bytes = 0
        #: second-hit ghost list: key -> nbytes, byte-bounded to capacity
        self._ghost: OrderedDict[Hashable, int] = OrderedDict()
        self._ghost_bytes = 0
        # cumulative accounting (survives cold restarts — billing and
        # gauges want totals, not the live residency)
        self.hits = 0                 # requests served from the device
        self.misses = 0               # requests that fell through to remote
        self.nvme_bytes = 0           # bytes served from the device
        self.promotions = 0
        self.promoted_bytes = 0
        self.evictions = 0
        self.writeback_admits = 0
        self.writeback_fallbacks = 0  # device full -> write-through

    # ---------------------------------------------------------- lookup --
    def split(self, requests):
        """Partition one batch's cache misses by residency.

        Returns ``(nvme_reqs, remote_reqs)``.  Resident keys are touched
        (LRU) and counted as tier hits; the rest fall through to the
        remote store and are counted as tier misses.
        """
        nvme_reqs, remote_reqs = [], []
        for rq in requests:
            if rq.key in self._resident:
                self._resident.move_to_end(rq.key)
                self.hits += 1
                self.nvme_bytes += rq.nbytes
                nvme_reqs.append(rq)
            else:
                self.misses += 1
                remote_reqs.append(rq)
        return nvme_reqs, remote_reqs

    # ------------------------------------------------------- promotion --
    def note_remote_fetch(self, key: Hashable, nbytes: int) -> None:
        """A remote miss-fetch for ``key`` completed: apply the
        promotion policy."""
        if key in self._resident:          # raced in via write-back
            self._resident.move_to_end(key)
            return
        if self.cfg.policy == "admit-always":
            self._admit(key, nbytes)
            return
        # second-hit: promote only keys that already ghost-missed once
        if key in self._ghost:
            self._ghost_bytes -= self._ghost.pop(key)
            self._admit(key, nbytes)
        else:
            self._ghost[key] = nbytes
            self._ghost_bytes += nbytes
            while self._ghost_bytes > self.capacity and self._ghost:
                _, s = self._ghost.popitem(last=False)
                self._ghost_bytes -= s

    def _admit(self, key: Hashable, nbytes: int) -> None:
        if nbytes > self.capacity:
            return
        self._resident[key] = nbytes
        self.used_bytes += nbytes
        self.promotions += 1
        self.promoted_bytes += nbytes
        while self.used_bytes > self.capacity and self._resident:
            k, s = self._resident.popitem(last=False)
            self.used_bytes -= s
            self.evictions += 1

    def admit_writeback(self, key: Hashable, nbytes: int) -> bool:
        """Place compaction output on the device (write-back policy).

        Returns False when the object cannot fit — the caller's flush
        already went (or goes) straight to the object store, so a full
        device degrades to write-through, never to data loss."""
        if nbytes > self.capacity:
            self.writeback_fallbacks += 1
            return False
        self._ghost_bytes -= self._ghost.pop(key, 0)
        if key in self._resident:
            self.used_bytes -= self._resident.pop(key)
        self._resident[key] = nbytes
        self.used_bytes += nbytes
        self.writeback_admits += 1
        while self.used_bytes > self.capacity and len(self._resident) > 1:
            k, s = self._resident.popitem(last=False)
            self.used_bytes -= s
            self.evictions += 1
        return True

    # ----------------------------------------------------- invalidation --
    def invalidate(self, key: Hashable) -> bool:
        """Drop a rewritten object's stale device copy (and its ghost
        entry — staleness is not a reuse signal).  Neither a tier hit
        nor a tier miss, mirroring the cache invalidation contract."""
        present = key in self._resident
        if present:
            self.used_bytes -= self._resident.pop(key)
        self._ghost_bytes -= self._ghost.pop(key, 0)
        return present

    # ------------------------------------------------- faults / restart --
    def reset(self) -> None:
        """Instance restart: the replacement node's device starts empty
        (cumulative counters survive — they price the whole run)."""
        self._resident.clear()
        self.used_bytes = 0
        self._ghost.clear()
        self._ghost_bytes = 0

    # ------------------------------------------------------------ stats --
    @property
    def resident_keys(self) -> int:
        return len(self._resident)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._resident

    def stats_dict(self) -> dict:
        return dict(
            capacity_bytes=self.capacity,
            used_bytes=self.used_bytes,
            resident_keys=len(self._resident),
            hits=self.hits, misses=self.misses,
            nvme_bytes=self.nvme_bytes,
            promotions=self.promotions,
            promoted_bytes=self.promoted_bytes,
            evictions=self.evictions,
            writeback_admits=self.writeback_admits,
            writeback_fallbacks=self.writeback_fallbacks,
            device_bytes=self.sim.total_bytes,
            device_requests=self.sim.total_requests,
        )


class TieredWritePath:
    """The ingest data plane of a tiered engine.

    :class:`repro.ingest.compaction.IngestAgent` talks to one object
    with ``submit_batch(nbytes, n_requests, on_done, put=...)``.  On a
    write-back tier, compaction PUTs land on the local device first —
    ``on_done`` fires at *local* completion, so the install (and the
    rewritten objects' visibility) precedes the object-store flush —
    and the remote flush PUT is issued asynchronously at that instant.
    Reads (compaction re-reads of sealed objects) and write-through
    PUTs pass through to the remote sim unchanged.
    """

    def __init__(self, tier: NVMeTier, remote: StorageSim):
        self.tier = tier
        self.remote = remote
        self.flush_pending = 0         # remote flush batches in flight
        self.flushes_done = 0

    def submit_batch(self, nbytes: int, n_requests: int,
                     on_done=None, *, put: bool = False):
        if not put or self.tier is None or not self.tier.writeback:
            return self.remote.submit_batch(nbytes, n_requests,
                                            on_done=on_done, put=put)

        def _local_done(tk):
            # install happens now; flush to the object store async
            self.flush_pending += 1
            self.remote.submit_batch(nbytes, n_requests,
                                     on_done=self._flush_done, put=True)
            if on_done is not None:
                on_done(tk)

        return self.tier.sim.submit_batch(nbytes, n_requests,
                                          on_done=_local_done, put=True)

    def _flush_done(self, tk) -> None:
        self.flush_pending -= 1
        self.flushes_done += 1
