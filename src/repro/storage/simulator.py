"""Discrete-event simulator of remote-storage I/O (paper §2.2 mechanisms).

Three resources gate every fetch batch:

1. **GET-rate limiter** (token bucket at ``get_qps_limit``): every request
   in a batch consumes a token — DiskANN's W batched requests still count
   as W IOs (paper footnote 8).  Under saturation this produces exactly
   the Fig 10d / Fig 19e IOPS ceiling.
2. **TTFB**: one lognormal sample per batch (requests in a batch are
   issued concurrently, so their first bytes arrive together); this gives
   graph search its ``rt × TTFB`` latency floor (§2.3.2).
3. **Shared bandwidth pipe** (processor sharing): all in-flight batch
   transfers progress at ``bandwidth / n_active`` — I/O congestion rises
   with recall × concurrency exactly as in Fig 9.

The simulator is deterministic for a given seed and tracks virtual time;
batches are the unit of transfer, requests the unit of rate limiting.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable

import numpy as np

from repro.storage.spec import StorageSpec


@dataclasses.dataclass
class BatchTicket:
    batch_id: int
    submit_t: float
    start_t: float = 0.0         # transfer start (post admission + TTFB)
    done_t: float = 0.0
    nbytes: int = 0
    n_requests: int = 0


class _SharedPipe:
    """Exact processor-sharing pipe: active transfers share bandwidth."""

    def __init__(self, bandwidth_Bps: float):
        self.bw = bandwidth_Bps
        self.active: dict[int, float] = {}     # id -> remaining bytes
        self.t = 0.0

    def _advance(self, t: float) -> None:
        if t <= self.t:
            return
        if self.active:
            rate = self.bw / len(self.active)
            dt = t - self.t
            for k in self.active:
                self.active[k] -= rate * dt
        self.t = t

    def add(self, t: float, tid: int, nbytes: float) -> None:
        self._advance(t)
        self.active[tid] = max(float(nbytes), 1.0)

    def next_completion(self) -> tuple[float, int] | None:
        """(time, id) of the earliest finishing transfer, else None."""
        if not self.active:
            return None
        rate = self.bw / len(self.active)
        tid, rem = min(self.active.items(), key=lambda kv: kv[1])
        return self.t + max(rem, 0.0) / rate, tid

    def complete(self, t: float, tid: int) -> None:
        self._advance(t)
        self.active.pop(tid, None)


class StorageSim:
    """Event-driven storage backend.

    Usage (driven by the serving engine): ``submit_batch`` returns a
    ticket; ``run_until_next_completion`` pops the next finished transfer.
    """

    def __init__(self, spec: StorageSpec, seed: int = 0):
        self.spec = spec
        self.pipe = _SharedPipe(spec.bandwidth_Bps)
        self.rng = np.random.default_rng(seed)
        self._bucket_vt = 0.0                  # IOPS token-bucket clock
        self._next_id = 0
        self._pending: list[tuple[float, int]] = []   # (start_t, batch_id)
        self._tickets: dict[int, BatchTicket] = {}
        # aggregates
        self.total_bytes = 0
        self.total_requests = 0

    # ----------------------------------------------------------- submit --
    def sample_ttfb(self) -> float:
        s = self.spec.ttfb_sigma
        mu = math.log(self.spec.ttfb_p50_s)
        return float(np.exp(self.rng.normal(mu, s)))

    def submit_batch(self, t: float, nbytes: int, n_requests: int
                     ) -> BatchTicket:
        """Admit a dependency-free batch of GETs at virtual time t."""
        tid = self._next_id
        self._next_id += 1
        # 1) GET-rate admission: n tokens at get_qps_limit
        self._bucket_vt = max(self._bucket_vt, t) + (
            n_requests / self.spec.get_qps_limit)
        admit_t = max(t, self._bucket_vt)
        # 2) TTFB (one overlapped sample per batch)
        start_t = admit_t + self.sample_ttfb() + self.spec.min_latency_s
        ticket = BatchTicket(batch_id=tid, submit_t=t, start_t=start_t,
                             nbytes=nbytes, n_requests=n_requests)
        self._tickets[tid] = ticket
        heapq.heappush(self._pending, (start_t, tid))
        self.total_bytes += nbytes
        self.total_requests += n_requests
        return ticket

    # ------------------------------------------------------------- step --
    def next_event_time(self) -> float | None:
        """Earliest among pending transfer-starts and pipe completions."""
        cands = []
        if self._pending:
            cands.append(self._pending[0][0])
        nc = self.pipe.next_completion()
        if nc is not None:
            cands.append(nc[0])
        return min(cands) if cands else None

    def advance_to(self, t: float) -> list[BatchTicket]:
        """Advance the clock to ``t``; returns batches completed by then."""
        done: list[BatchTicket] = []
        while True:
            nxt = None
            if self._pending:
                nxt = ("start", self._pending[0][0])
            nc = self.pipe.next_completion()
            if nc is not None and (nxt is None or nc[0] < nxt[1]):
                nxt = ("done", nc[0], nc[1])
            if nxt is None or nxt[1] > t + 1e-15:
                break
            if nxt[0] == "start":
                st, tid = heapq.heappop(self._pending)
                self.pipe.add(st, tid, self._tickets[tid].nbytes)
            else:
                _, ct, tid = nxt
                self.pipe.complete(ct, tid)
                tk = self._tickets.pop(tid)
                tk.done_t = ct
                done.append(tk)
        self.pipe._advance(t)
        return done

    @property
    def busy(self) -> bool:
        return bool(self._pending or self.pipe.active)
