"""Discrete-event simulator of remote-storage I/O (paper §2.2 mechanisms).

Three resources gate every fetch batch:

1. **GET-rate limiter** (token bucket at ``get_qps_limit``): every request
   in a batch consumes a token — DiskANN's W batched requests still count
   as W IOs (paper footnote 8).  Under saturation this produces exactly
   the Fig 10d / Fig 19e IOPS ceiling.
2. **TTFB**: one lognormal sample per batch (requests in a batch are
   issued concurrently, so their first bytes arrive together); this gives
   graph search its ``rt × TTFB`` latency floor (§2.3.2).
3. **Shared bandwidth pipe** (processor sharing): all in-flight batch
   transfers progress at ``bandwidth / n_active`` — I/O congestion rises
   with recall × concurrency exactly as in Fig 9.

The simulator is a component on the shared :class:`repro.sim.Kernel`: a
batch's transfer-start and transfer-completion are kernel events, and the
processor-sharing pipe keeps exactly one completion event scheduled —
rescheduled whenever pipe membership changes.  Passing no kernel gives the
sim a private one (standalone use in unit tests and notebooks).

Batches are the unit of transfer, requests the unit of rate limiting;
everything is deterministic for a given seed.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

from repro.sim.kernel import Event, Kernel
from repro.storage.spec import StorageSpec


@dataclasses.dataclass
class BatchTicket:
    batch_id: int
    submit_t: float
    start_t: float = 0.0         # transfer start (post admission + TTFB)
    done_t: float = 0.0
    nbytes: int = 0
    n_requests: int = 0


class _SharedPipe:
    """Exact processor-sharing pipe: active transfers share bandwidth."""

    def __init__(self, bandwidth_Bps: float):
        self.bw = bandwidth_Bps
        self.active: dict[int, float] = {}     # id -> remaining bytes
        self.t = 0.0

    def _advance(self, t: float) -> None:
        if t <= self.t:
            return
        if self.active:
            rate = self.bw / len(self.active)
            dt = t - self.t
            for k in self.active:
                self.active[k] -= rate * dt
        self.t = t

    def add(self, t: float, tid: int, nbytes: float) -> None:
        self._advance(t)
        self.active[tid] = max(float(nbytes), 1.0)

    def next_completion(self) -> tuple[float, int] | None:
        """(time, id) of the earliest finishing transfer, else None."""
        if not self.active:
            return None
        rate = self.bw / len(self.active)
        tid, rem = min(self.active.items(), key=lambda kv: kv[1])
        return self.t + max(rem, 0.0) / rate, tid

    def complete(self, t: float, tid: int) -> None:
        self._advance(t)
        self.active.pop(tid, None)

    def remove(self, t: float, tid: int) -> None:
        """Drop a transfer without completing it (fault abort)."""
        self._advance(t)
        self.active.pop(tid, None)


class StorageSim:
    """Event-driven storage backend on a (possibly shared) kernel.

    ``submit_batch(nbytes, n_requests, on_done)`` admits a batch at the
    kernel's current virtual time; ``on_done(ticket)`` fires at the
    batch's completion event.  Without a callback, completed tickets
    accumulate and :meth:`drain` (standalone kernels only) runs the clock
    forward and returns them.
    """

    def __init__(self, spec: StorageSpec, kernel: Kernel | None = None,
                 *, seed: int = 0):
        self.spec = spec
        self.kernel = kernel if kernel is not None else Kernel(seed=seed)
        self.pipe = _SharedPipe(spec.bandwidth_Bps)
        self.rng = self.kernel.rng(self.kernel.unique_name("storage"),
                                   seed=seed)
        self._bucket_vt = 0.0                  # IOPS token-bucket clock
        self._next_id = 0
        self._tickets: dict[int, BatchTicket] = {}
        self._on_done: dict[int, Callable[[BatchTicket], None] | None] = {}
        self._start_evs: dict[int, Event] = {}
        #: per-batch token-bucket charge (seconds of bucket time), kept
        #: until transfer start so abort_all can refund batches whose
        #: admission tokens were charged but never used
        self._bucket_charge: dict[int, float] = {}
        self._completion_ev: Event | None = None
        self.completed: list[BatchTicket] = []   # callback-less tickets
        # aggregates (puts are also included in the totals: a PUT is
        # admitted and transferred exactly like a GET, it just bills
        # differently — repro.obs.cost meters the split)
        self.total_bytes = 0
        self.total_requests = 0
        self.total_put_bytes = 0
        self.total_put_requests = 0

    # ----------------------------------------------------------- submit --
    def sample_ttfb(self) -> float:
        s = self.spec.ttfb_sigma
        mu = math.log(self.spec.ttfb_p50_s)
        return float(np.exp(self.rng.normal(mu, s)))

    def submit_batch(self, nbytes: int, n_requests: int,
                     on_done: Callable[[BatchTicket], None] | None = None,
                     *, put: bool = False) -> BatchTicket:
        """Admit a dependency-free batch of GETs at the current time.

        ``put=True`` marks the batch as object-store writes (compaction
        flushes): identical simulation behavior, but metered separately
        so the cost model can price PUT requests at their (much higher)
        rate."""
        t = self.kernel.now
        tid = self._next_id
        self._next_id += 1
        # 1) GET-rate admission: n tokens at get_qps_limit
        charge = n_requests / self.spec.get_qps_limit
        self._bucket_vt = max(self._bucket_vt, t) + charge
        self._bucket_charge[tid] = charge
        admit_t = max(t, self._bucket_vt)
        # 2) TTFB (one overlapped sample per batch)
        start_t = admit_t + self.sample_ttfb() + self.spec.min_latency_s
        ticket = BatchTicket(batch_id=tid, submit_t=t, start_t=start_t,
                             nbytes=nbytes, n_requests=n_requests)
        self._tickets[tid] = ticket
        self._on_done[tid] = on_done
        self._start_evs[tid] = self.kernel.at(start_t, self._start, tid)
        self.total_bytes += nbytes
        self.total_requests += n_requests
        if put:
            self.total_put_bytes += nbytes
            self.total_put_requests += n_requests
        return ticket

    # ------------------------------------------------------------ events --
    def _start(self, tid: int) -> None:
        """Transfer-start event: the batch joins the shared pipe."""
        self._start_evs.pop(tid, None)
        self._bucket_charge.pop(tid, None)     # tokens are spent now
        self.pipe.add(self.kernel.now, tid, self._tickets[tid].nbytes)
        self._reschedule_completion()

    def _reschedule_completion(self) -> None:
        """Keep exactly one completion event: pipe membership changed, so
        the earliest finisher (and its finish time) may have too."""
        if self._completion_ev is not None:
            self.kernel.cancel(self._completion_ev)
            self._completion_ev = None
        nc = self.pipe.next_completion()
        if nc is not None:
            self._completion_ev = self.kernel.at(
                max(nc[0], self.kernel.now), self._complete, nc[1])

    def _complete(self, tid: int) -> None:
        self._completion_ev = None
        t = self.kernel.now
        self.pipe.complete(t, tid)
        tk = self._tickets.pop(tid)
        tk.done_t = t
        cb = self._on_done.pop(tid)
        self._reschedule_completion()
        if cb is not None:
            cb(tk)
        else:
            self.completed.append(tk)

    # ------------------------------------------------------------ faults --
    def abort_all(self) -> None:
        """Drop every queued and in-flight transfer (the node died).

        Waiters are NOT notified — the failing server reports aborted
        jobs; storage just forgets the work.

        GET-rate tokens charged to batches that never reached transfer
        start are refunded: their admission slots were reserved but the
        requests never issued, so leaving ``_bucket_vt`` advanced would
        make post-fault traffic queue behind phantom I/O.
        """
        for tid, ev in self._start_evs.items():
            self.kernel.cancel(ev)
            self._bucket_vt -= self._bucket_charge.pop(tid, 0.0)
        self._bucket_vt = max(self._bucket_vt, self.kernel.now)
        self._start_evs.clear()
        self._bucket_charge.clear()
        for tid in list(self.pipe.active):
            self.pipe.remove(self.kernel.now, tid)
        if self._completion_ev is not None:
            self.kernel.cancel(self._completion_ev)
            self._completion_ev = None
        self._tickets.clear()
        self._on_done.clear()

    # ----------------------------------------------------------- helpers --
    @property
    def busy(self) -> bool:
        return bool(self._start_evs or self.pipe.active)

    def drain(self) -> list[BatchTicket]:
        """Standalone helper: run the (private) kernel dry and return the
        tickets completed without a callback since the last drain."""
        self.kernel.run()
        out = self.completed
        self.completed = []
        return out
