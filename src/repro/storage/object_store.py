"""In-process object store: the "remote storage" truth source (paper Fig 1).

Objects are immutable (key -> payload) with an explicit *billable size* in
bytes, which is what the I/O simulator charges for.  Index segment layouts:

* cluster index: one object per posting list
  (``("list", i)`` -> (ids, vectors); size = len * (D*itemsize + 8)).
* graph index: one object per node block, DiskANN's 4KB sector layout
  (``("node", i)`` -> (vector, neighbour ids); size rounded up to
  ``sector_bytes`` — nodes whose vector+adjacency exceed one sector span
  multiple sectors, which is why denser graphs are bigger, Table 4/Fig 17).
"""
from __future__ import annotations

from typing import Any, Hashable


class ObjectStore:
    def __init__(self) -> None:
        self._data: dict[Hashable, Any] = {}
        self._size: dict[Hashable, int] = {}

    def put(self, key: Hashable, payload: Any, nbytes: int) -> None:
        self._data[key] = payload
        self._size[key] = int(nbytes)

    def get(self, key: Hashable) -> Any:
        return self._data[key]

    def remove(self, key: Hashable) -> int:
        """Delete an object (compaction retired it); returns its billable
        size (0 when absent)."""
        self._data.pop(key, None)
        return self._size.pop(key, 0)

    def nbytes(self, key: Hashable) -> int:
        return self._size[key]

    def __contains__(self, key: Hashable) -> bool:
        return key in self._data

    def __len__(self) -> int:
        return len(self._data)

    @property
    def total_bytes(self) -> int:
        return sum(self._size.values())


def round_to_sectors(nbytes: int, sector_bytes: int) -> int:
    return -(-nbytes // sector_bytes) * sector_bytes
