"""In-process object store: the "remote storage" truth source (paper Fig 1).

Objects are immutable (key -> payload) with an explicit *billable size* in
bytes, which is what the I/O simulator charges for.  Index segment layouts:

* cluster index: one object per posting list
  (``("list", i)`` -> (ids, vectors); size = len * (D*itemsize + 8)).
* graph index: one object per node block, DiskANN's 4KB sector layout
  (``("node", i)`` -> (vector, neighbour ids); size rounded up to
  ``sector_bytes`` — nodes whose vector+adjacency exceed one sector span
  multiple sectors, which is why denser graphs are bigger, Table 4/Fig 17).
"""
from __future__ import annotations

from typing import Any, Hashable


class ObjectStore:
    def __init__(self) -> None:
        self._data: dict[Hashable, Any] = {}
        self._size: dict[Hashable, int] = {}
        # unlinked-but-still-readable payloads (POSIX-unlink semantics):
        # not billed, not a member, but a reader that resolved the key
        # before the unlink can still fetch it until purge_lingering().
        self._lingering: dict[Hashable, Any] = {}
        self._linger_t: dict[Hashable, float] = {}   # key -> unlink time

    def put(self, key: Hashable, payload: Any, nbytes: int) -> None:
        self._lingering.pop(key, None)     # re-insert supersedes a corpse
        self._linger_t.pop(key, None)
        self._data[key] = payload
        self._size[key] = int(nbytes)

    def get(self, key: Hashable) -> Any:
        if key in self._data:
            return self._data[key]
        return self._lingering[key]

    def remove(self, key: Hashable) -> int:
        """Delete an object (compaction retired it); returns its billable
        size (0 when absent)."""
        self._data.pop(key, None)
        self._lingering.pop(key, None)
        self._linger_t.pop(key, None)
        return self._size.pop(key, 0)

    def unlink(self, key: Hashable, t: float = 0.0) -> int:
        """Stop billing and membership for ``key`` but keep the payload
        readable until :meth:`purge_lingering` — the reclamation protocol
        for retired graph blocks: queries already holding a pre-compaction
        reference may still fetch the block; nothing new can find it, and
        its bytes no longer count toward :attr:`total_bytes`.  ``t`` is
        the unlink's virtual time, consulted by grace-based purges.
        Returns the bytes reclaimed (0 when absent)."""
        if key not in self._data:
            return 0
        self._lingering[key] = self._data.pop(key)
        self._linger_t[key] = float(t)
        return self._size.pop(key, 0)

    def purge_lingering(self, before: float | None = None) -> int:
        """Drop unlinked payloads — all of them, or (``before`` given)
        only corpses unlinked earlier than ``before``, so a reader whose
        sub-request was parked (shed backoff, fault window) across a
        compaction epoch still finds blocks retired within the grace
        window.  Returns how many corpses were purged."""
        if before is None:
            n = len(self._lingering)
            self._lingering.clear()
            self._linger_t.clear()
            return n
        victims = [k for k, t in self._linger_t.items() if t < before]
        for k in victims:
            self._lingering.pop(k, None)
            self._linger_t.pop(k, None)
        return len(victims)

    @property
    def lingering_count(self) -> int:
        return len(self._lingering)

    def nbytes(self, key: Hashable) -> int:
        return self._size[key]

    def __contains__(self, key: Hashable) -> bool:
        return key in self._data

    def __len__(self) -> int:
        return len(self._data)

    @property
    def total_bytes(self) -> int:
        return sum(self._size.values())


def round_to_sectors(nbytes: int, sector_bytes: int) -> int:
    return -(-nbytes // sector_bytes) * sector_bytes
