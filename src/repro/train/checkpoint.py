"""Fault-tolerant sharded checkpointing with elastic restore.

Design (no external deps):
* every pytree leaf is saved as its own ``.npy`` under a step directory,
  named by its tree path;
* a JSON manifest (leaf paths, shapes, dtypes, step, config digest) is
  written LAST via write-to-temp + atomic rename — a torn checkpoint is
  never visible to readers;
* restore takes a *target* abstract pytree + shardings and `device_put`s
  each loaded leaf to the requested NamedSharding — the checkpoint can be
  restored onto a different mesh than it was saved from (elastic
  re-sharding: scale 256 -> 512 chips or down to 1 CPU for debugging);
* ``keep_last`` garbage-collects old steps, never the newest complete one.

On a multi-host pod each host would write only the shards it owns
(`addressable_shards`); in this single-process container the full arrays
are written, and the restore path is identical either way.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any

import jax
import numpy as np

MANIFEST = "manifest.json"


def _leaf_name(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(re.sub(r"[^A-Za-z0-9_.-]", "_", str(p)))
    return "__".join(out) or "root"


def save(ckpt_dir: str, step: int, tree: Any, extra: dict | None = None
         ) -> str:
    """Atomically persist ``tree`` for ``step``.  Returns the step dir."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp_dir = step_dir + ".tmp"
    if os.path.exists(tmp_dir):
        shutil.rmtree(tmp_dir)
    os.makedirs(tmp_dir, exist_ok=True)
    leaves_meta = []
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        name = _leaf_name(path)
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp_dir, name + ".npy"), arr)
        leaves_meta.append({"name": name, "shape": list(arr.shape),
                            "dtype": str(arr.dtype)})
    manifest = {"step": step, "leaves": leaves_meta,
                "extra": extra or {}}
    mpath = os.path.join(tmp_dir, MANIFEST)
    with open(mpath + ".tmp", "w") as f:
        json.dump(manifest, f)
    os.replace(mpath + ".tmp", mpath)
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.replace(tmp_dir, step_dir)          # atomic publish
    return step_dir


def latest_step(ckpt_dir: str) -> int | None:
    """Newest step with a COMPLETE manifest (torn writes are ignored)."""
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for d in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, MANIFEST)):
            best = max(best or -1, int(m.group(1)))
    return best


def restore(ckpt_dir: str, step: int, target: Any,
            shardings: Any | None = None) -> Any:
    """Load ``step`` into the structure of ``target`` (abstract or
    concrete pytree).  ``shardings``: matching pytree of NamedSharding —
    leaves are device_put directly to their (possibly different) target
    mesh; None restores to default device."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(step_dir, MANIFEST)) as f:
        manifest = json.load(f)
    sizes = {m["name"]: (tuple(m["shape"]), m["dtype"])
             for m in manifest["leaves"]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(flat))
    out = []
    for (path, leaf), shard in zip(flat, shard_flat):
        name = _leaf_name(path)
        if name not in sizes:
            raise KeyError(f"checkpoint missing leaf {name}")
        want_shape = tuple(leaf.shape)
        got_shape, _ = sizes[name]
        if got_shape != want_shape:
            raise ValueError(
                f"{name}: checkpoint shape {got_shape} != target "
                f"{want_shape}")
        arr = np.load(os.path.join(step_dir, name + ".npy"))
        arr = arr.astype(leaf.dtype)
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def gc_old(ckpt_dir: str, keep_last: int = 2) -> None:
    steps = []
    if not os.path.isdir(ckpt_dir):
        return
    for d in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, MANIFEST)):
            steps.append(int(m.group(1)))
    for s in sorted(steps)[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)
