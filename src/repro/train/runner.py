"""Fault-tolerant training loop.

Large-scale runnability features:
* checkpoint/restart: resumes from the newest complete checkpoint; the
  data pipeline seeks to the restored step (no replay);
* preemption handling: SIGTERM/SIGINT trigger a save-and-exit at the next
  step boundary (cloud TPU preemption protocol);
* straggler watchdog: per-step wall times are recorded; steps slower than
  ``straggler_factor`` × the running median are counted and logged —
  on a real pod this signal feeds the scheduler's hot-spare swap;
* loss-spike guard: steps whose loss exceeds ``spike_factor`` × the
  running median are skipped (params restored from the pre-step copy),
  bounding the blast radius of data/hardware faults.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.train import checkpoint as ckpt


@dataclasses.dataclass
class RunnerConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 100
    keep_last: int = 2
    log_every: int = 10
    straggler_factor: float = 2.0
    spike_factor: float = 4.0
    spike_guard: bool = False


@dataclasses.dataclass
class RunReport:
    steps_run: int
    final_step: int
    losses: list
    step_times: list
    n_stragglers: int
    n_spikes_skipped: int
    preempted: bool


def run(cfg: RunnerConfig, train_step: Callable, params: Any,
        opt_state: Any, next_batch: Callable[[int], Any],
        log: Callable[[str], None] = print) -> tuple[Any, Any, RunReport]:
    preempted = {"flag": False}

    def _handler(signum, frame):
        preempted["flag"] = True

    old_handlers = {}
    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            old_handlers[sig] = signal.signal(sig, _handler)
        except ValueError:                      # non-main thread (tests)
            pass

    start = ckpt.latest_step(cfg.ckpt_dir)
    step = 0
    if start is not None:
        state = ckpt.restore(cfg.ckpt_dir, start,
                             {"params": jax.eval_shape(lambda: params),
                              "opt": jax.eval_shape(lambda: opt_state)})
        params, opt_state = state["params"], state["opt"]
        step = start
        log(f"resumed from step {step}")

    losses: list[float] = []
    times: list[float] = []
    n_strag = 0
    n_spikes = 0
    steps_run = 0
    try:
        while step < cfg.total_steps:
            t0 = time.perf_counter()
            batch = next_batch(step)
            prev = (params, opt_state) if cfg.spike_guard else None
            params, opt_state, metrics = train_step(params, opt_state,
                                                    batch)
            loss = float(jax.device_get(metrics["loss"]))
            dt = time.perf_counter() - t0
            if cfg.spike_guard and len(losses) >= 8:
                med = float(np.median(losses[-32:]))
                if loss > cfg.spike_factor * max(med, 1e-6):
                    params, opt_state = prev        # skip poisoned step
                    n_spikes += 1
                    step += 1
                    continue
            losses.append(loss)
            times.append(dt)
            if len(times) >= 8:
                med_t = float(np.median(times[-64:]))
                if dt > cfg.straggler_factor * med_t:
                    n_strag += 1
                    log(f"straggler step {step}: {dt:.2f}s vs median "
                        f"{med_t:.2f}s")
            step += 1
            steps_run += 1
            if step % cfg.log_every == 0:
                log(f"step {step} loss {loss:.4f} ({dt*1e3:.0f} ms)")
            if step % cfg.ckpt_every == 0 or preempted["flag"]:
                ckpt.save(cfg.ckpt_dir, step,
                          {"params": params, "opt": opt_state})
                ckpt.gc_old(cfg.ckpt_dir, cfg.keep_last)
                if preempted["flag"]:
                    log(f"preemption save at step {step}; exiting")
                    break
    finally:
        for sig, h in old_handlers.items():
            signal.signal(sig, h)

    report = RunReport(steps_run=steps_run, final_step=step, losses=losses,
                       step_times=times, n_stragglers=n_strag,
                       n_spikes_skipped=n_spikes,
                       preempted=preempted["flag"])
    return params, opt_state, report
