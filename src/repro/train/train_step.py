"""Train step: loss -> grads -> AdamW, with optional microbatch grad
accumulation (``lax.scan`` over microbatches keeps HLO size constant)."""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.model import LM
from repro.train import optimizer as opt

Params = Any


def make_train_step(lm: LM, ocfg: opt.OptimizerConfig,
                    microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, state,
    metrics).  batch leaves have leading dim global_batch."""

    def loss_fn(params, batch):
        return lm.loss(params, batch)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches,
                                 *x.shape[1:])
            mb = jax.tree.map(split, batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc_fn(carry, mbatch):
                loss_acc, gacc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mbatch)
                gacc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / microbatches,
                    gacc, grads)
                return (loss_acc + loss / microbatches, gacc), None

            (loss, grads), _ = jax.lax.scan(
                acc_fn, (jnp.zeros((), jnp.float32), zeros), mb)
        params, opt_state, stats = opt.apply_updates(
            ocfg, params, grads, opt_state)
        metrics = {"loss": loss, **stats}
        return params, opt_state, metrics

    return train_step
