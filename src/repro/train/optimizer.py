"""AdamW + cosine schedule + global-norm clipping, in pure jax.

Optimizer state is a params-shaped pytree (m, v) — it inherits the
params' sharding (FSDP×TP) via tree_map, which is what makes dbrx-132b
fit: 12 bytes/param spread over all 512 chips.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac * peak."""
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_state(params: Params) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    return {"m": zeros,
            "v": jax.tree.map(lambda p: jnp.zeros_like(p), params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(cfg: OptimizerConfig, params: Params, grads: Params,
                  state: dict) -> tuple[Params, dict, dict]:
    """One AdamW step.  Returns (params, state, stats)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                       # decay matrices, not norms
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    # three passes (XLA CSE dedupes under jit); a tuple-returning tree_map
    # would collide with tuples that are part of the params tree structure
    new_params = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v)[0],
                              params, grads, state["m"], state["v"])
    new_m = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v)[1],
                         params, grads, state["m"], state["v"])
    new_v = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v)[2],
                         params, grads, state["m"], state["v"])
    stats = {"lr": lr, "grad_norm": gnorm, "step": step}
    return new_params, {"m": new_m, "v": new_v, "step": step}, stats
