"""Per-arch config module (assigned architecture: see archs.py)."""
from repro.configs.archs import GEMMA_2B as CONFIG
from repro.configs.archs import smoke

SMOKE = smoke(CONFIG)
