from repro.configs.archs import ARCHS, get_config, smoke
from repro.configs.base import ModelConfig, ShapeConfig
from repro.configs.shapes import SHAPES, shapes_for

__all__ = ["ARCHS", "get_config", "smoke", "ModelConfig", "ShapeConfig",
           "SHAPES", "shapes_for"]
