"""Per-arch config module (assigned architecture: see archs.py)."""
from repro.configs.archs import MAMBA2_1P3B as CONFIG
from repro.configs.archs import smoke

SMOKE = smoke(CONFIG)
