"""Assigned input shapes (identical for all 10 LM architectures).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a
seq_len-deep KV cache / recurrent state), NOT ``train_step``.
``long_500k`` is only run for sub-quadratic architectures (ssm/hybrid);
full-attention archs record SKIP(full attention) — see DESIGN.md §4.
"""
from __future__ import annotations

from repro.configs.base import ShapeConfig

TRAIN_4K = ShapeConfig("train_4k", seq_len=4_096, global_batch=256,
                       kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32,
                          kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32_768, global_batch=128,
                         kind="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524_288, global_batch=1,
                        kind="decode")

SHAPES = {s.name: s for s in [TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K]}


def shapes_for(config) -> dict[str, ShapeConfig | None]:
    """The 4 assigned cells for an arch; None marks an assigned skip."""
    out: dict[str, ShapeConfig | None] = {}
    for name, s in SHAPES.items():
        if name == "long_500k" and not config.sub_quadratic:
            out[name] = None        # SKIP(full attention)
        else:
            out[name] = s
    return out
