"""The 10 assigned architectures, exact dims from the assignment sheet.

Each also gets a ``smoke()`` reduced config of the same family for CPU
tests (same block structure, tiny widths).  ``[source; verified-tier]``
annotations are carried in ``notes``.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig

MAMBA2_1P3B = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50280, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    notes="SSD (state-space duality) [arXiv:2405.21060; unverified]")

RECURRENTGEMMA_2B = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab=256_000, head_dim=256, mlp="geglu", local_window=2048,
    block_pattern=("rglru", "rglru", "attn"), lru_width=2560,
    notes="RG-LRU + local attn, 1:2 [arXiv:2402.19427; hf]")

GEMMA_2B = ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16384,
    vocab=256_000, head_dim=256, mlp="geglu", tie_embeddings=True,
    notes="GeGLU, head_dim=256, MQA [arXiv:2403.08295; hf]")

STARCODER2_7B = ModelConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, d_ff=18432,
    vocab=49152, mlp="gelu",
    notes="GQA kv=4, RoPE [arXiv:2402.19173; hf]")

INTERNLM2_20B = ModelConfig(
    name="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab=92544, mlp="swiglu",
    notes="GQA [arXiv:2403.17297; hf]")

QWEN3_32B = ModelConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, d_ff=25600,
    vocab=151_936, head_dim=128, qk_norm=True, mlp="swiglu",
    rope_theta=1_000_000.0,
    notes="qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]")

LLAMA32_VISION_11B = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=128_256, mlp="swiglu", rope_theta=500_000.0,
    cross_attn_period=5, n_frontend_tokens=1601,
    notes="cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision; "
          "unverified]; vision frontend is a stub (precomputed patch "
          "embeddings via input_specs)")

MUSICGEN_MEDIUM = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, d_ff=6144,
    vocab=2048, mlp="gelu", n_frontend_tokens=0,
    notes="decoder-only over EnCodec tokens [arXiv:2306.05284; hf]; "
          "EnCodec frontend is a stub (precomputed frame embeddings)")

DBRX_132B = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752,
    vocab=100_352, mlp="swiglu", n_experts=16, experts_per_token=4,
    notes="16 experts top-4, fine-grained [hf:databricks/dbrx-base; "
          "unverified]")

MOONSHOT_16B_A3B = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=163_840, mlp="swiglu", n_experts=64, experts_per_token=6,
    n_shared_experts=2,
    notes="kimi/moonlight, 64e top-6 [hf:moonshotai/Moonlight-16B-A3B; hf]")

ARCHS: dict[str, ModelConfig] = {c.name: c for c in [
    MAMBA2_1P3B, RECURRENTGEMMA_2B, GEMMA_2B, STARCODER2_7B, INTERNLM2_20B,
    QWEN3_32B, LLAMA32_VISION_11B, MUSICGEN_MEDIUM, DBRX_132B,
    MOONSHOT_16B_A3B,
]}


def smoke(config: ModelConfig) -> ModelConfig:
    """A reduced same-family config for CPU smoke tests."""
    kw: dict = dict(
        name=config.name + "-smoke",
        n_layers=min(config.n_layers, 4 if config.block_pattern else 3),
        d_model=64,
        vocab=256,
        dtype="float32",
        remat=False,
    )
    if config.block_pattern:
        kw["n_layers"] = len(config.block_pattern) + 1   # pattern + tail
    if config.n_heads:
        kw["n_heads"] = 4
        kw["n_kv_heads"] = min(config.n_kv_heads, 2) or 1
        if config.n_kv_heads == config.n_heads:
            kw["n_kv_heads"] = 4
        kw["head_dim"] = 16
    if config.d_ff:
        kw["d_ff"] = 128
    if config.n_experts:
        kw["n_experts"] = 4
        kw["experts_per_token"] = 2
        kw["d_ff"] = 32
        # drop-free capacity so prefill/decode equal teacher forcing
        kw["capacity_factor"] = 8.0
    if config.ssm_state:
        kw["ssm_state"] = 16
        kw["ssm_head_dim"] = 16
        kw["ssm_chunk"] = 16
    if config.lru_width:
        kw["lru_width"] = 64
    if config.local_window:
        kw["local_window"] = 16
    if config.cross_attn_period:
        kw["n_layers"] = 5
        kw["n_frontend_tokens"] = 12
    return dataclasses.replace(config, **kw)


def get_config(name: str) -> ModelConfig:
    return ARCHS[name]
