"""Per-arch config module (assigned architecture: see archs.py)."""
from repro.configs.archs import LLAMA32_VISION_11B as CONFIG
from repro.configs.archs import smoke

SMOKE = smoke(CONFIG)
