"""Per-arch config module (assigned architecture: see archs.py)."""
from repro.configs.archs import MOONSHOT_16B_A3B as CONFIG
from repro.configs.archs import smoke

SMOKE = smoke(CONFIG)
