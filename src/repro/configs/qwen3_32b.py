"""Per-arch config module (assigned architecture: see archs.py)."""
from repro.configs.archs import QWEN3_32B as CONFIG
from repro.configs.archs import smoke

SMOKE = smoke(CONFIG)
