"""Model / shape configuration dataclasses for the assigned architectures."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    # --- attention details ---
    mlp: str = "swiglu"         # swiglu | geglu | gelu
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    local_window: int = 0       # sliding-window size for local attention
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # --- hybrid (RecurrentGemma / Griffin) ---
    block_pattern: tuple[str, ...] = ()   # e.g. ("rglru", "rglru", "attn")
    lru_width: int = 0
    # --- VLM (cross-attention injection) ---
    cross_attn_period: int = 0  # one cross-attn layer per this many layers
    n_frontend_tokens: int = 0  # stub frontend sequence length (img/audio)
    # --- misc ---
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid-with-local-attention)."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        hd = self.resolved_head_dim
        embed = V * d * (1 if self.tie_embeddings else 2)
        total = embed
        for i in range(L):
            kind = self.layer_kind(i)
            if kind == "ssm":
                din, N, H = self.d_inner, self.ssm_state, self.ssm_heads
                total += d * (2 * din + 2 * N + H) + din * d + din  # in/out
                total += self.ssm_conv * (din + 2 * N)
                continue
            if kind == "rglru":
                w = self.lru_width or d
                total += d * w * 2 + w * d + 3 * w + self.ssm_conv * w
                total += self._mlp_params()
                continue
            # attention (self or self+cross)
            attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d
            total += attn * (2 if kind == "cross" else 1)
            if self.n_experts:
                gated = 2 if self.mlp in ("swiglu", "geglu") else 1
                expert = (gated + 1) * d * f
                total += self.n_experts * expert + d * self.n_experts
                total += self.n_shared_experts * expert
            else:
                total += self._mlp_params()
        return total

    def _mlp_params(self) -> int:
        gated = 2 if self.mlp in ("swiglu", "geglu") else 1
        return (gated + 1) * self.d_model * self.d_ff

    def n_active_params(self) -> int:
        """Active params per token (= n_params for dense)."""
        if not self.n_experts:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        gated = 2 if self.mlp in ("swiglu", "geglu") else 1
        expert = (gated + 1) * d * f
        inactive = (self.n_experts - self.experts_per_token) * expert
        return self.n_params() - self.n_layers * inactive

    def layer_kind(self, i: int) -> str:
        """Layer i's block kind: attn | ssm | rglru | cross."""
        if self.family == "ssm":
            return "ssm"
        if self.block_pattern:
            return self.block_pattern[i % len(self.block_pattern)]
        if self.cross_attn_period and (
                i % self.cross_attn_period == self.cross_attn_period - 1):
            return "cross"
        return "attn"


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch
