"""Greedy/sampled autoregressive generation on top of prefill/decode_step."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _grow_attention_caches(lm, caches, capacity: int):
    """Pad prefill-length KV caches up to decode capacity."""
    cfg = lm.cfg
    window = cfg.local_window if cfg.block_pattern else 0

    def grow(leaf):
        if (hasattr(leaf, "ndim") and leaf.ndim >= 4
                and cfg.n_kv_heads and leaf.shape[-2] == cfg.n_kv_heads):
            seq_ax = leaf.ndim - 3
            if (cfg.family == "vlm"
                    and leaf.shape[seq_ax] == cfg.n_frontend_tokens):
                return leaf                      # image K/V: fixed
            cap = min(capacity, window) if window else capacity
            pad = cap - leaf.shape[seq_ax]
            if pad > 0:
                widths = [(0, 0)] * leaf.ndim
                widths[seq_ax] = (0, pad)
                return jnp.pad(leaf, widths)
        return leaf

    return jax.tree.map(grow, caches)


def generate(lm, params, batch, n_tokens: int,
             temperature: float = 0.0, seed: int = 0) -> np.ndarray:
    """Prefill the prompt then decode ``n_tokens`` greedily (or sampled).

    batch: the prompt inputs (tokens (B, S) etc.).  Returns (B, n_tokens).
    """
    cfg = lm.cfg
    prompt = batch["tokens"]
    B, S = prompt.shape
    capacity = S + n_tokens
    prefill = jax.jit(lm.prefill)
    step = jax.jit(lm.decode_step)
    logits, caches = prefill(params, batch)
    caches = _grow_attention_caches(lm, caches, capacity)
    key = jax.random.PRNGKey(seed)
    out = []
    for t in range(n_tokens):
        if temperature > 0:
            key, sk = jax.random.split(key)
            tok = jax.random.categorical(sk, logits[:, -1] / temperature)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)
        out.append(np.asarray(tok))
        bt = dict(batch)
        bt["tokens"] = tok[:, None].astype(jnp.int32)
        logits, caches = step(params, bt, jnp.int32(S + t), caches)
    return np.stack(out, axis=1)
