"""Three-term roofline analysis from compiled dry-run artifacts.

Terms (per EXPERIMENTS.md §Roofline; TPU v5e constants):

    compute    = HLO_FLOPs_global    / (chips * 197e12 FLOP/s bf16)
    memory     = HLO_bytes_global    / (chips * 819e9  B/s HBM)
    collective = coll_bytes_global   / (chips * 50e9   B/s ICI link)

``compiled.cost_analysis()`` reports the per-partition (post-SPMD)
module, so per-device numbers are globalised by multiplying by the chip
count before applying the formulas (equivalently: per-device value over
per-chip peak).  Collective bytes are NOT in cost_analysis: we parse the
post-optimisation HLO text and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute (async
``-start`` forms counted once, ``-done`` forms skipped).
"""
from __future__ import annotations

import dataclasses
import re

HW = dict(
    peak_flops=197e12,      # bf16 FLOP/s per v5e chip
    hbm_Bps=819e9,          # HBM bandwidth per chip
    ici_Bps=50e9,           # per-link ICI bandwidth
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\(")
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_DEF_RE = re.compile(r"%([\w.\-]+) = ")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _result_bytes_map(hlo_text: str) -> dict[str, int]:
    """instruction name -> total bytes of its result (tuples summed).

    Post-optimisation HLO prints operands WITHOUT inline shapes, so
    collective operand sizes are recovered by looking up the producing
    instruction's result shape.
    """
    sizes: dict[str, int] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        if line.startswith("ROOT "):
            line = line[5:]
        m = _DEF_RE.match(line)
        if not m:
            continue
        rhs = line[m.end():]
        # result-type region: everything before the opcode's '('
        paren = rhs.find("(")
        # tuple results start with '(' immediately: find the opcode paren
        if rhs.startswith("("):
            close = rhs.find(")")
            region = rhs[: close + 1]
        else:
            region = rhs[:paren] if paren > 0 else rhs
        nbytes = sum(_shape_bytes(d, s)
                     for d, s in _SHAPE_RE.findall(region))
        if nbytes:
            sizes[m.group(1)] = nbytes
    return sizes


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind operand bytes (per device) from HLO text."""
    sizes = _result_bytes_map(hlo_text)
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        start = line.index(m.group(0)) + len(m.group(0)) - 1
        depth = 0
        end = start
        for i in range(start, len(line)):
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = _OPERAND_RE.findall(line[start:end + 1])
        nbytes = sum(sizes.get(op, 0) for op in operands)
        out[kind] = out.get(kind, 0) + nbytes
    return out


def count_collectives(hlo_text: str) -> dict[str, int]:
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m:
            out[m.group(1)] = out.get(m.group(1), 0) + 1
    return out


# ---------------------------------------------------------------------------
# While-trip-aware accounting.
#
# XLA's HloCostAnalysis (and a naive text scan) counts a while-loop body
# ONCE, but lax.scan bodies execute trip-count times — for a scanned layer
# stack that undercounts flops/bytes/collective-traffic by ~n_layers.
# (Measured: an 8-iteration scan of a 512^3 matmul reports exactly one
# iteration's flops.)  We reconstruct per-computation execution multipliers
# by walking the call graph: while bodies/conditions weighted by the trip
# count parsed from the condition's `compare(iv, constant(N))`.
# ---------------------------------------------------------------------------

_COMP_NAME = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)")
_CALL_RE = re.compile(
    r"(?:condition|body|to_apply|calls)=%?([\w.\-]+)")
_WHILE_RE = re.compile(r"\bwhile\(")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_ENTRY_KEY = "__entry__"


def _computations(hlo_text: str) -> dict[str, list[str]]:
    """name -> body lines.  The ENTRY computation's real name is also
    stored under ``_ENTRY_KEY`` (as a name alias)."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        s = line.strip()
        # computation headers end with '{' and declare a return type;
        # argument lists may contain nested tuple parens, so match loosely
        if s.endswith("{") and ("->" in s or s.startswith("ENTRY")):
            m = _COMP_NAME.match(s)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if s.startswith("ENTRY"):
                    comps[_ENTRY_KEY] = [cur]
                continue
        if s.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(s)
    return comps


def _trip_count(cond_lines: list[str]) -> int:
    """Trip count from a while condition.

    lax.scan conditions are `compare(iv, constant(N), LT)`, but XLA often
    wraps the compare in a kLoop fusion; the loop-bound constant still
    appears in the condition computation itself, and it is the only
    non-trivial constant there — so take the max constant found.
    """
    best = 1
    for s in cond_lines:
        for c in _CONST_RE.findall(s):
            best = max(best, int(c))
    return min(best, 10_000_000)


def computation_multipliers(hlo_text: str) -> dict[str, int]:
    """computation name -> number of executions of one program run."""
    comps = _computations(hlo_text)
    if not comps:
        return {}
    if _ENTRY_KEY in comps:
        entry = comps.pop(_ENTRY_KEY)[0]
    else:
        entry = next(iter(comps))
    mult: dict[str, int] = {}

    def visit(name: str, factor: int):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0) + factor
        for s in comps[name]:
            callees = _CALL_RE.findall(s)
            if not callees:
                continue
            weight = factor
            if _WHILE_RE.search(s):
                cond_name = None
                m = re.search(r"condition=%?([\w.\-]+)", s)
                if m:
                    cond_name = m.group(1)
                trips = _trip_count(comps.get(cond_name, []))
                weight = factor * trips
            for c in callees:
                visit(c, weight)

    visit(entry, 1)
    return mult


def collective_bytes_tripaware(hlo_text: str) -> dict[str, float]:
    """collective_bytes with while-body traffic multiplied by trip count."""
    sizes = _result_bytes_map(hlo_text)
    comps = _computations(hlo_text)
    mult = computation_multipliers(hlo_text)
    out: dict[str, float] = {}
    for cname, lines in comps.items():
        if cname == _ENTRY_KEY:
            continue
        factor = mult.get(cname, 0)
        if factor == 0:
            continue
        for line in lines:
            m = _COLL_RE.search(line)
            if not m:
                continue
            kind = m.group(1)
            start = line.index(m.group(0)) + len(m.group(0)) - 1
            depth = 0
            end = start
            for i in range(start, len(line)):
                if line[i] == "(":
                    depth += 1
                elif line[i] == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operands = _OPERAND_RE.findall(line[start:end + 1])
            nbytes = sum(sizes.get(op, 0) for op in operands)
            out[kind] = out.get(kind, 0.0) + float(nbytes * factor)
    return out


@dataclasses.dataclass
class Roofline:
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: dict
    model_flops: float            # 6ND (train) / 2ND (inference), active
    raw_cost_analysis: dict = dataclasses.field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / HW["peak_flops"]

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HW["hbm_Bps"]

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_device / HW["ici_Bps"]

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step time = max of the three overlappable terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs_global — remat/redundancy waste."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs / (chips * peak * roofline step time)."""
        denom = self.chips * HW["peak_flops"] * self.step_s
        return self.model_flops / denom if denom else 0.0

    def report(self) -> dict:
        return dict(
            chips=self.chips,
            compute_s=self.compute_s,
            memory_s=self.memory_s,
            collective_s=self.collective_s,
            bottleneck=self.bottleneck,
            step_s=self.step_s,
            model_flops=self.model_flops,
            hlo_flops_global=self.flops_per_device * self.chips,
            useful_flops_ratio=self.useful_flops_ratio,
            roofline_mfu=self.mfu,
            coll_breakdown=self.coll_breakdown,
            raw_cost_analysis=self.raw_cost_analysis,
        )


# ---------------------------------------------------------------------------
# Analytic cost model (matmul-exact FLOPs; parameter/activation HBM-traffic
# model).  Needed because HloCostAnalysis counts scan bodies once (see
# above); these formulas ARE the per-cell roofline numerators, with the raw
# cost_analysis kept alongside in every dry-run JSON for cross-checking.
# ---------------------------------------------------------------------------

def _layer_flops_per_token(cfg, kind: str, S_ctx: float, train: bool,
                           decode: bool) -> float:
    """Forward FLOPs per token for one layer of ``kind``.

    S_ctx: attended context length (chunked attention computes all
    (masked) blocks, so the score/AV term uses the full S, or
    window+chunk for the banded local path).
    """
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    f = cfg.d_ff
    gated = cfg.mlp in ("swiglu", "geglu")
    mlp_f = (6 if gated else 4) * d * f

    if kind == "ssm":
        din, N, Hs, P = (cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
                         cfg.ssm_head_dim)
        proj = 2 * d * (2 * din + 2 * N + Hs) + 2 * din * d
        conv = 2 * cfg.ssm_conv * (din + 2 * N)
        if decode:
            ssd = 4 * Hs * P * N                    # state update + readout
        else:
            Q = cfg.ssm_chunk
            ssd = Q * (2 * N + 2 * Hs * P) + 4 * Hs * P * N
        return proj + conv + ssd
    if kind == "rglru":
        w = cfg.lru_width or d
        rec = 2 * d * w * 2 + 2 * w * w * 2 + 2 * w * d \
            + 2 * cfg.ssm_conv * w + 10 * w
        return rec + mlp_f
    # attention kinds
    qkvo = 2 * d * H * hd + 2 * 2 * d * KV * hd + 2 * H * hd * d
    if kind == "cross":
        scores = 4 * cfg.n_frontend_tokens * H * hd
        if decode:
            qkvo = 2 * d * H * hd + 2 * H * hd * d   # K/V cached
        return qkvo + scores + mlp_f
    scores = 4 * S_ctx * H * hd
    ffn = mlp_f
    if cfg.n_experts:
        # router + K routed experts (+ shared); dispatch is gather/scatter
        ffn = 2 * d * cfg.n_experts \
            + cfg.experts_per_token * cfg.capacity_factor * mlp_f \
            + cfg.n_shared_experts * mlp_f
    return qkvo + scores + ffn


TRAIN_FLOP_FACTOR = 4.0


def analytic_flops(cfg, shape) -> float:
    """Total executed FLOPs (global, forward+backward as appropriate)."""
    from repro.models.layers import (ATTN_CHUNK, CAUSAL_BLOCK_UNROLL,
                                     CHUNKED_ATTN_THRESHOLD)
    from repro.models.transformer import layer_kinds
    S = shape.seq_len
    decode = shape.kind == "decode"
    train = shape.kind == "train"
    tokens = shape.global_batch if decode else shape.tokens
    total = 0.0
    for kind in layer_kinds(cfg):
        if decode:
            s_ctx = (min(cfg.local_window, S)
                     if (cfg.block_pattern and kind == "attn")
                     else S)
        elif cfg.block_pattern and kind == "attn" and cfg.local_window:
            s_ctx = min(S, cfg.local_window + ATTN_CHUNK)
        else:
            s_ctx = S
            nq = S // ATTN_CHUNK
            if (S > CHUNKED_ATTN_THRESHOLD
                    and 1 < nq <= CAUSAL_BLOCK_UNROLL):
                # causal-blocked path computes only (nq+1)/(2nq) of blocks
                s_ctx = S * (nq + 1) / (2 * nq)
        total += _layer_flops_per_token(cfg, kind, s_ctx, train, decode)
    total += 2 * cfg.d_model * cfg.vocab           # head matmul
    total *= tokens
    if train:
        # stack: fwd + remat recompute + bwd = 4x fwd under full remat
        # (nested attention checkpointing adds ~1 more fwd on the score
        # terms — folded in); 3x when dots are saved (set by dryrun
        # --remat dots via TRAIN_FLOP_FACTOR)
        return TRAIN_FLOP_FACTOR * total
    return total


def analytic_bytes(cfg, shape, chips: int) -> float:
    """Per-device HBM traffic model (documented, coarse):

    * params: read for fwd (+recompute +bwd) as bf16 casts of f32 masters,
      optimizer read/write p/m/v f32 (train);
    * activations: ~12 (B,S,d)-sized tensor read/writes per layer + MLP/
      attention internals, bf16;
    * decode: full KV-cache / recurrent-state read + write-back of one slot.
    """
    n_params = cfg.n_params()
    p_dev = n_params * 4.0 / chips
    L = cfg.n_layers
    d = cfg.d_model
    act_width = d + cfg.n_heads * cfg.resolved_head_dim \
        + (cfg.experts_per_token * cfg.capacity_factor
           if cfg.n_experts else 1) * cfg.d_ff * 0.5
    if shape.kind == "decode":
        tokens_dev = shape.global_batch / min(chips, shape.global_batch)
        cache = 0.0
        for kind in (cfg.layer_kind(i) for i in range(L)):
            if kind in ("attn", "cross"):
                ctx = (min(cfg.local_window, shape.seq_len)
                       if cfg.block_pattern else shape.seq_len)
                cache += 2 * ctx * cfg.n_kv_heads * cfg.resolved_head_dim \
                    * 2.0
            elif kind == "ssm":
                cache += cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state \
                    * 4.0
            elif kind == "rglru":
                cache += (cfg.lru_width or d) * 4.0
        cache_dev = cache * shape.global_batch / chips * (
            1.0 if shape.global_batch >= 16 else chips / 16)
        return p_dev + cache_dev + tokens_dev * L * act_width * 2 * 4
    tokens_dev = shape.tokens / chips
    act = tokens_dev * L * (12 * d + 2 * act_width) * 2.0
    mult = 3.0 if shape.kind == "train" else 1.0     # fwd+recompute+bwd
    opt = 20.0 * p_dev if shape.kind == "train" else 0.0
    return mult * act + 3.0 * p_dev + opt


def model_flops_for(cfg, shape) -> float:
    """6*N_active*tokens (train) / 2*N_active*tokens (inference).

    N counts matmul-participating params: the embedding table is a
    gather (0 FLOPs), so vocab*d is subtracted once (for tied embeddings
    the same table IS the head matmul, which stays counted).
    """
    n = cfg.n_active_params() - cfg.vocab * cfg.d_model
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch      # decode: one token per seq


def analyze(compiled, chips: int, cfg, shape) -> Roofline:
    """Roofline terms for one compiled cell.

    FLOPs/bytes numerators come from the analytic model (exact matmul
    accounting; HloCostAnalysis counts scan bodies once — its raw values
    are kept in ``raw_cost_analysis`` for cross-checking).  Collective
    bytes come from the trip-aware HLO walk.
    """
    ca = compiled.cost_analysis() or {}
    text = compiled.as_text()
    coll = collective_bytes_tripaware(text)
    coll_once = collective_bytes(text)
    return Roofline(
        chips=chips,
        flops_per_device=analytic_flops(cfg, shape) / chips,
        bytes_per_device=analytic_bytes(cfg, shape, chips),
        coll_bytes_per_device=float(sum(coll.values())),
        coll_breakdown=coll,
        model_flops=model_flops_for(cfg, shape),
        raw_cost_analysis=dict(
            flops_per_device_scan_once=float(ca.get("flops", 0.0)),
            bytes_per_device_scan_once=float(
                ca.get("bytes accessed", 0.0)),
            collective_bytes_scan_once=coll_once,
        ),
    )
