import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count at first init.
# This is the ONLY entry point that fakes 512 devices (dry-run exclusive).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell: build the step function (train_step for train shapes,
prefill/serve_step for serving shapes), attach FSDPxTP shardings from
``launch.sharding``, ``.lower().compile()`` on the production mesh, and
record ``memory_analysis()`` (fits-proof) + ``cost_analysis()`` +
parsed collective bytes (roofline fuel) to a JSON per cell.

Also dry-runs the paper's own distributed vector-search step (sharded
index fan-out/merge — core/distributed.py) on the same meshes.

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs.archs import ARCHS
from repro.configs.shapes import SHAPES, shapes_for
from repro.launch import roofline as rf
from repro.launch import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.models.model import LM
from repro.train import optimizer as opt
from repro.train.train_step import make_train_step

from jax.sharding import NamedSharding, PartitionSpec as P


def build_step(arch: str, shape_name: str, mesh):
    """Returns (jitted fn, example args (abstract), chips)."""
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    lm = LM(cfg)
    specs = lm.input_specs(shape)
    aparams = lm.abstract_params()
    psh = sh.params_shardings(mesh, aparams)
    bsh = sh.batch_shardings(mesh, specs["batch"])

    if shape.kind == "train":
        ocfg = opt.OptimizerConfig()
        aopt = jax.eval_shape(lambda p: opt.init_state(p), aparams)
        osh = {"m": psh, "v": psh,
               "step": NamedSharding(mesh, P())}
        # auto-microbatching: the remat carry stack is
        # L x B_loc x S x d bf16 per chip; split the per-device batch so
        # it stays under ~5 GB (grad accumulation via lax.scan)
        dp = 1
        dp_axes = ("pod", "data", "model") if sh.POLICY == "fsdp" \
            else ("pod", "data")
        for a in dp_axes:
            if a in mesh.axis_names:
                dp *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
        b_loc = max(1, shape.global_batch // dp)
        carry_gb = (cfg.n_layers * b_loc * shape.seq_len * cfg.d_model
                    * 2) / 2 ** 30
        mb = 1
        while carry_gb / mb > 2.0 and mb < b_loc:
            mb *= 2
        step = make_train_step(lm, ocfg, microbatches=mb)
        fn = jax.jit(step,
                     in_shardings=(psh, osh, bsh),
                     out_shardings=(psh, osh, None),
                     donate_argnums=(0, 1))
        args = (aparams, aopt, specs["batch"])
    elif shape.kind == "prefill":
        fn = jax.jit(lm.prefill, in_shardings=(psh, bsh))
        args = (aparams, specs["batch"])
    else:  # decode
        csh = sh.cache_shardings(mesh, specs["caches"],
                                 shape.global_batch)
        fn = jax.jit(lm.decode_step,
                     in_shardings=(psh, bsh,
                                   NamedSharding(mesh, P()), csh),
                     out_shardings=(None, csh),
                     donate_argnums=(3,))
        args = (aparams, specs["batch"], specs["pos"], specs["caches"])
    return fn, args, cfg, shape


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str | None = None, verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    fn, args, cfg, shape = build_step(arch, shape_name, mesh)
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    mem_info = {}
    for k in ("temp_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            mem_info[k] = int(v)
    roof = rf.analyze(compiled, chips, cfg, shape)
    n_coll = rf.count_collectives(compiled.as_text())
    result = dict(
        arch=arch, shape=shape_name,
        mesh="2x16x16" if multi_pod else "16x16", chips=chips,
        status="ok", lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        memory=mem_info,
        collective_counts=n_coll,
        roofline=roof.report(),
    )
    if verbose:
        print(json.dumps(result, indent=1, default=str))
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{result['mesh']}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(result, f, indent=1, default=str)
    return result


def run_vector_search_cell(multi_pod: bool, out_dir: str | None = None
                           ) -> dict:
    """Dry-run the paper's distributed sharded-index search step."""
    from repro.core.distributed import dryrun_distributed_search
    mesh = make_production_mesh(multi_pod=multi_pod)
    with mesh:
        result = dryrun_distributed_search(mesh)
    result["mesh"] = "2x16x16" if multi_pod else "16x16"
    result["arch"] = "vector-search-distributed"
    print(json.dumps(result, indent=1, default=str))
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"vector-search_{result['mesh']}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(result, f, indent=1, default=str)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--vector-search", action="store_true")
    ap.add_argument("--policy", default="tp_fsdp",
                    choices=["tp_fsdp", "fsdp"])
    ap.add_argument("--remat", default="full", choices=["full", "dots"])
    ap.add_argument("--attn-chunk", type=int, default=0)
    ap.add_argument("--no-causal-block", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    sh.set_policy(args.policy)
    if args.remat == "dots":
        from repro.models import transformer as _tr
        from repro.launch import roofline as _rf
        _tr.set_remat_policy("dots")
        _rf.TRAIN_FLOP_FACTOR = 3.0
    if args.attn_chunk:
        from repro.models import layers as _ly
        _ly.ATTN_CHUNK = args.attn_chunk
    if args.no_causal_block:
        from repro.models import layers as _ly
        _ly.CAUSAL_BLOCK_UNROLL = 0

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = []
    if args.vector_search:
        for mp in meshes:
            run_vector_search_cell(mp, args.out)
        return
    if args.all:
        for arch, cfg in ARCHS.items():
            for shape_name, s in shapes_for(cfg).items():
                for mp in meshes:
                    mesh_tag = "2x16x16" if mp else "16x16"
                    if s is None:
                        print(f"# {arch} x {shape_name} x {mesh_tag}: "
                              f"SKIP(full attention)")
                        continue
                    try:
                        r = run_cell(arch, shape_name, mp, args.out,
                                     verbose=False)
                        roof = r["roofline"]
                        print(f"# {arch} x {shape_name} x {mesh_tag}: OK "
                              f"compile={r['compile_s']}s "
                              f"bottleneck={roof['bottleneck']} "
                              f"mfu={roof['roofline_mfu']:.3f}",
                              flush=True)
                    except Exception as e:
                        failures.append((arch, shape_name, mp))
                        print(f"# {arch} x {shape_name} x {mesh_tag}: "
                              f"FAIL {e}", flush=True)
                        traceback.print_exc()
        for mp in meshes:
            try:
                run_vector_search_cell(mp, args.out)
            except Exception as e:
                failures.append(("vector-search", "-", mp))
                traceback.print_exc()
        if failures:
            print(f"# FAILURES: {failures}")
            sys.exit(1)
        return
    run_cell(args.arch, args.shape, args.multi_pod, args.out)


if __name__ == "__main__":
    main()
