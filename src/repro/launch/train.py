"""Production training driver: mesh + sharding + fault-tolerant runner.

On real hardware this runs under `jax.distributed.initialize()` across
hosts; on this container it drives the same code path on the 1-device
mesh (smoke) — the dry-run (launch/dryrun.py) proves the production-mesh
lowering for every assigned architecture.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
        --steps 20
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs.archs import ARCHS, smoke as smoke_cfg
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch import sharding as sh
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import LM
from repro.train import optimizer as opt
from repro.train.runner import RunnerConfig, run
from repro.train.train_step import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on the 1-device host mesh")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--policy", default="tp_fsdp",
                    choices=["tp_fsdp", "fsdp"])
    ap.add_argument("--ckpt", default="/tmp/repro_launch_ckpt")
    args = ap.parse_args()

    sh.set_policy(args.policy)
    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = smoke_cfg(cfg)
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh()
    lm = LM(cfg)
    print(f"{cfg.name}: {cfg.n_params()/1e6:.1f}M params on "
          f"{mesh.devices.size} devices ({args.policy})")

    with mesh:
        params = lm.init(jax.random.PRNGKey(0))
        psh = sh.params_shardings(mesh, params)
        params = jax.tree.map(jax.device_put, params, psh)
        ocfg = opt.OptimizerConfig(total_steps=args.steps)
        opt_state = opt.init_state(params)
        pipe = TokenPipeline(DataConfig(
            vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch))
        step_fn = jax.jit(
            make_train_step(lm, ocfg, microbatches=args.microbatches),
            donate_argnums=(0, 1))

        def next_batch(s):
            b = pipe.batch(s)
            if cfg.family == "audio":
                key = jax.random.PRNGKey(s)
                b = {"frames": jax.random.normal(
                    key, (args.batch, args.seq, cfg.d_model)),
                    "labels": jnp.asarray(b["labels"])}
            elif cfg.family == "vlm":
                key = jax.random.PRNGKey(s)
                b = dict(jax.tree.map(jnp.asarray, b))
                b["image_embeds"] = jax.random.normal(
                    key, (args.batch, cfg.n_frontend_tokens, cfg.d_model))
            else:
                b = jax.tree.map(jnp.asarray, b)
            return b

        rcfg = RunnerConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                            ckpt_every=max(10, args.steps // 3))
        _, _, report = run(rcfg, step_fn, params, opt_state, next_batch)
    print(f"done: {report.steps_run} steps, "
          f"loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f}")


if __name__ == "__main__":
    main()
