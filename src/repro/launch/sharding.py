"""Sharding policy: param-path rules -> PartitionSpec (FSDP x TP).

One place encodes the whole distribution strategy; the perf hillclimb
(EXPERIMENTS.md §Perf) edits THIS file's rules and re-lowers.

Axes:
* ``model`` — tensor parallel: vocab, attention heads, d_ff, experts;
* ``data`` — batch data-parallel AND parameter FSDP (params/optimizer
  sharded over it, all-gathered at use by GSPMD);
* ``pod``  — cross-pod data parallel (multi-pod mesh only; gradient
  all-reduce rides DCN).

Dims that don't divide the axis stay unsharded unless
``allow_uneven`` — GSPMD would pad (acceptable for q-heads 36/16; wasteful
for kv-heads 8/16, where GQA-TP conventionally replicates instead).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _shard_dim(mesh: Mesh, size: int, axis: str, allow_uneven=False):
    n = _axis_size(mesh, axis)
    if n == 1:
        return None
    if size % n == 0 or (allow_uneven and size >= n):
        return axis
    return None


POLICY = "tp_fsdp"      # "tp_fsdp" (default) | "fsdp" (pure ZeRO-3 DP)


def set_policy(name: str) -> None:
    """Select the global sharding policy (perf-hillclimb lever).

    tp_fsdp — model axis does tensor parallelism (heads/d_ff/vocab/
              experts), data axis does batch DP + param FSDP.
    fsdp    — NO tensor parallelism: every mesh axis is data parallel for
              the batch; params/optimizer fully sharded (ZeRO-3) over
              (data, model) and all-gathered at use.  Wins when
              tokens-per-device is large: weight all-gather bytes
              (=params) << activation all-reduce bytes (see
              EXPERIMENTS.md §Perf).
    """
    global POLICY
    assert name in ("tp_fsdp", "fsdp"), name
    POLICY = name
    from repro.models import layers
    layers.set_batch_axes(("pod", "data", "model") if name == "fsdp"
                          else ("pod", "data"))


def batch_axes(mesh: Mesh, batch_size: int):
    """Shard batch over pod x data (+ model under the fsdp policy)."""
    names = ("pod", "data", "model") if POLICY == "fsdp" \
        else ("pod", "data")
    axes = [a for a in names if a in mesh.axis_names]
    total = 1
    used = []
    for a in axes:
        n = _axis_size(mesh, a)
        if batch_size % (total * n) == 0:
            used.append(a)
            total *= n
    if not used:
        return None
    return tuple(used) if len(used) > 1 else used[0]


def _fsdp_pspec(mesh: Mesh, path: str, leaf) -> P:
    """Pure-FSDP placement: shard the largest dim that divides the
    combined (data, model) axes; fall back to single axes."""
    lead = 1 if "unit" in path else 0
    dims = list(range(lead, leaf.ndim))
    dims.sort(key=lambda i: -leaf.shape[i])
    combos = [("data", "model"), ("data",), ("model",)]
    for combo in combos:
        size = 1
        for a in combo:
            size *= _axis_size(mesh, a)
        if size == 1:
            continue
        for i in dims:
            if leaf.shape[i] % size == 0 and leaf.shape[i] >= size:
                spec = [None] * leaf.ndim
                spec[i] = combo if len(combo) > 1 else combo[0]
                return P(*spec)
    return P(*([None] * leaf.ndim))


def param_pspec(mesh: Mesh, path: str, leaf) -> P:
    """Map a parameter (by tree path) to its PartitionSpec."""
    if POLICY == "fsdp":
        return _fsdp_pspec(mesh, path, leaf)
    nd = leaf.ndim
    shape = leaf.shape
    m = lambda size, uneven=False: _shard_dim(mesh, size, "model", uneven)
    d = lambda size: _shard_dim(mesh, size, "data")

    def spec(*axes):
        return P(*axes)

    # --- stacked layer params have a leading layer axis: skip it -------
    lead = 1 if "unit" in path else 0
    dim = lambda i: shape[lead + i]
    core_nd = nd - lead

    def wrap(*axes):
        return P(*(((None,) * lead) + axes))

    if "embed" in path:                       # (V, D)
        return spec(m(shape[0]), d(shape[1]))
    if "lm_head" in path:                     # (D, V)
        return spec(d(shape[0]), m(shape[1]))
    if path.endswith("scale") or "norm" in path:
        return wrap(*((None,) * core_nd))
    # attention
    # NOTE: jax rejects non-divisible NamedShardings at the jit boundary
    # (no GSPMD padding for arguments) — head dims that don't divide the
    # model axis (36H starcoder2, 24H musicgen, 10H recurrentgemma) stay
    # unsharded; their TP parallelism comes from d_ff/vocab instead.
    if path.endswith("wq"):                   # (D, H, hd)
        return wrap(d(dim(0)), m(dim(1)), None)
    if path.endswith("wk") or path.endswith("wv"):
        return wrap(d(dim(0)), m(dim(1)), None)   # replicated if kv < TP
    if path.endswith("wo") and core_nd == 3:  # (H, hd, D)
        return wrap(m(dim(0)), None, d(dim(2)))
    # moe
    if "router" in path:                      # (D, E)
        return wrap(d(dim(0)), None)
    if core_nd == 3 and ("wi" in path or "wg" in path):   # (E, D, F)
        return wrap(m(dim(0)), d(dim(1)), None)
    if core_nd == 3 and "wo" in path:         # (E, F, D)
        return wrap(m(dim(0)), None, d(dim(2)))
    # dense mlp
    if core_nd == 2 and ("wi" in path or "wg" in path):   # (D, F)
        return wrap(d(dim(0)), m(dim(1)))
    if core_nd == 2 and "wo" in path:         # (F, D)
        return wrap(m(dim(0)), d(dim(1)))
    # ssm / rglru projections
    if core_nd == 2 and any(k in path for k in
                            ("in_x", "in_z", "in_rec", "in_gate",
                             "w_a", "w_x")):
        return wrap(d(dim(0)), m(dim(1)))
    if core_nd == 2 and any(k in path for k in ("in_B", "in_C", "in_dt")):
        return wrap(d(dim(0)), m(dim(1)))
    if core_nd == 2 and path.endswith("out"):  # (din|W, D)
        return wrap(m(dim(0)), d(dim(1)))
    if core_nd == 2 and "conv_w" in path:      # (K, C)
        return wrap(None, m(dim(1)))
    if core_nd == 1:                           # per-channel vectors
        return wrap(m(dim(0)))
    return wrap(*((None,) * core_nd))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def params_shardings(mesh: Mesh, abstract_params) -> Any:
    """NamedSharding pytree for a params (or optimizer m/v) pytree."""
    def one(path, leaf):
        ps = _path_str(path)
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, param_pspec(mesh, ps, leaf))
    return jax.tree_util.tree_map_with_path(one, abstract_params)


def opt_state_shardings(mesh: Mesh, abstract_opt_state, psharding):
    """m/v mirror params; step is replicated."""
    return {
        "m": psharding["params"] if isinstance(psharding, dict)
        else psharding,
        "v": psharding["params"] if isinstance(psharding, dict)
        else psharding,
        "step": NamedSharding(mesh, P()),
    }


def batch_shardings(mesh: Mesh, abstract_batch) -> Any:
    """Inputs: shard leading (batch) dim over pod x data."""
    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        ba = batch_axes(mesh, leaf.shape[0])
        return NamedSharding(
            mesh, P(ba, *([None] * (leaf.ndim - 1))))
    return jax.tree.map(one, abstract_batch)


def cache_shardings(mesh: Mesh, abstract_caches, batch_size: int) -> Any:
    """KV caches / recurrent state: batch dim over data, kv-heads over
    model when divisible.  Stacked unit caches carry a leading layer dim."""
    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        axes: list = [None] * leaf.ndim
        # find the batch dim: first dim equal to batch_size
        for i, s in enumerate(leaf.shape):
            if s == batch_size:
                axes[i] = batch_axes(mesh, batch_size)
                break
        # shard a heads-like or state dim over model if divisible
        msize = _axis_size(mesh, "model")
        if msize > 1:
            for i in range(leaf.ndim - 1, 0, -1):
                if axes[i] is None and leaf.shape[i] % msize == 0 \
                        and leaf.shape[i] >= msize:
                    axes[i] = "model"
                    break
        return NamedSharding(mesh, P(*axes))
    return jax.tree.map(one, abstract_caches)
