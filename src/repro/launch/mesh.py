"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — smoke tests must keep seeing 1 CPU device.

Production target: TPU v5e pods, 256 chips each, 16x16 ICI torus;
``multi_pod=True`` models 2 pods (512 chips) with a leading "pod" axis
(DCN between pods, ICI within).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh with the same axis names — lets every
    sharded program also run on the CPU container for smoke testing."""
    return jax.make_mesh((1, 1), ("data", "model"))
