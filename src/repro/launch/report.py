"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline table.

    PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load_cells(d: str) -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def fmt_table(cells: list[dict], mesh: str = "16x16") -> str:
    rows = []
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "bottleneck | useful ratio | roofline MFU | temp GB/chip |")
    sep = "|" + "---|" * 9
    rows.append(hdr)
    rows.append(sep)
    for c in cells:
        if c.get("mesh") != mesh or "roofline" not in c:
            continue
        r = c["roofline"]
        if "compute_s" not in r:
            continue
        temp = c.get("memory", {}).get("temp_size_in_bytes", 0) / 2 ** 30
        rows.append(
            f"| {c['arch']} | {c.get('shape','-')} "
            f"| {r['compute_s']:.3g} | {r['memory_s']:.3g} "
            f"| {r['collective_s']:.3g} | {r.get('bottleneck','-')} "
            f"| {r.get('useful_flops_ratio',0):.2f} "
            f"| {r.get('roofline_mfu',0):.3f} | {temp:.1f} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    cells = load_cells(args.dir)
    print(fmt_table(cells, args.mesh))


if __name__ == "__main__":
    main()
