"""Production serving driver: retrieval-augmented generation.

Pipeline (DESIGN.md §4): LM embeds the corpus -> cloud vector index
(simulated TOS) -> per-request retrieve -> prefill -> decode.  The
1-device smoke path exercises the exact code the dry-run compiles for
the production meshes.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b \
        --requests 4 --tokens 8
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.archs import ARCHS, smoke as smoke_cfg
from repro.core.cluster_index import ClusterIndex
from repro.core.types import ClusterIndexParams, SearchParams
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.model import LM
from repro.serve.decode import generate
from repro.serving.engine import run_workload
from repro.storage.spec import TOS


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--corpus", type=int, default=128)
    ap.add_argument("--k", type=int, default=4)
    args = ap.parse_args()

    cfg = smoke_cfg(ARCHS[args.arch])
    if cfg.family in ("audio",):
        raise SystemExit("serve driver targets token archs; musicgen's "
                         "frontend is a stub (see examples/)")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=32,
                                    global_batch=64))
    embed = jax.jit(
        lambda p, b: lm._backbone(p, b).astype(jnp.float32).mean(1))

    docs = np.concatenate([pipe.batch(s)["tokens"]
                           for s in range(args.corpus // 64)])
    vecs = []
    for s in range(0, len(docs), 64):
        b = {"tokens": jnp.asarray(docs[s:s + 64])}
        if cfg.family == "vlm":
            b["image_embeds"] = jnp.zeros(
                (64, cfg.n_frontend_tokens, cfg.d_model))
        v = np.asarray(embed(params, b))
        vecs.append(v / np.linalg.norm(v, axis=1, keepdims=True))
    vecs = np.concatenate(vecs).astype(np.float32)
    index = ClusterIndex.build(vecs, ClusterIndexParams(
        centroid_frac=0.2, num_replica=4))
    print(f"indexed {len(vecs)} docs "
          f"({index.meta.index_bytes/1e3:.0f} KB on {TOS.name})")

    qtok = pipe.batch(999)["tokens"][: args.requests]
    qb = {"tokens": jnp.asarray(qtok)}
    if cfg.family == "vlm":
        qb["image_embeds"] = jnp.zeros(
            (args.requests, cfg.n_frontend_tokens, cfg.d_model))
    qv = np.asarray(embed(params, qb))
    qv = (qv / np.linalg.norm(qv, axis=1, keepdims=True)).astype(
        np.float32)
    rep = run_workload(index, qv, SearchParams(k=args.k, nprobe=8), TOS,
                       concurrency=args.requests)
    print(f"retrieval p50 {rep.latency_percentile(50)*1e3:.1f} ms, "
          f"{rep.mean_bytes_read/1e3:.1f} KB/query")

    for rec in rep.records:
        top = rec.ids[rec.ids >= 0][:2]
        ctx = np.concatenate([docs[d] for d in top]
                             + [qtok[rec.qid]])[-64:]
        gb = {"tokens": jnp.asarray(ctx[None])}
        if cfg.family == "vlm":
            gb["image_embeds"] = jnp.zeros(
                (1, cfg.n_frontend_tokens, cfg.d_model))
        out = generate(lm, params, gb, n_tokens=args.tokens)
        print(f"request {rec.qid}: docs {list(top)} -> {out[0].tolist()}")


if __name__ == "__main__":
    main()
