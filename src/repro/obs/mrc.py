"""Online miss-ratio curves via SHARDS spatial sampling.

The cache-split tuner (``repro.tuning.tenancy``) prices candidate
splits from each tenant's **miss-ratio curve** (MRC).  Offline it
builds one from an exact object-access profile; this module estimates
the same curve **online**, from the live cache access stream, using the
SHARDS idea (Waldspurger et al., FAST'15): hash every key into [0, 1)
and track reuse distances only for keys below a fixed sampling
threshold ``R``, then scale each measured stack distance by ``1/R``.
Spatial (per-key) sampling keeps every sampled key's *complete* reuse
sequence, which is what makes the scaled distances unbiased — temporal
sampling would not.

Determinism and the observer contract:

* the sampling decision is a pure hash (``crc32(repr(key))``) — no RNG
  anywhere, so two identical runs produce identical curves;
* the estimator attaches to :class:`repro.cache.slru.SLRUCache` via its
  ``observer`` hook (a *sampled ghost list*: key metadata only, no
  payload bytes) and reads the stream without mutating the cache, so
  MRC-profiled runs stay bit-exact against the goldens.

Memory is bounded: per tenant, one ordered dict over *sampled* keys
plus a ~200-bucket log histogram of scaled distances, independent of
run length at a fixed sampling rate.

Accuracy (documented tolerance, asserted in
``tests/test_explain.py``): against the exact Che-approximation curve
on a synthetic zipf profile the SHARDS estimate is within **0.05 mean /
0.10 max** absolute miss-ratio error at ``sample_rate=1.0`` (exact
stack distances; residual error is LRU-vs-Che model difference) and
within **0.08 mean / 0.15 max** at ``sample_rate=0.25``.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from collections import OrderedDict

__all__ = ["MRCConfig", "TenantMRC", "MRCProfiler", "default_size_grid",
           "mrc_miss_ratio"]

#: log2 sub-buckets per octave for the distance histogram (~19% bucket
#: width — finer than the tolerance above, so bucketing is not the
#: accuracy bottleneck).
_BUCKETS_PER_OCTAVE = 4


@dataclasses.dataclass(frozen=True)
class MRCConfig:
    """Knobs for online MRC profiling."""

    sample_rate: float = 0.5
    #: curve evaluation grid in bytes; None derives a geometric grid
    #: around the fleet's per-instance cache budget.
    sizes: tuple[int, ...] | None = None

    def __post_init__(self) -> None:
        if not (0.0 < self.sample_rate <= 1.0):
            raise ValueError(f"sample_rate must be in (0, 1], got "
                             f"{self.sample_rate}")
        if self.sizes is not None and not self.sizes:
            raise ValueError("sizes grid must be non-empty when given")

    def to_dict(self) -> dict:
        return dict(sample_rate=self.sample_rate,
                    sizes=list(self.sizes) if self.sizes else None)


def default_size_grid(ref_bytes: int) -> tuple[int, ...]:
    """Geometric grid around a reference cache size: ref/16 .. 8*ref."""
    ref = max(int(ref_bytes), 1024)
    return tuple(ref * 2 ** i // 16 * 16 or 16 for i in range(-4, 4))


def _key_hash01(key) -> float:
    """Deterministic spatial hash of a cache key into [0, 1).

    crc32 alone is linear in GF(2), so near-identical keys (``(tid, i)``
    tuples differing in one digit) land on correlated values; the
    murmur3 fmix32 finalizer avalanches the bits so the sampled key set
    is unbiased even over tiny structured key spaces."""
    h = zlib.crc32(repr(key).encode()) & 0xFFFFFFFF
    h ^= h >> 16
    h = (h * 0x85EBCA6B) & 0xFFFFFFFF
    h ^= h >> 13
    h = (h * 0xC2B2AE35) & 0xFFFFFFFF
    h ^= h >> 16
    return h / 2 ** 32


class TenantMRC:
    """SHARDS reuse-distance estimator for one tenant's access stream."""

    def __init__(self, sample_rate: float = 0.5):
        self.sample_rate = float(sample_rate)
        #: sampled keys, LRU order (MRU last) -> last-known size in bytes
        self._stack: OrderedDict = OrderedDict()
        #: log2 bucket index -> count of scaled reuse distances
        self._dist: dict[int, int] = {}
        self.accesses = 0           # every access, sampled or not
        self.sampled = 0
        self.cold = 0               # sampled first-touches (inf distance)
        self.size_sum = 0.0         # over sampled sized accesses
        self.size_n = 0

    # ------------------------------------------------------------ intake --
    def access(self, key, nbytes: int | None = None) -> None:
        """One cache lookup.  ``nbytes`` may be unknown (None) at lookup
        time; :meth:`learn_size` backfills it from the put path."""
        self.accesses += 1
        if _key_hash01(key) >= self.sample_rate:
            return
        self.sampled += 1
        stack = self._stack
        if key in stack:
            # byte stack distance: this key + every sampled key touched
            # more recently than its previous access (MRU side of the
            # ordered dict, walked until we meet the key itself)
            dist = 0.0
            for k2 in reversed(stack):
                if k2 == key:
                    break
                dist += stack[k2]
            size = stack.pop(key)
            if nbytes is not None:
                size = nbytes
            dist += size
            self._record(dist / self.sample_rate)
            stack[key] = size
        else:
            self.cold += 1
            stack[key] = nbytes if nbytes is not None else 0
        if nbytes is not None:
            self.size_sum += nbytes
            self.size_n += 1

    def learn_size(self, key, nbytes: int) -> None:
        """Backfill a sampled key's size from the cache fill path."""
        if key in self._stack and self._stack[key] == 0:
            self._stack[key] = nbytes
        if _key_hash01(key) < self.sample_rate:
            self.size_sum += nbytes
            self.size_n += 1

    def _record(self, dist: float) -> None:
        if dist <= 0:
            b = 0
        else:
            b = max(0, int(math.log2(dist) * _BUCKETS_PER_OCTAVE))
        self._dist[b] = self._dist.get(b, 0) + 1

    # ------------------------------------------------------------- curve --
    @property
    def mean_obj_bytes(self) -> float:
        return self.size_sum / self.size_n if self.size_n else 0.0

    def miss_ratio(self, cache_bytes: int) -> float:
        """Estimated miss ratio of an LRU-ish cache of ``cache_bytes``
        for this stream: fraction of sampled accesses whose scaled
        reuse distance exceeds the size, plus all cold misses."""
        if self.sampled == 0:
            return 1.0
        if cache_bytes <= 0:
            return 1.0
        misses = float(self.cold)
        log_c = math.log2(cache_bytes) * _BUCKETS_PER_OCTAVE
        for b, n in self._dist.items():
            if b > log_c:
                misses += n
            elif b + 1 > log_c:
                # C falls inside this bucket: log-uniform interpolation
                misses += n * (b + 1 - log_c)
        return min(1.0, misses / self.sampled)

    def curve(self, sizes) -> list[float]:
        return [round(self.miss_ratio(int(s)), 6) for s in sizes]

    def to_dict(self, sizes) -> dict:
        return dict(
            accesses=self.accesses, sampled=self.sampled,
            cold=self.cold, sampled_keys=len(self._stack),
            mean_obj_bytes=round(self.mean_obj_bytes, 3),
            sizes=[int(s) for s in sizes],
            miss_ratio=self.curve(sizes))


class MRCProfiler:
    """Per-tenant online MRC over a fleet's cache access stream.

    Implements the :class:`~repro.cache.slru.SLRUCache` observer
    protocol (``record_get`` / ``record_put``); one profiler instance
    observes every instance cache in the fleet, so the estimated curve
    models the *aggregate* cache — the same operating point the
    cache-split tuner prices.  Tenant identity comes from the fleet's
    namespaced fetch keys ``(tid, *native_key)``.
    """

    def __init__(self, cfg: MRCConfig | None = None, *,
                 ref_bytes: int = 0,
                 tenant_names: dict[int, str] | None = None):
        self.cfg = cfg or MRCConfig()
        self.ref_bytes = int(ref_bytes)
        self.sizes = tuple(self.cfg.sizes) if self.cfg.sizes \
            else default_size_grid(self.ref_bytes)
        self.tenant_names = dict(tenant_names or {})
        self._tenants: dict[int, TenantMRC] = {}

    # -------------------------------------------------- observer protocol --
    @staticmethod
    def _tid(key) -> int:
        if isinstance(key, tuple) and key and isinstance(key[0], int):
            return key[0]
        return 0

    def _est(self, tid: int) -> TenantMRC:
        est = self._tenants.get(tid)
        if est is None:
            est = self._tenants[tid] = TenantMRC(self.cfg.sample_rate)
        return est

    def record_get(self, key, hit: bool) -> None:
        self._est(self._tid(key)).access(key)

    def record_put(self, key, nbytes: int) -> None:
        self._est(self._tid(key)).learn_size(key, nbytes)

    # ------------------------------------------------------------- wiring --
    def install(self, cache) -> None:
        """Attach to a cache object: a bare :class:`SLRUCache`, or a
        tenancy assembly (``.inner`` shared SLRU / ``.parts`` per-tenant
        SLRUs).  Unknown cache shapes (PinnedCache, None) are skipped —
        MRC needs an LRU-family access stream."""
        if cache is None:
            return
        if hasattr(cache, "set_observer"):
            cache.set_observer(self)
        elif hasattr(cache, "observer"):
            cache.observer = self
        elif hasattr(cache, "inner"):
            self.install(cache.inner)
        elif hasattr(cache, "parts"):
            for part in cache.parts.values():
                self.install(part)

    def wrap_factory(self, factory):
        """Wrap a cache factory so rebuilt caches (cold-cache fault
        recovery, autoscale scale-up) come back with the profiler
        already attached."""
        def _make():
            cache = factory()
            self.install(cache)
            return cache
        return _make

    # ---------------------------------------------------------- reporting --
    def _name(self, tid: int) -> str:
        return self.tenant_names.get(tid) or f"t{tid}"

    def publish(self, registry) -> None:
        """Live gauges: ``cache.mrc.<tenant>.mr`` (miss ratio at the
        reference size), ``.mr_half`` / ``.mr_double`` (curve slope
        around the operating point) and ``.samples``."""
        ref = self.ref_bytes
        for tid in sorted(self._tenants):
            est = self._tenants[tid]
            name = self._name(tid)
            registry.gauge(f"cache.mrc.{name}.mr").set(
                est.miss_ratio(ref))
            registry.gauge(f"cache.mrc.{name}.mr_half").set(
                est.miss_ratio(ref // 2))
            registry.gauge(f"cache.mrc.{name}.mr_double").set(
                est.miss_ratio(ref * 2))
            registry.gauge(f"cache.mrc.{name}.samples").set(est.sampled)

    def to_dict(self, wall_s: float | None = None) -> dict:
        """The ``mrc`` report block (and the ``--mrc`` artifact schema
        ``tune_cache_split`` accepts): per-tenant curves plus the demand
        rate the split screen prices misses against."""
        tenants = []
        for tid in sorted(self._tenants):
            est = self._tenants[tid]
            row = dict(tid=tid, name=self._name(tid),
                       **est.to_dict(self.sizes))
            if wall_s and wall_s > 0:
                row["demand_bytes_per_s"] = round(
                    est.accesses * est.mean_obj_bytes / wall_s, 3)
            tenants.append(row)
        return dict(sample_rate=self.cfg.sample_rate,
                    ref_bytes=self.ref_bytes,
                    sizes=[int(s) for s in self.sizes],
                    tenants=tenants)


def mrc_miss_ratio(sizes, miss_ratio, cache_bytes: float) -> float:
    """Interpolate a sampled miss-ratio curve at ``cache_bytes``
    (log-linear in size, clamped at the grid ends) — how the cache-split
    tuner reads ``--mrc`` artifacts."""
    pts = sorted(zip((float(s) for s in sizes),
                     (float(m) for m in miss_ratio)))
    if not pts:
        raise ValueError("empty miss-ratio curve")
    c = float(cache_bytes)
    if c <= pts[0][0]:
        return pts[0][1]
    if c >= pts[-1][0]:
        return pts[-1][1]
    for (s0, m0), (s1, m1) in zip(pts, pts[1:]):
        if s0 <= c <= s1:
            if s1 <= s0:
                return m1
            f = (math.log(c) - math.log(s0)) / \
                (math.log(s1) - math.log(s0))
            return m0 + f * (m1 - m0)
    return pts[-1][1]
