"""Dollar-denominated cost metering: price books, fleet cost folding,
and per-tenant show-back.

The simulator already counts every billable quantity — object-store GET
and PUT requests and bytes (``StorageSim``), instance-seconds
(``ShardServer.active_seconds``), and the cache DRAM each instance
reserves (``FleetConfig.cache_bytes``).  A :class:`PriceBook` turns
those counts into dollars *after* the run: costing is pure arithmetic
over the report, never a kernel event, so pricing a run cannot perturb
it (the bit-exactness tests in ``tests/test_monitor_cost.py`` enforce
this).

Two folds are provided:

* :func:`fleet_cost` — one fleet run → component dollars, total, and
  per-query unit economics (``usd_per_1k_queries``, ``queries_per_usd``).
* :func:`tenant_showback` — a multi-tenant run → a show-back table.
  Directly attributable costs (a tenant's storage GETs, egress bytes
  and ingest I/O) are charged to the tenant that caused them; shared
  costs (instance-hours and cache DRAM) are apportioned by each
  tenant's share of executed shard jobs.  I/O the per-query records
  cannot attribute (fault-aborted jobs whose metrics never merged back)
  lands in an explicit ``(unattributed)`` row, so the table sums to the
  fleet total *by construction* within float error.

Prices are config, not physics: ship presets live in
:data:`PRICEBOOKS` and ``--pricebook PATH`` accepts a JSON file with
the same fields (see ``docs/cost.md``).
"""
from __future__ import annotations

import dataclasses
import json
import os

GiB = float(1 << 30)

#: dollars are rounded for JSON emission only; sums are checked on the
#: unrounded values.
_USD_DECIMALS = 9


def _usd(v: float) -> float:
    return round(float(v), _USD_DECIMALS)


@dataclasses.dataclass(frozen=True)
class PriceBook:
    """Unit prices for everything the simulator meters.

    Defaults are deliberately in the ballpark of published cloud list
    prices (object-store GETs ~$0.40/M, PUTs ~$5/M, intra-region
    egress, a mid-size cache-carrying instance) so the *ratios* — PUTs
    ~12x GETs, requests vs bytes vs compute — are realistic even though
    absolute dollars depend on the provider.
    """

    name: str = "default"
    get_per_million_usd: float = 0.40
    put_per_million_usd: float = 5.00
    egress_per_gib_usd: float = 0.02
    instance_per_hour_usd: float = 0.50
    cache_dram_per_gib_hour_usd: float = 0.05
    #: local NVMe tier reservation (repro.storage.tier) — roughly an
    #: order of magnitude under DRAM, which is the whole point of the tier
    nvme_per_gib_hour_usd: float = 0.005

    def __post_init__(self) -> None:
        for f in dataclasses.fields(self):
            if f.name == "name":
                continue
            v = getattr(self, f.name)
            if not (isinstance(v, (int, float)) and v >= 0):
                raise ValueError(f"PriceBook.{f.name} must be >= 0, "
                                 f"got {v!r}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "PriceBook":
        known = {f.name for f in dataclasses.fields(cls)}
        extra = set(d) - known
        if extra:
            raise ValueError(f"unknown PriceBook fields: {sorted(extra)} "
                             f"(known: {sorted(known)})")
        return cls(**d)

    def components(self, *, get_requests: float = 0,
                   put_requests: float = 0, read_bytes: float = 0,
                   instance_seconds: float = 0.0,
                   cache_byte_seconds: float = 0.0,
                   nvme_byte_seconds: float = 0.0) -> dict:
        """Raw metered quantities -> unrounded component dollars."""
        return dict(
            get_usd=get_requests / 1e6 * self.get_per_million_usd,
            put_usd=put_requests / 1e6 * self.put_per_million_usd,
            egress_usd=read_bytes / GiB * self.egress_per_gib_usd,
            instance_usd=(instance_seconds / 3600.0
                          * self.instance_per_hour_usd),
            cache_usd=(cache_byte_seconds / GiB / 3600.0
                       * self.cache_dram_per_gib_hour_usd),
            nvme_usd=(nvme_byte_seconds / GiB / 3600.0
                      * self.nvme_per_gib_hour_usd),
        )


#: Ship presets.  ``egress-heavy`` models serving results across an
#: AZ/region boundary (egress dominates); ``dense-cache`` models a
#: memory-optimized tier where DRAM, not requests, is the spend.
PRICEBOOKS: dict[str, PriceBook] = {
    "default": PriceBook(),
    "egress-heavy": PriceBook(name="egress-heavy",
                              egress_per_gib_usd=0.09,
                              instance_per_hour_usd=0.40),
    "dense-cache": PriceBook(name="dense-cache",
                             instance_per_hour_usd=1.00,
                             cache_dram_per_gib_hour_usd=0.25),
}


def resolve_pricebook(spec: str) -> PriceBook:
    """``--pricebook NAME|PATH``: a preset name, or a JSON file whose
    keys are :class:`PriceBook` fields."""
    if spec in PRICEBOOKS:
        return PRICEBOOKS[spec]
    if os.path.exists(spec):
        with open(spec) as f:
            d = json.load(f)
        d.setdefault("name", os.path.basename(spec))
        return PriceBook.from_dict(d)
    raise KeyError(f"unknown price book {spec!r}: not a preset "
                   f"({sorted(PRICEBOOKS)}) and not a file")


def _fleet_quantities(report, cfg) -> dict:
    """Pull the billable counts out of a finished ``FleetReport``."""
    stats = report.shard_stats or []
    put_requests = sum(getattr(s, "storage_put_requests", 0)
                       for s in stats)
    put_bytes = sum(getattr(s, "storage_put_bytes", 0) for s in stats)
    instance_seconds = report.shards_seconds or 0.0
    return dict(
        get_requests=report.storage_requests - put_requests,
        put_requests=put_requests,
        read_bytes=report.storage_bytes - put_bytes,
        instance_seconds=instance_seconds,
        cache_byte_seconds=cfg.cache_bytes * instance_seconds,
        nvme_byte_seconds=(getattr(cfg, "nvme_bytes", 0)
                           * instance_seconds),
    )


def fleet_cost(report, cfg, book: PriceBook) -> dict:
    """Fold one fleet run down to dollars.

    ``get/put`` charge object-store requests (PUTs are compaction
    writes, metered separately by ``StorageSim``), ``egress`` charges
    storage-served bytes (remote only — the NVMe tier's device traffic
    never crosses the NIC), ``instance`` charges shard-instance uptime
    in *simulated* hours (autoscaled instances bill only while active),
    ``cache`` charges the DRAM reservation per active instance, and
    ``nvme`` the local-tier reservation (``FleetConfig.nvme_bytes``).
    """
    q = _fleet_quantities(report, cfg)
    comp = book.components(**q)
    total = sum(comp.values())
    n = len(report.records)
    out = dict(pricebook=book.name)
    out.update({k: _usd(v) for k, v in comp.items()})
    out["total_usd"] = _usd(total)
    out["usd_per_1k_queries"] = _usd(total / n * 1000.0) if n else 0.0
    out["queries_per_usd"] = (round(n / total, 2) if total > 0 else None)
    good = getattr(report, "good_total", None)
    if good is not None and total > 0:
        out["good_queries_per_usd"] = round(good / total, 2)
    return out


def _tenant_quantities(sl) -> dict:
    """Directly attributable counts for one ``TenantSlice``.

    Storage GETs per query are ``cache_lookups - cache_hits`` (every
    planned fetch probes the cache; each miss is one object-store
    request) and egress bytes are ``bytes_storage`` — both merged from
    the jobs that completed for this tenant.  Ingest adds the tenant's
    own compaction reads (GETs) and writes (PUTs).
    """
    get_requests = sum(r.metrics.cache_lookups - r.metrics.cache_hits
                       for r in sl.records)
    read_bytes = sum(r.metrics.bytes_storage for r in sl.records)
    put_requests = 0
    ing = sl.ingest or {}
    get_requests += ing.get("compaction_read_requests", 0)
    read_bytes += ing.get("compaction_read_bytes", 0)
    put_requests += ing.get("compaction_write_requests", 0)
    return dict(get_requests=get_requests, put_requests=put_requests,
                read_bytes=read_bytes)


def tenant_showback(tenants, fleet_report, cfg, book: PriceBook) -> dict:
    """Multi-tenant show-back table; rows sum to the fleet total.

    ``tenants`` is the list of ``TenantSlice``s, ``fleet_report`` the
    aggregate ``FleetReport`` from the same run.  Shared instance +
    cache dollars are apportioned by each tenant's share of executed
    shard jobs (the unit the autoscaler and queues actually contend
    on); request/egress dollars are charged to the causing tenant.  The
    ``(unattributed)`` row carries I/O the records cannot pin on a
    tenant (fault-aborted jobs) plus any unapportioned shared residue.
    """
    q = _fleet_quantities(fleet_report, cfg)
    fleet_comp = book.components(**q)
    fleet_total = sum(fleet_comp.values())

    jobs = {sl.name: sum(r.n_jobs for r in sl.records) for sl in tenants}
    jobs_total = sum(jobs.values())
    # instance-hours, cache DRAM and the NVMe tier reservation are all
    # per-instance capacity every tenant contends on -> one shared pool
    shared_usd = (fleet_comp["instance_usd"] + fleet_comp["cache_usd"]
                  + fleet_comp["nvme_usd"])

    rows = []
    sum_usd = 0.0
    rem = dict(get_requests=q["get_requests"],
               put_requests=q["put_requests"],
               read_bytes=q["read_bytes"])
    rem_share = 1.0
    for sl in tenants:
        tq = _tenant_quantities(sl)
        for k in rem:
            rem[k] -= tq[k]
        share = (jobs[sl.name] / jobs_total) if jobs_total else 0.0
        rem_share -= share
        comp = book.components(**tq)
        direct = comp["get_usd"] + comp["put_usd"] + comp["egress_usd"]
        total = direct + share * shared_usd
        sum_usd += total
        n = len(sl.records)
        rows.append(dict(
            tenant=sl.name,
            get_usd=_usd(comp["get_usd"]),
            put_usd=_usd(comp["put_usd"]),
            egress_usd=_usd(comp["egress_usd"]),
            shared_usd=_usd(share * shared_usd),
            shared_share=round(share, 6),
            total_usd=_usd(total),
            usd_per_1k_queries=_usd(total / n * 1000.0) if n else 0.0,
        ))

    # The residual is charged as-is (it can only be negative if a
    # tenant's records double-count fleet-level I/O, which would be a
    # bug worth seeing): sum(rows) == fleet total must hold exactly.
    un_comp = book.components(get_requests=rem["get_requests"],
                              put_requests=rem["put_requests"],
                              read_bytes=rem["read_bytes"])
    un_total = (un_comp["get_usd"] + un_comp["put_usd"]
                + un_comp["egress_usd"] + rem_share * shared_usd)
    sum_usd += un_total
    rows.append(dict(
        tenant="(unattributed)",
        get_usd=_usd(un_comp["get_usd"]),
        put_usd=_usd(un_comp["put_usd"]),
        egress_usd=_usd(un_comp["egress_usd"]),
        shared_usd=_usd(rem_share * shared_usd),
        shared_share=round(rem_share, 6),
        total_usd=_usd(un_total),
        usd_per_1k_queries=0.0,
    ))

    return dict(pricebook=book.name,
                fleet_total_usd=_usd(fleet_total),
                sum_usd=_usd(sum_usd),
                rows=rows)


def format_showback(showback: dict) -> str:
    """Render the show-back table for terminal / CI artifact output."""
    cols = ("tenant", "get_usd", "put_usd", "egress_usd", "shared_usd",
            "total_usd", "usd_per_1k_queries")
    lines = ["  ".join(f"{c:>18}" for c in cols)]
    for row in showback["rows"]:
        cells = [f"{row['tenant']:>18}"]
        cells += [f"{row[c]:>18.9f}" for c in cols[1:]]
        lines.append("  ".join(cells))
    lines.append(f"# pricebook={showback['pricebook']} "
                 f"fleet_total_usd={showback['fleet_total_usd']:.9f} "
                 f"sum_usd={showback['sum_usd']:.9f}")
    return "\n".join(lines)
