"""repro.obs — tracing, metrics, live monitoring, and cost.

Observability for the simulated serving stack: simulated-time span
trees (:mod:`~repro.obs.trace`), a fixed-memory metrics registry
(:mod:`~repro.obs.metrics`), Chrome-trace/Perfetto export
(:mod:`~repro.obs.export`), per-query critical-path attribution and
run-to-run trace diffs (:mod:`~repro.obs.critical_path`),
self-describing run manifests (:mod:`~repro.obs.manifest`), live SLO
monitors with burn-rate alerting (:mod:`~repro.obs.monitor`),
dollar-denominated cost metering with per-tenant show-back
(:mod:`~repro.obs.cost`), tail-latency exemplars with deterministic
``explain_tail`` reports (:mod:`~repro.obs.explain`), and online
miss-ratio-curve profiling via SHARDS spatial sampling
(:mod:`~repro.obs.mrc`).

The cardinal rule: observing never perturbs.  A run with a tracer,
monitor or price book attached is bit-exact against the same run
without them — only the opt-in alert->action bus (``--alert-actions``)
may change a schedule, and then on purpose.
"""
from repro.obs.cost import (PRICEBOOKS, PriceBook, fleet_cost,
                            format_showback, resolve_pricebook,
                            tenant_showback)
from repro.obs.critical_path import (AttributionReport, attribute,
                                     extract_paths, render_diff,
                                     trace_diff)
from repro.obs.explain import (ExplainCollector, ExplainConfig,
                               render_explain)
from repro.obs.export import chrome_trace, flame_summary, write_chrome_trace
from repro.obs.manifest import run_manifest
from repro.obs.mrc import MRCConfig, MRCProfiler, mrc_miss_ratio
from repro.obs.metrics import MetricsRegistry
from repro.obs.monitor import (DEFAULT_RULES, ActionBus, Alert, AlertLog,
                               BurnRateRule, FleetMonitor, MonitorConfig,
                               SLOMonitor)
from repro.obs.trace import NULL_TRACER, NullTracer, Span, Tracer

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER", "Span",
    "MetricsRegistry",
    "chrome_trace", "write_chrome_trace", "flame_summary",
    "attribute", "extract_paths", "AttributionReport",
    "trace_diff", "render_diff",
    "run_manifest",
    "MonitorConfig", "FleetMonitor", "SLOMonitor", "BurnRateRule",
    "Alert", "AlertLog", "ActionBus", "DEFAULT_RULES",
    "PriceBook", "PRICEBOOKS", "resolve_pricebook",
    "fleet_cost", "tenant_showback", "format_showback",
    "ExplainConfig", "ExplainCollector", "render_explain",
    "MRCConfig", "MRCProfiler", "mrc_miss_ratio",
]
