"""repro.obs — tracing, metrics, and critical-path attribution.

Observability for the simulated serving stack: simulated-time span
trees (:mod:`~repro.obs.trace`), a fixed-memory metrics registry
(:mod:`~repro.obs.metrics`), Chrome-trace/Perfetto export
(:mod:`~repro.obs.export`), per-query critical-path attribution and
run-to-run trace diffs (:mod:`~repro.obs.critical_path`), and
self-describing run manifests (:mod:`~repro.obs.manifest`).

The cardinal rule: tracing observes and never perturbs.  A run with a
tracer attached is bit-exact against the same run without one.
"""
from repro.obs.critical_path import (AttributionReport, attribute,
                                     extract_paths, render_diff,
                                     trace_diff)
from repro.obs.export import chrome_trace, flame_summary, write_chrome_trace
from repro.obs.manifest import run_manifest
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_TRACER, NullTracer, Span, Tracer

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER", "Span",
    "MetricsRegistry",
    "chrome_trace", "write_chrome_trace", "flame_summary",
    "attribute", "extract_paths", "AttributionReport",
    "trace_diff", "render_diff",
    "run_manifest",
]
