"""Tail-latency explanation: exemplar reservoirs, windowed attribution,
and alert forensics over the span stream.

Attribution (PR 6) answers "where does the *mean* sojourn go"; this
module answers the operator's actual question — **why is the tail
slow** — while staying a pure observer (no kernel events, no kernel
RNG, bit-exact goldens hold with an :class:`ExplainCollector` attached).

Three mechanisms, all bounded-memory:

* **Tail-exemplar reservoirs** — the K worst-sojourn queries per tenant
  (a min-heap keyed ``(sojourn, qid)`` — fully deterministic), plus a
  uniform reservoir (Algorithm R on a private seeded PRNG, never the
  kernel's) as the "normal query" baseline.  Each exemplar keeps its
  critical-path stage vector, dominant stage and dominant shard.
* **Windowed attribution** — per-query stage vectors are folded into
  per-window stage *shares* published as ``attrib.<stage>.share``
  gauges on the tracer's registry, so the snapshot ticker turns
  run-level attribution into a flamegraph-over-time (Perfetto counter
  tracks).
* **explain_tail()** — clusters the worst exemplars by
  ``(dominant stage, dominant shard)`` signature, names the
  compaction/fault/scale events concurrent with each cluster's
  exemplars, and emits a deterministic report whose headline reads
  like a diagnosis: ``p99.9 is storage_fetch on shard 3 during
  compaction:recluster@shard3``.

:meth:`ExplainCollector.forensics` snapshots the same state (plus
counter deltas) into a dict; the router installs it as the
``FleetMonitor.forensics_provider`` so every fired alert carries its
own root-cause bundle.
"""
from __future__ import annotations

import dataclasses
import heapq
import random

from .critical_path import STAGES, path_shares, query_path

__all__ = ["ExplainConfig", "Exemplar", "ExplainCollector",
           "render_explain"]


@dataclasses.dataclass(frozen=True)
class ExplainConfig:
    """Knobs for the tail-explanation collector."""

    k_worst: int = 8            # worst-sojourn exemplars kept per tenant
    uniform_k: int = 16         # baseline uniform reservoir size
    tail_pct: float = 99.9      # label for the report headline
    reservoir_seed: int = 0x5EED  # private PRNG (never the kernel's)

    def __post_init__(self) -> None:
        if self.k_worst < 1 or self.uniform_k < 1:
            raise ValueError("reservoir sizes must be >= 1")
        if not (50.0 <= self.tail_pct < 100.0):
            raise ValueError(f"tail_pct must be in [50, 100), got "
                             f"{self.tail_pct}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Exemplar:
    """One captured query: where its time went and what gated it."""

    qid: int
    tenant: str | None
    t0: float
    t1: float
    sojourn: float
    stages: dict[str, float]
    dominant_stage: str
    shard: int                  # dominant shard (-1: no shard job seen)

    def to_dict(self) -> dict:
        return dict(qid=self.qid, tenant=self.tenant,
                    sojourn_s=round(self.sojourn, 9),
                    t0=round(self.t0, 6), t1=round(self.t1, 6),
                    stage=self.dominant_stage, shard=self.shard,
                    stages_s={k: round(v, 9)
                              for k, v in self.stages.items() if v > 0})


def _dominant_stage(stages: dict[str, float]) -> str:
    """Largest stage, deterministic STAGES-order tie-break; ``other``
    for an all-zero vector (zero-duration query)."""
    best, best_v = "other", 0.0
    for name in STAGES:
        v = stages.get(name, 0.0)
        if v > best_v:
            best, best_v = name, v
    return best


def render_explain(rep: dict) -> str:
    """Human-readable rendering of an :meth:`ExplainCollector.
    explain_tail` report dict (also what ``--explain`` prints to
    stderr)."""
    lines = [f"tail explanation over {rep['n_queries']} queries "
             f"({rep['n_exemplars']} exemplars)",
             f"  {rep['headline']}"]
    for row in rep["clusters"]:
        ev = f"  [{', '.join(row['events'])}]" if row["events"] else ""
        shard = f" shard {row['shard']}" if row["shard"] >= 0 else ""
        lines.append(
            f"  {row['n']:>3}x {row['stage']:<14}{shard:<9} "
            f"mean {row['mean_sojourn_s'] * 1e3:8.3f} ms  "
            f"max {row['max_sojourn_s'] * 1e3:8.3f} ms{ev}")
    base = rep["baseline_shares"]
    tail = rep["tail_shares"]
    movers = sorted(STAGES, key=lambda s: -(tail[s] - base[s]))[:3]
    diffs = ", ".join(f"{s} {tail[s] - base[s]:+.0%}" for s in movers
                      if abs(tail[s] - base[s]) >= 0.005)
    if diffs:
        lines.append(f"  tail vs baseline shares: {diffs}")
    return "\n".join(lines)


class ExplainCollector:
    """Per-run tail-exemplar + windowed-attribution collector.

    The router calls :meth:`on_query` from ``_finish_query`` (the
    query's full span tree is recorded by then) and :meth:`publish`
    from its metrics-snapshot ticker.  Everything here reads tracer
    state; nothing is fed back into the simulation.
    """

    def __init__(self, tracer, cfg: ExplainConfig | None = None):
        self.cfg = cfg or ExplainConfig()
        self._tr = tracer
        # incremental children index: each span indexed exactly once
        self._by_parent: dict[int | None, list] = {}
        self._cursor = 0
        # tenant name (or "") -> min-heap of (sojourn, qid, Exemplar)
        self._worst: dict[str, list] = {}
        self._uniform: list[Exemplar] = []
        self._uniform_seen = 0
        self._rng = random.Random(self.cfg.reservoir_seed)
        # windowed attribution accumulators (reset on publish)
        self._win_stages = dict.fromkeys(STAGES, 0.0)
        self._win_sojourn = 0.0
        self._win_n = 0
        # cumulative (for baseline-free summaries)
        self._cum_stages = dict.fromkeys(STAGES, 0.0)
        self._cum_sojourn = 0.0
        self.n_queries = 0
        self._last_counters: dict[str, float] = {}

    # ------------------------------------------------------------ intake --
    def _index_new_spans(self) -> None:
        spans = self._tr.spans
        for sid in range(self._cursor, len(spans)):
            sp = spans[sid]
            self._by_parent.setdefault(sp.parent, []).append(sp)
        self._cursor = len(spans)

    def _dominant_shard(self, root) -> int:
        """Shard of the longest round-winning job (-1 without jobs)."""
        best_shard, best_dur = -1, -1.0
        for ch in self._by_parent.get(root.sid, []):
            if ch.name != "round":
                continue
            jobs = [j for j in self._by_parent.get(ch.sid, [])
                    if j.name == "shard_job" and j.t1 is not None]
            if not jobs:
                continue
            winner = max(jobs, key=lambda j: j.t1)
            dur = winner.t1 - winner.t0
            if dur > best_dur:
                best_dur = dur
                best_shard = (winner.attrs or {}).get("shard", -1)
        return best_shard

    def on_query(self, root) -> None:
        """Fold one completed query root span into the collector."""
        self._index_new_spans()
        qp = query_path(root, self._by_parent)
        if qp is None:
            return
        self.n_queries += 1
        for k, v in qp.stages.items():
            self._win_stages[k] += v
            self._cum_stages[k] += v
        self._win_sojourn += qp.sojourn
        self._cum_sojourn += qp.sojourn
        self._win_n += 1
        ex = Exemplar(
            qid=qp.qid, tenant=qp.tenant, t0=root.t0, t1=root.t1,
            sojourn=qp.sojourn, stages=qp.stages,
            dominant_stage=_dominant_stage(qp.stages),
            shard=self._dominant_shard(root))
        heap = self._worst.setdefault(qp.tenant or "", [])
        item = (qp.sojourn, qp.qid, ex)
        if len(heap) < self.cfg.k_worst:
            heapq.heappush(heap, item)
        elif item[:2] > heap[0][:2]:
            heapq.heapreplace(heap, item)
        # uniform baseline: Algorithm R on the private PRNG
        self._uniform_seen += 1
        if len(self._uniform) < self.cfg.uniform_k:
            self._uniform.append(ex)
        else:
            j = self._rng.randrange(self._uniform_seen)
            if j < self.cfg.uniform_k:
                self._uniform[j] = ex

    # ------------------------------------------------- windowed attribution --
    def publish(self, registry) -> None:
        """Publish the window-since-last-publish stage shares as gauges
        (``attrib.<stage>.share`` + ``attrib.window.queries``) and reset
        the window.  Driven by the router's snapshot ticker, so the
        shares land in the metrics time series and render as Perfetto
        counter tracks."""
        tot = self._win_sojourn
        for name in STAGES:
            share = self._win_stages[name] / tot if tot > 0 else 0.0
            registry.gauge(f"attrib.{name}.share").set(share)
        registry.gauge("attrib.window.queries").set(self._win_n)
        self._win_stages = dict.fromkeys(STAGES, 0.0)
        self._win_sojourn = 0.0
        self._win_n = 0

    # --------------------------------------------------------- reporting --
    def _worst_exemplars(self) -> list[Exemplar]:
        out = [it[2] for heap in self._worst.values() for it in heap]
        out.sort(key=lambda e: (-e.sojourn, e.tenant or "", e.qid))
        return out

    def _events(self) -> tuple[list, list]:
        """(compaction spans, instants) recorded by the tracer."""
        comps = [sp for sp in self._tr.spans if sp.name == "compaction"]
        return comps, list(self._tr.instants)

    @staticmethod
    def _concurrent_events(ex: Exemplar, comps: list,
                           instants: list) -> list[str]:
        """Deterministic labels of events overlapping ``[t0, t1]``."""
        labels = set()
        for sp in comps:
            hi = sp.t1 if sp.t1 is not None else float("inf")
            if sp.t0 <= ex.t1 and hi >= ex.t0:
                a = sp.attrs or {}
                labels.add(f"compaction:{a.get('kind', '?')}"
                           f"@shard{a.get('shard', '?')}")
        for name, t, attrs in instants:
            if ex.t0 <= t <= ex.t1:
                a = attrs or {}
                suffix = f"@shard{a['shard']}" if "shard" in a else ""
                labels.add(f"{name}{suffix}")
        return sorted(labels)

    @staticmethod
    def _mean_shares(exemplars: list[Exemplar]) -> dict[str, float]:
        if not exemplars:
            return dict.fromkeys(STAGES, 0.0)
        acc = dict.fromkeys(STAGES, 0.0)
        for ex in exemplars:
            shares = path_shares(ex)
            for k in STAGES:
                acc[k] += shares[k]
        return {k: round(v / len(exemplars), 6) for k, v in acc.items()}

    def explain_tail(self) -> dict:
        """The deterministic tail-explanation report.

        Clusters the worst exemplars by ``(dominant stage, shard)``,
        names concurrent compaction/fault/scale/alert events, and
        contrasts the tail's stage shares with the uniform baseline.
        """
        worst = self._worst_exemplars()
        comps, instants = self._events()
        clusters: dict[tuple[str, int], list[Exemplar]] = {}
        for ex in worst:
            clusters.setdefault((ex.dominant_stage, ex.shard),
                                []).append(ex)
        rows = []
        for (stage, shard), members in clusters.items():
            events = sorted({lab for ex in members for lab in
                             self._concurrent_events(ex, comps, instants)})
            shares = [path_shares(ex).get(stage, 0.0) for ex in members]
            rows.append(dict(
                stage=stage, shard=shard, n=len(members),
                frac=round(len(members) / len(worst), 4) if worst else 0.0,
                mean_sojourn_s=round(
                    sum(ex.sojourn for ex in members) / len(members), 9),
                max_sojourn_s=round(
                    max(ex.sojourn for ex in members), 9),
                mean_stage_share=round(sum(shares) / len(shares), 4),
                qids=sorted(ex.qid for ex in members),
                events=events))
        rows.sort(key=lambda r: (-r["n"], -r["max_sojourn_s"],
                                 r["stage"], r["shard"]))
        headline = f"p{self.cfg.tail_pct:g}: no completed queries"
        if rows:
            top = rows[0]
            headline = f"p{self.cfg.tail_pct:g} is {top['stage']}"
            if top["shard"] >= 0:
                headline += f" on shard {top['shard']}"
            if top["events"]:
                headline += f" during {', '.join(top['events'])}"
            headline += (f" ({top['n']}/{len(worst)} worst exemplars, "
                         f"worst {top['max_sojourn_s'] * 1e3:.3f} ms)")
        tenants = {}
        for name in sorted(self._worst):
            heap = self._worst[name]
            t_worst = max(heap, key=lambda it: it[:2])[2] if heap else None
            if t_worst is not None:
                tenants[name or "fleet"] = dict(
                    n_exemplars=len(heap),
                    worst_sojourn_s=round(t_worst.sojourn, 9),
                    worst_qid=t_worst.qid,
                    stage=t_worst.dominant_stage, shard=t_worst.shard)
        return dict(
            tail_pct=self.cfg.tail_pct,
            n_queries=self.n_queries,
            n_exemplars=len(worst),
            headline=headline,
            clusters=rows,
            tail_shares=self._mean_shares(worst),
            baseline_shares=self._mean_shares(self._uniform),
            baseline_n=len(self._uniform),
            exemplars=[ex.to_dict() for ex in worst],
            tenants=tenants,
        )

    def render(self, report: dict | None = None) -> str:
        """Human-readable tail explanation (stderr companion of the
        JSON block)."""
        return render_explain(report if report is not None
                              else self.explain_tail())

    # --------------------------------------------------------- forensics --
    def forensics(self, now: float, registry=None) -> dict:
        """Root-cause bundle for a firing alert: the current worst
        exemplars, counter deltas since the previous bundle, and the
        in-flight window's stage shares.  Pure read of observer state."""
        worst = self._worst_exemplars()[:3]
        tot = self._win_sojourn
        shares = {k: round(self._win_stages[k] / tot, 4)
                  for k in STAGES if tot > 0 and self._win_stages[k] > 0}
        deltas: dict[str, float] = {}
        if registry is not None:
            counters = registry.to_dict()["counters"]
            for name in sorted(counters):
                d = counters[name] - self._last_counters.get(name, 0.0)
                if d:
                    deltas[name] = round(d, 6)
            self._last_counters = dict(counters)
        return dict(
            at=round(now, 6),
            window=dict(queries=self._win_n, shares=shares),
            exemplars=[dict(qid=ex.qid, tenant=ex.tenant,
                            sojourn_s=round(ex.sojourn, 9),
                            stage=ex.dominant_stage, shard=ex.shard)
                       for ex in worst],
            counter_deltas=deltas,
        )
