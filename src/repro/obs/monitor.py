"""Live SLO monitors: rolling windows, multi-window burn rates, an
alert log in simulated time, and an alert->action hook bus.

Tracing (PR 6) is post-hoc; this module watches the run *while it is
in flight*.  Each :class:`SLOMonitor` keeps a rolling window of
good/bad observations against one objective (latency-SLO attainment,
recall floor, freshness-lag bound).  The **burn rate** over a window is
the classic SRE quantity::

    burn = bad_fraction(window) / error_budget,   budget = 1 - objective

i.e. burn 1.0 consumes the budget exactly at the sustainable rate; an
alert rule fires when the burn exceeds its threshold over *both* a long
and a short window (the short window makes alerts clear quickly once
the condition ends; the long window rejects blips).  Fired/cleared
alerts are stamped in simulated time in an :class:`AlertLog`.

Actions are **off by default**: the monitor only reads fleet state, and
its ticker — like the tracer's snapshot ticker — only consumes kernel
sequence numbers, shifting all later seqs uniformly, so a monitored run
stays bit-exact with an unmonitored one (enforced against the golden in
``tests/test_monitor_cost.py``).  With ``actions=True`` (CLI
``--alert-actions``) subscribers on the :class:`ActionBus` may
legitimately perturb the run: the autoscaler subscribes to scale out on
a sustained latency burn, and the admission layer subscribes to
deprioritize an over-budget tenant (see ``FleetRouter._execute``).
"""
from __future__ import annotations

import dataclasses
from collections import deque

from .trace import NULL_TRACER


@dataclasses.dataclass(frozen=True)
class BurnRateRule:
    """One multi-window burn-rate alert rule (SRE-style)."""

    name: str
    long_s: float
    short_s: float
    threshold: float
    severity: str = "page"

    def __post_init__(self) -> None:
        if not (self.long_s > self.short_s > 0):
            raise ValueError(f"rule {self.name!r}: need "
                             f"long_s > short_s > 0, got "
                             f"{self.long_s}/{self.short_s}")
        if self.threshold <= 0:
            raise ValueError(f"rule {self.name!r}: threshold must be "
                             f"> 0, got {self.threshold}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


#: Windows are in *simulated* seconds; fleet runs last O(seconds), so
#: these are the sim-scale analogue of Google's 1h/5m + 6h/30m pairs
#: (same ~12x long:short ratio between tiers, page fires on a fast
#: hard burn, ticket on a slow sustained one).
DEFAULT_RULES: tuple[BurnRateRule, ...] = (
    BurnRateRule("fast", long_s=0.25, short_s=0.05, threshold=8.0,
                 severity="page"),
    BurnRateRule("slow", long_s=1.0, short_s=0.25, threshold=2.0,
                 severity="ticket"),
)


class SLOMonitor:
    """Rolling good/bad observations against one objective.

    ``observe`` is O(1); window eviction is amortized O(1) because
    events leave the deque exactly once.  ``burn_rate`` scans only the
    events inside the widest rule window (bounded memory regardless of
    run length).
    """

    __slots__ = ("name", "kind", "tenant", "objective", "budget",
                 "rules", "min_samples", "_events", "_horizon",
                 "total", "bad_total", "last_value", "worst_value")

    def __init__(self, name: str, *, objective: float = 0.99,
                 rules: tuple[BurnRateRule, ...] = DEFAULT_RULES,
                 min_samples: int = 8, kind: str = "latency",
                 tenant: str | None = None) -> None:
        if not (0.0 < objective < 1.0):
            raise ValueError(f"objective must be in (0, 1), got "
                             f"{objective}")
        self.name = name
        self.kind = kind
        self.tenant = tenant
        self.objective = objective
        self.budget = 1.0 - objective
        self.rules = tuple(rules)
        self.min_samples = min_samples
        self._events: deque = deque()  # (t, bad: bool, value: float)
        self._horizon = max(r.long_s for r in self.rules)
        self.total = 0
        self.bad_total = 0
        self.last_value = 0.0
        self.worst_value = 0.0

    def observe(self, t: float, *, bad: bool, value: float = 0.0) -> None:
        self._events.append((t, bad, value))
        self.total += 1
        self.bad_total += bad
        self.last_value = value
        if value > self.worst_value:
            self.worst_value = value
        self._evict(t)

    def _evict(self, now: float) -> None:
        cutoff = now - self._horizon
        ev = self._events
        while ev and ev[0][0] < cutoff:
            ev.popleft()

    def window_counts(self, now: float, window: float) -> tuple[int, int]:
        """(events, bad events) inside ``[now - window, now]``."""
        cutoff = now - window
        n = bad = 0
        for t, b, _ in reversed(self._events):
            if t < cutoff:
                break
            n += 1
            bad += b
        return n, bad

    def burn_rate(self, now: float, window: float) -> float:
        """Bad fraction over ``window`` divided by the error budget;
        0.0 until ``min_samples`` events have landed in the window (a
        single early failure is not a trend)."""
        n, bad = self.window_counts(now, window)
        if n < self.min_samples:
            return 0.0
        return (bad / n) / self.budget

    def window_quantile(self, now: float, window: float,
                        q: float) -> float:
        """Quantile of observed values in the window (e.g. rolling
        p99 latency); 0.0 on an empty window."""
        cutoff = now - window
        vals = sorted(v for t, _, v in self._events if t >= cutoff)
        if not vals:
            return 0.0
        idx = min(int(q * len(vals)), len(vals) - 1)
        return vals[idx]

    def to_dict(self) -> dict:
        d = dict(name=self.name, kind=self.kind,
                 objective=self.objective, total=self.total,
                 bad_total=self.bad_total,
                 bad_frac=round(self.bad_total / self.total, 6)
                 if self.total else 0.0,
                 worst_value=round(self.worst_value, 6))
        if self.tenant is not None:
            d["tenant"] = self.tenant
        return d


@dataclasses.dataclass
class Alert:
    """One fired (and possibly cleared) alert, in simulated time."""

    monitor: str
    rule: str
    severity: str
    fired_t: float
    tenant: str | None = None
    cleared_t: float | None = None
    peak_burn: float = 0.0
    #: root-cause bundle snapshotted at fire time (exemplars, counter
    #: deltas, stage shares) when an explain collector is attached;
    #: omitted from the dict when absent so existing alert payloads
    #: are unchanged.
    forensics: dict | None = None

    @property
    def active(self) -> bool:
        return self.cleared_t is None

    def to_dict(self) -> dict:
        d = dict(monitor=self.monitor, rule=self.rule,
                 severity=self.severity,
                 fired_t=round(self.fired_t, 6),
                 cleared_t=(round(self.cleared_t, 6)
                            if self.cleared_t is not None else None),
                 peak_burn=round(self.peak_burn, 4))
        if self.tenant is not None:
            d["tenant"] = self.tenant
        if self.forensics is not None:
            d["forensics"] = self.forensics
        return d


class AlertLog:
    """Every fired/cleared alert of a run, stamped in simulated time.

    At most one active alert per (monitor, rule): while the condition
    persists the existing alert's ``peak_burn`` is updated instead of
    stacking duplicates.
    """

    def __init__(self) -> None:
        self.alerts: list[Alert] = []
        self._active: dict[tuple[str, str], Alert] = {}

    def fire(self, now: float, monitor: SLOMonitor, rule: BurnRateRule,
             burn: float) -> Alert | None:
        """Returns the new :class:`Alert` on a fresh fire, or ``None``
        if this (monitor, rule) is already firing (peak updated)."""
        key = (monitor.name, rule.name)
        cur = self._active.get(key)
        if cur is not None:
            if burn > cur.peak_burn:
                cur.peak_burn = burn
            return None
        alert = Alert(monitor=monitor.name, rule=rule.name,
                      severity=rule.severity, fired_t=now,
                      tenant=monitor.tenant, peak_burn=burn)
        self._active[key] = alert
        self.alerts.append(alert)
        return alert

    def clear(self, now: float, monitor: SLOMonitor,
              rule: BurnRateRule) -> Alert | None:
        """Returns the cleared :class:`Alert`, or ``None`` if nothing
        was firing."""
        alert = self._active.pop((monitor.name, rule.name), None)
        if alert is not None:
            alert.cleared_t = now
        return alert

    @property
    def active(self) -> list[Alert]:
        return list(self._active.values())

    def to_dicts(self) -> list[dict]:
        return [a.to_dict() for a in self.alerts]


class ActionBus:
    """Alert -> action hooks.  Disabled unless ``enabled``: with the
    bus off, ``publish`` returns before touching subscribers, so a
    monitored run stays a pure observer and goldens stay bit-exact."""

    def __init__(self, enabled: bool = False) -> None:
        self.enabled = enabled
        self._subs: list = []

    def subscribe(self, fn) -> None:
        """``fn(event, alert, now)`` with event ``"fired"``/``"cleared"``."""
        self._subs.append(fn)

    def publish(self, event: str, alert: Alert, now: float) -> None:
        if not self.enabled:
            return
        for fn in self._subs:
            fn(event, alert, now)


@dataclasses.dataclass(frozen=True)
class MonitorConfig:
    """Configuration for a fleet's live monitor set.

    ``interval_s`` is the evaluation tick (rules are checked on the
    tick, observations land continuously).  ``gt_ids`` optionally
    enables the live recall monitor: an ``(nq, k)`` int array of
    ground-truth neighbor ids — or, multi-tenant, a mapping of tenant
    name to such an array — compared per completed query.  ``gt_ids``
    is carried data, not config: it is excluded from ``to_dict``.
    """

    interval_s: float = 0.05
    objective: float = 0.99
    rules: tuple[BurnRateRule, ...] = DEFAULT_RULES
    min_samples: int = 8
    freshness_slo_s: float | None = None
    recall_target: float | None = None
    gt_ids: object = dataclasses.field(default=None, repr=False,
                                       compare=False)
    actions: bool = False
    max_instances: int = 4

    def __post_init__(self) -> None:
        if self.interval_s <= 0:
            raise ValueError("interval_s must be > 0")
        if not self.rules:
            raise ValueError("need at least one BurnRateRule")

    def to_dict(self) -> dict:
        return dict(interval_s=self.interval_s,
                    objective=self.objective,
                    rules=[r.to_dict() for r in self.rules],
                    min_samples=self.min_samples,
                    freshness_slo_s=self.freshness_slo_s,
                    recall_target=self.recall_target,
                    actions=self.actions,
                    max_instances=self.max_instances)


class FleetMonitor:
    """The live monitor set for one fleet run (owned by the router).

    The router feeds observations from ``_finish_query`` and the ingest
    apply hook, and calls :meth:`tick` from a kernel ticker.  All state
    here is derived from fleet events; nothing schedules kernel work.
    """

    def __init__(self, cfg: MonitorConfig, tracer=NULL_TRACER) -> None:
        self.cfg = cfg
        self.tracer = tracer
        self.monitors: dict[str, SLOMonitor] = {}
        self.log = AlertLog()
        self.bus = ActionBus(enabled=cfg.actions)
        #: optional ``fn(now) -> dict`` snapshotting forensics (tail
        #: exemplars, counter deltas, stage shares) onto each freshly
        #: fired alert — installed by the router when ``--explain`` is
        #: on; a pure read of observer state, so bit-exactness holds.
        self.forensics_provider = None

    def monitor(self, name: str, *, kind: str = "latency",
                tenant: str | None = None,
                objective: float | None = None) -> SLOMonitor:
        m = self.monitors.get(name)
        if m is None:
            m = SLOMonitor(
                name,
                objective=(self.cfg.objective if objective is None
                           else objective),
                rules=self.cfg.rules, min_samples=self.cfg.min_samples,
                kind=kind, tenant=tenant)
            self.monitors[name] = m
        return m

    # -- observation feeds (called by the router) ---------------------

    def observe_latency(self, t: float, name: str, sojourn_s: float,
                        slo_s: float, tenant: str | None = None) -> None:
        """The latency/goodput monitor: a query is *bad* when its
        sojourn misses the SLO, so ``bad_frac == 1 - goodput`` and the
        burn rate is goodput burn; the rolling window's p99 is exported
        as the ``slo.<name>.p99_s`` gauge when traced."""
        m = self.monitor(name, kind="latency", tenant=tenant)
        m.observe(t, bad=sojourn_s > slo_s, value=sojourn_s)

    def observe_recall(self, t: float, name: str, recall: float,
                       target: float, tenant: str | None = None) -> None:
        m = self.monitor(name, kind="recall", tenant=tenant)
        m.observe(t, bad=recall < target, value=recall)

    def observe_freshness(self, t: float, name: str, lag_s: float,
                          bound_s: float,
                          tenant: str | None = None) -> None:
        m = self.monitor(name, kind="freshness", tenant=tenant)
        m.observe(t, bad=lag_s > bound_s, value=lag_s)

    # -- rule evaluation ----------------------------------------------

    def tick(self, now: float) -> None:
        """Evaluate every rule on every monitor; fire/clear alerts and
        publish them on the bus.  Iteration order is insertion order,
        which is deterministic under the sim's event order."""
        tr = self.tracer
        for m in self.monitors.values():
            for rule in m.rules:
                burn_long = m.burn_rate(now, rule.long_s)
                burn_short = m.burn_rate(now, rule.short_s)
                firing = (burn_long > rule.threshold
                          and burn_short > rule.threshold)
                if firing:
                    alert = self.log.fire(now, m, rule,
                                          max(burn_long, burn_short))
                    if alert is not None:
                        if self.forensics_provider is not None:
                            alert.forensics = self.forensics_provider(now)
                        if tr.enabled:
                            tr.instant("alert_fired", now,
                                       monitor=m.name, rule=rule.name,
                                       severity=rule.severity,
                                       burn=round(burn_long, 3))
                        self.bus.publish("fired", alert, now)
                else:
                    alert = self.log.clear(now, m, rule)
                    if alert is not None:
                        if tr.enabled:
                            tr.instant("alert_cleared", now,
                                       monitor=m.name, rule=rule.name,
                                       severity=rule.severity)
                        self.bus.publish("cleared", alert, now)
            if tr.enabled:
                reg = tr.metrics
                rule0 = m.rules[0]
                reg.gauge(f"slo.{m.name}.burn").set(
                    m.burn_rate(now, rule0.long_s))
                if m.kind == "latency":
                    reg.gauge(f"slo.{m.name}.p99_s").set(
                        m.window_quantile(now, rule0.long_s, 0.99))

    # -- reporting ----------------------------------------------------

    def summary(self) -> dict:
        """The ``alerts`` block attached to the fleet report."""
        return dict(
            config=self.cfg.to_dict(),
            monitors=[m.to_dict() for m in self.monitors.values()],
            fired=self.log.to_dicts(),
        )
