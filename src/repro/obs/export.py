"""Chrome-trace / Perfetto JSON export and deterministic flame summary.

The export maps a run onto trace-viewer concepts:

* the **router** process (pid 1) has one thread lane per tenant; each
  query's span tree renders there as nested *async* slices;
* each **shard** gets its own process (pid 100 + shard id) with one
  thread lane per instance, carrying the ``shard_job`` spans and their
  queue/fetch/compute legs;
* hedges draw **flow arrows** from the round that launched them to the
  wasted attempt; sheds, faults, recoveries and autoscale decisions are
  **instant** events; registry snapshots become **counter** tracks
  (``cost.*`` dollar and ``slo.*`` burn-rate gauges included, when a
  price book / monitor is attached);
* alert lifecycle events (``alert_fired`` / ``alert_cleared`` and the
  ``alert_action_*`` actuations) get their own ``alert`` category so
  they can be isolated in the viewer's filter box.

All slices are emitted as async begin/end pairs (``ph: "b"/"e"``) keyed
by the local tree root, because many queries overlap on one lane and
synchronous ``X`` slices would force the viewer to mis-nest them.

Load the output at https://ui.perfetto.dev (or chrome://tracing).
Timestamps are simulated seconds scaled to microseconds.

The export is **byte-deterministic**: events follow span/instant/flow
recording order (itself deterministic under the simulator's
``(time, seq)`` discipline), counter rows and lane metadata are
explicitly sorted, and the JSON is written with pinned separators —
two identical runs produce identical trace files, so trace artifacts
can be diffed byte-for-byte across runs and CI uploads.
"""
from __future__ import annotations

import json

__all__ = ["chrome_trace", "write_chrome_trace", "flame_summary"]

_US = 1e6            # simulated seconds -> trace microseconds

_ROUTER_PID = 1
_SHARD_PID0 = 100


def _jsonable(attrs: dict) -> dict:
    """Coerce numpy scalars (query ids, byte counts) to plain JSON types."""
    out = {}
    for k, v in attrs.items():
        if isinstance(v, (bool, int, float, str)) or v is None:
            out[k] = v
        elif hasattr(v, "item"):            # numpy scalar
            out[k] = v.item()
        else:
            out[k] = str(v)
    return out


def _lane(span, attrs, roots) -> tuple[int, int]:
    """(pid, tid) for a span: shard process for shard work, else the
    router process with one lane per tenant (tid from root attrs)."""
    if span.name in ("shard_job", "compaction", "batch_compute"):
        shard = attrs.get("shard", 0)
        return _SHARD_PID0 + int(shard), int(attrs.get("instance", 0))
    root_attrs = roots.get(span.sid, {})
    return _ROUTER_PID, int(root_attrs.get("tid", 0))


def _local_roots(tracer) -> dict[int, dict]:
    """sid -> attrs of the span's local tree root (its topmost parent)."""
    out: dict[int, dict] = {}
    for sp in tracer.spans:             # parents precede children
        if sp.parent is None:
            out[sp.sid] = sp.attrs or {}
        else:
            out[sp.sid] = out[sp.parent]
    return out


def chrome_trace(tracer) -> dict:
    """Build the Chrome-trace JSON object for one traced run."""
    roots = _local_roots(tracer)
    events: list[dict] = []
    lanes: dict[tuple[int, int], None] = {}

    for sp in tracer.spans:
        if sp.t1 is None:
            continue
        attrs = dict(sp.attrs or {})
        pid, tid = _lane(sp, attrs, roots)
        lanes.setdefault((pid, tid))
        # async id = the local tree root, so one query's slices nest
        # together while concurrent queries on the same lane stay apart
        aid = sp.sid
        p = sp.parent
        while p is not None:
            aid = p
            p = tracer.spans[p].parent
        common = dict(cat="sim", name=sp.name, pid=pid, tid=tid,
                      id=aid)
        events.append(dict(common, ph="b", ts=sp.t0 * _US,
                           args=_jsonable(attrs)))
        events.append(dict(common, ph="e", ts=sp.t1 * _US))

    for name, t, attrs in tracer.instants:
        cat = "alert" if name.startswith("alert_") else "sim"
        events.append(dict(ph="i", cat=cat, name=name, ts=t * _US,
                           pid=_ROUTER_PID, tid=0, s="g",
                           args=_jsonable(attrs or {})))

    for i, (src, dst) in enumerate(tracer.flows):
        a, b = tracer.spans[src], tracer.spans[dst]
        pa, ta = _lane(a, dict(a.attrs or {}), roots)
        pb, tb = _lane(b, dict(b.attrs or {}), roots)
        events.append(dict(ph="s", cat="hedge", name="hedge", id=i,
                           ts=a.t0 * _US, pid=pa, tid=ta))
        events.append(dict(ph="f", cat="hedge", name="hedge", id=i,
                           ts=b.t0 * _US, pid=pb, tid=tb, bp="e"))

    if tracer.metrics is not None:
        for t, row in tracer.metrics.series:
            for name, value in sorted(row.items()):
                events.append(dict(ph="C", cat="metrics", name=name,
                                   ts=t * _US, pid=_ROUTER_PID, tid=0,
                                   args={"value": value}))

    meta: list[dict] = []
    for pid in sorted({p for p, _ in lanes} | {_ROUTER_PID}):
        pname = "router" if pid == _ROUTER_PID \
            else f"shard {pid - _SHARD_PID0}"
        meta.append(dict(ph="M", name="process_name", pid=pid, tid=0,
                         args={"name": pname}))
        for p, t in sorted(lanes):
            if p != pid:
                continue
            tname = f"tenant {t}" if pid == _ROUTER_PID \
                else f"instance {t}"
            meta.append(dict(ph="M", name="thread_name", pid=pid,
                             tid=t, args={"name": tname}))

    return dict(traceEvents=meta + events, displayTimeUnit="ms")


def write_chrome_trace(path, tracer) -> None:
    # pinned separators + insertion-ordered dicts => byte-identical
    # files for identical runs (asserted in tests/test_obs.py)
    with open(path, "w") as f:
        json.dump(chrome_trace(tracer), f, separators=(",", ":"))


def flame_summary(tracer, top: int = 20) -> str:
    """Deterministic text flame summary: per span name, the count,
    total (inclusive) time and self (exclusive-of-children) time."""
    total: dict[str, float] = {}
    count: dict[str, int] = {}
    child_time: dict[int, float] = {}
    for sp in tracer.spans:
        if sp.t1 is None:
            continue
        d = sp.t1 - sp.t0
        total[sp.name] = total.get(sp.name, 0.0) + d
        count[sp.name] = count.get(sp.name, 0) + 1
        if sp.parent is not None:
            child_time[sp.parent] = child_time.get(sp.parent, 0.0) + d
    self_t: dict[str, float] = {}
    for sp in tracer.spans:
        if sp.t1 is None:
            continue
        d = (sp.t1 - sp.t0) - child_time.get(sp.sid, 0.0)
        self_t[sp.name] = self_t.get(sp.name, 0.0) + max(0.0, d)
    rows = sorted(total, key=lambda n: (-total[n], n))[:top]
    lines = [f"{'span':<16}{'count':>8}{'total':>12}{'self':>12}"]
    for name in rows:
        lines.append(f"{name:<16}{count[name]:>8}"
                     f"{total[name] * 1e3:>10.3f}ms"
                     f"{self_t[name] * 1e3:>10.3f}ms")
    return "\n".join(lines)
