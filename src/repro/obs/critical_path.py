"""Critical-path extraction and aggregate attribution over span trees.

For every ``query`` root span the extractor walks the tree and charges
each instant of the query's sojourn to exactly one *stage*:

``admission``     waiting for an admission-window slot
``route``         router pricing/partition lookup (per round)
``dispatch``      gap between a round opening and its winning shard job
                  being submitted (retry backoff after sheds)
``queue``         winning job waiting in the shard's run queue
``batching``      waiting in a kernel-backend batch window (coalescing;
                  zero on the analytic backend)
``cache_fetch``   fetch legs served entirely from the shard DRAM cache
``nvme_fetch``    fetch legs served entirely from the local NVMe tier
``storage_fetch`` fetch legs that went to remote storage (a mixed
                  NVMe+remote round is bounded by the remote fetch and
                  charges here; its attrs carry the NVMe split)
``compute``       scan/ADC/distance work between fetch legs
``merge``         global top-k merge after the final gather
``other``         residue (float error, uninstrumented gaps)

The *winning* job of a round is the one whose completion closed the
round (largest end time); everything the query actually waited for lies
on that chain, so summing stages over it reproduces the sojourn exactly
(to float error) — the acceptance criterion checks <= 1% drift against
the measured mean sojourn.

:func:`attribute` aggregates per-query paths into an
:class:`AttributionReport` (overall + p99 tail); :func:`trace_diff`
compares two reports so a failed perf gate can say *where* the
regression lives.
"""
from __future__ import annotations

from dataclasses import dataclass, field

__all__ = ["STAGES", "QueryPath", "AttributionReport", "extract_paths",
           "query_path", "path_shares", "attribute", "trace_diff",
           "render_diff"]

STAGES = ("admission", "route", "dispatch", "queue", "batching",
          "cache_fetch", "nvme_fetch", "storage_fetch", "compute",
          "merge", "other")

_LEG_NAMES = frozenset(("queue", "batching", "cache_fetch", "nvme_fetch",
                        "storage_fetch", "compute"))


@dataclass
class QueryPath:
    """One query's critical path, decomposed into stage times."""

    qid: int
    tenant: str | None
    sojourn: float
    stages: dict[str, float]

    @property
    def accounted(self) -> float:
        return sum(self.stages.values())


def _dur(span, clamp_hi: float) -> float:
    """A span's duration, treating an unclosed span (query aborted
    mid-round by a fault, or a leg cut off at trace end) as running to
    ``clamp_hi`` — never None arithmetic, never negative."""
    t1 = span.t1 if span.t1 is not None else clamp_hi
    return max(0.0, t1 - span.t0)


def _leg_stages(children: list, lo: float, hi: float) -> dict[str, float]:
    """Charge [lo, hi] to queue/fetch/compute legs among ``children``."""
    out: dict[str, float] = {}
    covered = 0.0
    for ch in children:
        if ch.name in _LEG_NAMES:
            d = _dur(ch, hi)
            out[ch.name] = out.get(ch.name, 0.0) + d
            covered += d
    residue = (hi - lo) - covered
    if residue > 1e-12:
        out["other"] = out.get("other", 0.0) + residue
    return out


def query_path(root, kids_of) -> QueryPath | None:
    """One query root's critical path.  ``kids_of`` maps span sid ->
    child span list (any index shaped like ``Tracer.children_index()``).

    Degenerate trees are hardened, never fatal: unclosed children clamp
    to the root's end, jobless rounds charge to ``other``, and a
    zero-duration root yields an all-zero (finite) stage vector.
    Returns None for a root that never closed.
    """
    if root.t1 is None:
        return None
    root_hi = root.t1
    stages = dict.fromkeys(STAGES, 0.0)
    kids = kids_of.get(root.sid, [])
    # Single-engine traces put the job legs directly under the root.
    if not any(k.name == "round" for k in kids):
        for name, d in _leg_stages(kids, root.t0, root_hi).items():
            stages[name] += d
        for ch in kids:
            if ch.name in ("admission", "route", "merge"):
                d = _dur(ch, root_hi)
                stages[ch.name] += d
                stages["other"] = max(0.0, stages["other"] - d)
    else:
        for ch in kids:
            if ch.name in ("admission", "route", "merge"):
                stages[ch.name] += _dur(ch, root_hi)
            elif ch.name == "round":
                ch_hi = ch.t1 if ch.t1 is not None else root_hi
                jobs = [j for j in kids_of.get(ch.sid, [])
                        if j.name == "shard_job" and j.t1 is not None]
                if not jobs:
                    stages["other"] += max(0.0, ch_hi - ch.t0)
                    continue
                # the job whose completion closed the round
                winner = max(jobs, key=lambda j: j.t1)
                stages["dispatch"] += max(0.0, winner.t0 - ch.t0)
                legs = _leg_stages(kids_of.get(winner.sid, []),
                                   winner.t0, winner.t1)
                for name, d in legs.items():
                    stages[name] += d
                # gather fired at round close; job may end earlier
                # than the round boundary only by float error
                stages["other"] += max(0.0, ch_hi - winner.t1)
    attrs = root.attrs or {}
    return QueryPath(
        qid=attrs.get("qid", -1), tenant=attrs.get("tenant"),
        sojourn=max(0.0, root.t1 - root.t0), stages=stages)


def path_shares(path: QueryPath) -> dict[str, float]:
    """A path's stage vector normalised to fractions of its sojourn
    (all-zero for a zero-duration query — finite, never NaN)."""
    if path.sojourn <= 0.0:
        return dict.fromkeys(STAGES, 0.0)
    return {k: path.stages.get(k, 0.0) / path.sojourn for k in STAGES}


def extract_paths(tracer) -> list[QueryPath]:
    """Per-query critical paths from a tracer's span trees."""
    idx = tracer.children_index()
    paths: list[QueryPath] = []
    for root in idx.get(None, []):
        if root.name != "query":
            continue
        qp = query_path(root, idx)
        if qp is not None:
            paths.append(qp)
    return paths


@dataclass
class AttributionReport:
    """Aggregate stage attribution: where sojourn time goes."""

    n_queries: int
    mean_sojourn: float
    #: mean seconds per stage over all queries
    overall: dict[str, float]
    #: mean seconds per stage over the slowest 1% of queries
    tail: dict[str, float] = field(default_factory=dict)
    tail_mean_sojourn: float = 0.0

    @property
    def accounted(self) -> float:
        return sum(self.overall.values())

    def to_dict(self) -> dict:
        return dict(
            n_queries=self.n_queries,
            mean_sojourn_s=round(self.mean_sojourn, 9),
            accounted_s=round(self.accounted, 9),
            stages_s={k: round(v, 9) for k, v in self.overall.items()},
            tail_mean_sojourn_s=round(self.tail_mean_sojourn, 9),
            tail_stages_s={k: round(v, 9) for k, v in self.tail.items()},
        )

    def render(self) -> str:
        lines = [f"critical-path attribution over {self.n_queries} queries",
                 f"  mean sojourn {self.mean_sojourn * 1e3:9.3f} ms  "
                 f"(accounted {self.accounted * 1e3:.3f} ms)"]
        lines.append(f"  {'stage':<14}{'mean':>12}{'share':>8}"
                     f"{'p99-tail':>12}{'share':>8}")
        for name in STAGES:
            mu = self.overall.get(name, 0.0)
            tl = self.tail.get(name, 0.0)
            if mu <= 0.0 and tl <= 0.0:
                continue
            fs = mu / self.mean_sojourn if self.mean_sojourn else 0.0
            ft = tl / self.tail_mean_sojourn if self.tail_mean_sojourn \
                else 0.0
            lines.append(f"  {name:<14}{mu * 1e3:9.3f} ms{fs:7.1%}"
                         f"{tl * 1e3:9.3f} ms{ft:7.1%}")
        return "\n".join(lines)


def attribute(tracer) -> AttributionReport:
    """Aggregate per-query critical paths into one report."""
    paths = extract_paths(tracer)
    n = len(paths)
    if n == 0:
        return AttributionReport(0, 0.0, dict.fromkeys(STAGES, 0.0))
    overall = dict.fromkeys(STAGES, 0.0)
    for p in paths:
        for k, v in p.stages.items():
            overall[k] += v
    overall = {k: v / n for k, v in overall.items()}
    mean_sojourn = sum(p.sojourn for p in paths) / n
    # slowest 1% (at least one query)
    slow = sorted(paths, key=lambda p: p.sojourn)
    tail_n = max(1, int(round(n * 0.01)))
    tail_paths = slow[-tail_n:]
    tail = dict.fromkeys(STAGES, 0.0)
    for p in tail_paths:
        for k, v in p.stages.items():
            tail[k] += v
    tail = {k: v / tail_n for k, v in tail.items()}
    tail_mean = sum(p.sojourn for p in tail_paths) / tail_n
    return AttributionReport(n, mean_sojourn, overall, tail, tail_mean)


def trace_diff(a: dict, b: dict) -> dict:
    """Stage-by-stage delta between two attribution dicts (b - a).

    Antisymmetric by construction — ``trace_diff(a, b)`` negates
    ``trace_diff(b, a)`` — and exactly zero for identical runs.  Inputs
    are ``AttributionReport.to_dict()`` payloads (e.g. the ``attrib``
    block of a benchmark JSON).
    """
    sa, sb = a.get("stages_s", {}), b.get("stages_s", {})
    stages = {k: round(sb.get(k, 0.0) - sa.get(k, 0.0), 9)
              for k in sorted(set(sa) | set(sb))}
    return dict(
        mean_sojourn_delta_s=round(b.get("mean_sojourn_s", 0.0)
                                   - a.get("mean_sojourn_s", 0.0), 9),
        stages_delta_s=stages,
    )


def render_diff(diff: dict) -> str:
    """Human-readable trace diff, biggest movers first."""
    total = diff.get("mean_sojourn_delta_s", 0.0)
    lines = [f"attribution diff: mean sojourn {total * 1e3:+.3f} ms"]
    movers = sorted(diff.get("stages_delta_s", {}).items(),
                    key=lambda kv: -abs(kv[1]))
    for name, d in movers:
        if d == 0.0:
            continue
        share = d / total if total else 0.0
        lines.append(f"  {name:<14}{d * 1e3:+9.3f} ms"
                     + (f"  ({share:+.0%} of delta)" if total else ""))
    if len(lines) == 1:
        lines.append("  (no per-stage movement)")
    return "\n".join(lines)
