"""Run manifests: make every report/bench JSON self-describing.

A manifest pins down what produced a payload — git revision, RNG seed,
a content hash of the effective config, wall-clock cost and the exact
command line — so a BENCH_*.json entry or a trace file found on a CI
artifact shelf can be traced back to a reproducible run.
"""
from __future__ import annotations

import hashlib
import json
import subprocess
import sys
import time

__all__ = ["git_sha", "config_hash", "run_manifest"]


def git_sha() -> str:
    """Short git revision of the working tree, or ``"unknown"``."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=5)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def config_hash(config) -> str:
    """Short content hash of a JSON-able config mapping."""
    blob = json.dumps(config, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:12]


def run_manifest(*, seed: int | None = None, config=None,
                 argv: list[str] | None = None,
                 wall_s: float | None = None) -> dict:
    """Build the ``meta`` block for a report/bench payload."""
    if argv is None:
        argv = sys.argv
    meta = dict(
        git_sha=git_sha(),
        timestamp=time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        command=" ".join(argv),
        python=sys.version.split()[0],
    )
    if seed is not None:
        meta["seed"] = int(seed)
    if config is not None:
        meta["config_hash"] = config_hash(config)
    if wall_s is not None:
        meta["wall_s"] = round(wall_s, 3)
    return meta
