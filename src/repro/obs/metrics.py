"""Lightweight metrics registry: counters, gauges, log-bucketed histograms.

The serving layers publish into a :class:`MetricsRegistry` (owned by the
run's :class:`~repro.obs.trace.Tracer`) instead of growing ad-hoc lists.
All three instrument types use fixed memory regardless of sample count,
so a 10M-query replay costs the same as a smoke run.  A periodic
snapshot (driven by the fleet router's ticker when tracing is enabled)
turns the registry into a time series that the Chrome-trace export
renders as counter tracks.

Everything here is observational: instruments never touch the kernel,
so publishing is safe from any event callback.
"""
from __future__ import annotations

import math

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


class Counter:
    """Monotonically increasing count (events, bytes, ...)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount


class Gauge:
    """Last-write-wins instantaneous value (queue depth, instances)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)


class Histogram:
    """Log-bucketed histogram with fixed memory.

    Buckets are half-open decades split into ``buckets_per_decade``
    geometric sub-buckets covering [``lo``, ``hi``); samples outside the
    range clamp into the first/last bucket.  Quantiles interpolate
    within the winning bucket, which is plenty for attribution-grade
    summaries.

    Error bound (tested by ``test_histogram_quantile_exactness``): for
    in-range samples, the estimate and the true (inverted-CDF) sample
    quantile land in the *same* bucket, so the ratio estimate/true lies
    in ``[1/base, base]`` with ``base = 10**(1/buckets_per_decade)`` —
    a worst-case relative error of ``base - 1`` (~33% at the default 8
    buckets/decade — an earlier doc claimed ~12%, which the bound does
    not support; that would need ~20 buckets/decade).  The final clamp
    to [``min``, ``max``] makes q=0/q=1 exact for in-range samples and
    keeps every estimate inside the observed value range even when
    samples clamped into the edge buckets distort their bucket's edges.
    """

    __slots__ = ("name", "lo", "hi", "_base", "_n_buckets", "counts",
                 "count", "total", "min", "max")

    def __init__(self, name: str, lo: float = 1e-6, hi: float = 1e4,
                 buckets_per_decade: int = 8):
        self.name = name
        self.lo = lo
        self.hi = hi
        self._base = 10.0 ** (1.0 / buckets_per_decade)
        self._n_buckets = int(math.ceil(
            math.log(hi / lo) / math.log(self._base))) + 1
        self.counts = [0] * self._n_buckets
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def _bucket(self, x: float) -> int:
        if x <= self.lo:
            return 0
        i = int(math.log(x / self.lo) / math.log(self._base))
        return min(i, self._n_buckets - 1)

    def observe(self, x: float) -> None:
        self.counts[self._bucket(x)] += 1
        self.count += 1
        self.total += x
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Approximate quantile via linear interpolation in the bucket."""
        if not self.count:
            return 0.0
        target = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if seen + c >= target and c > 0:
                frac = (target - seen) / c
                b_lo = self.lo * self._base ** i
                b_hi = b_lo * self._base
                est = b_lo + frac * (b_hi - b_lo)
                return min(max(est, self.min), self.max)
            seen += c
        return self.max

    def to_dict(self) -> dict:
        return dict(count=self.count,
                    sum=round(self.total, 9),
                    mean=round(self.mean, 9),
                    min=round(self.min, 9) if self.count else 0.0,
                    max=round(self.max, 9) if self.count else 0.0,
                    p50=round(self.quantile(0.50), 9),
                    p99=round(self.quantile(0.99), 9))


class MetricsRegistry:
    """Named instruments plus periodic time-series snapshots."""

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}
        #: list of (sim time, {name: value}) rows from snapshot()
        self.series: list[tuple[float, dict[str, float]]] = []

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str, **kwargs) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name, **kwargs)
        return h

    def snapshot(self, t: float) -> None:
        """Append one time-series row of every counter and gauge, plus
        each histogram's running ``count``/``sum`` — with both, the delta
        between any two ticks reconstructs that window's observation
        count and mean without re-tracing (windowed means =
        Δsum / Δcount; the deltas across all ticks telescope to the
        final histogram totals)."""
        row = {c.name: c.value for c in self._counters.values()}
        row.update({g.name: g.value for g in self._gauges.values()})
        for h in self._histograms.values():
            row[f"{h.name}.count"] = float(h.count)
            row[f"{h.name}.sum"] = h.total
        self.series.append((t, row))

    def to_dict(self) -> dict:
        return dict(
            counters={k: v.value for k, v in sorted(self._counters.items())},
            gauges={k: v.value for k, v in sorted(self._gauges.items())},
            histograms={k: v.to_dict()
                        for k, v in sorted(self._histograms.items())},
        )
