"""Query-level tracing over the sim kernel: spans in *simulated* time.

A :class:`Span` is an interval of virtual time with a name, a parent
link and free-form attributes (shard id, tenant, cache hit/miss, bytes
fetched).  A :class:`Tracer` collects spans, instant events and flow
arrows for one run; :mod:`repro.obs.export` turns them into a
Chrome-trace/Perfetto JSON file and :mod:`repro.obs.critical_path`
extracts per-query critical paths and attribution reports from them.

Two properties are load-bearing:

* **Zero cost when disabled.**  Every instrumentation site in the
  serving stack guards on ``tracer.enabled``; the module-level
  :data:`NULL_TRACER` (a :class:`NullTracer`) is the kernel default, so
  an untraced run pays one attribute read + bool test per site and
  allocates nothing.
* **Observe, never perturb.**  A tracer records what the kernel already
  did: it schedules no events, draws no RNG, and never feeds a value
  back into the simulation.  A traced run is therefore bit-exact
  against the untraced goldens (the metrics-snapshot ticker the fleet
  router starts when tracing is on only *reads* state — see
  ``FleetRouter._obs_snapshot``).

Span-tree conventions (see ``docs/observability.md`` for the full
attribute table):

``query`` roots (one per query, ``t0`` = arrival) own ``admission``,
``route``, per-round ``round`` and final ``merge`` children; each
``round`` owns the ``shard_job`` spans whose completions the gather
consumed; each ``shard_job`` owns its ``queue`` wait and its
``storage_fetch`` / ``cache_fetch`` / ``compute`` legs.  Work the query
did not wait for — hedge-race losers, jobs aborted by a shard death —
is recorded as *parentless* spans with ``wasted=True`` (plus a flow
arrow from the round that launched it), so the tree invariant "child
interval inside parent interval" holds for every parented span.
"""
from __future__ import annotations

from typing import Any

__all__ = ["Span", "Tracer", "NullTracer", "NULL_TRACER",
           "emit_job_spans"]


class Span:
    """One interval of simulated time in a trace."""

    __slots__ = ("sid", "name", "t0", "t1", "parent", "attrs")

    def __init__(self, sid: int, name: str, t0: float,
                 parent: int | None = None,
                 attrs: dict[str, Any] | None = None):
        self.sid = sid
        self.name = name
        self.t0 = t0
        self.t1: float | None = None
        self.parent = parent             # parent span's sid
        self.attrs = attrs

    @property
    def duration(self) -> float:
        return (self.t1 if self.t1 is not None else self.t0) - self.t0

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        end = "open" if self.t1 is None else f"{self.t1:.6f}"
        return (f"Span({self.name}#{self.sid} [{self.t0:.6f}, {end}]"
                f"{'' if self.parent is None else f' <- #{self.parent}'})")


class Tracer:
    """Span/event/flow collector for one simulation run.

    Attach to a kernel with :meth:`attach` (done by the serving drivers
    when handed a tracer); scheduling then records the *current span*
    into every event so span context survives event-callback hops, and
    ``Event.__repr__`` shows which span scheduled it.
    """

    enabled = True

    def __init__(self):
        self.spans: list[Span] = []
        self.instants: list[tuple[str, float, dict | None]] = []
        self.flows: list[tuple[int, int]] = []    # (src sid, dst sid)
        self._kernel = None
        from repro.obs.metrics import MetricsRegistry
        self.metrics = MetricsRegistry()

    # ------------------------------------------------------------ wiring --
    def attach(self, kernel) -> "Tracer":
        """Register on ``kernel`` (sets ``kernel.tracer``); events then
        carry the span that was current when they were scheduled."""
        self._kernel = kernel
        kernel.tracer = self
        return self

    @property
    def current(self) -> Span | None:
        """The span of the event currently firing (kernel context)."""
        return self._kernel.current_span if self._kernel is not None \
            else None

    # ------------------------------------------------------------- spans --
    def begin(self, name: str, t0: float, parent: Span | None = None,
              **attrs) -> Span:
        """Open a span; close with :meth:`end`.  With no explicit
        ``parent`` the kernel's current span (if any) is the parent."""
        if parent is None:
            parent = self.current
        sp = Span(len(self.spans), name, t0,
                  parent=parent.sid if parent is not None else None,
                  attrs=attrs or None)
        self.spans.append(sp)
        return sp

    def end(self, span: Span, t1: float) -> Span:
        span.t1 = t1
        return span

    def record(self, name: str, t0: float, t1: float,
               parent: Span | None = None, **attrs) -> Span:
        """Record a complete span (both endpoints already known)."""
        sp = self.begin(name, t0, parent=parent, **attrs)
        sp.t1 = t1
        return sp

    # -------------------------------------------------- events / arrows --
    def instant(self, name: str, t: float, **attrs) -> None:
        """A point event (shed, shard fail/recover, autoscale decision)."""
        self.instants.append((name, t, attrs or None))

    def flow(self, src: Span, dst: Span) -> None:
        """An async arrow (e.g. a hedge forking off its round)."""
        self.flows.append((src.sid, dst.sid))

    # ------------------------------------------------------------- intro --
    def children_index(self) -> dict[int | None, list[Span]]:
        """sid -> children (in record order); key None = root spans."""
        out: dict[int | None, list[Span]] = {}
        for sp in self.spans:
            out.setdefault(sp.parent, []).append(sp)
        return out

    def __len__(self) -> int:
        return len(self.spans)


class NullTracer:
    """The disabled tracer: every operation is a no-op.

    Call sites guard on :attr:`enabled`, so in an untraced run the only
    cost tracing adds is that boolean test.
    """

    enabled = False
    metrics = None
    spans: list = []
    instants: list = []
    flows: list = []
    current = None

    def attach(self, kernel) -> "NullTracer":
        kernel.tracer = self
        return self

    def begin(self, name, t0, parent=None, **attrs):
        return None

    def end(self, span, t1):
        return None

    def record(self, name, t0, t1, parent=None, **attrs):
        return None

    def instant(self, name, t, **attrs):
        return None

    def flow(self, src, dst):
        return None


#: The shared disabled tracer every kernel starts with.
NULL_TRACER = NullTracer()


def emit_job_spans(tr: Tracer, parent: Span | None, submit_t: float,
                   job) -> None:
    """Synthesize one shard job's sub-spans from its completion record.

    ``job`` is a :class:`repro.serving.engine.JobRecord`; its
    ``start_t``/``end_t`` and per-batch :class:`BatchTrace` rows carry
    enough to tile the interval exactly: queue wait (submit -> engine
    start), alternating ``compute`` and fetch legs, final compute.
    Fetch legs are ``storage_fetch`` when any request missed to remote
    storage, ``nvme_fetch`` when the misses were served entirely from
    the local NVMe tier, and ``cache_fetch`` when the whole batch was
    served from the DRAM cache.  A mixed round (some misses NVMe, some
    remote) is bounded by the remote fetch, so it stays a
    ``storage_fetch`` leg and carries the NVMe split in its attrs.  On a
    kernel backend the job's ``coalesce`` intervals (waits in the batch
    window) are tiled out of the compute gaps as ``batching`` legs; with
    no coalescing the emitted spans are identical to before the backend
    existed.
    """
    coalesce = getattr(job, "coalesce", None) or ()

    def compute_legs(lo: float, hi: float) -> None:
        cur = lo
        for iv in coalesce:
            e, f = iv[0], iv[1]
            if f is None or f <= cur or e >= hi:
                continue
            e, f = max(e, cur), min(f, hi)
            if e > cur:
                tr.record("compute", cur, e, parent=parent)
            tr.record("batching", e, f, parent=parent)
            cur = f
        if hi > cur:
            tr.record("compute", cur, hi, parent=parent)

    if job.start_t > submit_t:
        tr.record("queue", submit_t, job.start_t, parent=parent)
    cursor = job.start_t
    for b in job.batches:
        if b.submit_t > cursor:
            compute_legs(cursor, b.submit_t)
        n_nvme = getattr(b, "n_nvme", 0)
        if b.n_requests > 0:
            name = "storage_fetch"
        elif n_nvme > 0:
            name = "nvme_fetch"
        else:
            name = "cache_fetch"
        attrs = dict(requests=b.n_requests, hits=b.n_hits,
                     bytes_storage=b.nbytes_storage, bytes=b.nbytes_total)
        if n_nvme > 0:
            # only tiered runs grow the attr set — flat spans stay
            # byte-identical to the pre-tier tracer output
            attrs["nvme_requests"] = n_nvme
            attrs["bytes_nvme"] = getattr(b, "nbytes_nvme", 0)
        tr.record(name, b.submit_t, b.done_t, parent=parent, **attrs)
        cursor = b.done_t
    if job.end_t > cursor:
        compute_legs(cursor, job.end_t)
