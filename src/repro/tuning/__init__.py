"""``repro.tuning`` — simulation-driven auto-configuration (paper §5.2/§7
as a decision system).

Pipeline: ``enumerate_space`` (declarative grids, paper-derived priors)
→ ``screen`` (analytic Eq. 1/2 pricing prunes ≥90%) → ``successive_halving``
(survivors run on the real engine + storage simulator at subsampled scale)
→ ``pareto_frontier`` + ``autotune`` (knee-with-slack recommendation).

CLI: ``python -m repro.tuning --recall 0.95 --concurrency 64 --dim 960
--storage tos`` emits a JSON :class:`Recommendation`.
"""
from repro.tuning.evaluate import (EvalBudget, EvalOutcome, default_budget,
                                   successive_halving)
from repro.tuning.fleet import (FleetOutcome, FleetPoint,
                                FleetRecommendation, LoadOutcome,
                                LoadRecommendation, WindowOutcome,
                                WindowRecommendation, evaluate_batch_window,
                                evaluate_fleet_load, evaluate_fleet_point,
                                tune_batch_window, tune_fleet,
                                tune_fleet_for_load)
from repro.tuning.ingest import (IngestOutcome, IngestPoint,
                                 IngestPrediction, IngestRecommendation,
                                 analytic_write_amplification,
                                 enumerate_ingest_space, screen_ingest,
                                 tune_ingest)
from repro.tuning.pareto import hypervolume, pareto_frontier
from repro.tuning.tenancy import (CacheSplit, CacheSplitRecommendation,
                                  SplitOutcome, SplitPrediction,
                                  che_hit_rate, enumerate_splits,
                                  miss_curve, object_access_profile,
                                  screen_cache_splits, tune_cache_split)
from repro.tuning.recommend import Recommendation, autotune
from repro.tuning.tier import (TierOutcome, TierPrediction, TierSplit,
                               TierSplitRecommendation,
                               enumerate_tier_splits, evaluate_tier_split,
                               fleet_access_profile, screen_tier_splits,
                               tune_tier_split)
from repro.tuning.screen import (Prediction, ScreenResult,
                                 best_predicted_qps, predict, screen)
from repro.tuning.space import (Candidate, EnvSpec, WorkloadSpec,
                                enumerate_space, resolve_storage)

__all__ = [
    "autotune", "Recommendation", "WorkloadSpec", "EnvSpec", "Candidate",
    "enumerate_space", "resolve_storage", "screen", "predict",
    "Prediction", "ScreenResult", "best_predicted_qps",
    "successive_halving", "EvalBudget", "EvalOutcome", "default_budget",
    "pareto_frontier", "hypervolume",
    "FleetPoint", "FleetOutcome", "FleetRecommendation",
    "evaluate_fleet_point", "tune_fleet",
    "LoadOutcome", "LoadRecommendation", "evaluate_fleet_load",
    "tune_fleet_for_load",
    "WindowOutcome", "WindowRecommendation", "evaluate_batch_window",
    "tune_batch_window",
    "IngestPoint", "IngestPrediction", "IngestOutcome",
    "IngestRecommendation", "enumerate_ingest_space", "screen_ingest",
    "analytic_write_amplification", "tune_ingest",
    "CacheSplit", "SplitPrediction", "SplitOutcome",
    "CacheSplitRecommendation", "object_access_profile", "che_hit_rate",
    "miss_curve", "enumerate_splits", "screen_cache_splits",
    "tune_cache_split",
    "TierSplit", "TierPrediction", "TierOutcome",
    "TierSplitRecommendation", "fleet_access_profile",
    "enumerate_tier_splits", "screen_tier_splits", "evaluate_tier_split",
    "tune_tier_split",
]
