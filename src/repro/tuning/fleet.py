"""Fleet sizing as a tuning axis: shard count × replication as evaluable
points (the ROADMAP's "tuner-driven replica/centroid re-partitioning").

The single-node tuner answers *which index and knobs*; this module
answers *how many shards and how many replicas* once one node isn't
enough.  Each :class:`FleetPoint` is priced by running the real fleet —
partition, scatter-gather router, shard engines — on a subsampled
workload analogue (the same scaling discipline as
``tuning.evaluate``), and the sweep shares one index build across all
points because only the *placement* changes.

Selection is cost-first: the smallest fleet (shards × replication =
machines × stored copies) whose measured speedup over one shard meets
``target_speedup`` and whose recall meets the workload target.  Replica
count matters beyond fault tolerance: R >= 2 unlocks
power-of-two-choices balancing and hedging, at the price of extra
storage and diluted per-shard cache.
"""
from __future__ import annotations

import dataclasses
import json

from repro.core.cluster_index import ClusterIndex
from repro.core.flat import exact_topk
from repro.core.types import ClusterIndexParams, SearchParams
from repro.data.synth import DatasetSpec, make_dataset
from repro.fleet.partition import ClusterPartition
from repro.fleet.router import FleetConfig, FleetRouter
from repro.sim.arrivals import Scenario
from repro.tuning.space import EnvSpec, WorkloadSpec

SHARD_GRID = (1, 2, 4, 8)
FLEET_REPLICA_GRID = (1, 2)
#: batch-window sweep grid (µs) for the kernel execution backend
WINDOW_GRID_US = (0.0, 50.0, 100.0, 200.0, 500.0, 1000.0)


@dataclasses.dataclass(frozen=True)
class FleetPoint:
    """One evaluable fleet configuration (the tuner's new axes)."""

    n_shards: int
    replication: int = 1
    hedge: bool = False

    def __post_init__(self):
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if not 1 <= self.replication <= self.n_shards:
            raise ValueError(
                f"replication must be in [1, {self.n_shards}], got "
                f"{self.replication}")

    @property
    def machines(self) -> int:
        return self.n_shards

    @property
    def stored_copies(self) -> int:
        return self.replication

    def label(self) -> str:
        h = ",hedge" if self.hedge else ""
        return f"fleet[S={self.n_shards},R={self.replication}{h}]"

    def to_dict(self) -> dict:
        return dict(n_shards=self.n_shards, replication=self.replication,
                    hedge=self.hedge)


@dataclasses.dataclass
class FleetOutcome:
    """Measured behaviour of one fleet point at eval scale."""

    point: FleetPoint
    qps: float
    speedup: float                 # vs the 1-shard baseline of this sweep
    p99_s: float
    recall: float
    load_imbalance: float
    hedge_rate: float
    shed_rate: float
    eval_n: int

    @property
    def cost_units(self) -> int:
        """Machines × stored copies — what the fleet bills for."""
        return self.point.n_shards * self.point.replication

    def to_dict(self) -> dict:
        return dict(config=self.point.to_dict(),
                    qps_eval=round(self.qps, 2),
                    speedup=round(self.speedup, 3),
                    p99_s=round(self.p99_s, 6),
                    recall=round(self.recall, 4),
                    load_imbalance=round(self.load_imbalance, 4),
                    hedge_rate=round(self.hedge_rate, 4),
                    shed_rate=round(self.shed_rate, 4),
                    cost_units=self.cost_units, eval_n=self.eval_n)


@dataclasses.dataclass
class FleetRecommendation:
    """Sweep result: the cheapest fleet that meets the targets."""

    workload: WorkloadSpec
    env_storage: str
    point: FleetPoint
    speedup: float
    feasible: bool                 # meets target_speedup AND recall target
    target_speedup: float
    outcomes: list[FleetOutcome]

    def to_dict(self) -> dict:
        return dict(
            workload=dataclasses.asdict(self.workload),
            environment=dict(storage=self.env_storage),
            recommendation=self.point.to_dict(),
            speedup=round(self.speedup, 3),
            meets_target=self.feasible,
            target_speedup=self.target_speedup,
            sweep=[o.to_dict() for o in self.outcomes])

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)


def _eval_index(w: WorkloadSpec, eval_n: int, nq: int, seed: int):
    n = min(eval_n, w.n)
    spec = DatasetSpec("fleet-analog", w.dim, w.dtype, n, nq,
                       n_clusters=max(8, min(64, n // 16)),
                       intrinsic_dim=min(32, w.dim), seed=seed)
    data, queries = make_dataset(spec)
    gt, _ = exact_topk(data, queries, w.k)
    index = ClusterIndex.build(data, ClusterIndexParams(
        kmeans_iters=4, seed=seed))
    return index, queries, gt


def _fleet_cfg(w: WorkloadSpec, env: EnvSpec, point: FleetPoint,
               seed: int, exec_kw: dict | None = None) -> FleetConfig:
    """The sweep's concrete fleet config for one point — shared between
    closed-loop pricing, open-loop pricing and traced validation so all
    three measure the *same* fleet.  ``exec_kw`` selects the execution
    backend (``backend``/``batch_window_s``/``calibration`` FleetConfig
    fields; default analytic)."""
    # fixed total fleet cache: replication dilutes the per-shard share
    per_shard_cache = env.cache_bytes // point.n_shards
    return FleetConfig(
        n_shards=point.n_shards, replication=point.replication,
        storage=env.storage, concurrency=max(w.concurrency, 32),
        shard_concurrency=8, queue_depth=64,
        cache_bytes=per_shard_cache,
        cache_policy="slru" if per_shard_cache > 0 else "none",
        hedge=point.hedge, seed=seed, **(exec_kw or {}))


def evaluate_fleet_point(w: WorkloadSpec, env: EnvSpec, point: FleetPoint,
                         index, queries, gt, *, nprobe: int = 64,
                         baseline_qps: float | None = None,
                         exec_kw: dict | None = None,
                         seed: int = 0) -> FleetOutcome:
    """Run one fleet point on the shared eval index and measure it.

    The fleet question only exists under load: the driver holds enough
    closed-loop queries outstanding to saturate a single shard, so the
    sweep measures added *capacity*, not an idle latency floor.
    """
    params = SearchParams(k=w.k, nprobe=min(nprobe, index.meta.n_lists))
    cfg = _fleet_cfg(w, env, point, seed, exec_kw)
    partition = ClusterPartition.build(index.meta.list_nbytes,
                                       point.n_shards, point.replication)
    rep = FleetRouter(index, cfg, partition=partition).run(queries, params)
    qps = rep.qps
    return FleetOutcome(
        point=point, qps=qps,
        speedup=qps / baseline_qps if baseline_qps else 1.0,
        p99_s=rep.latency_percentile(99), recall=rep.recall_against(gt),
        load_imbalance=rep.load_imbalance, hedge_rate=rep.hedge_rate,
        shed_rate=rep.shed_rate, eval_n=index.meta.n_data)


def tune_fleet(w: WorkloadSpec, env: EnvSpec, target_speedup: float = 2.0,
               shard_grid: tuple[int, ...] = SHARD_GRID,
               replica_grid: tuple[int, ...] = FLEET_REPLICA_GRID,
               hedge: bool = False, eval_n: int = 1200, nq: int = 48,
               nprobe: int = 32, exec_kw: dict | None = None,
               seed: int = 0) -> FleetRecommendation:
    """Sweep shards × replication; pick the cheapest point meeting the
    speedup and recall targets (ties: higher QPS)."""
    index, queries, gt = _eval_index(w, eval_n, nq, seed)
    base = evaluate_fleet_point(
        w, env, FleetPoint(1, 1), index, queries, gt, nprobe=nprobe,
        exec_kw=exec_kw, seed=seed)
    outcomes = [dataclasses.replace(base, speedup=1.0)]
    for s in shard_grid:
        for r in replica_grid:
            if r > s or (s == 1 and r == 1):
                continue
            point = FleetPoint(s, r, hedge=hedge and r > 1)
            outcomes.append(evaluate_fleet_point(
                w, env, point, index, queries, gt, nprobe=nprobe,
                baseline_qps=base.qps, exec_kw=exec_kw, seed=seed))
    feas = [o for o in outcomes
            if o.speedup >= target_speedup
            and o.recall >= w.target_recall - 0.005]
    if feas:
        pick = min(feas, key=lambda o: (o.cost_units, -o.qps))
        feasible = True
    else:
        pick = max(outcomes, key=lambda o: (o.speedup, -o.cost_units))
        feasible = False
    return FleetRecommendation(
        workload=w, env_storage=env.storage.name, point=pick.point,
        speedup=pick.speedup, feasible=feasible,
        target_speedup=target_speedup, outcomes=outcomes)


# ------------------------------------------------- scenario-driven sizing --

@dataclasses.dataclass
class LoadOutcome:
    """One fleet point measured under an open-loop scenario."""

    point: FleetPoint
    offered_qps: float
    achieved_qps: float
    goodput_frac: float            # arrivals served within the SLO
    p99_sojourn_s: float           # arrival-to-completion p99
    recall: float
    shed_rate: float
    eval_n: int

    @property
    def cost_units(self) -> int:
        return self.point.n_shards * self.point.replication

    def to_dict(self) -> dict:
        return dict(config=self.point.to_dict(),
                    offered_qps=round(self.offered_qps, 2),
                    achieved_qps=round(self.achieved_qps, 2),
                    goodput_frac=round(self.goodput_frac, 4),
                    p99_sojourn_s=round(self.p99_sojourn_s, 6),
                    recall=round(self.recall, 4),
                    shed_rate=round(self.shed_rate, 4),
                    cost_units=self.cost_units, eval_n=self.eval_n)


@dataclasses.dataclass
class LoadRecommendation:
    """The cheapest fleet that serves an offered load within its SLO."""

    workload: WorkloadSpec
    env_storage: str
    scenario: Scenario
    point: FleetPoint
    feasible: bool
    goodput_target: float
    outcomes: list[LoadOutcome]

    def to_dict(self) -> dict:
        return dict(
            workload=dataclasses.asdict(self.workload),
            environment=dict(storage=self.env_storage),
            scenario=self.scenario.to_dict(),
            recommendation=self.point.to_dict(),
            meets_slo=self.feasible,
            goodput_target=self.goodput_target,
            sweep=[o.to_dict() for o in self.outcomes])

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)


def evaluate_fleet_load(w: WorkloadSpec, env: EnvSpec, point: FleetPoint,
                        scenario: Scenario, index, queries, gt, *,
                        nprobe: int = 32, exec_kw: dict | None = None,
                        seed: int = 0) -> LoadOutcome:
    """Run one fleet point under an open-loop scenario and measure
    whether it keeps up: achieved vs offered QPS, goodput under the SLO
    and p99 sojourn (arrival -> completion, backlog wait included)."""
    params = SearchParams(k=w.k, nprobe=min(nprobe, index.meta.n_lists))
    cfg = _fleet_cfg(w, env, point, seed, exec_kw)
    partition = ClusterPartition.build(index.meta.list_nbytes,
                                       point.n_shards, point.replication)
    arrivals = scenario.make_arrivals(len(queries), cfg.concurrency,
                                      seed=seed)
    rep = FleetRouter(index, cfg, partition=partition).run(
        queries, params, arrivals=arrivals, slo_s=scenario.slo_s)
    return LoadOutcome(
        point=point, offered_qps=rep.offered_qps, achieved_qps=rep.qps,
        goodput_frac=rep.goodput_frac,
        p99_sojourn_s=rep.sojourn_percentile(99),
        recall=rep.recall_against(gt), shed_rate=rep.shed_rate,
        eval_n=index.meta.n_data)


def tune_fleet_for_load(w: WorkloadSpec, env: EnvSpec, scenario: Scenario,
                        goodput_target: float = 0.99,
                        shard_grid: tuple[int, ...] = SHARD_GRID,
                        replica_grid: tuple[int, ...] = FLEET_REPLICA_GRID,
                        hedge: bool = False, eval_n: int = 1200,
                        nq: int = 48, nprobe: int = 32,
                        exec_kw: dict | None = None,
                        seed: int = 0) -> LoadRecommendation:
    """Size the fleet for an **offered load + SLO** instead of a speedup
    target: sweep shards × replication under the open-loop scenario and
    pick the cheapest point whose goodput (fraction of arrivals served
    within ``scenario.slo_s``) meets ``goodput_target`` at the workload's
    recall target.  Ties: lower p99 sojourn."""
    if scenario.kind == "closed":
        raise ValueError(
            "tune_fleet_for_load needs an open-loop scenario (poisson/"
            "burst/trace); use tune_fleet for closed-loop speedup targets")
    index, queries, gt = _eval_index(w, eval_n, nq, seed)
    outcomes = []
    for s in shard_grid:
        for r in replica_grid:
            if r > s:
                continue
            point = FleetPoint(s, r, hedge=hedge and r > 1)
            outcomes.append(evaluate_fleet_load(
                w, env, point, scenario, index, queries, gt,
                nprobe=nprobe, exec_kw=exec_kw, seed=seed))
    feas = [o for o in outcomes
            if o.goodput_frac >= goodput_target
            and o.recall >= w.target_recall - 0.005]
    if feas:
        pick = min(feas, key=lambda o: (o.cost_units, o.p99_sojourn_s))
        feasible = True
    else:
        pick = max(outcomes, key=lambda o: (o.goodput_frac, -o.cost_units))
        feasible = False
    return LoadRecommendation(
        workload=w, env_storage=env.storage.name, scenario=scenario,
        point=pick.point, feasible=feasible,
        goodput_target=goodput_target, outcomes=outcomes)


def trace_fleet_point(w: WorkloadSpec, env: EnvSpec, point: FleetPoint,
                      *, scenario: Scenario | None = None, tracer=None,
                      monitor=None, pricebook=None,
                      eval_n: int = 1200, nq: int = 48, nprobe: int = 32,
                      exec_kw: dict | None = None, seed: int = 0):
    """Re-run one (typically: the recommended) fleet point with a tracer
    attached, on the same eval index and config recipe the sweep used.

    The sweep itself stays untraced — tracing all grid points would slow
    the search for spans nobody reads; the validation rerun shows *why*
    the winning point behaves as it does.  ``monitor``/``pricebook``
    (repro.obs) attach live SLO monitors and dollar metering to the same
    rerun, so a sizing recommendation can carry an alert log and a cost
    estimate.  Returns the FleetReport; the spans land in ``tracer``.
    """
    index, queries, _ = _eval_index(w, eval_n, nq, seed)
    params = SearchParams(k=w.k, nprobe=min(nprobe, index.meta.n_lists))
    cfg = _fleet_cfg(w, env, point, seed, exec_kw)
    partition = ClusterPartition.build(index.meta.list_nbytes,
                                       point.n_shards, point.replication)
    arrivals = None
    slo_s = None
    if scenario is not None and scenario.kind != "closed":
        arrivals = scenario.make_arrivals(len(queries), cfg.concurrency,
                                          seed=seed)
        slo_s = scenario.slo_s
    return FleetRouter(index, cfg, partition=partition).run(
        queries, params, arrivals=arrivals, slo_s=slo_s, tracer=tracer,
        monitor=monitor, pricebook=pricebook)


# ---------------------------------------------------- batch-window tuning --

@dataclasses.dataclass
class WindowOutcome:
    """One batch-coalescing window measured on the kernel backend."""

    window_us: float
    achieved_qps: float
    p99_s: float                   # completion p99: latency (closed-loop)
    #                                or sojourn (open-loop)
    goodput_frac: float            # 1.0 on closed-loop runs (no SLO clock)
    recall: float
    mean_occupancy: float          # query-tile fill across MXU batches
    mean_batch_jobs: float         # jobs coalesced per batch
    batches: int
    eval_n: int

    def to_dict(self) -> dict:
        return dict(window_us=round(self.window_us, 3),
                    achieved_qps=round(self.achieved_qps, 2),
                    p99_s=round(self.p99_s, 6),
                    goodput_frac=round(self.goodput_frac, 4),
                    recall=round(self.recall, 4),
                    mean_occupancy=round(self.mean_occupancy, 4),
                    mean_batch_jobs=round(self.mean_batch_jobs, 3),
                    batches=self.batches, eval_n=self.eval_n)


@dataclasses.dataclass
class WindowRecommendation:
    """Sweep result: the highest-occupancy window still inside budget."""

    workload: WorkloadSpec
    env_storage: str
    point: FleetPoint
    scenario: Scenario | None
    window_us: float
    feasible: bool
    goodput_target: float
    p99_slack: float
    outcomes: list[WindowOutcome]

    def to_dict(self) -> dict:
        d = dict(
            workload=dataclasses.asdict(self.workload),
            environment=dict(storage=self.env_storage),
            fleet=self.point.to_dict(),
            recommendation=dict(backend="kernel",
                                batch_window_us=round(self.window_us, 3)),
            meets_target=self.feasible,
            goodput_target=self.goodput_target,
            p99_slack=self.p99_slack,
            sweep=[o.to_dict() for o in self.outcomes])
        if self.scenario is not None:
            d["scenario"] = self.scenario.to_dict()
        return d

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)


def _backend_stats(router) -> tuple[int, int, float]:
    """(batches, jobs_batched, occupancy_sum) summed across the fleet's
    shard-engine backends — read post-run, no tracer required."""
    batches = jobs = 0
    occ = 0.0
    for g in router.groups:
        for srv in g.all_servers():
            be = srv.engine.backend
            if be is None:
                continue
            batches += be.batches
            jobs += be.jobs_batched
            occ += be.occupancy_sum
    return batches, jobs, occ


def evaluate_batch_window(w: WorkloadSpec, env: EnvSpec, point: FleetPoint,
                          window_us: float, index, queries, gt, *,
                          scenario: Scenario | None = None,
                          calibration: str | None = None,
                          nprobe: int = 32, seed: int = 0) -> WindowOutcome:
    """Run one coalescing window on the kernel backend and measure the
    latency/occupancy trade it buys.  Occupancy and batch sizes come from
    the shard backends' own counters, so the sweep stays untraced."""
    params = SearchParams(k=w.k, nprobe=min(nprobe, index.meta.n_lists))
    cfg = _fleet_cfg(w, env, point, seed, dict(
        backend="kernel", batch_window_s=window_us * 1e-6,
        calibration=calibration))
    partition = ClusterPartition.build(index.meta.list_nbytes,
                                       point.n_shards, point.replication)
    router = FleetRouter(index, cfg, partition=partition)
    arrivals = None
    slo_s = None
    if scenario is not None and scenario.kind != "closed":
        arrivals = scenario.make_arrivals(len(queries), cfg.concurrency,
                                          seed=seed)
        slo_s = scenario.slo_s
    rep = router.run(queries, params, arrivals=arrivals, slo_s=slo_s)
    batches, jobs, occ = _backend_stats(router)
    open_loop = arrivals is not None
    return WindowOutcome(
        window_us=window_us, achieved_qps=rep.qps,
        p99_s=(rep.sojourn_percentile(99) if open_loop
               else rep.latency_percentile(99)),
        goodput_frac=rep.goodput_frac if open_loop else 1.0,
        recall=rep.recall_against(gt),
        mean_occupancy=occ / batches if batches else 0.0,
        mean_batch_jobs=jobs / batches if batches else 0.0,
        batches=batches, eval_n=index.meta.n_data)


def tune_batch_window(w: WorkloadSpec, env: EnvSpec,
                      point: FleetPoint | None = None, *,
                      scenario: Scenario | None = None,
                      window_grid_us: tuple[float, ...] = WINDOW_GRID_US,
                      calibration: str | None = None,
                      goodput_target: float = 0.99,
                      p99_slack: float = 0.2, eval_n: int = 1200,
                      nq: int = 48, nprobe: int = 32,
                      seed: int = 0) -> WindowRecommendation:
    """Sweep the kernel backend's coalescing window on one fleet point.

    Wider windows fold more concurrent scans into each MXU dispatch —
    higher query-tile occupancy, better-amortized unit cost — at the
    price of queueing delay.  The sweep maps that frontier; the pick is
    the highest-occupancy window that (a) meets the goodput and recall
    targets and (b) keeps p99 within ``1 + p99_slack`` of the sweep's
    p99 floor, ties broken toward lower p99.  When nothing qualifies the
    min-p99 window wins and ``feasible`` is False.
    """
    if point is None:
        point = FleetPoint(2, 1)
    index, queries, gt = _eval_index(w, eval_n, nq, seed)
    outcomes = [evaluate_batch_window(
        w, env, point, us, index, queries, gt, scenario=scenario,
        calibration=calibration, nprobe=nprobe, seed=seed)
        for us in window_grid_us]
    p99_floor = min(o.p99_s for o in outcomes)
    feas = [o for o in outcomes
            if o.goodput_frac >= goodput_target
            and o.recall >= w.target_recall - 0.005
            and o.p99_s <= p99_floor * (1.0 + p99_slack)]
    if feas:
        pick = max(feas, key=lambda o: (o.mean_occupancy, -o.p99_s))
        feasible = True
    else:
        pick = min(outcomes, key=lambda o: o.p99_s)
        feasible = False
    return WindowRecommendation(
        workload=w, env_storage=env.storage.name, point=point,
        scenario=scenario, window_us=pick.window_us, feasible=feasible,
        goodput_target=goodput_target, p99_slack=p99_slack,
        outcomes=outcomes)
