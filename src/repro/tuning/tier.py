"""Budget-split tuning across the storage hierarchy: given a fixed
fleet spend in $/hour, how should it divide between machines, DRAM
cache, and the local NVMe tier?

The knobs trade against each other through one price book
(:class:`repro.obs.cost.PriceBook`): a wider fleet buys parallelism but
dilutes the per-query cache budget; more DRAM buys the fastest hits at
~10x the $/GiB of NVMe; a big NVMe tier absorbs the DRAM overflow at
~100us instead of the object store's ~10ms.  The paper's observation
that storage pricing, not raw latency, decides the deployment shape is
exactly this trade.

Same two-stage discipline as :mod:`repro.tuning.tenancy`:

* **screen** — enumerate (width, DRAM GiB, NVMe GiB) points that spend
  the budget, predict per-tier hit rates with Che's approximation
  (:func:`repro.tuning.tenancy.che_hit_rate`) over the workload's
  cluster-list access profile — or a measured miss-ratio curve from
  ``repro.obs.mrc`` when one is supplied — and rank by expected fetch
  latency ``h_dram*0 + (h_nvme - h_dram)*t_nvme + (1 - h_nvme)*t_remote``.
* **refine** — re-price the top-K screened points with real tiered
  fleet runs and recommend the measured-p99 winner.

Candidate byte budgets are scaled by the eval-to-full index-bytes
ratio (the ``tuning.evaluate`` coverage discipline), so a 1200-vector
analogue sees the same *fraction* of its index cached as the full
deployment would.
"""
from __future__ import annotations

import dataclasses
import json

from repro.core.types import SearchParams
from repro.fleet.partition import ClusterPartition
from repro.fleet.router import FleetConfig, FleetRouter
from repro.obs.cost import GiB, PriceBook
from repro.obs.mrc import mrc_miss_ratio
from repro.storage.spec import NVME
from repro.tuning.fleet import _eval_index
from repro.tuning.space import EnvSpec, WorkloadSpec
from repro.tuning.tenancy import che_hit_rate

TIER_WIDTH_GRID = (1, 2, 4)


def fleet_access_profile(index, queries, nprobe: int) -> dict:
    """(key -> [nbytes, access_count]) over the probed posting lists —
    the single-tenant analogue of ``tenancy.object_access_profile``."""
    profile: dict = {}
    np_eff = min(nprobe, index.meta.n_lists)
    for q in queries:
        lids, _ = index.select_lists(q, np_eff)
        for li in lids:
            key = ("list", int(li))
            ent = profile.get(key)
            if ent is None:
                profile[key] = [int(index.meta.list_nbytes[int(li)]), 1]
            else:
                ent[1] += 1
    return profile


@dataclasses.dataclass(frozen=True)
class TierSplit:
    """One evaluable point: machines x per-machine DRAM x per-machine
    NVMe.  GiB figures are *full-scale* (what the budget buys)."""

    n_shards: int
    dram_gib: float
    nvme_gib: float

    def __post_init__(self):
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if self.dram_gib < 0 or self.nvme_gib < 0:
            raise ValueError("dram_gib/nvme_gib must be >= 0, got "
                             f"({self.dram_gib}, {self.nvme_gib})")

    def usd_per_hour(self, book: PriceBook) -> float:
        return self.n_shards * (
            book.instance_per_hour_usd
            + self.dram_gib * book.cache_dram_per_gib_hour_usd
            + self.nvme_gib * book.nvme_per_gib_hour_usd)

    def label(self) -> str:
        return (f"tier[S={self.n_shards},dram={self.dram_gib:.1f}GiB,"
                f"nvme={self.nvme_gib:.1f}GiB]")

    def to_dict(self) -> dict:
        return dict(n_shards=self.n_shards,
                    dram_gib=round(self.dram_gib, 3),
                    nvme_gib=round(self.nvme_gib, 3))


@dataclasses.dataclass
class TierPrediction:
    """Analytic screen result for one split."""

    split: TierSplit
    usd_per_hour: float
    hit_dram: float                # fetches absorbed by DRAM
    hit_nvme: float                # cumulative: DRAM or NVMe
    expected_fetch_s: float        # access-weighted mean fetch latency

    def to_dict(self) -> dict:
        return dict(split=self.split.to_dict(),
                    usd_per_hour=round(self.usd_per_hour, 6),
                    hit_dram=round(self.hit_dram, 4),
                    hit_nvme=round(self.hit_nvme, 4),
                    expected_fetch_s=round(self.expected_fetch_s, 9))


def enumerate_tier_splits(budget_usd_per_hour: float, book: PriceBook,
                          widths: tuple[int, ...] = TIER_WIDTH_GRID,
                          steps: int = 6) -> list[TierSplit]:
    """Splits that spend the budget: for each feasible width, sweep the
    DRAM share of the per-machine residual in ``steps`` increments (the
    rest buys NVMe).  Endpoints are the pure strategies — all-DRAM
    (flat cache fleet, no tier) and all-NVMe."""
    if budget_usd_per_hour <= 0:
        raise ValueError("budget_usd_per_hour must be > 0, got "
                         f"{budget_usd_per_hour}")
    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    out = []
    for w in widths:
        rem = budget_usd_per_hour / w - book.instance_per_hour_usd
        if rem <= 0:
            continue                    # width alone blows the budget
        for i in range(steps + 1):
            f = i / steps
            out.append(TierSplit(
                n_shards=w,
                dram_gib=f * rem / book.cache_dram_per_gib_hour_usd,
                nvme_gib=(1.0 - f) * rem / book.nvme_per_gib_hour_usd))
    if not out:
        raise ValueError(
            f"budget ${budget_usd_per_hour}/h cannot pay for one "
            f"instance at ${book.instance_per_hour_usd}/h "
            f"(pricebook {book.name!r})")
    return out


def resolve_mrc_curve(artifact: dict) -> dict:
    """Accept either a bare curve (``{"sizes", "miss_ratio"}``) or a
    full ``--mrc`` profiler artifact (``repro.obs.mrc``).  The tier
    split is fleet-wide, so a multi-tenant artifact is ambiguous —
    loud error rather than a silent pick."""
    if "miss_ratio" in artifact and "sizes" in artifact:
        return artifact
    rows = artifact.get("tenants")
    if isinstance(rows, list) and len(rows) == 1:
        return rows[0]
    raise ValueError(
        "tier tuning wants one fleet-wide miss-ratio curve: pass "
        "{'sizes': [...], 'miss_ratio': [...]} or a single-tenant "
        "--mrc artifact "
        f"(got {len(rows) if isinstance(rows, list) else 'no'} "
        "tenant rows)")


def _hit(profile: dict, mrc: dict | None, cache_bytes: float) -> float:
    if mrc is not None:
        return 1.0 - mrc_miss_ratio(mrc["sizes"], mrc["miss_ratio"],
                                    cache_bytes)
    return che_hit_rate(profile, int(cache_bytes))


def screen_tier_splits(profile: dict, splits: list[TierSplit],
                       book: PriceBook, *, remote_spec,
                       scale: float = 1.0,
                       mrc: dict | None = None) -> list[TierPrediction]:
    """Rank splits by predicted mean fetch latency.

    ``scale`` maps full-scale GiB onto the profiled index (the
    eval-to-full index-bytes ratio; 1.0 when profiling at full scale).
    DRAM hits cost nothing extra (the engine never leaves the node);
    NVMe hits pay the device's TTFB; the rest pay ``remote_spec``.
    Ties break toward fewer machines — same latency, simpler fleet.
    """
    t_nvme = NVME.ttfb_p50_s + NVME.min_latency_s
    t_remote = remote_spec.ttfb_p50_s + remote_spec.min_latency_s
    preds = []
    for s in splits:
        dram = s.n_shards * s.dram_gib * GiB * scale
        hd = _hit(profile, mrc, dram)
        hn = _hit(profile, mrc, dram + s.n_shards * s.nvme_gib * GiB
                  * scale)
        hn = max(hn, hd)               # cumulative by construction
        preds.append(TierPrediction(
            split=s, usd_per_hour=s.usd_per_hour(book), hit_dram=hd,
            hit_nvme=hn,
            expected_fetch_s=(hn - hd) * t_nvme + (1.0 - hn) * t_remote))
    preds.sort(key=lambda p: (p.expected_fetch_s, p.split.n_shards,
                              -p.hit_dram))
    return preds


@dataclasses.dataclass
class TierOutcome:
    """Measured behaviour of one refined split at eval scale."""

    split: TierSplit
    usd_per_hour: float
    qps: float
    p99_s: float
    recall: float
    hit_dram: float                # measured DRAM hit rate
    hit_nvme_frac: float           # NVMe share of DRAM misses
    eval_n: int

    def to_dict(self) -> dict:
        return dict(split=self.split.to_dict(),
                    usd_per_hour=round(self.usd_per_hour, 6),
                    qps_eval=round(self.qps, 2),
                    p99_s=round(self.p99_s, 6),
                    recall=round(self.recall, 4),
                    hit_dram=round(self.hit_dram, 4),
                    hit_nvme_frac=round(self.hit_nvme_frac, 4),
                    eval_n=self.eval_n)


@dataclasses.dataclass
class TierSplitRecommendation:
    """screen + refine result: how to spend the hourly budget."""

    workload: WorkloadSpec
    env_storage: str
    budget_usd_per_hour: float
    pricebook: str
    split: TierSplit
    feasible: bool                 # a refined split met the recall floor
    screened: list[TierPrediction]
    refined: list[TierOutcome]

    def to_dict(self) -> dict:
        return dict(
            workload=dataclasses.asdict(self.workload),
            environment=dict(storage=self.env_storage),
            budget_usd_per_hour=self.budget_usd_per_hour,
            pricebook=self.pricebook,
            recommendation=self.split.to_dict(),
            meets_recall=self.feasible,
            screened=[p.to_dict() for p in self.screened[:12]],
            refined=[o.to_dict() for o in self.refined])

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)


def _tier_fleet_cfg(w: WorkloadSpec, env: EnvSpec, split: TierSplit,
                    scale: float, index_bytes: int,
                    seed: int) -> FleetConfig:
    """The refine run's concrete fleet: per-shard budgets scaled onto
    the eval index and clamped to it (a device bigger than the dataset
    buys nothing)."""
    cache = min(int(split.dram_gib * GiB * scale), index_bytes)
    nvme = min(int(split.nvme_gib * GiB * scale), index_bytes)
    return FleetConfig(
        n_shards=split.n_shards, storage=env.storage,
        concurrency=max(w.concurrency, 32), shard_concurrency=8,
        queue_depth=64, cache_bytes=cache,
        cache_policy="slru" if cache > 0 else "none",
        nvme_bytes=nvme, seed=seed)


def evaluate_tier_split(w: WorkloadSpec, env: EnvSpec, split: TierSplit,
                        index, queries, gt, *, scale: float,
                        book: PriceBook, nprobe: int = 32,
                        seed: int = 0) -> TierOutcome:
    """Run one split on the shared eval index and measure it."""
    params = SearchParams(k=w.k, nprobe=min(nprobe, index.meta.n_lists))
    cfg = _tier_fleet_cfg(w, env, split, scale, index.meta.index_bytes,
                          seed)
    partition = ClusterPartition.build(index.meta.list_nbytes,
                                       split.n_shards, 1)
    rep = FleetRouter(index, cfg, partition=partition).run(queries, params)
    nv_hits = nv_misses = 0
    for s in rep.shard_stats or []:
        nv = getattr(s, "nvme", None)
        if nv:
            nv_hits += nv["hits"]
            nv_misses += nv["misses"]
    return TierOutcome(
        split=split, usd_per_hour=split.usd_per_hour(book), qps=rep.qps,
        p99_s=rep.latency_percentile(99), recall=rep.recall_against(gt),
        hit_dram=rep.hit_rate,
        hit_nvme_frac=(nv_hits / (nv_hits + nv_misses)
                       if nv_hits + nv_misses else 0.0),
        eval_n=index.meta.n_data)


def tune_tier_split(w: WorkloadSpec, env: EnvSpec,
                    budget_usd_per_hour: float, *,
                    book: PriceBook | None = None,
                    widths: tuple[int, ...] = TIER_WIDTH_GRID,
                    steps: int = 6, refine_top: int = 3,
                    mrc: dict | None = None, eval_n: int = 1200,
                    nq: int = 48, nprobe: int = 32,
                    seed: int = 0) -> TierSplitRecommendation:
    """Split a fixed $/h budget across fleet width, DRAM and NVMe.

    Screens every budget-spending split analytically, then re-prices
    the top ``refine_top`` with real tiered fleet runs; the pick is the
    measured-p99 winner among refined splits meeting the workload's
    recall floor (ties: fewer machines).  ``mrc`` accepts a measured
    miss-ratio curve (``{"sizes": [...], "miss_ratio": [...]}`` from
    ``repro.obs.mrc``) in place of the Che screen.
    """
    book = book or PriceBook()
    if mrc is not None:
        mrc = resolve_mrc_curve(mrc)
    index, queries, gt = _eval_index(w, eval_n, nq, seed)
    profile = {} if mrc is not None else \
        fleet_access_profile(index, queries, nprobe)
    scale = index.meta.index_bytes / max(w.n * w.vector_bytes, 1)
    splits = enumerate_tier_splits(budget_usd_per_hour, book,
                                   widths=widths, steps=steps)
    screened = screen_tier_splits(profile, splits, book,
                                  remote_spec=env.storage, scale=scale,
                                  mrc=mrc)
    refined = [evaluate_tier_split(
        w, env, p.split, index, queries, gt, scale=scale, book=book,
        nprobe=nprobe, seed=seed)
        for p in screened[:max(refine_top, 1)]]
    feas = [o for o in refined if o.recall >= w.target_recall - 0.005]
    if feas:
        pick = min(feas, key=lambda o: (o.p99_s, o.split.n_shards))
        feasible = True
    else:
        pick = max(refined, key=lambda o: (o.recall, -o.p99_s))
        feasible = False
    return TierSplitRecommendation(
        workload=w, env_storage=env.storage.name,
        budget_usd_per_hour=budget_usd_per_hour, pricebook=book.name,
        split=pick.split, feasible=feasible, screened=screened,
        refined=refined)
