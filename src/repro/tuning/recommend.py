"""Stage 3 of the tuner: frontier extraction and the final recommendation.

Selection is *knee-with-slack*: among configs that meet the recall target,
all configs within ``QPS_SLACK`` of the best QPS are considered tied and
the tie breaks toward higher recall (then fewer storage bytes).  This is
what reproduces the paper's cloud-vs-SSD parameter gap: on cloud storage
the TTFB floor makes QPS nearly flat in nprobe, so the slack band is wide
and the tuner buys recall headroom with a much larger nprobe; on local
SSD every extra probe costs real latency, the band is narrow, and the
minimal feasible nprobe wins (§5.2, Figs 18–19).
"""
from __future__ import annotations

import dataclasses
import json

from repro.tuning import evaluate as ev
from repro.tuning import screen as scr
from repro.tuning.pareto import pareto_frontier
from repro.tuning.space import (Candidate, EnvSpec, WorkloadSpec,
                                enumerate_space)

QPS_SLACK = 0.10                     # "tied" band around the best QPS


@dataclasses.dataclass
class Recommendation:
    """Typed tuner output: one pick plus the evidence around it."""

    workload: WorkloadSpec
    env_storage: str
    cache_bytes: int
    config: Candidate
    pred_recall: float               # recall estimate for the pick
    pred_qps: float                  # full-scale QPS estimate for the pick
    hit_rate: float
    feasible: bool                   # pick meets the recall target
    frontier: list[dict]             # recall-vs-QPS Pareto points
    screen_total: int
    screen_kept: int
    simulated: int                   # configs actually run through the sim
    tips: list[str]

    @property
    def prune_fraction(self) -> float:
        return 1.0 - self.screen_kept / max(1, self.screen_total)

    def to_dict(self) -> dict:
        return dict(
            workload=dataclasses.asdict(self.workload),
            environment=dict(storage=self.env_storage,
                             cache_bytes=self.cache_bytes),
            recommendation=self.config.to_dict(),
            pred_recall=round(self.pred_recall, 4),
            pred_qps=round(self.pred_qps, 2),
            hit_rate=round(self.hit_rate, 4),
            meets_target=self.feasible,
            pareto_frontier=self.frontier,
            screen=dict(total=self.screen_total, kept=self.screen_kept,
                        prune_fraction=round(self.prune_fraction, 4)),
            simulated=self.simulated,
            tips=self.tips,
        )

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)


def _tips(w: WorkloadSpec, env: EnvSpec, c: Candidate) -> list[str]:
    """Paper-rule rationale for the *chosen* config — each tip explains a
    knob value the tuner actually picked, never counter-recommends."""
    tips = []
    cloudy = env.storage.ttfb_p50_s > 1e-3
    if c.kind == "cluster":
        if c.centroid_frac >= 0.32:
            tips.append("fine-grained lists (centroid% ~32) chosen for "
                        "the I/O-congested regime (paper Fig 14)")
        if c.num_replica >= 8:
            tips.append("replica=8 keeps boundary-vector recall quality "
                        "(paper Fig 16)")
        elif c.cache_policy != "none":
            tips.append("fewer replicas shrink the working set and raise "
                        "cache hit rate (paper Fig 24)")
        if cloudy and c.nprobe >= 64:
            tips.append("large nprobe is nearly free under the cloud "
                        "TTFB floor — recall headroom bought cheaply "
                        "(paper SS5.2)")
    else:
        if c.R >= 64:
            tips.append("dense graph (R>=64) suits cloud serving "
                        "(paper Fig 17)")
        if c.beamwidth >= 32:
            tips.append("wide beam (W>=32) cuts roundtrips on the TTFB "
                        "floor (paper Fig 19)")
        elif cloudy:
            tips.append("beamwidth kept <=16 under the GET-rate ceiling "
                        "(paper Fig 19f)")
        if c.cache_policy == "pinned":
            tips.append("pin the entry-point neighbourhood — early rounds "
                        "carry near-1 hit rates (paper Fig 23, A3)")
    return tips


def _pick(entries: list[tuple[Candidate, float, float, float, bool]],
          target_recall: float
          ) -> tuple[Candidate, float, float, float, bool]:
    """Knee-with-slack over (cand, recall, qps, hit_rate, feasible).

    Pool preference: configs that strictly meet the recall target, then
    margin-feasible ones (screen tolerance), then everything — so the
    tuner only recommends a near-miss when nothing truly reaches the
    target."""
    strict = [e for e in entries if e[1] >= target_recall]
    margin = [e for e in entries if e[4]]
    pool = strict or margin or entries
    best_qps = max(e[2] for e in pool)
    band = [e for e in pool if e[2] >= (1.0 - QPS_SLACK) * best_qps]
    # inside the band: max recall, then max qps
    return max(band, key=lambda e: (e[1], e[2]))


def autotune(workload: WorkloadSpec, env: EnvSpec,
             budget: ev.EvalBudget | str | None = None,
             kinds: tuple[str, ...] = ("cluster", "graph"),
             seed: int = 0) -> Recommendation:
    """Search the joint config space for (workload, env).

    ``budget="screen"`` skips simulation (pure analytic answer, fast);
    otherwise screen survivors are refined by successive halving on the
    real engine + storage simulator.
    """
    cands = enumerate_space(workload, env, kinds=kinds)
    result = scr.screen(workload, env, cands)
    screened = result.kept

    outcomes: list[ev.EvalOutcome] = []
    if budget != "screen":
        eb = budget if isinstance(budget, ev.EvalBudget) else \
            ev.default_budget(workload, seed=seed)
        outcomes = ev.successive_halving(workload, env, screened, eb)

    # unified (cand, recall, qps, hit_rate, feasible) entries: simulated
    # outcomes override their screen predictions.
    simulated_keys = {tuple(sorted(o.cand.to_dict().items()))
                      for o in outcomes}
    entries = [(o.cand, o.recall_est, o.final.pred_qps, o.hit_rate,
                o.final.feasible) for o in outcomes]
    entries += [(p.cand, p.pred_recall, p.pred_qps, p.hit_rate, p.feasible)
                for p in screened
                if tuple(sorted(p.cand.to_dict().items()))
                not in simulated_keys]

    cand, rec, qps, hr, _ = _pick(entries, workload.target_recall)
    # report target attainment strictly: the screening margin is a search
    # tolerance, not something to promise the user.
    feas = rec >= workload.target_recall - 0.005
    front = pareto_frontier(entries, recall_of=lambda e: e[1],
                            qps_of=lambda e: e[2])
    frontier = [dict(config=e[0].to_dict(), recall=round(e[1], 4),
                     qps=round(e[2], 2),
                     simulated=tuple(sorted(e[0].to_dict().items()))
                     in simulated_keys)
                for e in front]
    return Recommendation(
        workload=workload, env_storage=env.storage.name,
        cache_bytes=env.cache_bytes, config=cand,
        pred_recall=rec, pred_qps=qps, hit_rate=hr, feasible=feas,
        frontier=frontier, screen_total=result.n_total,
        screen_kept=len(screened), simulated=len(outcomes),
        tips=_tips(workload, env, cand))
