"""Recall-vs-QPS Pareto frontier extraction.

A point dominates another when it is at least as good on both axes and
strictly better on one.  The frontier is returned sorted by recall
ascending (so it reads as the paper's QPS–recall curves, Figs 7/18).
"""
from __future__ import annotations

from typing import Callable, Sequence, TypeVar

T = TypeVar("T")


def pareto_frontier(points: Sequence[T],
                    recall_of: Callable[[T], float],
                    qps_of: Callable[[T], float]) -> list[T]:
    """Maximal (recall, qps) points, sorted by recall ascending.

    Ties collapse to a single representative (the first seen), so the
    frontier never contains two points with identical coordinates.
    """
    # sort by recall desc, qps desc: a point is on the frontier iff its
    # qps strictly exceeds the best qps seen at any higher-or-equal recall.
    order = sorted(range(len(points)),
                   key=lambda i: (-recall_of(points[i]), -qps_of(points[i])))
    frontier: list[T] = []
    best_qps = float("-inf")
    for i in order:
        p = points[i]
        if qps_of(p) > best_qps:
            frontier.append(p)
            best_qps = qps_of(p)
    frontier.reverse()
    return frontier


def hypervolume(points: Sequence[T],
                recall_of: Callable[[T], float],
                qps_of: Callable[[T], float],
                ref_recall: float = 0.0, ref_qps: float = 0.0) -> float:
    """Dominated-area indicator vs a reference corner (frontier quality)."""
    front = pareto_frontier(points, recall_of, qps_of)
    area = 0.0
    prev_r = ref_recall
    for p in front:                       # recall ascending
        r, q = recall_of(p), qps_of(p)
        if r <= prev_r or q <= ref_qps:
            continue
        area += (r - prev_r) * (q - ref_qps)
        prev_r = r
    return area
