"""Stage 2 of the tuner: successive-halving refinement on real components.

Screen survivors are run through the *actual* serving stack — index build
(``core/cluster_index.py`` / ``core/graph_index.py``), the discrete-event
storage simulator, and the segment cache — on subsampled synthetic data
(``data/synth.py``) matching the workload's dim/dtype.  Measured recall
and measured cache hit rate then re-price each survivor at full workload
scale through the analytic model (``screen.predict``), replacing the
stage-1 priors with observations.

Scaling discipline (what transfers from a few-hundred-point analogue and
what does not):

* recall vs the search knob transfers (clustered low-intrinsic-dim data);
  when the eval index is too small to exercise a knob (nprobe clamped to
  the number of lists) the measurement is uninformative and the prior is
  kept — ``recall_est = min(measured, prior + 0.05)`` caps the small-scale
  optimism either way.
* graph out-degree is scaled down with the subsample (R/4) — degree ratios
  stay comparable; build passes drop to 1.  Builds are cached per
  ``Candidate.build_sig`` within a tuning run.
* the cache budget is scaled by the eval-to-full index-bytes ratio so
  *coverage* (the axis that drives policy behaviour) is preserved.
"""
from __future__ import annotations

import dataclasses
from collections import Counter

import numpy as np

from repro.core.cluster_index import ClusterIndex
from repro.core.flat import exact_topk
from repro.core.graph_index import GraphIndex
from repro.core.types import (ClusterIndexParams, GraphIndexParams,
                              QueryMetrics, SearchParams)
from repro.data.synth import DatasetSpec, make_dataset
from repro.serving.engine import EngineConfig, QueryEngine
from repro.serving.workload import sequential, zipf_repeated
from repro.tuning import screen as scr
from repro.tuning.space import Candidate, EnvSpec, WorkloadSpec


@dataclasses.dataclass(frozen=True)
class EvalBudget:
    """Successive-halving rungs: (subsample n, query count) per rung."""

    rungs: tuple[tuple[int, int], ...]
    max_rung0: int = 12          # configs entering rung 0
    min_promote: int = 3
    seed: int = 0


def default_budget(w: WorkloadSpec, seed: int = 0) -> EvalBudget:
    """Rung sizes scaled so graph builds stay seconds, not minutes."""
    if w.dim >= 512:
        rungs = ((500, 24), (900, 36))
    else:
        rungs = ((1500, 40), (3000, 56))
    return EvalBudget(rungs=rungs, seed=seed)


@dataclasses.dataclass
class EvalOutcome:
    pred: scr.Prediction                 # stage-1 screen entry
    measured_recall: float
    measured_qps: float                  # virtual-time QPS at eval scale
    hit_rate: float
    recall_est: float                    # blended (see module docstring)
    final: scr.Prediction                # full-scale re-prediction
    rung: int
    eval_n: int

    @property
    def cand(self) -> Candidate:
        return self.pred.cand

    def to_dict(self) -> dict:
        return dict(config=self.cand.to_dict(),
                    measured_recall=round(self.measured_recall, 4),
                    measured_qps_eval=round(self.measured_qps, 2),
                    measured_hit_rate=round(self.hit_rate, 4),
                    recall_est=round(self.recall_est, 4),
                    qps_full_scale=round(self.final.pred_qps, 2),
                    feasible=self.final.feasible,
                    rung=self.rung, eval_n=self.eval_n)


# ---------------------------------------------------------------- data ---

class _Rung:
    """One subsample scale: dataset + ground truth + per-build index cache."""

    def __init__(self, w: WorkloadSpec, n: int, nq: int, seed: int):
        n = min(n, w.n)
        self.n = n
        spec = DatasetSpec(
            "tuner-analog", w.dim, w.dtype, n, nq,
            n_clusters=max(8, min(64, n // 16)),
            intrinsic_dim=min(32, w.dim), seed=seed)
        self.data, self.queries = make_dataset(spec)
        self.gt, _ = exact_topk(self.data, self.queries, w.k)
        self._indexes: dict[tuple, object] = {}
        self.seed = seed

    def index_for(self, c: Candidate):
        sig = c.build_sig()
        if sig in self._indexes:
            return self._indexes[sig]
        if c.kind == "cluster":
            idx = ClusterIndex.build(self.data, ClusterIndexParams(
                centroid_frac=c.centroid_frac, num_replica=c.num_replica,
                kmeans_iters=4, seed=self.seed))
        else:
            R_eval = max(12, c.R // 4)
            from repro.core.pq import default_pq_dims
            idx = GraphIndex.build(self.data, GraphIndexParams(
                R=R_eval, L_build=max(24, 2 * R_eval), build_passes=1,
                pq_dims=default_pq_dims(self.data.shape[1]),
                seed=self.seed))
        self._indexes[sig] = idx
        return idx


def _search_params(w: WorkloadSpec, c: Candidate, index) -> SearchParams:
    if c.kind == "cluster":
        return SearchParams(k=w.k, nprobe=min(c.nprobe, index.meta.n_lists))
    return SearchParams(k=w.k, search_len=c.search_len,
                        beamwidth=c.beamwidth)


def _workload_stream(w: WorkloadSpec, queries: np.ndarray, seed: int
                     ) -> tuple[np.ndarray, np.ndarray]:
    if w.query_dist == "zipf":
        return zipf_repeated(queries, n_total=3 * len(queries),
                             a=w.zipf_a, seed=seed)
    return sequential(queries)


def hot_keys(index, queries: np.ndarray, params: SearchParams,
             budget_bytes: int, n_warmup: int = 12) -> frozenset:
    """Frequency-ranked fetch keys from a warmup slice, greedily packed
    into the byte budget — the pinned policy's fixed content."""
    freq: Counter = Counter()
    sizes: dict = {}
    for q in queries[: n_warmup]:
        gen = index.search_plan(q, params, QueryMetrics())
        try:
            batch = next(gen)
            while True:
                for rq in batch.requests:
                    freq[rq.key] += 1
                    sizes[rq.key] = rq.nbytes
                batch = gen.send({rq.key: index.store.get(rq.key)
                                  for rq in batch.requests})
        except StopIteration:
            pass
    picked = []
    used = 0
    for key, _ in freq.most_common():
        nb = sizes[key]
        if used + nb > budget_bytes:
            continue
        picked.append(key)
        used += nb
    return frozenset(picked)


def evaluate_candidate(w: WorkloadSpec, env: EnvSpec, pred: scr.Prediction,
                       rung: _Rung, rung_idx: int) -> EvalOutcome:
    """Build (or reuse), simulate, measure, and re-price one candidate."""
    c = pred.cand
    index = rung.index_for(c)
    params = _search_params(w, c, index)
    stream_q, stream_ids = _workload_stream(w, rung.queries, rung.seed)

    # preserve cache *coverage* at eval scale
    cache_eval = 0
    pinned: frozenset | None = None
    if c.cache_policy != "none" and env.cache_bytes > 0:
        full_bytes = scr.index_bytes(w, c)
        cache_eval = int(env.cache_bytes
                         * index.meta.index_bytes / max(full_bytes, 1.0))
        cache_eval = min(cache_eval, index.meta.index_bytes)
        if c.cache_policy == "pinned":
            pinned = hot_keys(index, stream_q, params, cache_eval)

    cfg = EngineConfig(
        storage=env.storage, concurrency=min(w.concurrency, len(stream_q)),
        cache_bytes=cache_eval, cache_policy=c.cache_policy,
        pinned_keys=pinned, seed=rung.seed)
    eng = QueryEngine(index, cfg)
    if c.cache_policy == "slru" and cache_eval > 0:
        # steady-state measurement: one warm-up pass fills the cache so
        # SLRU isn't charged its compulsory cold misses against the
        # pinned policy, whose set is prefilled from its own warm-up.
        # (Pinned contents are fixed — a warm-up pass would be a no-op.)
        eng.run(stream_q, params)
    rep = eng.run(stream_q, params, query_ids=stream_ids)

    measured_recall = rep.recall_against(rung.gt)
    hit_rate = rep.hit_rate
    # a saturated measurement (probing ~every list / visiting ~the whole
    # graph, or recall pegged at ~1 by the small scale) carries no signal
    # about full-scale recall: fall back to the prior.  An unsaturated
    # measurement is informative both ways — it can veto an optimistic
    # prior outright, or lift a pessimistic one by at most 0.05.
    saturated = measured_recall >= 0.995 or (
        c.nprobe >= index.meta.n_lists if c.kind == "cluster"
        else c.search_len >= rung.n)
    if saturated:
        recall_est = min(measured_recall, pred.pred_recall)
    else:
        recall_est = min(measured_recall, pred.pred_recall + 0.05)
    final = scr.predict(w, env, c, hit_rate=hit_rate, recall=recall_est)
    return EvalOutcome(pred=pred, measured_recall=measured_recall,
                       measured_qps=rep.qps, hit_rate=hit_rate,
                       recall_est=recall_est, final=final,
                       rung=rung_idx, eval_n=rung.n)


def trace_candidate(w: WorkloadSpec, env: EnvSpec, cand: Candidate, *,
                    eval_n: int = 800, nq: int = 32, seed: int = 0,
                    tracer=None):
    """Re-run one (typically: the recommended) candidate with a tracer
    attached, using the same rung recipe as :func:`evaluate_candidate`.

    The halving sweep stays untraced — spans from discarded configs are
    noise; the single validation rerun shows where the winner's time
    goes.  Returns the engine report; the spans land in ``tracer``.
    """
    rung = _Rung(w, eval_n, nq, seed)
    index = rung.index_for(cand)
    params = _search_params(w, cand, index)
    stream_q, stream_ids = _workload_stream(w, rung.queries, rung.seed)
    cache_eval = 0
    pinned: frozenset | None = None
    if cand.cache_policy != "none" and env.cache_bytes > 0:
        full_bytes = scr.index_bytes(w, cand)
        cache_eval = int(env.cache_bytes
                         * index.meta.index_bytes / max(full_bytes, 1.0))
        cache_eval = min(cache_eval, index.meta.index_bytes)
        if cand.cache_policy == "pinned":
            pinned = hot_keys(index, stream_q, params, cache_eval)
    cfg = EngineConfig(
        storage=env.storage, concurrency=min(w.concurrency, len(stream_q)),
        cache_bytes=cache_eval, cache_policy=cand.cache_policy,
        pinned_keys=pinned, seed=rung.seed)
    eng = QueryEngine(index, cfg)
    return eng.run(stream_q, params, query_ids=stream_ids, tracer=tracer)


def _score(o: EvalOutcome) -> tuple:
    """Feasible first, then full-scale QPS, then recall headroom."""
    return (o.final.feasible, o.final.pred_qps, o.recall_est)


def successive_halving(w: WorkloadSpec, env: EnvSpec,
                       screened: list[scr.Prediction],
                       budget: EvalBudget | None = None
                       ) -> list[EvalOutcome]:
    """Run survivors through progressively larger simulations, halving the
    cohort between rungs.  Returns the latest outcome per candidate."""
    budget = budget or default_budget(w)
    cohort = sorted(screened, key=lambda p: -p.pred_qps)[: budget.max_rung0]
    latest: dict[tuple, EvalOutcome] = {}
    for ri, (n_sub, nq) in enumerate(budget.rungs):
        if not cohort:
            break
        rung = _Rung(w, n_sub, nq, seed=budget.seed + ri)
        outcomes = [evaluate_candidate(w, env, p, rung, ri) for p in cohort]
        for o in outcomes:
            latest[tuple(sorted(o.cand.to_dict().items()))] = o
        outcomes.sort(key=_score, reverse=True)
        n_next = max(budget.min_promote, len(outcomes) // 2)
        cohort = [o.pred for o in outcomes[:n_next]]
    return list(latest.values())
