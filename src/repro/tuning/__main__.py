"""CLI entry: ``python -m repro.tuning`` → JSON recommendation on stdout.

Example (the paper's agentic-RAG-style workload on cloud object storage):

    python -m repro.tuning --recall 0.95 --concurrency 64 --dim 960 \
        --storage tos --cache-gb 4
"""
from __future__ import annotations

import argparse
import sys

from repro.tuning.evaluate import EvalBudget
from repro.tuning.recommend import autotune
from repro.tuning.space import (STORAGE_ALIASES, EnvSpec, WorkloadSpec,
                                resolve_storage)


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.tuning",
        description="Auto-tune index class, build/search params and cache "
                    "policy for a workload + storage environment.")
    p.add_argument("--n", type=int, default=1_000_000,
                   help="dataset cardinality (default 1M)")
    p.add_argument("--dim", type=int, default=960)
    p.add_argument("--dtype", choices=["float32", "int8"], default="float32")
    p.add_argument("--recall", type=float, default=0.9,
                   help="target recall@k")
    p.add_argument("--concurrency", type=int, default=1)
    p.add_argument("--dist", choices=["sequential", "zipf"],
                   default="sequential", help="query distribution")
    p.add_argument("--zipf-a", type=float, default=1.2)
    p.add_argument("--k", type=int, default=10)
    p.add_argument("--storage", default="tos",
                   help="storage preset: %s or a full preset name"
                        % "/".join(sorted(STORAGE_ALIASES)))
    p.add_argument("--cache-gb", type=float, default=0.0,
                   help="compute-node cache budget in GiB")
    p.add_argument("--budget", choices=["screen", "quick", "full"],
                   default="quick",
                   help="screen = analytic only; quick = small simulation "
                        "rungs; full = default rungs")
    p.add_argument("--kinds", default="cluster,graph",
                   help="comma-separated index kinds to consider")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--compact", action="store_true",
                   help="single-line JSON output")
    return p


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    w = WorkloadSpec(n=args.n, dim=args.dim, dtype=args.dtype,
                     target_recall=args.recall,
                     concurrency=args.concurrency, query_dist=args.dist,
                     zipf_a=args.zipf_a, k=args.k)
    try:
        storage = resolve_storage(args.storage)
    except KeyError as e:
        build_parser().error(str(e.args[0]))
    env = EnvSpec(storage=storage,
                  cache_bytes=int(args.cache_gb * 2**30))
    if args.budget == "screen":
        budget: EvalBudget | str = "screen"
    elif args.budget == "quick":
        rungs = ((400, 20), (800, 32)) if args.dim >= 512 \
            else ((1200, 32), (2400, 48))
        budget = EvalBudget(rungs=rungs, max_rung0=10, seed=args.seed)
    else:
        budget = None                      # default_budget inside autotune
    rec = autotune(w, env, budget=budget, kinds=tuple(
        k.strip() for k in args.kinds.split(",") if k.strip()))
    print(rec.to_json(indent=None if args.compact else 2))
    return 0


if __name__ == "__main__":
    sys.exit(main())
