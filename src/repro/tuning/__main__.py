"""CLI entry: ``python -m repro.tuning`` → JSON recommendation on stdout.

Two modes:

* **index tuning** (default): pick index class, build/search params and
  cache policy for a workload + storage environment.

      python -m repro.tuning --recall 0.95 --concurrency 64 --dim 960 \\
          --storage tos --cache-gb 4

* **fleet sizing** (``--fleet``): pick shards × replication.  With the
  default closed-loop scenario the target is a speedup over one shard;
  with an open-loop scenario (``--scenario poisson/burst/trace``) the
  fleet is sized for an **offered load + SLO** — the cheapest fleet whose
  goodput under ``--slo-ms`` meets ``--goodput``.

      python -m repro.tuning --fleet --scenario poisson --rate 400 \\
          --duration 1 --slo-ms 50

* **batch-window tuning** (``--tune-window``): sweep the kernel
  execution backend's per-shard batch-coalescing window on a fixed
  fleet point and map the occupancy vs p99 frontier.  Both fleet modes
  also accept ``--backend kernel`` to price the sweep from a measured
  CalibrationTable instead of the analytic ComputeSpec constants.

      python -m repro.tuning --tune-window --scenario poisson --rate 400

* **cache-split tuning** (``--tune-split``): split a shared cache
  budget across tenants.  The analytic screen prices candidates from
  Che-approximation curves, or — with ``--mrc-curves`` — from measured
  miss-ratio curves written by a live ``--mrc``-profiled fleet run
  (docs/observability.md).

      python -m repro.tuning --tune-split --tenants tenants.json \\
          --cache-gb 0.004 --mrc-curves mrc.json

* **tier-split tuning** (``--tune-tier``): split a fixed $/hour budget
  across fleet width, DRAM cache and the local NVMe tier
  (docs/storage.md).  The screen prices per-tier hit rates from the
  workload's access profile (or ``--mrc-curves``) and a price book;
  the top candidates are re-priced on real tiered fleet runs.

      python -m repro.tuning --tune-tier --budget-usd-hour 2.0 \\
          --pricebook default
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

from repro.cli import (add_common_args, add_exec_args, add_monitor_args,
                       add_obs_args, add_scenario_args, emit_json,
                       emit_obs, exec_fields_from_args, monitor_from_args,
                       pricebook_from_args, scenario_from_args,
                       tracer_from_args)
from repro.tuning.evaluate import EvalBudget
from repro.tuning.fleet import (tune_batch_window, tune_fleet,
                                tune_fleet_for_load)
from repro.tuning.recommend import autotune
from repro.tuning.space import (STORAGE_ALIASES, EnvSpec, WorkloadSpec,
                                resolve_storage)


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.tuning",
        description="Auto-tune index class, build/search params and cache "
                    "policy for a workload + storage environment; with "
                    "--fleet, size a serving fleet (optionally for an "
                    "open-loop offered load + SLO).")
    p.add_argument("--n", type=int, default=1_000_000,
                   help="dataset cardinality (default 1M)")
    p.add_argument("--dim", type=int, default=960)
    p.add_argument("--dtype", choices=["float32", "int8"], default="float32")
    p.add_argument("--recall", type=float, default=0.9,
                   help="target recall@k")
    p.add_argument("--concurrency", type=int, default=1)
    p.add_argument("--dist", choices=["sequential", "zipf"],
                   default="sequential", help="query distribution")
    p.add_argument("--zipf-a", type=float, default=1.2,
                   help="zipf exponent for --dist zipf")
    p.add_argument("--k", type=int, default=10)
    p.add_argument("--storage", default="tos",
                   help="storage preset: %s or a full preset name"
                        % "/".join(sorted(STORAGE_ALIASES)))
    p.add_argument("--cache-gb", type=float, default=0.0,
                   help="compute-node cache budget in GiB")
    p.add_argument("--budget", choices=["screen", "quick", "full"],
                   default="quick",
                   help="screen = analytic only; quick = small simulation "
                        "rungs; full = default rungs")
    p.add_argument("--kinds", default="cluster,graph",
                   help="comma-separated index kinds to consider")
    # fleet sizing mode
    p.add_argument("--fleet", action="store_true",
                   help="size a fleet (shards x replication) instead of "
                        "tuning index knobs")
    p.add_argument("--target-speedup", type=float, default=2.0,
                   help="closed-loop fleet target: speedup over 1 shard")
    p.add_argument("--goodput", type=float, default=0.99,
                   help="open-loop fleet target: min fraction of arrivals "
                        "served within the SLO")
    p.add_argument("--hedge", action="store_true",
                   help="consider hedged fleets (R >= 2 points)")
    p.add_argument("--tune-window", action="store_true",
                   help="sweep the kernel backend's batch-coalescing "
                        "window on a fixed fleet point and map the "
                        "occupancy vs p99 frontier (docs/execution.md)")
    g = p.add_argument_group("cache-split tuning (--tune-split)")
    g.add_argument("--tune-split", action="store_true",
                   help="split the --cache-gb budget across --tenants: "
                        "analytic screen + refinement on real static-"
                        "policy fleet runs (docs/tenancy.md)")
    g.add_argument("--tenants", default=None, metavar="SPEC.JSON",
                   help="tenant spec file (same schema as python -m "
                        "repro.fleet --tenants)")
    g.add_argument("--mrc-curves", default=None, metavar="MRC.JSON",
                   help="price the split screen from measured miss-"
                        "ratio curves (an artifact written by a fleet "
                        "run's --mrc PATH) instead of the analytic "
                        "Che-approximation profiles")
    g.add_argument("--split-steps", type=int, default=8,
                   help="screen granularity: simplex steps per tenant")
    g.add_argument("--refine-top", type=int, default=3,
                   help="screen candidates to refine on real runs")
    g.add_argument("--shards", type=int, default=2,
                   help="fleet point for the refinement runs")
    g.add_argument("--replicas", type=int, default=1,
                   help="fleet point for the refinement runs")
    t = p.add_argument_group("tier-split tuning (--tune-tier)")
    t.add_argument("--tune-tier", action="store_true",
                   help="split a fixed $/hour budget across fleet width, "
                        "DRAM cache and the local NVMe tier: analytic "
                        "screen + refinement on real tiered fleet runs "
                        "(docs/storage.md)")
    t.add_argument("--budget-usd-hour", type=float, default=0.0,
                   metavar="USD",
                   help="the hourly budget to split (required; priced "
                        "with --pricebook, default price book otherwise)")
    t.add_argument("--tier-steps", type=int, default=6,
                   help="screen granularity: DRAM-share steps per width")
    t.add_argument("--tier-widths", default="1,2,4", metavar="W,W,...",
                   help="fleet widths the screen considers")
    add_exec_args(p)
    add_scenario_args(p, faults=False)
    add_obs_args(p)
    add_monitor_args(p)
    add_common_args(p)
    return p


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    w = WorkloadSpec(n=args.n, dim=args.dim, dtype=args.dtype,
                     target_recall=args.recall,
                     concurrency=args.concurrency, query_dist=args.dist,
                     zipf_a=args.zipf_a, k=args.k,
                     write_rate_qps=args.write_rate)
    try:
        storage = resolve_storage(args.storage)
    except KeyError as e:
        build_parser().error(str(e.args[0]))
    env = EnvSpec(storage=storage,
                  cache_bytes=int(args.cache_gb * 2**30))

    tracer = tracer_from_args(args)
    parser = build_parser()
    monitor = monitor_from_args(args, parser)
    pricebook = pricebook_from_args(args, parser)
    if monitor is not None and not args.fleet:
        parser.error("--monitor applies to the fleet-sizing validation "
                     "rerun; add --fleet (index tuning has no serving "
                     "run to monitor)")
    if pricebook is not None and not (args.fleet or args.tune_tier):
        parser.error("--pricebook applies to the fleet-sizing validation "
                     "rerun or the --tune-tier budget screen; add --fleet "
                     "or --tune-tier")
    if monitor is not None and monitor.recall_target is not None:
        parser.error("--recall-slo is a serving-run knob (python -m "
                     "repro.fleet); the sizing rerun has no precomputed "
                     "ground truth to judge live recall against")
    if args.tune_split:
        if args.fleet or args.tune_window or args.tune_tier:
            parser.error("--tune-split is its own mode; drop --fleet/"
                         "--tune-window/--tune-tier")
        if not args.tenants:
            parser.error("--tune-split needs --tenants SPEC.JSON")
        if args.cache_gb <= 0:
            parser.error("--tune-split splits the --cache-gb budget; "
                         "give a budget > 0")
    elif args.tenants:
        parser.error("--tenants belongs to --tune-split")
    elif args.mrc_curves and not args.tune_tier:
        parser.error("--mrc-curves belongs to --tune-split/--tune-tier")
    if args.tune_tier:
        if args.fleet or args.tune_window:
            parser.error("--tune-tier is its own mode; drop --fleet/"
                         "--tune-window")
        if args.budget_usd_hour <= 0:
            parser.error("--tune-tier splits an hourly dollar budget; "
                         "give --budget-usd-hour > 0")
        if args.cache_gb:
            parser.error("--cache-gb conflicts with --tune-tier (the "
                         "DRAM budget is a tuned output, priced from "
                         "--budget-usd-hour)")
    elif args.budget_usd_hour:
        parser.error("--budget-usd-hour belongs to --tune-tier")
    exec_kw = None
    if args.tune_window:
        if args.batch_window_us:
            parser.error("--batch-window-us conflicts with --tune-window "
                         "(the window is the swept axis)")
        if args.fleet:
            parser.error("--tune-window sweeps one fixed fleet point; "
                         "drop --fleet (size the fleet first, then tune "
                         "its window)")
    else:
        fields = exec_fields_from_args(args, parser)
        if args.backend == "kernel":
            if not args.fleet and not args.tune_split:
                parser.error("--backend kernel applies to fleet sweeps; "
                             "add --fleet (or --tune-window; the index "
                             "tuner has no serving fleet to price)")
            exec_kw = fields
    from repro.obs import run_manifest

    if args.tune_split:
        import json as _json

        from repro.fleet import FleetConfig
        from repro.tenancy import load_tenant_specs
        from repro.tuning.tenancy import tune_cache_split
        specs = load_tenant_specs(args.tenants)
        mrc = None
        if args.mrc_curves:
            with open(args.mrc_curves) as f:
                mrc = _json.load(f)
        cfg = FleetConfig(
            n_shards=args.shards, replication=args.replicas,
            storage=storage, concurrency=args.concurrency,
            cache_bytes=env.cache_bytes, cache_policy="slru",
            seed=args.seed, **fields)
        t0 = time.perf_counter()
        rec = tune_cache_split(specs, cfg, steps=args.split_steps,
                               refine_top=args.refine_top, mrc=mrc)
        out = rec.to_dict()
        out["meta"] = run_manifest(
            seed=args.seed,
            config=dict(mode="cache-split", tenants=args.tenants,
                        mrc_curves=args.mrc_curves,
                        cache_bytes=env.cache_bytes),
            wall_s=time.perf_counter() - t0)
        emit_json(out, args)
        return 0

    if args.tune_tier:
        import json as _json

        from repro.tuning.tier import tune_tier_split
        mrc = None
        if args.mrc_curves:
            with open(args.mrc_curves) as f:
                mrc = _json.load(f)
        try:
            widths = tuple(int(x) for x in args.tier_widths.split(",")
                           if x.strip())
            if not widths:
                raise ValueError
        except ValueError:
            parser.error("--tier-widths wants comma-separated ints, got "
                         f"{args.tier_widths!r}")
        t0 = time.perf_counter()
        try:
            rec = tune_tier_split(
                w, env, args.budget_usd_hour, book=pricebook,
                widths=widths, steps=args.tier_steps,
                refine_top=args.refine_top, mrc=mrc, seed=args.seed)
        except ValueError as e:
            parser.error(str(e))
        out = rec.to_dict()
        out["meta"] = run_manifest(
            seed=args.seed,
            config=dict(mode="tier-split",
                        budget_usd_per_hour=args.budget_usd_hour,
                        pricebook=rec.pricebook,
                        mrc_curves=args.mrc_curves),
            wall_s=time.perf_counter() - t0)
        emit_json(out, args)
        return 0

    if args.tune_window:
        try:
            scenario = scenario_from_args(args)
        except ValueError as e:
            build_parser().error(str(e))
        t0 = time.perf_counter()
        rec = tune_batch_window(
            w, env,
            scenario=scenario if scenario.kind != "closed" else None,
            calibration=args.calibration, goodput_target=args.goodput,
            seed=args.seed)
        out = rec.to_dict()
        if tracer is not None:
            # traced validation rerun at the recommended window (the
            # sweep itself stays untraced; see trace_fleet_point)
            from repro.tuning.fleet import trace_fleet_point
            trace_fleet_point(
                w, env, rec.point, scenario=scenario, tracer=tracer,
                exec_kw=dict(backend="kernel",
                             batch_window_s=rec.window_us * 1e-6,
                             calibration=args.calibration),
                seed=args.seed)
        out["meta"] = run_manifest(
            seed=args.seed,
            config=dict(mode="batch-window", **dataclasses.asdict(w)),
            wall_s=time.perf_counter() - t0)
        emit_obs(out, args, tracer)
        emit_json(out, args)
        return 0

    if args.fleet:
        try:
            scenario = scenario_from_args(args)
        except ValueError as e:
            build_parser().error(str(e))
        t0 = time.perf_counter()
        if scenario.kind == "closed":
            rec = tune_fleet(w, env, target_speedup=args.target_speedup,
                             hedge=args.hedge, exec_kw=exec_kw,
                             seed=args.seed)
        else:
            rec = tune_fleet_for_load(w, env, scenario,
                                      goodput_target=args.goodput,
                                      hedge=args.hedge, exec_kw=exec_kw,
                                      seed=args.seed)
        out = rec.to_dict()
        if tracer is not None or monitor is not None \
                or pricebook is not None:
            # validation rerun of the winning point (the sweep itself
            # stays untraced/unmetered; see trace_fleet_point) — the
            # recommendation carries its alert log and dollar estimate
            from repro.tuning.fleet import trace_fleet_point
            vrep = trace_fleet_point(w, env, rec.point, scenario=scenario,
                                     tracer=tracer, monitor=monitor,
                                     pricebook=pricebook, exec_kw=exec_kw,
                                     seed=args.seed)
            if vrep.alerts is not None:
                out["alerts"] = vrep.alerts
            if vrep.cost is not None:
                out["cost"] = vrep.cost
        out["meta"] = run_manifest(
            seed=args.seed,
            config=dict(mode="fleet", **dataclasses.asdict(w)),
            wall_s=time.perf_counter() - t0)
        emit_obs(out, args, tracer)
        emit_json(out, args)
        return 0

    if args.budget == "screen":
        budget: EvalBudget | str = "screen"
    elif args.budget == "quick":
        rungs = ((400, 20), (800, 32)) if args.dim >= 512 \
            else ((1200, 32), (2400, 48))
        budget = EvalBudget(rungs=rungs, max_rung0=10, seed=args.seed)
    else:
        budget = None                      # default_budget inside autotune
    t0 = time.perf_counter()
    rec = autotune(w, env, budget=budget, kinds=tuple(
        k.strip() for k in args.kinds.split(",") if k.strip()))
    if tracer is not None:
        # traced validation rerun of the recommended config (the halving
        # sweep stays untraced; see trace_candidate)
        from repro.tuning.evaluate import trace_candidate
        trace_candidate(w, env, rec.config, tracer=tracer, seed=args.seed)
    out = rec.to_dict()
    if args.write_rate > 0:
        # the workload churns: also pick the compaction knobs for the
        # recommended index config (analytic screen; --budget != screen
        # refines the top points on the real engine)
        from repro.tuning.ingest import tune_ingest
        refine = 0 if args.budget == "screen" else 3
        out["ingest"] = tune_ingest(w, env, rec.config, refine=refine,
                                    seed=args.seed).to_dict()
    out["meta"] = run_manifest(
        seed=args.seed,
        config=dict(mode="index", budget=args.budget,
                    **dataclasses.asdict(w)),
        wall_s=time.perf_counter() - t0)
    emit_obs(out, args, tracer)
    emit_json(out, args)
    return 0


if __name__ == "__main__":
    sys.exit(main())
