"""Ingest tuning: the compaction knobs as a tunable axis.

A read-write workload adds three knobs — delta capacity, flush trigger
and compaction parallelism — whose trade surface is the classic LSM
one, priced here with the repo's cloud cost vocabulary:

* **write amplification** (analytic screen term): a flush rewrites
  every sealed object its delta touches, so small deltas pay the whole
  posting list per handful of new vectors while big deltas amortise —
  but big deltas seal late (freshness) and flush in storms (p99).
* **bandwidth share**: compaction reads + writes move through the same
  NIC/IOPS budget as queries; the screen derates predicted QPS by the
  share the write rate implies and rejects points whose compaction
  cannot keep up.
* **freshness**: the expected seal lag is fill-time + flush-time — the
  analytic mirror of the measured ``seal_lag`` in
  :class:`repro.ingest.metrics.IngestReport`.

``tune_ingest`` screens the grid analytically, optionally refines the
survivors on the real engine (a small rw run per point), and recommends
the freshest point within a QPS slack of the best — the same
knee-with-slack shape as the index tuner.
"""
from __future__ import annotations

import dataclasses

from repro.ingest.compaction import IngestConfig
from repro.ingest.memtable import ID_BYTES
from repro.tuning import screen as scr
from repro.tuning.space import Candidate, EnvSpec, WorkloadSpec

DELTA_CAP_GRID = (64 * 1024, 256 * 1024, 1024 * 1024)
FLUSH_FRAC_GRID = (0.3, 0.6, 0.9)
PARALLELISM_GRID = (1, 2)

#: fraction of the NIC compaction may consume before a point is ruled
#: infeasible (beyond this the delta grows without bound)
MAX_BANDWIDTH_SHARE = 0.5
#: QPS slack for the freshest-within-slack recommendation
QPS_SLACK = 0.05
#: fitted back-edge rewrite factor: a stitched insert rewrites about
#: ``0.4 R`` neighbour blocks (measured on the repo's graph flushes)
GRAPH_BACKEDGE_BETA = 0.4


@dataclasses.dataclass(frozen=True)
class IngestPoint:
    """One point of the compaction-knob grid."""

    delta_cap_bytes: int
    flush_frac: float = 0.5
    compaction_parallelism: int = 1

    def to_config(self, **overrides) -> IngestConfig:
        return IngestConfig(delta_cap_bytes=self.delta_cap_bytes,
                            flush_frac=self.flush_frac,
                            compaction_parallelism=(
                                self.compaction_parallelism),
                            **overrides)

    def to_dict(self) -> dict:
        return dict(delta_cap_bytes=self.delta_cap_bytes,
                    flush_frac=self.flush_frac,
                    compaction_parallelism=self.compaction_parallelism)


def enumerate_ingest_space() -> list[IngestPoint]:
    return [IngestPoint(cap, ff, par)
            for cap in DELTA_CAP_GRID
            for ff in FLUSH_FRAC_GRID
            for par in PARALLELISM_GRID]


# ------------------------------------------------------------ analytics --

def entry_nbytes(w: WorkloadSpec) -> int:
    return w.vector_bytes + ID_BYTES


def flush_batch_entries(w: WorkloadSpec, point: IngestPoint) -> float:
    """Delta entries per flush at the trigger point."""
    return max(1.0, point.flush_frac * point.delta_cap_bytes
               / entry_nbytes(w))


def analytic_write_amplification(w: WorkloadSpec, c: Candidate,
                                 point: IngestPoint) -> float:
    """Expected compaction bytes written per payload byte ingested.

    Cluster: a flush of ``E`` entries (each closure-replicated into
    ``rep_eff`` lists) rewrites the distinct lists it touches — the
    coupon-collector expectation ``L (1 − (1 − 1/L)^{E·rep})`` — at
    ``avg_list_bytes`` each.  Graph: every stitched insert writes its
    own block plus ~``0.4 R`` back-edge neighbour rewrites, with a mild
    dedup discount for bigger flush batches (shared targets)."""
    E = flush_batch_entries(w, point)
    eb = entry_nbytes(w)
    if c.kind == "cluster":
        n_lists, _, list_bytes = scr.cluster_stats(w, c)
        rep_eff = 1.0 + scr.REPLICATION_PER_REPLICA * c.num_replica
        touched = n_lists * (1.0 - (1.0 - 1.0 / n_lists)
                             ** (E * rep_eff))
        written = touched * (list_bytes + eb) + E * eb
        return written / (E * eb)
    node_b = scr.graph_node_bytes(w, c)
    blocks_per_insert = (1.0 + GRAPH_BACKEDGE_BETA * c.R) \
        * max(0.5, 1.0 - 0.04 * (E ** 0.5))
    return blocks_per_insert * node_b / eb


def compaction_bandwidth_share(w: WorkloadSpec, env: EnvSpec,
                               c: Candidate, point: IngestPoint) -> float:
    """Fraction of the storage NIC the steady-state write rate claims
    (reads before rewrite ≈ writes, hence the factor 2)."""
    if w.write_rate_qps <= 0:
        return 0.0
    wa = analytic_write_amplification(w, c, point)
    byte_rate = 2.0 * wa * w.write_rate_qps * entry_nbytes(w)
    return min(1.0, byte_rate / env.storage.bandwidth_Bps)


def analytic_seal_lag(w: WorkloadSpec, env: EnvSpec, c: Candidate,
                      point: IngestPoint) -> float:
    """Expected seal lag ≈ time to fill the delta to the trigger plus
    the flush's own I/O time."""
    if w.write_rate_qps <= 0:
        return 0.0
    E = flush_batch_entries(w, point)
    fill_s = E / w.write_rate_qps
    wa = analytic_write_amplification(w, c, point)
    flush_bytes = 2.0 * wa * E * entry_nbytes(w)
    flush_s = flush_bytes / env.storage.bandwidth_Bps \
        / max(1, point.compaction_parallelism)
    return fill_s / 2.0 + flush_s


@dataclasses.dataclass(frozen=True)
class IngestPrediction:
    point: IngestPoint
    write_amplification: float
    bandwidth_share: float
    pred_qps: float                 # derated by the compaction share
    pred_seal_lag_s: float
    feasible: bool

    def to_dict(self) -> dict:
        return dict(point=self.point.to_dict(),
                    write_amplification=round(self.write_amplification, 3),
                    bandwidth_share=round(self.bandwidth_share, 4),
                    pred_qps=round(self.pred_qps, 2),
                    pred_seal_lag_s=round(self.pred_seal_lag_s, 6),
                    feasible=self.feasible)


def screen_ingest(w: WorkloadSpec, env: EnvSpec, c: Candidate,
                  points: list[IngestPoint] | None = None
                  ) -> list[IngestPrediction]:
    """Analytic pass: derate the candidate's predicted QPS by each
    point's compaction bandwidth share; points whose compaction would
    saturate the NIC are infeasible.  Sorted best-QPS-first."""
    points = points if points is not None else enumerate_ingest_space()
    base = scr.predict(w, env, c)
    preds = []
    for point in points:
        wa = analytic_write_amplification(w, c, point)
        share = compaction_bandwidth_share(w, env, c, point)
        preds.append(IngestPrediction(
            point=point, write_amplification=wa, bandwidth_share=share,
            pred_qps=base.pred_qps * (1.0 - share),
            pred_seal_lag_s=analytic_seal_lag(w, env, c, point),
            feasible=share < MAX_BANDWIDTH_SHARE))
    preds.sort(key=lambda p: (-p.feasible, -p.pred_qps))
    return preds


# ------------------------------------------------------------ refine -----

@dataclasses.dataclass
class IngestOutcome:
    pred: IngestPrediction
    measured_wa: float
    measured_seal_p99_s: float
    measured_p99_s: float           # query p99 during the rw run
    measured_qps: float

    def to_dict(self) -> dict:
        d = self.pred.to_dict()
        d.update(measured_write_amplification=round(self.measured_wa, 3),
                 measured_seal_p99_s=round(self.measured_seal_p99_s, 6),
                 measured_query_p99_s=round(self.measured_p99_s, 6),
                 measured_qps=round(self.measured_qps, 2))
        return d


def evaluate_ingest_point(w: WorkloadSpec, env: EnvSpec,
                          pred: IngestPrediction, *, eval_n: int = 1200,
                          nq: int = 32, seed: int = 0) -> IngestOutcome:
    """Measure one knob point on the real engine: a small closed-loop
    query stream with a live update stream and this point's compaction
    config."""
    import numpy as np

    from repro.core.cluster_index import ClusterIndex
    from repro.core.types import ClusterIndexParams, SearchParams
    from repro.data.synth import DatasetSpec, make_dataset
    from repro.ingest import make_mutable, synth_updates
    from repro.serving.engine import run_workload

    c = Candidate(kind="cluster")  # the rw eval rides the cluster engine
    spec = DatasetSpec("ingest-analog", w.dim, w.dtype, eval_n, nq,
                       n_clusters=max(8, min(64, eval_n // 16)),
                       intrinsic_dim=min(32, w.dim), seed=seed)
    data, queries = make_dataset(spec)
    index = make_mutable(ClusterIndex.build(
        data, ClusterIndexParams(kmeans_iters=4, seed=seed)))
    # scale the write rate to eval scale: keep the write:read byte ratio
    stream = synth_updates(
        data, rate_qps=max(w.write_rate_qps, 1.0),
        n_updates=max(8, int(w.write_rate_qps)), seed=seed)
    # scale the delta cap by the eval-to-full index ratio so flush
    # cadence (flushes per update) is preserved
    full_bytes = scr.index_bytes(w, c)
    ratio = index.meta.index_bytes / max(full_bytes, 1.0)
    cap = max(4 * index.entry_nbytes,
              int(pred.point.delta_cap_bytes * ratio))
    cfg = pred.point.to_config()
    cfg = dataclasses.replace(cfg, delta_cap_bytes=cap)
    rep = run_workload(index, np.concatenate([queries, queries]),
                       SearchParams(k=w.k, nprobe=16), env.storage,
                       concurrency=max(1, w.concurrency), seed=seed,
                       updates=stream, ingest=cfg)
    ing = rep.ingest
    return IngestOutcome(
        pred=pred, measured_wa=ing["write_amplification"],
        measured_seal_p99_s=ing["seal_lag"]["p99_s"],
        measured_p99_s=rep.latency_percentile(99),
        measured_qps=rep.qps)


# --------------------------------------------------------- recommend -----

@dataclasses.dataclass
class IngestRecommendation:
    point: IngestPoint
    screened: list[IngestPrediction]
    outcomes: list[IngestOutcome]
    reason: str

    def to_dict(self) -> dict:
        return dict(point=self.point.to_dict(), reason=self.reason,
                    screened=[p.to_dict() for p in self.screened[:8]],
                    refined=[o.to_dict() for o in self.outcomes])


def tune_ingest(w: WorkloadSpec, env: EnvSpec,
                cand: Candidate | None = None, *, refine: int = 0,
                eval_n: int = 1200, nq: int = 32, seed: int = 0
                ) -> IngestRecommendation:
    """Pick compaction knobs for a workload with ``write_rate_qps`` > 0.

    Analytic screen over the knob grid; with ``refine`` > 0 the top
    ``refine`` feasible points are measured on the real engine.  The
    recommendation is the *freshest* feasible point whose (predicted or
    measured) QPS is within ``QPS_SLACK`` of the best — freshness is
    what the delta tier exists to buy, so it is the tiebreak."""
    if w.write_rate_qps <= 0:
        raise ValueError("tune_ingest needs a WorkloadSpec with "
                         "write_rate_qps > 0 (read-only workloads have "
                         "no compaction to tune)")
    c = cand if cand is not None else Candidate(kind="cluster")
    screened = screen_ingest(w, env, c)
    feasible = [p for p in screened if p.feasible]
    if not feasible:
        return IngestRecommendation(
            point=min(screened,
                      key=lambda p: p.bandwidth_share).point,
            screened=screened, outcomes=[],
            reason="no point keeps compaction under "
                   f"{MAX_BANDWIDTH_SHARE:.0%} of the NIC at "
                   f"{w.write_rate_qps:g} writes/s; returning the "
                   "least-saturating point")
    outcomes: list[IngestOutcome] = []
    if refine > 0:
        for p in feasible[:refine]:
            outcomes.append(evaluate_ingest_point(
                w, env, p, eval_n=eval_n, nq=nq, seed=seed))
        best_qps = max(o.measured_qps for o in outcomes)
        ok = [o for o in outcomes
              if o.measured_qps >= (1.0 - QPS_SLACK) * best_qps]
        pick = min(ok, key=lambda o: o.measured_seal_p99_s)
        return IngestRecommendation(
            point=pick.pred.point, screened=screened, outcomes=outcomes,
            reason=f"freshest measured point within {QPS_SLACK:.0%} of "
                   f"best QPS ({best_qps:.1f})")
    best_qps = feasible[0].pred_qps
    ok = [p for p in feasible
          if p.pred_qps >= (1.0 - QPS_SLACK) * best_qps]
    pick = min(ok, key=lambda p: p.pred_seal_lag_s)
    return IngestRecommendation(
        point=pick.point, screened=screened, outcomes=[],
        reason=f"freshest screened point within {QPS_SLACK:.0%} of best "
               f"predicted QPS ({best_qps:.1f})")
