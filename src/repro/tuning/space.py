"""Declarative tuning space: workloads, environments, candidate configs.

The joint space the auto-tuner searches is the paper's §5.2/§7 parameter
landscape made explicit:

    {index class} × {build params} × {search params} × {cache policy}

Grids carry *paper-derived priors* — they are centred on the settings the
paper's sweeps (Figs 7, 14–19) found load-bearing, not on exhaustive
ranges:

* cluster (SPANN-class): ``centroid_frac`` around 16% with the
  fine-grained 32% variant that wins under I/O congestion (Fig 14);
  ``num_replica`` 4/8 (Fig 16/24); ``nprobe`` the power-of-two sweep of
  the §5.1 protocol.
* graph (DiskANN-class): out-degree ``R`` 32–128 (Fig 17: cloud favours
  dense graphs), beamwidth 4–32 (Fig 19: the IOPS-vs-latency trade),
  ``search_len`` the §5.1 power-of-two sweep.
* cache policy: none / scan-resistant SLRU / pinned hot-set (§5.1, §7 A3).
"""
from __future__ import annotations

import dataclasses

from repro.cache.slru import CACHE_POLICIES
from repro.storage.spec import PRESETS, StorageSpec

# power-of-two sweeps from the paper's §5.1 protocol
NPROBE_GRID = (8, 16, 32, 64, 128, 256, 512, 1024, 2048)
SEARCHLEN_GRID = (20, 40, 80, 160, 320, 640)

CENTROID_FRAC_GRID = (0.08, 0.16, 0.32)
REPLICA_GRID = (4, 8)
R_GRID = (32, 64, 128)
BEAMWIDTH_GRID = (4, 8, 16, 32)

# cache policies come from the cache layer itself (one source of truth)
assert CACHE_POLICIES == ("none", "slru", "pinned")

# short CLI aliases for the paper's Table 1 environments
STORAGE_ALIASES = {
    "tos": "volcano-tos",
    "tos-external": "volcano-tos-external",
    "ssd": "local-ssd",
    "s3": "s3-external",
    "internal": "tos-internal-50gbps",
}


def resolve_storage(name: str) -> StorageSpec:
    key = STORAGE_ALIASES.get(name, name)
    if key not in PRESETS:
        known = sorted(set(STORAGE_ALIASES) | set(PRESETS))
        raise KeyError(f"unknown storage {name!r}; one of {known}")
    return PRESETS[key]


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """What the user wants served (the tuner's input, paper Table 2 axes)."""

    n: int = 1_000_000
    dim: int = 960
    dtype: str = "float32"            # "float32" | "int8"
    target_recall: float = 0.9        # recall@k floor
    concurrency: int = 1
    query_dist: str = "sequential"    # "sequential" | "zipf"
    zipf_a: float = 1.2
    k: int = 10
    write_rate_qps: float = 0.0       # live updates/s (ingest tuning axis)

    @property
    def dtype_bytes(self) -> int:
        return 4 if self.dtype == "float32" else 1

    @property
    def vector_bytes(self) -> int:
        return self.dim * self.dtype_bytes


@dataclasses.dataclass(frozen=True)
class EnvSpec:
    """Where it runs: a storage preset plus the compute-node cache budget."""

    storage: StorageSpec
    cache_bytes: int = 0

    def describe(self) -> str:
        return (f"{self.storage.describe()}, "
                f"cache {self.cache_bytes / 2**30:.2f} GiB")


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the joint config space.

    ``kind`` selects which fields are meaningful: cluster uses
    (centroid_frac, num_replica, nprobe); graph uses (R, beamwidth,
    search_len).  ``cache_policy`` applies to both.
    """

    kind: str                          # "cluster" | "graph"
    cache_policy: str = "none"
    # cluster build + search
    centroid_frac: float = 0.16
    num_replica: int = 8
    nprobe: int = 64
    # graph build + search
    R: int = 64
    beamwidth: int = 16
    search_len: int = 80

    def build_sig(self) -> tuple:
        """Hashable identity of the *build* (what forces a re-index)."""
        if self.kind == "cluster":
            return ("cluster", self.centroid_frac, self.num_replica)
        return ("graph", self.R)

    def label(self) -> str:
        if self.kind == "cluster":
            return (f"cluster[cf={self.centroid_frac:g},rep={self.num_replica},"
                    f"nprobe={self.nprobe},cache={self.cache_policy}]")
        return (f"graph[R={self.R},W={self.beamwidth},L={self.search_len},"
                f"cache={self.cache_policy}]")

    def to_dict(self) -> dict:
        d = dict(kind=self.kind, cache_policy=self.cache_policy)
        if self.kind == "cluster":
            d.update(centroid_frac=self.centroid_frac,
                     num_replica=self.num_replica, nprobe=self.nprobe)
        else:
            d.update(R=self.R, beamwidth=self.beamwidth,
                     search_len=self.search_len)
        return d


def cache_policies(env: EnvSpec) -> tuple[str, ...]:
    """Policies worth considering: without a cache budget only "none"."""
    return ("none",) if env.cache_bytes <= 0 else CACHE_POLICIES


def enumerate_space(workload: WorkloadSpec, env: EnvSpec,
                    kinds: tuple[str, ...] = ("cluster", "graph"),
                    ) -> list[Candidate]:
    """The full joint grid for (workload, env) before any screening."""
    cands: list[Candidate] = []
    policies = cache_policies(env)
    if "cluster" in kinds:
        for cf in CENTROID_FRAC_GRID:
            for rep in REPLICA_GRID:
                for nprobe in NPROBE_GRID:
                    if nprobe > cf * workload.n:    # more probes than lists
                        continue
                    for pol in policies:
                        cands.append(Candidate(
                            kind="cluster", cache_policy=pol,
                            centroid_frac=cf, num_replica=rep,
                            nprobe=nprobe))
    if "graph" in kinds:
        for R in R_GRID:
            for W in BEAMWIDTH_GRID:
                for L in SEARCHLEN_GRID:
                    if L < workload.k:
                        continue
                    for pol in policies:
                        cands.append(Candidate(
                            kind="graph", cache_policy=pol,
                            R=R, beamwidth=W, search_len=L))
    return cands
