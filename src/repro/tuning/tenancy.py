"""Cache-split tuning for multi-tenant fleets (the tenancy axis).

Question: given N tenants sharing one fleet's ``cache_bytes``, how
should the bytes be split?  Same discipline as every other axis in
``repro.tuning`` — an **analytic screen** prunes the candidate space,
then **simulation refinement** runs the few survivors on the real
multi-tenant fleet:

1. **Per-tenant miss curves.**  Each tenant's object-access profile
   (which store keys its query set touches, how often, how many bytes)
   is extracted by replaying its probe selection against its own index
   — exact for cluster tenants (``select_lists`` per query), sampled
   beam traces for graph tenants.  The profile feeds **Che's
   approximation** for LRU: the characteristic time ``T`` solves
   ``Σ_i s_i·(1 − e^{−λ_i T}) = C`` and each object hits with
   probability ``1 − e^{−λ_i T}`` — the standard closed-form miss
   curve ``miss_t(C)``, concave in C, exact in the large-cache limit.
2. **Screen.**  Candidate splits (a simplex grid over per-tenant
   fractions) are priced as weighted miss *bytes per second*:
   ``Σ_t rate_t · miss_t(f_t·C) · bytes_per_query_t`` — miss bytes are
   what the shared NIC pipe and GET buckets actually charge for.
3. **Refine.**  The top ``refine_top`` splits run as real
   ``static``-policy fleet evaluations (quota weights = the split);
   the recommendation is the split with the best measured aggregate
   goodput, with the analytic ranking reported alongside.

The screen's closed form is also the **documented tuning rule** of
``docs/tenancy.md``: give each tenant cache proportional to where its
miss-curve knee sits, not to its traffic share.
"""
from __future__ import annotations

import dataclasses
import itertools
import json

import numpy as np

from repro.fleet.router import FleetConfig
from repro.tenancy.fleet import Tenant, materialize_tenant, run_tenant_fleet
from repro.tenancy.spec import TenantSpec


# ----------------------------------------------------- access profiles --

def object_access_profile(tenant: Tenant, max_probe_queries: int = 16
                          ) -> dict:
    """(key -> [nbytes, access_count]) over the tenant's query set.

    Cluster tenants are profiled exactly: the probed posting lists of
    every query.  Graph tenants are sampled: full beam traces of up to
    ``max_probe_queries`` queries (block-touch skew comes from the
    entry-point neighbourhood, which sampling preserves — Fig 23)."""
    index = tenant.index
    profile: dict = {}

    def touch(key, nbytes):
        ent = profile.get(key)
        if ent is None:
            profile[key] = [int(nbytes), 1]
        else:
            ent[1] += 1

    if tenant.spec.index == "cluster":
        for q in tenant.queries:
            lids, _ = index.select_lists(q, tenant.params.nprobe)
            for li in lids:
                touch(("list", int(li)),
                      int(index.meta.list_nbytes[int(li)]))
    else:
        sample = tenant.queries[:max_probe_queries]
        for q in sample:
            from repro.core.types import QueryMetrics
            gen = index.search_plan(q, tenant.params, QueryMetrics())
            try:
                batch = next(gen)
                while True:
                    payloads = {}
                    for rq in batch.requests:
                        touch(rq.key, rq.nbytes)
                        payloads[rq.key] = index.store.get(rq.key)
                    batch = gen.send(payloads)
            except StopIteration:
                pass
    return profile


def che_hit_rate(profile: dict, cache_bytes: int) -> float:
    """Byte-weighted LRU hit rate under Che's approximation.

    Solves ``Σ_i s_i (1 − e^{−λ_i T}) = C`` for the characteristic time
    ``T`` by bisection, then returns the access-weighted hit rate
    ``Σ_i λ_i (1 − e^{−λ_i T}) / Σ_i λ_i``."""
    if not profile or cache_bytes <= 0:
        return 0.0
    sizes = np.array([v[0] for v in profile.values()], dtype=np.float64)
    lam = np.array([v[1] for v in profile.values()], dtype=np.float64)
    lam /= max(lam.sum(), 1e-12)
    total_bytes = sizes.sum()
    if cache_bytes >= total_bytes:
        return 1.0

    def occupied(T: float) -> float:
        return float((sizes * -np.expm1(-lam * T)).sum())

    lo, hi = 0.0, 1.0
    while occupied(hi) < cache_bytes and hi < 1e18:
        hi *= 2.0
    for _ in range(80):
        mid = 0.5 * (lo + hi)
        if occupied(mid) < cache_bytes:
            lo = mid
        else:
            hi = mid
    T = 0.5 * (lo + hi)
    p_hit = -np.expm1(-lam * T)
    return float((lam * p_hit).sum())


def miss_curve(tenant: Tenant, sizes: list[int] | np.ndarray,
               profile: dict | None = None) -> list[tuple[int, float]]:
    """``[(cache_bytes, miss_rate)]`` for one tenant — its isolated
    LRU miss curve over the candidate quota sizes."""
    prof = profile if profile is not None else \
        object_access_profile(tenant)
    return [(int(c), 1.0 - che_hit_rate(prof, int(c))) for c in sizes]


# ------------------------------------------------------------- screen --

@dataclasses.dataclass(frozen=True)
class CacheSplit:
    """One candidate split: per-tenant fractions of the total budget."""

    fractions: tuple[float, ...]

    def __post_init__(self):
        if not self.fractions or any(f < 0 for f in self.fractions):
            raise ValueError(f"fractions must be >= 0, got "
                             f"{self.fractions}")
        if abs(sum(self.fractions) - 1.0) > 1e-6:
            raise ValueError(f"fractions must sum to 1, got "
                             f"{self.fractions}")

    def label(self) -> str:
        return "/".join(f"{f:.2f}" for f in self.fractions)


@dataclasses.dataclass
class SplitPrediction:
    """Analytic screen output for one candidate split."""

    split: CacheSplit
    miss_rates: tuple[float, ...]      # per-tenant at its quota
    miss_bytes_per_s: float            # Σ rate·miss·bytes-per-query

    def to_dict(self) -> dict:
        return dict(split=list(self.split.fractions),
                    miss_rates=[round(m, 4) for m in self.miss_rates],
                    miss_bytes_per_s=round(self.miss_bytes_per_s, 2))


def enumerate_splits(n_tenants: int, steps: int = 8) -> list[CacheSplit]:
    """The simplex grid of per-tenant fractions at ``1/steps``
    resolution (every tenant gets at least one slice)."""
    if n_tenants == 1:
        return [CacheSplit((1.0,))]
    if steps < n_tenants:
        raise ValueError(
            f"steps={steps} cannot give each of {n_tenants} tenants a "
            f"1/{steps} slice — raise steps to >= the tenant count")
    out = []
    for combo in itertools.product(range(1, steps), repeat=n_tenants - 1):
        rest = steps - sum(combo)
        if rest < 1:
            continue
        out.append(CacheSplit(tuple(c / steps for c in combo)
                              + (rest / steps,)))
    return out


def _mrc_rows(tenants: list[Tenant], mrc: dict) -> list[dict]:
    """Match an ``repro.obs.mrc`` artifact's per-tenant curves to the
    tenant list by name, loudly."""
    rows = {r.get("name"): r for r in mrc.get("tenants", [])}
    missing = [t.spec.name for t in tenants if t.spec.name not in rows]
    if missing:
        raise ValueError(
            f"mrc curves missing tenants {missing}; artifact has "
            f"{sorted(k for k in rows if k)}")
    return [rows[t.spec.name] for t in tenants]


def screen_cache_splits(tenants: list[Tenant], total_cache_bytes: int,
                        splits: list[CacheSplit] | None = None,
                        steps: int = 8,
                        mrc: dict | None = None) -> list[SplitPrediction]:
    """Rank candidate splits by predicted aggregate miss bytes/s
    (ascending — the screen's best candidate first).

    ``mrc`` swaps the analytic model out for **measured** curves: an
    ``repro.obs.mrc`` artifact (``MRCProfiler.to_dict()`` — the
    ``--mrc`` output of a monitored fleet run) supplies each tenant's
    online miss-ratio curve and demand rate, and the screen prices
    splits by interpolating those curves instead of replaying probe
    selection through Che's approximation."""
    if total_cache_bytes <= 0:
        raise ValueError("total_cache_bytes must be > 0 to tune a split")
    cands = splits if splits is not None else \
        enumerate_splits(len(tenants), steps=steps)
    if mrc is not None:
        from repro.obs.mrc import mrc_miss_ratio
        rows = _mrc_rows(tenants, mrc)
        # miss bytes/s = demand bytes/s × miss ratio; fall back to raw
        # access volume when the artifact carries no wall time (scale
        # is global, so the ranking is unchanged)
        demand = [r.get("demand_bytes_per_s")
                  or r["accesses"] * r.get("mean_obj_bytes", 1.0)
                  for r in rows]

        def miss_at(i: int, cache_bytes: int) -> float:
            return mrc_miss_ratio(rows[i]["sizes"],
                                  rows[i]["miss_ratio"], cache_bytes)
    else:
        profiles = [object_access_profile(t) for t in tenants]
        rates = [t.spec.rate_qps
                 if t.spec.scenario not in ("closed", "rw") else 1.0
                 for t in tenants]
        bytes_per_query = [
            sum(v[0] * v[1] for v in prof.values())
            / max(1, sum(v[1] for v in prof.values()))
            * (t.params.nprobe if t.spec.index == "cluster"
               else t.params.search_len)
            for t, prof in zip(tenants, profiles)]
        demand = [r * b for r, b in zip(rates, bytes_per_query)]

        def miss_at(i: int, cache_bytes: int) -> float:
            return 1.0 - che_hit_rate(profiles[i], cache_bytes)
    preds = []
    for split in cands:
        miss = tuple(
            miss_at(i, int(split.fractions[i] * total_cache_bytes))
            for i in range(len(tenants)))
        cost = sum(d * m for d, m in zip(demand, miss))
        preds.append(SplitPrediction(split, miss, cost))
    preds.sort(key=lambda p: (p.miss_bytes_per_s,
                              p.split.fractions))
    return preds


# ------------------------------------------------------------- refine --

@dataclasses.dataclass
class SplitOutcome:
    """One candidate split measured on the real multi-tenant fleet."""

    split: CacheSplit
    aggregate_goodput_qps: float
    aggregate_hit_rate: float
    per_tenant_p99_s: tuple[float, ...]

    def to_dict(self) -> dict:
        return dict(split=list(self.split.fractions),
                    aggregate_goodput_qps=round(
                        self.aggregate_goodput_qps, 3),
                    aggregate_hit_rate=round(self.aggregate_hit_rate, 4),
                    per_tenant_p99_s=[round(p, 6)
                                      for p in self.per_tenant_p99_s])


@dataclasses.dataclass
class CacheSplitRecommendation:
    """The tuner's answer: the best measured split + the full ranking."""

    split: CacheSplit
    screened: list[SplitPrediction]
    outcomes: list[SplitOutcome]
    total_cache_bytes: int

    def to_dict(self) -> dict:
        return dict(
            recommendation=list(self.split.fractions),
            total_cache_bytes=self.total_cache_bytes,
            screened=[p.to_dict() for p in self.screened[:12]],
            refined=[o.to_dict() for o in self.outcomes])

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)


def tune_cache_split(specs: list[TenantSpec], cfg: FleetConfig, *,
                     steps: int = 8, refine_top: int = 3,
                     mrc: dict | None = None,
                     ) -> CacheSplitRecommendation:
    """Screen the split simplex analytically, then refine the top
    candidates on real ``static``-policy fleet runs; recommend the
    split with the best measured aggregate goodput.

    ``mrc`` (an ``repro.obs.mrc`` artifact from a live profiled run)
    replaces the analytic screen's access profiles with measured
    miss-ratio curves — the online path from a running fleet straight
    into the tuner."""
    if len(specs) < 2:
        raise ValueError("cache-split tuning needs >= 2 tenants")
    if cfg.cache_bytes <= 0:
        raise ValueError("FleetConfig.cache_bytes must be > 0 to tune a "
                         "cache split")
    tenants = [materialize_tenant(s, base_seed=cfg.seed, tid=i)
               for i, s in enumerate(specs)]
    preds = screen_cache_splits(tenants, cfg.cache_bytes, steps=steps,
                                mrc=mrc)
    outcomes = []
    for pred in preds[:max(1, refine_top)]:
        quota = {i: f for i, f in enumerate(pred.split.fractions)}
        # read-only tenants are not mutated by a run (caches and
        # partitions live outside the Tenant) — only write-stream
        # tenants need a fresh materialisation per candidate
        fresh = [t if t.updates is None
                 else materialize_tenant(specs[i], base_seed=cfg.seed,
                                         tid=i)
                 for i, t in enumerate(tenants)]
        rep = run_tenant_fleet(fresh, cfg, "static", quota_weights=quota)
        outcomes.append(SplitOutcome(
            split=pred.split,
            aggregate_goodput_qps=rep.aggregate_goodput_qps,
            aggregate_hit_rate=rep.fleet.hit_rate,
            per_tenant_p99_s=tuple(t.sojourn_percentile(99)
                                   for t in rep.tenants)))
    best = max(outcomes, key=lambda o: (o.aggregate_goodput_qps,
                                        o.aggregate_hit_rate))
    return CacheSplitRecommendation(
        split=best.split, screened=preds, outcomes=outcomes,
        total_cache_bytes=cfg.cache_bytes)
