"""Stage 1 of the tuner: cheap analytic screening (no simulation).

Every candidate is priced with the paper's executable cost models
(Eq. 1 / Eq. 2 in ``core/cost_model.py``) at *full workload scale*, plus
two priors that make the pricing recall- and cache-aware:

* **recall priors** — monotone curves anchored on the paper's §5.2
  parameter sweeps (the knob values Figs 7/17–19 needed per recall level
  at GIST-like dimensionality), rescaled for dim / replica / out-degree.
  They are priors, not measurements: stage 2 replaces them with recall
  measured on subsampled data.
* **hit-rate priors** — a Zipf/coverage model of the segment cache
  (§4.1's "commonality and stability"): SLRU approaches the Zipf head
  mass ``coverage^(1-1/a)`` but pays a churn discount at small coverage;
  a pinned hot set avoids churn but cannot adapt, so the two cross over
  as the cache grows — the §7 policy-flip the tuner must rediscover.

``screen`` keeps the top predicted-QPS configs among those predicted to
meet the recall target, reserving a few slots for minority index kinds
and cache policies so stage 2 can observe crossovers.  On the standard
grids (≥40 configs) it prunes ≥90% of the space by construction
(``keep ≤ len(space) // 10``); heavily filtered small spaces keep a
floor of 4 survivors so stage 2 still has a cohort.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.cost_model import (ClusterWorkloadPoint, GraphWorkloadPoint,
                                   cluster_query_cost, graph_query_cost,
                                   predicted_qps)
from repro.storage.object_store import round_to_sectors
from repro.tuning.space import Candidate, EnvSpec, WorkloadSpec

# (recall, knob) anchors at the reference point: dim=960, n=1e6,
# replica=8 / R>=64.  From the paper's sweep protocol (§5.1/§5.2).
_CLUSTER_ANCHORS = ((0.70, 16), (0.90, 64), (0.95, 128), (0.99, 512),
                    (0.995, 2048))
_GRAPH_ANCHORS = ((0.70, 20), (0.90, 60), (0.95, 110), (0.99, 300),
                  (0.995, 500))

REPLICATION_PER_REPLICA = 0.10      # closure-replication bytes growth/replica
HIT_LATENCY_S = 100e-6


def _interp_recall(anchors, knob: float) -> float:
    """Monotone piecewise-linear recall(log2 knob) with saturating tails."""
    x = math.log2(max(knob, 1.0))
    pts = [(math.log2(v), r) for r, v in anchors]
    x0, r0 = pts[0]
    if x <= x0:                       # extrapolate down, floor at 0.05
        slope = (pts[1][1] - r0) / (pts[1][0] - x0)
        return max(0.05, r0 + slope * (x - x0))
    for (xa, ra), (xb, rb) in zip(pts, pts[1:]):
        if x <= xb:
            return ra + (rb - ra) * (x - xa) / (xb - xa)
    xn, rn = pts[-1]                  # saturate toward 1.0 above the top
    return min(0.9995, rn + (1.0 - rn) * (1.0 - 2.0 ** (xn - x)))


def cluster_recall_prior(w: WorkloadSpec, c: Candidate) -> float:
    """Effective nprobe: harder at high dim (§5.2 dimensionality study),
    helped by replication (Fig 16) and hurt by finer partitions at equal
    nprobe (Fig 14 — each of more lists covers fewer points)."""
    ne = (c.nprobe * math.sqrt(960.0 / w.dim)
          * (c.num_replica / 8.0) ** 0.3
          * (0.16 / c.centroid_frac) ** 0.5)
    return _interp_recall(_CLUSTER_ANCHORS, ne)


def graph_recall_prior(w: WorkloadSpec, c: Candidate) -> float:
    """Effective search_len: dim penalty plus sparse-graph penalty (Fig 17)
    and a mild beamwidth bonus (wider frontier explores more, Fig 19)."""
    le = (c.search_len * math.sqrt(960.0 / w.dim)
          * min(1.0, c.R / 64.0) ** 0.5
          * (c.beamwidth / 16.0) ** 0.1)
    return _interp_recall(_GRAPH_ANCHORS, le)


def graph_roundtrips(w: WorkloadSpec, c: Candidate) -> int:
    """rt grows with search_len/beamwidth and log(n) (Fig 8b).

    Total expansions ≈ 1.5 × search_len (DiskANN visits a constant factor
    beyond L; the paper's rt-vs-recall anchors give rt·W/L ≈ 1.5), spread
    over W-wide rounds.
    """
    scale = math.log2(max(w.n, 2)) / math.log2(1e6)
    return max(3, round(1.5 * c.search_len / c.beamwidth * scale))


# ------------------------------------------------------------- sizing ----

def cluster_stats(w: WorkloadSpec, c: Candidate) -> tuple[float, float, float]:
    """(n_lists, avg_list_len, avg_list_bytes) at full workload scale."""
    n_lists = max(1.0, c.centroid_frac * w.n)
    rep_factor = 1.0 + REPLICATION_PER_REPLICA * c.num_replica
    avg_len = w.n * rep_factor / n_lists
    return n_lists, avg_len, avg_len * (w.vector_bytes + 8)


def graph_node_bytes(w: WorkloadSpec, c: Candidate) -> int:
    return round_to_sectors(w.vector_bytes + c.R * 4 + 8, 4096)


def index_bytes(w: WorkloadSpec, c: Candidate) -> float:
    if c.kind == "cluster":
        n_lists, _, list_bytes = cluster_stats(w, c)
        return n_lists * list_bytes
    return float(w.n) * graph_node_bytes(w, c)


# ----------------------------------------------------------- hit rates ---

def hit_rate_prior(w: WorkloadSpec, env: EnvSpec, c: Candidate) -> float:
    """Expected steady-state segment-cache hit rate for (policy, dist)."""
    if c.cache_policy == "none" or env.cache_bytes <= 0:
        return 0.0
    cov = min(1.0, env.cache_bytes / index_bytes(w, c))
    if cov <= 0.0:
        return 0.0
    if w.query_dist == "zipf":
        # Zipf head mass reachable with this coverage (Che-style).
        head = cov ** max(0.12, 1.0 - 1.0 / w.zipf_a)
        if c.cache_policy == "slru":
            return min(0.98, head * (1.0 - 0.30 * (1.0 - cov)))
        return min(0.95, head * (0.95 - 0.35 * cov))        # pinned
    # sequential / cold-ish: only inter-query segment sharing helps …
    hr = 0.5 * cov
    if c.kind == "graph":
        # … plus the entry-neighbourhood rounds every query revisits
        # (Fig 23); a pinned hot set captures exactly those.
        rt = graph_roundtrips(w, c)
        entry = min(0.5, (2.5 if c.cache_policy == "pinned" else 1.5) / rt)
        hr = max(hr, entry * min(1.0, cov * 50.0))
    return min(0.9, hr)


# ------------------------------------------------------------ predict ----

@dataclasses.dataclass(frozen=True)
class Prediction:
    cand: Candidate
    pred_recall: float
    pred_qps: float
    hit_rate: float
    cost: dict
    feasible: bool

    def to_dict(self) -> dict:
        return dict(config=self.cand.to_dict(),
                    pred_recall=round(self.pred_recall, 4),
                    pred_qps=round(self.pred_qps, 2),
                    hit_rate_prior=round(self.hit_rate, 4),
                    feasible=self.feasible)


def predict(w: WorkloadSpec, env: EnvSpec, c: Candidate,
            hit_rate: float | None = None,
            recall: float | None = None,
            recall_margin: float = 0.02) -> Prediction:
    """Full-scale analytic (recall, QPS) for one candidate.

    ``hit_rate``/``recall`` override the priors — stage 2 calls back in
    with *measured* values to re-price survivors at full scale.
    """
    hr = hit_rate_prior(w, env, c) if hit_rate is None else hit_rate
    if c.kind == "cluster":
        n_lists, avg_len, list_bytes = cluster_stats(w, c)
        cost = cluster_query_cost(
            env.storage,
            ClusterWorkloadPoint(n_lists=int(n_lists),
                                 avg_list_bytes=list_bytes,
                                 avg_list_len=avg_len, dim=w.dim,
                                 nprobe=c.nprobe),
            concurrency=w.concurrency, hit_rate=hr,
            hit_latency_s=HIT_LATENCY_S)
        r = cluster_recall_prior(w, c) if recall is None else recall
    else:
        cost = graph_query_cost(
            env.storage,
            GraphWorkloadPoint(roundtrips=graph_roundtrips(w, c),
                               requests_per_round=float(c.beamwidth),
                               node_nbytes=graph_node_bytes(w, c),
                               R=c.R, pq_m=max(48, w.dim // 8), dim=w.dim),
            concurrency=w.concurrency, hit_rate=hr,
            hit_latency_s=HIT_LATENCY_S)
        r = graph_recall_prior(w, c) if recall is None else recall
    qps = predicted_qps(env.storage, cost["total"], cost["bytes"],
                        cost["requests"], w.concurrency)
    return Prediction(cand=c, pred_recall=r, pred_qps=qps, hit_rate=hr,
                      cost=cost, feasible=r >= w.target_recall - recall_margin)


# ------------------------------------------------------------- screen ----

@dataclasses.dataclass
class ScreenResult:
    kept: list[Prediction]
    n_total: int

    @property
    def prune_fraction(self) -> float:
        return 1.0 - len(self.kept) / max(1, self.n_total)


def best_predicted_qps(preds: list[Prediction]) -> float:
    """Best predicted QPS among feasible predictions (0 if none)."""
    return max((p.pred_qps for p in preds if p.feasible), default=0.0)


def screen(w: WorkloadSpec, env: EnvSpec, cands: list[Candidate],
           keep: int | None = None) -> ScreenResult:
    """Analytically prune the space down to the survivors stage 2 will
    simulate: ≤10% of the candidates (≥90% pruned) whenever the space has
    at least 40 configs, with a floor of 4 survivors on smaller spaces."""
    preds = [predict(w, env, c) for c in cands]
    cap = max(4, len(cands) // 10)
    cap = min(cap, keep) if keep is not None else cap
    feasible = sorted((p for p in preds if p.feasible),
                      key=lambda p: -p.pred_qps)
    if not feasible:
        # nothing meets the target: surface the closest-to-target configs
        # so the caller can report the achievable frontier honestly.
        closest = sorted(preds, key=lambda p: (-p.pred_recall, -p.pred_qps))
        return ScreenResult(kept=closest[:cap], n_total=len(cands))
    # diversify across the *search knob* first: many (build-param) variants
    # of the same knob value score near-identically, and keeping them all
    # would crowd the knee band (recommend.QPS_SLACK) out of the kept set.
    def _knob(c: Candidate):
        return (c.nprobe,) if c.kind == "cluster" else (
            c.search_len, c.beamwidth)

    knob_groups: dict[tuple, list[Prediction]] = {}
    for p in feasible:
        knob_groups.setdefault((p.cand.kind, p.cand.cache_policy,
                                _knob(p.cand)), []).append(p)
    # group representative: the highest-recall member among those within
    # 5% of the group's best QPS (build variants of one knob value are
    # near-ties on cost; recall is what distinguishes them).
    reps = []
    for members in knob_groups.values():
        best_q = max(m.pred_qps for m in members)
        near = [m for m in members if m.pred_qps >= 0.95 * best_q]
        reps.append(max(near, key=lambda m: (m.pred_recall, m.pred_qps)))
    kept = sorted(reps, key=lambda p: -p.pred_qps)[:cap]
    seen = set(id(p) for p in kept)
    # reserve the best of each missing (kind, cache_policy) group FIRST —
    # crossovers (index class, policy flip) must survive to simulation —
    # evicting the lowest-QPS member of an over-represented group when
    # the cap is already reached.
    groups: dict[tuple, Prediction] = {}
    for p in feasible:                    # qps-sorted: first is group best
        groups.setdefault((p.cand.kind, p.cand.cache_policy), p)

    def _gkey(p: Prediction) -> tuple:
        return (p.cand.kind, p.cand.cache_policy)

    for key, p in groups.items():
        if any(_gkey(k) == key for k in kept):
            continue
        if len(kept) >= cap:
            counts: dict[tuple, int] = {}
            for k in kept:
                counts[_gkey(k)] = counts.get(_gkey(k), 0) + 1
            victims = [k for k in kept if counts[_gkey(k)] > 1]
            if not victims:
                continue                  # every group is a singleton
            worst = min(victims, key=lambda k: k.pred_qps)
            kept.remove(worst)
            seen.discard(id(worst))
        kept.append(p)
        seen.add(id(p))
    # fill any remaining slots with the next-best overall
    for p in feasible:
        if len(kept) >= cap:
            break
        if id(p) not in seen:
            kept.append(p)
            seen.add(id(p))
    kept.sort(key=lambda p: -p.pred_qps)
    return ScreenResult(kept=kept, n_total=len(cands))
