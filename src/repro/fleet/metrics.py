"""Fleet-level measurement: tail latency, balance, hedging, backpressure.

Extends the single-node §5.1 instrumentation with the quantities that only
exist at fleet scale: p99.9 (hedging's target), per-shard load imbalance
(partitioning quality), hedge rate (how often the tail deadline fired) and
shed rate (admission-queue backpressure).
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.core.types import QueryMetrics
from repro.fleet.server import ShardStats


@dataclasses.dataclass
class FleetQueryRecord:
    """One query's fleet-side lifecycle."""

    qid: int
    start_t: float
    end_t: float
    ids: np.ndarray
    dists: np.ndarray
    metrics: QueryMetrics          # aggregated over router + shard jobs
    rounds: int                    # scatter-gather rounds
    n_jobs: int                    # shard jobs issued (incl. hedges)
    shards_touched: int
    hedged: bool = False
    shed_retries: int = 0

    @property
    def latency(self) -> float:
        return self.end_t - self.start_t


@dataclasses.dataclass
class FleetReport:
    """Aggregates for one fleet run (the fleet analogue of
    :class:`repro.serving.metrics.WorkloadReport`)."""

    records: list[FleetQueryRecord]
    shard_stats: list[ShardStats]
    wall_time_s: float
    n_shards: int
    replication: int
    concurrency: int
    jobs_total: int                # accepted shard jobs (incl. hedges)
    hedges_launched: int
    hedge_wins: int
    sheds_total: int
    submissions_total: int         # accepted + shed submission attempts

    # ------------------------------------------------------- throughput --
    @property
    def qps(self) -> float:
        return len(self.records) / max(self.wall_time_s, 1e-12)

    # ---------------------------------------------------------- latency --
    def latency_percentile(self, p: float) -> float:
        if not self.records:
            return 0.0
        return float(np.percentile([r.latency for r in self.records], p))

    @property
    def mean_latency(self) -> float:
        if not self.records:
            return 0.0
        return float(np.mean([r.latency for r in self.records]))

    # ---------------------------------------------------------- balance --
    @property
    def load_imbalance(self) -> float:
        """max/mean of per-shard jobs served (1.0 = perfectly even)."""
        jobs = np.array([s.jobs_done for s in self.shard_stats],
                        dtype=np.float64)
        return float(jobs.max() / max(jobs.mean(), 1e-12))

    @property
    def bytes_imbalance(self) -> float:
        """max/mean of per-shard bytes actually served from storage."""
        b = np.array([s.storage_bytes for s in self.shard_stats],
                     dtype=np.float64)
        return float(b.max() / max(b.mean(), 1e-12))

    # ------------------------------------------------- hedging/shedding --
    @property
    def hedge_rate(self) -> float:
        return self.hedges_launched / max(1, self.jobs_total)

    @property
    def hedge_win_rate(self) -> float:
        return self.hedge_wins / max(1, self.hedges_launched)

    @property
    def shed_rate(self) -> float:
        return self.sheds_total / max(1, self.submissions_total)

    # ----------------------------------------------------------- totals --
    @property
    def storage_bytes(self) -> int:
        return sum(s.storage_bytes for s in self.shard_stats)

    @property
    def storage_requests(self) -> int:
        return sum(s.storage_requests for s in self.shard_stats)

    @property
    def hit_rate(self) -> float:
        hits = sum(r.metrics.cache_hits for r in self.records)
        lookups = sum(r.metrics.cache_lookups for r in self.records)
        return hits / lookups if lookups else 0.0

    def recall_against(self, gt_ids: np.ndarray) -> float:
        from repro.core.types import recall_at_k
        recs = [recall_at_k(r.ids[r.ids >= 0], gt_ids[r.qid])
                for r in self.records]
        return float(np.mean(recs))

    # ------------------------------------------------------------- JSON --
    def summary(self) -> dict:
        return dict(
            n_queries=len(self.records),
            n_shards=self.n_shards,
            replication=self.replication,
            concurrency=self.concurrency,
            qps=round(self.qps, 4),
            mean_latency_s=round(self.mean_latency, 9),
            p50_latency_s=round(self.latency_percentile(50), 9),
            p99_latency_s=round(self.latency_percentile(99), 9),
            p999_latency_s=round(self.latency_percentile(99.9), 9),
            load_imbalance=round(self.load_imbalance, 4),
            bytes_imbalance=round(self.bytes_imbalance, 4),
            hedge_rate=round(self.hedge_rate, 4),
            hedge_win_rate=round(self.hedge_win_rate, 4),
            shed_rate=round(self.shed_rate, 4),
            jobs_total=self.jobs_total,
            hedges_launched=self.hedges_launched,
            sheds_total=self.sheds_total,
            storage_bytes=self.storage_bytes,
            storage_requests=self.storage_requests,
            hit_rate=round(self.hit_rate, 4),
            wall_time_s=round(self.wall_time_s, 9),
            shards=[s.to_dict() for s in self.shard_stats],
        )

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.summary(), indent=indent)
