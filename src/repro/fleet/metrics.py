"""Fleet-level measurement: tail latency, balance, hedging, backpressure,
and — under open-loop scenarios — offered-vs-achieved load, goodput,
queue depth and capacity over time.

Extends the single-node §5.1 instrumentation with the quantities that only
exist at fleet scale: p99.9 (hedging's target), per-shard load imbalance
(partitioning quality), hedge rate (how often the tail deadline fired),
shed rate (admission-queue backpressure), and the scenario axes: a
time-sliced :class:`FleetSeries` (achieved vs offered QPS, goodput, queue
depth, instance count) plus shards·seconds cost when the autoscaler runs.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.core.types import QueryMetrics
from repro.fleet.server import ShardStats


@dataclasses.dataclass
class FleetQueryRecord:
    """One query's fleet-side lifecycle."""

    qid: int
    start_t: float                 # service start (left the router backlog)
    end_t: float
    ids: np.ndarray
    dists: np.ndarray
    metrics: QueryMetrics          # aggregated over router + shard jobs
    rounds: int                    # scatter-gather rounds
    n_jobs: int                    # shard jobs issued (incl. hedges)
    shards_touched: int
    hedged: bool = False
    shed_retries: int = 0
    arrive_t: float | None = None  # open-loop arrival (None => start_t)

    @property
    def latency(self) -> float:
        return self.end_t - self.start_t

    @property
    def sojourn(self) -> float:
        """Arrival-to-completion time (includes router backlog wait)."""
        t0 = self.start_t if self.arrive_t is None else self.arrive_t
        return self.end_t - t0


@dataclasses.dataclass
class FleetSeries:
    """Per-slice counters sampled by the fleet's monitor process."""

    dt: float
    t: list = dataclasses.field(default_factory=list)
    arrived: list = dataclasses.field(default_factory=list)
    completed: list = dataclasses.field(default_factory=list)
    good: list = dataclasses.field(default_factory=list)
    queue_depth: list = dataclasses.field(default_factory=list)
    instances: list = dataclasses.field(default_factory=list)

    def append(self, *, t: float, arrived: int, completed: int, good: int,
               queue_depth: int, instances: int) -> None:
        self.t.append(round(t, 9))
        self.arrived.append(arrived)
        self.completed.append(completed)
        self.good.append(good)
        self.queue_depth.append(queue_depth)
        self.instances.append(instances)

    def to_dict(self) -> dict:
        """Per-slice rates (QPS) alongside the raw counters."""
        dts = np.diff([0.0] + self.t)
        dts = np.maximum(dts, 1e-12)
        return dict(
            dt=self.dt, t=self.t,
            offered_qps=[round(a / d, 3)
                         for a, d in zip(self.arrived, dts)],
            achieved_qps=[round(c / d, 3)
                          for c, d in zip(self.completed, dts)],
            goodput_qps=[round(g / d, 3) for g, d in zip(self.good, dts)],
            queue_depth=self.queue_depth,
            instances=self.instances)


@dataclasses.dataclass
class FleetReport:
    """Aggregates for one fleet run (the fleet analogue of
    :class:`repro.serving.metrics.WorkloadReport`)."""

    records: list[FleetQueryRecord]
    shard_stats: list[ShardStats]
    wall_time_s: float
    n_shards: int
    replication: int
    concurrency: int
    jobs_total: int                # accepted shard jobs (incl. hedges)
    hedges_launched: int
    hedge_wins: int
    sheds_total: int
    submissions_total: int         # accepted + shed submission attempts
    # -------------------------------------------------- scenario fields --
    scenario: str = "closed"
    n_arrivals: int = 0
    offered_qps: float = 0.0       # arrival rate (== qps when closed-loop)
    slo_s: float | None = None
    good_total: int | None = None  # completions with sojourn <= slo
    series: FleetSeries | None = None
    shards_seconds: float | None = None   # ∫ active instances dt (cost)
    scale_events: list | None = None      # autoscaler decision log
    fault_log: list | None = None         # fail/recover events observed
    ingest: dict | None = None            # repro.ingest accounting (rw)
    # ------------------------------------------- live obs (PR 7) fields --
    alerts: dict | None = None            # repro.obs.monitor summary
    cost: dict | None = None              # repro.obs.cost fleet_cost
    # ------------------------------------------ tail obs (PR 9) fields --
    explain: dict | None = None           # repro.obs.explain tail report
    mrc: dict | None = None               # repro.obs.mrc curves

    # ------------------------------------------------------- throughput --
    @property
    def qps(self) -> float:
        return len(self.records) / max(self.wall_time_s, 1e-12)

    @property
    def goodput_qps(self) -> float:
        """Completions that met the SLO, per second of wall time."""
        if self.good_total is None:
            return self.qps
        return self.good_total / max(self.wall_time_s, 1e-12)

    @property
    def goodput_frac(self) -> float:
        """Fraction of arrivals served within the SLO."""
        if self.good_total is None or not self.n_arrivals:
            return 1.0
        return self.good_total / self.n_arrivals

    # ---------------------------------------------------------- latency --
    def _sorted(self, kind: str) -> np.ndarray:
        """Sorted per-record values, computed once per report.

        ``summary()`` asks for five percentiles plus the mean; sorting
        the record list on every call made that O(5 · n log n) — on a
        million-record replay the sort dominates.  The cache keeps one
        sorted float64 array per kind (latency/sojourn) for the life of
        the report; records are append-only once the run finishes, so
        invalidation is a non-problem.
        """
        cache = self.__dict__.setdefault("_pctl_cache", {})
        arr = cache.get(kind)
        if arr is None:
            arr = np.sort(np.asarray([getattr(r, kind)
                                      for r in self.records],
                                     dtype=np.float64))
            cache[kind] = arr
        return arr

    @staticmethod
    def _percentile(arr: np.ndarray, p: float) -> float:
        """``np.percentile(..., method="linear")`` over a pre-sorted
        array, bit-identical to numpy (same two-branch lerp)."""
        n = arr.size
        if n == 1:
            return float(arr[0])
        pos = (p / 100.0) * (n - 1)
        i = int(pos)
        t = pos - i
        a = float(arr[i])
        if t == 0.0:
            return a
        b = float(arr[min(i + 1, n - 1)])
        d = b - a
        lerp = a + d * t
        if t >= 0.5:
            lerp = b - d * (1.0 - t)
        return lerp

    def latency_percentile(self, p: float) -> float:
        if not self.records:
            return 0.0
        return self._percentile(self._sorted("latency"), p)

    def sojourn_percentile(self, p: float) -> float:
        if not self.records:
            return 0.0
        return self._percentile(self._sorted("sojourn"), p)

    @property
    def mean_latency(self) -> float:
        if not self.records:
            return 0.0
        return float(np.mean(self._sorted("latency")))

    # ---------------------------------------------------------- balance --
    @property
    def load_imbalance(self) -> float:
        """max/mean of per-shard jobs served (1.0 = perfectly even)."""
        jobs = np.array([s.jobs_done for s in self.shard_stats],
                        dtype=np.float64)
        return float(jobs.max() / max(jobs.mean(), 1e-12))

    @property
    def bytes_imbalance(self) -> float:
        """max/mean of per-shard bytes actually served from storage."""
        b = np.array([s.storage_bytes for s in self.shard_stats],
                     dtype=np.float64)
        return float(b.max() / max(b.mean(), 1e-12))

    # ------------------------------------------------- hedging/shedding --
    @property
    def hedge_rate(self) -> float:
        return self.hedges_launched / max(1, self.jobs_total)

    @property
    def hedge_win_rate(self) -> float:
        return self.hedge_wins / max(1, self.hedges_launched)

    @property
    def shed_rate(self) -> float:
        return self.sheds_total / max(1, self.submissions_total)

    # ----------------------------------------------------------- totals --
    @property
    def storage_bytes(self) -> int:
        return sum(s.storage_bytes for s in self.shard_stats)

    @property
    def storage_requests(self) -> int:
        return sum(s.storage_requests for s in self.shard_stats)

    @property
    def hit_rate(self) -> float:
        hits = sum(r.metrics.cache_hits for r in self.records)
        lookups = sum(r.metrics.cache_lookups for r in self.records)
        return hits / lookups if lookups else 0.0

    def recall_against(self, gt_ids: np.ndarray) -> float:
        from repro.core.types import recall_at_k
        recs = [recall_at_k(r.ids[r.ids >= 0], gt_ids[r.qid])
                for r in self.records]
        return float(np.mean(recs))

    # ------------------------------------------------------------- JSON --
    def summary(self) -> dict:
        out = dict(
            n_queries=len(self.records),
            n_shards=self.n_shards,
            replication=self.replication,
            concurrency=self.concurrency,
            qps=round(self.qps, 4),
            mean_latency_s=round(self.mean_latency, 9),
            p50_latency_s=round(self.latency_percentile(50), 9),
            p99_latency_s=round(self.latency_percentile(99), 9),
            p999_latency_s=round(self.latency_percentile(99.9), 9),
            load_imbalance=round(self.load_imbalance, 4),
            bytes_imbalance=round(self.bytes_imbalance, 4),
            hedge_rate=round(self.hedge_rate, 4),
            hedge_win_rate=round(self.hedge_win_rate, 4),
            shed_rate=round(self.shed_rate, 4),
            jobs_total=self.jobs_total,
            hedges_launched=self.hedges_launched,
            sheds_total=self.sheds_total,
            storage_bytes=self.storage_bytes,
            storage_requests=self.storage_requests,
            hit_rate=round(self.hit_rate, 4),
            wall_time_s=round(self.wall_time_s, 9),
            shards=[s.to_dict() for s in self.shard_stats],
        )
        if self.scenario != "closed" or self.slo_s is not None:
            out["scenario"] = dict(
                kind=self.scenario,
                n_arrivals=self.n_arrivals,
                offered_qps=round(self.offered_qps, 4),
                achieved_qps=round(self.qps, 4),
                p50_sojourn_s=round(self.sojourn_percentile(50), 9),
                p99_sojourn_s=round(self.sojourn_percentile(99), 9))
            if self.slo_s is not None:
                out["scenario"].update(
                    slo_s=self.slo_s,
                    goodput_qps=round(self.goodput_qps, 4),
                    goodput_frac=round(self.goodput_frac, 4))
        if self.series is not None:
            out["series"] = self.series.to_dict()
        if self.shards_seconds is not None:
            out["shards_seconds"] = round(self.shards_seconds, 6)
        if self.scale_events is not None:
            out["autoscale"] = dict(
                events=self.scale_events,
                final_instances=(self.series.instances[-1]
                                 if self.series and self.series.instances
                                 else None))
        if self.fault_log is not None:
            out["faults"] = self.fault_log
        if self.ingest is not None:
            out["ingest"] = self.ingest
        # live-obs blocks last: bit-exactness tests compare a monitored
        # run's summary minus these keys against the plain run.
        if self.alerts is not None:
            out["alerts"] = self.alerts
        if self.cost is not None:
            out["cost"] = self.cost
        if self.explain is not None:
            out["explain"] = self.explain
        if self.mrc is not None:
            out["mrc"] = self.mrc
        return out

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.summary(), indent=indent)
