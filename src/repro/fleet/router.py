"""Scatter-gather fleet routing on one shared virtual clock.

The router owns the compute-node-resident index metadata (BKT centroids /
PQ codes — what the paper's single node caches, §2.1) and drives N
:class:`ShardServer` engines plus its own event heap on one deterministic
virtual clock:

* **Cluster queries** — centroid search runs at the router; the selected
  posting lists scatter to shard-local *scan jobs* (fetch + distance scan
  + local top-k, priced on the shard's compute), and the router merges the
  local top-ks into the global result.  One scatter round per query
  (paper §2.3.1's single dependency-free roundtrip, now fanned out).
* **Graph queries** — beam-search state stays at the router (the PQ/ADC
  frontier is metadata-resident); each expansion round's W node-block
  fetches scatter to the owning shards and gather before the next round,
  preserving the ``rt × TTFB`` floor per shard.

Routing policies:

* **power-of-two-choices** replica selection: among a key's R replica
  owners, sample two and pick the shorter queue (queue depth = running +
  waiting jobs) — the classic load-balance result, and the reason
  replication pays beyond fault tolerance.
* **hedged requests**: once enough job latencies are observed, a slot
  whose job outlives the fleet's p-th latency percentile is re-issued to
  the other replicas; first completion wins, the loser's work still
  burns shard resources (hedge_rate / hedge_win_rate in the report).
* **backpressure**: a shed submission (admission queue full) is retried
  after ``shed_retry_s`` with fresh replica choice — sheds delay queries
  and show up in shed_rate, they never drop data.

Determinism: one event heap, stable sequence numbers, per-shard
sub-generators seeded from (fleet seed, shard id) — identical seeds give
bit-identical :class:`FleetReport` JSON.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Iterable

import numpy as np

from repro.cache.slru import CACHE_POLICIES
from repro.core.cluster_index import dedup_topk, scan_posting_lists
from repro.core.cost_model import ComputeSpec, plan_compute_seconds
from repro.core.types import (FetchBatch, FetchRequest, QueryMetrics,
                              SearchParams, SearchResult)
from repro.fleet.metrics import FleetQueryRecord, FleetReport
from repro.fleet.partition import partition_for_index
from repro.fleet.server import ShardServer
from repro.serving.engine import EngineConfig, JobRecord
from repro.storage.spec import TOS, StorageSpec


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Everything that defines a serving fleet (the tuner's new axis)."""

    n_shards: int = 4
    replication: int = 1
    storage: StorageSpec = TOS
    concurrency: int = 8           # closed-loop outstanding fleet queries
    shard_concurrency: int = 4     # jobs executing per shard
    queue_depth: int = 16          # shard admission queue bound
    cache_bytes: int = 0           # per-shard segment cache budget
    cache_policy: str = "none"     # "none" | "slru"
    hedge: bool = False
    hedge_percentile: float = 95.0
    hedge_min_samples: int = 24
    shed_retry_s: float = 1e-3
    hit_latency_s: float = 100e-6
    compute: ComputeSpec = dataclasses.field(default_factory=ComputeSpec)
    seed: int = 0

    def __post_init__(self):
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if not 1 <= self.replication <= self.n_shards:
            raise ValueError(
                f"replication must be in [1, n_shards={self.n_shards}], "
                f"got {self.replication}")
        if self.cache_policy not in CACHE_POLICIES or \
                self.cache_policy == "pinned":
            raise ValueError(
                f"fleet cache_policy must be 'none' or 'slru', "
                f"got {self.cache_policy!r}")
        if self.concurrency < 1 or self.shard_concurrency < 1:
            raise ValueError("concurrency and shard_concurrency must be "
                             ">= 1")
        if self.queue_depth < 0:
            raise ValueError(f"queue_depth must be >= 0, got "
                             f"{self.queue_depth}")
        if self.hedge and not 50.0 <= self.hedge_percentile < 100.0:
            raise ValueError(
                f"hedge_percentile must be in [50, 100), got "
                f"{self.hedge_percentile}")

    def to_dict(self) -> dict:
        return dict(n_shards=self.n_shards, replication=self.replication,
                    storage=self.storage.name,
                    concurrency=self.concurrency,
                    shard_concurrency=self.shard_concurrency,
                    queue_depth=self.queue_depth,
                    cache_bytes=self.cache_bytes,
                    cache_policy=self.cache_policy, hedge=self.hedge,
                    hedge_percentile=self.hedge_percentile, seed=self.seed)


def merge_topk(results: list[SearchResult], k: int
               ) -> tuple[np.ndarray, np.ndarray]:
    """Global top-k over shard-local top-ks, deduplicating replica ids.

    Every member of the true global top-k is necessarily inside its own
    shard's local top-k, so the merge is lossless — same kernel as the
    single-node scan (``dedup_topk``).
    """
    ids = np.concatenate([r.ids for r in results])
    d = np.concatenate([r.dists for r in results])
    valid = ids >= 0
    return dedup_topk(ids[valid], d[valid], k)


class _Slot:
    """One shard-destined sub-request of one scatter round."""

    __slots__ = ("slot_id", "reqs", "shard", "done", "hedge_launched",
                 "outstanding", "collected")

    def __init__(self, slot_id: int, reqs: list[FetchRequest], shard: int):
        self.slot_id = slot_id
        self.reqs = reqs
        self.shard = shard
        self.done = False
        self.hedge_launched = False
        self.outstanding: dict[int, set] = {}     # attempt -> open tags
        self.collected: dict[int, list] = {}      # attempt -> job results


class _FleetQuery:
    """Router-side state machine for one in-flight query."""

    __slots__ = ("idx", "qid", "q", "k", "kind", "gen", "metrics",
                 "start_t", "snapshot", "rounds", "n_jobs", "shards",
                 "hedged", "shed_retries", "slots", "open_slots",
                 "local_results", "payloads", "done")

    def __init__(self, idx: int, qid: int, q: np.ndarray, kind: str,
                 k: int, start_t: float):
        self.idx = idx
        self.qid = qid
        self.q = q
        self.k = k
        self.kind = kind
        self.gen = None
        self.metrics = QueryMetrics()
        self.start_t = start_t
        self.snapshot = (0, 0)
        self.rounds = 0
        self.n_jobs = 0
        self.shards: set[int] = set()
        self.hedged = False
        self.shed_retries = 0
        self.slots: dict[int, _Slot] = {}
        self.open_slots = 0
        self.local_results: list[SearchResult] = []
        self.payloads: dict = {}
        self.done = False


def _scan_plan(q: np.ndarray, reqs: list[FetchRequest], k: int,
               metrics: QueryMetrics):
    """Shard-local cluster job: fetch my lists, scan, return local top-k."""
    payloads = yield FetchBatch(list(reqs))
    metrics.roundtrips += 1
    metrics.requests += len(reqs)
    metrics.bytes_read += sum(r.nbytes for r in reqs)
    return scan_posting_lists(q, (payloads[rq.key] for rq in reqs), k,
                              metrics)


def _fetch_plan(reqs: list[FetchRequest]):
    """Shard-local graph job: fetch my node blocks, return the payloads."""
    payloads = yield FetchBatch(list(reqs))
    return payloads


def _merge_metrics(dst: QueryMetrics, src: QueryMetrics) -> None:
    for f in dataclasses.fields(QueryMetrics):
        setattr(dst, f.name, getattr(dst, f.name) + getattr(src, f.name))


class FleetRouter:
    """Closed-loop scatter-gather serving over N shard servers."""

    def __init__(self, index, cfg: FleetConfig, partition=None):
        self.index = index
        self.cfg = cfg
        self.partition = partition if partition is not None else \
            partition_for_index(index, cfg.n_shards, cfg.replication,
                                seed=cfg.seed)
        if self.partition.n_shards != cfg.n_shards:
            raise ValueError(
                f"partition has {self.partition.n_shards} shards, config "
                f"says {cfg.n_shards}")
        self.kind = self.partition.kind
        self.dim = index.meta.dim
        pq = getattr(index.meta, "pq", None)
        self.pq_m = pq.m if pq is not None else 0

    def _shard_engine_cfg(self, shard_id: int) -> EngineConfig:
        cfg = self.cfg
        return EngineConfig(
            storage=cfg.storage, concurrency=1,
            cache_bytes=cfg.cache_bytes, cache_policy=cfg.cache_policy,
            hit_latency_s=cfg.hit_latency_s, compute=cfg.compute,
            seed=cfg.seed + shard_id * 7919)

    # ------------------------------------------------------------- run ---
    def run(self, queries: np.ndarray, params: SearchParams,
            query_ids: Iterable[int] | None = None) -> FleetReport:
        cfg = self.cfg
        qids = list(query_ids) if query_ids is not None else list(
            range(len(queries)))
        self.servers = [
            ShardServer(s, self._shard_engine_cfg(s), self.index.store,
                        dim=self.dim, pq_m=self.pq_m,
                        max_inflight=cfg.shard_concurrency,
                        queue_depth=cfg.queue_depth,
                        on_complete=self._job_done)
            for s in range(cfg.n_shards)]
        self._events: list = []            # (t, seq, kind, payload)
        self._seq = 0
        self._ctx: dict[int, tuple] = {}   # tag -> (query, slot, attempt, t)
        self._tag_seq = 0
        self._slot_seq = 0
        self._lat: deque = deque(maxlen=256)
        self._rng = np.random.default_rng(cfg.seed ^ 0xF1EE7)
        self._records: list[FleetQueryRecord] = []
        self._jobs_total = 0
        self._hedges = 0
        self._hedge_wins = 0
        pending = list(range(len(queries)))
        pending.reverse()

        def start_next(t: float) -> None:
            if not pending:
                return
            qi = pending.pop()
            self._begin_query(qi, qids[qi], queries[qi], params, t)

        self._start_next = start_next
        for _ in range(min(cfg.concurrency, len(pending))):
            start_next(0.0)

        while True:
            t_router = self._events[0][0] if self._events else float("inf")
            t_shard = float("inf")
            shard = None
            for srv in self.servers:
                ts = srv.next_event_time()
                if ts is not None and ts < t_shard:
                    t_shard = ts
                    shard = srv
            if t_router == float("inf") and shard is None:
                break
            if t_router <= t_shard:
                t, _, kind, payload = heapq.heappop(self._events)
                self._dispatch(kind, payload, t)
            else:
                shard.advance_to(t_shard)

        wall = max((r.end_t for r in self._records), default=0.0)
        stats = [srv.finalize_stats() for srv in self.servers]
        return FleetReport(
            records=self._records, shard_stats=stats, wall_time_s=wall,
            n_shards=cfg.n_shards, replication=cfg.replication,
            concurrency=cfg.concurrency, jobs_total=self._jobs_total,
            hedges_launched=self._hedges, hedge_wins=self._hedge_wins,
            sheds_total=sum(s.sheds for s in stats),
            submissions_total=sum(s.submissions for s in stats))

    # ----------------------------------------------------- query driver --
    def _price(self, fq: _FleetQuery) -> float:
        """Charge router-side compute since the last checkpoint."""
        m = fq.metrics
        d0, p0 = fq.snapshot
        fq.snapshot = (m.dist_comps, m.pq_dist_comps)
        return plan_compute_seconds(m.dist_comps - d0,
                                    m.pq_dist_comps - p0,
                                    self.dim, self.pq_m, self.cfg.compute)

    def _begin_query(self, idx: int, qid: int, q: np.ndarray,
                     params: SearchParams, t: float) -> None:
        fq = _FleetQuery(idx, qid, q, self.kind, params.k, t)
        meta = self.index.meta
        if self.kind == "cluster":
            lids, ndist = self.index.select_lists(q, params.nprobe)
            fq.metrics.dist_comps += ndist
            fq.metrics.lists_visited = len(lids)
            reqs = [FetchRequest(("list", int(i)),
                                 int(meta.list_nbytes[i])) for i in lids]
            self._push(t + self._price(fq), "scatter", (fq, reqs))
        else:
            fq.gen = self.index.search_plan(q, params, fq.metrics)
            batch = next(fq.gen)
            self._push(t + self._price(fq), "scatter",
                       (fq, list(batch.requests)))

    def _push(self, t: float, kind: str, payload) -> None:
        heapq.heappush(self._events, (t, self._seq, kind, payload))
        self._seq += 1

    def _dispatch(self, kind: str, payload, t: float) -> None:
        if kind == "scatter":
            fq, reqs = payload
            self._scatter(fq, reqs, t)
        elif kind == "hedge":
            fq, slot = payload
            self._maybe_hedge(fq, slot, t)
        elif kind == "retry":
            fq, slot = payload
            self._retry_slot(fq, slot, t)

    # ---------------------------------------------------------- scatter --
    def _pick_replica(self, owners: tuple[int, ...],
                      exclude: int | None = None) -> int:
        """Power-of-two-choices by shard queue depth."""
        cand = [s for s in owners if s != exclude]
        if not cand:
            cand = list(owners)
        if len(cand) == 1:
            return cand[0]
        if len(cand) == 2:
            a, b = cand
        else:
            i, j = self._rng.choice(len(cand), size=2, replace=False)
            a, b = cand[int(i)], cand[int(j)]
        la, lb = self.servers[a].load, self.servers[b].load
        if la != lb:
            return a if la < lb else b
        return min(a, b)

    def _scatter(self, fq: _FleetQuery, reqs: list[FetchRequest],
                 t: float) -> None:
        """Fan one round's requests out by replica-chosen owner."""
        fq.rounds += 1
        fq.slots = {}
        fq.payloads = {}
        groups: dict[int, list[FetchRequest]] = {}
        for rq in reqs:
            shard = self._pick_replica(self.partition.owners(rq.key))
            groups.setdefault(shard, []).append(rq)
        for shard in sorted(groups):
            slot = _Slot(self._slot_seq, groups[shard], shard)
            self._slot_seq += 1
            fq.slots[slot.slot_id] = slot
        fq.open_slots = len(fq.slots)
        for slot in fq.slots.values():
            self._submit_primary(fq, slot, t)

    def _make_plan(self, fq: _FleetQuery, reqs: list[FetchRequest],
                   metrics: QueryMetrics):
        if self.kind == "cluster":
            return _scan_plan(fq.q, reqs, fq.k, metrics)
        return _fetch_plan(reqs)

    def _retry_slot(self, fq: _FleetQuery, slot: _Slot, t: float) -> None:
        """A shed slot comes back with fresh per-key replica choice,
        avoiding the shard that shed (loads have changed meanwhile).
        Keys that re-group onto several shards split into new slots."""
        if slot.done or fq.done:
            return
        groups: dict[int, list[FetchRequest]] = {}
        for rq in slot.reqs:
            owners = self.partition.owners(rq.key)
            shard = self._pick_replica(
                owners, exclude=slot.shard if len(owners) > 1 else None)
            groups.setdefault(shard, []).append(rq)
        if len(groups) == 1:
            slot.shard = next(iter(groups))
            self._submit_primary(fq, slot, t)
            return
        del fq.slots[slot.slot_id]
        fq.open_slots -= 1
        for shard in sorted(groups):
            ns = _Slot(self._slot_seq, groups[shard], shard)
            self._slot_seq += 1
            fq.slots[ns.slot_id] = ns
            fq.open_slots += 1
            self._submit_primary(fq, ns, t)

    def _submit_primary(self, fq: _FleetQuery, slot: _Slot,
                        t: float) -> None:
        """Submit a slot to its chosen shard; shed -> backoff retry."""
        cfg = self.cfg
        if slot.done or fq.done:
            return
        shard = slot.shard
        metrics = QueryMetrics()
        tag = self._tag_seq
        self._tag_seq += 1
        plan = self._make_plan(fq, slot.reqs, metrics)
        if self.servers[shard].try_submit(t, plan, metrics, tag):
            slot.outstanding.setdefault(0, set()).add(tag)
            slot.collected.setdefault(0, [])
            self._ctx[tag] = (fq, slot, 0, t)
            self._jobs_total += 1
            fq.n_jobs += 1
            fq.shards.add(shard)
            if (cfg.hedge and cfg.replication > 1
                    and not slot.hedge_launched
                    and len(self._lat) >= cfg.hedge_min_samples):
                deadline = float(np.percentile(
                    np.asarray(self._lat), cfg.hedge_percentile))
                self._push(t + deadline, "hedge", (fq, slot))
        else:
            fq.shed_retries += 1
            self._push(t + cfg.shed_retry_s, "retry", (fq, slot))

    def _maybe_hedge(self, fq: _FleetQuery, slot: _Slot, t: float) -> None:
        """Deadline fired: re-issue the slot's keys on the other replicas."""
        if fq.done or slot.done or slot.hedge_launched:
            return
        slot.hedge_launched = True
        groups: dict[int, list[FetchRequest]] = {}
        for rq in slot.reqs:
            owners = self.partition.owners(rq.key)
            alt = [s for s in owners if s != slot.shard]
            if not alt:
                return                     # un-hedgeable key (R=1)
            shard = self._pick_replica(tuple(alt))
            groups.setdefault(shard, []).append(rq)
        # hedge only when every target replica would admit the duplicate
        # right now — a loaded fleet gets no speculative extra work, and
        # no hedge sub-job is ever orphaned by a partial shed.
        if any(not self.servers[s].has_capacity for s in groups):
            return
        self._hedges += 1
        fq.hedged = True
        slot.outstanding[1] = set()
        slot.collected[1] = []
        for shard in sorted(groups):
            metrics = QueryMetrics()
            tag = self._tag_seq
            self._tag_seq += 1
            plan = self._make_plan(fq, groups[shard], metrics)
            self.servers[shard].try_submit(t, plan, metrics, tag)
            slot.outstanding[1].add(tag)
            self._ctx[tag] = (fq, slot, 1, t)
            self._jobs_total += 1
            fq.n_jobs += 1
            fq.shards.add(shard)

    # ----------------------------------------------------------- gather --
    def _job_done(self, shard_id: int, job: JobRecord) -> None:
        ctx = self._ctx.pop(job.tag, None)
        if ctx is None:
            return
        fq, slot, attempt, t_submit = ctx
        self._lat.append(job.end_t - t_submit)
        _merge_metrics(fq.metrics, job.metrics)
        if fq.done or slot.done or attempt not in slot.outstanding:
            return                          # stale (hedge race loser)
        open_tags = slot.outstanding[attempt]
        open_tags.discard(job.tag)
        slot.collected[attempt].append(job.result)
        if open_tags:
            return                          # more sub-jobs of this attempt
        slot.done = True
        if attempt > 0:
            self._hedge_wins += 1
        if self.kind == "cluster":
            fq.local_results.extend(slot.collected[attempt])
        else:
            for payloads in slot.collected[attempt]:
                fq.payloads.update(payloads)
        fq.open_slots -= 1
        if fq.open_slots == 0:
            self._round_done(fq, job.end_t)

    def _round_done(self, fq: _FleetQuery, t: float) -> None:
        if self.kind == "cluster":
            ids, dists = merge_topk(fq.local_results, fq.k)
            self._finish_query(fq, t, ids, dists)
            return
        # graph: resume the beam-search generator with this round's blocks
        # (router-side snapshot excludes shard-merged counters, so compute
        # pricing charges only the plan's own ADC/exact work)
        fq.snapshot = (fq.metrics.dist_comps, fq.metrics.pq_dist_comps)
        try:
            batch = fq.gen.send(fq.payloads)
        except StopIteration as stop:
            res = stop.value
            self._finish_query(fq, t + self._price(fq), res.ids, res.dists)
            return
        self._push(t + self._price(fq), "scatter",
                   (fq, list(batch.requests)))

    def _finish_query(self, fq: _FleetQuery, t: float, ids: np.ndarray,
                      dists: np.ndarray) -> None:
        fq.done = True
        self._records.append(FleetQueryRecord(
            qid=fq.qid, start_t=fq.start_t, end_t=t, ids=ids, dists=dists,
            metrics=fq.metrics, rounds=fq.rounds, n_jobs=fq.n_jobs,
            shards_touched=len(fq.shards), hedged=fq.hedged,
            shed_retries=fq.shed_retries))
        self._start_next(t)


def run_fleet(index, queries: np.ndarray, params: SearchParams,
              cfg: FleetConfig,
              query_ids: Iterable[int] | None = None) -> FleetReport:
    """One-call fleet evaluation (the fleet analogue of run_workload)."""
    return FleetRouter(index, cfg).run(queries, params,
                                       query_ids=query_ids)
