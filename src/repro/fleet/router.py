"""Scatter-gather fleet routing on one shared event kernel.

The router owns the compute-node-resident index metadata (BKT centroids /
PQ codes — what the paper's single node caches, §2.1) and serves queries
across N :class:`ShardGroup` s, all registered on one deterministic
:class:`repro.sim.Kernel`:

* **Cluster queries** — centroid search runs at the router; the selected
  posting lists scatter to shard-local *scan jobs* (fetch + distance scan
  + local top-k, priced on the shard's compute), and the router merges the
  local top-ks into the global result.  One scatter round per query
  (paper §2.3.1's single dependency-free roundtrip, now fanned out).
* **Graph queries** — beam-search state stays at the router (the PQ/ADC
  frontier is metadata-resident); each expansion round's W node-block
  fetches scatter to the owning shards and gather before the next round,
  preserving the ``rt × TTFB`` floor per shard.

Routing policies:

* **power-of-two-choices** replica selection: among a key's R live
  replica owners, sample two and pick the shorter queue — the classic
  load-balance result, and the reason replication pays beyond fault
  tolerance.
* **hedged requests**: once enough job latencies are observed, a slot
  whose job outlives the fleet's p-th latency percentile is re-issued to
  the other replicas; first completion wins (kernel timers, cancellable).
* **backpressure**: a shed submission (admission queue full) is retried
  after ``shed_retry_s`` with fresh replica choice — sheds delay queries
  and show up in shed_rate, they never drop data.

Scenario axes (all deterministic for a given seed):

* **arrivals** (:mod:`repro.sim.arrivals`): closed loop (default — the
  regime under which this file reproduces the pre-kernel reports
  exactly), open-loop Poisson with diurnal/burst modulation, or trace
  replay.  Open-loop arrivals queue in a router backlog behind a window
  of ``concurrency`` in-service queries.
* **faults** (:mod:`repro.sim.faults`): shard kill/revive schedules; the
  victims' jobs are re-routed to surviving replica owners (recall is
  unchanged when R >= 2); unroutable keys back off until recovery.
* **autoscaling** (:mod:`repro.sim.autoscale`): an SLO controller adds /
  drains shard instances; the report prices the run in shards·seconds.

**Tenancy**: the router serves any number of *tenant contexts*
(:class:`_TenantCtx`) over the same shard groups — each tenant has its
own index, partition, arrival process, admission window (its fair share
of ``concurrency``) and SLO accounting, while caches, NIC bandwidth and
GET tokens are shared fleet-wide.  Fetch keys are namespaced by tenant
id, so one instance cache can hold (and a sharing policy can arbitrate)
every tenant's objects.  The single-tenant :meth:`FleetRouter.run` is
the degenerate one-context case and reproduces the pre-tenancy reports
bit-exactly; :mod:`repro.tenancy` builds the N-context runs.

Determinism: one event kernel, (time, seq) total order, per-component
seeded RNG streams — identical seeds give bit-identical
:class:`FleetReport` JSON.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Iterable

import numpy as np

from repro.cache.slru import CACHE_POLICIES
from repro.core.cluster_index import dedup_topk, scan_posting_lists
from repro.core.cost_model import ComputeSpec, plan_compute_seconds
from repro.core.types import (FetchBatch, FetchRequest, QueryMetrics,
                              SearchParams, SearchResult, recall_at_k)
from repro.fleet.metrics import FleetQueryRecord, FleetReport, FleetSeries
from repro.fleet.partition import partition_for_index
from repro.fleet.server import ShardGroup, ShardServer
from repro.obs.cost import PriceBook, fleet_cost
from repro.obs.explain import ExplainCollector, ExplainConfig
from repro.obs.monitor import FleetMonitor, MonitorConfig
from repro.obs.mrc import MRCConfig, MRCProfiler
from repro.obs.trace import NULL_TRACER, Tracer, emit_job_spans
from repro.serving.engine import EngineConfig, JobRecord
from repro.sim.admission import AdmissionWindow
from repro.sim.arrivals import ArrivalProcess, ClosedLoop
from repro.sim.autoscale import AutoscaleConfig, Autoscaler
from repro.sim.faults import FaultSchedule
from repro.sim.kernel import Kernel
from repro.storage.spec import TOS, StorageSpec
from repro.storage.tier import TIER_POLICIES, TierConfig

#: A slot that cannot be routed (all owners down) retries on a backoff
#: timer; past this many retries the scenario is declared unservable.
RETRY_LIMIT = 100_000


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Everything that defines a serving fleet (the tuner's new axis)."""

    n_shards: int = 4
    replication: int = 1
    storage: StorageSpec = TOS
    concurrency: int = 8           # in-service fleet queries (window)
    shard_concurrency: int = 4     # jobs executing per shard
    queue_depth: int = 16          # shard admission queue bound
    cache_bytes: int = 0           # per-shard segment cache budget
    cache_policy: str = "none"     # "none" | "slru"
    hedge: bool = False
    hedge_percentile: float = 95.0
    hedge_min_samples: int = 24
    shed_retry_s: float = 1e-3
    hit_latency_s: float = 100e-6
    compute: ComputeSpec = dataclasses.field(default_factory=ComputeSpec)
    #: "analytic" prices compute from the ComputeSpec constants;
    #: "kernel" routes every shard's compute through a repro.exec
    #: KernelBackend — batch-coalesced and priced from a measured
    #: CalibrationTable (see docs/execution.md)
    backend: str = "analytic"
    batch_window_s: float = 0.0    # kernel backend: coalescing window
    calibration: str | None = None  # table path; None = committed default
    #: per-instance local NVMe tier (repro.storage.tier); 0 keeps the
    #: flat DRAM -> remote hierarchy bit-exact (no tier is constructed)
    nvme_bytes: int = 0
    tier_policy: str = "second-hit"  # "second-hit" | "admit-always"
    nvme_writeback: bool = False   # compaction output lands on NVMe first
    seed: int = 0

    def __post_init__(self):
        if self.backend not in ("analytic", "kernel"):
            raise ValueError(
                f"backend must be 'analytic' or 'kernel', got "
                f"{self.backend!r}")
        if self.batch_window_s < 0:
            raise ValueError(f"batch_window_s must be >= 0, got "
                             f"{self.batch_window_s}")
        if self.backend == "analytic" and (self.batch_window_s
                                           or self.calibration):
            raise ValueError(
                "batch_window_s/calibration are kernel-backend knobs "
                "(set backend='kernel')")
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if not 1 <= self.replication <= self.n_shards:
            raise ValueError(
                f"replication must be in [1, n_shards={self.n_shards}], "
                f"got {self.replication}")
        if self.cache_policy not in CACHE_POLICIES or \
                self.cache_policy == "pinned":
            raise ValueError(
                f"fleet cache_policy must be 'none' or 'slru', "
                f"got {self.cache_policy!r}")
        if self.concurrency < 1 or self.shard_concurrency < 1:
            raise ValueError("concurrency and shard_concurrency must be "
                             ">= 1")
        if self.queue_depth < 0:
            raise ValueError(f"queue_depth must be >= 0, got "
                             f"{self.queue_depth}")
        if self.hedge and not 50.0 <= self.hedge_percentile < 100.0:
            raise ValueError(
                f"hedge_percentile must be in [50, 100), got "
                f"{self.hedge_percentile}")
        if self.nvme_bytes < 0:
            raise ValueError(f"nvme_bytes must be >= 0, got "
                             f"{self.nvme_bytes}")
        if self.tier_policy not in TIER_POLICIES:
            raise ValueError(
                f"tier_policy must be one of {TIER_POLICIES}, got "
                f"{self.tier_policy!r}")
        if self.nvme_bytes == 0 and (self.tier_policy != "second-hit"
                                     or self.nvme_writeback):
            raise ValueError(
                "tier_policy/nvme_writeback are NVMe-tier knobs "
                "(set nvme_bytes > 0)")

    def to_dict(self) -> dict:
        d = dict(n_shards=self.n_shards, replication=self.replication,
                 storage=self.storage.name,
                 concurrency=self.concurrency,
                 shard_concurrency=self.shard_concurrency,
                 queue_depth=self.queue_depth,
                 cache_bytes=self.cache_bytes,
                 cache_policy=self.cache_policy, hedge=self.hedge,
                 hedge_percentile=self.hedge_percentile, seed=self.seed)
        # keys appear only off the default so analytic config dicts stay
        # byte-identical to pre-backend goldens/baselines
        if self.backend != "analytic":
            d.update(backend=self.backend,
                     batch_window_us=round(self.batch_window_s * 1e6, 3),
                     calibration=self.calibration or "default")
        if self.nvme_bytes > 0:
            d.update(nvme_bytes=self.nvme_bytes,
                     tier_policy=self.tier_policy,
                     nvme_writeback=self.nvme_writeback)
        return d


def merge_topk(results: list[SearchResult], k: int
               ) -> tuple[np.ndarray, np.ndarray]:
    """Global top-k over shard-local top-ks, deduplicating replica ids.

    Every member of the true global top-k is necessarily inside its own
    shard's local top-k, so the merge is lossless — same kernel as the
    single-node scan (``dedup_topk``).
    """
    ids = np.concatenate([r.ids for r in results])
    d = np.concatenate([r.dists for r in results])
    valid = ids >= 0
    return dedup_topk(ids[valid], d[valid], k)


class _TenantCtx:
    """One tenant's serving state inside a fleet run.

    The router itself is tenant-agnostic: every query belongs to a
    context carrying the tenant's index, partition, workload, admission
    window and SLO bookkeeping.  Fetch keys are namespaced
    ``(tid, *native_key)`` so stores and caches shared across tenants
    cannot collide.
    """

    __slots__ = ("tid", "name", "index", "partition", "kind", "dim",
                 "pq_m", "queries", "params", "qids", "arrivals", "window",
                 "weight", "slo_s", "updates", "ingest_cfg", "adm",
                 "records", "good_total", "ingest_agents", "ingest_report")

    def __init__(self, tid: int, index, partition, queries: np.ndarray,
                 params: SearchParams, qids: list[int],
                 arrivals: ArrivalProcess, window: int,
                 slo_s: float | None = None, weight: float = 1.0,
                 name: str | None = None, updates=None, ingest_cfg=None):
        self.tid = tid
        self.name = name if name is not None else f"tenant{tid}"
        self.index = index
        self.partition = partition
        self.kind = partition.kind
        self.dim = index.meta.dim
        pq = getattr(index.meta, "pq", None)
        self.pq_m = pq.m if pq is not None else 0
        self.queries = queries
        self.params = params
        self.qids = qids
        self.arrivals = arrivals
        self.window = window
        self.weight = weight
        self.slo_s = slo_s
        self.updates = updates
        self.ingest_cfg = ingest_cfg
        self.adm: AdmissionWindow | None = None
        self.records: list[FleetQueryRecord] = []
        self.good_total = 0
        self.ingest_agents: dict[int, object] = {}
        self.ingest_report = None


class _TenantStore:
    """Key-dispatching view over the tenants' object stores: the shard
    engines see one store whose keys are ``(tid, *native_key)``."""

    __slots__ = ("ctxs",)

    def __init__(self, ctxs: list[_TenantCtx]):
        self.ctxs = ctxs

    def get(self, key):
        return self.ctxs[key[0]].index.store.get(key[1:])


class _Slot:
    """One shard-destined sub-request of one scatter round."""

    __slots__ = ("slot_id", "reqs", "shard", "done", "hedge_launched",
                 "outstanding", "collected")

    def __init__(self, slot_id: int, reqs: list[FetchRequest], shard: int):
        self.slot_id = slot_id
        self.reqs = reqs
        self.shard = shard
        self.done = False
        self.hedge_launched = False
        self.outstanding: dict[int, set] = {}     # attempt -> open tags
        self.collected: dict[int, list] = {}      # attempt -> job results


class _FleetQuery:
    """Router-side state machine for one in-flight query."""

    __slots__ = ("ctx", "idx", "qid", "q", "k", "kind", "gen", "metrics",
                 "start_t", "arrive_t", "snapshot", "rounds", "n_jobs",
                 "shards", "hedged", "shed_retries", "slots", "open_slots",
                 "local_results", "payloads", "done", "span", "round_span")

    def __init__(self, ctx: _TenantCtx, idx: int, qid: int, q: np.ndarray,
                 k: int, start_t: float, arrive_t: float):
        self.ctx = ctx
        self.idx = idx
        self.qid = qid
        self.q = q
        self.k = k
        self.kind = ctx.kind
        self.gen = None
        self.metrics = QueryMetrics()
        self.start_t = start_t
        self.arrive_t = arrive_t
        self.snapshot = (0, 0)
        self.rounds = 0
        self.n_jobs = 0
        self.shards: set[int] = set()
        self.hedged = False
        self.shed_retries = 0
        self.slots: dict[int, _Slot] = {}
        self.open_slots = 0
        self.local_results: list[SearchResult] = []
        self.payloads: dict = {}
        self.done = False
        self.span = None               # root "query" span when tracing
        self.round_span = None         # open "round" span when tracing


def _scan_plan(q: np.ndarray, reqs: list[FetchRequest], k: int,
               metrics: QueryMetrics, delta_fn=None, dead_fn=None):
    """Shard-local cluster job: fetch my lists, scan, return local top-k.

    ``delta_fn``/``dead_fn`` (live-ingest runs) are evaluated at scan
    time — after the fetch completes — so the job sees the shard's delta
    points for the probed lists and its tombstones *as of the scan
    instant*, not as of scatter: freshness is measured where it happens.
    """
    payloads = yield FetchBatch(list(reqs))
    metrics.roundtrips += 1
    metrics.requests += len(reqs)
    metrics.bytes_read += sum(r.nbytes for r in reqs)
    items = [payloads[rq.key] for rq in reqs]
    if delta_fn is not None:
        ids, vecs = delta_fn()
        if len(ids):
            items.append((ids, vecs))
    exclude = dead_fn() if dead_fn is not None else None
    return scan_posting_lists(q, items, k, metrics, exclude=exclude)


def _fetch_plan(reqs: list[FetchRequest]):
    """Shard-local graph job: fetch my node blocks, return the payloads."""
    payloads = yield FetchBatch(list(reqs))
    return payloads


def _merge_metrics(dst: QueryMetrics, src: QueryMetrics) -> None:
    for f in dataclasses.fields(QueryMetrics):
        setattr(dst, f.name, getattr(dst, f.name) + getattr(src, f.name))


class FleetRouter:
    """Scatter-gather serving over N shard groups on one event kernel."""

    def __init__(self, index, cfg: FleetConfig, partition=None):
        self.index = index
        self.cfg = cfg
        self.partition = partition if partition is not None else \
            partition_for_index(index, cfg.n_shards, cfg.replication,
                                seed=cfg.seed)
        if self.partition.n_shards != cfg.n_shards:
            raise ValueError(
                f"partition has {self.partition.n_shards} shards, config "
                f"says {cfg.n_shards}")
        self.kind = self.partition.kind
        self.dim = index.meta.dim
        pq = getattr(index.meta, "pq", None)
        self.pq_m = pq.m if pq is not None else 0
        #: tenancy installs a per-instance cache-assembly factory here
        #: (None -> each ShardServer builds cfg.make_cache())
        self._cache_factory = None

    @functools.cached_property
    def _exec_table(self):
        """--backend kernel: the calibration table, resolved once per
        router (lazy so subclasses with their own __init__ — the
        tenancy router — get it too); every shard instance gets its own
        coalescer over this shared table."""
        if self.cfg.backend != "kernel":
            return None
        from repro.exec import load_table
        return load_table(self.cfg.calibration)

    def _shard_engine_cfg(self, shard_id: int, instance: int
                          ) -> EngineConfig:
        cfg = self.cfg
        tier = None
        if cfg.nvme_bytes > 0:
            tier = TierConfig(capacity_bytes=cfg.nvme_bytes,
                              policy=cfg.tier_policy,
                              writeback=cfg.nvme_writeback)
        return EngineConfig(
            storage=cfg.storage, concurrency=1,
            cache_bytes=cfg.cache_bytes, cache_policy=cfg.cache_policy,
            hit_latency_s=cfg.hit_latency_s, compute=cfg.compute,
            seed=cfg.seed + shard_id * 7919 + instance * 104729,
            tier=tier)

    def _spawn_server(self, shard_id: int, instance: int) -> ShardServer:
        cfg = self.cfg
        backend_factory = None
        if self._exec_table is not None:
            from repro.exec import KernelBackend
            backend_factory = lambda: KernelBackend(  # noqa: E731
                self._exec_table, window_s=cfg.batch_window_s,
                shard_id=shard_id, instance=instance)
        return ShardServer(
            shard_id, self._shard_engine_cfg(shard_id, instance),
            self._store, kernel=self.kernel, dim=self.ctxs[0].dim,
            pq_m=self.ctxs[0].pq_m, instance=instance,
            max_inflight=cfg.shard_concurrency,
            queue_depth=cfg.queue_depth, on_complete=self._job_done,
            cache_factory=self._cache_factory,
            backend_factory=backend_factory)

    # ------------------------------------------------------------- run ---
    def run(self, queries: np.ndarray, params: SearchParams,
            query_ids: Iterable[int] | None = None, *,
            arrivals: ArrivalProcess | None = None,
            faults: FaultSchedule | None = None,
            autoscale: AutoscaleConfig | None = None,
            slo_s: float | None = None,
            series_dt: float | None = None,
            updates=None, ingest=None,
            tracer: Tracer | None = None,
            monitor: MonitorConfig | None = None,
            pricebook: PriceBook | None = None,
            explain: bool | ExplainConfig = False,
            mrc: bool | MRCConfig = False) -> FleetReport:
        """``updates`` (an :class:`repro.ingest.stream.UpdateStream`)
        turns the run into a read-write workload: the router forwards
        each update to the shard groups owning its keys, every owner
        group ingests independently (its own delta tier, freshness lag
        and compaction schedule, with compaction I/O charged to its own
        instances' storage sims), and rewritten objects are invalidated
        from every instance cache.  With no updates the run is
        byte-identical to the pure-query path.

        ``monitor`` attaches live SLO monitors with burn-rate alerting
        (``repro.obs.monitor``); unless ``monitor.actions`` is set they
        only observe, and the run stays bit-exact.  ``pricebook``
        prices the run (``repro.obs.cost``) into the report's ``cost``
        block — pure post-hoc arithmetic, never a kernel event.

        ``explain`` attaches the tail-explanation collector
        (``repro.obs.explain``; requires ``tracer``) and ``mrc`` the
        online miss-ratio-curve profiler (``repro.obs.mrc``).  Both are
        pure observers — explained/profiled runs stay bit-exact — and
        land in the report's ``explain`` / ``mrc`` blocks."""
        cfg = self.cfg
        qids = list(query_ids) if query_ids is not None else list(
            range(len(queries)))
        arr = arrivals if arrivals is not None else ClosedLoop(
            cfg.concurrency, n_total=len(queries))
        window = arr.window if arr.window is not None else cfg.concurrency
        ctx = _TenantCtx(
            0, self.index, self.partition, queries, params, qids, arr,
            window,
            slo_s=(autoscale.slo_p99_s if autoscale is not None
                   and slo_s is None else slo_s),
            updates=updates, ingest_cfg=ingest)
        wall = self._execute([ctx], faults=faults, autoscale=autoscale,
                             series_dt=series_dt, tracer=tracer,
                             monitor=monitor, pricebook=pricebook,
                             explain=explain, mrc=mrc)
        self.index = ctx.index          # make_mutable may have wrapped it
        stats = [srv.finalize_stats() for g in self.groups
                 for srv in g.all_servers()]
        shards_seconds = sum(srv.active_seconds(wall) for g in self.groups
                             for srv in g.all_servers())
        ingest_dict = None
        if ctx.ingest_report is not None:
            ingest_dict = ctx.ingest_report.to_dict(ctx.records)
        report = FleetReport(
            records=ctx.records, shard_stats=stats, wall_time_s=wall,
            n_shards=cfg.n_shards, replication=cfg.replication,
            concurrency=cfg.concurrency, jobs_total=self._jobs_total,
            hedges_launched=self._hedges, hedge_wins=self._hedge_wins,
            sheds_total=sum(s.sheds for s in stats),
            submissions_total=sum(s.submissions for s in stats),
            scenario=arr.kind, n_arrivals=ctx.adm.arrivals_total,
            offered_qps=ctx.adm.offered_qps(wall), slo_s=ctx.slo_s,
            good_total=ctx.good_total if ctx.slo_s is not None else None,
            series=self._series, shards_seconds=shards_seconds,
            scale_events=(self._autoscaler.events
                          if self._autoscaler is not None else None),
            fault_log=self._fault_log if faults is not None else None,
            ingest=ingest_dict)
        self.attach_obs(report)
        return report

    def attach_obs(self, report: FleetReport) -> None:
        """Attach the monitor's alert block and the priced ``cost``
        block to a finished report.  Costing reads the report's own
        aggregates, so it must run after construction; both land in
        dedicated fields so bit-exactness checks can compare everything
        else unchanged."""
        if self._slo_monitor is not None:
            report.alerts = self._slo_monitor.summary()
            report.alerts["actions"] = list(self._alert_actions)
        if self._pricebook is not None:
            report.cost = fleet_cost(report, self.cfg, self._pricebook)
        if self._explain is not None:
            report.explain = self._explain.explain_tail()
        if self._mrc is not None:
            report.mrc = self._mrc.to_dict(wall_s=report.wall_time_s)

    def _execute(self, ctxs: list[_TenantCtx], *,
                 faults: FaultSchedule | None = None,
                 autoscale: AutoscaleConfig | None = None,
                 series_dt: float | None = None,
                 tracer: Tracer | None = None,
                 monitor: MonitorConfig | None = None,
                 pricebook: PriceBook | None = None,
                 explain: bool | ExplainConfig = False,
                 mrc: bool | MRCConfig = False) -> float:
        """Drive the shared kernel over all tenant contexts; returns the
        run's wall time.  One context reproduces the pre-tenancy event
        sequence exactly (same RNG streams, same scheduling order).

        ``tracer`` records the run's span trees and metrics.  Tracing
        never perturbs the schedule — spans are written from state the
        router already has — so traced and untraced runs are bit-exact.
        """
        cfg = self.cfg
        self.ctxs = ctxs
        self._store = _TenantStore(ctxs)
        self.kernel = Kernel(seed=cfg.seed)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.tracer.attach(self.kernel)
        # Tail-explanation collector: folds every finished query's span
        # tree into exemplar reservoirs + windowed attribution.  Pure
        # observer — it reads spans the tracer already holds.
        self._explain = None
        if explain:
            if not self.tracer.enabled:
                raise ValueError("explain requires a tracer")
            self._explain = ExplainCollector(
                self.tracer,
                explain if isinstance(explain, ExplainConfig) else None)
        # Online MRC profiler: attaches to every instance cache as a
        # read-only access-stream observer.  Wrapping the cache factory
        # (rather than the built caches) keeps the observer attached
        # across cold-cache fault recovery and autoscale spawns.
        self._mrc = None
        if mrc:
            names = {c.tid: ("fleet" if len(ctxs) == 1 else c.name)
                     for c in ctxs}
            self._mrc = MRCProfiler(
                mrc if isinstance(mrc, MRCConfig) else None,
                ref_bytes=cfg.cache_bytes, tenant_names=names)
            base_factory = self._cache_factory
            if base_factory is None:
                base_factory = self._shard_engine_cfg(0, 0).make_cache
            self._cache_factory = self._mrc.wrap_factory(base_factory)
        self.groups = [ShardGroup(s, self._spawn_server)
                       for s in range(cfg.n_shards)]
        for ctx in ctxs:
            ctx.adm = AdmissionWindow(
                self.kernel, ctx.window,
                lambda item, t, ctx=ctx: self._begin_query(
                    ctx, item[0], item[1], t))
        self._ctx: dict[int, tuple] = {}   # tag -> (query, slot, attempt, t)
        self._live_queries: set[_FleetQuery] = set()
        self._tag_seq = 0
        self._slot_seq = 0
        self._lat: deque = deque(maxlen=256)
        self._rng = self.kernel.rng("router", seed=cfg.seed ^ 0xF1EE7)
        self._jobs_total = 0
        self._hedges = 0
        self._hedge_wins = 0
        self._retry_pending = 0
        self._fault_log: list[dict] = []
        self.recent_sojourns: deque = deque(
            maxlen=autoscale.window if autoscale is not None else 256)
        # monitor + controller processes
        self._series: FleetSeries | None = None
        self._monitor = None
        self._slice_counts = [0, 0, 0]     # arrived, completed, good
        need_monitor = (series_dt is not None or autoscale is not None
                        or faults is not None or len(ctxs) > 1
                        or any(c.arrivals.kind != "closed" for c in ctxs))
        if need_monitor:
            dt = series_dt if series_dt is not None else 0.05
            self._series = FleetSeries(dt=dt)
            self._monitor = self.kernel.every(dt, self._sample_slice)
        # Periodic metrics snapshots for the trace's counter tracks.  The
        # ticker only *reads* router state; its events consume sequence
        # numbers, which shifts later seqs uniformly and so preserves the
        # relative order of every other event pair — goldens stay exact.
        self._obs_ticker = None
        if self.tracer.enabled:
            self._obs_ticker = self.kernel.every(
                series_dt if series_dt is not None else 0.05,
                self._obs_snapshot)
        # Live SLO monitors (repro.obs.monitor).  Like the obs ticker,
        # the evaluation tick only reads router state and shifts later
        # event seqs uniformly, so monitoring keeps runs bit-exact;
        # only the (opt-in) action bus may perturb the schedule.
        self._pricebook = pricebook
        self._slo_monitor = None
        self._monitor_ticker = None
        self._alert_actions: list[dict] = []
        if monitor is not None:
            self._slo_monitor = FleetMonitor(monitor, tracer=self.tracer)
            if self._explain is not None:
                # every fired alert snapshots its own root-cause bundle
                self._slo_monitor.forensics_provider = (
                    lambda now: self._explain.forensics(
                        now, self.tracer.metrics))
            for ctx in ctxs:
                if ctx.slo_s is not None:
                    self._slo_monitor.monitor(
                        f"{self._mon_name(ctx)}.latency", kind="latency",
                        tenant=ctx.name)
            self._monitor_ticker = self.kernel.every(
                monitor.interval_s, self._monitor_tick)
        # Instance-count limits for scale_up_one/scale_down_one: the
        # autoscaler's bounds when it runs, else the monitor's cap for
        # alert-driven scale-out.
        self._scale_min = 1
        self._scale_max = 4
        if autoscale is not None:
            self._scale_min = autoscale.min_instances
            self._scale_max = autoscale.max_instances
        elif monitor is not None:
            self._scale_max = monitor.max_instances
        self._autoscaler = None
        if autoscale is not None:
            self._autoscaler = Autoscaler(autoscale, self)
            self._autoscaler.start(self.kernel)
        if self._slo_monitor is not None and monitor.actions:
            self._slo_monitor.bus.subscribe(self._alert_scale_out)
            self._slo_monitor.bus.subscribe(self._alert_admission)
        if faults is not None:
            faults.install(self.kernel, self)
        for ctx in ctxs:
            if ctx.updates is not None and len(ctx.updates):
                self._setup_ingest(ctx)
                ctx.updates.start(
                    self.kernel,
                    lambda op, ctx=ctx: self._deliver_update(ctx, op))

        for ctx in ctxs:
            ctx.arrivals.start(
                self.kernel,
                lambda ai, wi, ctx=ctx: self._arrive(ctx, ai, wi),
                len(ctx.queries),
                done=lambda ctx=ctx: self._arrivals_exhausted(ctx))
        self.kernel.run()

        wall = max((r.end_t for ctx in ctxs for r in ctx.records),
                   default=0.0)
        if self._series is not None:
            self._flush_slice(wall)
        for ctx in ctxs:
            if ctx.ingest_report is not None:
                for agent in ctx.ingest_agents.values():
                    agent.finalize()
        return wall

    # ----------------------------------------------------------- ingest --
    def _setup_ingest(self, ctx: _TenantCtx) -> None:
        """One :class:`IngestAgent` per shard group: independent delta
        tier, apply queue and compaction schedule, with compaction I/O
        charged through the group's live instances' storage sims."""
        from repro.ingest.compaction import IngestAgent, IngestConfig
        from repro.ingest.metrics import IngestReport
        from repro.ingest.mutable import make_mutable
        ctx.index = make_mutable(ctx.index)
        ctx.ingest_report = IngestReport()
        cfg = ctx.ingest_cfg if ctx.ingest_cfg is not None else \
            IngestConfig()
        for g in self.groups:
            owned = None
            if ctx.kind == "cluster":
                owned = {li for li in range(ctx.index.meta.n_lists)
                         if g.shard_id in
                         ctx.partition.owners(("list", li))}

            def provider(g=g):
                # write_path IS the remote sim on flat instances; on a
                # write-back tier it lands compaction PUTs locally first
                srv = g.pick()
                return srv.engine.write_path if srv is not None else None

            ctx.ingest_agents[g.shard_id] = IngestAgent(
                ctx.index, site_id=g.shard_id, kernel=self.kernel,
                cfg=cfg, compute=self.cfg.compute, sim_provider=provider,
                report=ctx.ingest_report,
                invalidate=lambda key, ctx=ctx: self._invalidate_key(
                    ctx.tid, key),
                on_new_list=lambda new_li, parent_li, ctx=ctx:
                    self._on_new_list(ctx, new_li, parent_li),
                owned_lists=owned, inflight_floor=self.inflight_floor)
        if (self._slo_monitor is not None
                and self._slo_monitor.cfg.freshness_slo_s is not None):
            bound = self._slo_monitor.cfg.freshness_slo_s
            mname = self._mon_name(ctx)
            ctx.ingest_report.on_apply = (
                lambda kind, lag, ctx=ctx, mname=mname, bound=bound:
                    self._slo_monitor.observe_freshness(
                        self.kernel.now, f"{mname}.freshness", lag,
                        bound, tenant=ctx.name))

    def _invalidate_key(self, tid: int, key) -> None:
        """Broadcast a rewritten object's staleness to every instance
        cache and NVMe tier (non-owners never cached the key; dropping
        is a no-op).  On a write-back tier the owning shards' instances
        additionally admit the rewritten object to NVMe residency at its
        new size — the compaction PUT just landed on their device."""
        wrapped = (tid,) + key
        wb_nbytes = None
        owners: tuple[int, ...] = ()
        if self.cfg.nvme_writeback:
            wb_nbytes = self._key_nbytes(self.ctxs[tid], key)
            if wb_nbytes is not None:
                owners = self.ctxs[tid].partition.owners(key)
        for g in self.groups:
            wb = wb_nbytes if g.shard_id in owners else None
            for srv in g.all_servers():
                srv.invalidate(wrapped, writeback_nbytes=wb)

    @staticmethod
    def _key_nbytes(ctx: _TenantCtx, key) -> int | None:
        """Current (post-install) size of a native fetch key."""
        if key[0] == "list":
            meta = ctx.index.meta
            if key[1] < len(meta.list_nbytes):
                return int(meta.list_nbytes[key[1]])
            return None
        node_nbytes = getattr(ctx.index, "node_nbytes", None)
        return int(node_nbytes()) if callable(node_nbytes) else None

    def _on_new_list(self, ctx: _TenantCtx, new_li: int,
                     parent_li: int) -> None:
        """A re-cluster split: the new posting list inherits the parent's
        replica owners (no data movement) and joins owned-list sets."""
        ctx.partition.inherit(new_li, parent_li)
        owners = set(ctx.partition.owners(("list", new_li)))
        for sid, agent in ctx.ingest_agents.items():
            if agent.owned_lists is not None and sid in owners:
                agent.owned_lists.add(new_li)

    def _deliver_update(self, ctx: _TenantCtx, op) -> None:
        """Route one update to the shard groups owning its keys.  Each
        owner group applies its own copy — delta-tier replication
        mirroring the sealed replication, so any replica owner can serve
        a probed list's fresh points."""
        if ctx.kind == "cluster":
            if op.kind == "insert":
                lists, ndist = ctx.index.assign_lists(op.vec)
            else:
                lists, ndist = ctx.index.lists_of(op.id), 0
            owner_set = {s for li in lists
                         for s in ctx.partition.owners(("list", li))}
            if op.kind == "delete":
                # the victim may still be delta-only on some sites
                for sid, mem in ctx.index.sites.items():
                    if op.id in mem.entries:
                        owner_set.add(sid)
                if not owner_set:
                    # the insert is still in some apply queue (delivered
                    # but not applied): broadcast — per-site FIFO apply
                    # order serializes the delete behind its insert at
                    # the sites that will hold it, and a spurious
                    # tombstone elsewhere clears at that site's next
                    # flush
                    owner_set = set(ctx.ingest_agents)
            for s in sorted(owner_set):
                agent = ctx.ingest_agents[s]
                mine = tuple(li for li in lists if agent.owned_lists
                             is None or li in agent.owned_lists)
                agent.deliver(op, lists=mine, ndist=ndist)
        else:
            # graph delta is single-homed on the primary hash owner; the
            # router's merged search reads every site, so placement does
            # not affect visibility.
            owner = ctx.partition.owners(("node", op.id))[0]
            ctx.ingest_agents[owner].deliver(op, lists=(), ndist=0)

    # ------------------------------------------------- arrivals / window --
    def _arrive(self, ctx: _TenantCtx, arrival_idx: int,
                workload_idx: int) -> None:
        self._slice_counts[0] += 1
        ctx.adm.offer((arrival_idx, workload_idx), key=arrival_idx)

    def _arrivals_exhausted(self, ctx: _TenantCtx) -> None:
        ctx.adm.mark_exhausted()
        self._maybe_shutdown()

    def _maybe_shutdown(self) -> None:
        """Stop the monitor/controller tickers once every tenant's
        workload drains — they would otherwise keep the kernel alive
        forever."""
        if not all(ctx.adm.drained for ctx in self.ctxs):
            return
        if self._monitor is not None:
            self._monitor.cancel()
        if self._obs_ticker is not None:
            self._obs_ticker.cancel()
        if self._monitor_ticker is not None:
            self._monitor_ticker.cancel()
        if self._autoscaler is not None:
            self._autoscaler.stop()

    # ----------------------------------------------------- query driver --
    def _price(self, fq: _FleetQuery) -> float:
        """Charge router-side compute since the last checkpoint.

        On the kernel backend the router's own work (list selection,
        merges) is priced from the same calibration table as the shards
        — at batch-of-one, since router work is per-query."""
        m = fq.metrics
        d0, p0 = fq.snapshot
        fq.snapshot = (m.dist_comps, m.pq_dist_comps)
        if self._exec_table is not None:
            return self._exec_table.plan_seconds(
                m.dist_comps - d0, m.pq_dist_comps - p0,
                fq.ctx.dim, fq.ctx.pq_m)
        return plan_compute_seconds(m.dist_comps - d0,
                                    m.pq_dist_comps - p0,
                                    fq.ctx.dim, fq.ctx.pq_m,
                                    self.cfg.compute)

    def _begin_query(self, ctx: _TenantCtx, arrival_idx: int,
                     workload_idx: int, t: float) -> None:
        q = ctx.queries[workload_idx]
        fq = _FleetQuery(ctx, arrival_idx, ctx.qids[workload_idx], q,
                         ctx.params.k, t,
                         ctx.adm.pop_arrive_t(arrival_idx))
        self._live_queries.add(fq)
        tr = self.tracer
        if tr.enabled:
            fq.span = tr.begin("query", fq.arrive_t, parent=None,
                               qid=fq.qid, tenant=ctx.name, tid=ctx.tid,
                               kind=ctx.kind)
            if t > fq.arrive_t:
                tr.record("admission", fq.arrive_t, t, parent=fq.span)
            tr.metrics.counter("fleet.queries").inc()
            tr.metrics.counter(f"tenant.{ctx.name}.queries").inc()
        meta = ctx.index.meta
        if ctx.kind == "cluster":
            lids, ndist = ctx.index.select_lists(q, ctx.params.nprobe)
            fq.metrics.dist_comps += ndist
            fq.metrics.lists_visited = len(lids)
            reqs = [FetchRequest((ctx.tid, "list", int(i)),
                                 int(meta.list_nbytes[i])) for i in lids]
        else:
            fq.gen = ctx.index.search_plan(q, ctx.params, fq.metrics)
            batch = next(fq.gen)
            reqs = [FetchRequest((ctx.tid,) + rq.key, rq.nbytes)
                    for rq in batch.requests]
        dt = self._price(fq)
        if tr.enabled:
            tr.record("route", t, t + dt, parent=fq.span)
        self.kernel.at(t + dt, self._scatter, fq, reqs)

    # ---------------------------------------------------------- scatter --
    def _owners(self, fq: _FleetQuery, key) -> tuple[int, ...]:
        """Replica owners of a tenant-namespaced fetch key."""
        return fq.ctx.partition.owners(key[1:])

    def _group_has_capacity(self, shard: int) -> bool:
        srv = self.groups[shard].pick()
        return srv is not None and srv.has_capacity

    def _pick_replica(self, owners: tuple[int, ...],
                      exclude: int | None = None) -> int | None:
        """Power-of-two-choices by shard queue depth over live shards.

        Returns None when no owner is alive (the caller backs off and
        retries — the keys become routable again at recovery)."""
        cand = [s for s in owners if s != exclude and self.groups[s].alive]
        if not cand:
            cand = [s for s in owners if self.groups[s].alive]
            if not cand:
                return None
        if len(cand) == 1:
            return cand[0]
        if len(cand) == 2:
            a, b = cand
        else:
            i, j = self._rng.choice(len(cand), size=2, replace=False)
            a, b = cand[int(i)], cand[int(j)]
        la, lb = self.groups[a].load, self.groups[b].load
        if la != lb:
            return a if la < lb else b
        return min(a, b)

    def _scatter(self, fq: _FleetQuery, reqs: list[FetchRequest]) -> None:
        """Fan one round's requests out by replica-chosen owner."""
        t = self.kernel.now
        fq.rounds += 1
        if self.tracer.enabled:
            fq.round_span = self.tracer.begin("round", t, parent=fq.span,
                                              idx=fq.rounds)
        fq.slots = {}
        fq.payloads = {}
        groups: dict[int | None, list[FetchRequest]] = {}
        for rq in reqs:
            shard = self._pick_replica(self._owners(fq, rq.key))
            groups.setdefault(shard, []).append(rq)
        order = sorted(groups, key=lambda s: (s is None, s))
        for shard in order:
            slot = _Slot(self._slot_seq, groups[shard],
                         shard if shard is not None else -1)
            self._slot_seq += 1
            fq.slots[slot.slot_id] = slot
        fq.open_slots = len(fq.slots)
        for slot in fq.slots.values():
            if slot.shard < 0:                 # no live owner right now
                fq.shed_retries += 1
                self._schedule_retry(fq, slot)
            else:
                self._submit_primary(fq, slot, t)

    def _make_plan(self, fq: _FleetQuery, reqs: list[FetchRequest],
                   metrics: QueryMetrics, shard: int):
        ctx = fq.ctx
        if ctx.kind == "cluster":
            delta_fn = dead_fn = None
            if ctx.ingest_agents:
                mem = ctx.index.sites.get(shard)
                lids = tuple(int(rq.key[2]) for rq in reqs)
                if mem is not None:
                    delta_fn = lambda: mem.live_items(lids)  # noqa: E731
                dead_fn = ctx.index.deleted_array
            return _scan_plan(fq.q, reqs, fq.k, metrics,
                              delta_fn=delta_fn, dead_fn=dead_fn)
        return _fetch_plan(reqs)

    def _schedule_retry(self, fq: _FleetQuery, slot: _Slot) -> None:
        if fq.shed_retries > RETRY_LIMIT:
            raise RuntimeError(
                f"query {fq.qid} retried {fq.shed_retries} times — keys "
                f"unroutable (every replica owner down with no recovery?)")
        self._retry_pending += 1
        self.kernel.after(self.cfg.shed_retry_s, self._retry_fire, fq, slot)

    def _retry_fire(self, fq: _FleetQuery, slot: _Slot) -> None:
        self._retry_pending -= 1
        self._retry_slot(fq, slot, self.kernel.now)

    def _retry_slot(self, fq: _FleetQuery, slot: _Slot, t: float) -> None:
        """A shed or orphaned slot comes back with fresh per-key replica
        choice, avoiding the shard that rejected (or lost) it.  Keys that
        re-group onto several shards split into new slots."""
        if slot.done or fq.done:
            return
        groups: dict[int, list[FetchRequest]] = {}
        for rq in slot.reqs:
            owners = self._owners(fq, rq.key)
            shard = self._pick_replica(
                owners, exclude=slot.shard if len(owners) > 1 else None)
            if shard is None:                  # every owner is down
                fq.shed_retries += 1
                self._schedule_retry(fq, slot)
                return
            groups.setdefault(shard, []).append(rq)
        if len(groups) == 1:
            slot.shard = next(iter(groups))
            self._submit_primary(fq, slot, t)
            return
        # The slot splits across shards: retire the old slot object so a
        # hedge timer still holding it cannot resurrect it (which would
        # double-decrement open_slots via ghost hedge jobs).
        slot.done = True
        del fq.slots[slot.slot_id]
        fq.open_slots -= 1
        for shard in sorted(groups):
            ns = _Slot(self._slot_seq, groups[shard], shard)
            self._slot_seq += 1
            fq.slots[ns.slot_id] = ns
            fq.open_slots += 1
            self._submit_primary(fq, ns, t)

    def _submit_primary(self, fq: _FleetQuery, slot: _Slot,
                        t: float) -> None:
        """Submit a slot to its chosen shard; shed -> backoff retry."""
        cfg = self.cfg
        if slot.done or fq.done:
            return
        shard = slot.shard
        srv = self.groups[shard].pick()
        metrics = QueryMetrics()
        tag = self._tag_seq
        self._tag_seq += 1
        plan = self._make_plan(fq, slot.reqs, metrics, shard)
        if srv is not None and srv.try_submit(t, plan, metrics, tag,
                                              dim=fq.ctx.dim,
                                              pq_m=fq.ctx.pq_m):
            slot.outstanding.setdefault(0, set()).add(tag)
            slot.collected.setdefault(0, [])
            self._ctx[tag] = (fq, slot, 0, t)
            self._jobs_total += 1
            fq.n_jobs += 1
            fq.shards.add(shard)
            if (cfg.hedge and cfg.replication > 1
                    and not slot.hedge_launched
                    and len(self._lat) >= cfg.hedge_min_samples):
                deadline = float(np.percentile(
                    np.asarray(self._lat), cfg.hedge_percentile))
                self.kernel.at(t + deadline, self._maybe_hedge, fq, slot)
        else:
            fq.shed_retries += 1
            self._schedule_retry(fq, slot)

    def _maybe_hedge(self, fq: _FleetQuery, slot: _Slot) -> None:
        """Deadline fired: re-issue the slot's keys on the other replicas."""
        t = self.kernel.now
        if fq.done or slot.done or slot.hedge_launched:
            return
        slot.hedge_launched = True
        groups: dict[int, list[FetchRequest]] = {}
        for rq in slot.reqs:
            owners = self._owners(fq, rq.key)
            alt = [s for s in owners
                   if s != slot.shard and self.groups[s].alive]
            if not alt:
                return                     # un-hedgeable key (R=1 / faults)
            shard = self._pick_replica(tuple(alt))
            if shard is None:
                return
            groups.setdefault(shard, []).append(rq)
        # hedge only when every target replica would admit the duplicate
        # right now — a loaded fleet gets no speculative extra work, and
        # no hedge sub-job is ever orphaned by a partial shed.
        if any(not self._group_has_capacity(s) for s in groups):
            return
        self._hedges += 1
        fq.hedged = True
        if self.tracer.enabled:
            self.tracer.metrics.counter("fleet.hedges").inc()
        slot.outstanding[1] = set()
        slot.collected[1] = []
        for shard in sorted(groups):
            metrics = QueryMetrics()
            tag = self._tag_seq
            self._tag_seq += 1
            plan = self._make_plan(fq, groups[shard], metrics, shard)
            self.groups[shard].pick().try_submit(t, plan, metrics, tag,
                                                 dim=fq.ctx.dim,
                                                 pq_m=fq.ctx.pq_m)
            slot.outstanding[1].add(tag)
            self._ctx[tag] = (fq, slot, 1, t)
            self._jobs_total += 1
            fq.n_jobs += 1
            fq.shards.add(shard)

    # ----------------------------------------------------------- gather --
    def _record_job_span(self, fq: _FleetQuery, attempt: int,
                         t_submit: float, server: ShardServer,
                         job: JobRecord, *, stale: bool) -> None:
        """Synthesize a completed shard job's span sub-tree.

        Consumed jobs hang off the query's current round; work the
        query did not wait for (hedge-race losers, post-abort
        completions) is parentless with ``wasted=True`` — it ends after
        the round closed, so parenting it would break the child-within-
        parent tree invariant.  A flow arrow still ties hedges back to
        the round that launched them.
        """
        tr = self.tracer
        attrs = dict(shard=server.shard_id, instance=server.instance,
                     attempt=attempt, qid=fq.qid, tid=fq.ctx.tid)
        if stale:
            attrs["wasted"] = True
        sp = tr.record("shard_job", t_submit, job.end_t,
                       parent=None if stale else fq.round_span, **attrs)
        emit_job_spans(tr, sp, t_submit, job)
        if attempt > 0 and fq.round_span is not None:
            tr.flow(fq.round_span, sp)
        tr.metrics.counter("fleet.jobs").inc()
        if stale:
            tr.metrics.counter("fleet.jobs_wasted").inc()
        tr.metrics.histogram("shard.job_sojourn_s").observe(
            job.end_t - t_submit)

    def _job_done(self, server: ShardServer, job: JobRecord) -> None:
        ctx = self._ctx.pop(job.tag, None)
        if ctx is None:
            return
        fq, slot, attempt, t_submit = ctx
        self._lat.append(job.end_t - t_submit)
        _merge_metrics(fq.metrics, job.metrics)
        stale = fq.done or slot.done or attempt not in slot.outstanding
        if self.tracer.enabled:
            self._record_job_span(fq, attempt, t_submit, server, job,
                                  stale=stale)
        if stale:
            return                          # stale (hedge race loser)
        open_tags = slot.outstanding[attempt]
        open_tags.discard(job.tag)
        slot.collected[attempt].append(job.result)
        if open_tags:
            return                          # more sub-jobs of this attempt
        slot.done = True
        if attempt > 0:
            self._hedge_wins += 1
        if fq.kind == "cluster":
            fq.local_results.extend(slot.collected[attempt])
        else:
            for payloads in slot.collected[attempt]:
                for key, val in payloads.items():
                    fq.payloads[key[1:]] = val     # un-namespace for plan
        fq.open_slots -= 1
        if fq.open_slots == 0:
            self._round_done(fq, job.end_t)

    def _round_done(self, fq: _FleetQuery, t: float) -> None:
        tr = self.tracer
        if tr.enabled and fq.round_span is not None:
            tr.end(fq.round_span, t)
        if fq.kind == "cluster":
            ids, dists = merge_topk(fq.local_results, fq.k)
            if tr.enabled:
                tr.record("merge", t, t, parent=fq.span)
            self._finish_query(fq, t, ids, dists)
            return
        # graph: resume the beam-search generator with this round's blocks
        # (router-side snapshot excludes shard-merged counters, so compute
        # pricing charges only the plan's own ADC/exact work)
        fq.snapshot = (fq.metrics.dist_comps, fq.metrics.pq_dist_comps)
        try:
            batch = fq.gen.send(fq.payloads)
        except StopIteration as stop:
            res = stop.value
            if fq.ctx.ingest_agents:
                # router-side delta merge + tombstone filter: the graph
                # delta lives in site memtables the beam never traversed
                res = fq.ctx.index.merge_result(fq.q, fq.k, res,
                                                fq.metrics)
            dt = self._price(fq)
            if tr.enabled:
                tr.record("merge", t, t + dt, parent=fq.span)
            self._finish_query(fq, t + dt, res.ids, res.dists)
            return
        reqs = [FetchRequest((fq.ctx.tid,) + rq.key, rq.nbytes)
                for rq in batch.requests]
        dt = self._price(fq)
        if tr.enabled:
            tr.record("route", t, t + dt, parent=fq.span)
        self.kernel.at(t + dt, self._scatter, fq, reqs)

    def inflight_floor(self) -> float:
        """Earliest start time among in-flight queries (inf when idle) —
        the reclamation safety line: no corpse unlinked before it can
        still be referenced by any live sub-request."""
        return min((fq.start_t for fq in self._live_queries),
                   default=float("inf"))

    def _finish_query(self, fq: _FleetQuery, t: float, ids: np.ndarray,
                      dists: np.ndarray) -> None:
        fq.done = True
        self._live_queries.discard(fq)
        ctx = fq.ctx
        ctx.records.append(FleetQueryRecord(
            qid=fq.qid, start_t=fq.start_t, end_t=t, ids=ids, dists=dists,
            metrics=fq.metrics, rounds=fq.rounds, n_jobs=fq.n_jobs,
            shards_touched=len(fq.shards), hedged=fq.hedged,
            shed_retries=fq.shed_retries, arrive_t=fq.arrive_t))
        sojourn = t - fq.arrive_t
        tr = self.tracer
        if tr.enabled and fq.span is not None:
            tr.end(fq.span, t)
            tr.metrics.histogram("fleet.sojourn_s").observe(sojourn)
            tr.metrics.histogram("fleet.latency_s").observe(t - fq.start_t)
            if self._explain is not None:
                self._explain.on_query(fq.span)
        self.recent_sojourns.append(sojourn)
        self._slice_counts[1] += 1
        if ctx.slo_s is not None and sojourn <= ctx.slo_s:
            ctx.good_total += 1
            self._slice_counts[2] += 1
        if self._slo_monitor is not None:
            mon = self._slo_monitor
            mname = self._mon_name(ctx)
            if ctx.slo_s is not None:
                mon.observe_latency(t, f"{mname}.latency", sojourn,
                                    ctx.slo_s, tenant=ctx.name)
            mcfg = mon.cfg
            if mcfg.recall_target is not None and mcfg.gt_ids is not None:
                gt = mcfg.gt_ids
                if isinstance(gt, dict):
                    gt = gt.get(ctx.name)
                if gt is not None and fq.qid < len(gt):
                    rec = recall_at_k(ids[ids >= 0], gt[fq.qid])
                    mon.observe_recall(t, f"{mname}.recall", rec,
                                       mcfg.recall_target,
                                       tenant=ctx.name)
        if not ctx.adm.release(t):
            self._maybe_shutdown()

    # ------------------------------------------------- faults / scaling --
    def fail_shard(self, shard: int) -> None:
        t = self.kernel.now
        tags = self.groups[shard].fail_all(t)
        self._fault_log.append(dict(t=round(t, 6), event="fail",
                                    shard=shard, jobs_aborted=len(tags)))
        if self.tracer.enabled:
            self.tracer.instant("shard_fail", t, shard=shard,
                                jobs_aborted=len(tags))
        for tag in tags:
            self._job_aborted(tag, shard)

    def recover_shard(self, shard: int) -> None:
        t = self.kernel.now
        self.groups[shard].recover_all(t)
        self._fault_log.append(dict(t=round(t, 6), event="recover",
                                    shard=shard))
        if self.tracer.enabled:
            self.tracer.instant("shard_recover", t, shard=shard)

    def _job_aborted(self, tag: int, shard: int) -> None:
        """A shard died under this sub-job: re-route its slot to the
        surviving replica owners (or back off until one recovers)."""
        ctx = self._ctx.pop(tag, None)
        if ctx is None:
            return
        fq, slot, attempt, t_submit = ctx
        if self.tracer.enabled:
            # no JobRecord exists for an aborted job; record the doomed
            # interval as parentless wasted work ending at the fault
            self.tracer.record("shard_job", t_submit, self.kernel.now,
                               parent=None, shard=shard, attempt=attempt,
                               qid=fq.qid, tid=fq.ctx.tid, wasted=True,
                               aborted=True)
            self.tracer.metrics.counter("fleet.jobs_aborted").inc()
        if fq.done or slot.done:
            return
        if attempt not in slot.outstanding:
            return
        # The attempt lost one of its sub-jobs, so it can never gather a
        # complete key set again — drop it wholesale.  Surviving sibling
        # tags become stale (their completions are ignored in _job_done),
        # exactly like hedge-race losers; any other attempt still covers
        # every key of the slot.
        slot.outstanding.pop(attempt)
        slot.collected.pop(attempt, None)
        if not slot.outstanding:           # no live attempt remains
            self._retry_slot(fq, slot, self.kernel.now)

    @property
    def total_instances(self) -> int:
        return sum(len(g.routable) for g in self.groups)

    def scale_up_one(self) -> bool:
        cands = [g for g in self.groups
                 if g.alive and len(g.routable) < self._scale_max]
        if not cands:
            return False
        grp = max(cands, key=lambda g: (
            sum(s.load for s in g.routable) / len(g.routable),
            -g.shard_id))
        grp.scale_up()
        return True

    def scale_down_one(self) -> bool:
        cands = [g for g in self.groups
                 if len(g.routable) > self._scale_min]
        if not cands:
            return False
        grp = min(cands, key=lambda g: (
            sum(s.load for s in g.routable) / len(g.routable),
            g.shard_id))
        return grp.begin_drain(self.kernel.now) is not None

    # -------------------------------------------- live SLO monitoring --
    def _mon_name(self, ctx: _TenantCtx) -> str:
        """Monitor namespace: ``fleet`` for the single-tenant run,
        the tenant name otherwise."""
        return "fleet" if len(self.ctxs) == 1 else ctx.name

    def _monitor_tick(self, now: float) -> None:
        """Rule-evaluation tick: reads monitor state, fires/clears
        alerts.  With the action bus disabled this is read-only."""
        self._slo_monitor.tick(now)

    def _alert_scale_out(self, event: str, alert, now: float) -> None:
        """Action-bus subscriber: a *page* (fast-burn) latency alert
        adds an instance to the most loaded shard.  Routed through the
        autoscaler when one is running so both policies share a
        cooldown and an event log; standalone otherwise, capped by
        ``MonitorConfig.max_instances``."""
        if event != "fired" or alert.severity != "page":
            return
        if not alert.monitor.endswith(".latency"):
            return
        if self._autoscaler is not None:
            acted = self._autoscaler.alert_scale_up(now, alert)
        else:
            acted = self.scale_up_one()
        if acted:
            self._alert_actions.append(dict(
                t=round(now, 6), action="scale_up",
                monitor=alert.monitor, rule=alert.rule,
                instances=self.total_instances))
            if self.tracer.enabled:
                self.tracer.instant("alert_action_scale_up", now,
                                    monitor=alert.monitor,
                                    instances=self.total_instances)

    def _alert_admission(self, event: str, alert, now: float) -> None:
        """Action-bus subscriber: a *ticket* (slow sustained burn)
        latency alert from one tenant of a multi-tenant fleet shrinks
        that tenant's admission window by one (floor 1), restored on
        clear.  The over-budget tenant's excess queries wait in its own
        backlog instead of occupying shared shard queues — its burn
        becomes backlog wait it already owns, and the other tenants'
        queues drain."""
        if len(self.ctxs) <= 1 or alert.tenant is None:
            return
        if alert.severity != "ticket" or \
                not alert.monitor.endswith(".latency"):
            return
        ctx = next((c for c in self.ctxs if c.name == alert.tenant),
                   None)
        if ctx is None or ctx.adm is None:
            return
        if event == "fired":
            if ctx.adm.window <= 1:
                return
            ctx.adm.window -= 1
            action = "deprioritize"
        else:
            if ctx.adm.window >= ctx.window:
                return
            ctx.adm.window += 1
            action = "restore"
        self._alert_actions.append(dict(
            t=round(now, 6), action=action, tenant=ctx.name,
            monitor=alert.monitor, rule=alert.rule,
            window=ctx.adm.window))
        if self.tracer.enabled:
            self.tracer.instant(f"alert_action_{action}", now,
                                tenant=ctx.name, window=ctx.adm.window)

    def _running_cost(self, now: float) -> dict:
        """Dollars accrued so far (read-only; feeds the trace's cost
        counter tracks — the final report uses :func:`fleet_cost`)."""
        get_req = put_req = read_bytes = 0
        inst_s = 0.0
        for g in self.groups:
            for srv in g.all_servers():
                sim = srv.engine.sim
                get_req += sim.total_requests - sim.total_put_requests
                put_req += sim.total_put_requests
                read_bytes += sim.total_bytes - sim.total_put_bytes
                inst_s += srv.active_seconds(now)
        comp = self._pricebook.components(
            get_requests=get_req, put_requests=put_req,
            read_bytes=read_bytes, instance_seconds=inst_s,
            cache_byte_seconds=self.cfg.cache_bytes * inst_s,
            nvme_byte_seconds=self.cfg.nvme_bytes * inst_s)
        comp["total_usd"] = sum(comp.values())
        return comp

    # ----------------------------------------------------------- monitor --
    def _queue_depth(self) -> int:
        depth = self._retry_pending + sum(c.adm.depth for c in self.ctxs)
        for g in self.groups:
            depth += sum(s.load for s in g.instances)
        return depth

    def _sample_slice(self, now: float) -> None:
        self._flush_slice(now)

    def _obs_snapshot(self, now: float) -> None:
        """Read-only metrics tick: gauges + one time-series row."""
        m = self.tracer.metrics
        m.gauge("fleet.queue_depth").set(self._queue_depth())
        m.gauge("fleet.instances").set(self.total_instances)
        if self.cfg.nvme_bytes > 0:
            # per-tier hit/byte gauges (flat runs emit none of these,
            # keeping pre-tier metric exports byte-identical)
            hits = misses = nvme_b = used = 0
            for g in self.groups:
                for srv in g.all_servers():
                    tier = srv.engine.tier
                    if tier is None:
                        continue
                    hits += tier.hits
                    misses += tier.misses
                    nvme_b += tier.nvme_bytes
                    used += tier.used_bytes
            m.gauge("tier.nvme.hits").set(hits)
            m.gauge("tier.nvme.misses").set(misses)
            m.gauge("tier.nvme.bytes").set(nvme_b)
            m.gauge("tier.nvme.used_bytes").set(used)
        if self._pricebook is not None:
            for k, v in self._running_cost(now).items():
                m.gauge(f"cost.{k}").set(round(v, 9))
        if self._explain is not None:
            self._explain.publish(m)
        if self._mrc is not None:
            self._mrc.publish(m)
        m.snapshot(now)

    def _flush_slice(self, now: float) -> None:
        a, c, g = self._slice_counts
        self._slice_counts = [0, 0, 0]
        self._series.append(t=now, arrived=a, completed=c, good=g,
                            queue_depth=self._queue_depth(),
                            instances=self.total_instances)


def run_fleet(index, queries: np.ndarray, params: SearchParams,
              cfg: FleetConfig,
              query_ids: Iterable[int] | None = None, *,
              arrivals: ArrivalProcess | None = None,
              faults: FaultSchedule | None = None,
              autoscale: AutoscaleConfig | None = None,
              slo_s: float | None = None,
              series_dt: float | None = None,
              updates=None, ingest=None,
              tracer: Tracer | None = None,
              monitor: MonitorConfig | None = None,
              pricebook: PriceBook | None = None,
              explain: bool | ExplainConfig = False,
              mrc: bool | MRCConfig = False) -> FleetReport:
    """One-call fleet evaluation (the fleet analogue of run_workload)."""
    return FleetRouter(index, cfg).run(
        queries, params, query_ids=query_ids, arrivals=arrivals,
        faults=faults, autoscale=autoscale, slo_s=slo_s,
        series_dt=series_dt, updates=updates, ingest=ingest,
        tracer=tracer, monitor=monitor, pricebook=pricebook,
        explain=explain, mrc=mrc)
