"""Shard server: one compute node of the fleet (paper §2.1's
one-node-to-one-bucket unit, replicated N times).

Each server owns an independent :class:`SteppableEngine` — its own segment
cache and its own discrete-event storage simulator (own NIC bandwidth pipe,
own GET-rate bucket) — but never advances time itself: the fleet router
drives every server on one shared virtual clock.

Admission control: at most ``max_inflight`` jobs execute concurrently;
further submissions wait in a bounded FIFO queue; when the queue is full
the submission is **shed** (rejected back to the router, which retries a
replica or backs off).  Shed accounting is the backpressure signal the
fleet report surfaces.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

from repro.cache.slru import make_cache
from repro.serving.engine import EngineConfig, JobRecord, SteppableEngine


@dataclasses.dataclass
class ShardStats:
    """Per-shard accounting for the fleet report."""

    shard_id: int
    jobs_done: int = 0
    submissions: int = 0           # accepted + shed
    sheds: int = 0
    peak_queue: int = 0
    peak_inflight: int = 0
    busy_s: float = 0.0            # sum of job service times (no queue wait)
    storage_bytes: int = 0
    storage_requests: int = 0

    def to_dict(self) -> dict:
        return dict(shard=self.shard_id, jobs=self.jobs_done,
                    submissions=self.submissions, sheds=self.sheds,
                    peak_queue=self.peak_queue,
                    peak_inflight=self.peak_inflight,
                    busy_s=round(self.busy_s, 9),
                    storage_bytes=self.storage_bytes,
                    storage_requests=self.storage_requests)


class ShardServer:
    """A bounded admission queue in front of one steppable shard engine."""

    def __init__(self, shard_id: int, cfg: EngineConfig, store, *,
                 dim: int, pq_m: int = 0, max_inflight: int = 4,
                 queue_depth: int = 16,
                 on_complete: Callable[[int, JobRecord], None] | None = None):
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        if queue_depth < 0:
            raise ValueError(f"queue_depth must be >= 0, got {queue_depth}")
        self.shard_id = shard_id
        self.max_inflight = max_inflight
        self.queue_depth = queue_depth
        self.on_complete = on_complete
        cache = make_cache(cfg.cache_policy, cfg.cache_bytes,
                           cfg.pinned_keys)
        self.engine = SteppableEngine(cfg, store, cache, dim=dim, pq_m=pq_m,
                                      on_complete=self._job_done)
        self._queue: deque = deque()       # (plan, metrics, tag)
        self.stats = ShardStats(shard_id=shard_id)

    # ---------------------------------------------------------- routing --
    @property
    def load(self) -> int:
        """Queue depth the router balances on: running + waiting jobs."""
        return self.engine.in_flight + len(self._queue)

    @property
    def has_capacity(self) -> bool:
        """Would a submission right now be admitted (not shed)?"""
        return (self.engine.in_flight < self.max_inflight
                or len(self._queue) < self.queue_depth)

    def try_submit(self, t: float, plan, metrics, tag) -> bool:
        """Admit a job at virtual time ``t``; False means shed."""
        self.stats.submissions += 1
        if self.engine.in_flight < self.max_inflight:
            self.engine.submit(t, plan, metrics, tag=tag)
            self.stats.peak_inflight = max(self.stats.peak_inflight,
                                           self.engine.in_flight)
            return True
        if len(self._queue) < self.queue_depth:
            self._queue.append((plan, metrics, tag))
            self.stats.peak_queue = max(self.stats.peak_queue,
                                        len(self._queue))
            return True
        self.stats.sheds += 1
        return False

    def _job_done(self, job: JobRecord) -> None:
        self.stats.jobs_done += 1
        self.stats.busy_s += job.latency
        if self._queue and self.engine.in_flight < self.max_inflight:
            plan, metrics, tag = self._queue.popleft()
            self.engine.submit(job.end_t, plan, metrics, tag=tag)
        if self.on_complete is not None:
            self.on_complete(self.shard_id, job)

    # ------------------------------------------------------------ clock --
    def next_event_time(self) -> float | None:
        return self.engine.next_event_time()

    def advance_to(self, t: float) -> None:
        self.engine.advance_to(t)

    @property
    def busy(self) -> bool:
        return self.engine.busy or bool(self._queue)

    def finalize_stats(self) -> ShardStats:
        self.stats.storage_bytes = self.engine.sim.total_bytes
        self.stats.storage_requests = self.engine.sim.total_requests
        return self.stats
