"""Shard servers: the compute nodes of the fleet (paper §2.1's
one-node-to-one-bucket unit, replicated N times).

Each :class:`ShardServer` is one *instance*: an independent
:class:`SteppableEngine` — its own segment cache and its own
discrete-event storage simulator (own NIC bandwidth pipe, own GET-rate
bucket) — registered on the fleet's shared :class:`repro.sim.Kernel`.

Admission control: at most ``max_inflight`` jobs execute concurrently;
further submissions wait in a bounded FIFO queue; when the queue is full
the submission is **shed** (rejected back to the router, which retries a
replica or backs off).  Shed accounting is the backpressure signal the
fleet report surfaces.

Because storage is disaggregated, a logical shard can be served by any
number of stateless instances over the same data.  :class:`ShardGroup`
holds the instances of one shard: fault injection kills and revives them
(cold cache on recovery — the re-warm shows up as a hit-rate dip), and
the autoscaler adds instances under SLO pressure and drains them when
load subsides.  Per-instance activation intervals price the fleet in
shards·seconds.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

from repro.serving.engine import EngineConfig, JobRecord, SteppableEngine
from repro.sim.kernel import Kernel


@dataclasses.dataclass
class ShardStats:
    """Per-instance accounting for the fleet report."""

    shard_id: int
    instance: int = 0
    jobs_done: int = 0
    submissions: int = 0           # accepted + shed
    sheds: int = 0
    peak_queue: int = 0
    peak_inflight: int = 0
    busy_s: float = 0.0            # sum of job service times (no queue wait)
    storage_bytes: int = 0
    storage_requests: int = 0
    storage_put_bytes: int = 0     # compaction writes (subset of totals)
    storage_put_requests: int = 0
    failures: int = 0
    jobs_aborted: int = 0
    #: NVMe tier accounting (repro.storage.tier); None on flat instances
    #: so their to_dict stays byte-identical to the pre-tier layout
    nvme: dict | None = None

    def to_dict(self) -> dict:
        d = dict(shard=self.shard_id, instance=self.instance,
                 jobs=self.jobs_done,
                 submissions=self.submissions, sheds=self.sheds,
                 peak_queue=self.peak_queue,
                 peak_inflight=self.peak_inflight,
                 busy_s=round(self.busy_s, 9),
                 storage_bytes=self.storage_bytes,
                 storage_requests=self.storage_requests,
                 storage_put_bytes=self.storage_put_bytes,
                 storage_put_requests=self.storage_put_requests)
        if self.failures:
            d.update(failures=self.failures, jobs_aborted=self.jobs_aborted)
        if self.nvme is not None:
            d["nvme"] = self.nvme
        return d


class ShardServer:
    """A bounded admission queue in front of one kernel-resident engine."""

    def __init__(self, shard_id: int, cfg: EngineConfig, store, *,
                 kernel: Kernel, dim: int, pq_m: int = 0, instance: int = 0,
                 max_inflight: int = 4, queue_depth: int = 16,
                 on_complete: Callable[["ShardServer", JobRecord], None]
                 | None = None,
                 cache_factory: Callable[[], object] | None = None,
                 backend_factory: Callable[[], object] | None = None):
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        if queue_depth < 0:
            raise ValueError(f"queue_depth must be >= 0, got {queue_depth}")
        self.shard_id = shard_id
        self.instance = instance
        self.cfg = cfg
        self.max_inflight = max_inflight
        self.queue_depth = queue_depth
        self.on_complete = on_complete
        self.on_retired: Callable[["ShardServer"], None] | None = None
        # tenancy hands in a factory building tenant-aware cache
        # assemblies; default is the config's single-tenant cache path
        self._cache_factory = cache_factory if cache_factory is not None \
            else cfg.make_cache
        # --backend kernel hands in a factory building this instance's
        # batch coalescer (repro.exec.KernelBackend); None = analytic
        backend = backend_factory() if backend_factory is not None else None
        self.engine = SteppableEngine(cfg, store, self._cache_factory(),
                                      kernel=kernel, dim=dim, pq_m=pq_m,
                                      on_complete=self._job_done,
                                      backend=backend)
        self._queue: deque = deque()       # (plan, metrics, tag, dim, pq_m)
        self.stats = ShardStats(shard_id=shard_id, instance=instance)
        self.alive = True
        self.draining = False
        # [on, off] activation intervals for shards·seconds pricing
        self.active_intervals: list[list[float | None]] = [[kernel.now, None]]

    # ---------------------------------------------------------- routing --
    @property
    def load(self) -> int:
        """Queue depth the router balances on: running + waiting jobs."""
        return self.engine.in_flight + len(self._queue)

    @property
    def routable(self) -> bool:
        return self.alive and not self.draining

    @property
    def idle(self) -> bool:
        return self.engine.in_flight == 0 and not self._queue

    @property
    def has_capacity(self) -> bool:
        """Would a submission right now be admitted (not shed)?"""
        return self.routable and (
            self.engine.in_flight < self.max_inflight
            or len(self._queue) < self.queue_depth)

    def try_submit(self, t: float, plan, metrics, tag,
                   dim: int | None = None, pq_m: int | None = None) -> bool:
        """Admit a job at virtual time ``t``; False means shed.

        ``dim``/``pq_m``: per-job compute-pricing geometry (tenants of
        different index shapes share one shard engine)."""
        if not self.routable:
            return False
        self.stats.submissions += 1
        if self.engine.in_flight < self.max_inflight:
            self.engine.submit(plan, metrics, tag=tag, at=t,
                               dim=dim, pq_m=pq_m)
            self.stats.peak_inflight = max(self.stats.peak_inflight,
                                           self.engine.in_flight)
            return True
        if len(self._queue) < self.queue_depth:
            self._queue.append((plan, metrics, tag, dim, pq_m))
            self.stats.peak_queue = max(self.stats.peak_queue,
                                        len(self._queue))
            return True
        self.stats.sheds += 1
        tr = self.engine.kernel.tracer
        if tr.enabled:
            tr.instant("shed", t, shard=self.shard_id,
                       instance=self.instance)
            tr.metrics.counter("fleet.sheds").inc()
        return False

    def invalidate(self, key, writeback_nbytes: int | None = None) -> None:
        """Drop a rewritten object's stale cached copy (compaction).

        Invalidation is neither a hit nor a miss in any tier's stats.
        ``writeback_nbytes`` is set by the router only on owning shards
        of a write-back tier: the rewritten object just landed on local
        NVMe, so it is admitted to residency at its new size."""
        if self.engine.cache is not None:
            self.engine.cache.remove(key)
        tier = self.engine.tier
        if tier is not None:
            tier.invalidate(key)
            if writeback_nbytes is not None and tier.writeback:
                tier.admit_writeback(key, writeback_nbytes)

    def _job_done(self, job: JobRecord) -> None:
        self.stats.jobs_done += 1
        self.stats.busy_s += job.latency
        if self._queue and self.engine.in_flight < self.max_inflight:
            plan, metrics, tag, dim, pq_m = self._queue.popleft()
            self.engine.submit(plan, metrics, tag=tag, at=job.end_t,
                               dim=dim, pq_m=pq_m)
        if self.on_complete is not None:
            self.on_complete(self, job)
        if self.draining and self.idle and self.on_retired is not None:
            self.on_retired(self)

    # ------------------------------------------------- faults / scaling --
    def fail(self, t: float) -> list:
        """The node dies: abort every queued and running job; returns the
        aborted tags so the router can re-route them to replicas."""
        if not self.alive:
            return []
        self.alive = False
        self.stats.failures += 1
        tags = [item[2] for item in self._queue]
        self._queue.clear()
        tags = self.engine.abort_all() + tags
        self.stats.jobs_aborted += len(tags)
        self._close_interval(t)
        return tags

    def recover(self, t: float) -> None:
        """The node comes back **cold**: its cache restarts empty and
        re-warms from traffic (the post-recovery hit-rate dip).  An
        instance that was already draining stays retired — recovery
        revives capacity, not scale-down decisions."""
        if self.alive or self.draining:
            return
        self.alive = True
        self.engine.cache = self._cache_factory()
        if self.engine.tier is not None:
            # the replacement node's local NVMe starts empty too
            self.engine.tier.reset()
        self.active_intervals.append([t, None])

    def retire(self, t: float) -> None:
        """Close the instance's billing interval (autoscale drain done)."""
        self._close_interval(t)

    def _close_interval(self, t: float) -> None:
        if self.active_intervals and self.active_intervals[-1][1] is None:
            self.active_intervals[-1][1] = t

    def active_seconds(self, horizon: float) -> float:
        """Billed seconds in [0, horizon] (open intervals run to horizon)."""
        total = 0.0
        for on, off in self.active_intervals:
            end = horizon if off is None else min(off, horizon)
            total += max(0.0, end - on)
        return total

    def finalize_stats(self) -> ShardStats:
        self.stats.storage_bytes = self.engine.sim.total_bytes
        self.stats.storage_requests = self.engine.sim.total_requests
        self.stats.storage_put_bytes = self.engine.sim.total_put_bytes
        self.stats.storage_put_requests = (
            self.engine.sim.total_put_requests)
        if self.engine.tier is not None:
            nv = self.engine.tier.stats_dict()
            wp = self.engine.write_path
            if wp is not self.engine.sim:       # write-back data plane
                nv["flushes_done"] = wp.flushes_done
                nv["flush_pending"] = wp.flush_pending
            self.stats.nvme = nv
        return self.stats


class ShardGroup:
    """The serving instances of one logical shard.

    Data placement (which shard owns which keys) is the partition's job;
    this is purely the *capacity* dimension: 1..N stateless instances
    serving the same keys, each with its own cache and NIC.
    """

    def __init__(self, shard_id: int,
                 spawn: Callable[[int, int], ShardServer]):
        self.shard_id = shard_id
        self._spawn = spawn
        self._next_instance = 1
        self.instances: list[ShardServer] = [spawn(shard_id, 0)]
        self.retired: list[ShardServer] = []

    # ---------------------------------------------------------- routing --
    @property
    def routable(self) -> list[ShardServer]:
        return [s for s in self.instances if s.routable]

    @property
    def alive(self) -> bool:
        return bool(self.routable)

    @property
    def load(self) -> float:
        """Best-case admission load (what po2c balances on)."""
        inst = self.routable
        return min(s.load for s in inst) if inst else float("inf")

    def pick(self) -> ShardServer | None:
        """Least-loaded routable instance (ties: oldest instance)."""
        best = None
        for s in self.instances:
            if s.routable and (best is None or s.load < best.load):
                best = s
        return best

    # ------------------------------------------------- faults / scaling --
    def fail_all(self, t: float) -> list:
        tags = []
        for s in self.instances:
            tags.extend(s.fail(t))
        return tags

    def recover_all(self, t: float) -> None:
        for s in self.instances:
            s.recover(t)

    def scale_up(self) -> ShardServer:
        srv = self._spawn(self.shard_id, self._next_instance)
        self._next_instance += 1
        self.instances.append(srv)
        return srv

    def begin_drain(self, t: float) -> ShardServer | None:
        """Mark the least-loaded extra instance draining: no new routes;
        it retires (stops billing) once its queue and engine are idle."""
        cands = [s for s in self.routable if s.instance != 0]
        if not cands:
            return None
        srv = min(cands, key=lambda s: (s.load, -s.instance))
        srv.draining = True
        if srv.idle:
            self._retire(srv, t)
        else:
            srv.on_retired = lambda s: self._retire(s, s.engine.kernel.now)
        return srv

    def _retire(self, srv: ShardServer, t: float) -> None:
        srv.retire(t)
        srv.on_retired = None
        if srv in self.instances:
            self.instances.remove(srv)
            self.retired.append(srv)

    def all_servers(self) -> list[ShardServer]:
        return self.instances + self.retired
