"""``repro.fleet`` — sharded, replicated fleet serving.

The paper analyses one compute node against one storage bucket (§2.1) and
defers distributed serving to future work; this subsystem is that future
work: N shard servers (each an independent engine + cache + storage
simulator) advanced on one shared deterministic virtual clock, with

* ``partition``: posting-list (balanced, replicated) and node-block
  (hashed, replicated) placement;
* ``server``: bounded admission queues with shed accounting
  (backpressure);
* ``router``: scatter-gather fan-out, power-of-two-choices replica
  selection, hedged requests, global top-k merge;
* ``metrics``: :class:`FleetReport` — tail latency (p50/p99/p99.9), load
  imbalance, hedge and shed rates.

CLI: ``python -m repro.fleet --shards 4 --replicas 2`` emits a
deterministic JSON report.
"""
from repro.fleet.metrics import FleetQueryRecord, FleetReport, FleetSeries
from repro.fleet.partition import (ClusterPartition, GraphPartition,
                                   partition_for_index)
from repro.fleet.router import (FleetConfig, FleetRouter, merge_topk,
                                run_fleet)
from repro.fleet.server import ShardGroup, ShardServer, ShardStats

__all__ = [
    "FleetConfig", "FleetRouter", "run_fleet", "merge_topk",
    "FleetReport", "FleetQueryRecord", "FleetSeries",
    "ShardGroup", "ShardServer", "ShardStats",
    "ClusterPartition", "GraphPartition", "partition_for_index",
]
