"""Index partitioning across shard servers (fleet data placement).

Two placement schemes, one per index family:

* **Cluster (SPANN-class)** — posting lists are the unit of placement.
  Balanced assignment is greedy LPT on *billable bytes* (lists sorted by
  size, each list's R replicas go to the R least-loaded distinct shards),
  so a skewed list-size distribution still yields near-even per-shard
  storage.  Replication factor R means every probed list can be served by
  any of R shards — the routing freedom power-of-two-choices and hedging
  exploit.
* **Graph (DiskANN-class)** — node blocks are hash-partitioned
  (splitmix64 finalizer keyed by the partition seed), replicas on the next
  R-1 shards ring-wise.  Beam-search rounds touch pseudo-random node sets,
  so hashing spreads every round's W fetches across the fleet.

Both expose the same interface the router consumes:
``owners(key) -> tuple[shard ids]`` for a fetch key, plus byte/count
balance introspection.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

_MASK64 = (1 << 64) - 1


def _splitmix64(x: int) -> int:
    """splitmix64 finalizer: a cheap, well-mixed 64-bit hash."""
    x = (x + 0x9E3779B97F4A7C15) & _MASK64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK64
    return x ^ (x >> 31)


def _check(n_items: int, n_shards: int, replication: int) -> None:
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if not 1 <= replication <= n_shards:
        raise ValueError(
            f"replication must be in [1, n_shards={n_shards}], "
            f"got {replication}")
    if n_items < 1:
        raise ValueError(f"nothing to partition (n_items={n_items})")


@dataclasses.dataclass
class ClusterPartition:
    """Posting-list -> shard placement with replication factor R."""

    kind = "cluster"
    n_shards: int
    replication: int
    owners_arr: np.ndarray        # (n_lists, R) int32 distinct shard ids
    shard_bytes: np.ndarray       # (n_shards,) int64 stored bytes per shard

    @staticmethod
    def build(list_nbytes: np.ndarray, n_shards: int,
              replication: int) -> "ClusterPartition":
        """Balanced greedy LPT over list byte sizes (deterministic)."""
        list_nbytes = np.asarray(list_nbytes, dtype=np.int64)
        n_lists = len(list_nbytes)
        _check(n_lists, n_shards, replication)
        owners = np.zeros((n_lists, replication), dtype=np.int32)
        load = [(0, s) for s in range(n_shards)]      # (bytes, shard) heap
        heapq.heapify(load)
        order = np.argsort(-list_nbytes, kind="stable")
        for li in order:
            nb = int(list_nbytes[li])
            picked = [heapq.heappop(load) for _ in range(replication)]
            for r, (b, s) in enumerate(picked):
                owners[li, r] = s
                heapq.heappush(load, (b + nb, s))
        shard_bytes = np.zeros(n_shards, dtype=np.int64)
        for li in range(n_lists):
            for s in owners[li]:
                shard_bytes[s] += list_nbytes[li]
        return ClusterPartition(n_shards=n_shards, replication=replication,
                                owners_arr=owners, shard_bytes=shard_bytes)

    def owners(self, key) -> tuple[int, ...]:
        _, li = key
        return tuple(int(s) for s in self.owners_arr[li])

    def inherit(self, new_li: int, parent_li: int) -> None:
        """A re-cluster split ``parent_li``: the new list keeps the
        parent's replica owners (the data stays where it already lives —
        a split moves no bytes between shards)."""
        if new_li < len(self.owners_arr):
            return                       # already registered
        if new_li != len(self.owners_arr):
            raise ValueError(
                f"non-contiguous list id {new_li} "
                f"(have {len(self.owners_arr)})")
        self.owners_arr = np.vstack(
            [self.owners_arr, self.owners_arr[parent_li][None]])

    @property
    def bytes_imbalance(self) -> float:
        """max/mean stored bytes across shards (1.0 = perfectly even)."""
        mean = self.shard_bytes.mean()
        return float(self.shard_bytes.max() / max(mean, 1e-12))


@dataclasses.dataclass
class GraphPartition:
    """Node-block -> shard placement: seeded hash, ring replication."""

    kind = "graph"
    n_shards: int
    replication: int
    base: np.ndarray              # (n_nodes,) int32 primary shard per node
    seed: int = 0

    @staticmethod
    def build(n_nodes: int, n_shards: int, replication: int,
              seed: int = 0) -> "GraphPartition":
        _check(n_nodes, n_shards, replication)
        base = np.fromiter(
            (_splitmix64(i ^ (seed * 0x9E3779B97F4A7C15 & _MASK64))
             % n_shards for i in range(n_nodes)),
            dtype=np.int32, count=n_nodes)
        return GraphPartition(n_shards=n_shards, replication=replication,
                              base=base, seed=seed)

    def owners(self, key) -> tuple[int, ...]:
        _, node = key
        if node < len(self.base):
            b = int(self.base[node])
        else:                         # a node stitched in by live ingest
            b = _splitmix64(
                node ^ (self.seed * 0x9E3779B97F4A7C15 & _MASK64)
            ) % self.n_shards
        return tuple((b + r) % self.n_shards for r in range(self.replication))

    @property
    def bytes_imbalance(self) -> float:
        """max/mean node count across shards (blocks are equal-sized)."""
        counts = np.bincount(self.base, minlength=self.n_shards)
        return float(counts.max() / max(counts.mean(), 1e-12))


def partition_for_index(index, n_shards: int, replication: int,
                        seed: int = 0):
    """Pick the placement scheme matching the index family."""
    meta = index.meta
    if hasattr(meta, "list_nbytes"):        # ClusterIndexMeta
        return ClusterPartition.build(meta.list_nbytes, n_shards,
                                      replication)
    if hasattr(meta, "node_nbytes"):        # GraphIndexMeta
        return GraphPartition.build(meta.n_data, n_shards, replication,
                                    seed=seed)
    raise TypeError(f"cannot partition index with meta {type(meta)!r}")
