"""CLI entry: ``python -m repro.fleet`` → JSON fleet report on stdout.

Builds a synthetic workload analogue (``data/synth.py``), builds the
index, partitions it across the fleet and serves the query set under the
selected scenario; the report is bit-identical for a given ``--seed``.

Examples:

    python -m repro.fleet --shards 4 --replicas 2
    python -m repro.fleet --shards 8 --replicas 2 --hedge --index graph
    # open-loop Poisson at 300 QPS for 2 virtual seconds, 50ms SLO
    python -m repro.fleet --scenario poisson --rate 300 --duration 2
    # kill shard 1 mid-run, recover it, watch p99 (recall is unchanged)
    python -m repro.fleet --scenario poisson --replicas 2 \\
        --fail 1:0.5:1.5
    # let the autoscaler defend the SLO through a 4x burst
    python -m repro.fleet --scenario burst --rate 150 --duration 2 \\
        --autoscale --slo-ms 80
    # read-write mix: live inserts/deletes + background compaction
    python -m repro.fleet --scenario rw --write-rate 400 \\
        --n-updates 200 --delta-kb 64
    # multi-tenant: N workloads sharing the fleet's caches + bandwidth
    python -m repro.fleet --tenants tenants.json --cache-mb 4 \\
        --cache-policy weighted
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.cli import (add_common_args, add_exec_args, add_monitor_args,
                       add_obs_args, add_scenario_args,
                       autoscale_from_args, emit_json, emit_obs,
                       exec_fields_from_args, faults_from_args,
                       ingest_from_args, monitor_from_args,
                       pricebook_from_args, scenario_from_args,
                       tracer_from_args)
from repro.core.cluster_index import ClusterIndex
from repro.core.flat import exact_topk
from repro.core.graph_index import GraphIndex
from repro.core.types import (ClusterIndexParams, GraphIndexParams,
                              SearchParams)
from repro.data.synth import DatasetSpec, make_dataset
from repro.fleet.router import FleetConfig, run_fleet
from repro.tuning.space import STORAGE_ALIASES, resolve_storage


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.fleet",
        description="Serve a synthetic workload across a sharded, "
                    "replicated fleet and report tail latency, balance, "
                    "hedge and shed rates — under closed-loop or "
                    "open-loop (poisson/burst/trace) arrivals, with "
                    "optional fault injection and SLO autoscaling.")
    p.add_argument("--shards", type=int, default=4)
    p.add_argument("--replicas", type=int, default=2,
                   help="replication factor R (replica shards per segment)")
    p.add_argument("--index", choices=["cluster", "graph"],
                   default="cluster")
    p.add_argument("--n", type=int, default=2000,
                   help="synthetic dataset cardinality")
    p.add_argument("--dim", type=int, default=64)
    p.add_argument("--queries", type=int, default=64)
    p.add_argument("--k", type=int, default=10)
    p.add_argument("--nprobe", type=int, default=16)
    p.add_argument("--search-len", type=int, default=40)
    p.add_argument("--beamwidth", type=int, default=8)
    p.add_argument("--storage", default="tos",
                   help="storage preset: %s or a full preset name"
                        % "/".join(sorted(STORAGE_ALIASES)))
    p.add_argument("--concurrency", type=int, default=8,
                   help="in-service fleet queries (admission window)")
    p.add_argument("--shard-concurrency", type=int, default=4)
    p.add_argument("--queue-depth", type=int, default=16)
    p.add_argument("--cache-mb", type=float, default=0.0,
                   help="per-shard SLRU cache budget in MiB")
    p.add_argument("--nvme-gb", type=float, default=0.0,
                   help="per-instance local NVMe tier capacity in GiB "
                        "(0 = flat DRAM-over-remote hierarchy)")
    p.add_argument("--tier-policy", default="second-hit",
                   choices=["second-hit", "admit-always"],
                   help="NVMe promotion policy (needs --nvme-gb > 0)")
    p.add_argument("--nvme-writeback", action="store_true",
                   help="land compaction output on local NVMe first, "
                        "flush to the object store asynchronously "
                        "(needs --nvme-gb > 0)")
    p.add_argument("--hedge", action="store_true",
                   help="enable hedged requests (needs --replicas >= 2)")
    p.add_argument("--hedge-percentile", type=float, default=95.0)
    p.add_argument("--no-recall", action="store_true",
                   help="skip the exact ground-truth pass")
    t = p.add_argument_group("tenancy")
    t.add_argument("--tenants", default=None, metavar="SPEC.JSON",
                   help="serve N tenant workloads (JSON list of tenant "
                        "specs; see docs/tenancy.md) over this one fleet")
    t.add_argument("--cache-policy", default="shared",
                   choices=["shared", "static", "weighted"],
                   help="how the per-instance cache budget is split "
                        "across tenants (--tenants runs only)")
    t.add_argument("--no-solo", action="store_true",
                   help="skip the per-tenant solo baseline runs (no "
                        "interference ratios in the report)")
    add_exec_args(p)
    add_scenario_args(p)
    add_obs_args(p)
    add_monitor_args(p)
    add_common_args(p)
    return p


def fleet_config_from_args(args, storage) -> FleetConfig:
    """The one CLI-to-FleetConfig mapping (single- and multi-tenant).
    Config-level validation errors (e.g. tier knobs without --nvme-gb)
    surface as parser errors, not tracebacks."""
    try:
        return _fleet_config(args, storage)
    except ValueError as e:
        build_parser().error(str(e))


def _fleet_config(args, storage) -> FleetConfig:
    return FleetConfig(
        n_shards=args.shards, replication=args.replicas, storage=storage,
        concurrency=args.concurrency,
        shard_concurrency=args.shard_concurrency,
        queue_depth=args.queue_depth,
        cache_bytes=int(args.cache_mb * 2**20),
        cache_policy="slru" if args.cache_mb > 0 else "none",
        nvme_bytes=int(args.nvme_gb * 2**30),
        tier_policy=args.tier_policy,
        nvme_writeback=args.nvme_writeback,
        hedge=args.hedge, hedge_percentile=args.hedge_percentile,
        seed=args.seed,
        **exec_fields_from_args(args, build_parser()))


def validated_faults(args):
    """Parse --fail and range-check shard ids against --shards."""
    try:
        faults = faults_from_args(args)
    except ValueError as e:
        build_parser().error(str(e))
    if faults is not None:
        bad = [f.shard for f in faults.faults if f.shard >= args.shards]
        if bad:
            build_parser().error(f"--fail shard(s) {bad} out of range for "
                                 f"--shards {args.shards}")
    return faults


#: single-tenant workload flags that tenant specs own entirely — their
#: appearing alongside --tenants is a user error, not a silent no-op
#: (defaults come from the parser itself, so they can never drift)
_TENANT_OWNED_FLAGS = (
    "scenario", "rate", "duration", "arrivals", "slo_ms",
    "burst_factor", "burst_start", "burst_len", "trace_zipf_a",
    "write_rate", "n_updates", "delete_frac",
    "delta_kb", "flush_frac", "compaction_par",
    "index", "n", "dim", "queries", "k", "nprobe", "search_len",
    "beamwidth",
)


def run_tenancy(args, storage) -> int:
    """The --tenants path: N workloads over one shared fleet."""
    from repro.core.flat import exact_topk
    from repro.tenancy import (Tenant, load_tenant_specs,
                               materialize_tenant, measure_interference,
                               run_tenant_fleet)
    parser = build_parser()
    dead = [name for name in _TENANT_OWNED_FLAGS
            if getattr(args, name) != parser.get_default(name)]
    if dead:
        parser.error(
            f"--tenants runs take every workload axis from the tenant "
            f"spec file; --{'/--'.join(d.replace('_', '-') for d in dead)} "
            f"would be ignored — set it per tenant in the JSON instead")
    if args.cache_policy != "shared" and args.cache_mb <= 0:
        parser.error(
            f"--cache-policy {args.cache_policy} needs a cache budget "
            f"(--cache-mb > 0); with no cache there is nothing to "
            f"partition")
    try:
        specs = load_tenant_specs(args.tenants)
    except (OSError, ValueError) as e:
        build_parser().error(f"--tenants: {e}")
    faults = validated_faults(args)
    if args.autoscale:
        build_parser().error(
            "--autoscale composes with --tenants only through a fleet-"
            "wide SLO, which multi-tenant runs don't have (each tenant "
            "carries its own); drop one of the two flags")
    cfg = fleet_config_from_args(args, storage)

    def make_tenants() -> list[Tenant]:
        return [materialize_tenant(s, base_seed=cfg.seed, tid=i)
                for i, s in enumerate(specs)]

    # ground truth only needs each tenant's data/queries/update stream,
    # which the serving runs leave intact — keep the first materialised
    # list instead of paying the index builds a further time for recall
    first: list[Tenant] = []

    def tenants_once() -> list[Tenant]:
        made = make_tenants()
        if not first:
            first.extend(made)
        return made

    tracer = tracer_from_args(args)
    monitor = monitor_from_args(args, parser)
    pricebook = pricebook_from_args(args, parser)
    if monitor is not None and monitor.recall_target is not None:
        # live recall needs ground truth up front; tenant name -> gt
        import dataclasses as _dc
        gt_map = {}
        for t in tenants_once():
            if t.updates is None:
                gt_map[t.spec.name] = exact_topk(t.data, t.queries,
                                                 t.spec.k)[0]
        monitor = _dc.replace(monitor, gt_ids=gt_map)
    t0 = time.perf_counter()
    if args.no_solo or faults is not None:
        # interference baselines are only meaningful on a healthy fleet
        rep = run_tenant_fleet(tenants_once(), cfg, args.cache_policy,
                               faults=faults,
                               series_dt=args.series_dt, tracer=tracer,
                               monitor=monitor, pricebook=pricebook,
                               explain=bool(args.explain),
                               mrc=bool(args.mrc))
    else:
        rep = measure_interference(tenants_once, cfg, args.cache_policy,
                                   series_dt=args.series_dt,
                                   tracer=tracer, monitor=monitor,
                                   pricebook=pricebook,
                                   explain=bool(args.explain),
                                   mrc=bool(args.mrc))
    wall_s = time.perf_counter() - t0
    if rep.showback is not None:
        from repro.obs import format_showback
        print(format_showback(rep.showback), file=sys.stderr)
    from repro.obs import run_manifest
    out = dict(config=cfg.to_dict(), cache_policy=args.cache_policy,
               tenant_specs=[s.to_dict() for s in specs],
               report=rep.summary(),
               meta=run_manifest(seed=args.seed, config=cfg.to_dict(),
                                 wall_s=wall_s))
    emit_obs(out, args, tracer)
    if faults is not None:
        out["fault_schedule"] = faults.to_dicts()
    if not args.no_recall:
        recalls = {}
        for sl, t in zip(rep.tenants, first):
            if t.updates is not None:
                from repro.ingest.stream import churn_ground_truth
                gt = churn_ground_truth(t.data, queries=t.queries,
                                        k=t.spec.k, stream=t.updates)
            else:
                gt, _ = exact_topk(t.data, t.queries, t.spec.k)
            recalls[sl.name] = round(sl.recall_against(gt), 4)
        out["recall"] = recalls
    emit_json(out, args)
    return 0


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    try:
        storage = resolve_storage(args.storage)
    except KeyError as e:
        build_parser().error(str(e.args[0]))
    if args.tenants is not None:
        return run_tenancy(args, storage)
    try:
        scenario = scenario_from_args(args)
        autoscale = autoscale_from_args(args)
    except ValueError as e:
        build_parser().error(str(e))
    faults = validated_faults(args)
    if autoscale is not None and scenario.kind == "closed":
        build_parser().error(
            "--autoscale needs an open-loop --scenario (poisson/burst/"
            "trace): closed-loop sojourns measure drain position, which "
            "would pin the SLO controller at permanent scale-up")

    spec = DatasetSpec("fleet-analog", args.dim, "float32", args.n,
                       args.queries, n_clusters=max(8, min(64, args.n // 16)),
                       intrinsic_dim=min(32, args.dim), seed=args.seed)
    data, queries = make_dataset(spec)
    if args.index == "cluster":
        index = ClusterIndex.build(data, ClusterIndexParams(
            kmeans_iters=4, seed=args.seed))
        params = SearchParams(k=args.k, nprobe=args.nprobe)
    else:
        from repro.core.pq import default_pq_dims
        index = GraphIndex.build(data, GraphIndexParams(
            R=24, L_build=48, build_passes=1,
            pq_dims=default_pq_dims(args.dim), seed=args.seed))
        params = SearchParams(k=args.k, search_len=args.search_len,
                              beamwidth=args.beamwidth)

    cfg = fleet_config_from_args(args, storage)
    arrivals = scenario.make_arrivals(len(queries), cfg.concurrency,
                                      seed=args.seed)
    updates = None
    ingest_cfg = None
    if scenario.kind == "rw":
        protected = frozenset([index.meta.medoid]) \
            if args.index == "graph" else None
        updates = scenario.make_updates(data, seed=args.seed,
                                        protected=protected)
        ingest_cfg = ingest_from_args(args)
    # closed-loop sojourns measure drain position, not service time —
    # goodput-vs-SLO is only meaningful for open-loop arrivals (rw runs
    # its queries closed-loop too)
    slo_s = scenario.slo_s if scenario.kind not in ("closed", "rw") \
        else None
    tracer = tracer_from_args(args)
    parser = build_parser()
    monitor = monitor_from_args(args, parser)
    pricebook = pricebook_from_args(args, parser)
    gt_pre = None
    if monitor is not None:
        import dataclasses as _dc
        if scenario.kind == "rw":
            # freshness-lag SLO: alert when updates take longer than
            # the latency SLO to become visible
            monitor = _dc.replace(monitor,
                                  freshness_slo_s=args.slo_ms * 1e-3)
        if monitor.recall_target is not None:
            if updates is not None:
                parser.error("--recall-slo needs a pure-query scenario: "
                             "under churn the ground truth moves with "
                             "every applied update")
            gt_pre, _ = exact_topk(data, queries, args.k)
            monitor = _dc.replace(monitor, gt_ids=gt_pre)
    t0 = time.perf_counter()
    report = run_fleet(index, queries, params, cfg,
                       arrivals=arrivals, faults=faults,
                       autoscale=autoscale, slo_s=slo_s,
                       series_dt=args.series_dt,
                       updates=updates, ingest=ingest_cfg,
                       tracer=tracer, monitor=monitor,
                       pricebook=pricebook,
                       explain=bool(args.explain), mrc=bool(args.mrc))
    wall_s = time.perf_counter() - t0

    from repro.obs import run_manifest
    out = dict(config=cfg.to_dict(), index=args.index,
               scenario=scenario.to_dict(), report=report.summary(),
               meta=run_manifest(seed=args.seed, config=cfg.to_dict(),
                                 wall_s=wall_s))
    emit_obs(out, args, tracer)
    if faults is not None:
        out["fault_schedule"] = faults.to_dicts()
    if autoscale is not None:
        out["autoscale_config"] = autoscale.to_dict()
    if scenario.kind == "rw":
        out["ingest_config"] = ingest_cfg.to_dict()
        if updates is not None:
            out["update_stream"] = updates.to_dict()
    if not args.no_recall:
        if updates is not None:
            from repro.ingest.stream import churn_ground_truth
            gt = churn_ground_truth(data, queries=queries, k=args.k,
                                    stream=updates)
        elif gt_pre is not None:
            gt = gt_pre
        else:
            gt, _ = exact_topk(data, queries, args.k)
        out["recall"] = round(report.recall_against(gt), 4)
    emit_json(out, args)
    return 0


if __name__ == "__main__":
    sys.exit(main())
