"""Scan-resistant segmented LRU (SLRU) cache — the paper's cache policy
(§5.1: "scan-resistant LRU eviction policy [50]").

Two segments, both LRU-ordered:
* probation — first-time entries land here; a scan can only ever pollute
  this segment.
* protected — entries re-referenced while in probation are promoted;
  protected evictions demote back to probation (not out of the cache).

Capacities are in bytes (cache sizes in the paper are 1/4/8 GB).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Hashable, Iterable

CACHE_POLICIES = ("none", "slru", "pinned")


def make_cache(policy: str, capacity_bytes: int = 0,
               pinned_keys: Iterable | None = None):
    """Instantiate the segment cache for a policy name (or None for no
    cache).  The single construction point shared by the serving engine and
    the fleet shard servers — unknown policies fail here, loudly.
    """
    if policy == "none":
        return None
    if policy == "slru":
        return SLRUCache(capacity_bytes) if capacity_bytes > 0 else None
    if policy == "pinned":
        if pinned_keys is None:
            raise ValueError(
                "cache_policy='pinned' requires pinned_keys (a set of "
                "object keys to pin)")
        keys = set(pinned_keys)
        return PinnedCache(keys) if keys else None
    raise ValueError(
        f"unknown cache policy {policy!r}; one of {CACHE_POLICIES}")


class SLRUCache:
    def __init__(self, capacity_bytes: int, protected_frac: float = 0.8):
        assert capacity_bytes >= 0
        self.capacity = int(capacity_bytes)
        self.protected_frac = float(protected_frac)
        self.protected_cap = int(capacity_bytes * protected_frac)
        self.probation: OrderedDict[Hashable, int] = OrderedDict()
        self.protected: OrderedDict[Hashable, int] = OrderedDict()
        self.probation_bytes = 0
        self.protected_bytes = 0
        self.hits = 0
        self.misses = 0
        #: optional ``fn(key, nbytes)`` fired on every *capacity* eviction
        #: (not on explicit remove/invalidate) — the hook ghost lists and
        #: other second-chance structures attach to.
        self.on_evict: Callable[[Hashable, int], None] | None = None
        #: optional pure observer of the access stream: ``record_get(key,
        #: hit)`` on every lookup, ``record_put(key, nbytes)`` on every
        #: miss-fill.  The sampled-ghost MRC estimator
        #: (:mod:`repro.obs.mrc`) attaches here; observers read, never
        #: mutate, so cache behaviour is byte-identical with one attached.
        self.observer = None

    # ------------------------------------------------------------ stats --
    @property
    def used_bytes(self) -> int:
        return self.probation_bytes + self.protected_bytes

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def reset_stats(self) -> None:
        self.hits = 0
        self.misses = 0

    def __contains__(self, key: Hashable) -> bool:
        return key in self.probation or key in self.protected

    def __len__(self) -> int:
        return len(self.probation) + len(self.protected)

    # ------------------------------------------------------------ logic --
    def get(self, key: Hashable) -> bool:
        """Lookup; promotes on probation hit.  Returns hit/miss."""
        hit = self._get(key)
        if self.observer is not None:
            self.observer.record_get(key, hit)
        return hit

    def _get(self, key: Hashable) -> bool:
        if self.capacity == 0:
            self.misses += 1
            return False
        if key in self.protected:
            self.protected.move_to_end(key)
            self.hits += 1
            return True
        if key in self.probation:
            size = self.probation.pop(key)
            self.probation_bytes -= size
            self._insert_protected(key, size)
            self.hits += 1
            return True
        self.misses += 1
        return False

    def put(self, key: Hashable, nbytes: int) -> None:
        """Insert after a miss-fetch.  New entries go to probation."""
        if self.observer is not None:
            self.observer.record_put(key, nbytes)
        if self.capacity == 0 or nbytes > self.capacity:
            return
        if key in self.protected or key in self.probation:
            return
        self.probation[key] = nbytes
        self.probation_bytes += nbytes
        self._evict_probation()

    def _insert_protected(self, key: Hashable, nbytes: int) -> None:
        self.protected[key] = nbytes
        self.protected_bytes += nbytes
        # demote protected LRU back to probation until it fits
        while self.protected_bytes > self.protected_cap and self.protected:
            k, s = self.protected.popitem(last=False)
            self.protected_bytes -= s
            self.probation[k] = s
            self.probation_bytes += s
        self._evict_probation()

    def _evict_probation(self) -> None:
        while self.used_bytes > self.capacity and self.probation:
            k, s = self.probation.popitem(last=False)
            self.probation_bytes -= s
            if self.on_evict is not None:
                self.on_evict(k, s)

    # ---------------------------------------------------------- resizing --
    def set_capacity(self, capacity_bytes: int) -> None:
        """Resize the byte budget in place (the weighted-quota policy's
        reallocation step).  A shrink demotes protected overflow and then
        evicts probation LRU-first until the cache fits the new budget;
        a grow simply raises the ceilings — content is preserved."""
        if capacity_bytes < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity_bytes}")
        self.capacity = int(capacity_bytes)
        self.protected_cap = int(capacity_bytes * self.protected_frac)
        while self.protected_bytes > self.protected_cap and self.protected:
            k, s = self.protected.popitem(last=False)
            self.protected_bytes -= s
            self.probation[k] = s
            self.probation_bytes += s
        self._evict_probation()

    # ----------------------------------------------------- invalidation --
    def remove(self, key: Hashable) -> int:
        """Drop ``key`` from whichever segment holds it (compaction
        rewrote the object, so the cached copy is stale).  Returns the
        bytes freed (0 when the key was not cached); byte accounting is
        adjusted on the segment the entry actually occupied."""
        if key in self.protected:
            size = self.protected.pop(key)
            self.protected_bytes -= size
            return size
        if key in self.probation:
            size = self.probation.pop(key)
            self.probation_bytes -= size
            return size
        return 0

    def invalidate(self, key: Hashable) -> bool:
        """``remove`` as a hit/miss predicate (True when a stale copy
        was actually dropped)."""
        present = key in self
        self.remove(key)
        return present


class PinnedCache:
    """Fixed-content cache: always hits on the pinned key set.

    Models the paper's A3 suggestion for DiskANN under non-IOPS-saturated
    settings: pin the entry-point neighbourhood (Fig 23 shows those rounds
    carry near-1 hit rates) instead of running a general LRU.
    """

    def __init__(self, keys: set):
        self.keys = set(keys)
        self.hits = 0
        self.misses = 0

    @property
    def used_bytes(self) -> int:  # bookkeeping parity with SLRUCache
        return 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def reset_stats(self) -> None:
        self.hits = 0
        self.misses = 0

    def __contains__(self, key) -> bool:
        return key in self.keys

    def get(self, key) -> bool:
        if key in self.keys:
            self.hits += 1
            return True
        self.misses += 1
        return False

    def put(self, key, nbytes: int) -> None:
        pass                     # contents are fixed

    # ----------------------------------------------------- invalidation --
    def remove(self, key) -> int:
        """Un-pin a rewritten object: its pinned copy is stale and the
        policy cannot refresh content, so the key stops hitting."""
        self.keys.discard(key)
        return 0                 # pinned bookkeeping carries no bytes

    def invalidate(self, key) -> bool:
        present = key in self.keys
        self.keys.discard(key)
        return present
