"""repro.exec — the batched execution backend (kernel <-> serving loop).

Three pieces close the loop between the Pallas kernels and the serving
simulation (see ``docs/execution.md``):

* :mod:`repro.exec.batched` — real pad-to-tile batched execution of the
  fused top-k kernel (property-tested bit-identical to the per-query
  reference oracles);
* :mod:`repro.exec.calibrate` — measures that backend over a
  (dim, pq_m, batch) grid and persists a :class:`CalibrationTable`, the
  measured replacement for the analytic ``ComputeSpec`` constants;
* :mod:`repro.exec.backend` — the per-shard :class:`KernelBackend`
  coalescer that batches concurrent jobs within a window and prices
  them from the table (``--backend kernel`` on the fleet/tuning CLIs).
"""
from repro.exec.backend import KernelBackend
from repro.exec.batched import (CAND_TILE, QUERY_TILE, batched_topk,
                                coalesce_scan, pad_amount, scan_topk_oracle)
from repro.exec.table import (DEFAULT_TABLE_PATH, CalibEntry,
                              CalibrationTable, load_table)

__all__ = [
    "KernelBackend",
    "CalibEntry", "CalibrationTable", "DEFAULT_TABLE_PATH", "load_table",
    "QUERY_TILE", "CAND_TILE", "pad_amount",
    "batched_topk", "scan_topk_oracle", "coalesce_scan",
]
