"""Calibration harness: measure the kernel backend, persist the table.

Times the *actual* execution backend — :func:`repro.exec.batched.batched_topk`
(fused distance + top-k, the serving scan op) and :func:`repro.kernels.ops.
adc_lookup` — over a grid of (dim, pq_m, batch size) points, converts each
point to a ``unit_s`` (seconds per distance computation / per ADC lookup)
and persists a :class:`~repro.exec.table.CalibrationTable` JSON.  On this
container the backend is Pallas interpret / XLA:CPU; on a TPU the same
calls compile to Mosaic and the measured numbers change accordingly —
which is the point: pricing follows the hardware, not hand-set constants.

Each dist point is cross-checked against the roofline model
(:data:`repro.launch.roofline.HW`): achieved FLOP/s above the hardware
peak would mean the timer is lying, so that fails loudly; the achieved
fraction is recorded in the table meta either way.

CLI::

    python -m repro.exec.calibrate --out calibration.json [--quick]

The committed default table (``calibration_default.json``) was generated
with this harness once; re-run to re-measure for your host.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from repro.exec.batched import batched_topk
from repro.exec.table import CalibEntry, CalibrationTable
from repro.kernels import ops

__all__ = ["measure_table", "main"]

#: (B queries, N candidates) points per dim — the batch axis is B*N pairs.
DIST_POINTS = [(1, 128), (4, 512), (8, 1024), (32, 2048)]
DIST_POINTS_QUICK = [(1, 128), (8, 1024)]
DIMS = [16, 32, 64, 128]
DIMS_QUICK = [32, 64]
#: (n codes, ) points per pq_m — the batch axis is n*m lookups.
ADC_POINTS = [256, 2048, 16384]
ADC_POINTS_QUICK = [256, 2048]
PQ_MS = [8, 16]
PQ_MS_QUICK = [8]
TOPK = 10


def _time(fn, iters: int, warmup: int) -> float:
    """Median wall-clock seconds per call (warmed; result synced)."""
    for _ in range(warmup):
        fn()
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        samples.append(time.perf_counter() - t0)
    samples.sort()
    return samples[len(samples) // 2]


def measure_table(quick: bool = False, *, iters: int | None = None,
                  seed: int = 0, verbose: bool = False) -> CalibrationTable:
    """Run the measurement grid and build a :class:`CalibrationTable`."""
    iters = iters or (2 if quick else 5)
    warmup = 1 if quick else 2
    dims = DIMS_QUICK if quick else DIMS
    dist_points = DIST_POINTS_QUICK if quick else DIST_POINTS
    pq_ms = PQ_MS_QUICK if quick else PQ_MS
    adc_points = ADC_POINTS_QUICK if quick else ADC_POINTS
    rng = np.random.default_rng(seed)

    import jax
    from repro.launch.roofline import HW

    entries: list[CalibEntry] = []
    rooflines: list[dict] = []
    for dim in dims:
        for bq, n in dist_points:
            q = rng.standard_normal((bq, dim)).astype(np.float32)
            x = rng.standard_normal((n, dim)).astype(np.float32)
            sec = _time(lambda: batched_topk(q, x, TOPK), iters, warmup)
            pairs = bq * n
            unit_s = sec / pairs
            achieved = 2.0 * dim * pairs / sec
            frac = achieved / HW["peak_flops"]
            if frac > 1.0:
                raise RuntimeError(
                    f"calibration point dim={dim} pairs={pairs} measured "
                    f"{achieved:.3e} FLOP/s above the roofline peak "
                    f"{HW['peak_flops']:.3e} — timer is broken")
            entries.append(CalibEntry(
                op="dist", dim=dim, pq_m=0, batch=pairs, dtype="float32",
                unit_s=unit_s, us_per_call=sec * 1e6))
            rooflines.append(dict(dim=dim, batch=pairs,
                                  achieved_gflops=round(achieved / 1e9, 3),
                                  roofline_frac=round(frac, 9)))
            if verbose:
                print(f"  dist dim={dim:<4} pairs={pairs:<6} "
                      f"{sec * 1e6:9.1f} us/call  "
                      f"{achieved / 1e9:8.3f} GFLOP/s", file=sys.stderr)
    for m in pq_ms:
        for n in adc_points:
            codes = rng.integers(0, 256, (n, m), dtype=np.uint8)
            table = rng.standard_normal((m, 256)).astype(np.float32)
            sec = _time(
                lambda: np.asarray(ops.adc_lookup(codes, table)),
                iters, warmup)
            lookups = n * m
            entries.append(CalibEntry(
                op="adc", dim=0, pq_m=m, batch=lookups, dtype="uint8",
                unit_s=sec / lookups, us_per_call=sec * 1e6))
            if verbose:
                print(f"  adc  m={m:<6} codes={n:<6} "
                      f"{sec * 1e6:9.1f} us/call", file=sys.stderr)

    meta = dict(backend=jax.default_backend(),
                interpret=ops.default_interpret(),
                jax=jax.__version__,
                quick=bool(quick), iters=iters, topk=TOPK,
                rooflines=rooflines,
                generated_by="python -m repro.exec.calibrate")
    return CalibrationTable(entries, meta=meta)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.exec.calibrate",
        description="Measure the kernel backend and write a "
                    "CalibrationTable JSON.")
    ap.add_argument("--out", default="calibration.json",
                    help="output path (default: %(default)s)")
    ap.add_argument("--quick", action="store_true",
                    help="small grid, few iters (CI smoke)")
    ap.add_argument("--iters", type=int, default=None,
                    help="timing iterations per point")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--summary", action="store_true",
                    help="print the table summary JSON to stdout")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    table = measure_table(quick=args.quick, iters=args.iters,
                          seed=args.seed, verbose=True)
    table.save(args.out)
    print(f"wrote {args.out}: {len(table.entries)} entries in "
          f"{time.perf_counter() - t0:.1f}s", file=sys.stderr)
    if args.summary:
        print(json.dumps(table.describe(), indent=1, sort_keys=True))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
