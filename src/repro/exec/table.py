"""Measured compute pricing: the :class:`CalibrationTable`.

The analytic cost model (:mod:`repro.core.cost_model`) prices shard
compute from two hand-set constants — ``dist_flops_per_s`` and
``adc_lookup_s``.  The calibration table replaces those constants with
*measurements*: :mod:`repro.exec.calibrate` times the actual kernel
backend (Pallas interpret / XLA:CPU here, Mosaic on a TPU) over a grid
of ``(dim, pq_m, batch size, dtype)`` points and persists one
``unit_s`` — seconds per distance computation (dist ops) or seconds per
table lookup (ADC ops) — per grid point.

Lookups mirror the analytic formula exactly, so a table is a drop-in
pricing source::

    seconds = d_dist * unit_s_dist(dim, batch)
            + d_pq * max(pq_m, 1) * unit_s_adc(pq_m, batch)

where the analytic model would use ``2 * dim / dist_flops_per_s`` and
``adc_lookup_s``.  The batch axis is what the coalescer buys: larger
batches amortize dispatch overhead and fill MXU tiles, so ``unit_s``
falls with batch size and the table interpolates (linearly in log batch
size, clamped at the measured ends) between grid points.

Measurements vary per host, so a table generated once with the
calibrate CLI is committed as ``calibration_default.json`` and loaded
by default — simulations stay deterministic across machines while still
being priced from real kernel timings.  Re-measure with::

    python -m repro.exec.calibrate --out my_table.json
"""
from __future__ import annotations

import dataclasses
import json
import math
import os

__all__ = ["CalibEntry", "CalibrationTable", "DEFAULT_TABLE_PATH",
           "load_table"]

#: The committed, measured-once table (see module docstring).
DEFAULT_TABLE_PATH = os.path.join(os.path.dirname(__file__),
                                  "calibration_default.json")


@dataclasses.dataclass(frozen=True)
class CalibEntry:
    """One measured grid point.

    ``op`` is ``"dist"`` (batched L2 distance + fused top-k; ``dim`` set,
    ``pq_m`` 0) or ``"adc"`` (PQ table lookup; ``pq_m`` set, ``dim`` 0).
    ``batch`` is the batch-size axis the coalescer moves along: total
    query·candidate pairs for dist, total codes scanned for adc.
    ``unit_s`` is seconds per distance computation / per single lookup.
    """

    op: str
    dim: int
    pq_m: int
    batch: int
    dtype: str
    unit_s: float
    us_per_call: float = 0.0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _interp_log(points: list[tuple[int, float]], batch: float) -> float:
    """Piecewise-linear interpolation of unit_s over log(batch), clamped
    at the measured ends.  ``points`` is sorted by batch ascending."""
    if batch <= points[0][0]:
        return points[0][1]
    if batch >= points[-1][0]:
        return points[-1][1]
    for (b0, u0), (b1, u1) in zip(points, points[1:]):
        if b0 <= batch <= b1:
            if b1 == b0:
                return u0
            f = (math.log(batch) - math.log(b0)) / \
                (math.log(b1) - math.log(b0))
            return u0 + f * (u1 - u0)
    return points[-1][1]                       # pragma: no cover


class CalibrationTable:
    """Measured ``unit_s`` grid with nearest-bucket + log-interp lookup."""

    def __init__(self, entries: list[CalibEntry], meta: dict | None = None):
        if not any(e.op == "dist" for e in entries):
            raise ValueError("calibration table has no 'dist' entries")
        self.entries = list(entries)
        self.meta = dict(meta or {})
        # op -> key (dim or pq_m) -> [(batch, unit_s)] sorted by batch
        self._grid: dict[str, dict[int, list[tuple[int, float]]]] = {}
        for e in self.entries:
            key = e.dim if e.op == "dist" else e.pq_m
            self._grid.setdefault(e.op, {}).setdefault(key, []).append(
                (e.batch, e.unit_s))
        for buckets in self._grid.values():
            for pts in buckets.values():
                pts.sort()

    # -- lookups ------------------------------------------------------

    def _nearest(self, op: str, key: int) -> list[tuple[int, float]]:
        buckets = self._grid.get(op)
        if not buckets:
            raise KeyError(f"no '{op}' entries in calibration table")
        if key in buckets:
            return buckets[key]
        # nearest bucket by log distance (dims/pq_m are geometric-ish)
        best = min(buckets, key=lambda k: (abs(math.log(max(key, 1))
                                               - math.log(max(k, 1))), k))
        return buckets[best]

    def dist_unit_s(self, dim: int, batch: float = 1.0) -> float:
        """Seconds per query·candidate distance computation."""
        return _interp_log(self._nearest("dist", dim), max(batch, 1.0))

    def adc_unit_s(self, pq_m: int, batch: float = 1.0) -> float:
        """Seconds per single ADC table lookup."""
        if "adc" not in self._grid:
            return 0.0
        return _interp_log(self._nearest("adc", pq_m), max(batch, 1.0))

    def plan_seconds(self, d_dist: int, d_pq: int, dim: int, pq_m: int,
                     *, dist_batch: float | None = None,
                     adc_batch: float | None = None) -> float:
        """Calibrated mirror of
        :func:`repro.core.cost_model.plan_compute_seconds`.

        ``dist_batch`` / ``adc_batch`` let the coalescer price one job's
        work at the *batch's* aggregate operating point (defaults: the
        job's own work — a batch of one).
        """
        s = 0.0
        if d_dist:
            s += d_dist * self.dist_unit_s(
                dim, d_dist if dist_batch is None else dist_batch)
        if d_pq:
            lookups = d_pq * max(pq_m, 1)
            s += lookups * self.adc_unit_s(
                pq_m, lookups if adc_batch is None else adc_batch)
        return s

    def dist_flops_per_s(self, dim: int, batch: float = 1.0) -> float:
        """Equivalent of the analytic ``dist_flops_per_s`` constant at
        one operating point (2·dim FLOPs per distance computation)."""
        return 2.0 * dim / self.dist_unit_s(dim, batch)

    # -- persistence --------------------------------------------------

    def to_dict(self) -> dict:
        return dict(version=1, meta=self.meta,
                    entries=[e.to_dict() for e in self.entries])

    @classmethod
    def from_dict(cls, d: dict) -> "CalibrationTable":
        entries = [CalibEntry(**row) for row in d["entries"]]
        return cls(entries, meta=d.get("meta"))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "CalibrationTable":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    def describe(self) -> dict:
        """Small summary block for bench/report meta."""
        dims = sorted({e.dim for e in self.entries if e.op == "dist"})
        pq_ms = sorted({e.pq_m for e in self.entries if e.op == "adc"})
        return dict(backend=self.meta.get("backend", "?"),
                    n_entries=len(self.entries), dims=dims, pq_ms=pq_ms)


def load_table(path: str | None = None) -> CalibrationTable:
    """Load a calibration table; ``None`` means the committed default."""
    return CalibrationTable.load(path or DEFAULT_TABLE_PATH)
