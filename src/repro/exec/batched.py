"""Pad-to-tile batched execution of the fused top-k kernel.

The coalescer's data-plane contract: many concurrent scan jobs against
one shard's candidate pool become *one* MXU-shaped ``fused_topk``
dispatch.  Queries pad up to the f32 sublane tile (8) and candidates up
to the lane tile (128); candidate padding is masked inside the kernel
(``n_total``), query padding rows are computed and dropped — pad waste,
which the occupancy gauges account for.

Bit-exactness contract (property-tested in ``tests/test_exec.py``):
:func:`batched_topk` result *ids* are identical to the per-query
:func:`scan_topk_oracle` built on :mod:`repro.kernels.ref`, including
tie-break order for duplicate distances, for ragged batch sizes and
``k > n_candidates`` (tail filled with ``(+inf, -1)``).  Both sides
canonicalize each row by ``(distance, id)``, which pins the order even
where float reduction order could differ.  Distances are bit-identical
too whenever the inputs are integer-valued (sums below 2**24 are exact
in f32 regardless of association); for arbitrary floats the kernel and
the reference accumulate in different orders, so distances agree only
to the last ulp.
"""
from __future__ import annotations

import numpy as np

from repro.kernels import ops

__all__ = ["QUERY_TILE", "CAND_TILE", "pad_amount", "batched_topk",
           "scan_topk_oracle", "coalesce_scan"]

#: MXU-facing tile sizes for float32 (sublane x lane — see the Pallas
#: guide's tiling table; the MXU itself is 128x128).
QUERY_TILE = 8
CAND_TILE = 128


def pad_amount(n: int, tile: int) -> int:
    """Rows of padding needed to round ``n`` up to a multiple of ``tile``."""
    return (-int(n)) % tile


def _canonicalize(vals: np.ndarray, ids: np.ndarray) -> None:
    """Sort each row by (distance, id) in place — the tie-break contract."""
    for i in range(vals.shape[0]):
        order = np.lexsort((ids[i], vals[i]))
        vals[i] = vals[i][order]
        ids[i] = ids[i][order]


def batched_topk(qs, x, k: int, *, interpret: bool | None = None):
    """Cross-query fused top-k with explicit pad-to-tile.

    ``qs`` is a ragged batch of B queries (B, D); ``x`` the shared
    candidate matrix (N, D).  Queries are zero-padded to a QUERY_TILE
    multiple and dispatched as ONE ``ops.l2_topk`` call with tile-shaped
    blocks (the kernel pads/masks candidates to CAND_TILE internally).
    Returns ``(vals (B, k) f32, ids (B, k) i32)`` with rows sorted by
    (distance, id); when ``k > N`` the tail is ``(+inf, -1)``.
    """
    qs = np.ascontiguousarray(np.asarray(qs, dtype=np.float32))
    x = np.ascontiguousarray(np.asarray(x, dtype=np.float32))
    B = qs.shape[0]
    N = x.shape[0]
    out_vals = np.full((B, k), np.inf, dtype=np.float32)
    out_ids = np.full((B, k), -1, dtype=np.int32)
    if B == 0 or N == 0 or k == 0:
        return out_vals, out_ids
    k_eff = min(k, N)
    padq = pad_amount(B, QUERY_TILE)
    qp = np.pad(qs, ((0, padq), (0, 0))) if padq else qs
    vals, ids = ops.l2_topk(qp, x, k_eff, block_q=QUERY_TILE,
                            block_n=CAND_TILE, interpret=interpret)
    out_vals[:, :k_eff] = np.asarray(vals)[:B]
    out_ids[:, :k_eff] = np.asarray(ids)[:B]
    _canonicalize(out_vals, out_ids)
    return out_vals, out_ids


def scan_topk_oracle(qs, x, k: int):
    """Per-query oracle on the kernel-free :mod:`repro.kernels.ref` path.

    Same output contract as :func:`batched_topk` (shape, (+inf, -1)
    fill, (distance, id) row order) but computed one query at a time
    from the full reference distance matrix — no batching, no padding.
    """
    qs = np.asarray(qs, dtype=np.float32)
    x = np.asarray(x, dtype=np.float32)
    B = qs.shape[0]
    N = x.shape[0]
    out_vals = np.full((B, k), np.inf, dtype=np.float32)
    out_ids = np.full((B, k), -1, dtype=np.int32)
    if B == 0 or N == 0 or k == 0:
        return out_vals, out_ids
    k_eff = min(k, N)
    row_ids = np.arange(N, dtype=np.int32)
    for i in range(B):
        d = np.asarray(ops.ref.l2_distance_ref(qs[i:i + 1], x))[0]
        order = np.lexsort((row_ids, d))[:k_eff]
        out_vals[i, :k_eff] = d[order]
        out_ids[i, :k_eff] = row_ids[order]
    _canonicalize(out_vals, out_ids)
    return out_vals, out_ids


def coalesce_scan(queries, x, global_ids, k: int, *,
                  interpret: bool | None = None):
    """Execute a coalesced batch and scatter results back per owner.

    ``queries`` is the list of B owning jobs' query vectors; ``x`` the
    shard's candidate rows with ``global_ids`` giving each row's vector
    id.  One batched dispatch, then row ``i`` of the padded result is
    scattered back to job ``i`` as ``(dists, global ids)`` — padding
    rows and the ``k > N`` tail never leak (-1 ids stay -1).
    """
    gid = np.asarray(global_ids, dtype=np.int64)
    vals, idx = batched_topk(queries, x, k, interpret=interpret)
    out = []
    for i in range(len(queries)):
        valid = idx[i] >= 0
        mapped = np.where(valid, gid[np.clip(idx[i], 0, None)], -1)
        out.append((vals[i].copy(), mapped.astype(np.int64)))
    return out
